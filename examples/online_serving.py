"""Online autotuning demo: wisdom misses become tuning work, live.

A matmul WisdomKernel starts with an *empty* wisdom dir — every launch
falls through the §4.5 heuristic to the default config. With the online
tuner attached, synthetic traffic drives the whole loop:

  miss detection -> budgeted cost-model screening -> epsilon-greedy live
  trials (successive halving) -> confident winner promoted into wisdom
  with ``online`` provenance -> next launch selects it at tier "exact".

Run: PYTHONPATH=src python examples/online_serving.py
"""

import os
import tempfile

import numpy as np

from repro.core import Wisdom, WisdomKernel, get_device, get_kernel
from repro.online import enable_online_tuning
from repro.tuner.runner import CostModelEvaluator
from repro.tuner.strategies import tune_exhaustive


def main():
    tmp = tempfile.mkdtemp(prefix="kl-online-")
    wisdom_dir = os.path.join(tmp, "wisdom")

    builder = get_kernel("matmul")
    kernel = WisdomKernel(builder, wisdom_dir=wisdom_dir,
                          device_kind="tpu-v5e", backend="reference")
    svc = enable_online_tuning(kernel, objective="costmodel", seed=0)

    rng = np.random.default_rng(0)
    a = rng.standard_normal((256, 256)).astype(np.float32)
    b = rng.standard_normal((256, 256)).astype(np.float32)

    ev = CostModelEvaluator(builder, (256, 256, 256), "float32",
                            get_device("tpu-v5e"), verify="none")
    offline = tune_exhaustive(builder.space, ev)
    print(f"offline optimum (exhaustive, {len(offline.evaluations)} evals): "
          f"{offline.best_score_us:.2f}us  {offline.best_config}")

    last_tier = None
    for i in range(1, 301):
        kernel(a, b)
        st = kernel.stats[-1]
        if st.tier != last_tier:
            print(f"launch {i:3d}: tier -> {st.tier:8s} "
                  f"(simulated {ev(st.config).score_us:7.2f}us)")
            last_tier = st.tier
        if svc.promotions() and st.tier == "exact":
            break

    promo = svc.promotions()[0]
    print(f"\npromoted after {svc.status()['launches']} launches: "
          f"{promo.record.config}")
    print(f"  incumbent was {promo.incumbent_score_us:.2f}us, promoted "
          f"{promo.record.score_us:.2f}us "
          f"({100 * promo.improvement:.0f}% faster), "
          f"ratio to offline optimum "
          f"{promo.record.score_us / offline.best_score_us:.3f}")
    print(f"  provenance: strategy={promo.record.provenance['strategy']} "
          f"evals={promo.record.provenance['evaluations']} "
          f"live={promo.record.provenance['live_measurements']}")

    s = svc.status()
    print(f"\ntraffic: {s['launches']} launches, {s['trials']} trials "
          f"({100 * s['trials'] / s['launches']:.0f}%), "
          f"{s['screens']} cost-model screens, "
          f"{1e6 * s['overhead_per_launch_s']:.0f}us overhead/launch")
    w = Wisdom.load("matmul", wisdom_dir)
    print(f"wisdom file now holds {len(w)} record(s) at {wisdom_dir}")


if __name__ == "__main__":
    main()
