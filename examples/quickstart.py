"""Quickstart: the Kernel Launcher flow on the matmul kernel, end to end.

  1. define/launch a tunable kernel (default config),
  2. capture the launch (KERNEL_LAUNCHER_CAPTURE),
  3. replay-tune it for this device,
  4. relaunch: the wisdom-selected config now wins.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

import numpy as np

os.environ.setdefault("KERNEL_LAUNCHER_CAPTURE", "matmul")

from repro.core import WisdomKernel, get_kernel, list_captures  # noqa: E402
from repro.tuner import CostModelEvaluator, tune_capture        # noqa: E402
from repro.core import get_device                               # noqa: E402


def main():
    tmp = tempfile.mkdtemp(prefix="kl-quickstart-")
    os.environ["KERNEL_LAUNCHER_CAPTURE_DIR"] = f"{tmp}/captures"
    os.environ["KERNEL_LAUNCHER_WISDOM_DIR"] = f"{tmp}/wisdom"

    rng = np.random.default_rng(0)
    a = rng.standard_normal((512, 1024)).astype(np.float32)
    b = rng.standard_normal((1024, 512)).astype(np.float32)

    builder = get_kernel("matmul")
    kernel = WisdomKernel(builder, device_kind="tpu-v5e")

    # 1+2: launch (runs + captures; reference path on CPU, Pallas on TPU)
    c = kernel(a, b)
    print(f"launch #1: tier={kernel.stats[-1].tier} "
          f"config={kernel.stats[-1].config}")

    # 3: replay the capture through the tuner (Bayesian, simulated v5e)
    cap = list_captures()[0]
    os.environ.pop("KERNEL_LAUNCHER_CAPTURE")
    res = tune_capture(cap, "tpu-v5e", strategy="bayes", max_evals=80,
                       time_budget_s=60)
    print(f"tuned: best={res.best_score_us:.1f}us after "
          f"{len(res.evaluations)} evals -> {res.best_config}")

    # 4: relaunch — runtime selection now finds the tuned record
    kernel.invalidate()
    c2 = kernel(a, b)
    st = kernel.stats[-1]
    print(f"launch #2: tier={st.tier} config={st.config}")
    np.testing.assert_allclose(np.asarray(c), np.asarray(c2), rtol=1e-4,
                               atol=1e-4)

    ev = CostModelEvaluator(builder, (512, 512, 1024), "float32",
                            get_device("tpu-v5e"), verify="none")
    t_default = ev(builder.default_config()).score_us
    t_tuned = ev(res.best_config).score_us
    print(f"simulated v5e time: default={t_default:.1f}us "
          f"tuned={t_tuned:.1f}us ({t_default / t_tuned:.2f}x)")


if __name__ == "__main__":
    main()
