"""End-to-end driver: train a ~100M-param LM for a few hundred steps on the
deterministic synthetic pipeline, with checkpoints, watchdog, and restart —
kill it mid-run and re-invoke to see it resume.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch ID]
"""

import argparse

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.data import SyntheticTokenDataset
from repro.models import build_model
from repro.optim import AdamW, cosine_schedule
from repro.runtime import StepWatchdog
from repro.runtime.driver import TrainDriver
from repro.train import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    args = ap.parse_args()

    # ~100M-param variant of the chosen architecture (CPU-trainable)
    cfg = get_arch(args.arch).reduced(
        n_layers=4, d_model=512, n_heads=8, n_kv_heads=4, d_head=64,
        d_ff=2048, vocab=8192, max_seq=4096)
    model = build_model(cfg, remat=True)
    print(f"arch={cfg.name} params~{cfg.n_params()/1e6:.1f}M")

    optimizer = AdamW(lr=cosine_schedule(3e-4, warmup=20,
                                         total=args.steps))
    dataset = SyntheticTokenDataset(vocab=cfg.vocab, seq=args.seq,
                                    global_batch=args.batch, seed=17)
    driver = TrainDriver(
        model=model, optimizer=optimizer,
        train_step=jax.jit(make_train_step(model, optimizer,
                                           microbatches=2)),
        dataset=dataset,
        ckpt=CheckpointManager(args.ckpt_dir, keep=3, save_every=25),
        total_steps=args.steps,
        watchdog=StepWatchdog(),
        log_every=10,
    )
    out = driver.run(jax.random.PRNGKey(0))
    losses = [h["loss"] for h in out["history"]]
    if losses:
        print(f"loss: first={losses[0]:.3f} last={losses[-1]:.3f}")
    print(f"final checkpoint: {out['final_checkpoint']}")
    if out["stragglers"]:
        print(f"stragglers observed: {len(out['stragglers'])}")


if __name__ == "__main__":
    main()
