"""Serve a small LM with batched requests through the continuous batcher:
submit more requests than slots, watch cohorts drain, print throughput.

Run: PYTHONPATH=src python examples/serve_lm.py [--requests 8 --slots 4]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import build_model
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced(
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_head=32,
        d_ff=1024, vocab=4096)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, n_slots=args.slots, max_seq=128,
                      temperature=args.temperature)

    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=int(rng.integers(4, 12)),
                              dtype=np.int32)
        ok = eng.submit(Request(rid, prompt, max_new_tokens=args.max_new))
        print(f"submit #{rid} prompt_len={len(prompt)} "
              f"{'ok' if ok else 'REJECTED'}")

    t0 = time.perf_counter()
    out = eng.run()
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in out.values())
    for rid, toks in sorted(out.items()):
        print(f"request {rid}: {toks}")
    print(f"{total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s across {args.slots} slots, "
          f"{eng.steps_run} decode steps)")


if __name__ == "__main__":
    main()
