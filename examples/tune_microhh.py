"""The paper's evaluation, miniaturized: tune both MicroHH kernels for all
16 scenarios and print the portability matrix + PPM summary — then show the
runtime selection picking per-scenario winners.

Run: PYTHONPATH=src python examples/tune_microhh.py [--max-evals 100]
"""

import argparse
import tempfile
import zlib

from repro.configs.microhh import scenarios
from repro.core import WisdomKernel, get_kernel
from repro.tuner import tune_kernel

SCS = [s for s in scenarios() if s.grid[0] == 256]  # 8 scenarios, fast


def stable_seed(key: str) -> int:
    """Per-scenario rng seed. crc32, not hash(): the builtin is
    randomized per process (PYTHONHASHSEED), which would make every run
    tune differently."""
    return zlib.crc32(key.encode()) % 2**31


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-evals", type=int, default=100,
                    help="evaluation budget per scenario")
    ap.add_argument("--budget-seconds", type=float, default=60.0)
    ap.add_argument("--record-dataset", default=None, metavar="DIR",
                    help="also record every evaluation as tuning-space "
                         "datasets (docs/tuning-datasets.md)")
    args = ap.parse_args(argv)

    wisdom_dir = tempfile.mkdtemp(prefix="kl-microhh-")
    print(f"wisdom -> {wisdom_dir}")
    for sc in SCS:
        res = tune_kernel(get_kernel(sc.kernel), sc.grid, sc.dtype,
                          sc.device, strategy="bayes",
                          max_evals=args.max_evals,
                          time_budget_s=args.budget_seconds,
                          wisdom_dir=wisdom_dir,
                          seed=stable_seed(sc.key),
                          record_dataset=args.record_dataset)
        print(f"tuned {sc.key:42s} best={res.best_score_us:9.1f}us "
              f"evals={len(res.evaluations)}")

    print("\nruntime selection (paper §4.5):")
    for sc in SCS:
        k = WisdomKernel(get_kernel(sc.kernel), wisdom_dir=wisdom_dir,
                         device_kind=sc.device)
        cfg, tier = k.select_config(sc.grid, sc.dtype)
        print(f"  {sc.key:42s} tier={tier:8s} "
              f"bz={cfg.get('block_z')} by={cfg.get('block_y')}")
    # a scenario nobody tuned: fuzzy match
    k = WisdomKernel(get_kernel("advec_u"), wisdom_dir=wisdom_dir,
                     device_kind="tpu-v5e")
    cfg, tier = k.select_config((384, 384, 384), "float32")
    print(f"  {'advec_u-384^3-float32-tpu-v5e (untuned)':42s} tier={tier}")


if __name__ == "__main__":
    main()
