"""Paper Fig 2: per-scenario performance distribution histograms, with the
default config's fraction-of-optimum and configuration C (the optimum of the
first scenario) transplanted into every other scenario."""

from __future__ import annotations

import numpy as np

from repro.core import get_kernel

from .common import BENCH_SCENARIOS, best_config, population, score


def run() -> list[str]:
    rows = ["distribution,scenario,frac_within_10pct,default_frac,"
            "configC_frac,n_configs"]
    ref_key = BENCH_SCENARIOS[0].key        # advec_u-256^3-float32-tpu-v5e
    config_c, _ = best_config(ref_key)
    for sc in BENCH_SCENARIOS:
        res = population(sc.key)
        scores = np.array([e.score_us for e in res.feasible_evaluations])
        opt = scores.min()
        within = float((scores <= opt / 0.9).mean())
        b = get_kernel(sc.kernel)
        default_frac = opt / score(sc, b.default_config())
        c_frac = opt / score(sc, config_c)
        rows.append(f"distribution,{sc.key},{within:.3f},"
                    f"{default_frac:.3f},{c_frac:.3f},{len(scores)}")
    # paper headline: mean default fraction (~0.75 in the paper)
    fracs = []
    for sc in BENCH_SCENARIOS:
        res = population(sc.key)
        opt = res.best_score_us
        fracs.append(opt / score(sc, get_kernel(sc.kernel).default_config()))
    rows.append(f"distribution,MEAN_DEFAULT_FRACTION,,{np.mean(fracs):.3f},,")
    return rows
