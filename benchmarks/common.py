"""Shared benchmark machinery: the 16-scenario MicroHH table (paper §5) on
the simulated device pair, with a per-scenario tuning cache so the expensive
random-search population is computed once per process."""

from __future__ import annotations

import functools
import zlib

import numpy as np

from repro.configs.microhh import Scenario, scenarios
from repro.core import get_device, get_kernel
from repro.tuner import CostModelEvaluator
from repro.tuner.strategies import TuningResult, tune_random

# Benchmarks run the paper's full 256^3 / 512^3 grids through the simulated
# objective (no allocation happens for cost-model scoring).
BENCH_SCENARIOS: list[Scenario] = scenarios()


def evaluator(sc: Scenario) -> CostModelEvaluator:
    return CostModelEvaluator(get_kernel(sc.kernel), sc.grid, sc.dtype,
                              get_device(sc.device), verify="none")


@functools.lru_cache(maxsize=None)
def population(key: str, max_evals: int = 300) -> TuningResult:
    """Random-search population for one scenario (Fig 2's histogram data +
    the scenario's budgeted optimum)."""
    sc = next(s for s in BENCH_SCENARIOS if s.key == key)
    b = get_kernel(sc.kernel)
    # crc32, not hash(): the builtin is per-process randomized
    # (PYTHONHASHSEED), which would make benchmark populations — and
    # every figure derived from them — differ between runs.
    return tune_random(b.space, evaluator(sc), max_evals=max_evals,
                       rng=np.random.default_rng(zlib.crc32(key.encode())
                                                 % 2**31))


def best_config(key: str) -> tuple[dict, float]:
    res = population(key)
    return res.best_config, res.best_score_us


def score(sc: Scenario, config: dict) -> float:
    return evaluator(sc)(config).score_us


def csv_row(*fields) -> str:
    return ",".join(str(f) for f in fields)
