"""Benchmark harness: one module per paper table/figure. Prints CSV.

  Table 3  -> capture_bench      (capture time/size scaling)
  Fig 2    -> distribution       (search-space histograms, default & config-C)
  Fig 3    -> tuning_session     (random vs Bayesian convergence)
  Fig 4    -> portability        (cross-scenario optimum transfer matrix)
  Tables 4/5 -> ppm              (performance-portability metric)
  Fig 5    -> overhead           (first vs cached launch breakdown)
  (ours)   -> online_convergence (traffic-driven tuning: launches to reach
                                  5% of the offline optimum)
  (ours)   -> fleet_tuning       (N-worker shard parallelism at equal eval
                                  budget; byte-identical assembled wisdom)
  (ours)   -> strategy_bench     (fraction-of-optimum per strategy on the
                                  shipped recorded spaces; deterministic,
                                  threshold-gated)
  (ours)   -> transfer_portability (held-out-device transfer: fraction of
                                  the hidden target optimum reached by
                                  transferred wisdom vs cold fallback)
  (ours)   -> select_scaling      (wisdom select() p50 flat from 10^2 to
                                  10^5 records; indexed == linear scan on
                                  the shipped fixtures)
  (ours)   -> serve_throughput    (token-level continuous batching vs
                                  lock-step cohorts on a mixed-length
                                  workload: steps + slot occupancy)

Usage: PYTHONPATH=src python -m benchmarks.run [--json PATH] [module ...]

Besides the CSV on stdout, every run writes a machine-readable artifact
(default ``BENCH_results.json``; ``--json PATH`` overrides): per module,
the header-keyed rows plus wall time, so CI jobs and notebooks consume
results without re-parsing CSV.
"""

from __future__ import annotations

import json
import sys
import time


MODULES = ("capture_bench", "distribution", "tuning_session",
           "portability", "ppm", "overhead", "online_convergence",
           "fleet_tuning", "strategy_bench", "transfer_portability",
           "select_scaling", "serve_throughput")


def rows_to_records(rows: list[str]) -> list[dict]:
    """CSV rows (first column = table name; a header row per table) as
    a list of header-keyed dicts."""
    headers: dict[str, list[str]] = {}
    records = []
    for row in rows:
        cells = row.split(",")
        table, cells = cells[0], cells[1:]
        if table not in headers:
            headers[table] = cells
            continue
        rec = {"table": table}
        for key, value in zip(headers[table], cells):
            rec[key] = value
        records.append(rec)
    return records


def main() -> None:
    argv = sys.argv[1:]
    out_path = "BENCH_results.json"
    if "--json" in argv:
        i = argv.index("--json")
        out_path = argv[i + 1]
        del argv[i:i + 2]
    want = argv or MODULES
    print("table,_fields...")
    results: dict[str, dict] = {}
    for name in want:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.perf_counter()
        rows = []
        for row in mod.run():
            rows.append(str(row))
            print(row)
        dt = time.perf_counter() - t0
        results[name] = {"rows": rows_to_records(rows),
                         "seconds": round(dt, 3)}
        print(f"# {name} finished in {dt:.1f}s", file=sys.stderr)
    with open(out_path, "w") as f:
        json.dump({"version": 1, "modules": results}, f,
                  indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {out_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
