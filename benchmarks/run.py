"""Benchmark harness: one module per paper table/figure. Prints CSV.

  Table 3  -> capture_bench      (capture time/size scaling)
  Fig 2    -> distribution       (search-space histograms, default & config-C)
  Fig 3    -> tuning_session     (random vs Bayesian convergence)
  Fig 4    -> portability        (cross-scenario optimum transfer matrix)
  Tables 4/5 -> ppm              (performance-portability metric)
  Fig 5    -> overhead           (first vs cached launch breakdown)
  (ours)   -> online_convergence (traffic-driven tuning: launches to reach
                                  5% of the offline optimum)
  (ours)   -> fleet_tuning       (N-worker shard parallelism at equal eval
                                  budget; byte-identical assembled wisdom)
  (ours)   -> strategy_bench     (fraction-of-optimum per strategy on the
                                  shipped recorded spaces; deterministic,
                                  threshold-gated)
  (ours)   -> transfer_portability (held-out-device transfer: fraction of
                                  the hidden target optimum reached by
                                  transferred wisdom vs cold fallback)

Usage: PYTHONPATH=src python -m benchmarks.run [module ...]
"""

from __future__ import annotations

import sys
import time


MODULES = ("capture_bench", "distribution", "tuning_session",
           "portability", "ppm", "overhead", "online_convergence",
           "fleet_tuning", "strategy_bench", "transfer_portability")


def main() -> None:
    want = sys.argv[1:] or MODULES
    print("table,_fields...")
    for name in want:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.perf_counter()
        for row in mod.run():
            print(row)
        print(f"# {name} finished in {time.perf_counter()-t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
