"""Held-out-device transfer portability benchmark (repro.transfer).

Protocol: the tpu-v5 family is *held out* — the transfer engine only
ever sees spaces recorded on tpu-v4 (re-recorded here deterministically)
— and the shipped tpu-v5e recordings under ``benchmarks/datasets/``
(plus deterministic re-recordings for the extra problem sizes) act as
the hidden ground truth. Per scenario, :func:`repro.transfer.holdout_report`
scores the config the transfer tier serves and the config the *cold*
scenario-distance fallback would have served, as fractions of the
target's recorded optimum.

Asserts (the ISSUE 5 acceptance criteria):

  * the report is byte-deterministic (two runs, identical JSON);
  * per kernel, mean transfer fraction-of-optimum >= ``THRESHOLD``
    (the pinned CI regression gate);
  * per kernel, transfer strictly beats the cold fallback on average —
    the reason the transfer tier exists.

CSV: kernel, problem, transfer_fraction, fallback_fraction,
default_fraction, confidence, pass.

Run standalone to write the report artifact CI uploads::

    python -m benchmarks.transfer_portability --out report.json
"""

from __future__ import annotations

from pathlib import Path

from repro.core.registry import get_kernel
from repro.transfer import dump_holdout_report, holdout_report
from repro.tunebench import SpaceDataset, record_space

from .common import csv_row

DATASET_DIR = Path(__file__).parent / "datasets"

#: Tuned source family (recorded spaces the predictor may see) and the
#: held-out target family (ground truth only — never a transfer source).
SOURCE_DEVICE = "tpu-v4"
HELD_OUT_DEVICE = "tpu-v5e"

#: Pinned regression gate on the per-kernel mean transfer
#: fraction-of-optimum (current values: matmul ~0.97, advec_u ~0.99 —
#: see docs/transfer-tuning.md).
THRESHOLD = 0.80

#: Replayed scenarios. The first problem per kernel is the shipped
#: recorded space; the extras stress problem sizes where the source and
#: target optima diverge (re-recorded deterministically, cost model).
SCENARIOS: dict[str, list[tuple[int, ...]]] = {
    "matmul": [(256, 256, 256)],
    "advec_u": [(64, 64, 128), (128, 128, 128), (64, 128, 256)],
}

REPORT_VERSION = 1


def _truth(kernel: str, problem: tuple[int, ...]) -> SpaceDataset:
    problem_s = "x".join(str(d) for d in problem)
    shipped = (DATASET_DIR
               / f"{kernel}--{HELD_OUT_DEVICE}--{problem_s}"
                 f"--float32.space.json")
    if shipped.exists():
        return SpaceDataset.load(shipped)
    return record_space(get_kernel(kernel), problem, "float32",
                        HELD_OUT_DEVICE)


def build_report() -> dict:
    """The full held-out evaluation as one JSON-serializable document
    (no timestamps; byte-identical across runs and hosts)."""
    kernels = []
    all_pass = True
    for kernel in sorted(SCENARIOS):
        scenarios = []
        for problem in SCENARIOS[kernel]:
            source = record_space(get_kernel(kernel), problem, "float32",
                                  SOURCE_DEVICE)
            scenarios.append(holdout_report(source, _truth(kernel, problem)))
        tx = [s["transfer"]["fraction"] or 0.0 for s in scenarios]
        fb = [s["fallback"]["fraction"] or 0.0 for s in scenarios]
        mean_tx = round(sum(tx) / len(tx), 6)
        mean_fb = round(sum(fb) / len(fb), 6)
        passed = mean_tx >= THRESHOLD and mean_tx > mean_fb
        all_pass = all_pass and passed
        kernels.append({
            "kernel": kernel,
            "mean_transfer_fraction": mean_tx,
            "mean_fallback_fraction": mean_fb,
            "threshold": THRESHOLD,
            "pass": passed,
            "scenarios": scenarios,
        })
    return {
        "version": REPORT_VERSION,
        "source_device": SOURCE_DEVICE,
        "held_out_device": HELD_OUT_DEVICE,
        "threshold": THRESHOLD,
        "pass": all_pass,
        "kernels": kernels,
    }


def run():
    yield csv_row("transfer_portability", "kernel", "problem",
                  "transfer_fraction", "fallback_fraction",
                  "default_fraction", "confidence", "pass")
    report = build_report()
    again = build_report()
    assert dump_holdout_report(report) == dump_holdout_report(again), \
        "transfer portability report is not deterministic"
    for k in report["kernels"]:
        for s in k["scenarios"]:
            problem = s["scenario"].split("|")[1]
            yield csv_row("transfer_portability", k["kernel"], problem,
                          s["transfer"]["fraction"],
                          s["fallback"]["fraction"],
                          s["default"]["fraction"],
                          s["confidence"], int(k["pass"]))
    assert report["pass"], (
        "transfer portability regression: a kernel's mean transfer "
        "fraction dropped below its gate or behind the cold fallback")


def main(argv: list[str] | None = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.transfer_portability")
    ap.add_argument("--out", default=None, help="write report JSON here")
    args = ap.parse_args(argv)
    report = build_report()
    text = dump_holdout_report(report)
    if args.out:
        Path(args.out).write_text(text)
        print(f"report -> {args.out}")
    for k in report["kernels"]:
        state = "ok  " if k["pass"] else "FAIL"
        print(f"{state} {k['kernel']}: transfer "
              f"{k['mean_transfer_fraction']:.4f} vs fallback "
              f"{k['mean_fallback_fraction']:.4f} "
              f"(threshold {k['threshold']:.2f})")
    print("overall:", "PASS" if report["pass"] else "FAIL")
    return 0 if report["pass"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
