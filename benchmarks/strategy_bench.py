"""Strategy benchmark on the shipped recorded spaces (repro.tunebench).

Replays every search strategy against the recorded tuning-space datasets
checked in under ``benchmarks/datasets/`` — matmul plus one MicroHH
stencil — with the harness defaults, exactly as
``python -m repro.tunebench compare`` does, so the CSV here and the CLI
report are two views of the same deterministic computation. Asserts:

  * the report is deterministic (two runs produce byte-identical JSON —
    the ISSUE 4 acceptance criterion);
  * every strategy clears its fraction-of-optimum regression threshold
    (a failure means a strategy change made the tuner worse);
  * the profile-guided surrogate (repro.prof.guided) meets or beats the
    plain ridge surrogate's fraction-of-optimum at every recorded
    budget on every shipped space — the profile-features-help
    regression gate.

CSV: dataset, strategy, final_fraction, threshold, frac@25%, frac@50%,
best_us, optimum_us, pass — then per-surrogate rerank rows:
dataset, surrogate, fraction@budget columns, fit_quality, pass.
"""

from __future__ import annotations

from pathlib import Path

from repro.tunebench import SpaceDataset, compare, dump_report

from .common import csv_row

DATASET_DIR = Path(__file__).parent / "datasets"


def shipped_datasets() -> list[SpaceDataset]:
    paths = sorted(DATASET_DIR.glob("*.space.json"))
    assert paths, f"no shipped datasets under {DATASET_DIR}"
    return [SpaceDataset.load(p) for p in paths]


def run():
    yield csv_row("strategy_bench", "dataset", "strategy",
                  "final_fraction", "threshold", "frac_at_25pct",
                  "frac_at_50pct", "best_us", "optimum_us", "pass")
    datasets = shipped_datasets()
    report = compare(datasets)
    again = compare(datasets)
    assert dump_report(report) == dump_report(again), \
        "strategy benchmark report is not deterministic"
    for ds in report["datasets"]:
        for s in ds["strategies"]:
            curve = s["mean_curve"]
            q25 = curve[len(curve) // 4 - 1] if curve else 0.0
            q50 = curve[len(curve) // 2 - 1] if curve else 0.0
            best = min((b for b in s["per_seed_best_us"] if b is not None),
                       default=None)
            yield csv_row("strategy_bench", ds["dataset"], s["strategy"],
                          f"{s['final_fraction']:.4f}",
                          f"{s['threshold']:.2f}",
                          f"{q25:.4f}", f"{q50:.4f}",
                          best, ds["optimum_us"], int(s["pass"]))
    assert report["pass"], \
        "a strategy dropped below its fraction-of-optimum threshold"

    # Profile-guided surrogate re-ranking (repro.prof.guided): train on
    # a small subsample of recorded scores, rank the space by surrogate
    # prediction, and compare fraction-of-optimum at fixed budgets. The
    # gate: profile features must never hurt.
    from repro.prof.guided import rerank_gate, surrogate_rerank
    yield csv_row("rerank", "dataset", "surrogate",
                  "frac_at_8", "frac_at_16", "frac_at_32", "frac_at_64",
                  "fit_quality", "pass")
    for ds in datasets:
        r = surrogate_rerank(ds)
        again = surrogate_rerank(ds)
        assert r == again, "surrogate re-rank is not deterministic"
        problems = rerank_gate(r)
        for row in r["surrogates"]:
            yield csv_row("rerank", r["dataset"], row["surrogate"],
                          *(f"{row['fraction_at'][str(b)]:.4f}"
                            for b in r["budgets"]),
                          f"{row['fit_quality']:.4f}", int(not problems))
        assert not problems, \
            f"profile-guided surrogate regressed on {r['dataset']}: " \
            + "; ".join(problems)
