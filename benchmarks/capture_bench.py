"""Paper Table 3: kernel-capture time and size vs grid size and precision.

Captures real arrays (like the paper), so sizes match exactly:
3 (or 4) fields x nx*ny*nz x dtype bytes.
"""

from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core import write_capture

GRIDS = ((64, 64, 128), (128, 128, 256))   # scaled-down 256^3/512^3 pair
DTYPES = ("float32", "bfloat16")


def run() -> list[str]:
    import jax.numpy as jnp
    rows = [
        "capture_bench,kernel,grid,dtype,capture_seconds,capture_mb"]
    rng = np.random.default_rng(0)
    with tempfile.TemporaryDirectory() as d:
        for kernel, nfields in (("advec_u", 3), ("diff_uvw", 4)):
            for grid in GRIDS:
                for dtype in DTYPES:
                    fields = [np.asarray(jnp.asarray(
                        rng.standard_normal(grid), dtype))
                        for _ in range(nfields)]
                    scal = np.array([[1.0, 1.0, 1.0, 0]], np.float32)
                    t0 = time.perf_counter()
                    p = write_capture(kernel, grid, dtype,
                                      fields + [scal], out_dir=d)
                    dt = time.perf_counter() - t0
                    size = sum(f.stat().st_size
                               for f in Path(d).glob(
                                   p.stem.replace(".capture", "") + "*"))
                    rows.append(
                        f"capture_bench,{kernel},{grid[0]}x{grid[1]}x"
                        f"{grid[2]},{dtype},{dt:.3f},{size/2**20:.1f}")
    return rows
