"""Paper Fig 5: first-launch overhead breakdown (wisdom read / compile /
launch) vs cached subsequent launches — measured for real on this host with
the XLA JIT standing in for NVRTC."""

from __future__ import annotations

import numpy as np

from repro.core import WisdomKernel, get_kernel
from repro.tuner import tune_kernel


def run() -> list[str]:
    import tempfile
    rows = ["overhead,kernel,phase,seconds"]
    rng = np.random.default_rng(0)
    u, v, w = (rng.standard_normal((32, 32, 128)).astype(np.float32)
               for _ in range(3))
    scal = np.array([[1.0, 1.0, 1.0, 0]], np.float32)
    with tempfile.TemporaryDirectory() as d:
        tune_kernel(get_kernel("advec_u"), (32, 32, 128), "float32",
                    "tpu-v5e", strategy="random", max_evals=30,
                    time_budget_s=30, wisdom_dir=d)
        k = WisdomKernel(get_kernel("advec_u"), wisdom_dir=d,
                         device_kind="tpu-v5e", backend="interpret")
        k(u, v, w, scal)          # first launch: wisdom + compile + run
        for _ in range(5):
            k(u, v, w, scal)      # cached
        first = k.stats[0]
        rows.append(f"overhead,advec_u,first_wisdom_read,"
                    f"{first.wisdom_read_s:.6f}")
        rows.append(f"overhead,advec_u,first_select,{first.select_s:.6f}")
        rows.append(f"overhead,advec_u,first_compile,{first.compile_s:.6f}")
        rows.append(f"overhead,advec_u,first_launch,{first.launch_s:.6f}")
        cached = [s.launch_s for s in k.stats[1:]]
        rows.append(f"overhead,advec_u,cached_launch_mean,"
                    f"{np.mean(cached):.6f}")
        total_first = (first.wisdom_read_s + first.select_s
                       + first.compile_s + first.launch_s)
        rows.append(f"overhead,advec_u,compile_fraction_of_first,"
                    f"{first.compile_s / total_first:.3f}")
    return rows
