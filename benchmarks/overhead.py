"""Paper Fig 5: first-launch overhead breakdown (wisdom read / compile /
launch) vs cached subsequent launches — measured for real on this host with
the XLA JIT standing in for NVRTC.

``--check`` runs the *instrumentation overhead gate* instead: the
telemetry layer (repro.obs) sits directly on the launch hot path, which
is only acceptable if the disabled path costs nothing measurable. The
gate microbenchmarks one disabled instrument site (a ``metrics()``
global read plus an ``is not None`` branch) against the pinned budget
below and exits non-zero when it is blown — CI runs this on every
change.
"""

from __future__ import annotations

import time
import timeit

import numpy as np

from repro.core import WisdomKernel, get_kernel
from repro.tuner import tune_kernel

#: Pinned gate: one *disabled* instrument site must cost at most this
#: many nanoseconds (median of repeated timeit runs). The site is one
#: function call + one branch — tens of ns on any current CPU; the
#: budget leaves ~20x headroom for slow shared CI machines while still
#: catching a disabled path that grew real work (dict building, label
#: formatting, locking).
DISABLED_SITE_BUDGET_NS = 2_000.0

#: Sanity ceiling for one *enabled* counter increment (series-key build
#: + dict lookup + float add). Not a hot-path guarantee — enabled mode
#: is allowed to cost — just a guard against accidental O(n) work per
#: event.
ENABLED_SITE_BUDGET_NS = 60_000.0


def _site_cost_ns(stmt: str, setup: str, number: int = 200_000,
                  repeats: int = 7) -> float:
    """Median per-iteration cost of ``stmt`` in nanoseconds."""
    timer = timeit.Timer(stmt, setup=setup, timer=time.perf_counter)
    runs = sorted(timer.repeat(repeat=repeats, number=number))
    return runs[len(runs) // 2] / number * 1e9


def measure() -> dict[str, float]:
    """Per-site instrumentation costs (ns): disabled branch, enabled
    counter inc, and the bare-loop floor for context."""
    base = ("from repro.obs import runtime as obs\n"
            "from repro.obs.metrics import MetricsRegistry\n")
    disabled = _site_cost_ns(
        "m = obs.metrics()\n"
        "if m is not None:\n"
        "    m.counter('launch.count', kernel='k').inc()",
        base + "obs.disable()")
    enabled = _site_cost_ns(
        "m = obs.metrics()\n"
        "if m is not None:\n"
        "    m.counter('launch.count', kernel='k').inc()",
        base + "obs.disable(); obs.enable(trace=False)")
    floor = _site_cost_ns("pass", base)
    return {"disabled_site_ns": disabled, "enabled_site_ns": enabled,
            "loop_floor_ns": floor}


def check() -> int:
    """The CI gate: measure, print, and fail on a blown budget."""
    costs = measure()
    print(f"disabled instrument site: {costs['disabled_site_ns']:.1f} ns "
          f"(budget {DISABLED_SITE_BUDGET_NS:.0f} ns)")
    print(f"enabled counter inc:      {costs['enabled_site_ns']:.1f} ns "
          f"(budget {ENABLED_SITE_BUDGET_NS:.0f} ns)")
    print(f"bare loop floor:          {costs['loop_floor_ns']:.1f} ns")
    failures = []
    if costs["disabled_site_ns"] > DISABLED_SITE_BUDGET_NS:
        failures.append("disabled-site budget blown")
    if costs["enabled_site_ns"] > ENABLED_SITE_BUDGET_NS:
        failures.append("enabled-site budget blown")
    if failures:
        print("FAIL: " + "; ".join(failures))
        return 1
    print("OK: instrumentation overhead within pinned bounds")
    return 0


def run() -> list[str]:
    import tempfile
    rows = ["overhead,kernel,phase,seconds"]
    rng = np.random.default_rng(0)
    u, v, w = (rng.standard_normal((32, 32, 128)).astype(np.float32)
               for _ in range(3))
    scal = np.array([[1.0, 1.0, 1.0, 0]], np.float32)
    with tempfile.TemporaryDirectory() as d:
        tune_kernel(get_kernel("advec_u"), (32, 32, 128), "float32",
                    "tpu-v5e", strategy="random", max_evals=30,
                    time_budget_s=30, wisdom_dir=d)
        k = WisdomKernel(get_kernel("advec_u"), wisdom_dir=d,
                         device_kind="tpu-v5e", backend="interpret")
        k(u, v, w, scal)          # first launch: wisdom + compile + run
        for _ in range(5):
            k(u, v, w, scal)      # cached
        first = k.stats[0]
        rows.append(f"overhead,advec_u,first_wisdom_read,"
                    f"{first.wisdom_read_s:.6f}")
        rows.append(f"overhead,advec_u,first_select,{first.select_s:.6f}")
        rows.append(f"overhead,advec_u,first_compile,{first.compile_s:.6f}")
        rows.append(f"overhead,advec_u,first_launch,{first.launch_s:.6f}")
        cached = [s.launch_s for s in k.stats[1:]]
        rows.append(f"overhead,advec_u,cached_launch_mean,"
                    f"{np.mean(cached):.6f}")
        total_first = (first.wisdom_read_s + first.select_s
                       + first.compile_s + first.launch_s)
        rows.append(f"overhead,advec_u,compile_fraction_of_first,"
                    f"{first.compile_s / total_first:.3f}")
    for phase, ns in measure().items():
        rows.append(f"overhead,obs,{phase},{ns / 1e9:.9f}")
    return rows


if __name__ == "__main__":
    import sys
    if "--check" in sys.argv:
        raise SystemExit(check())
    for r in run():
        print(r)
