"""Paper Fig 5: first-launch overhead breakdown (wisdom read / compile /
launch) vs cached subsequent launches — measured for real on this host with
the XLA JIT standing in for NVRTC.

``--check`` runs the *instrumentation overhead gate* instead: the
telemetry layer (repro.obs) sits directly on the launch hot path, which
is only acceptable if the disabled path costs nothing measurable. The
gate microbenchmarks one disabled instrument site (a ``metrics()``
global read plus an ``is not None`` branch) against the pinned budget
below and exits non-zero when it is blown — CI runs this on every
change. The profiler (repro.prof) sits on the same hot path, so the
gate also pins its detached site (attribute read + branch) and the
amortized cost of sampled profiling at the default 1-in-16 period.
"""

from __future__ import annotations

import time
import timeit

import numpy as np

from repro.core import WisdomKernel, get_kernel
from repro.tuner import tune_kernel

#: Pinned gate: one *disabled* instrument site must cost at most this
#: many nanoseconds (median of repeated timeit runs). The site is one
#: function call + one branch — tens of ns on any current CPU; the
#: budget leaves ~20x headroom for slow shared CI machines while still
#: catching a disabled path that grew real work (dict building, label
#: formatting, locking).
DISABLED_SITE_BUDGET_NS = 2_000.0

#: Sanity ceiling for one *enabled* counter increment (series-key build
#: + dict lookup + float add). Not a hot-path guarantee — enabled mode
#: is allowed to cost — just a guard against accidental O(n) work per
#: event.
ENABLED_SITE_BUDGET_NS = 60_000.0

#: The detached profiler site on the launch path is one attribute read
#: + ``is not None`` — it shares the disabled-obs budget.
PROF_DISABLED_SITE_BUDGET_NS = DISABLED_SITE_BUDGET_NS

#: Amortized per-launch cost of *sampled* profiling at the default
#: period (``Profiler.due`` every launch + one full workload-hook
#: profile every 16th). The hook builds a Workload dataclass and a
#: KernelProfile — microseconds of Python — so amortized over the
#: period it must stay well under typical kernel launch latencies;
#: 25µs leaves slack for slow shared CI hosts.
PROF_SAMPLED_BUDGET_NS = 25_000.0


def _site_cost_ns(stmt: str, setup: str, number: int = 200_000,
                  repeats: int = 7) -> float:
    """Median per-iteration cost of ``stmt`` in nanoseconds."""
    timer = timeit.Timer(stmt, setup=setup, timer=time.perf_counter)
    runs = sorted(timer.repeat(repeat=repeats, number=number))
    return runs[len(runs) // 2] / number * 1e9


def measure() -> dict[str, float]:
    """Per-site instrumentation costs (ns): disabled branch, enabled
    counter inc, and the bare-loop floor for context."""
    base = ("from repro.obs import runtime as obs\n"
            "from repro.obs.metrics import MetricsRegistry\n")
    disabled = _site_cost_ns(
        "m = obs.metrics()\n"
        "if m is not None:\n"
        "    m.counter('launch.count', kernel='k').inc()",
        base + "obs.disable()")
    enabled = _site_cost_ns(
        "m = obs.metrics()\n"
        "if m is not None:\n"
        "    m.counter('launch.count', kernel='k').inc()",
        base + "obs.disable(); obs.enable(trace=False)")
    floor = _site_cost_ns("pass", base)
    out = {"disabled_site_ns": disabled, "enabled_site_ns": enabled,
           "loop_floor_ns": floor}
    out.update(measure_prof())
    return out


def measure_prof() -> dict[str, float]:
    """Profiler launch-path costs (ns per launch): the detached site
    (``self.profiler`` read + branch, what every unprofiled process
    pays) and the amortized cost of sampled profiling at the default
    period (``due()`` every launch, a full workload-hook profile every
    16th)."""
    setup = (
        "from repro.obs import runtime as obs\n"
        "obs.disable()\n"
        "from repro.core import get_kernel\n"
        "from repro.core.device import get_device\n"
        "from repro.prof.profiler import Profiler\n"
        "class _K:\n"
        "    profiler = None\n"
        "k = _K()\n"
        "builder = get_kernel('advec_u')\n"
        "cfg = builder.default_config()\n"
        "dev = get_device('tpu-v5e')\n"
        "pr = Profiler(sample_every=16, max_profiles=64)\n")
    detached = _site_cost_ns(
        "p = k.profiler\n"
        "if p is not None and p.due('advec_u'):\n"
        "    pass",
        setup)
    sampled = _site_cost_ns(
        "if pr.due('advec_u'):\n"
        "    pr.profile_launch(builder, cfg, (32, 32, 128), 'float32',\n"
        "                      dev, 12.5, tier='exact', baseline_us=12.0)",
        setup, number=50_000)
    return {"prof_disabled_site_ns": detached,
            "prof_sampled_amortized_ns": sampled}


def check() -> int:
    """The CI gate: measure, print, and fail on a blown budget."""
    costs = measure()
    print(f"disabled instrument site: {costs['disabled_site_ns']:.1f} ns "
          f"(budget {DISABLED_SITE_BUDGET_NS:.0f} ns)")
    print(f"enabled counter inc:      {costs['enabled_site_ns']:.1f} ns "
          f"(budget {ENABLED_SITE_BUDGET_NS:.0f} ns)")
    print(f"detached profiler site:   "
          f"{costs['prof_disabled_site_ns']:.1f} ns "
          f"(budget {PROF_DISABLED_SITE_BUDGET_NS:.0f} ns)")
    print(f"sampled profiling (amortized, 1/16): "
          f"{costs['prof_sampled_amortized_ns']:.1f} ns "
          f"(budget {PROF_SAMPLED_BUDGET_NS:.0f} ns)")
    print(f"bare loop floor:          {costs['loop_floor_ns']:.1f} ns")
    failures = []
    if costs["disabled_site_ns"] > DISABLED_SITE_BUDGET_NS:
        failures.append("disabled-site budget blown")
    if costs["enabled_site_ns"] > ENABLED_SITE_BUDGET_NS:
        failures.append("enabled-site budget blown")
    if costs["prof_disabled_site_ns"] > PROF_DISABLED_SITE_BUDGET_NS:
        failures.append("detached-profiler-site budget blown")
    if costs["prof_sampled_amortized_ns"] > PROF_SAMPLED_BUDGET_NS:
        failures.append("sampled-profiling budget blown")
    if failures:
        print("FAIL: " + "; ".join(failures))
        return 1
    print("OK: instrumentation overhead within pinned bounds")
    return 0


def run() -> list[str]:
    import tempfile
    rows = ["overhead,kernel,phase,seconds"]
    rng = np.random.default_rng(0)
    u, v, w = (rng.standard_normal((32, 32, 128)).astype(np.float32)
               for _ in range(3))
    scal = np.array([[1.0, 1.0, 1.0, 0]], np.float32)
    with tempfile.TemporaryDirectory() as d:
        tune_kernel(get_kernel("advec_u"), (32, 32, 128), "float32",
                    "tpu-v5e", strategy="random", max_evals=30,
                    time_budget_s=30, wisdom_dir=d)
        k = WisdomKernel(get_kernel("advec_u"), wisdom_dir=d,
                         device_kind="tpu-v5e", backend="interpret")
        k(u, v, w, scal)          # first launch: wisdom + compile + run
        for _ in range(5):
            k(u, v, w, scal)      # cached
        first = k.stats[0]
        rows.append(f"overhead,advec_u,first_wisdom_read,"
                    f"{first.wisdom_read_s:.6f}")
        rows.append(f"overhead,advec_u,first_select,{first.select_s:.6f}")
        rows.append(f"overhead,advec_u,first_compile,{first.compile_s:.6f}")
        rows.append(f"overhead,advec_u,first_launch,{first.launch_s:.6f}")
        cached = [s.launch_s for s in k.stats[1:]]
        rows.append(f"overhead,advec_u,cached_launch_mean,"
                    f"{np.mean(cached):.6f}")
        total_first = (first.wisdom_read_s + first.select_s
                       + first.compile_s + first.launch_s)
        rows.append(f"overhead,advec_u,compile_fraction_of_first,"
                    f"{first.compile_s / total_first:.3f}")
    for phase, ns in measure().items():
        rows.append(f"overhead,obs,{phase},{ns / 1e9:.9f}")
    return rows


if __name__ == "__main__":
    import sys
    if "--check" in sys.argv:
        raise SystemExit(check())
    for r in run():
        print(r)
