"""Token-level vs cohort serving throughput on a mixed-length workload.

The number this PR exists to move: on a workload whose requests have
*unequal* lengths, lock-step cohorts stall every slot on the cohort's
slowest member, while token-level continuous batching refills a freed
slot mid-stream (per-slot attention-window masking over the shared
arena, see docs/serving.md). Both modes run the identical workload on
the identical tiny transformer with greedy sampling, so the comparison
is purely scheduling.

Gates (``--check``, part of the ``serve-smoke`` CI job):

* token-level completes the workload in strictly fewer decode steps;
* token-level's slot occupancy (useful slot-steps / total slot-steps)
  is strictly higher;
* both modes return identical per-request token counts (scheduling must
  not change how much gets generated).

CSV: mode, requests, steps, arena_generations, occupancy,
inflight_admissions, tokens_per_step.
"""

from __future__ import annotations

import sys

import numpy as np

try:
    from .common import csv_row
except ImportError:     # run as a plain script: python benchmarks/...py
    def csv_row(*fields) -> str:
        return ",".join(str(f) for f in fields)

N_SLOTS = 4
MAX_SEQ = 96

#: (prompt_len, max_new_tokens) per request — deliberately mixed lengths
#: (short chats next to long generations) so cohort mode pays its
#: slowest-member stall on every cohort.
WORKLOAD = ((4, 4), (6, 40), (3, 6), (5, 28), (4, 8), (8, 36),
            (2, 4), (6, 24), (3, 10), (5, 32), (4, 6), (7, 20))


def _model():
    from repro.configs import get_arch
    from repro.models import build_model
    cfg = get_arch("stablelm-1.6b").reduced()
    return cfg, build_model(cfg)


def serve_mode(mode: str):
    """Run the workload under one scheduling mode; returns the report."""
    import jax
    from repro.serve import Request, ServeEngine
    cfg, model = _model()
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                      mode=mode)
    rng = np.random.default_rng(7)
    for rid, (plen, mnew) in enumerate(WORKLOAD):
        eng.submit(Request(
            rid, rng.integers(0, cfg.vocab, size=plen, dtype=np.int32),
            max_new_tokens=mnew,
            scenario=f"tpu-v5e|{plen}x{mnew}|int32"))
    return eng.run()


def run():
    yield csv_row("serve_throughput", "mode", "requests", "steps",
                  "arena_generations", "occupancy",
                  "inflight_admissions", "tokens_per_step")
    reports = {mode: serve_mode(mode) for mode in ("token", "cohort")}
    for mode, rep in reports.items():
        tokens = sum(len(t) for t in rep.values())
        yield csv_row("serve_throughput", mode, rep.requests_completed,
                      rep.steps, rep.cohorts, f"{rep.occupancy:.4f}",
                      rep.inflight_admissions,
                      f"{tokens / rep.steps:.4f}" if rep.steps else "0")
    token, cohort = reports["token"], reports["cohort"]
    same_outputs = ({rid: len(t) for rid, t in token.items()}
                    == {rid: len(t) for rid, t in cohort.items()})
    run.passed = (token.steps < cohort.steps
                  and token.occupancy > cohort.occupancy
                  and same_outputs)
    yield csv_row("serve_throughput_gate", "token_beats_cohort")
    yield csv_row("serve_throughput_gate", int(run.passed))


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    check = "--check" in argv
    for row in run():
        print(row)
    if check and not run.passed:
        print("serve_throughput: FAILED (token-level did not beat cohort "
              "on steps+occupancy with identical outputs)",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
