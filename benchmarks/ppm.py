"""Paper Tables 4/5: the performance-portability metric (PPM, Pennycook et
al. — harmonic mean of the fraction-of-best across scenarios) for (a) the
default config, (b) each single-scenario-tuned config, (c) Kernel Launcher's
runtime selection (which by construction picks each scenario's best known
config -> PPM = 1.0)."""

from __future__ import annotations

import numpy as np

from repro.core import get_kernel

from .common import BENCH_SCENARIOS, best_config, score


def _ppm(fractions: list[float]) -> float:
    f = np.array(fractions)
    return len(f) / (1.0 / f).sum()


def run() -> list[str]:
    rows = ["ppm,kernel,config_tuned_for,best,worst,ppm"]
    for kernel in sorted({s.kernel for s in BENCH_SCENARIOS}):
        scs = [s for s in BENCH_SCENARIOS if s.kernel == kernel]
        opts = {s.key: best_config(s.key) for s in scs}

        def fractions(cfg) -> list[float]:
            return [opts[s.key][1] / score(s, cfg) for s in scs]

        fr = fractions(get_kernel(kernel).default_config())
        rows.append(f"ppm,{kernel},default,{max(fr):.2f},{min(fr):.2f},"
                    f"{_ppm(fr):.2f}")
        for s in scs:
            fr = fractions(opts[s.key][0])
            rows.append(f"ppm,{kernel},{s.key},{max(fr):.2f},"
                        f"{min(fr):.2f},{_ppm(fr):.2f}")
        # compile-time selection (Kernel Tuner headers, paper §3): one
        # baked config per *device* (built for 256^3-f32), no runtime
        # dispatch on problem size or dtype
        baked = {dev: opts[next(s.key for s in scs
                                if s.device == dev and s.grid[0] == 256
                                and s.dtype == "float32")][0]
                 for dev in {s.device for s in scs}}
        fr = [opts[s.key][1] / score(s, baked[s.device]) for s in scs]
        rows.append(f"ppm,{kernel},compile_time_per_device,"
                    f"{max(fr):.2f},{min(fr):.2f},{_ppm(fr):.2f}")
        # Kernel Launcher: per-scenario best -> all fractions 1.0
        rows.append(f"ppm,{kernel},kernel_launcher,1.00,1.00,1.00")
    return rows
