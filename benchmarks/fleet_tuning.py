"""Fleet tuning: N-worker shard parallelism at equal eval budget.

Runs the deterministic in-process fleet (``run_local_fleet``) over the
same seeded demand with 1 and N workers. Both runs execute the identical
shard set (sharding is fixed by the job spec, not the worker count), so
the total evaluation budget is equal by construction; the speedup is the
critical-path ratio: evaluations done by the busiest worker, the
simulated-parallelism analogue of wall time when every worker is a real
host. Asserts N workers beat one (the whole point of sharding) and that
both runs assemble byte-identical fleet wisdom (sharding must not change
the answer).

CSV: workers, jobs, shards_per_job, total_evals, makespan_evals,
speedup_vs_1, wisdom_identical.
"""

from __future__ import annotations

import json

from repro.fleet import run_local_fleet

from .common import csv_row

WORKER_COUNTS = (1, 2, 3)
N_SHARDS = 6


def _fleet(n_workers: int):
    return run_local_fleet(n_workers=n_workers, n_shards=N_SHARDS,
                           strategy="exhaustive", seed=0)


def run():
    yield csv_row("fleet_tuning", "workers", "jobs", "shards_per_job",
                  "total_evals", "makespan_evals", "speedup_vs_1",
                  "wisdom_identical")
    base = _fleet(1)
    base_doc = json.dumps(base.wisdom_docs, sort_keys=True)
    assert base.makespan_evals == base.total_evals
    for n in WORKER_COUNTS:
        report = base if n == 1 else _fleet(n)
        identical = (json.dumps(report.wisdom_docs, sort_keys=True)
                     == base_doc)
        assert identical, f"{n}-worker wisdom diverged from 1-worker"
        assert report.total_evals == base.total_evals, \
            f"{n}-worker run changed the eval budget"
        speedup = base.makespan_evals / max(report.makespan_evals, 1)
        if n > 1:
            assert speedup > 1.2, \
                f"{n} workers gave no shard parallelism ({speedup:.2f}x)"
        yield csv_row("fleet_tuning", n, len(report.jobs_assembled),
                      N_SHARDS, report.total_evals, report.makespan_evals,
                      f"{speedup:.2f}", int(identical))
