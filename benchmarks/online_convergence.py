"""Online-tuning convergence: launches-to-within-5%-of-offline-optimum.

For each scenario: start from an *empty* wisdom dir, serve synthetic
traffic through a WisdomKernel with the online autotuner attached
(cost-model objective, fixed seed), and record

  * launches until the incumbent is within 5% of the offline optimum
    (the exhaustive-search best under the same objective),
  * launches until promotion (the online record landing in wisdom),
  * the trial fraction (how much live traffic ran candidates), and
  * the measured online overhead per launch.

CSV: scenario, launches_to_5pct, launches_to_promo, online_us, offline_us,
ratio, trial_frac, overhead_us_per_launch.
"""

from __future__ import annotations

import tempfile

import numpy as np

from repro.core import WisdomKernel, get_device, get_kernel
from repro.online import enable_online_tuning
from repro.tuner.runner import CostModelEvaluator
from repro.tuner.strategies import tune_exhaustive

from .common import csv_row

MAX_LAUNCHES = 300
TARGET = 1.05


def _matmul_args(m, n, k, dtype):
    rng = np.random.default_rng(0)
    a = rng.standard_normal((m, k)).astype(dtype)
    b = rng.standard_normal((k, n)).astype(dtype)
    return a, b


SCENARIOS = [
    # (label, kernel, launch args, problem, dtype, device)
    ("matmul-256-f32-v5e", "matmul", _matmul_args(256, 256, 256, "float32"),
     (256, 256, 256), "float32", "tpu-v5e"),
    ("matmul-512x256-f32-v4", "matmul",
     _matmul_args(512, 256, 512, "float32"), (512, 256, 512), "float32",
     "tpu-v4"),
]


def run():
    yield csv_row("online_convergence", "scenario", "launches_to_5pct",
                  "launches_to_promo", "online_us", "offline_us", "ratio",
                  "trial_frac", "overhead_us_per_launch")
    for label, kname, args, problem, dtype, device in SCENARIOS:
        builder = get_kernel(kname)
        ev = CostModelEvaluator(builder, problem, dtype, get_device(device),
                                verify="none")
        offline = tune_exhaustive(builder.space, ev)

        wisdom_dir = tempfile.mkdtemp(prefix="kl-online-bench-")
        kernel = WisdomKernel(builder, wisdom_dir=wisdom_dir,
                              device_kind=device, backend="reference")
        svc = enable_online_tuning(kernel, objective="costmodel", seed=0)

        to_5pct = to_promo = None
        for i in range(1, MAX_LAUNCHES + 1):
            kernel(*args)
            if to_promo is None and svc.promotions():
                to_promo = i
            if to_5pct is None:
                cfg, _ = kernel.select_config(problem, dtype)
                if ev(cfg).score_us <= offline.best_score_us * TARGET:
                    to_5pct = i
            if to_5pct is not None and to_promo is not None:
                break

        cfg, _ = kernel.select_config(problem, dtype)
        online_us = ev(cfg).score_us
        st = svc.status()
        launches = max(st["launches"], 1)
        yield csv_row(
            "online_convergence", label,
            to_5pct if to_5pct is not None else f">{MAX_LAUNCHES}",
            to_promo if to_promo is not None else f">{MAX_LAUNCHES}",
            f"{online_us:.2f}", f"{offline.best_score_us:.2f}",
            f"{online_us / offline.best_score_us:.3f}",
            f"{st['trials'] / launches:.3f}",
            f"{1e6 * st['overhead_per_launch_s']:.1f}")
