"""Paper Fig 3: tuning-session convergence, random vs Bayesian optimization.
Reports best-so-far trajectories and the evaluations needed to reach within
10% / 5% of the budgeted optimum (paper: 3.4 min / 7.5 min wall — here the
unit is evaluations, since the simulated objective is instant)."""

from __future__ import annotations

import numpy as np

from repro.core import get_kernel
from repro.tuner import tune_bayes, tune_random

from .common import BENCH_SCENARIOS, evaluator


def _evals_to_within(res, target_frac: float, optimum: float) -> int | None:
    best = float("inf")
    for i, e in enumerate(res.evaluations):
        if e.feasible and e.score_us < best:
            best = e.score_us
        if best <= optimum / target_frac:
            return i + 1
    return None


def run() -> list[str]:
    rows = ["tuning_session,scenario,strategy,best_us,evals_to_10pct,"
            "evals_to_5pct,n_evals"]
    # the paper shows two sessions; we run the 256^3-f32 pair on both devices
    picks = [s for s in BENCH_SCENARIOS
             if s.grid[0] == 256 and s.dtype == "float32"]
    for sc in picks:
        results = {}
        # budget ~20% of the space: the regime where model-based search
        # should beat random (the paper's space is 7.7M, ours ~10^2-10^3,
        # so equal-budget full-space sessions make random look exhaustive)
        for name, strat in (("random", tune_random), ("bayes", tune_bayes)):
            res = strat(get_kernel(sc.kernel).space, evaluator(sc),
                        max_evals=60, rng=np.random.default_rng(0))
            results[name] = res
        optimum = min(r.best_score_us for r in results.values())
        for name, res in results.items():
            e10 = _evals_to_within(res, 0.9, optimum)
            e5 = _evals_to_within(res, 0.95, optimum)
            rows.append(f"tuning_session,{sc.key},{name},"
                        f"{res.best_score_us:.2f},{e10},{e5},"
                        f"{len(res.evaluations)}")
    return rows
