"""Paper Fig 4: the NxN portability matrix — how the optimum of scenario i
performs in scenario j, as a fraction of scenario j's own optimum."""

from __future__ import annotations

from .common import BENCH_SCENARIOS, best_config, score


def run() -> list[str]:
    kernels = sorted({s.kernel for s in BENCH_SCENARIOS})
    rows = ["portability,kernel,from_scenario,to_scenario,fraction"]
    for kernel in kernels:
        scs = [s for s in BENCH_SCENARIOS if s.kernel == kernel]
        opt = {s.key: best_config(s.key) for s in scs}
        for si in scs:
            cfg_i, _ = opt[si.key]
            for sj in scs:
                frac = opt[sj.key][1] / score(sj, cfg_i)
                rows.append(f"portability,{kernel},{si.key},{sj.key},"
                            f"{frac:.3f}")
    return rows
