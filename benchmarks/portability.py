"""Paper Fig 4: the NxN portability matrix — how the optimum of scenario i
performs in scenario j, as a fraction of scenario j's own optimum — plus
the cross-*backend* section: TPU-recorded wisdom transferred across the
lowering boundary to the GPU device family.

Cross-backend protocol (the paper's A4000/A100 portability tables, one
abstraction further out): the GPU family is held out — the transfer
engine only sees spaces recorded on ``tpu-v5e`` — and GPU recordings
(shipped under ``benchmarks/datasets/`` or re-recorded here
deterministically) act as hidden ground truth. Per scenario,
:func:`repro.transfer.holdout_report` scores the config the transfer
tier serves and the cold scenario-distance fallback as fractions of the
GPU target's recorded optimum.

Pinned gates (the ISSUE 10 acceptance criteria):

  * GPU-recorded spaces exist for >= 2 kernels in ``benchmarks/datasets``;
  * per kernel, mean transfer fraction-of-optimum across both GPU
    targets >= ``CROSS_BACKEND_THRESHOLD`` and strictly beats the cold
    fallback — TPU wisdom moved through the confidence-penalized
    predictor still beats an untuned GPU;
  * every cross-backend result carries the backend mismatch penalty
    (``backend_penalty < 1``) in its audited components, and any served
    transfer record cleared ``TRANSFER_MIN_CONFIDENCE`` *with* that
    penalty applied — the regression surface for "no cross-backend
    record is ever served above the gate without the penalty";
  * the report is byte-deterministic (two builds, identical JSON).

Run standalone to check the gate / write the report artifact CI uploads::

    python -m benchmarks.portability --check --out portability-report.json
"""

from __future__ import annotations

import functools
from pathlib import Path

try:
    from .common import BENCH_SCENARIOS, best_config, csv_row, score
except ImportError:     # executed as a script: python benchmarks/portability.py
    import sys
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks.common import (BENCH_SCENARIOS, best_config, csv_row,
                                   score)

from repro.core.device import get_device
from repro.core.registry import get_kernel
from repro.core.wisdom import TRANSFER_MIN_CONFIDENCE
from repro.transfer import dump_holdout_report, holdout_report
from repro.transfer.model import BACKEND_MISMATCH_PENALTY
from repro.tunebench import SpaceDataset, record_space

DATASET_DIR = Path(__file__).parent / "datasets"

#: Tuned source (TPU wisdom the predictor may see) and the held-out GPU
#: device family (ground truth only — never a transfer source).
SOURCE_DEVICE = "tpu-v5e"
GPU_TARGETS = ("gpu-a100", "gpu-a4000")

#: Pinned regression gate on the per-kernel mean cross-backend transfer
#: fraction-of-optimum (current values: matmul ~0.96, advec_u ~0.90 —
#: see docs/gpu-backend.md).
CROSS_BACKEND_THRESHOLD = 0.85

#: Cross-backend scenarios per kernel, replayed against *both* GPU
#: targets. The first problem per (kernel, target) pair with a shipped
#: recording uses it; the rest are re-recorded deterministically
#: (cost-model objective, exhaustive).
CROSS_SCENARIOS: dict[str, list[tuple[int, ...]]] = {
    "matmul": [(256, 256, 256), (512, 512, 512), (512, 512, 2048)],
    "advec_u": [(64, 64, 128), (128, 128, 128), (32, 64, 128)],
}

CROSS_REPORT_VERSION = 1


@functools.lru_cache(maxsize=None)
def _dataset(kernel: str, device: str,
             problem: tuple[int, ...]) -> SpaceDataset:
    problem_s = "x".join(str(d) for d in problem)
    shipped = (DATASET_DIR
               / f"{kernel}--{device}--{problem_s}--float32.space.json")
    if shipped.exists():
        return SpaceDataset.load(shipped)
    return record_space(get_kernel(kernel), problem, "float32", device)


def shipped_gpu_kernels() -> list[str]:
    """Kernels with a GPU-backend recording shipped in the dataset dir."""
    kernels = set()
    for path in sorted(DATASET_DIR.glob("*.space.json")):
        kernel, device = path.name.split("--")[:2]
        if get_device(device).backend == "gpu":
            kernels.add(kernel)
    return sorted(kernels)


def _penalty_audit(report: dict) -> bool:
    """Whether one holdout scenario honors the cross-backend serving
    contract: the mismatch penalty is recorded in the audited
    components, and if the transfer tier actually served, its
    (penalized) confidence cleared the gate."""
    comp = report["components"]
    penalized = (comp.get("backends") == "tpu->gpu"
                 and comp.get("backend_penalty") == BACKEND_MISMATCH_PENALTY
                 and comp["backend_penalty"] < 1.0
                 # similarity already *includes* the penalty: it can
                 # never exceed the penalty factor itself.
                 and comp["similarity"] <= BACKEND_MISMATCH_PENALTY)
    if report["transfer"]["tier"] == "transfer":
        penalized = (penalized
                     and report["confidence"] >= TRANSFER_MIN_CONFIDENCE)
    return bool(penalized)


def build_cross_backend_report() -> dict:
    """The full cross-backend evaluation as one JSON-serializable
    document (no timestamps; byte-identical across runs and hosts)."""
    kernels = []
    all_pass = True
    for kernel in sorted(CROSS_SCENARIOS):
        scenarios = []
        for target in GPU_TARGETS:
            for problem in CROSS_SCENARIOS[kernel]:
                source = _dataset(kernel, SOURCE_DEVICE, problem)
                truth = _dataset(kernel, target, problem)
                rep = holdout_report(source, truth)
                rep["penalty_applied"] = _penalty_audit(rep)
                scenarios.append(rep)
        tx = [s["transfer"]["fraction"] or 0.0 for s in scenarios]
        fb = [s["fallback"]["fraction"] or 0.0 for s in scenarios]
        mean_tx = round(sum(tx) / len(tx), 6)
        mean_fb = round(sum(fb) / len(fb), 6)
        passed = (mean_tx >= CROSS_BACKEND_THRESHOLD and mean_tx > mean_fb
                  and all(s["penalty_applied"] for s in scenarios))
        all_pass = all_pass and passed
        kernels.append({
            "kernel": kernel,
            "mean_transfer_fraction": mean_tx,
            "mean_fallback_fraction": mean_fb,
            "threshold": CROSS_BACKEND_THRESHOLD,
            "pass": passed,
            "scenarios": scenarios,
        })
    gpu_kernels = shipped_gpu_kernels()
    all_pass = all_pass and len(gpu_kernels) >= 2
    return {
        "version": CROSS_REPORT_VERSION,
        "source_device": SOURCE_DEVICE,
        "gpu_targets": list(GPU_TARGETS),
        "threshold": CROSS_BACKEND_THRESHOLD,
        "shipped_gpu_kernels": gpu_kernels,
        "pass": all_pass,
        "kernels": kernels,
    }


def run():
    # -- Fig 4: same-device cross-scenario matrix -----------------------------
    kernels = sorted({s.kernel for s in BENCH_SCENARIOS})
    yield "portability,kernel,from_scenario,to_scenario,fraction"
    for kernel in kernels:
        scs = [s for s in BENCH_SCENARIOS if s.kernel == kernel]
        opt = {s.key: best_config(s.key) for s in scs}
        for si in scs:
            cfg_i, _ = opt[si.key]
            for sj in scs:
                frac = opt[sj.key][1] / score(sj, cfg_i)
                yield (f"portability,{kernel},{si.key},{sj.key},"
                       f"{frac:.3f}")

    # -- cross-backend: TPU wisdom -> held-out GPU family ---------------------
    yield csv_row("portability_xbackend", "kernel", "target", "problem",
                  "transfer_fraction", "fallback_fraction", "confidence",
                  "penalty_applied", "pass")
    report = build_cross_backend_report()
    again = build_cross_backend_report()
    assert dump_holdout_report(report) == dump_holdout_report(again), \
        "cross-backend portability report is not deterministic"
    for k in report["kernels"]:
        for s in k["scenarios"]:
            problem = s["scenario"].split("|")[1]
            yield csv_row("portability_xbackend", k["kernel"],
                          s["target_device"], problem,
                          s["transfer"]["fraction"],
                          s["fallback"]["fraction"],
                          s["confidence"], int(s["penalty_applied"]),
                          int(k["pass"]))
    assert report["pass"], (
        "cross-backend portability regression: a kernel's mean transfer "
        "fraction dropped below its gate, behind the cold fallback, or a "
        "cross-backend record escaped the backend penalty")


def main(argv: list[str] | None = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(prog="python -m benchmarks.portability")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless every pinned gate passes")
    ap.add_argument("--out", default=None, help="write report JSON here")
    args = ap.parse_args(argv)
    report = build_cross_backend_report()
    text = dump_holdout_report(report)
    if args.out:
        Path(args.out).write_text(text)
        print(f"report -> {args.out}")
    for k in report["kernels"]:
        state = "ok  " if k["pass"] else "FAIL"
        print(f"{state} {k['kernel']}: cross-backend transfer "
              f"{k['mean_transfer_fraction']:.4f} vs fallback "
              f"{k['mean_fallback_fraction']:.4f} "
              f"(threshold {k['threshold']:.2f}, "
              f"{len(k['scenarios'])} scenarios over "
              f"{len(report['gpu_targets'])} GPU targets)")
    print(f"shipped GPU-recorded kernels: "
          f"{', '.join(report['shipped_gpu_kernels'])}")
    print("overall:", "PASS" if report["pass"] else "FAIL")
    if args.check and not report["pass"]:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
