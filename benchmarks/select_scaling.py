"""Select-latency scaling gate: ``Wisdom.select`` must stay O(1) as the
store grows (ISSUE 9; latency-regression gating motivated by the KTT
autotuning benchmark-suite methodology).

Two checks, both deterministic:

* **Scaling**: populate synthetic wisdom stores of 10^2 → 10^5 records
  (unique scenarios over a device/dtype/problem grid) and measure
  exact-tier ``select_record`` latency. With the :class:`WisdomIndex`
  the select cost is a few dict hops regardless of store size, so the
  p50 at 10^5 records must stay within ``MAX_P50_RATIO`` (2x) of the
  p50 at 10^2 — the pre-index linear scan fails this by ~three orders
  of magnitude. Per size, the p50 is taken per measurement round and
  the best round wins, which suppresses scheduler noise in CI.

* **Equivalence**: on wisdom built from the shipped recorded-space
  fixtures (``benchmarks/datasets/``) plus synthetic transferred
  records, indexed ``select_record`` must return a byte-identical
  (record_id, tier) to the historical linear scan
  (``select_record_linear``) for every query in a grid of exact hits,
  every fallback tier, confidence-gated transfers and default misses.
  (The randomized version of this proof lives in
  ``tests/test_wisdom_index_props.py``; this is the fixture-anchored
  smoke the CI gate runs.)

CSV: size, p50_us, ratio_vs_smallest, pass — then one equivalence row.
``--check`` exits nonzero if any gate fails (the ``serve-smoke`` CI job).
"""

from __future__ import annotations

import statistics
import sys
import time
from pathlib import Path

from repro.core.device import get_device
from repro.core.wisdom import (Wisdom, WisdomRecord,
                               make_transfer_provenance)

try:
    from .common import csv_row
except ImportError:     # run as a plain script: python benchmarks/...py
    def csv_row(*fields) -> str:
        return ",".join(str(f) for f in fields)

DATASET_DIR = Path(__file__).parent / "datasets"

SIZES = (100, 1_000, 10_000, 100_000)
MAX_P50_RATIO = 2.0
ROUNDS = 5
CALLS_PER_ROUND = 400

_DEVICES = (("tpu-v5e", "tpu-v5"), ("tpu-v4", "tpu-v4"), ("cpu", "cpu"))
_DTYPES = ("float32", "bfloat16", "float16", "int8")


def synth_record(i: int) -> WisdomRecord:
    """Deterministic synthetic record #i with a unique scenario."""
    kind, family = _DEVICES[i % len(_DEVICES)]
    dtype = _DTYPES[(i // len(_DEVICES)) % len(_DTYPES)]
    # Spread problem sizes so fallback-tier distances are non-trivial.
    m = 8 << (i % 11)
    n = 8 << ((i // 11) % 11)
    k = 8 + i // 121
    return WisdomRecord(
        device_kind=kind, device_family=family,
        problem_size=(m, n, k), dtype=dtype,
        config={"block_m": 64, "block_n": 128, "seq": i},
        score_us=float(1 + (i % 997)),
        provenance={"strategy": "synthetic", "evaluations": 64})


def synth_wisdom(n: int) -> Wisdom:
    return Wisdom("synthetic", [synth_record(i) for i in range(n)])


def measure_p50(wisdom: Wisdom, queries) -> float:
    """Best-of-rounds p50 select latency in microseconds."""
    wisdom.select_record(*queries[0])       # warm: build the index once
    round_p50s = []
    for _ in range(ROUNDS):
        times = []
        for j in range(CALLS_PER_ROUND):
            q = queries[j % len(queries)]
            t0 = time.perf_counter()
            wisdom.select_record(*q)
            times.append(time.perf_counter() - t0)
        round_p50s.append(statistics.median(times))
    return min(round_p50s) * 1e6


def scaling_rows():
    """[(size, p50_us)] for each synthetic store size."""
    out = []
    for size in SIZES:
        wisdom = synth_wisdom(size)
        # Exact-tier queries spread across the store (the serve hot path).
        step = max(1, size // 64)
        queries = [(r.device_kind, r.problem_size, r.dtype)
                   for r in wisdom.records[::step]]
        out.append((size, measure_p50(wisdom, queries)))
    return out


def fixture_wisdom() -> Wisdom:
    """Wisdom over the shipped recorded-space fixtures: every feasible
    entry of every dataset becomes a measured record (same scenario →
    keep-best dedup, exercising add()'s index path), plus one synthetic
    transferred record per dataset scenario."""
    from repro.tunebench import SpaceDataset
    paths = sorted(DATASET_DIR.glob("*.space.json"))
    assert paths, f"no shipped datasets under {DATASET_DIR}"
    wisdom = Wisdom("fixture")
    for p in paths:
        ds = SpaceDataset.load(p)
        family = get_device(ds.device_kind).family
        for ev in ds.feasible():
            wisdom.add(WisdomRecord(
                device_kind=ds.device_kind, device_family=family,
                problem_size=ds.problem_size, dtype=ds.dtype,
                config=dict(ev.config), score_us=float(ev.score_us),
                provenance={"strategy": "recorded", "evaluations": 1}))
        wisdom.add(WisdomRecord(
            device_kind="tpu-v4", device_family="tpu-v4",
            problem_size=ds.problem_size, dtype=ds.dtype,
            config={"transferred": True},
            score_us=1.0,
            provenance=make_transfer_provenance(
                ds.device_kind, len(ds), confidence=0.8,
                predicted_us=1.0)), keep_best=False)
    return wisdom


def equivalence_queries(wisdom: Wisdom):
    """Query grid hitting every §4.5 tier against ``wisdom``."""
    queries = []
    for r in wisdom.records:
        p = r.problem_size
        queries += [
            (r.device_kind, p, r.dtype, None),                 # exact
            (r.device_kind, p, "bfloat16", None),              # dtype miss
            (r.device_kind, tuple(2 * x for x in p), r.dtype, None),
            ("tpu-v4", p, r.dtype, None),                      # transfer/dev
            ("tpu-v4", p, r.dtype, 0.9),                       # gated out
            ("tpu-v5-lite", p, r.dtype, None),                 # family tier
            ("gpu-h100", p, "float64", None),                  # any tier
        ]
    return queries


def check_equivalence(wisdom: Wisdom) -> tuple[int, int]:
    """(queries, mismatches) of indexed vs linear-scan selection."""
    queries = equivalence_queries(wisdom)
    bad = 0
    for q in queries:
        got = wisdom.select_record(*q)
        want = wisdom.select_record_linear(*q)
        got_id = got[0].record_id() if got[0] is not None else None
        want_id = want[0].record_id() if want[0] is not None else None
        if (got_id, got[1]) != (want_id, want[1]):
            bad += 1
    return len(queries), bad


def run():
    yield csv_row("select_scaling", "records", "p50_us",
                  "ratio_vs_smallest", "pass")
    rows = scaling_rows()
    base = rows[0][1]
    worst = 0.0
    for size, p50 in rows:
        ratio = p50 / base if base else 0.0
        worst = max(worst, ratio)
        yield csv_row("select_scaling", size, f"{p50:.3f}",
                      f"{ratio:.3f}", int(ratio <= MAX_P50_RATIO))
    yield csv_row("select_equivalence", "queries", "mismatches", "pass")
    n_q, bad = check_equivalence(fixture_wisdom())
    yield csv_row("select_equivalence", n_q, bad, int(bad == 0))
    run.passed = worst <= MAX_P50_RATIO and bad == 0


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    check = "--check" in argv
    for row in run():
        print(row)
    if check and not run.passed:
        print("select_scaling: FAILED (p50 not flat or indexed select "
              "diverged from linear scan)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
