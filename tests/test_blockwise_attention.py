"""Property tests: blockwise (flash-style jnp) attention == naive oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ref import (_naive_attention_ref,
                               blockwise_attention_ref)


def mk(rng, b, hq, hkv, sq, sk, d, dv=None):
    q = rng.standard_normal((b, hq, sq, d)).astype(np.float32)
    k = rng.standard_normal((b, hkv, sk, d)).astype(np.float32)
    v = rng.standard_normal((b, hkv, sk, dv or d)).astype(np.float32)
    return q, k, v


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 1000),
    sq=st.sampled_from([63, 64, 100, 128]),
    sk=st.sampled_from([48, 64, 96, 130]),
    causal=st.booleans(),
    window=st.sampled_from([None, 0, 16, 1000]),
    softcap=st.sampled_from([None, 20.0]),
    group=st.sampled_from([(2, 2), (4, 2), (4, 1)]),
)
def test_blockwise_matches_naive(seed, sq, sk, causal, window, softcap,
                                 group):
    rng = np.random.default_rng(seed)
    hq, hkv = group
    q, k, v = mk(rng, 2, hq, hkv, sq, sk, 32)
    a = blockwise_attention_ref(q, k, v, causal=causal, window=window,
                                softcap=softcap, q_chunk=32, k_chunk=32)
    b = _naive_attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=None, kv_offset=0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


def test_blockwise_kv_offset_decode_semantics(rng):
    """kv_offset: queries start mid-cache (chunked prefill semantics)."""
    q, k, v = mk(rng, 1, 2, 2, 8, 64, 16)
    a = blockwise_attention_ref(q, k, v, causal=True, kv_offset=40,
                                q_chunk=4, k_chunk=16)
    b = _naive_attention_ref(q, k, v, causal=True, window=None,
                             softcap=None, scale=None, kv_offset=40)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


def test_blockwise_different_v_dim(rng):
    """MLA folds (nope++rope) into qk-dim while v stays smaller."""
    q, k, v = mk(rng, 1, 4, 4, 64, 64, 48, dv=32)
    a = blockwise_attention_ref(q, k, v, causal=True, q_chunk=16,
                                k_chunk=32, scale=0.17)
    b = _naive_attention_ref(q, k, v, causal=True, window=None,
                             softcap=None, scale=0.17, kv_offset=0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


def test_blockwise_grad_matches(rng):
    import jax
    import jax.numpy as jnp
    q, k, v = mk(rng, 1, 2, 2, 64, 64, 16)

    def loss_block(q):
        return blockwise_attention_ref(jnp.asarray(q), k, v, causal=True,
                                       q_chunk=16, k_chunk=16).sum()

    def loss_naive(q):
        return _naive_attention_ref(jnp.asarray(q), k, v, causal=True,
                                    window=None, softcap=None, scale=None,
                                    kv_offset=0).sum()

    g1 = jax.grad(loss_block)(q)
    g2 = jax.grad(loss_naive)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-4, atol=1e-4)
