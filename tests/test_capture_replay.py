"""Capture (paper §4.2) + replay tuning (paper §4.3)."""

import numpy as np
import pytest

from repro.core import (CAPTURE_ENV, WisdomKernel, capture_requested,
                        get_kernel, list_captures, load_capture,
                        write_capture)
from repro.tuner import tune_capture


def test_capture_env_gating(monkeypatch):
    monkeypatch.delenv(CAPTURE_ENV, raising=False)
    assert not capture_requested("advec_u")
    monkeypatch.setenv(CAPTURE_ENV, "advec_u,matmul")
    assert capture_requested("advec_u")
    assert capture_requested("matmul")
    assert not capture_requested("diff_uvw")
    monkeypatch.setenv(CAPTURE_ENV, "*")
    assert capture_requested("anything")


def test_capture_roundtrip(capture_dir, small_fields):
    u, v, w, _, scal = small_fields
    path = write_capture("advec_u", (32, 32, 128), "float32",
                         [u, v, w, scal])
    cap = load_capture(path)
    assert cap.kernel_name == "advec_u"
    assert cap.problem_size == (32, 32, 128)
    assert len(cap.args) == 4
    np.testing.assert_array_equal(cap.args[0], u)
    assert cap.nbytes == sum(a.nbytes for a in [u, v, w, scal])
    assert cap.meta["capture_seconds"] > 0


def test_launch_captures_when_requested(monkeypatch, capture_dir,
                                        wisdom_dir, small_fields):
    u, v, w, _, scal = small_fields
    monkeypatch.setenv(CAPTURE_ENV, "advec_u")
    k = WisdomKernel(get_kernel("advec_u"), wisdom_dir=wisdom_dir,
                     device_kind="tpu-v5e", backend="reference")
    k(u, v, w, scal)
    caps = list_captures(capture_dir)
    assert len(caps) == 1
    assert "advec_u-32x32x128-float32" in caps[0].name


def test_tune_capture_end_to_end(monkeypatch, capture_dir, wisdom_dir,
                                 small_fields):
    """The paper's full loop: capture -> replay-tune -> wisdom -> runtime
    selection picks the tuned config."""
    u, v, w, _, scal = small_fields
    monkeypatch.setenv(CAPTURE_ENV, "advec_u")
    k = WisdomKernel(get_kernel("advec_u"), wisdom_dir=wisdom_dir,
                     device_kind="tpu-v5e", backend="reference")
    k(u, v, w, scal)
    assert k.stats[-1].tier == "default"
    monkeypatch.delenv(CAPTURE_ENV)

    cap = list_captures(capture_dir)[0]
    res = tune_capture(cap, "tpu-v5e", strategy="random", max_evals=40,
                       wisdom_dir=wisdom_dir, time_budget_s=30)
    assert res.best_config is not None
    assert np.isfinite(res.best_score_us)
    # every feasible evaluation was verified or scored
    assert len(res.evaluations) >= 30

    k.invalidate()
    k(u, v, w, scal)
    assert k.stats[-1].tier == "exact"
    assert k.stats[-1].config == res.best_config


def test_tuned_config_beats_default_on_simulated_device(
        monkeypatch, capture_dir, wisdom_dir, small_fields):
    u, v, w, _, scal = small_fields
    from repro.tuner import CostModelEvaluator
    from repro.core import get_device
    b = get_kernel("advec_u")
    ev = CostModelEvaluator(b, (16, 16, 128), "float32",
                            get_device("tpu-v5e"), verify="none")
    default_score = ev(b.default_config()).score_us
    monkeypatch.setenv(CAPTURE_ENV, "advec_u")
    k = WisdomKernel(b, wisdom_dir=wisdom_dir, device_kind="tpu-v5e",
                     backend="reference")
    k(u, v, w, scal)
    monkeypatch.delenv(CAPTURE_ENV)
    res = tune_capture(list_captures(capture_dir)[0], "tpu-v5e",
                       strategy="bayes", max_evals=60,
                       wisdom_dir=wisdom_dir, time_budget_s=60)
    assert res.best_score_us <= default_score
