"""Checkpointing (atomic, keep-k, elastic) + fault-tolerance runtime."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, \
    save_checkpoint
from repro.checkpoint.manager import latest_step
from repro.runtime import CrossPodSync, StepWatchdog
from repro.runtime.watchdog import StragglerReport


def tiny_state(x=1.0):
    return {"params": {"w": jnp.full((4, 4), x), "b": jnp.arange(4.0)},
            "opt": {"m": {"w": jnp.zeros((4, 4)), "b": jnp.zeros(4)},
                    "count": jnp.asarray(3, jnp.int32)},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    state = tiny_state(2.5)
    save_checkpoint(tmp_path, 7, state)
    like = jax.eval_shape(lambda: tiny_state())
    restored, manifest = load_checkpoint(tmp_path, like=like)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_no_tmp_left_and_partial_ignored(tmp_path):
    save_checkpoint(tmp_path, 5, tiny_state())
    assert not list(tmp_path.glob("*.tmp"))
    # a crashed (partial) write must be invisible to latest_step
    bad = tmp_path / "step-00000009.tmp"
    bad.mkdir()
    (bad / "leaf-00000.npy").write_bytes(b"garbage")
    assert latest_step(tmp_path) == 5


def test_keep_k_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, save_every=1)
    for s in range(1, 6):
        mgr.save(s, tiny_state(float(s)))
    steps = sorted(int(p.name.split("-")[1]) for p in tmp_path.iterdir())
    assert steps == [4, 5]


def test_shape_mismatch_rejected(tmp_path):
    save_checkpoint(tmp_path, 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        load_checkpoint(tmp_path, like={"w": jnp.zeros((3, 3))})


def test_elastic_restore_new_mesh(tmp_path):
    """Checkpoint written under one sharding restores onto another mesh
    layout (leaves are stored unsharded)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(tmp_path, 1, state)
    mesh = jax.make_mesh((1,), ("model",))
    shard = {"w": NamedSharding(mesh, P("model", None))}
    restored, _ = load_checkpoint(tmp_path, like=state, shardings=shard)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert restored["w"].sharding == shard["w"]


# -------------------------------- watchdog --------------------------------


def test_watchdog_flags_stragglers_and_hangs():
    wd = StepWatchdog(window=50, tolerance=1.5, hang_factor=10.0,
                      min_samples=5)
    for i in range(10):
        assert wd.record(i, 1.0) is None
    r = wd.record(10, 1.8)
    assert r is not None and r.kind == "straggle"
    r = wd.record(11, 30.0)
    assert r is not None and r.kind == "hang"
    assert wd.is_hang(25.0)
    assert not wd.is_hang(2.0)


def test_watchdog_suspect_workers():
    wd = StepWatchdog(min_samples=5, tolerance=1.5)
    for i in range(20):
        wd.record(i, 1.0, worker=0)
    for i in range(20, 30):
        wd.record(i, 2.5 if i % 2 else 1.0, worker=1)  # 50% straggles
    assert wd.suspects() == [1]


# ------------------------------- cross-pod --------------------------------


def test_crosspod_sync_compression_and_agreement():
    params = {"w": jnp.ones((8, 8)), "b": jnp.zeros(8)}
    sync = CrossPodSync(n_pods=2, inner_steps=4)
    pods = sync.init(params)
    # simulate divergent inner training
    pods[0] = jax.tree.map(lambda p: p + 0.01, pods[0])
    pods[1] = jax.tree.map(lambda p: p + 0.03, pods[1])
    anchor, new_pods, stats = sync.sync(params, pods)
    # pods agree afterwards
    for a, b in zip(jax.tree.leaves(new_pods[0]),
                    jax.tree.leaves(new_pods[1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # averaged delta applied: anchor ~ params + 0.02
    np.testing.assert_allclose(np.asarray(anchor["w"]),
                               np.ones((8, 8)) + 0.02, atol=1e-3)
    assert stats["compression"] > 3.0   # int8 vs f32


def test_crosspod_error_feedback_recovers_small_deltas():
    """Deltas below one quant step are not lost: error feedback carries
    them into later syncs."""
    params = {"w": jnp.zeros(16)}
    sync = CrossPodSync(n_pods=1, inner_steps=1)
    pods = sync.init(params)
    anchor = params
    total_true = 0.0
    for step in range(20):
        # one big outlier forces a coarse scale; tiny real signal elsewhere
        delta = jnp.full(16, 1e-4).at[0].set(1.0 if step == 0 else 0.0)
        pods[0] = jax.tree.map(lambda p, d=delta: p + d, anchor)
        total_true += 1e-4
        anchor, pods, _ = sync.sync(anchor, pods)
    np.testing.assert_allclose(np.asarray(anchor["w"][1:]),
                               np.full(15, total_true), rtol=0.2)
