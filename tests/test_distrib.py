"""Fleet wisdom distribution: versioned stores, merge, sync, CLI.

Covers the ISSUE 2 acceptance criteria: conflicting same-scenario records
merge deterministically to the statistical winner with both provenances
preserved in its lineage (library AND ``python -m repro.wisdom merge``),
equal-time ties resolve identically regardless of input order, files from
a future ``WISDOM_VERSION`` are refused loudly, and v1 files round-trip
through ``migrate``.
"""

import json

import numpy as np
import pytest

from repro.core.wisdom import (WISDOM_VERSION, Wisdom, WisdomRecord,
                               WisdomVersionError, make_provenance,
                               migrate_doc)
from repro.distrib import (DirectoryTransport, MemoryTransport, PullSync,
                           PushSync, WisdomStore, merge_stores, merge_wisdom)
from repro.distrib.cli import main as wisdom_cli


def rec(device="tpu-v5e", family="tpu-v5", problem=(256, 256),
        dtype="float32", score=100.0, config=None, host="hostA",
        strategy="bayes", evals=10):
    prov = make_provenance(strategy=strategy, evals=evals,
                           objective="costmodel")
    prov["host"] = host
    return WisdomRecord(device_kind=device, device_family=family,
                        problem_size=tuple(problem), dtype=dtype,
                        config=config or {"block": 1},
                        score_us=score, provenance=prov)


def store_with(path, *records, kernel="k"):
    store = WisdomStore(path)
    w = Wisdom(kernel)
    for r in records:
        w.add(r, keep_best=False)
    store.save(w)
    return store


# ------------------------------- merge engine --------------------------------

def test_merge_conflict_keeps_faster_and_both_provenances(tmp_path):
    """The acceptance-criteria scenario: two stores, same (device, problem,
    dtype), different configs/scores -> faster wins, lineage holds both."""
    slow = rec(score=100.0, config={"block": 1}, host="hostA")
    fast = rec(score=40.0, config={"block": 8}, host="hostB")
    a = store_with(tmp_path / "a", slow)
    b = store_with(tmp_path / "b", fast)

    report = merge_stores(a, b)
    merged = a.load("k")
    assert len(merged) == 1
    winner = merged.records[0]
    assert winner.config == {"block": 8}
    assert winner.score_us == 40.0
    assert winner.provenance["host"] == "hostB"
    hosts = {e.get("host") for e in winner.lineage}
    assert hosts == {"hostA", "hostB"}          # both provenances preserved
    assert report.conflicts == 1 and report.replaced == 1


def test_merge_is_order_independent(tmp_path):
    records = [rec(score=s, config={"block": i}, host=f"h{i}")
               for i, s in enumerate([50.0, 30.0, 80.0])]
    wisdoms = [Wisdom("k", [r]) for r in records]
    docs = []
    for order in ([0, 1, 2], [2, 1, 0], [1, 0, 2]):
        merged = merge_wisdom(*[wisdoms[i] for i in order])
        docs.append(json.dumps(merged.to_doc(), sort_keys=True))
    assert docs[0] == docs[1] == docs[2]
    assert merge_wisdom(*wisdoms).records[0].config == {"block": 1}


def test_merge_equal_times_tie_breaks_on_evaluations_then_id(tmp_path):
    """Duplicate scenarios with equal measured times: more tuning effort
    wins; with effort also equal the pick is still deterministic."""
    light = rec(score=50.0, config={"block": 1}, host="hA", evals=5)
    heavy = rec(score=50.0, config={"block": 2}, host="hB", evals=500)
    m1 = merge_wisdom(Wisdom("k", [light]), Wisdom("k", [heavy]))
    m2 = merge_wisdom(Wisdom("k", [heavy]), Wisdom("k", [light]))
    assert m1.records[0].config == {"block": 2}        # more evaluations
    assert (json.dumps(m1.to_doc(), sort_keys=True)
            == json.dumps(m2.to_doc(), sort_keys=True))

    # fully-equal stats: winner decided by record_id, same either way
    x = rec(score=50.0, config={"block": 3}, host="hX", evals=5)
    y = rec(score=50.0, config={"block": 4}, host="hY", evals=5)
    w1 = merge_wisdom(Wisdom("k", [x]), Wisdom("k", [y])).records[0]
    w2 = merge_wisdom(Wisdom("k", [y]), Wisdom("k", [x])).records[0]
    assert w1.config == w2.config
    expected = min([x, y], key=lambda r: r.record_id())
    assert w1.config == expected.config


def test_merge_idempotent_and_self_merge_stable(tmp_path):
    a = store_with(tmp_path / "a", rec(score=10.0, config={"block": 1}),
                   rec(problem=(64, 64), score=5.0, config={"block": 2}))
    b = store_with(tmp_path / "b", rec(score=7.0, config={"block": 9},
                                       host="hB"))
    merge_stores(a, b)
    snap = a.load("k").to_doc()
    merge_stores(a, b)                        # merging again changes nothing
    assert a.load("k").to_doc() == snap


def test_merge_refuses_mixed_kernels():
    with pytest.raises(ValueError, match="different kernels"):
        merge_wisdom(Wisdom("k1", [rec()]), Wisdom("k2", [rec()]))


def test_merge_disjoint_kernels_unions(tmp_path):
    a = WisdomStore(tmp_path / "a")
    wa = Wisdom("alpha")
    wa.add(rec())
    a.save(wa)
    b = WisdomStore(tmp_path / "b")
    wb = Wisdom("beta")
    wb.add(rec(config={"block": 3}))
    b.save(wb)
    merge_stores(a, b)
    assert a.kernels() == ["alpha", "beta"]
    assert len(a.load("beta")) == 1


# ---------------------------- schema versioning ------------------------------

def write_doc(path, doc):
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(doc))


def test_future_version_refused_loudly(tmp_path):
    store = WisdomStore(tmp_path)
    write_doc(store.path_for("k"), {
        "kernel": "k", "version": WISDOM_VERSION + 1,
        "records": [rec().to_json()]})
    with pytest.raises(WisdomVersionError, match="version "
                       f"{WISDOM_VERSION + 1}"):
        store.load("k")
    with pytest.raises(WisdomVersionError):
        store.migrate()
    # merge must refuse too, not silently drop the records
    dest = store_with(tmp_path / "dest", rec())
    with pytest.raises(WisdomVersionError):
        merge_stores(dest, store)
    # validate reports it instead of raising (complete report semantics)
    issues = store.validate()
    assert len(issues) == 1 and "version" in issues[0].problem


def test_v1_file_migrate_round_trip(tmp_path):
    store = WisdomStore(tmp_path)
    v1_rec = rec(score=12.0, config={"block": 4}).to_json()
    del v1_rec["lineage"]                      # v1 records have no lineage
    write_doc(store.path_for("k"), {"kernel": "k", "version": 1,
                                    "records": [v1_rec]})
    assert store.version_of("k") == 1
    # loading migrates in memory without touching the file
    loaded = store.load("k")
    assert loaded.records[0].lineage == []
    assert store.version_of("k") == 1

    assert store.migrate() == ["k"]
    assert store.version_of("k") == WISDOM_VERSION
    again = store.load("k")
    assert again.records[0].config == {"block": 4}
    assert again.records[0].score_us == 12.0
    assert store.migrate() == []               # idempotent
    assert store.validate() == []


def test_unversioned_doc_counts_as_v1(tmp_path):
    store = WisdomStore(tmp_path)
    v1_rec = rec().to_json()
    del v1_rec["lineage"]
    write_doc(store.path_for("k"), {"kernel": "k", "records": [v1_rec]})
    assert store.version_of("k") == 1
    assert len(store.load("k")) == 1


def test_migrate_doc_refuses_future_and_is_pure():
    doc = {"kernel": "k", "version": 1, "records": [{"device_kind": "d"}]}
    out = migrate_doc(doc)
    assert out["version"] == WISDOM_VERSION
    assert "lineage" in out["records"][0]
    assert "lineage" not in doc["records"][0]      # input untouched
    with pytest.raises(WisdomVersionError):
        migrate_doc({"version": WISDOM_VERSION + 5})


# ------------------------------- store upkeep --------------------------------

def test_store_validate_flags_bad_json_and_mismatch(tmp_path):
    store = WisdomStore(tmp_path)
    store.path_for("broken").parent.mkdir(parents=True, exist_ok=True)
    store.path_for("broken").write_text("{not json")
    store.path_for("listdoc").write_text("[]")     # valid JSON, wrong shape
    write_doc(store.path_for("other"), {"kernel": "different",
                                        "version": WISDOM_VERSION,
                                        "records": []})
    problems = {i.kernel: i.problem for i in store.validate()}
    assert "unreadable JSON" in problems["broken"]
    assert "not a JSON object" in problems["listdoc"]
    assert "does not match" in problems["other"]
    with pytest.raises(ValueError, match="not a JSON object"):
        store.load("listdoc")


def test_store_prune(tmp_path):
    dup_a = rec(score=10.0, config={"block": 1})
    dup_b = rec(score=4.0, config={"block": 2})
    other_dev = rec(device="tpu-v4", family="tpu-v4", score=9.0)
    store = store_with(tmp_path, dup_a, dup_b, other_dev)
    report = store.prune(device_kind="tpu-v5e")
    assert report.total == 2                     # the dup loser + tpu-v4
    kept = store.load("k").records
    assert len(kept) == 1 and kept[0].config == {"block": 2}
    # pruning everything removes the file
    store.prune(device_kind="no-such-device")
    assert store.kernels() == []


def test_provenance_tolerates_host_and_platform_failures(monkeypatch):
    import platform
    import socket

    def boom(*a, **k):
        raise OSError("sandboxed")

    monkeypatch.setattr(socket, "gethostname", boom)
    monkeypatch.setattr(platform, "platform", boom)
    prov = make_provenance(strategy="s", evals=1, objective="o")
    assert prov["host"] == "unknown"
    assert prov["platform"] == "unknown"
    assert prov["strategy"] == "s"


# ----------------------------------- sync ------------------------------------

def test_push_broadcast_pull_round_trip(tmp_path):
    local = store_with(tmp_path / "local", rec(score=10.0,
                                               config={"block": 1}))
    transport = MemoryTransport()
    push = PushSync(local, transport)
    push.push()
    assert transport.list_kernels() == ["k"]

    # a second host broadcasts a faster promotion for the same scenario
    promoted = rec(score=3.0, config={"block": 16}, host="hostB",
                   strategy="online")
    PushSync(WisdomStore(tmp_path / "b"), transport).broadcast("k", promoted)

    puller = store_with(tmp_path / "c", rec(score=8.0, config={"block": 2},
                                            host="hostC"))
    PullSync(puller, transport, interval=1).pull()
    got = puller.load("k").records[0]
    assert got.config == {"block": 16}
    assert {e.get("host") for e in got.lineage} >= {"hostB", "hostC"}


def test_pull_persists_lineage_only_changes(tmp_path):
    """Same winner on both sides, but the fleet copy carries lineage from
    other hosts: the pooled history must be saved locally, not dropped."""
    import dataclasses

    base = rec(score=5.0, config={"block": 1}, host="h1")
    local = store_with(tmp_path / "l", base)
    transport = MemoryTransport()
    remote = dataclasses.replace(
        base, lineage=[{"host": "h2", "date": "2026-01-01T00:00:00+00:00"}])
    transport.publish("k", Wisdom("k", [remote]).to_doc())
    PullSync(local, transport, interval=1).pull()
    got = local.load("k").records[0]
    assert got.record_id() == base.record_id()
    assert any(e.get("host") == "h2" for e in got.lineage)


def test_push_never_clobbers_better_remote(tmp_path):
    transport = MemoryTransport()
    fast = rec(score=2.0, config={"block": 7}, host="fasthost")
    PushSync(store_with(tmp_path / "fast", fast), transport).push()
    slow = rec(score=90.0, config={"block": 1}, host="slowhost")
    PushSync(store_with(tmp_path / "slow", slow), transport).push()
    remote = transport.fetch("k")["records"]
    assert len(remote) == 1 and remote[0]["config"] == {"block": 7}


def test_directory_transport_equivalent_to_memory(tmp_path):
    src = store_with(tmp_path / "src", rec(score=5.0, config={"block": 3}))
    shared = DirectoryTransport(tmp_path / "shared")
    PushSync(src, shared).push()
    dst = WisdomStore(tmp_path / "dst")
    PullSync(dst, shared, interval=1).pull()
    assert (json.dumps(dst.load("k").to_doc(), sort_keys=True)
            == json.dumps(src.load("k").to_doc(), sort_keys=True))


def test_pull_tick_interval_and_kernel_refresh(tmp_path):
    class FakeKernel:
        def __init__(self, name):
            self.builder = type("B", (), {"name": name})()
            self.refreshes = 0

        def refresh_wisdom(self):
            self.refreshes += 1

    transport = MemoryTransport()
    PushSync(store_with(tmp_path / "src", rec(config={"block": 5})),
             transport).push()
    local = WisdomStore(tmp_path / "local")
    kern = FakeKernel("k")
    sync = PullSync(local, transport, kernels=[kern], interval=4)
    for _ in range(8):
        sync.tick()
    assert sync.pulls == 2                      # ticks 0 and 4
    assert kern.refreshes == 1                  # only the changing pull
    assert len(local.load("k")) == 1


class FlakyTransport(MemoryTransport):
    """MemoryTransport that raises on fetches of one kernel until
    ``heal()`` — the shared-mount-hiccup simulator."""

    def __init__(self, fail_on: str):
        super().__init__()
        self.fail_on = fail_on
        self.failing = True

    def heal(self):
        self.failing = False

    def fetch(self, kernel_name):
        if self.failing and kernel_name == self.fail_on:
            raise OSError(f"transport lost mid-pull fetching {kernel_name}")
        return super().fetch(kernel_name)


def test_pull_is_transactional_on_transport_failure(tmp_path):
    """ISSUE 5 satellite: a transport dying mid-pull must leave the local
    store byte-identical — no kernel from earlier in the same pull may
    have been persisted (partial store state)."""
    transport = FlakyTransport(fail_on="bbb")
    transport.publish("aaa", Wisdom("aaa", [rec(config={"block": 2})])
                      .to_doc())
    transport.publish("bbb", Wisdom("bbb", [rec(config={"block": 3})])
                      .to_doc())
    local = WisdomStore(tmp_path / "local")
    sync = PullSync(local, transport, interval=1)
    with pytest.raises(OSError):
        sync.pull()
    # "aaa" fetched fine *before* "bbb" died — it must still not be saved
    assert local.kernels() == []
    transport.heal()
    sync.pull()
    assert local.kernels() == ["aaa", "bbb"]


def test_tick_swallows_transport_failure_and_recovers(tmp_path):
    """The serving-loop hook must never let a sync hiccup escape into
    the decode step: failures are counted, the previously pulled wisdom
    stays served, and the next due tick retries."""
    transport = FlakyTransport(fail_on="k")
    served = Wisdom("k", [rec(score=5.0, config={"block": 9})])
    local = WisdomStore(tmp_path / "local")
    local.save(served)
    transport.publish("k", Wisdom("k", [rec(score=1.0,
                                            config={"block": 4})]).to_doc())
    sync = PullSync(local, transport, interval=2)
    assert sync.tick() is None                 # due, but transport raised
    assert sync.failures == 1 and isinstance(sync.last_error, OSError)
    assert local.load("k").records[0].config == {"block": 9}   # intact
    assert sync.tick() is None                 # off-interval: no attempt
    assert sync.failures == 1
    transport.heal()
    assert sync.tick() is not None             # due again: pull succeeds
    assert local.load("k").records[0].config == {"block": 4}


def test_serve_engine_survives_sync_failure_mid_pull(tmp_path):
    """ServeEngine end to end: the transport raising mid-pull must not
    kill the cohort, and the engine keeps serving from the wisdom it
    already had (no partial store state)."""
    import jax.numpy as jnp
    from repro.serve.engine import Request, ServeEngine

    class TinyLM:
        def init_cache(self, n_slots, max_seq):
            return {"pos": jnp.zeros((), jnp.int32)}

        def decode_step(self, params, cache, tok):
            return jnp.zeros((tok.shape[0], 1, 8), jnp.float32), cache

    transport = FlakyTransport(fail_on="bbb")
    transport.publish("aaa", Wisdom("aaa", [rec(config={"block": 2})])
                      .to_doc())
    transport.publish("bbb", Wisdom("bbb", [rec(config={"block": 3})])
                      .to_doc())
    local = WisdomStore(tmp_path / "local")
    before = Wisdom("aaa", [rec(score=1.0, config={"block": 8})])
    local.save(before)
    before_bytes = json.dumps(local.load("aaa").to_doc(), sort_keys=True)

    sync = PullSync(local, transport, interval=2)
    eng = ServeEngine(TinyLM(), params={}, n_slots=1, max_seq=16, sync=sync)
    assert eng.submit(Request(0, np.array([1, 2], np.int32),
                              max_new_tokens=3))
    out = eng.run()
    assert out[0] and eng.steps_run > 0        # serving completed
    assert sync.failures > 0
    # no partial state: neither kernel changed under the engine
    assert local.kernels() == ["aaa"]
    assert json.dumps(local.load("aaa").to_doc(),
                      sort_keys=True) == before_bytes


def test_serve_engine_ticks_sync(tmp_path):
    import jax.numpy as jnp
    from repro.serve.engine import Request, ServeEngine

    class TinyLM:
        def init_cache(self, n_slots, max_seq):
            return {"pos": jnp.zeros((), jnp.int32)}

        def decode_step(self, params, cache, tok):
            return jnp.zeros((tok.shape[0], 1, 8), jnp.float32), cache

    transport = MemoryTransport()
    PushSync(store_with(tmp_path / "fleet", rec(config={"block": 6})),
             transport).push()
    local = WisdomStore(tmp_path / "local")
    sync = PullSync(local, transport, interval=2)
    eng = ServeEngine(TinyLM(), params={}, n_slots=1, max_seq=16, sync=sync)
    assert eng.submit(Request(0, np.array([1, 2], np.int32),
                              max_new_tokens=3))
    eng.run()
    assert eng.steps_run > 0
    assert sync.pulls == (eng.steps_run + 1) // 2
    assert local.load("k").records[0].config == {"block": 6}


def test_promotion_broadcast_hook(tmp_path, wisdom_dir):
    from repro.core import WisdomKernel, get_kernel, load_builtin_kernels
    from repro.online.promotion import PromotionPipeline

    load_builtin_kernels()
    kernel = WisdomKernel(get_kernel("matmul"), wisdom_dir=wisdom_dir,
                          device_kind="tpu-v5e", backend="reference")
    transport = MemoryTransport()
    push = PushSync(WisdomStore(wisdom_dir), transport)
    pipe = PromotionPipeline(kernel, wisdom_dir=wisdom_dir, broadcast=push)
    promo = pipe.promote(
        device_kind="tpu-v5e", problem=(64, 64, 64), dtype="float32",
        config=dict(kernel.builder.default_config()), score_us=10.0,
        incumbent_score_us=100.0, n_measurements=3, evals=12,
        objective="costmodel")
    assert promo is not None
    assert pipe.broadcasts == 1
    remote = transport.fetch("matmul")
    assert remote is not None and len(remote["records"]) == 1
    assert remote["records"][0]["score_us"] == 10.0


def test_tune_kernel_writes_through_store(tmp_path):
    from repro.core import get_kernel, load_builtin_kernels
    from repro.tuner.tune import tune_kernel

    load_builtin_kernels()
    store = WisdomStore(tmp_path / "w")
    res = tune_kernel(get_kernel("matmul"), (64, 64, 64), "float32",
                      "tpu-v5e", strategy="random", max_evals=4,
                      time_budget_s=None, store=store)
    assert res.best_config is not None
    wisdom = store.load("matmul")
    assert len(wisdom) == 1
    assert store.version_of("matmul") == WISDOM_VERSION


# ------------------------------------ CLI ------------------------------------

def test_cli_merge_matches_library(tmp_path, capsys):
    """Acceptance: `python -m repro.wisdom merge` produces the identical
    result to the library merge."""
    slow = rec(score=100.0, config={"block": 1}, host="hostA")
    fast = rec(score=40.0, config={"block": 8}, host="hostB")
    lib_a = store_with(tmp_path / "lib_a", slow)
    lib_b = store_with(tmp_path / "lib_b", fast)
    cli_a = store_with(tmp_path / "cli_a", slow)
    cli_b = store_with(tmp_path / "cli_b", fast)

    merge_stores(lib_a, lib_b)
    assert wisdom_cli(["merge", "--into", str(cli_a.root),
                       str(cli_b.root)]) == 0
    lib_doc = lib_a.path_for("k").read_text()
    cli_doc = cli_a.path_for("k").read_text()
    assert lib_doc == cli_doc                  # byte-identical on disk
    winner = cli_a.load("k").records[0]
    assert winner.config == {"block": 8}
    assert {e.get("host") for e in winner.lineage} == {"hostA", "hostB"}


def test_cli_inspect_validate_migrate_prune_diff(tmp_path, capsys):
    store = store_with(tmp_path / "s", rec(score=7.0, config={"block": 2}))
    assert wisdom_cli(["inspect", "--dir", str(store.root), "-v"]) == 0
    out = capsys.readouterr().out
    assert "k: 1 record(s)" in out and "7.00us" in out

    assert wisdom_cli(["validate", "--dir", str(store.root)]) == 0

    # v1 file -> validate ok, migrate rewrites it
    v1 = rec().to_json()
    del v1["lineage"]
    write_doc(store.path_for("old"), {"kernel": "old", "version": 1,
                                      "records": [v1]})
    assert wisdom_cli(["migrate", "--dir", str(store.root)]) == 0
    assert "old: migrated" in capsys.readouterr().out
    assert store.version_of("old") == WISDOM_VERSION

    # future version -> validate exits non-zero; diff/merge report the
    # version skew cleanly (exit 2) instead of crashing
    write_doc(store.path_for("future"),
              {"kernel": "future", "version": WISDOM_VERSION + 9,
               "records": []})
    assert wisdom_cli(["validate", "--dir", str(store.root)]) == 1
    capsys.readouterr()
    assert wisdom_cli(["diff", str(store.root), str(store.root)]) == 2
    assert "error:" in capsys.readouterr().out
    assert wisdom_cli(["merge", "--into", str(tmp_path / "m"),
                       str(store.root)]) == 2
    store.path_for("future").unlink()

    other = store_with(tmp_path / "o", rec(score=3.0, config={"block": 4}))
    assert wisdom_cli(["diff", str(store.root), str(other.root)]) == 1
    assert "conflict" in capsys.readouterr().out

    assert wisdom_cli(["prune", "--dir", str(store.root),
                       "--device", "tpu-v5e"]) == 0
