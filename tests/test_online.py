"""Online autotuning: tracker, scheduler, promotion, end-to-end convergence.

The convergence test is the acceptance criterion for the subsystem: with an
*empty* wisdom dir, a WisdomKernel served with synthetic traffic must reach
a config within 5% of the offline-tuned optimum (cost-model objective,
fixed seed) in at most 300 launches, while non-trial launches keep running
the incumbent.
"""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Wisdom, WisdomKernel, get_device, get_kernel
from repro.online import (MISS_TIERS, OnlineTuner, OverheadBudget,
                          ScenarioTracker, TrialScheduler,
                          enable_online_tuning)
from repro.tuner.runner import CostModelEvaluator, EvalResult
from repro.tuner.strategies import tune_exhaustive

PROBLEM = (256, 256, 256)
DTYPE = "float32"
DEVICE = "tpu-v5e"


def _mm_args():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((256, 256)).astype(np.float32)
    b = rng.standard_normal((256, 256)).astype(np.float32)
    return a, b


def _kernel(wisdom_dir, **kw):
    k = WisdomKernel(get_kernel("matmul"), wisdom_dir=wisdom_dir,
                     device_kind=DEVICE, backend="reference")
    svc = enable_online_tuning(k, objective="costmodel", seed=0, **kw)
    return k, svc


def _offline_best():
    builder = get_kernel("matmul")
    ev = CostModelEvaluator(builder, PROBLEM, DTYPE, get_device(DEVICE),
                            verify="none")
    return tune_exhaustive(builder.space, ev)


# --------------------------- acceptance criterion ---------------------------

def test_online_convergence_within_300_launches(wisdom_dir):
    """Empty wisdom + synthetic traffic -> within 5% of offline optimum in
    <= 300 launches; non-trial launches always run the incumbent."""
    k, svc = _kernel(wisdom_dir)
    a, b = _mm_args()
    default_cfg = k.builder.default_config()

    promoted_at = None
    for i in range(300):
        k(a, b)
        if svc.promotions() and promoted_at is None:
            promoted_at = i + 1
            break
    assert promoted_at is not None, "no promotion within 300 launches"
    assert promoted_at <= 300

    # trailing traffic runs the promoted config at tier "exact"
    for _ in range(5):
        k(a, b)
    assert all(s.tier == "exact" for s in k.stats[-5:])

    # before promotion, every non-trial launch ran the incumbent (the
    # default config here — wisdom started empty)
    pre = k.stats[:promoted_at - 1]
    for s in pre:
        if s.tier != "trial":
            assert s.tier == "default"
            assert s.config == default_cfg

    # within 5% of the exhaustive offline optimum, same objective/seeding
    off = _offline_best()
    ev = CostModelEvaluator(k.builder, PROBLEM, DTYPE, get_device(DEVICE),
                            verify="none")
    inc_cfg, tier = k.select_config(PROBLEM, DTYPE)
    assert tier == "exact"
    assert ev(inc_cfg).score_us <= off.best_score_us * 1.05


def test_promotion_writes_online_record(wisdom_dir):
    k, svc = _kernel(wisdom_dir)
    a, b = _mm_args()
    for _ in range(300):
        k(a, b)
        if svc.promotions():
            break
    assert svc.promotions()
    w = Wisdom.load("matmul", wisdom_dir)
    assert len(w.records) == 1
    rec = w.records[0]
    assert rec.device_kind == DEVICE
    assert rec.device_family == get_device(DEVICE).family
    assert rec.problem_size == PROBLEM
    assert rec.dtype == DTYPE
    assert np.isfinite(rec.score_us)
    assert k.builder.space.is_valid(rec.config)
    assert rec.provenance["strategy"] == "online"
    assert rec.provenance["online"] is True
    assert rec.provenance["objective"] == "costmodel"
    assert rec.provenance["evaluations"] > 0
    assert rec.provenance["live_measurements"] >= 1


def test_promoted_variant_is_prewarmed(wisdom_dir):
    """The hot swap must not stall the next launch on compilation."""
    k, svc = _kernel(wisdom_dir)
    a, b = _mm_args()
    for _ in range(300):
        k(a, b)
        if svc.promotions():
            break
    assert svc.promotions()
    k(a, b)
    assert k.stats[-1].tier == "exact"
    assert k.stats[-1].cached            # promotion prewarmed it
    assert k.stats[-1].compile_s == 0.0


# ------------------------------ trial behaviour ------------------------------

def test_epsilon_zero_never_trials(wisdom_dir):
    k, svc = _kernel(wisdom_dir, epsilon=0.0)
    a, b = _mm_args()
    for _ in range(60):
        k(a, b)
    assert all(s.tier != "trial" for s in k.stats)
    assert svc.meter.trials == 0
    assert not svc.promotions()          # no live confirmation -> no promo


def test_budget_caps_screens_per_launch(wisdom_dir):
    budget = OverheadBudget(per_launch_s=10.0, screens_per_launch=2)
    k, svc = _kernel(wisdom_dir, budget=budget)
    a, b = _mm_args()
    n = 40
    for _ in range(n):
        k(a, b)
    assert svc.meter.screens <= budget.screens_per_launch * n


def test_tick_advances_screening_without_launches(wisdom_dir):
    k, svc = _kernel(wisdom_dir, epsilon=0.0)
    a, b = _mm_args()
    for _ in range(4):                   # past the activation threshold
        k(a, b)
    state = svc.state(PROBLEM, DTYPE)
    assert state is not None
    before = state.scheduler.screens
    while not state.scheduler.screening_done():
        assert svc.tick() > 0
    assert state.scheduler.screens > before


# --------------------------------- tracker -----------------------------------

def test_tracker_counts_misses_and_activates():
    t = ScenarioTracker(activation_threshold=3)
    for _ in range(2):
        t.observe(DEVICE, PROBLEM, DTYPE, "default")
    assert not t.is_hot(DEVICE, PROBLEM, DTYPE)
    t.observe(DEVICE, PROBLEM, DTYPE, "device+dtype")
    assert t.is_hot(DEVICE, PROBLEM, DTYPE)
    st = t.stats(DEVICE, PROBLEM, DTYPE)
    assert st.launches == 3 and st.misses == 3


def test_tracker_exact_and_forced_are_not_misses():
    t = ScenarioTracker(activation_threshold=1)
    t.observe(DEVICE, PROBLEM, DTYPE, "exact")
    t.observe(DEVICE, PROBLEM, DTYPE, "forced")
    assert not t.is_hot(DEVICE, PROBLEM, DTYPE)
    assert t.stats(DEVICE, PROBLEM, DTYPE).misses == 0
    assert "exact" not in MISS_TIERS and "forced" not in MISS_TIERS


# ------------------------ successive halving bracket -------------------------

def test_scheduler_halving_picks_best_under_noise():
    """Wall-clock-style noisy measurements: halving still finds the truly
    best candidate of the bracket."""
    builder = get_kernel("matmul")
    ev = CostModelEvaluator(builder, PROBLEM, DTYPE, get_device(DEVICE),
                            verify="none")
    rng = np.random.default_rng(1)
    sched = TrialScheduler(builder.space, ev, rng, pool_size=32,
                           bracket_size=4)

    class _Timer:
        def take(self):
            return True

    sched.screen(_Timer())
    assert sched.screening_done()
    truth = {sched.space.freeze(m.config): m.screen_score_us
             for m in sched._bracket.members}
    best_key = min(truth, key=truth.get)
    meas_rng = np.random.default_rng(2)
    for _ in range(200):
        cand = sched.next_trial()
        if cand is None:
            break
        noisy = truth[sched.space.freeze(cand)] * meas_rng.uniform(0.97, 1.03)
        sched.report_trial(cand, noisy)
    won = sched.winner()
    assert won is not None
    cfg, score, n = won
    assert sched.space.freeze(cfg) == best_key
    assert n >= 1


# --------------------------- traced launch streams ---------------------------

def test_traced_launches_feed_tracker_and_tick_promotes(wisdom_dir):
    """Kernels launched inside an outer jit can't run live trials, but
    their trace-time selection registers demand, and tick() resolves the
    whole loop under the cost-model objective."""
    import jax

    k, svc = _kernel(wisdom_dir)
    a, b = _mm_args()

    @jax.jit
    def outer(x, y):
        return k(x, y)

    np.asarray(outer(a, b))              # one traced execution stream
    state = svc.state(PROBLEM, DTYPE)
    assert state is not None and state.traced
    assert svc.meter.trials == 0         # no live trials were interleaved

    for _ in range(500):
        svc.tick()
        if svc.promotions():
            break
    assert svc.promotions(), "tick() never resolved the traced scenario"
    rec = Wisdom.load("matmul", wisdom_dir).records[0]
    assert rec.provenance["strategy"] == "online"
    cfg, tier = k.select_config(PROBLEM, DTYPE)
    assert tier == "exact"               # the next trace selects it


def test_dead_bracket_finishes_scenario(wisdom_dir):
    """Nothing feasible in the space -> scenario finishes without
    promotion instead of spending budget forever."""
    from repro.core import KernelBuilder

    b = KernelBuilder("dead-space-kernel")
    b.tune("x", (1, 2))
    b.restriction(lambda c: False)       # no valid config exists
    b.reference(lambda v: v)
    k = WisdomKernel(b, wisdom_dir=wisdom_dir, device_kind=DEVICE,
                     backend="reference")
    svc = enable_online_tuning(k, objective="costmodel", seed=0)
    v = np.ones((4,), np.float32)
    for _ in range(10):
        k(v)
    state = svc.state((4,), DTYPE)
    assert state is not None and state.finished
    assert not svc.promotions()
    assert any(kind == "no-candidates" for kind, _, _ in svc.events)


def test_incumbent_baseline_resets_when_selection_flips(wisdom_dir):
    """Wall-clock incumbent timings must not blend two different configs."""
    k, svc = _kernel(wisdom_dir, epsilon=0.0)
    a, b = _mm_args()
    for _ in range(10):
        k(a, b)
    state = svc.state(PROBLEM, DTYPE)
    assert len(state.incumbent_runs) > 0
    state.incumbent_score_us = 123.0
    flipped = dict(state.incumbent_config)
    flipped["block_m"] = 64 if flipped["block_m"] != 64 else 128
    state.set_incumbent(k.builder.space, flipped)
    assert len(state.incumbent_runs) == 0
    assert state.incumbent_score_us is None
    # rolling window stays bounded in observe-only mode
    for _ in range(5):
        k(a, b)
    assert state.incumbent_runs.maxlen is not None


# ----------------------------- host integration ------------------------------

class _FakeTuner:
    def __init__(self):
        self.ticks = 0

    def tick(self):
        self.ticks += 1
        return 0


def test_train_step_ticks_online_during_warmup():
    from repro.optim.adamw import AdamW
    from repro.train.step import make_train_step

    class TinyModel:
        def loss(self, params, batch):
            loss = jnp.sum(params["w"] ** 2) + jnp.sum(batch["x"])
            return loss, {"loss": loss}

    svc = _FakeTuner()
    opt = AdamW(lr=1e-2)
    params = {"w": jnp.ones((4,), jnp.float32)}
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    step_fn = make_train_step(TinyModel(), opt, online=svc,
                              online_warmup_steps=2)
    batch = {"x": jnp.ones((2, 2), jnp.float32)}
    for _ in range(4):
        state, _ = step_fn(state, batch)
    assert svc.ticks == 2                # only the warmup steps sponsor work


def test_serve_engine_ticks_online_each_decode_step():
    from repro.serve.engine import Request, ServeEngine

    class TinyLM:
        def init_cache(self, n_slots, max_seq):
            return {"pos": jnp.zeros((), jnp.int32)}

        def decode_step(self, params, cache, tok):
            logits = jnp.zeros((tok.shape[0], 1, 8), jnp.float32)
            return logits, cache

    svc = _FakeTuner()
    eng = ServeEngine(TinyLM(), params={}, n_slots=2, max_seq=32,
                      online=svc)
    assert eng.submit(Request(0, np.array([1, 2], np.int32),
                              max_new_tokens=3))
    eng.run()
    assert eng.steps_run > 0
    assert svc.ticks == eng.steps_run


# ------------------------------- env plumbing --------------------------------

def test_env_auto_attach(monkeypatch, wisdom_dir):
    monkeypatch.setenv("KERNEL_LAUNCHER_ONLINE", "1")
    k = WisdomKernel(get_kernel("matmul"), wisdom_dir=wisdom_dir,
                     device_kind=DEVICE, backend="reference")
    assert isinstance(k.online, OnlineTuner)
    monkeypatch.setenv("KERNEL_LAUNCHER_ONLINE", "0")
    k2 = WisdomKernel(get_kernel("matmul"), wisdom_dir=wisdom_dir,
                      device_kind=DEVICE, backend="reference")
    assert k2.online is None


def test_env_budget(monkeypatch):
    monkeypatch.setenv("KERNEL_LAUNCHER_ONLINE_BUDGET_MS", "5")
    monkeypatch.setenv("KERNEL_LAUNCHER_ONLINE_SCREENS", "3")
    b = OverheadBudget.from_env()
    assert b.per_launch_s == pytest.approx(5e-3)
    assert b.screens_per_launch == 3
