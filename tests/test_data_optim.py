"""Data pipeline determinism/sharding + optimizer + compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import SyntheticTokenDataset
from repro.optim import (AdamW, CompressionState, compress_int8,
                         constant_schedule, cosine_schedule,
                         decompress_int8)


def test_dataset_step_addressable():
    ds = SyntheticTokenDataset(vocab=256, seq=32, global_batch=8)
    b1 = ds.batch(7)
    b2 = ds.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_dataset_labels_are_shifted_tokens():
    ds = SyntheticTokenDataset(vocab=256, seq=32, global_batch=4)
    b = ds.batch(0)
    assert b["tokens"].shape == (4, 32)
    assert b["labels"].shape == (4, 32)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 256


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 100), hosts=st.sampled_from([2, 4]))
def test_dataset_host_sharding_partitions_global_batch(step, hosts):
    """Union of per-host slices == hosts x host_batch rows, deterministic
    per host; different hosts draw different rows."""
    shards = [SyntheticTokenDataset(vocab=128, seq=16, global_batch=8,
                                    num_hosts=hosts, host_id=h).batch(step)
              for h in range(hosts)]
    assert all(s["tokens"].shape[0] == 8 // hosts for s in shards)
    flat = np.concatenate([s["tokens"] for s in shards])
    assert flat.shape[0] == 8
    # hosts must not duplicate each other's rows (prob. of collision ~0)
    assert len({row.tobytes() for row in flat}) == 8


def test_dataset_has_learnable_structure():
    """Markov structure: bigram entropy < unigram entropy (learnability)."""
    ds = SyntheticTokenDataset(vocab=128, seq=512, global_batch=8)
    toks = ds.batch(0)["tokens"].reshape(-1)
    uni = np.bincount(toks, minlength=128) + 1e-9
    p_uni = uni / uni.sum()
    h_uni = -(p_uni * np.log(p_uni)).sum()
    pairs = toks[:-1].astype(np.int64) * 128 + toks[1:]
    bi = np.bincount(pairs, minlength=128 * 128).reshape(128, 128) + 1e-9
    p_joint = bi / bi.sum()
    p_cond_entropy = -(p_joint * (np.log(p_joint)
                                  - np.log(p_joint.sum(1, keepdims=True)))
                       ).sum()
    assert p_cond_entropy < 0.8 * h_uni


# ------------------------------- optimizer -------------------------------


def test_adamw_converges_on_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = opt.init(params)
    target = jnp.asarray([1.0, 2.0, -1.0])
    for _ in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = opt.update(grads, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_clips_gradients():
    opt = AdamW(lr=1e-3, clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    _, _, metrics = opt.update({"w": jnp.full(4, 100.0)}, state, params)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_schedules():
    s = cosine_schedule(1.0, warmup=10, total=100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert float(s(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(s(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)
    assert float(constant_schedule(3e-4)(jnp.asarray(5))) == \
        pytest.approx(3e-4)


# ------------------------------ compression ------------------------------


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(1e-3, 1e3))
def test_int8_roundtrip_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(256) * scale, jnp.float32)
    q, s, err = compress_int8(g)
    rec = decompress_int8(q, s)
    max_err = float(jnp.abs(rec - g).max())
    assert max_err <= float(s) * 0.5 + 1e-6   # half-ulp of the quant grid
    np.testing.assert_allclose(np.asarray(err), np.asarray(g - rec),
                               rtol=1e-5, atol=1e-6)


def test_error_feedback_unbiased_over_time():
    """With error feedback, the accumulated transmitted signal tracks the
    accumulated true signal (residual stays bounded)."""
    rng = np.random.default_rng(0)
    residual = jnp.zeros(64)
    sent_total = np.zeros(64)
    true_total = np.zeros(64)
    for _ in range(100):
        g = jnp.asarray(rng.standard_normal(64) * 0.01, jnp.float32)
        q, s, residual = compress_int8(g, residual)
        sent_total += np.asarray(decompress_int8(q, s))
        true_total += np.asarray(g)
    np.testing.assert_allclose(sent_total, true_total, atol=1e-3)
