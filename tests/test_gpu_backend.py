"""GPU as a second device family (ISSUE 10): device parsing tables, the
honest estimated-spec path for unknown hardware, backend-aware kernel
lowering (TPU-only Mosaic params must never reach a Triton or
interpreter lowering), GPU interpret-mode numerics against the reference
oracle, and the cross-backend transfer contract — predictions across the
TPU/GPU boundary are possible but confidence-penalized, and ``select``'s
transfer tier never serves one above the gate without the penalty
applied (property-tested against the real predictor)."""

import functools
import math
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.device import (BACKENDS, CAPABILITY_AXES, DEVICES, GPU_A100,
                               TPU_V5E, capability_vector, get_device,
                               parse_device_kind)
from repro.core.wisdom import TRANSFER_MIN_CONFIDENCE, Wisdom, WisdomRecord
from repro.kernels._lowering import active_backend, lowering_kwargs
from repro.transfer.model import (BACKEND_MISMATCH_PENALTY,
                                  ESTIMATED_SIMILARITY_CAP, DeviceModel)
from repro.transfer.predictor import (CONFIDENCE_BASE,
                                      CONFIDENCE_COVERAGE_WEIGHT,
                                      CONFIDENCE_FIT_WEIGHT,
                                      transfer_scenario)
from repro.tuner.runner import verify_against_reference
from repro.tunebench import SpaceDataset

DATASET_DIR = Path(__file__).parent.parent / "benchmarks" / "datasets"


# ---------------------------------------------------------------- parsing ----

#: (raw jax device_kind, platform) -> canonical kind. The v5p/v6e rows
#: are the regression surface for the old slugifier, which turned
#: "TPU v5p" into a prefix family that inherited v5e peaks, and matched
#: the bare "v5" marker before the "v5 lite" variants.
PARSE_TABLE = [
    ("TPU v4", "tpu", "tpu-v4"),
    ("TPU v5e", "tpu", "tpu-v5e"),
    ("TPU v5 lite", "tpu", "tpu-v5e"),
    ("TPU v5lite", "tpu", "tpu-v5e"),
    ("TPU v5p", "tpu", "tpu-v5p"),
    ("TPU v5", "tpu", "tpu-v5p"),
    ("TPU v6e", "tpu", "tpu-v6e"),
    ("TPU v6 lite", "tpu", "tpu-v6e"),
    ("NVIDIA A100-SXM4-40GB", "gpu", "gpu-a100"),
    ("NVIDIA A100-SXM4-80GB", "gpu", "gpu-a100"),
    ("NVIDIA RTX A4000", "gpu", "gpu-a4000"),
    # unknown parts slug to a backend-prefixed kind so get_device can at
    # least pick the right baseline for the estimated spec
    ("TPU v9x", "tpu", "tpu-v9x"),
    ("Tesla T4", "gpu", "gpu-tesla-t4"),
    ("AMD Instinct MI300X", "", "gpu-amd-instinct-mi300x"),
    ("cpu", "cpu", "cpu"),
    ("Apple M2", "", "cpu"),
]


@pytest.mark.parametrize("raw,platform,expected", PARSE_TABLE)
def test_parse_device_kind_table(raw, platform, expected):
    assert parse_device_kind(raw, platform) == expected


def test_parsed_known_kinds_resolve_to_table_specs():
    for raw, platform, expected in PARSE_TABLE:
        spec = get_device(parse_device_kind(raw, platform))
        if expected in DEVICES:
            assert not spec.estimated, (raw, expected)
        else:
            assert spec.estimated, (raw, expected)


# ---------------------------------------------------- device specs & table ---

def test_every_table_spec_declares_a_backend():
    for kind, spec in DEVICES.items():
        assert spec.backend in BACKENDS, kind
        assert not spec.estimated, kind
    assert get_device("gpu-a100").backend == "gpu"
    assert get_device("gpu-a4000").backend == "gpu"
    assert get_device("tpu-v5e").backend == "tpu"
    assert get_device("cpu").backend == "cpu"


def test_gpu_pair_mirrors_the_papers_hardware():
    a100, a4000 = get_device("gpu-a100"), get_device("gpu-a4000")
    assert a100.family == a4000.family == "gpu-ampere"
    assert a100.matmul_granule == a4000.matmul_granule == 16
    # the data-center part is ~4x the workstation part, like the paper's
    assert 3.0 < a100.flops_f32 / a4000.flops_f32 < 5.0
    assert 3.0 < a100.hbm_bw / a4000.hbm_bw < 4.0


def test_unknown_kind_is_estimated_not_silently_v5e():
    spec = get_device("gpu-h100")
    assert spec.estimated
    assert spec.backend == "gpu"
    assert spec.kind == "gpu-h100"
    # peaks are cloned from the *backend's* baseline, not from tpu-v5e
    assert capability_vector(spec)[:3] == capability_vector(GPU_A100)[:3]
    tpu_unknown = get_device("tpu-v9x")
    assert tpu_unknown.estimated
    assert tpu_unknown.backend == "tpu"
    assert capability_vector(tpu_unknown)[:3] == \
        capability_vector(TPU_V5E)[:3]


# -------------------------------------------------- cross-backend model -----

def _raw_similarity(model: DeviceModel) -> float:
    """exp(-rms(log2 ratios)) with no penalty/floor — the pre-GPU value."""
    logs = [math.log2(r) for r in model.ratios().values()]
    return math.exp(-math.sqrt(sum(x * x for x in logs) / len(logs)))


def test_backend_penalty_enters_similarity():
    same = DeviceModel.between("tpu-v5e", "tpu-v4")
    cross = DeviceModel.between("tpu-v5e", "gpu-a100")
    assert same.backend_penalty() == 1.0
    assert cross.backend_penalty() == BACKEND_MISMATCH_PENALTY < 1.0
    # same-backend pairs are untouched (the pre-GPU value)
    assert same.similarity() == pytest.approx(_raw_similarity(same))
    # cross-backend similarity is exactly the penalized raw value, and
    # can never exceed the penalty factor itself
    assert cross.similarity() == pytest.approx(
        _raw_similarity(cross) * BACKEND_MISMATCH_PENALTY)
    assert cross.similarity() <= BACKEND_MISMATCH_PENALTY


def test_estimated_pair_floors_below_the_serving_gate():
    for target in ("gpu-h100", "tpu-v9x", "gpu-mystery"):
        m = DeviceModel.between("tpu-v5e", target)
        assert m.estimated()
        assert m.similarity() <= ESTIMATED_SIMILARITY_CAP
        # the cap is chosen so even a perfect fit + coverage cannot
        # reach the serving gate
        best_possible = math.sqrt(ESTIMATED_SIMILARITY_CAP) * (
            CONFIDENCE_BASE + CONFIDENCE_FIT_WEIGHT
            + CONFIDENCE_COVERAGE_WEIGHT)
        assert best_possible < TRANSFER_MIN_CONFIDENCE


def test_capability_axes_unchanged():
    # the transfer model's axes are a serialization surface (wisdom
    # provenance and reports reference them); growing the spec with
    # backend/estimated/granule fields must not have widened them
    assert CAPABILITY_AXES == ("flops_bf16", "flops_f32", "hbm_bw",
                               "vmem_bytes", "program_overhead")


# ------------------------------------------- predictor: cross-backend -------

@functools.lru_cache(maxsize=None)
def _matmul_result(target: str):
    ds = SpaceDataset.load(
        DATASET_DIR / "matmul--tpu-v5e--256x256x256--float32.space.json")
    return transfer_scenario(ds, target)


def test_cross_backend_transfer_is_eligible_but_penalized():
    result = _matmul_result("gpu-a100")
    comp = result.components
    assert comp["backends"] == "tpu->gpu"
    assert comp["backend_penalty"] == BACKEND_MISMATCH_PENALTY
    assert comp["estimated"] is False
    # the penalty costs sqrt(0.5) of confidence but the A100's peaks are
    # close enough to v5e's that the prediction still clears the gate
    assert result.eligible()
    assert result.confidence >= TRANSFER_MIN_CONFIDENCE
    same_backend = _matmul_result("tpu-v4")
    assert same_backend.components["backend_penalty"] == 1.0
    assert result.confidence < same_backend.confidence


def test_cross_backend_record_carries_backends_provenance():
    rec = _matmul_result("gpu-a100").record()
    assert rec.provenance["backends"] == "tpu->gpu"
    assert rec.device_kind == "gpu-a100"
    assert rec.is_transferred()
    # same-backend records keep the pre-GPU byte layout (no new key)
    assert "backends" not in _matmul_result("tpu-v4").record().provenance


def test_estimated_target_never_eligible():
    result = _matmul_result("gpu-h100")
    assert result.components["estimated"] is True
    assert result.confidence < TRANSFER_MIN_CONFIDENCE
    assert not result.eligible()


TARGETS = ("tpu-v4", "tpu-v5p", "gpu-a100", "gpu-a4000", "gpu-h100", "cpu")


@settings(max_examples=60, deadline=None)
@given(target=st.sampled_from(TARGETS),
       min_conf=st.sampled_from((None, 0.0, 0.25, 0.30, 0.33, 0.42,
                                 0.5, 0.9)),
       measured_score=st.floats(1.0, 100.0))
def test_select_never_serves_unpenalized_cross_backend(target, min_conf,
                                                       measured_score):
    """The regression property for the ISSUE 10 serving contract.

    For every target / gate combination: (a) the predictor's confidence
    is exactly the documented mix over its audited components, whose
    similarity already carries the backend penalty (and the estimated
    floor); (b) when ``select``'s transfer tier serves the record, its
    confidence clears the gate *with* the penalty applied and
    cross-backend provenance is stamped; (c) estimated targets never
    serve at the default gate.
    """
    result = _matmul_result(target)
    comp = result.components
    model = DeviceModel.between("tpu-v5e", target)

    # (a) confidence == sqrt(penalized similarity) x component mix
    sim = comp["similarity"]
    expected_sim = _raw_similarity(model) * model.backend_penalty()
    if model.estimated():
        expected_sim = min(expected_sim, ESTIMATED_SIMILARITY_CAP)
    assert sim == pytest.approx(expected_sim, abs=1e-6)
    expected_conf = math.sqrt(sim) * (
        CONFIDENCE_BASE + CONFIDENCE_FIT_WEIGHT * comp["fit_quality"]
        + CONFIDENCE_COVERAGE_WEIGHT * comp["coverage"])
    assert result.confidence == pytest.approx(min(1.0, expected_conf),
                                              abs=1e-6)

    # (b)+(c): build a wisdom store the way the serving path does —
    # a measured record for a *different* problem (the cold fallback)
    # plus the transferred record when it clears this gate.
    cross = get_device(target).backend != "tpu"
    wisdom = Wisdom("matmul", [WisdomRecord(
        device_kind=target, device_family=get_device(target).family,
        problem_size=(512, 512, 512), dtype="float32",
        config={"block_m": 128, "block_n": 128, "block_k": 256,
                "grid_order": "mnk", "dim_semantics": "parallel"},
        score_us=measured_score,
        provenance={"strategy": "test", "evaluations": 1})])
    if result.eligible(min_conf):
        wisdom.add(result.record())
    rec, tier = wisdom.select_record(target, (256, 256, 256), "float32",
                                    min_transfer_confidence=min_conf)
    threshold = (TRANSFER_MIN_CONFIDENCE if min_conf is None
                 else float(min_conf))
    if tier == "transfer":
        assert rec.is_transferred()
        assert rec.transfer_confidence() >= threshold
        assert rec.transfer_confidence() == pytest.approx(
            result.confidence, abs=1e-6)
        assert ("backends" in rec.provenance) == cross
        if cross:
            assert rec.provenance["backends"].split("->")[0] == "tpu"
            assert comp["backend_penalty"] < 1.0
    else:
        # no transferred record cleared the gate -> the measured
        # fallback (device tier) serves instead, never a low-confidence
        # transfer
        assert rec is not None and not rec.is_transferred()
    if get_device(target).estimated and (min_conf is None
                                         or min_conf >=
                                         TRANSFER_MIN_CONFIDENCE):
        assert tier != "transfer"


# ------------------------------------------------- kernel lowering gate -----

def test_lowering_kwargs_per_backend():
    from jax.experimental.pallas import triton as pltriton
    ds = ("parallel", "parallel", "arbitrary")
    tpu = lowering_kwargs(dimension_semantics=ds, backend="tpu")
    assert "compiler_params" in tpu
    assert tuple(tpu["compiler_params"].dimension_semantics) == ds
    gpu = lowering_kwargs(dimension_semantics=ds, num_warps=4,
                          num_stages=2, backend="gpu")
    cp = gpu["compiler_params"]
    triton_cls = getattr(pltriton, "CompilerParams",
                         getattr(pltriton, "TritonCompilerParams", None))
    assert isinstance(cp, triton_cls)
    # Mosaic-only kwargs never leak across the backend boundary
    assert not hasattr(cp, "dimension_semantics")
    assert lowering_kwargs(dimension_semantics=ds, backend="cpu") == {}
    # the interpreter takes no params on any backend
    for b in BACKENDS:
        assert lowering_kwargs(dimension_semantics=ds, num_warps=4,
                               interpret=True, backend=b) == {}


@pytest.fixture()
def gpu_device(monkeypatch):
    monkeypatch.setenv("KERNEL_LAUNCHER_DEVICE", "gpu-a100")


def test_active_backend_follows_device_env(monkeypatch):
    monkeypatch.setenv("KERNEL_LAUNCHER_DEVICE", "gpu-a100")
    assert active_backend() == "gpu"
    monkeypatch.setenv("KERNEL_LAUNCHER_DEVICE", "tpu-v5e")
    assert active_backend() == "tpu"
    monkeypatch.setenv("KERNEL_LAUNCHER_DEVICE", "cpu")
    assert active_backend() == "cpu"


def test_gpu_matmul_interpret_matches_reference(rng, gpu_device):
    from repro.core import get_kernel
    b = get_kernel("matmul")
    a = rng.standard_normal((256, 512)).astype(np.float32)
    bb = rng.standard_normal((512, 256)).astype(np.float32)
    for order in ("mnk", "nmk"):
        cfg = b.default_config() | {"grid_order": order}
        ok, msg = verify_against_reference(b, cfg, [a, bb])
        assert ok, f"{order}: {msg}"


def test_gpu_stencils_interpret_match_reference(rng, gpu_device,
                                                small_fields):
    from repro.core import get_kernel
    u, v, w, evisc, scal = small_fields
    b = get_kernel("advec_u")
    ok, msg = verify_against_reference(
        b, b.default_config() | {"block_z": 4, "block_y": 8}, [u, v, w, scal])
    assert ok, msg
    b = get_kernel("diff_uvw")
    ok, msg = verify_against_reference(b, b.default_config(),
                                       [u, v, w, evisc, scal])
    assert ok, msg


def test_flash_attention_has_no_gpu_lowering(rng, gpu_device):
    from repro.core import get_kernel
    b = get_kernel("flash_attention_causal")
    q = rng.standard_normal((2, 256, 128)).astype(np.float32)
    with pytest.raises(NotImplementedError, match="GPU"):
        b.make(b.default_config(), (q, q, q))


def test_ops_attention_falls_back_on_gpu(rng, gpu_device, monkeypatch):
    # even with the Pallas backend forced, the router must not pick the
    # TPU-only flash kernel on a GPU device — the jnp oracle serves
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    import jax.numpy as jnp
    from repro.kernels import ref
    from repro.kernels.ops import attention
    q = jnp.asarray(rng.standard_normal((1, 2, 256, 128)),
                    dtype=jnp.float32)
    out = attention(q, q, q, causal=True)
    expected = ref.attention_ref(q, q, q, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=1e-5, atol=1e-5)


def test_gpu_tuning_space_records_and_scores(tmp_path, gpu_device):
    # tunebench end-to-end on the GPU device: the cost model picks up
    # the tensor-core granule / vector ratio and the space records
    from repro.core import get_kernel
    from repro.tunebench import record_space
    ds = record_space(get_kernel("matmul"), (256, 256, 256), "float32",
                      "gpu-a100")
    assert ds.best() is not None
    assert ds.device_kind == "gpu-a100"
    shipped = SpaceDataset.load(
        DATASET_DIR / "matmul--gpu-a100--256x256x256--float32.space.json")
    assert shipped.best().config == ds.best().config


# --------------------------------------------------- profiler annotation ----

def test_profile_marks_estimated_devices():
    from repro.core import get_kernel
    from repro.prof.profile import KernelProfile, profile_from_workload
    b = get_kernel("matmul")
    w = b.make_workload(b.default_config(), (256, 256, 256), "float32")
    known = profile_from_workload(w, get_device("gpu-a100"), "float32",
                                  100.0, kernel="matmul",
                                  problem_size=(256, 256, 256))
    assert not known.estimated
    assert "estimated" not in known.to_json()   # byte-compat for known HW
    guessed = profile_from_workload(w, get_device("gpu-h100"), "float32",
                                    100.0, kernel="matmul",
                                    problem_size=(256, 256, 256))
    assert guessed.estimated
    doc = guessed.to_json()
    assert doc["estimated"] is True
    assert KernelProfile.from_json(doc).estimated
