"""Fleet tuning orchestrator: demand, sharding, leases, e2e determinism.

Covers the ISSUE 3 acceptance criteria: three in-process workers over a
``MemoryTransport`` drain a seeded demand table, every shard lease is
claimed exactly once (except the forced-crash shard, which is claimed
twice — once by the victim, once by the reclaimer), and the merged fleet
wisdom is byte-for-byte identical to the single-worker exhaustive run.
"""

import json

import numpy as np
import pytest

from repro.core.builder import KernelBuilder
from repro.core.param import ConfigSpace
from repro.core.registry import register, unregister
from repro.core.workload import Workload
from repro.distrib import (CONTROL_PREFIX, DirectoryTransport,
                           MemoryTransport, PullSync, WisdomStore)
from repro.fleet import (ControlBus, Coordinator, FleetWorker, ManualClock,
                         TuningJob, aggregate_demand, claim_shard,
                         fetch_lease, job_id_for, prioritize, run_local_fleet,
                         seed_demand)
from repro.fleet.cli import main as fleet_cli
from repro.online import ScenarioStats, ScenarioTracker, format_key, parse_key

KERNEL = "fleettestk"
SCENARIO_A = ("tpu-v5e", (128, 128), "float32")
SCENARIO_B = ("tpu-v5e", (512, 256), "float32")


def _make_test_kernel() -> KernelBuilder:
    b = KernelBuilder(KERNEL, source="tests/test_fleet.py")
    b.tune("bx", (8, 16, 32, 64), default=8)
    b.tune("by", (8, 16, 32, 64), default=8)
    b.restriction("bx * by <= 2048")

    @b.workload
    def _wl(config, problem, dtype):
        n = 1
        for d in problem:
            n *= int(d)
        tile = config["bx"] * config["by"]
        return Workload(flops=2.0 * n, hbm_bytes=4.0 * n * (1 + 64 / tile),
                        vmem_bytes=tile * 4, grid=max(n // tile, 1),
                        lane_extent=config["bx"],
                        sublane_extent=min(config["by"], 8))

    return b


BUILDER = _make_test_kernel()
N_VALID = sum(1 for _ in BUILDER.space.enumerate())


@pytest.fixture(autouse=True)
def _registered_kernel():
    """Register the synthetic kernel per test and clean up, so registry-
    wide iteration elsewhere (test_kernels) stays builtin-only."""
    register(BUILDER)
    yield
    unregister(KERNEL)


# ------------------------------ scenario keys --------------------------------

def test_scenario_key_round_trips_canonically():
    key = ("tpu-v5e", (256, 128, 8), "bfloat16")
    s = format_key(key)
    assert s == "tpu-v5e|256x128x8|bfloat16"
    assert parse_key(s) == key
    # scalar (rank-0) problems survive too
    assert parse_key(format_key(("cpu", (), "float32"))) == \
        ("cpu", (), "float32")
    with pytest.raises(ValueError):
        format_key(("bad|device", (1,), "float32"))
    with pytest.raises(ValueError):
        parse_key("only|two")


def test_scenario_stats_survive_json_transport():
    """The satellite bug: tuple keys turned into lists across JSON
    publish/fetch. The canonical string form must round-trip exactly."""
    t = ScenarioTracker()
    t.observe(*SCENARIO_A, tier="default", weight=4)
    t.observe(*SCENARIO_A, tier="device")
    snap = json.loads(json.dumps(t.snapshot()))       # simulate transport
    st = ScenarioStats.from_json(snap[0])
    assert st.key == ScenarioTracker.key(*SCENARIO_A)
    assert isinstance(st.key[1], tuple)
    assert st.misses == 5 and st.launches == 2
    assert st.tiers == {"default": 1, "device": 1}


# --------------------------------- demand ------------------------------------

def test_demand_aggregates_across_workers():
    bus = ControlBus(MemoryTransport())
    seed_demand(bus, "w0", [(KERNEL, SCENARIO_A, 5)])
    seed_demand(bus, "w1", [(KERNEL, SCENARIO_A, 2),
                            (KERNEL, SCENARIO_B, 7)])
    # republishing w0 must replace, not double-count
    seed_demand(bus, "w0", [(KERNEL, SCENARIO_A, 5)])
    table = aggregate_demand(bus)
    by_key = {e.key: e for e in table}
    assert by_key[SCENARIO_A].misses == 7
    assert by_key[SCENARIO_A].workers == 2
    assert by_key[SCENARIO_B].misses == 7 and by_key[SCENARIO_B].workers == 1


def test_prioritize_orders_by_misses_times_speedup():
    transport = MemoryTransport()
    bus = ControlBus(transport)
    seed_demand(bus, "w0", [(KERNEL, SCENARIO_A, 3),
                            (KERNEL, SCENARIO_B, 3)])
    ranked = prioritize(aggregate_demand(bus), transport)
    assert len(ranked) == 2
    for p in ranked:
        assert p.speedup >= 1.0
        assert p.priority == pytest.approx(p.entry.misses * p.speedup)
    assert ranked[0].priority >= ranked[1].priority
    # unknown kernels cannot be ranked here and are skipped, not fatal
    seed_demand(bus, "w1", [("no-such-kernel", SCENARIO_A, 9)])
    assert len(prioritize(aggregate_demand(bus), transport)) == 2


# -------------------------------- sharding -----------------------------------

def test_space_shard_partitions_exactly():
    space = BUILDER.space
    full = {space.freeze(c) for c in space.enumerate()}
    n = 3
    shards = [space.shard(i, n) for i in range(n)]
    seen = []
    for sub in shards:
        seen.extend(sub.freeze(c) for c in sub.enumerate())
    assert len(seen) == len(set(seen))            # disjoint
    assert set(seen) == full                      # complete
    # deterministic: re-partitioning yields identical membership
    again = [space.shard(i, n) for i in range(n)]
    for sub, sub2 in zip(shards, again):
        assert ([sub.freeze(c) for c in sub.enumerate()]
                == [sub2.freeze(c) for c in sub2.enumerate()])
    # one shard is the whole space
    assert {space.freeze(c)
            for c in space.shard(0, 1).enumerate()} == full
    with pytest.raises(ValueError):
        space.shard(3, 3)


def test_config_hash_is_process_stable():
    space = ConfigSpace()
    space.tune("a", (1, 2, 3))
    space.tune("b", ("x", "y"))
    # pinned value: guards against hash() randomization sneaking in
    assert space.config_hash({"a": 2, "b": "y"}) \
        == space.config_hash({"b": "y", "a": 2})
    h1 = space.config_hash({"a": 1, "b": "x"})
    assert isinstance(h1, int) and h1 == space.config_hash(
        {"a": 1, "b": "x"})


# --------------------------------- leases ------------------------------------

def _job(n_shards=2, max_evals=100, round_=0):
    return TuningJob(job_id=job_id_for(KERNEL, SCENARIO_A, round_),
                     kernel=KERNEL, device_kind=SCENARIO_A[0],
                     problem=SCENARIO_A[1], dtype=SCENARIO_A[2],
                     n_shards=n_shards, max_evals_per_shard=max_evals,
                     round_=round_)


def test_lease_claim_conflict_expiry_reclaim():
    bus = ControlBus(MemoryTransport())
    clock = ManualClock()
    job = _job()
    lease = claim_shard(bus, job, "s000", "w0", clock, ttl_s=30.0)
    assert lease is not None and lease.worker == "w0" and lease.claims == 1
    # live lease: nobody else can claim
    assert claim_shard(bus, job, "s000", "w1", clock, ttl_s=30.0) is None
    # expiry: the shard is claimable again, hand-off counted
    clock.advance(31.0)
    lease2 = claim_shard(bus, job, "s000", "w1", clock, ttl_s=30.0)
    assert lease2 is not None and lease2.worker == "w1"
    assert lease2.claims == 2
    # a done lease is never reclaimed, even after expiry
    from repro.fleet import release
    release(bus, lease2)
    clock.advance(100.0)
    assert claim_shard(bus, job, "s000", "w2", clock, ttl_s=30.0) is None
    assert fetch_lease(bus, job.job_id, "s000").state == "done"


def test_lease_claimable_exactly_at_expiry_boundary():
    """ISSUE 5 satellite: ``expires_at == now`` means *expired* — the
    boundary instant belongs to the reclaimer, not the holder (claim
    checks ``expires_at > now``), and the stale holder discovers the
    hand-off at its next heartbeat."""
    from repro.fleet import LeaseLost, heartbeat

    bus = ControlBus(MemoryTransport())
    clock = ManualClock()
    job = _job()
    stale = claim_shard(bus, job, "s000", "w0", clock, ttl_s=30.0)
    clock.advance(30.0)                       # now == expires_at exactly
    assert clock.now() == stale.expires_at
    fresh = claim_shard(bus, job, "s000", "w1", clock, ttl_s=30.0)
    assert fresh is not None and fresh.claims == 2
    with pytest.raises(LeaseLost):
        heartbeat(bus, stale, clock, ttl_s=30.0)


def test_heartbeat_at_exact_expiry_renews_unclaimed_lease():
    """The mirror case: at the boundary instant with no reclaimer yet,
    the holder's heartbeat still owns the nonce and renews — expiry is
    only enforced through claims, never by silently dropping a live
    worker mid-shard."""
    from repro.fleet import heartbeat

    bus = ControlBus(MemoryTransport())
    clock = ManualClock()
    job = _job()
    lease = claim_shard(bus, job, "s000", "w0", clock, ttl_s=30.0)
    clock.advance(30.0)                       # now == expires_at exactly
    renewed = heartbeat(bus, lease, clock, ttl_s=30.0)
    assert renewed.expires_at == clock.now() + 30.0
    assert renewed.claims == 1                # no hand-off happened
    assert claim_shard(bus, job, "s000", "w1", clock, ttl_s=30.0) is None


def test_reclaim_racing_same_tick_heartbeat_leaves_one_owner():
    """Reclaim and heartbeat land on the same clock tick: whichever
    publish wins, exactly one worker owns the shard afterwards and the
    other finds out through LeaseLost — never two live owners."""
    from repro.fleet import LeaseLost, heartbeat

    # ordering A: the stale holder heartbeats first, reclaim bounces
    bus = ControlBus(MemoryTransport())
    clock = ManualClock()
    job = _job()
    holder = claim_shard(bus, job, "s000", "w0", clock, ttl_s=30.0)
    clock.advance(30.0)
    heartbeat(bus, holder, clock, ttl_s=30.0)
    assert claim_shard(bus, job, "s000", "w1", clock, ttl_s=30.0) is None
    assert fetch_lease(bus, job.job_id, "s000").worker == "w0"

    # ordering B: the reclaimer publishes first, the heartbeat refuses
    bus = ControlBus(MemoryTransport())
    clock = ManualClock()
    holder = claim_shard(bus, job, "s000", "w0", clock, ttl_s=30.0)
    clock.advance(30.0)
    fresh = claim_shard(bus, job, "s000", "w1", clock, ttl_s=30.0)
    assert fresh is not None
    with pytest.raises(LeaseLost):
        heartbeat(bus, holder, clock, ttl_s=30.0)
    cur = fetch_lease(bus, job.job_id, "s000")
    assert cur.worker == "w1" and cur.claims == 2


def test_stalled_worker_cannot_steal_back_reclaimed_lease():
    """A worker that stalls past its TTL must abandon the shard at its
    next checkpoint, not overwrite the reclaimer's lease."""
    from repro.fleet import LeaseLost, heartbeat, release

    bus = ControlBus(MemoryTransport())
    clock = ManualClock()
    job = _job()
    stale = claim_shard(bus, job, "s000", "w0", clock, ttl_s=30.0)
    clock.advance(31.0)
    fresh = claim_shard(bus, job, "s000", "w1", clock, ttl_s=30.0)
    assert fresh is not None and fresh.claims == 2
    # the stalled worker wakes up: heartbeat and release both refuse
    with pytest.raises(LeaseLost):
        heartbeat(bus, stale, clock, ttl_s=30.0)
    with pytest.raises(LeaseLost):
        release(bus, stale)
    cur = fetch_lease(bus, job.job_id, "s000")
    assert cur.worker == "w1" and cur.claims == 2 and cur.state != "done"
    # the rightful owner's heartbeat still works
    heartbeat(bus, fresh, clock, ttl_s=30.0)


def test_job_round_trips_and_id_deterministic():
    job = _job(n_shards=5, max_evals=42, round_=2)
    again = TuningJob.from_json(json.loads(json.dumps(job.to_json())))
    assert again == job
    assert job_id_for(KERNEL, SCENARIO_A, 0) == \
        job_id_for(KERNEL, SCENARIO_A, 0)
    assert job_id_for(KERNEL, SCENARIO_A, 0) != \
        job_id_for(KERNEL, SCENARIO_A, 1)
    assert job.shard_seed("s000") != job.shard_seed("s001")


# ----------------------------- worker + coordinator --------------------------

def test_single_worker_drains_job_and_assembles_wisdom():
    transport = MemoryTransport()
    bus = ControlBus(transport)
    clock = ManualClock()
    seed_demand(bus, "svc", [(KERNEL, SCENARIO_A, 5)])
    coord = Coordinator(bus, n_shards=2, max_evals_per_shard=100)
    jobs = coord.plan()
    assert len(jobs) == 1
    worker = FleetWorker(bus, "w0", clock=clock)
    assert worker.drain() == 2                    # both shards
    assert worker.evals_run == N_VALID            # exhaustive, no overlap
    records = coord.assemble()
    assert len(records) == 1
    rec = records[0]
    assert rec.provenance["source"] == "fleet"
    assert rec.provenance["evaluations"] == N_VALID
    assert rec.provenance["job"] == jobs[0].job_id
    assert "date" not in rec.provenance           # deterministic identity
    doc = transport.fetch(KERNEL)
    assert doc is not None and len(doc["records"]) == 1
    # a second coordination round is a no-op: demand unchanged
    report = coord.tick()
    assert report.idle


def test_acceptance_crash_reclaim_byte_identical_wisdom():
    """ISSUE 3 acceptance: 3 workers + forced crash vs 1 worker."""
    demand = [(KERNEL, SCENARIO_A, 5), (KERNEL, SCENARIO_B, 4)]
    kw = dict(demand=demand, n_shards=4, strategy="exhaustive",
              checkpoint_every=2, seed=0)
    r3 = run_local_fleet(n_workers=3, crash_worker="w0",
                         crash_after_evals=3, **kw)
    r1 = run_local_fleet(n_workers=1, **kw)

    # the demand table drained: every scenario's job assembled
    assert len(r3.jobs_assembled) == 2
    assert r3.status["jobs_open"] == 0
    assert r3.crashes == 1
    # every shard lease claimed exactly once, except the crashed shard
    # (claimed by the victim, reclaimed once after expiry)
    claims = r3.claims()
    assert len(claims) == 8
    assert sorted(claims.values()) == [1] * 7 + [2]
    crashed = [n for n, c in claims.items() if c == 2][0]
    assert r3.leases[crashed].state == "done"
    assert r3.leases[crashed].worker != "w0"      # finished by a reclaimer
    # warm start really resumed: no evaluation was measured twice
    assert r3.total_evals == r1.total_evals == 2 * N_VALID
    # byte-for-byte identical fleet wisdom
    assert json.dumps(r3.wisdom_docs, sort_keys=True) \
        == json.dumps(r1.wisdom_docs, sort_keys=True)
    # and the fleet optimum matches a plain single-space exhaustive tune
    from repro.core import get_device
    from repro.tuner import CostModelEvaluator, tune_exhaustive
    ev = CostModelEvaluator(BUILDER, SCENARIO_A[1], SCENARIO_A[2],
                            get_device(SCENARIO_A[0]), verify="none")
    offline = tune_exhaustive(BUILDER.space, ev)
    recs = [r for r in r3.wisdom_docs[KERNEL]["records"]
            if tuple(r["problem_size"]) == SCENARIO_A[1]]
    assert recs[0]["config"] == offline.best_config
    assert recs[0]["score_us"] == pytest.approx(offline.best_score_us)


def test_worker_skips_jobs_for_unknown_kernels_without_claiming():
    """Heterogeneous fleet: a job planned elsewhere for a kernel this
    host does not have must be left alone — no crash, no lease held."""
    bus = ControlBus(MemoryTransport())
    job = TuningJob(job_id=job_id_for("elsewhere-kernel", SCENARIO_A, 0),
                    kernel="elsewhere-kernel", device_kind=SCENARIO_A[0],
                    problem=SCENARIO_A[1], dtype=SCENARIO_A[2], n_shards=2)
    bus.publish("job", job.job_id, job.to_json())
    worker = FleetWorker(bus, "w0", clock=ManualClock())
    assert worker.run_once() is None
    assert bus.names("lease") == []               # never claimed


def test_coordinator_reenqueues_regressed_scenario():
    report = run_local_fleet(n_workers=2, demand=[(KERNEL, SCENARIO_A, 5)],
                             n_shards=2)
    assert report.jobs_assembled == [job_id_for(KERNEL, SCENARIO_A, 0)]
    bus = ControlBus(report.transport)
    coord = Coordinator(bus, n_shards=2)
    # demand level unchanged -> nothing to do
    assert coord.plan() == []
    # a new worker reports fresh misses: the scenario regressed
    seed_demand(bus, "late-worker", [(KERNEL, SCENARIO_A, 4)])
    jobs = coord.plan()
    assert [j.job_id for j in jobs] == [job_id_for(KERNEL, SCENARIO_A, 1)]
    assert jobs[0].round_ == 1


def test_random_strategy_fleet_matches_across_worker_counts():
    """Sharded non-exhaustive search is still schedule-independent: the
    shard seed comes from the job, not the worker."""
    demand = [(KERNEL, SCENARIO_A, 5)]
    kw = dict(demand=demand, n_shards=3, strategy="random",
              max_evals_per_shard=6, seed=0)
    r1 = run_local_fleet(n_workers=1, **kw)
    r2 = run_local_fleet(n_workers=2, **kw)
    assert json.dumps(r1.wisdom_docs, sort_keys=True) \
        == json.dumps(r2.wisdom_docs, sort_keys=True)


# ------------------------- transports + wisdom isolation ---------------------

def test_control_docs_invisible_to_wisdom_layer(tmp_path):
    shared = DirectoryTransport(tmp_path / "shared")
    bus = ControlBus(shared)
    seed_demand(bus, "w0", [(KERNEL, SCENARIO_A, 5)])
    bus.publish("job", "j-test-r0", _job().to_json())
    # the raw transport sees control docs; the wisdom store does not
    assert any(n.startswith(CONTROL_PREFIX) for n in shared.list_kernels())
    assert WisdomStore(tmp_path / "shared").kernels() == []
    # PullSync over the shared dir ignores them entirely
    local = WisdomStore(tmp_path / "local")
    PullSync(local, shared, interval=1).pull()
    assert local.kernels() == []
    assert WisdomStore(tmp_path / "shared").validate() == []


def test_directory_transport_fleet_run_matches_memory(tmp_path):
    demand = [(KERNEL, SCENARIO_A, 5)]
    kw = dict(n_workers=2, demand=demand, n_shards=2)
    r_mem = run_local_fleet(**kw)
    r_dir = run_local_fleet(
        transport=DirectoryTransport(tmp_path / "shared"), **kw)
    assert json.dumps(r_mem.wisdom_docs, sort_keys=True) \
        == json.dumps(r_dir.wisdom_docs, sort_keys=True)


# ----------------------------------- CLI -------------------------------------

def test_fleet_cli_plan_work_status(tmp_path, capsys):
    d = str(tmp_path / "shared")
    bus = ControlBus(DirectoryTransport(d))
    seed_demand(bus, "host-a", [(KERNEL, SCENARIO_A, 5)])

    assert fleet_cli(["plan", "--dir", d, "--dry-run"]) == 0
    out = capsys.readouterr().out
    assert "dry run" in out and KERNEL in out
    assert bus.names("job") == []                 # dry run published nothing

    assert fleet_cli(["plan", "--dir", d, "--shards", "2",
                      "--evals-per-shard", "100"]) == 0
    assert len(bus.names("job")) == 1
    capsys.readouterr()

    # --poll must exit once every shard has a result, even though the
    # coordinator has not assembled the job yet (one-shot sequencing)
    assert fleet_cli(["work", "--dir", d, "--worker-id", "host-a",
                      "--poll", "0.01"]) == 0
    assert "finished 2 shard(s)" in capsys.readouterr().out

    assert fleet_cli(["coordinate", "--dir", d, "--shards", "2",
                      "--evals-per-shard", "100"]) == 0
    capsys.readouterr()
    assert fleet_cli(["status", "--dir", d]) == 0
    out = capsys.readouterr().out
    assert "assembled" in out and "misses=5" in out
    assert WisdomStore(d).kernels() == [KERNEL]

    # --poll exits on its own once every job is assembled
    assert fleet_cli(["work", "--dir", d, "--worker-id", "host-b",
                      "--poll", "0.01"]) == 0
    assert "finished 0 shard(s)" in capsys.readouterr().out


def test_fleet_cli_status_empty_dir(tmp_path, capsys):
    assert fleet_cli(["status", "--dir", str(tmp_path / "nothing")]) == 0
    assert "0 demand" in capsys.readouterr().out


# -------------------------- tune CLI dedup satellite -------------------------

def test_tune_cli_dedups_captures_and_dry_runs(tmp_path, capsys,
                                               wisdom_dir):
    import shutil

    from repro.core.capture import write_capture
    from repro.tuner.tune import main as tune_cli

    rng = np.random.default_rng(0)
    a = rng.standard_normal((64, 64)).astype(np.float32)
    b = rng.standard_normal((64, 64)).astype(np.float32)
    cap_dir = tmp_path / "caps"
    p = write_capture("matmul", (64, 64, 64), "float32", [a, b],
                      out_dir=cap_dir)
    shutil.copy(p, cap_dir / "copy-of-same.capture.json")
    glob_arg = str(cap_dir / "*.capture.json")

    assert tune_cli(["--captures", glob_arg, "--dry-run"]) == 0
    out = capsys.readouterr().out
    assert "would tune matmul 64x64x64 float32" in out
    assert "+1 duplicate(s)" in out
    assert "1 scenario(s) from 2 capture(s), 1 duplicate(s) skipped" in out
    assert not (wisdom_dir / "matmul.wisdom.json").exists()

    assert tune_cli(["--captures", glob_arg, "--strategy", "random",
                     "--budget-evals", "4"]) == 0
    out = capsys.readouterr().out
    assert out.count("best=") == 1                # tuned once, not twice
    assert "skipped (same scenario" in out
    assert len(WisdomStore(wisdom_dir).load("matmul").records) == 1


# ------------------------ sandboxed shard evaluation -------------------------

class _RaisingEvaluator:
    """Counts every config it sees; raises on exactly one of them."""

    def __init__(self, bad_config):
        self.bad_config = dict(bad_config)
        self.calls = []

    def __call__(self, config):
        from repro.tuner.runner import EvalResult
        self.calls.append(dict(config))
        if {k: config[k] for k in self.bad_config} == self.bad_config:
            raise RuntimeError("injected mid-config evaluator crash")
        return EvalResult(float(config["bx"] * config["by"]), True)


def test_crashed_shard_resumes_without_rerunning_checkpointed_configs():
    """ISSUE 7 regression: a shard whose evaluator crashed mid-config is
    re-claimed and re-runs only the configs the checkpoint does not
    cover — including *not* re-running the config that crashed, whose
    sandbox verdict is already recorded in the checkpointed log."""
    from repro.fleet.jobs import lease_name
    from repro.fleet.worker import WorkerCrash

    bus = ControlBus(MemoryTransport())
    clock = ManualClock()
    job = _job(n_shards=1)
    bus.publish("job", job.job_id, job.to_json())
    bad = {"bx": 8, "by": 32}          # 3rd in enumeration order

    # Worker 0: the inline sandbox turns the evaluator's raise into an
    # infeasible sandbox:crash evaluation (checkpointed like any other),
    # then the injected WorkerCrash kills the worker after 5 evals.
    ev0 = _RaisingEvaluator(bad)
    w0 = FleetWorker(bus, "w0", clock=clock, ttl_s=30.0,
                     checkpoint_every=1, crash_after_evals=5,
                     evaluator_factory=lambda builder, job_: ev0)
    with pytest.raises(WorkerCrash):
        w0.run_once()
    assert len(ev0.calls) == 5 and bad in ev0.calls

    # The crash lost nothing: all 5 evaluations (the crashing config's
    # sandbox verdict included) are in the checkpointed state doc.
    state = bus.fetch("state", lease_name(job.job_id, "s000"))
    evals = state["evaluations"]
    assert len(evals) == 5
    crashed = [e for e in evals if e["config"] == bad]
    assert len(crashed) == 1
    assert crashed[0]["feasible"] is False
    assert crashed[0]["error"].startswith("sandbox:crash")
    assert "injected mid-config evaluator crash" in crashed[0]["error"]

    # The lease expires; a second worker re-claims and finishes the
    # shard, replaying the checkpoint instead of re-measuring it.
    clock.advance(31.0)
    ev1 = _RaisingEvaluator(bad)       # would raise again if re-run
    w1 = FleetWorker(bus, "w1", clock=clock, ttl_s=30.0,
                     evaluator_factory=lambda builder, job_: ev1)
    assert w1.run_once() == lease_name(job.job_id, "s000")
    assert len(ev1.calls) == N_VALID - 5
    assert bad not in ev1.calls
    result = bus.fetch("result", lease_name(job.job_id, "s000"))
    assert result["worker"] == "w1"
    assert result["evals"] == N_VALID
    assert result["feasible_evals"] == N_VALID - 1
    assert result["best_config"] == {"bx": 8, "by": 8}
