"""ConfigSpace unit + property tests."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ConfigSpace


def make_space():
    s = ConfigSpace()
    s.tune("block_x", (16, 32, 64, 128), default=32)
    s.tune("block_y", (1, 2, 4, 8))
    s.tune("unroll", (1, 2, 4))
    s.tune("flag", (True, False))
    s.restrict("block_x * block_y <= 512")
    s.restrict(lambda c: c["block_x"] % c["unroll"] == 0)
    return s


def test_cardinality_and_enumerate():
    s = make_space()
    assert s.cardinality() == 4 * 4 * 3 * 2
    cfgs = list(s.enumerate())
    assert all(s.is_valid(c) for c in cfgs)
    assert len(cfgs) == s.valid_cardinality()
    assert 0 < len(cfgs) < s.cardinality()


def test_default_is_valid():
    s = make_space()
    assert s.is_valid(s.default_config())


def test_duplicate_param_rejected():
    s = ConfigSpace()
    s.tune("a", (1, 2))
    with pytest.raises(ValueError):
        s.tune("a", (3,))


def test_default_not_in_values_rejected():
    s = ConfigSpace()
    with pytest.raises(ValueError):
        s.tune("a", (1, 2), default=3)


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 8))
def test_sample_produces_valid_configs(seed, n):
    s = make_space()
    rng = np.random.default_rng(seed)
    for cfg in s.sample(rng, n):
        assert s.is_valid(cfg)


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_neighbor_stays_valid_and_close(seed):
    s = make_space()
    rng = np.random.default_rng(seed)
    cfg = s.sample(rng, 1)[0]
    nb = s.neighbor(cfg, rng)
    assert s.is_valid(nb)
    diffs = sum(1 for k in cfg if cfg[k] != nb[k])
    assert diffs <= 1


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_unit_encoding_roundtrip(seed):
    s = make_space()
    rng = np.random.default_rng(seed)
    cfg = s.sample(rng, 1)[0]
    assert s.from_unit(s.to_unit(cfg)) == cfg


def test_freeze_is_hashable_and_stable():
    s = make_space()
    c = s.default_config()
    assert s.freeze(c) == s.freeze(dict(reversed(list(c.items()))))
    {s.freeze(c): 1}
