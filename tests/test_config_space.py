"""ConfigSpace unit + property tests."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ConfigSpace


def make_space():
    s = ConfigSpace()
    s.tune("block_x", (16, 32, 64, 128), default=32)
    s.tune("block_y", (1, 2, 4, 8))
    s.tune("unroll", (1, 2, 4))
    s.tune("flag", (True, False))
    s.restrict("block_x * block_y <= 512")
    s.restrict(lambda c: c["block_x"] % c["unroll"] == 0)
    return s


def test_cardinality_and_enumerate():
    s = make_space()
    assert s.cardinality() == 4 * 4 * 3 * 2
    cfgs = list(s.enumerate())
    assert all(s.is_valid(c) for c in cfgs)
    assert len(cfgs) == s.valid_cardinality()
    assert 0 < len(cfgs) < s.cardinality()


def test_default_is_valid():
    s = make_space()
    assert s.is_valid(s.default_config())


def test_duplicate_param_rejected():
    s = ConfigSpace()
    s.tune("a", (1, 2))
    with pytest.raises(ValueError):
        s.tune("a", (3,))


def test_default_not_in_values_rejected():
    s = ConfigSpace()
    with pytest.raises(ValueError):
        s.tune("a", (1, 2), default=3)


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 8))
def test_sample_produces_valid_configs(seed, n):
    s = make_space()
    rng = np.random.default_rng(seed)
    for cfg in s.sample(rng, n):
        assert s.is_valid(cfg)


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_neighbor_stays_valid_and_close(seed):
    s = make_space()
    rng = np.random.default_rng(seed)
    cfg = s.sample(rng, 1)[0]
    nb = s.neighbor(cfg, rng)
    assert s.is_valid(nb)
    diffs = sum(1 for k in cfg if cfg[k] != nb[k])
    assert diffs <= 1


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_unit_encoding_roundtrip(seed):
    s = make_space()
    rng = np.random.default_rng(seed)
    cfg = s.sample(rng, 1)[0]
    assert s.from_unit(s.to_unit(cfg)) == cfg


def test_freeze_is_hashable_and_stable():
    s = make_space()
    c = s.default_config()
    assert s.freeze(c) == s.freeze(dict(reversed(list(c.items()))))
    {s.freeze(c): 1}


# ----------------- config_hash / shard edge cases (ISSUE 4) -----------------


def test_config_hash_ignores_dict_ordering():
    s = make_space()
    c = s.default_config()
    reordered = dict(reversed(list(c.items())))
    assert s.config_hash(c) == s.config_hash(reordered)


def test_config_hash_is_cross_process_stable():
    """Shard membership must agree between machines and runs: the hash
    is pinned to a literal so any derivation change (which would tear
    every in-flight fleet job's shards apart — and orphan every recorded
    dataset's entry keys) fails loudly here."""
    s = ConfigSpace()
    s.tune("block_x", (16, 32, 64, 128), default=32)
    s.tune("flag", (True, False))
    assert s.config_hash({"block_x": 16, "flag": True}) \
        == 0x7375c74b6b75025f
    assert ConfigSpace().config_hash({}) == 0x0caa2b8ca1cd534f


def test_empty_space_hash_enumerate_shard():
    s = ConfigSpace()
    assert s.cardinality() == 1                  # the empty product
    assert list(s.enumerate()) == [{}]
    sub = s.shard(0, 1)
    assert list(sub.enumerate()) == [{}]
    # with n_shards > 1 exactly one shard owns the single (empty) config
    owners = [i for i in range(3)
              if list(s.shard(i, 3).enumerate())]
    assert len(owners) == 1


def test_shard_count_exceeding_config_count():
    s = ConfigSpace()
    s.tune("x", (0, 1, 2))                       # 3 valid configs
    n_shards = 8
    shards = [list(s.shard(i, n_shards).enumerate())
              for i in range(n_shards)]
    everything = [tuple(sorted(c.items())) for sh in shards for c in sh]
    # disjoint union == the whole space; surplus shards are just empty
    assert sorted(everything) == sorted(
        tuple(sorted(c.items())) for c in s.enumerate())
    assert len(everything) == len(set(everything)) == 3
    assert sum(1 for sh in shards if not sh) >= n_shards - 3


def test_shard_partition_is_exact_and_deterministic():
    s = make_space()
    valid = [tuple(sorted(c.items())) for c in s.enumerate()]
    for n in (1, 2, 5):
        parts = [[tuple(sorted(c.items()))
                  for c in s.shard(i, n).enumerate()] for i in range(n)]
        flat = [c for p in parts for c in p]
        assert sorted(flat) == sorted(valid)          # union, no overlap
        # re-derived shards are identical (replanning safety)
        again = [[tuple(sorted(c.items()))
                  for c in s.shard(i, n).enumerate()] for i in range(n)]
        assert parts == again


def test_shard_index_validation():
    s = make_space()
    with pytest.raises(ValueError):
        s.shard(-1, 4)
    with pytest.raises(ValueError):
        s.shard(4, 4)
    with pytest.raises(ValueError):
        s.shard(0, 0)


def test_shard_keeps_parent_restrictions():
    s = make_space()
    for i in range(4):
        for cfg in s.shard(i, 4).enumerate():
            assert s.is_valid(cfg)
