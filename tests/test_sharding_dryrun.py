"""Sharding rules + a debug-mesh dry-run slice (the full 512-device run is
``python -m repro.launch.dryrun --all``; here we prove the machinery on the
devices tests have)."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch, list_archs
from repro.launch import sharding as sh
from repro.launch.mesh import dp_axes, make_debug_mesh
from repro.launch.shapes import (SHAPES, all_cells, cell_skip_reason,
                                 input_specs, runnable_cells)
from repro.models import build_model
from repro.roofline import model_flops
from repro.roofline.hlo_parse import hlo_cost_analysis


def small_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", list_archs())
def test_param_specs_valid_all_archs(arch):
    cfg = get_arch(arch)
    model = build_model(cfg)
    pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    mesh = small_mesh()
    specs = sh.param_pspecs(pshape, mesh, cfg)
    assert sh.validate_specs(pshape, specs, mesh) == []
    cshape = jax.eval_shape(lambda: model.init_cache(4, 128))
    cspecs = sh.cache_pspecs(cshape, mesh)
    assert sh.validate_specs(cshape, cspecs, mesh) == []


def test_divisibility_fallback():
    """Odd dims must silently drop the axis rather than emit bad specs."""
    from types import SimpleNamespace
    mesh = SimpleNamespace(axis_names=("data", "model"),
                           shape={"data": 4, "model": 2})
    spec = sh._spec(mesh, (7, 16), {0: "data", 1: "model"})
    assert spec == P(None, "model")
    spec = sh._spec(mesh, (8, 15), {0: "data", 1: "model"})
    assert spec == P("data", None)
    spec = sh._spec(mesh, (8, 16), {0: ("data", "model")})
    assert spec == P(("data", "model"), None)


def test_cell_table_counts():
    assert len(all_cells()) == 40
    skips = [c for c in all_cells()
             if cell_skip_reason(get_arch(c[0]), SHAPES[c[1]])]
    assert len(skips) == 7          # documented long_500k skips
    assert len(runnable_cells()) == 33
    for arch, shape in skips:
        assert shape == "long_500k"


@pytest.mark.parametrize("arch,shape", [
    ("stablelm-1.6b", "train_4k"),
    ("gemma2-2b", "decode_32k"),
    ("rwkv6-7b", "long_500k"),
])
def test_input_specs_are_abstract(arch, shape):
    cfg = get_arch(arch)
    specs = input_specs(cfg, SHAPES[shape])
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_debug_mesh_lower_compile_smoke():
    """A reduced config lowers + compiles with the same machinery the
    512-device dry-run uses."""
    from repro.launch.dryrun import lower_cell  # noqa: F401 (env-safe here)
    from repro.optim import AdamW
    from repro.train import init_train_state, make_train_step
    from jax.sharding import NamedSharding

    cfg = get_arch("stablelm-1.6b").reduced()
    model = build_model(cfg, remat=True)
    mesh = small_mesh()
    opt = AdamW()
    state_shape = jax.eval_shape(
        lambda r: init_train_state(model, opt, r), jax.random.PRNGKey(0))
    sspec = sh.state_pspecs(state_shape, mesh, cfg)
    batch = {"tokens": jax.ShapeDtypeStruct((4, 64), np.int32),
             "labels": jax.ShapeDtypeStruct((4, 64), np.int32)}
    bspec = sh.batch_pspecs(batch, mesh)
    named = lambda t: jax.tree.map(  # noqa: E731
        lambda s: NamedSharding(mesh, s), t,
        is_leaf=lambda x: isinstance(x, P))
    step = make_train_step(model, opt, microbatches=2)
    with mesh:
        lowered = jax.jit(step, in_shardings=(named(sspec), named(bspec)),
                          donate_argnums=(0,)).lower(state_shape, batch)
        compiled = lowered.compile()
    walk = hlo_cost_analysis(compiled.as_text())
    # trip-count-aware flops must be within 8x of the 6*N*T estimate
    # (remat + attention + CE overhead push it above 1x)
    mf = model_flops(cfg, "train", 4, 64)
    assert walk["flops"] > 0.8 * mf
    assert walk["flops"] < 8 * mf


def test_hlo_walker_scan_equivalence():
    """Walker invariant: scan(f, L) costs == L sequential applications."""
    import jax.numpy as jnp
    from jax import lax
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def unrolled(a):
        for _ in range(6):
            a = jnp.tanh(a @ a)
        return a

    def scanned(a):
        return lax.scan(lambda c, _: (jnp.tanh(c @ c), None), a, None,
                        length=6)[0]

    f1 = hlo_cost_analysis(jax.jit(unrolled).lower(x).compile().as_text())
    f2 = hlo_cost_analysis(jax.jit(scanned).lower(x).compile().as_text())
    assert f1["flops"] == pytest.approx(f2["flops"], rel=0.02)
