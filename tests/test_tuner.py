"""Tuner strategies + cost model."""

import numpy as np
import pytest

from repro.core import ConfigSpace, Workload, get_device, get_kernel
from repro.tuner import (CostModel, CostModelEvaluator, Evaluation,
                         evaluation_from_json, evaluation_to_json,
                         tune_anneal, tune_bayes, tune_exhaustive,
                         tune_random)
from repro.tuner.runner import EvalResult


def quadratic_space():
    """Known landscape: score = (x-5)^2 + (y-3)^2 + 1, minimum at (5,3)."""
    s = ConfigSpace()
    s.tune("x", tuple(range(10)))
    s.tune("y", tuple(range(10)))

    def evaluate(cfg):
        v = (cfg["x"] - 5) ** 2 + (cfg["y"] - 3) ** 2 + 1.0
        return EvalResult(score_us=float(v), feasible=True)

    return s, evaluate


@pytest.mark.parametrize("strategy", [tune_random, tune_bayes, tune_anneal])
def test_strategies_find_optimum_region(strategy):
    s, ev = quadratic_space()
    res = strategy(s, ev, max_evals=60, rng=np.random.default_rng(0))
    assert res.best_score_us <= 3.0  # within the optimum's neighborhood


def test_exhaustive_finds_exact_optimum():
    s, ev = quadratic_space()
    res = tune_exhaustive(s, ev, limit=1000)
    assert res.best_score_us == 1.0
    assert res.best_config == {"x": 5, "y": 3}


def test_bayes_beats_random_on_average():
    """Paper C4-lite: Bayesian optimization converges faster than random
    on the real kernel landscape (advec_u cost model)."""
    b = get_kernel("advec_u")
    wins = 0
    trials = 5
    for seed in range(trials):
        ev = CostModelEvaluator(b, (256, 256, 256), "float32",
                                get_device("tpu-v5e"), verify="none")
        r_r = tune_random(b.space, ev, max_evals=40,
                          rng=np.random.default_rng(seed))
        r_b = tune_bayes(b.space, ev, max_evals=40,
                         rng=np.random.default_rng(seed))
        if r_b.best_score_us <= r_r.best_score_us:
            wins += 1
    assert wins >= 3


def test_trajectory_monotone():
    s, ev = quadratic_space()
    res = tune_random(s, ev, max_evals=50, rng=np.random.default_rng(1))
    traj = res.trajectory()
    scores = [b for _, b in traj]
    assert scores == sorted(scores, reverse=True)


def test_dedup_same_config_not_reevaluated():
    s, _ = quadratic_space()
    calls = []

    def ev(cfg):
        calls.append(dict(cfg))
        return EvalResult(1.0, True)

    tune_anneal(s, ev, max_evals=30, rng=np.random.default_rng(0))
    keys = [tuple(sorted(c.items())) for c in calls]
    assert len(keys) == len(set(keys))


# ----------------------- warm start (fleet resume) -----------------------


class _Interrupted(Exception):
    pass


def _crash_then_resume(strategy, max_evals=40, crash_after=13, seed=7):
    """Run ``strategy`` three ways on the quadratic landscape: straight
    through, killed mid-session (the fleet worker's crash path: the log
    records every measured config, including the one whose result the
    session never saw), and resumed from the serialized log."""
    s, ev = quadratic_space()

    full_calls = []

    def ev_full(cfg):
        full_calls.append(s.freeze(cfg))
        return ev(cfg)

    full = strategy(s, ev_full, max_evals=max_evals,
                    rng=np.random.default_rng(seed))

    log = []

    def ev_crash(cfg):
        r = ev(cfg)
        log.append(Evaluation(config=dict(cfg), score_us=r.score_us,
                              feasible=r.feasible, wall_s=0.0,
                              error=r.error))
        if len(log) >= crash_after:
            raise _Interrupted
        return r

    with pytest.raises(_Interrupted):
        strategy(s, ev_crash, max_evals=max_evals,
                 rng=np.random.default_rng(seed))

    history = [evaluation_from_json(d)                 # disk round-trip
               for d in [evaluation_to_json(e) for e in log]]
    resumed_calls = []

    def ev_resumed(cfg):
        resumed_calls.append(s.freeze(cfg))
        return ev(cfg)

    resumed = strategy(s, ev_resumed, max_evals=max_evals,
                       rng=np.random.default_rng(seed),
                       history=history)
    return s, full, full_calls, log, resumed, resumed_calls


@pytest.mark.parametrize("strategy",
                         [tune_bayes, tune_anneal, tune_random])
def test_warm_start_resume_is_deterministic(strategy):
    """ISSUE 3 satellite: resuming from a serialized history with the
    same seed must visit exactly the configs an uninterrupted run would
    have visited after the crash point — no re-measurement, no drift."""
    s, full, full_calls, log, resumed, resumed_calls = \
        _crash_then_resume(strategy)
    k = len(log)
    # the interrupted prefix matches the uninterrupted run
    assert [s.freeze(e.config) for e in log] == full_calls[:k]
    # the resume measures exactly the remaining configs, in order
    assert resumed_calls == full_calls[k:]
    # and the final session state is identical
    assert [s.freeze(e.config) for e in resumed.evaluations] \
        == [s.freeze(e.config) for e in full.evaluations]
    assert resumed.best_config == full.best_config
    assert resumed.best_score_us == full.best_score_us


def test_warm_start_exhaustive_skips_measured_prefix():
    s, ev = quadratic_space()
    calls = []

    def ev_live(cfg):
        calls.append(s.freeze(cfg))
        return ev(cfg)

    head = [c for _, c in zip(range(30), s.enumerate())]
    history = [Evaluation(config=dict(c),
                          score_us=float((c["x"] - 5) ** 2
                                         + (c["y"] - 3) ** 2 + 1.0),
                          feasible=True, wall_s=0.0) for c in head]
    res = tune_exhaustive(s, ev_live, limit=1000, history=history)
    assert len(calls) == 100 - 30                  # prefix replayed free
    assert len(res.evaluations) == 100
    assert res.best_config == {"x": 5, "y": 3}


# ------------------------------ cost model ------------------------------


def test_cost_model_vmem_spill_then_infeasible():
    dev = get_device("tpu-v5e")
    m = CostModel(dev, noise_sigma=0)
    fit = Workload(flops=1e9, hbm_bytes=1e6, vmem_bytes=1024, grid=1)
    spill = Workload(flops=1e9, hbm_bytes=1e6,
                     vmem_bytes=int(dev.vmem_bytes * 1.5), grid=1)
    blown = Workload(flops=1e9, hbm_bytes=1e6,
                     vmem_bytes=int(dev.vmem_bytes * 4.5), grid=1)
    t_fit = m.time(fit, "float32")
    t_spill = m.time(spill, "float32")
    assert np.isfinite(t_fit) and np.isfinite(t_spill)
    assert t_spill > t_fit          # spilling degrades
    assert not np.isfinite(m.time(blown, "float32"))


def test_cost_model_monotone_in_flops_and_bytes():
    m = CostModel(get_device("tpu-v5e"), noise_sigma=0)
    base = dict(hbm_bytes=1e9, vmem_bytes=1024, grid=16)
    t1 = m.time(Workload(flops=1e12, **base), "bfloat16")
    t2 = m.time(Workload(flops=4e12, **base), "bfloat16")
    assert t2 > t1
    t3 = m.time(Workload(flops=1e9, hbm_bytes=1e9, vmem_bytes=1024,
                         grid=16), "bfloat16")
    t4 = m.time(Workload(flops=1e9, hbm_bytes=8e9, vmem_bytes=1024,
                         grid=16), "bfloat16")
    assert t4 > t3


def test_cost_model_f32_slower_than_bf16_when_compute_bound():
    m = CostModel(get_device("tpu-v5e"), noise_sigma=0)
    w = Workload(flops=1e13, hbm_bytes=1e6, vmem_bytes=1024, grid=1,
                 mxu_tile=(256, 256, 256))
    assert m.time(w, "float32") > m.time(w, "bfloat16")


def test_cost_model_alignment_penalty():
    m = CostModel(get_device("tpu-v5e"), noise_sigma=0)
    base = dict(flops=1e13, hbm_bytes=1e6, vmem_bytes=1024, grid=1)
    aligned = m.time(Workload(**base, mxu_tile=(256, 256, 256)), "bfloat16")
    ragged = m.time(Workload(**base, mxu_tile=(130, 257, 256)), "bfloat16")
    assert ragged > aligned


def test_cost_model_noise_deterministic():
    m = CostModel(get_device("tpu-v5e"))
    w = Workload(flops=1e12, hbm_bytes=1e9, vmem_bytes=1024, grid=4)
    a = m.time(w, "float32", noise_key="k1")
    b = m.time(w, "float32", noise_key="k1")
    c = m.time(w, "float32", noise_key="k2")
    assert a == b
    assert a != c
