"""ISSUE 4 satellite: the public tuner/fleet/tunebench APIs stay
documented. Every name exported from the package ``__init__`` must
resolve, and every exported class/function must carry a real paragraph
docstring; for ``repro.tuner`` and ``repro.fleet`` the docstring must
also include a usage example (the bar the docs pass set — this test
keeps future exports honest)."""

import inspect

import pytest

import repro.fleet
import repro.prof
import repro.sandbox
import repro.serve
import repro.transfer
import repro.tunebench
import repro.tuner

MODULES = {
    "repro.tuner": (repro.tuner, True),
    "repro.fleet": (repro.fleet, True),
    "repro.tunebench": (repro.tunebench, False),   # docstring only
    "repro.transfer": (repro.transfer, False),     # docstring only
    "repro.sandbox": (repro.sandbox, True),
    "repro.prof": (repro.prof, True),
    "repro.serve": (repro.serve, False),   # docstring only
}


def exported(module):
    for name in module.__all__:
        yield name, getattr(module, name)   # AttributeError = broken export


@pytest.mark.parametrize("modname", sorted(MODULES))
def test_all_exports_resolve(modname):
    module, _ = MODULES[modname]
    names = [name for name, _obj in exported(module)]
    assert names == list(module.__all__)
    assert len(set(names)) == len(names), "duplicate names in __all__"


@pytest.mark.parametrize("modname", sorted(MODULES))
def test_exported_callables_have_paragraph_docstrings(modname):
    module, need_example = MODULES[modname]
    missing, thin, unexemplified = [], [], []
    for name, obj in exported(module):
        if not (inspect.isclass(obj) or inspect.isfunction(obj)):
            continue        # constants/registries are documented in-module
        doc = inspect.getdoc(obj)
        if not doc:
            missing.append(name)
        elif len(doc) < 60:
            thin.append(name)
        elif need_example and "example" not in doc.lower() \
                and ">>>" not in doc:
            unexemplified.append(name)
    assert not missing, f"{modname}: exports without docstrings: {missing}"
    assert not thin, (f"{modname}: one-liner docstrings (need a "
                      f"paragraph): {thin}")
    assert not unexemplified, (f"{modname}: docstrings without a usage "
                               f"example: {unexemplified}")
