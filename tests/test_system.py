"""System-level behaviour: the paper's end-to-end claims, reproduced small.

C1  default config is far from optimum;
C2  a config tuned for one scenario transfers poorly to others;
C3  runtime selection (Kernel Launcher) achieves the per-scenario optimum
    (PPM = 1.0) while any fixed config does not;
C5  first launch pays compilation, subsequent launches are cache hits.
"""

import numpy as np
import pytest

from repro.configs.microhh import Scenario
from repro.core import WisdomKernel, get_device, get_kernel
from repro.tuner import CostModelEvaluator, tune_kernel, tune_random


SCENARIOS = [
    Scenario("advec_u", (32, 32, 128), "float32", "tpu-v5e"),
    Scenario("advec_u", (64, 64, 128), "float32", "tpu-v5e"),
    Scenario("advec_u", (32, 32, 128), "bfloat16", "tpu-v4"),
    Scenario("advec_u", (64, 64, 128), "float32", "tpu-v4"),
]


def evaluator(sc: Scenario) -> CostModelEvaluator:
    return CostModelEvaluator(get_kernel(sc.kernel), sc.grid, sc.dtype,
                              get_device(sc.device), verify="none")


@pytest.fixture(scope="module")
def tuned():
    """Best config per scenario (random search, fixed budget)."""
    best = {}
    for sc in SCENARIOS:
        b = get_kernel(sc.kernel)
        res = tune_random(b.space, evaluator(sc), max_evals=80,
                          rng=np.random.default_rng(hash(sc.key) % 2**31))
        best[sc.key] = (res.best_config, res.best_score_us)
    return best


def test_c1_default_far_from_optimum(tuned):
    b = get_kernel("advec_u")
    for sc in SCENARIOS:
        default_t = evaluator(sc)(b.default_config()).score_us
        best_t = tuned[sc.key][1]
        assert best_t < default_t, sc.key
    fracs = [tuned[sc.key][1] / evaluator(sc)(b.default_config()).score_us
             for sc in SCENARIOS]
    assert np.mean(fracs) < 0.9   # tuning buys >10% on average


def test_c2_single_scenario_config_not_portable(tuned):
    """The config tuned for scenario 0 is suboptimal elsewhere."""
    donor_cfg = tuned[SCENARIOS[0].key][0]
    worse = 0
    for sc in SCENARIOS[1:]:
        t_donor = evaluator(sc)(donor_cfg).score_us
        t_best = tuned[sc.key][1]
        if t_donor > t_best * 1.02:
            worse += 1
    assert worse >= 2, "transferred config should be suboptimal somewhere"


def test_c3_runtime_selection_achieves_optimum(tmp_path, tuned):
    """Wisdom-backed runtime selection hits the per-scenario best (PPM=1)."""
    for sc in SCENARIOS:
        tune_kernel(get_kernel(sc.kernel), sc.grid, sc.dtype, sc.device,
                    strategy="random", max_evals=80,
                    time_budget_s=60, wisdom_dir=tmp_path,
                    seed=hash(sc.key) % 2**31)
    for sc in SCENARIOS:
        k = WisdomKernel(get_kernel(sc.kernel), wisdom_dir=tmp_path,
                         device_kind=sc.device)
        cfg, tier = k.select_config(sc.grid, sc.dtype)
        assert tier == "exact", sc.key
        t_sel = evaluator(sc)(cfg).score_us
        # wisdom stores the best seen under the same budget regime
        assert t_sel <= tuned[sc.key][1] * 1.25


def test_c5_first_launch_compiles_then_caches(wisdom_dir, small_fields):
    u, v, w, _, scal = small_fields
    k = WisdomKernel(get_kernel("advec_u"), wisdom_dir=wisdom_dir,
                     device_kind="tpu-v5e", backend="reference")
    k(u, v, w, scal)
    k(u, v, w, scal)
    first, second = k.stats[0], k.stats[1]
    assert not first.cached and second.cached
    assert first.compile_s > 0 and second.compile_s == 0
    # new problem size -> new compilation (paper §4.5)
    u2, v2, w2 = u[:8], v[:8], w[:8]
    k(u2, v2, w2, scal)
    assert not k.stats[2].cached
