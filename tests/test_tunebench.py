"""Recorded tuning-space datasets + simulated strategy benchmarking."""

import json

import numpy as np
import pytest

from repro.core import ConfigSpace, get_kernel
from repro.distrib.sync import MemoryTransport
from repro.fleet import ControlBus, FleetWorker, ManualClock, TuningJob
from repro.fleet.jobs import lease_name
from repro.tunebench import (DATASET_VERSION, DatasetMiss, DatasetStore,
                             DatasetVersionError, SimulatedRunner,
                             SpaceDataset, compare, dump_report,
                             fraction_curve, history_from_dataset,
                             migrate_dataset_doc, record_space,
                             run_on_dataset)
from repro.tuner import (CostModelEvaluator, fit_from_dataset, tune_kernel,
                         tune_random)


def small_space() -> ConfigSpace:
    s = ConfigSpace()
    s.tune("x", (0, 1, 2, 3), default=0)
    s.tune("y", (0, 1, 2), default=0)
    return s


def quadratic_dataset() -> SpaceDataset:
    """Known landscape: score = (x-2)^2 + (y-1)^2 + 1, optimum at (2,1)."""
    s = small_space()
    ds = SpaceDataset("quad", s, (8, 8), "float32", "tpu-v5e")
    for cfg in s.enumerate():
        score = (cfg["x"] - 2) ** 2 + (cfg["y"] - 1) ** 2 + 1.0
        ds.add(cfg, score, "ok")
    return ds


# ------------------------------- dataset ---------------------------------


def test_add_keeps_best_outcome():
    s = small_space()
    ds = SpaceDataset("k", s, (8, 8), "float32", "tpu-v5e")
    cfg = {"x": 1, "y": 1}
    ds.add(cfg, 10.0, "ok")
    ds.add(cfg, float("inf"), "infeasible", error="later failure")
    assert ds.lookup(cfg).score_us == 10.0          # ok beats infeasible
    ds.add(cfg, 5.0, "ok")
    assert ds.lookup(cfg).score_us == 5.0           # lower ok wins
    ds.add(cfg, 7.0, "ok")
    assert ds.lookup(cfg).score_us == 5.0
    assert len(ds) == 1


def test_best_and_feasible():
    ds = quadratic_dataset()
    assert len(ds) == 12
    best = ds.best()
    assert best.score_us == 1.0
    assert best.config == {"x": 2, "y": 1}
    assert len(ds.feasible()) == 12


def test_roundtrip_is_byte_stable(tmp_path):
    ds = quadratic_dataset()
    p1 = ds.save(tmp_path / "a.space.json")
    ds2 = SpaceDataset.load(p1)
    p2 = ds2.save(tmp_path / "b.space.json")
    assert p1.read_bytes() == p2.read_bytes()
    assert ds2.best().config == ds.best().config
    assert ds2.space().names == ds.space().names


def test_key_mismatch_refused(tmp_path):
    ds = quadratic_dataset()
    path = ds.save(tmp_path / "d.space.json")
    doc = json.loads(path.read_text())
    key = next(iter(doc["evaluations"]))
    doc["evaluations"][key]["config"] = {"x": 3, "y": 2}   # tampered
    path.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="does not match"):
        SpaceDataset.load(path)


def test_future_version_refused_loudly():
    doc = quadratic_dataset().to_doc()
    doc["version"] = DATASET_VERSION + 1
    with pytest.raises(DatasetVersionError, match="NOT read"):
        SpaceDataset.from_doc(doc)
    # and migration refuses the same way (no silent downgrade)
    with pytest.raises(DatasetVersionError):
        migrate_dataset_doc(doc)


def test_migrate_normalizes_versionless_doc():
    doc = quadratic_dataset().to_doc()
    del doc["version"]
    out = migrate_dataset_doc(doc)
    assert out["version"] == DATASET_VERSION
    assert "version" not in doc                     # input not mutated
    assert len(SpaceDataset.from_doc(out).evaluations) == 12


def test_wrong_format_refused():
    with pytest.raises(ValueError, match="tuning-space"):
        SpaceDataset.from_doc({"format": "wisdom", "kernel": "k"})


def test_dataset_store_roundtrip(tmp_path):
    store = DatasetStore(tmp_path / "ds")
    ds = quadratic_dataset()
    path = store.save(ds)
    assert path.name == "quad--tpu-v5e--8x8--float32.space.json"
    again = store.load_for("quad", "tpu-v5e", (8, 8), "float32")
    assert again is not None and len(again) == 12
    assert store.load_for("quad", "tpu-v4", (8, 8), "float32") is None
    assert store.datasets() == [path]


# ------------------------------ recording --------------------------------


def test_evaluator_records_every_evaluation_including_infeasible():
    b = get_kernel("advec_u")
    ds = SpaceDataset(b.name, b.space, (64, 64, 128), "float32", "tpu-v5e")
    ev = CostModelEvaluator(b, (64, 64, 128), "float32", "tpu-v5e",
                            verify="none", record_to=ds)
    res = tune_random(b.space, ev, max_evals=50,
                      rng=np.random.default_rng(0))
    assert len(ds) == len(res.evaluations)
    statuses = {e.status for e in ds.evaluations.values()}
    assert "ok" in statuses
    assert "infeasible" in statuses     # 64^3 advec_u has vmem blowups
    # recorded scores match the session's
    for e in res.evaluations:
        got = ds.lookup(e.config)
        assert got is not None
        if e.feasible:
            assert got.score_us == e.score_us


def test_record_space_is_deterministic():
    b = get_kernel("matmul")
    d1 = record_space(b, (128, 128, 128), "float32", "tpu-v5e")
    d2 = record_space(b, (128, 128, 128), "float32", "tpu-v5e")
    assert d1.to_doc() == d2.to_doc()
    assert len(d1) == b.space.valid_cardinality()


def test_tune_kernel_record_dataset_merges(tmp_path):
    b = get_kernel("matmul")
    res = tune_kernel(b, (128, 128, 128), "float32", "tpu-v5e",
                      strategy="random", max_evals=20, time_budget_s=None,
                      write_wisdom=False, seed=0,
                      record_dataset=tmp_path / "ds")
    store = DatasetStore(tmp_path / "ds")
    ds = store.load_for("matmul", "tpu-v5e", (128, 128, 128), "float32")
    assert ds is not None and len(ds) == len(res.evaluations)
    # a second session with a different seed merges into the same file
    tune_kernel(b, (128, 128, 128), "float32", "tpu-v5e",
                strategy="random", max_evals=20, time_budget_s=None,
                write_wisdom=False, seed=1,
                record_dataset=tmp_path / "ds")
    merged = store.load_for("matmul", "tpu-v5e", (128, 128, 128), "float32")
    assert len(merged) >= len(ds)


# ------------------------------ simulation -------------------------------


def test_simulated_runner_replays_and_counts():
    ds = quadratic_dataset()
    sim = SimulatedRunner(ds)
    assert sim({"x": 2, "y": 1}).score_us == 1.0
    missing = sim({"x": 99, "y": 99})
    assert not missing.feasible and "not in dataset" in missing.error
    assert (sim.calls, sim.hits, sim.misses) == (2, 1, 1)


def test_simulated_runner_on_miss_error():
    sim = SimulatedRunner(quadratic_dataset(), on_miss="error")
    with pytest.raises(DatasetMiss):
        sim({"x": 99, "y": 99})
    with pytest.raises(ValueError):
        SimulatedRunner(quadratic_dataset(), on_miss="what")


@pytest.mark.parametrize("strategy", ["random", "bayes", "anneal",
                                      "exhaustive"])
def test_simulated_sessions_are_deterministic(strategy):
    ds = quadratic_dataset()
    a = run_on_dataset(ds, strategy, budget=10, seed=3)
    b = run_on_dataset(ds, strategy, budget=10, seed=3)
    assert [e.config for e in a.evaluations] \
        == [e.config for e in b.evaluations]
    assert a.best_config == b.best_config


# ------------------------------- harness ---------------------------------


def test_fraction_curve_monotone_and_padded():
    ds = quadratic_dataset()
    res = run_on_dataset(ds, "random", budget=20, seed=0)
    curve = fraction_curve(ds, res, 20)
    assert len(curve) == 20                    # padded past exhaustion
    assert curve == sorted(curve)              # monotone nondecreasing
    assert curve[-1] == 1.0                    # 12-config space: optimum hit


def test_compare_report_deterministic_and_gated():
    ds = quadratic_dataset()
    r1 = compare([ds], budget=12, seeds=(0, 1))
    r2 = compare([ds], budget=12, seeds=(0, 1))
    assert dump_report(r1) == dump_report(r2)
    assert r1["pass"]
    # an unreachable threshold flips the dataset and the report to fail
    r3 = compare([ds], budget=12, seeds=(0, 1),
                 thresholds={"random": 1.1})
    assert not r3["pass"]
    by_name = {s["strategy"]: s for s in r3["datasets"][0]["strategies"]}
    assert not by_name["random"]["pass"]
    assert by_name["exhaustive"]["pass"]


def test_compare_carries_no_timestamps():
    report = compare([quadratic_dataset()], budget=6, seeds=(0,))
    text = dump_report(report)
    assert "date" not in text and "wall" not in text


# ---------------------------- cost-model fit -----------------------------


def test_fit_from_dataset_beats_constant_predictor():
    b = get_kernel("matmul")
    ds = record_space(b, (128, 128, 128), "float32", "tpu-v5e")
    model = fit_from_dataset(ds)
    assert model.n_samples == len(ds.feasible())
    assert model.rmse_log < model.baseline_rmse_log
    # rank agreement: the model orders a config pair the way the data does
    feas = ds.feasible()
    lo = min(feas, key=lambda e: e.score_us)
    hi = max(feas, key=lambda e: e.score_us)
    assert model.predict(lo.config) < model.predict(hi.config)


def test_fit_needs_enough_samples():
    s = small_space()
    ds = SpaceDataset("k", s, (8, 8), "float32", "tpu-v5e")
    ds.add({"x": 0, "y": 0}, 1.0, "ok")
    with pytest.raises(ValueError, match="at least 3"):
        fit_from_dataset(ds)


# --------------------------- fleet warm start ----------------------------


def _matmul_job() -> TuningJob:
    return TuningJob(job_id="j-test-r0", kernel="matmul",
                     device_kind="tpu-v5e", problem=(128, 128, 128),
                     dtype="float32", strategy="exhaustive", n_shards=2,
                     max_evals_per_shard=10_000)


def test_worker_warm_starts_from_dataset(tmp_path):
    store = DatasetStore(tmp_path)
    store.save(record_space(get_kernel("matmul"), (128, 128, 128),
                            "float32", "tpu-v5e"))
    job = _matmul_job()

    def run(datasets):
        bus = ControlBus(MemoryTransport())
        bus.publish("job", job.job_id, job.to_json())
        worker = FleetWorker(bus, "w0", clock=ManualClock(),
                             datasets=datasets)
        worker.drain()
        results = [bus.fetch("result", lease_name(job.job_id, s))
                   for s in job.shard_ids()]
        assert all(r is not None for r in results)
        return worker, results

    cold_worker, cold = run(None)
    warm_worker, warm = run(store)
    # the dataset covers the whole space: nothing is measured live
    assert cold_worker.evals_run > 0
    assert warm_worker.evals_run == 0
    # ... and the published shard results are identical anyway
    for c, w in zip(cold, warm):
        assert c["best_config"] == w["best_config"]
        assert c["best_score_us"] == w["best_score_us"]


def test_history_from_dataset_filters_to_shard():
    ds = quadratic_dataset()
    full = history_from_dataset(ds)
    assert len(full) == 12
    shard0 = ds.space().shard(0, 3)
    shard_hist = history_from_dataset(ds, shard0)
    assert 0 < len(shard_hist) < 12
    assert all(shard0.is_valid(e.config) for e in shard_hist)
    # shards partition the history exactly
    total = sum(len(history_from_dataset(ds, ds.space().shard(i, 3)))
                for i in range(3))
    assert total == 12


# --------------------------------- CLI -----------------------------------


def test_cli_record_run_compare_report(tmp_path, capsys):
    from repro.tunebench.cli import main

    out_dir = tmp_path / "datasets"
    assert main(["record", "--kernel", "matmul",
                 "--problem", "128,128,128", "--dtype", "float32",
                 "--device", "tpu-v5e", "--out", str(out_dir)]) == 0
    files = list(out_dir.glob("*.space.json"))
    assert len(files) == 1

    capsys.readouterr()                       # drain the record output
    assert main(["run", "--dataset", str(files[0]), "--strategy", "bayes",
                 "--budget", "16", "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["evals"] == 16
    assert payload["best_score_us"] is not None

    report_path = tmp_path / "report.json"
    assert main(["compare", "--datasets", str(out_dir / "*.space.json"),
                 "--budget", "16", "--seeds", "0,1",
                 "--out", str(report_path), "--check"]) == 0
    report = json.loads(report_path.read_text())
    assert report["pass"] and report["budget"] == 16

    assert main(["report", str(report_path), "--check"]) == 0
    # byte-identical re-run (the acceptance criterion, via the CLI path)
    report2_path = tmp_path / "report2.json"
    assert main(["compare", "--datasets", str(out_dir / "*.space.json"),
                 "--budget", "16", "--seeds", "0,1",
                 "--out", str(report2_path)]) == 0
    assert report_path.read_bytes() == report2_path.read_bytes()


def test_cli_compare_check_fails_below_threshold(tmp_path):
    from repro.tunebench.cli import main
    ds = quadratic_dataset()
    # a dataset with no feasible optimum reachable -> fraction 0
    empty = SpaceDataset("empty", small_space(), (8, 8), "float32",
                         "tpu-v5e")
    for cfg in small_space().enumerate():
        empty.add(cfg, float("inf"), "infeasible", error="nope")
    p1 = ds.save(tmp_path / "quad.space.json")
    p2 = empty.save(tmp_path / "empty.space.json")
    assert main(["compare", "--datasets", str(p1), "--budget", "12",
                 "--seeds", "0", "--check"]) == 0
    assert main(["compare", "--datasets", str(p2), "--budget", "12",
                 "--seeds", "0", "--check"]) == 1


def test_benchmark_entry_reproduces_cli_curves():
    """ISSUE 4 acceptance: the strategy_bench benchmark and the CLI
    compare produce the same curves on the shipped recorded spaces."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    try:
        from benchmarks.strategy_bench import shipped_datasets
    finally:
        sys.path.pop(0)
    datasets = shipped_datasets()
    assert {d.kernel for d in datasets} == {"matmul", "advec_u"}
    report = compare(datasets)
    assert report["pass"], "shipped spaces must clear their thresholds"
    # same inputs through the harness twice -> byte-identical (what the
    # CI job's compare --out artifact relies on)
    assert dump_report(report) == dump_report(compare(datasets))


def test_record_dataset_refuses_cross_scenario_merge(tmp_path):
    """Review fix: merging a session into a dataset recorded for a
    different scenario/objective must refuse, not silently mix scores."""
    path = tmp_path / "one.space.json"
    b = get_kernel("matmul")
    tune_kernel(b, (128, 128, 128), "float32", "tpu-v5e",
                strategy="random", max_evals=5, time_budget_s=None,
                write_wisdom=False, record_dataset=path)
    with pytest.raises(ValueError, match="cannot merge"):
        tune_kernel(b, (256, 256, 256), "float32", "tpu-v5e",
                    strategy="random", max_evals=5, time_budget_s=None,
                    write_wisdom=False, record_dataset=path)


def test_compare_runs_exhaustive_once_per_dataset():
    """Review fix: exhaustive ignores the seed, so compare() samples it
    once instead of replicating a constant across the seed list."""
    report = compare([quadratic_dataset()], budget=6, seeds=(0, 1, 2))
    by_name = {s["strategy"]: s
               for s in report["datasets"][0]["strategies"]}
    assert len(by_name["exhaustive"]["per_seed_final"]) == 1
    assert len(by_name["random"]["per_seed_final"]) == 3


# --------------------------- sandbox-verdict replay --------------------------


def faulted_dataset() -> SpaceDataset:
    """quadratic_dataset with every x == 0 config recorded as a sandbox
    crash (the way a SandboxedEvaluator's ``record_to`` persists one)."""
    s = small_space()
    ds = SpaceDataset("quadfault", s, (8, 8), "float32", "tpu-v5e")
    for cfg in s.enumerate():
        if cfg["x"] == 0:
            ds.add(cfg, float("inf"), "infeasible",
                   error="sandbox:crash: injected evaluator fault",
                   verdict="crash")
        else:
            ds.add(cfg, (cfg["x"] - 2) ** 2 + (cfg["y"] - 1) ** 2 + 1.0,
                   "ok")
    return ds


def test_dataset_verdict_field_roundtrips_and_stays_compact():
    ds = faulted_dataset()
    doc = json.loads(json.dumps(ds.to_doc()))
    assert doc["version"] == DATASET_VERSION       # no schema bump
    again = SpaceDataset.from_doc(doc)
    assert again.lookup({"x": 0, "y": 1}).verdict == "crash"
    ok_entry = again.lookup({"x": 2, "y": 1})
    assert ok_entry.verdict == ""
    assert "verdict" not in ok_entry.to_json()     # absent key, not ""


def test_simulated_runner_replays_sandbox_verdicts_and_counts_waste():
    sim = SimulatedRunner(faulted_dataset())
    first = sim({"x": 0, "y": 0})
    assert not first.feasible
    assert first.error.startswith("sandbox:crash")
    assert first.info["sandbox"] == "crash"
    sim({"x": 0, "y": 1})            # a different fatal config: not waste
    assert sim.wasted_evals == 0
    sim({"x": 0, "y": 0})            # re-proposing a known crash: waste
    assert sim.wasted_evals == 1
    assert sim.verdicts == {"crash": 3}
    assert sim({"x": 2, "y": 1}).feasible          # plain replay untouched


def test_compare_report_v2_carries_verdict_counters():
    ds = faulted_dataset()
    report = compare([ds], strategies=["exhaustive"], budget=12,
                     seeds=(0,))
    assert report["version"] == 2
    out = report["datasets"][0]["strategies"][0]
    assert out["verdicts"] == {"crash": 3}         # all three x == 0 configs
    assert out["wasted_evals"] == 0                # exhaustive never repeats
    # run_on_dataset's runner= hook exposes the counters to callers
    sim = SimulatedRunner(ds)
    run_on_dataset(ds, "random", budget=30, seed=0, runner=sim)
    assert sim.verdicts.get("crash", 0) >= 1
    assert sim.wasted_evals == 0                   # random dedups proposals
