"""ISSUE 9 satellite: the indexed ``Wisdom.select_record`` is
byte-identical to the historical linear-scan implementation.

``select_record_linear`` (the pre-index O(n) scan, kept verbatim) is
the oracle; Hypothesis generates record sets with measured and
transferred records, duplicate scenarios (built via ``keep_best=False``
so the list really holds collisions), equal-score/equal-distance
tie-break collisions and borderline transfer confidences, then asserts
the indexed path returns the same (record_id, tier) for queries across
every §4.5 tier. A second property checks equivalence *survives
mutation*: interleaved ``add()`` calls (which update the index
incrementally) and direct ``records`` mutation (which forces a
rebuild)."""

from hypothesis import given, settings, strategies as st

from repro.core import Wisdom, WisdomRecord
from repro.core.device import get_device
from repro.core.wisdom import make_transfer_provenance

DEVICES = ("tpu-v5e", "tpu-v4", "cpu", "tpu-v5-lite")
DTYPES = ("float32", "bfloat16")
# Small pools on purpose: collisions (same scenario, same score, same
# distance) must be common, because the tie-break path is the part of
# select() most likely to diverge between two implementations.
DIMS = (8, 16, 64)
SCORES = (1.0, 2.0, 2.0, 7.5)
CONFIDENCES = (0.0, 0.29, 0.30, 0.31, 0.9)


def measured_records(draw_tuple):
    (kind, dtype, m, n, score, block) = draw_tuple
    return WisdomRecord(
        device_kind=kind, device_family=get_device(kind).family,
        problem_size=(m, n), dtype=dtype,
        config={"block": block}, score_us=score,
        provenance={"strategy": "test", "evaluations": block})


def transferred_records(draw_tuple):
    (kind, dtype, m, n, score, conf) = draw_tuple
    return WisdomRecord(
        device_kind=kind, device_family=get_device(kind).family,
        problem_size=(m, n), dtype=dtype,
        config={"transferred": True}, score_us=score,
        provenance=make_transfer_provenance("tpu-v5e", 32, conf, score))


measured_st = st.tuples(
    st.sampled_from(DEVICES), st.sampled_from(DTYPES),
    st.sampled_from(DIMS), st.sampled_from(DIMS),
    st.sampled_from(SCORES), st.integers(1, 3)).map(measured_records)

transferred_st = st.tuples(
    st.sampled_from(DEVICES), st.sampled_from(DTYPES),
    st.sampled_from(DIMS), st.sampled_from(DIMS),
    st.sampled_from(SCORES),
    st.sampled_from(CONFIDENCES)).map(transferred_records)

records_st = st.lists(st.one_of(measured_st, transferred_st),
                      min_size=0, max_size=24)

query_st = st.tuples(
    st.sampled_from(DEVICES + ("gpu-h100",)),       # incl. unknown kind
    st.tuples(st.sampled_from(DIMS + (32,)), st.sampled_from(DIMS)),
    st.sampled_from(DTYPES + ("float16",)),
    st.sampled_from((None, 0.0, 0.30, 0.5)))


def assert_equivalent(w: Wisdom, query) -> None:
    kind, problem, dtype, threshold = query
    got = w.select_record(kind, problem, dtype, threshold)
    want = w.select_record_linear(kind, problem, dtype, threshold)
    got_id = got[0].record_id() if got[0] is not None else None
    want_id = want[0].record_id() if want[0] is not None else None
    assert (got_id, got[1]) == (want_id, want[1]), \
        f"indexed {got_id, got[1]} != linear {want_id, want[1]} " \
        f"for query {query} over {len(w)} records"


@settings(max_examples=200, deadline=None)
@given(records=records_st, queries=st.lists(query_st, min_size=1,
                                            max_size=8))
def test_indexed_select_matches_linear_scan(records, queries):
    # Constructor path: duplicate scenarios allowed to coexist, exactly
    # like a keep_best=False bulk load.
    w = Wisdom("k", records)
    for q in queries:
        assert_equivalent(w, q)


@settings(max_examples=100, deadline=None)
@given(records=records_st, keep_best=st.lists(st.booleans(), min_size=0,
                                              max_size=24),
       queries=st.lists(query_st, min_size=1, max_size=4))
def test_equivalence_survives_interleaved_adds(records, keep_best,
                                               queries):
    """add() maintains the index incrementally (keep-best replacement,
    lineage no-ops, plain appends) — select between adds must keep
    matching the oracle, which always reads the raw list."""
    w = Wisdom("k")
    for i, r in enumerate(records):
        w.add(r, keep_best=keep_best[i] if i < len(keep_best) else True)
        assert_equivalent(w, queries[i % len(queries)])
    for q in queries:
        assert_equivalent(w, q)


@settings(max_examples=50, deadline=None)
@given(records=records_st.filter(bool), query=query_st)
def test_direct_records_mutation_forces_rebuild(records, query):
    """The index is derived state: appending to (or rebinding) the raw
    ``records`` list bypasses the incremental hooks, and the next select
    must notice and rebuild rather than serve a stale answer."""
    w = Wisdom("k", records[:-1])
    assert_equivalent(w, query)         # builds the index
    w.records.append(records[-1])       # behind the index's back
    assert_equivalent(w, query)
    w.records = list(records[:1])       # rebind entirely
    assert_equivalent(w, query)


def test_tie_break_collision_is_deterministic():
    """Two same-scenario same-score records (distinct configs -> distinct
    record_ids) must resolve identically through both paths, in either
    insertion order."""
    a = WisdomRecord("tpu-v5e", "tpu-v5", (64, 64), "float32",
                     {"block": 1}, 2.0, {"strategy": "a"})
    b = WisdomRecord("tpu-v5e", "tpu-v5", (64, 64), "float32",
                     {"block": 2}, 2.0, {"strategy": "b"})
    for order in ([a, b], [b, a]):
        w = Wisdom("k", list(order))
        got = w.select_record("tpu-v5e", (64, 64), "float32")
        want = w.select_record_linear("tpu-v5e", (64, 64), "float32")
        assert got[0] is want[0] and got[1] == want[1] == "exact"
        assert got[0].record_id() == min(a.record_id(), b.record_id())
