"""Compile-time selection baseline (paper §3) vs runtime selection."""

import numpy as np
import pytest

from repro.core import get_kernel, WisdomKernel
from repro.core.export import StaticKernel, export_header, load_header
from repro.tuner import CostModelEvaluator, tune_kernel
from repro.core import get_device


def test_export_and_static_kernel(tmp_path, rng):
    b = get_kernel("advec_u")
    tune_kernel(b, (32, 32, 128), "float32", "tpu-v5e", strategy="random",
                max_evals=40, time_budget_s=30, wisdom_dir=tmp_path)
    hdr = export_header("advec_u", "tpu-v5e", wisdom_dir=tmp_path,
                        out_dir=tmp_path / "gen")
    doc = load_header(hdr)
    assert doc["device"] == "tpu-v5e"
    assert b.space.is_valid(doc["config"])
    # the C-header rendering exists and has a macro per parameter
    h = (tmp_path / "gen" / "advec_u-tpu-v5e.h").read_text()
    assert h.count("#define") >= len(b.space.names)

    u, v, w = (rng.standard_normal((32, 32, 128)).astype(np.float32)
               for _ in range(3))
    scal = np.array([[1.0, 1.0, 1.0, 0]], np.float32)
    k = StaticKernel(b, hdr, backend="reference")
    out1 = k(u, v, w, scal)
    out2 = k(u, v, w, scal)  # compiled-once cache
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_export_requires_wisdom(tmp_path):
    with pytest.raises(FileNotFoundError):
        export_header("advec_u", "tpu-v5e", wisdom_dir=tmp_path,
                      out_dir=tmp_path / "gen")


def test_static_selection_is_scenario_blind(tmp_path):
    """The baked config cannot adapt across problem sizes; runtime
    selection can (the paper's central comparison)."""
    b = get_kernel("advec_u")
    for grid in ((32, 32, 128), (128, 128, 128)):
        tune_kernel(b, grid, "float32", "tpu-v5e", strategy="random",
                    max_evals=60, time_budget_s=30, wisdom_dir=tmp_path,
                    seed=grid[0])
    hdr = export_header("advec_u", "tpu-v5e", wisdom_dir=tmp_path,
                        out_dir=tmp_path / "gen",
                        reference_problem=(32, 32, 128))
    static_cfg = load_header(hdr)["config"]

    # runtime selection adapts per problem
    wk = WisdomKernel(b, wisdom_dir=tmp_path, device_kind="tpu-v5e")
    cfg_small, _ = wk.select_config((32, 32, 128), "float32")
    cfg_big, _ = wk.select_config((128, 128, 128), "float32")
    assert cfg_small == static_cfg

    ev_big = CostModelEvaluator(b, (128, 128, 128), "float32",
                                get_device("tpu-v5e"), verify="none")
    t_static = ev_big(static_cfg).score_us
    t_runtime = ev_big(cfg_big).score_us
    # runtime selection is never worse on the big problem
    assert t_runtime <= t_static * 1.001
