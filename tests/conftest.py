import importlib.util
import os
import pathlib
import sys

# tests must see exactly ONE device (the dry-run sets 512 itself)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Several test modules use hypothesis property tests. On environments where
# the real package is unavailable, install the deterministic compatibility
# shim under the same import name *before* collection imports the modules.
try:  # pragma: no cover — depends on the environment
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _shim_path = pathlib.Path(__file__).with_name("_hypothesis_compat.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _shim_path)
    _shim = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _shim
    _spec.loader.exec_module(_shim)
    sys.modules["hypothesis.strategies"] = _shim.strategies

import numpy as np
import pytest


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def small_fields(rng):
    """Small periodic 3-D fields for the stencil kernels."""
    u, v, w = (rng.standard_normal((32, 32, 128)).astype(np.float32)
               for _ in range(3))
    evisc = (rng.standard_normal((32, 32, 128)).astype(np.float32)) ** 2
    scal = np.array([[1.1, 0.9, 1.3, 0.0]], np.float32)
    return u, v, w, evisc, scal


@pytest.fixture()
def wisdom_dir(tmp_path, monkeypatch):
    d = tmp_path / "wisdom"
    monkeypatch.setenv("KERNEL_LAUNCHER_WISDOM_DIR", str(d))
    return d


@pytest.fixture()
def capture_dir(tmp_path, monkeypatch):
    d = tmp_path / "captures"
    monkeypatch.setenv("KERNEL_LAUNCHER_CAPTURE_DIR", str(d))
    return d
