"""Observability: deterministic snapshots, Chrome traces, wisdom health.

The contracts under test are the ones the fleet health layer and the CI
report job lean on: snapshot JSON round-trips byte-exactly, histogram
bucketing is identical across processes, exported traces satisfy the
Chrome ``trace_event`` schema, the disabled path is a no-op, and the
health report is a pure function of its snapshot.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import (COUNT_BUCKETS, DEFAULT_BUCKETS_US, MetricsRegistry,
                       Tracer, load_snapshot, load_trace, merge_snapshots,
                       parse_series, render_report, save_snapshot,
                       scenario_health, series_key, snapshot_bytes,
                       snapshot_from_trace, validate_trace)
from repro.obs import runtime

SRC = str(Path(__file__).resolve().parents[1] / "src")


@pytest.fixture(autouse=True)
def _obs_off():
    """Every test starts and ends with observability disabled."""
    runtime.disable()
    yield
    runtime.disable()


# ------------------------------ metrics --------------------------------------

def test_series_key_roundtrip():
    key = series_key("select.tier", {"kernel": "matmul", "tier": "exact"})
    assert key == "select.tier{kernel=matmul,tier=exact}"
    assert parse_series(key) == ("select.tier",
                                 {"kernel": "matmul", "tier": "exact"})
    assert parse_series("launch.count") == ("launch.count", {})
    with pytest.raises(ValueError):
        series_key("bad{name", {})
    with pytest.raises(ValueError):
        series_key("n", {"k": "a,b"})


def _populated_registry() -> MetricsRegistry:
    reg = MetricsRegistry()
    reg.counter("launch.count", kernel="matmul").inc(7)
    reg.gauge("serve.queue_depth").set(3)
    h = reg.histogram("launch.latency_us", kernel="matmul")
    for v in (0.5, 3.0, 999.0, 2_000_000.0):
        h.observe(v)
    return reg


def test_snapshot_save_load_roundtrip(tmp_path):
    reg = _populated_registry()
    snap = reg.snapshot()
    p = save_snapshot(snap, tmp_path / "s.json")
    loaded = load_snapshot(p)
    assert loaded == snap
    assert snapshot_bytes(loaded) == p.read_bytes()
    h = snap["histograms"]["launch.latency_us{kernel=matmul}"]
    assert h["bounds"] == list(DEFAULT_BUCKETS_US)
    assert sum(h["counts"]) == h["count"] == 4
    assert h["counts"][-1] == 1                 # +Inf bucket got 2e6


def test_load_snapshot_rejects_future_version(tmp_path):
    p = tmp_path / "v.json"
    p.write_text(json.dumps({"version": 99, "counters": {}}))
    with pytest.raises(ValueError, match="version 99"):
        load_snapshot(p)
    (tmp_path / "junk.json").write_text("[1,2]")
    with pytest.raises(ValueError):
        load_snapshot(tmp_path / "junk.json")


def test_histogram_bucketing_deterministic_across_processes():
    """Same observations in another interpreter -> byte-identical
    snapshot (fixed declared bounds, no data-dependent bucketing)."""
    values = [0.9, 1.0, 1.1, 47.0, 999.999, 1e7, 0.0]
    reg = MetricsRegistry()
    for v in values:
        reg.histogram("launch.latency_us", kernel="k").observe(v)
    here = snapshot_bytes(reg.snapshot())

    script = (
        "import sys; sys.path.insert(0, sys.argv[1])\n"
        "from repro.obs import MetricsRegistry, snapshot_bytes\n"
        "reg = MetricsRegistry()\n"
        f"for v in {values!r}:\n"
        "    reg.histogram('launch.latency_us', kernel='k').observe(v)\n"
        "sys.stdout.buffer.write(snapshot_bytes(reg.snapshot()))\n")
    out = subprocess.run([sys.executable, "-c", script, SRC],
                         capture_output=True, check=True,
                         env={**os.environ, "JAX_PLATFORMS": "cpu"})
    assert out.stdout == here


def test_histogram_redeclare_with_other_bounds_raises():
    reg = MetricsRegistry()
    reg.histogram("h", COUNT_BUCKETS, kernel="k")
    with pytest.raises(ValueError, match="different bounds"):
        reg.histogram("h", DEFAULT_BUCKETS_US, kernel="k")
    with pytest.raises(ValueError):
        reg.histogram("h2", bounds=(3.0, 1.0))   # not ascending


def test_merge_snapshots_semantics():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("launch.count").inc(2)
    b.counter("launch.count").inc(5)
    a.gauge("serve.queue_depth").set(3)
    b.gauge("serve.queue_depth").set(9)
    a.histogram("h", COUNT_BUCKETS).observe(1)
    b.histogram("h", COUNT_BUCKETS).observe(300)
    merged = merge_snapshots([a.snapshot(), b.snapshot()])
    assert merged["counters"]["launch.count"] == 7       # sum
    assert merged["gauges"]["serve.queue_depth"] == 9    # max
    h = merged["histograms"]["h"]
    assert h["count"] == 2 and h["counts"][0] == 1 and h["counts"][-1] == 1

    c = MetricsRegistry()
    c.histogram("h", DEFAULT_BUCKETS_US).observe(1)
    with pytest.raises(ValueError, match="bounds differ"):
        merge_snapshots([a.snapshot(), c.snapshot()])


# ------------------------------- tracing -------------------------------------

class _FakeClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        self.t += 0.001
        return self.t


def _scripted_trace() -> Tracer:
    tr = Tracer(clock=_FakeClock())
    with tr.span("launch", cat="kernel", kernel="matmul", tier="exact",
                 scenario="tpu-v5e|8x8|float32"):
        tr.instant("online.promoted", cat="online", kernel="matmul")
    with tr.span("serve.cohort", cat="serve", size=2):
        pass
    return tr


def test_trace_chrome_schema_valid_and_deterministic(tmp_path):
    t1, t2 = _scripted_trace(), _scripted_trace()
    assert validate_trace(t1.to_chrome()) == []
    p = t1.save(tmp_path / "t.json")
    doc = load_trace(p)
    assert doc == t1.to_chrome()
    assert len(t1) == 3
    # injectable clock => byte-determinism across tracer instances
    assert json.dumps(t1.to_chrome(), sort_keys=True) == \
        json.dumps(t2.to_chrome(), sort_keys=True)
    ph = [ev["ph"] for ev in doc["traceEvents"]]
    assert ph == ["i", "X", "X"]             # instant inside the first span


def test_validate_trace_rejects_bad(tmp_path):
    assert validate_trace([]) != []
    assert validate_trace({"traceEvents": [{"name": "x"}]}) != []
    bad = {"traceEvents": [{"name": "x", "cat": "c", "ph": "X", "ts": 0,
                            "pid": 1, "tid": 0, "dur": -5}]}
    assert any("negative" in e for e in validate_trace(bad))
    p = tmp_path / "bad.json"
    p.write_text(json.dumps(bad))
    with pytest.raises(ValueError, match="not a valid Chrome trace"):
        load_trace(p)


# --------------------------- runtime switch ----------------------------------

def test_disabled_mode_is_noop_and_enable_is_idempotent():
    assert runtime.metrics() is None and runtime.tracer() is None
    assert not runtime.enabled()
    reg, tr = runtime.enable()
    reg2, tr2 = runtime.enable()
    assert reg is reg2 and tr is tr2         # counters survive re-enable
    assert runtime.metrics() is reg
    runtime.disable()
    assert runtime.metrics() is None


def test_launch_instrumentation_and_always_on_tier_tally(wisdom_dir):
    """Disabled: a launch leaves no registry but still tallies tiers on
    the kernel (the satellite API). Enabled: the same launch produces
    select.tier/launch.count series and a trace launch event."""
    from repro.core import WisdomKernel, get_kernel
    a = np.ones((64, 64), np.float32)
    k = WisdomKernel(get_kernel("matmul"), wisdom_dir=wisdom_dir,
                     device_kind="tpu-v5e", backend="reference")
    k(a, a)
    assert k.tier_counts == {"default": 1} and k.last_tier == "default"
    assert runtime.metrics() is None         # stayed disabled

    reg, tr = runtime.enable()
    k(a, a)
    assert k.tier_counts["default"] == 2
    snap = reg.snapshot()
    tier_keys = [s for s in snap["counters"] if s.startswith("select.tier")]
    assert tier_keys == ["select.tier{kernel=matmul,"
                         "scenario=tpu-v5e|64x64x64|float32,tier=default}"]
    assert snap["counters"]["launch.count{kernel=matmul}"] == 1
    assert snap["counters"]["compile.cache{kernel=matmul,outcome=hit}"] == 1
    launches = [ev for ev in tr.events if ev["name"] == "launch"]
    assert len(launches) == 1
    assert launches[0]["args"]["tier"] == "default"
    assert validate_trace(tr.to_chrome()) == []


def test_single_source_of_tier_names():
    """Satellite: core/scenario.py is the one definition — the online
    tracker re-exports the very same objects, and Wisdom.select only
    produces tiers from it."""
    from repro.core import scenario
    from repro.online import tracker
    assert tracker.MISS_TIERS is scenario.MISS_TIERS
    assert tracker.SELECT_TIERS is scenario.SELECT_TIERS
    assert tracker.format_key is scenario.format_key
    assert scenario.SELECT_TIERS[0] == "exact"
    assert scenario.SELECT_TIERS[-1] == "default"
    assert scenario.MISS_TIERS == set(scenario.SELECT_TIERS) - {"exact"}
    key = ("tpu-v5e", (256, 256), "float32")
    assert scenario.parse_key(scenario.format_key(key)) == key


# ------------------------------- report --------------------------------------

def _health_registry() -> MetricsRegistry:
    reg = MetricsRegistry()
    sc = "tpu-v5e|256x256x256|float32"
    for tier, n in (("exact", 8), ("device+dtype", 2)):
        reg.counter("select.tier", kernel="matmul", scenario=sc,
                    tier=tier).inc(n)
    reg.counter("select.tier", kernel="attn",
                scenario="tpu-v4|64x64|bfloat16", tier="default").inc(5)
    reg.counter("launch.count", kernel="matmul").inc(10)
    return reg


def test_report_is_pure_and_names_scenarios():
    snap = _health_registry().snapshot()
    r1, r2 = render_report(snap), render_report(snap)
    assert r1 == r2                           # same snapshot, same bytes
    assert "matmul tpu-v5e|256x256x256|float32: hit-rate=0.80" in r1
    assert "attn tpu-v4|64x64|bfloat16: hit-rate=0.00" in r1
    assert "dominant-tier=default" in r1
    health = scenario_health(snap)
    assert [h.kernel for h in health] == ["attn", "matmul"]
    assert health[1].misses == 2 and health[1].launches == 10


def test_snapshot_from_trace_matches_counters():
    tr = _scripted_trace()
    snap = snapshot_from_trace(tr.to_chrome())
    key = ("select.tier{kernel=matmul,scenario=tpu-v5e|8x8|float32,"
           "tier=exact}")
    assert snap["counters"][key] == 1
    assert snap["histograms"]["launch.latency_us{kernel=matmul}"][
        "count"] == 1
    assert "hit-rate=1.00" in render_report(snap)


# ----------------------------- serve stats -----------------------------------

class _ToyModel:
    """Minimal decode-only model: next token = (tok + 1) mod vocab."""

    vocab = 13

    def init_cache(self, n_slots, max_seq):
        return {"pos": jnp.zeros((), jnp.int32)}

    def decode_step(self, params, cache, tok):
        logits = jax.nn.one_hot((tok[:, 0] + 1) % self.vocab,
                                self.vocab)[:, None]
        return logits, {"pos": cache["pos"] + 1}


def test_serve_run_returns_report_with_stats():
    from repro.serve import Request, ServeEngine, ServeReport
    eng = ServeEngine(_ToyModel(), params={}, n_slots=2, max_seq=16)
    for rid in range(4):                      # 4 requests, 2 slots
        eng.submit(Request(rid, np.array([1, 2], np.int32),
                           max_new_tokens=3))
    reg, _ = runtime.enable()
    out = eng.run()
    assert isinstance(out, ServeReport)
    # mapping compatibility with the old {rid: tokens} return value
    assert set(out) == {0, 1, 2, 3} and len(out) == 4
    assert out[0][0] == 3 and 2 in out
    assert sorted(out.keys()) == [0, 1, 2, 3]
    # the new per-run stats
    assert out.cohorts == 2
    assert out.requests_completed == 4
    assert out.steps == eng.steps_run > 0
    assert out.sync_pulls == 0 and out.sync_failures == 0
    assert out.to_json()["cohorts"] == 2
    snap = reg.snapshot()
    assert snap["counters"]["serve.decode_steps"] == out.steps
    assert snap["counters"]["serve.requests_completed"] == 4
    assert snap["histograms"]["serve.cohort_size"]["count"] == 2


# ----------------------------- fleet health ----------------------------------

def test_fleet_health_aggregates_bus_snapshots():
    from repro.distrib.sync import MemoryTransport
    from repro.fleet import ControlBus
    from repro.fleet.health import (MetricsPublisher,
                                    aggregate_fleet_metrics, fleet_health,
                                    fleet_snapshots, publish_metrics)
    bus = ControlBus(MemoryTransport())
    r1, r2 = MetricsRegistry(), MetricsRegistry()
    sc = "tpu-v5e|8x8|float32"
    r1.counter("select.tier", kernel="m", scenario=sc, tier="exact").inc(3)
    r2.counter("select.tier", kernel="m", scenario=sc, tier="default").inc(1)
    publish_metrics(bus, "host-1", r1)
    publish_metrics(bus, "host-2", r2)
    assert sorted(fleet_snapshots(bus)) == ["host-1", "host-2"]
    merged = aggregate_fleet_metrics(bus)
    assert merged["counters"][
        f"select.tier{{kernel=m,scenario={sc},tier=exact}}"] == 3
    text = fleet_health(bus)
    assert f"m {sc}: hit-rate=0.75 launches=4" in text

    with pytest.raises(RuntimeError, match="disabled"):
        publish_metrics(bus, "host-3")       # no registry, obs off

    pub = MetricsPublisher(bus, "host-3", interval=2, registry=r1)
    assert [pub.tick() for _ in range(4)] == [True, False, True, False]
    assert pub.publishes == 2
    assert MetricsPublisher(bus, "h", registry=None).tick() is False


def test_lease_lifecycle_metrics():
    from repro.distrib.sync import MemoryTransport
    from repro.fleet import ControlBus, ManualClock, TuningJob
    from repro.fleet.jobs import (claim_shard, heartbeat, job_id_for,
                                  release)
    reg, _ = runtime.enable()
    bus = ControlBus(MemoryTransport())
    clock = ManualClock()
    key = ("tpu-v5e", (64, 64, 64), "float32")
    job = TuningJob(job_id=job_id_for("matmul", key), kernel="matmul",
                    device_kind="tpu-v5e", problem=(64, 64, 64),
                    dtype="float32", n_shards=2)
    lease = claim_shard(bus, job, "s000", "w1", clock)
    assert lease is not None
    heartbeat(bus, lease, clock)
    assert claim_shard(bus, job, "s000", "w2", clock) is None  # live: no event
    clock.advance(120.0)
    stolen = claim_shard(bus, job, "s000", "w2", clock)        # expired
    assert stolen is not None and stolen.claims == 2
    release(bus, stolen)
    from repro.fleet.jobs import LeaseLost
    with pytest.raises(LeaseLost):
        heartbeat(bus, lease, clock)         # w1's nonce is gone
    c = reg.snapshot()["counters"]
    assert c["fleet.lease{event=acquire,worker=w1}"] == 1
    assert c["fleet.lease{event=heartbeat,worker=w1}"] == 1
    assert c["fleet.lease{event=reclaim,worker=w2}"] == 1
    assert c["fleet.lease{event=release,worker=w2}"] == 1
    assert c["fleet.lease{event=lost,worker=w1}"] == 1


def test_sync_failure_isolated_and_counted(tmp_path):
    from repro.distrib.store import WisdomStore
    from repro.distrib.sync import PullSync

    class _DeadTransport:
        def list_kernels(self):
            raise OSError("mount gone")

        def fetch(self, name):              # pragma: no cover
            return None

        def publish(self, name, doc):       # pragma: no cover
            pass

    reg, _ = runtime.enable()
    sync = PullSync(WisdomStore(tmp_path), _DeadTransport(), interval=1)
    assert sync.tick() is None
    assert sync.failures == 1
    assert reg.snapshot()["counters"][
        "sync.failures{direction=pull}"] == 1


# --------------------------------- CLI ---------------------------------------

def test_cli_report_snapshot_trace(tmp_path, capsys):
    from repro.obs.cli import main
    snap_path = save_snapshot(_health_registry().snapshot(),
                              tmp_path / "s.json")
    assert main(["report", str(snap_path)]) == 0
    first = capsys.readouterr().out
    assert main(["report", str(snap_path)]) == 0
    assert capsys.readouterr().out == first   # byte-deterministic
    assert "Tier breakdown (per kernel)" in first

    trace_path = _scripted_trace().save(tmp_path / "t.json")
    assert main(["trace", str(trace_path)]) == 0
    assert "valid Chrome trace: 3 event(s)" in capsys.readouterr().out

    merged = tmp_path / "merged.json"
    assert main(["snapshot", str(snap_path), str(snap_path),
                 "--out", str(merged)]) == 0
    doc = load_snapshot(merged)
    assert doc["counters"]["launch.count{kernel=matmul}"] == 20  # summed

    bad = tmp_path / "bad-trace.json"
    bad.write_text("{}")
    assert main(["trace", str(bad)]) == 1
