"""Wisdom-file persistence + the paper §4.5 selection heuristic."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import Wisdom, WisdomRecord, make_provenance
from repro.core.wisdom import _distance


def rec(device="tpu-v5e", family="tpu-v5", problem=(256, 256, 256),
        dtype="float32", score=100.0, config=None):
    return WisdomRecord(device_kind=device, device_family=family,
                        problem_size=tuple(problem), dtype=dtype,
                        config=config or {"block": 1},
                        score_us=score, provenance=make_provenance())


def test_roundtrip(tmp_path):
    w = Wisdom("k")
    w.add(rec(score=5.0, config={"block": 8}))
    w.add(rec(device="tpu-v4", family="tpu-v4", score=7.0))
    p = w.save(tmp_path)
    assert p.exists()
    w2 = Wisdom.load("k", tmp_path)
    assert len(w2) == 2
    assert w2.records[0].config == {"block": 8}


def test_retune_keeps_best():
    w = Wisdom("k")
    w.add(rec(score=10.0, config={"block": 1}))
    w.add(rec(score=5.0, config={"block": 2}))    # same scenario, better
    w.add(rec(score=9.0, config={"block": 3}))    # same scenario, worse
    assert len(w) == 1
    assert w.records[0].config == {"block": 2}


def test_selection_tiers():
    w = Wisdom("k")
    w.add(rec(problem=(256, 256, 256), config={"c": "exact"}))
    w.add(rec(problem=(512, 512, 512), config={"c": "far"}))
    w.add(rec(device="tpu-v4", family="tpu-v4", problem=(256, 256, 256),
              config={"c": "other-dev"}))
    default = {"c": "default"}

    cfg, tier = w.select("tpu-v5e", (256, 256, 256), "float32", default)
    assert tier == "exact" and cfg["c"] == "exact"

    # same device, fuzzy size -> Euclidean-closest record
    cfg, tier = w.select("tpu-v5e", (300, 300, 300), "float32", default)
    assert tier == "device+dtype" and cfg["c"] == "exact"
    cfg, tier = w.select("tpu-v5e", (500, 500, 500), "float32", default)
    assert cfg["c"] == "far"

    # unknown device with known family member -> family tier
    cfg, tier = w.select("tpu-v4", (256, 256, 256), "float32", default)
    assert cfg["c"] == "other-dev"

    # unknown everything -> any record, closest size
    cfg, tier = w.select("tpu-v9x", (256, 256, 256), "bfloat16", default)
    assert tier in ("any", "any+dtype")

    # empty wisdom -> default
    cfg, tier = Wisdom("k2").select("tpu-v5e", (1, 2), "float32", default)
    assert tier == "default" and cfg == default


@settings(max_examples=60, deadline=None)
@given(
    probs=st.lists(st.tuples(st.integers(8, 1024), st.integers(8, 1024)),
                   min_size=1, max_size=6, unique=True),
    query=st.tuples(st.integers(8, 1024), st.integers(8, 1024)),
)
def test_same_device_selection_minimizes_distance(probs, query):
    w = Wisdom("k")
    for i, p in enumerate(probs):
        w.add(rec(problem=p, config={"i": i}, score=1.0))
    cfg, tier = w.select("tpu-v5e", query, "float32", {"i": -1})
    dists = [_distance(p, query) for p in probs]
    best = int(np.argmin(dists))
    assert cfg["i"] == best or dists[cfg["i"]] == dists[best]


def test_equal_score_equal_distance_tie_breaks_deterministically():
    """ISSUE 5 regression: two records at the same distance with the same
    score must resolve to the same winner regardless of insertion order
    (previously the first-inserted record won — merge order leaked into
    serving behavior)."""
    a = rec(problem=(128,), config={"c": "a"}, score=7.0)
    b = rec(problem=(512,), config={"c": "b"}, score=7.0)
    assert _distance((128,), (256,)) == _distance((512,), (256,))
    expect = min((a, b), key=lambda r: r.record_id()).config
    w_ab = Wisdom("k")
    w_ab.add(a)
    w_ab.add(b)
    w_ba = Wisdom("k")
    w_ba.add(b)
    w_ba.add(a)
    got_ab, _ = w_ab.select("tpu-v5e", (256,), "float32", {"c": "d"})
    got_ba, _ = w_ba.select("tpu-v5e", (256,), "float32", {"c": "d"})
    assert got_ab == got_ba == expect


def test_distance_is_scale_normalized():
    """A small relative change on a huge axis must not drown out a large
    relative change on a small axis (the tier 2-4 regression)."""
    query = (1024, 64)
    w = Wisdom("k")
    w.add(rec(problem=(1024, 8), config={"c": "small-axis-8x"}))
    w.add(rec(problem=(1100, 64), config={"c": "big-axis-7pct"}))
    cfg, tier = w.select("tpu-v5e", query, "float32", {"c": "default"})
    # raw Euclidean would pick the 8x-different small axis (|d|=56 vs 76);
    # normalized distance prefers the 7% change on the big axis.
    assert cfg["c"] == "big-axis-7pct"
    assert _distance((1024, 8), query) > _distance((1100, 64), query)


# --------------------- §4.5 fallback chain, tier by tier ---------------------

DEFAULT = {"c": "default"}


def _tier_wisdom():
    """One record per tier-discriminating scenario component."""
    w = Wisdom("k")
    w.add(rec(device="tpu-v5e", family="tpu-v5", problem=(256, 256),
              dtype="float32", config={"c": "exact"}))
    w.add(rec(device="tpu-v5e", family="tpu-v5", problem=(128, 128),
              dtype="float32", config={"c": "dev-dtype"}))
    w.add(rec(device="tpu-v5e", family="tpu-v5", problem=(64, 64),
              dtype="bfloat16", config={"c": "dev-other-dtype"}))
    w.add(rec(device="tpu-v5p", family="tpu-v5", problem=(64, 64),
              dtype="float16", config={"c": "family"}))
    w.add(rec(device="tpu-v4", family="tpu-v4", problem=(64, 64),
              dtype="float16", config={"c": "any"}))
    return w


def test_tier1_exact():
    cfg, tier = _tier_wisdom().select("tpu-v5e", (256, 256), "float32",
                                      DEFAULT)
    assert (tier, cfg["c"]) == ("exact", "exact")


def test_tier2_same_device_closest_size():
    cfg, tier = _tier_wisdom().select("tpu-v5e", (130, 130), "float32",
                                      DEFAULT)
    assert (tier, cfg["c"]) == ("device+dtype", "dev-dtype")


def test_tier2b_same_device_any_dtype():
    cfg, tier = _tier_wisdom().select("tpu-v5e", (64, 64), "float64",
                                      DEFAULT)
    assert (tier, cfg["c"]) == ("device", "dev-other-dtype")


def test_tier3_family():
    # no tpu-v5e records at all, but a sibling tpu-v5p (family tpu-v5) one
    w = Wisdom("k")
    w.add(rec(device="tpu-v5p", family="tpu-v5", problem=(64, 64),
              dtype="float16", config={"c": "family"}))
    w.add(rec(device="tpu-v4", family="tpu-v4", problem=(64, 64),
              dtype="float16", config={"c": "any"}))
    cfg, tier = w.select("tpu-v5e", (64, 64), "float16", DEFAULT)
    assert (tier, cfg["c"]) == ("family+dtype", "family")


def test_tier3b_family_any_dtype():
    w = Wisdom("k")
    w.add(rec(device="tpu-v5p", family="tpu-v5", problem=(64, 64),
              dtype="float16", config={"c": "family"}))
    cfg, tier = w.select("tpu-v5e", (64, 64), "int8", DEFAULT)
    assert (tier, cfg["c"]) == ("family", "family")


def test_tier4_any_record():
    cfg, tier = _tier_wisdom().select("gpu-h100", (64, 64), "float16",
                                      DEFAULT)
    assert tier == "any+dtype" and cfg["c"] in ("family", "any")
    cfg, tier = _tier_wisdom().select("gpu-h100", (64, 64), "int8", DEFAULT)
    assert tier == "any"


def test_tier5_empty_wisdom_default():
    cfg, tier = Wisdom("k-empty").select("tpu-v5e", (256, 256), "float32",
                                         DEFAULT)
    assert (tier, cfg) == ("default", DEFAULT)


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_selection_never_fails_never_invents(data):
    """Property: select() always returns either a stored config or the
    default, for arbitrary record sets and queries."""
    w = Wisdom("k")
    n = data.draw(st.integers(0, 5))
    stored = []
    for i in range(n):
        d = data.draw(st.sampled_from(["tpu-v5e", "tpu-v4", "gpu-x"]))
        fam = "-".join(d.split("-")[:2])
        p = data.draw(st.tuples(st.integers(1, 64), st.integers(1, 64)))
        dt = data.draw(st.sampled_from(["float32", "bfloat16"]))
        w.add(WisdomRecord(d, fam, p, dt, {"i": i}, float(i + 1), {}))
        stored.append({"i": i})
    q_dev = data.draw(st.sampled_from(["tpu-v5e", "tpu-v4", "other"]))
    q_p = data.draw(st.tuples(st.integers(1, 64), st.integers(1, 64)))
    cfg, tier = w.select(q_dev, q_p, "float32", {"i": -1})
    assert cfg in stored + [{"i": -1}]
    if n == 0:
        assert tier == "default"


# -------------- mixed-device-family fallback ordering (ISSUE 4) --------------


def _mixed_family_wisdom():
    """Records spread over two families and three device kinds, with
    dtype/distance decoys, so every inter-tier preference is observable."""
    w = Wisdom("k")
    # family tpu-v5: a sibling device (not the query device), wrong dtype
    w.add(rec(device="tpu-v5p", family="tpu-v5", problem=(256, 256),
              dtype="bfloat16", config={"c": "v5-sibling-bf16"}))
    # family tpu-v4: exact dtype, exact problem — but the wrong family
    w.add(rec(device="tpu-v4", family="tpu-v4", problem=(256, 256),
              dtype="float32", config={"c": "v4-f32"}))
    return w


def test_family_beats_other_family_even_with_wrong_dtype():
    """Tier "family" (right family, wrong dtype) outranks "any+dtype"
    (wrong family, right dtype): architecture similarity dominates
    precision similarity in the §4.5 chain."""
    cfg, tier = _mixed_family_wisdom().select("tpu-v5e", (256, 256),
                                              "float32", DEFAULT)
    assert (tier, cfg["c"]) == ("family", "v5-sibling-bf16")


def test_family_dtype_beats_family_distance():
    """Within the family tiers, dtype match outranks problem-size
    proximity: a far family record with the right dtype wins over a
    byte-exact-size family record with the wrong dtype."""
    w = _mixed_family_wisdom()
    w.add(rec(device="tpu-v5p", family="tpu-v5", problem=(1024, 1024),
              dtype="float32", config={"c": "v5-sibling-far-f32"}))
    cfg, tier = w.select("tpu-v5e", (256, 256), "float32", DEFAULT)
    assert (tier, cfg["c"]) == ("family+dtype", "v5-sibling-far-f32")


def test_unknown_device_kind_joins_its_prefix_family():
    """A device kind nobody tuned (e.g. a new v5 variant) derives its
    family from the first two kind segments ("tpu-v5-lite" -> "tpu-v5")
    and still lands on family wisdom instead of falling through to
    "any"."""
    cfg, tier = _mixed_family_wisdom().select("tpu-v5-lite", (256, 256),
                                              "bfloat16", DEFAULT)
    assert (tier, cfg["c"]) == ("family+dtype", "v5-sibling-bf16")


def test_device_tier_beats_family_tier_regardless_of_distance():
    """A far record on the exact device outranks an exact-size record on
    a family sibling: tiers are strict, distance only breaks ties inside
    one tier."""
    w = _mixed_family_wisdom()
    w.add(rec(device="tpu-v5e", family="tpu-v5", problem=(4096, 4096),
              dtype="bfloat16", config={"c": "v5e-far-bf16"}))
    cfg, tier = w.select("tpu-v5e", (256, 256), "float32", DEFAULT)
    assert (tier, cfg["c"]) == ("device", "v5e-far-bf16")


def test_mixed_families_last_resort_any():
    """With no family cousin at all, the wrong-family record is still
    used (tier "any+dtype"/"any") — wisdom never invents configs, and
    never returns the default while *any* record exists."""
    w = Wisdom("k")
    w.add(rec(device="tpu-v4", family="tpu-v4", problem=(256, 256),
              dtype="bfloat16", config={"c": "v4-bf16"}))
    cfg, tier = w.select("gpu-h100", (256, 256), "float32", DEFAULT)
    assert (tier, cfg["c"]) == ("any", "v4-bf16")
    cfg, tier = w.select("gpu-h100", (256, 256), "bfloat16", DEFAULT)
    assert (tier, cfg["c"]) == ("any+dtype", "v4-bf16")


# -- add() through the index (ISSUE 9 regression) -----------------------------

def test_same_record_readd_is_noop():
    """Re-adding the identical record (a fleet sync echo) must not grow
    the store, must not grow lineage, and must keep the index live."""
    w = Wisdom("k")
    r = rec(score=5.0, config={"block": 8})
    w.add(r)
    lineage_before = [dict(e) for e in w.records[0].lineage]
    echo = WisdomRecord.from_json(r.to_json())     # same record_id
    assert echo.record_id() == r.record_id()
    w.add(echo)
    assert len(w) == 1
    assert w.records[0].lineage == lineage_before
    got, tier = w.select_record("tpu-v5e", (256, 256, 256), "float32")
    assert tier == "exact" and got.config == {"block": 8}


def test_keep_best_merges_lineage_through_index():
    """The keep-best winner absorbs the loser's provenance whether the
    winner is the incumbent or the newcomer — and the index serves the
    survivor either way."""
    # newcomer wins
    w = Wisdom("k")
    w.add(rec(score=10.0, config={"block": 1}))
    w.add(rec(score=5.0, config={"block": 2}))
    assert len(w) == 1 and w.records[0].config == {"block": 2}
    assert len(w.records[0].lineage) >= 2          # both provenances pooled
    got, tier = w.select_record("tpu-v5e", (256, 256, 256), "float32")
    assert got is w.records[0] and tier == "exact"
    # incumbent wins
    w2 = Wisdom("k")
    w2.add(rec(score=5.0, config={"block": 2}))
    w2.add(rec(score=10.0, config={"block": 1}))
    assert len(w2) == 1 and w2.records[0].config == {"block": 2}
    assert len(w2.records[0].lineage) >= 2
    got2, _ = w2.select_record("tpu-v5e", (256, 256, 256), "float32")
    assert got2.config == {"block": 2}


def test_add_after_direct_mutation_rebuilds_index():
    """Mutating ``records`` directly (merge/prune code paths do) must not
    leave add() consulting a stale scenario map."""
    w = Wisdom("k")
    w.add(rec(score=9.0, config={"block": 1}))
    w.records.append(rec(device="tpu-v4", family="tpu-v4",
                         score=7.0, config={"block": 4}))
    w.add(rec(device="tpu-v4", family="tpu-v4",
              score=3.0, config={"block": 16}))    # better than appended
    assert len(w) == 2
    got, tier = w.select_record("tpu-v4", (256, 256, 256), "float32")
    assert tier == "exact" and got.config == {"block": 16}
