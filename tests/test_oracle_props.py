"""Property-based guarantees for the correctness oracle (ISSUE 7
satellite): across random probe inputs, shapes and dtypes, the oracle
(a) accepts a kernel that reproduces its reference exactly, (b) accepts
perturbations comfortably inside the dtype tolerance, and (c) rejects
perturbations just above it with a ``numerics-mismatch`` verdict. Runs
under real ``hypothesis`` when installed, else the deterministic compat
shim (``tests/_hypothesis_compat.py``)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.builder import KernelBuilder
from repro.sandbox import CorrectnessOracle
from repro.tuner.runner import _tolerances

DTYPES = ["float32", "float16", "bfloat16"]


def _perturbed_identity(delta: float) -> KernelBuilder:
    """A kernel whose honest computation is the identity and whose built
    variant adds a constant ``delta`` everywhere — the smallest possible
    numerics fault, so the accept/reject boundary is exactly the oracle's
    elementwise tolerance."""
    b = KernelBuilder("oracle_props_identity", source="tests")
    b.tune("unit", (1,), default=1)

    @b.problem_size
    def _problem(x):
        return tuple(int(d) for d in x.shape)

    @b.build
    def _build(config, problem, meta, interpret=False):
        def run(x):
            return np.asarray(x, np.float64) + delta
        return run

    @b.reference
    def _reference(x):
        return np.asarray(x)

    return b


def _probe(data) -> np.ndarray:
    """A random probe array with |x| <= 1, so the comparison's reference
    scale is exactly 1 and the elementwise tolerance is atol + rtol*|x|."""
    shape = tuple(data.draw(
        st.lists(st.integers(1, 8), min_size=1, max_size=3)))
    seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    return rng.uniform(-1.0, 1.0, shape)


def _check(delta: float, x: np.ndarray, dtype: str):
    oracle = CorrectnessOracle(_perturbed_identity(delta),
                               [x.astype(dtype)])
    return oracle.check({"unit": 1})


@settings(max_examples=50, deadline=None)
@given(st.data())
def test_reference_accepts_itself(data):
    x = _probe(data)
    dtype = data.draw(st.sampled_from(DTYPES))
    verdict = _check(0.0, x, dtype)
    assert verdict.ok, verdict.detail
    assert verdict.max_err == 0.0
    rtol, atol = _tolerances(dtype)
    assert (verdict.rtol, verdict.atol) == (rtol, atol)


@settings(max_examples=50, deadline=None)
@given(st.data())
def test_perturbation_within_tolerance_accepted(data):
    x = _probe(data)
    dtype = data.draw(st.sampled_from(DTYPES))
    rtol, atol = _tolerances(dtype)
    # |x| <= 1 means every element's allowed deviation is at least atol
    delta = atol * data.draw(st.floats(0.0, 0.5))
    verdict = _check(delta, x, dtype)
    assert verdict.ok, verdict.detail
    assert verdict.max_err <= delta + 1e-12


@settings(max_examples=50, deadline=None)
@given(st.data())
def test_perturbation_above_tolerance_rejected(data):
    x = _probe(data)
    dtype = data.draw(st.sampled_from(DTYPES))
    rtol, atol = _tolerances(dtype)
    # |x| <= 1 bounds every element's allowed deviation by atol + rtol,
    # so anything safely past that must trip the oracle
    delta = (atol + rtol) * data.draw(st.floats(1.5, 100.0))
    verdict = _check(delta, x, dtype)
    assert verdict.status == "numerics-mismatch", verdict.status
    assert verdict.max_err is not None and verdict.max_err > atol
    assert "allclose" in verdict.detail


def test_tolerances_are_dtype_aware():
    """The same small error is acceptable for half precision and a
    failure for float32 — the oracle judges against the input dtype."""
    x = np.random.default_rng(0).uniform(-1.0, 1.0, (8, 8))
    delta = 2e-3          # between float32's 1e-5 and float16's 1e-2
    assert _check(delta, x, "float16").ok
    assert _check(delta, x, "bfloat16").ok
    assert _check(delta, x, "float32").status == "numerics-mismatch"
