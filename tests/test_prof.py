"""Kernel profiler: roofline counters, bottleneck attribution, sampled
launch-path profiling, drift detection, and profile-guided tuning.

The contracts under test are the ones the CI ``prof-smoke`` job and the
strategy-bench gate lean on: profiles round-trip byte-exactly and refuse
future schema versions, classification reproduces the device physics
(small matmul memory-bound, serving-scale matmul compute-bound, stencils
memory-bound), sampling touches the hot path only through one branch per
launch, recorded datasets carry per-config profile fields, and the
profile-guided surrogate never ranks worse than plain ridge on the
shipped spaces.
"""

import json
import os
from pathlib import Path

import numpy as np
import pytest

from repro.core import Wisdom, WisdomRecord, get_kernel, make_provenance
from repro.core.builder import KernelBuilder
from repro.core.device import get_device
from repro.obs import Tracer, validate_trace
from repro.obs import runtime
from repro.prof import (DEFAULT_SAMPLE_EVERY, PROFILE_FEATURES,
                        PROFILE_VERSION, KernelProfile, Profiler,
                        ProfileVersionError, StepProfiler,
                        classify_bottleneck, classify_dataset,
                        load_profiles, process_profiler, prof_requested,
                        profile_feature_vector, profile_fields,
                        profile_from_workload, render_attribution,
                        render_profiles, rerank_gate,
                        reset_process_profiler, save_profiles,
                        summarize, surrogate_rerank)

DATASET_DIR = Path(__file__).resolve().parents[1] / "benchmarks" / "datasets"
ADVEC_PATH = DATASET_DIR / "advec_u--tpu-v5e--64x64x128--float32.space.json"
MATMUL_BIG = DATASET_DIR / "matmul--tpu-v5e--8192x8192x8192--float32.space.json"


@pytest.fixture(autouse=True)
def _clean():
    """Profiler tests start and end with obs off and no ambient profiler."""
    runtime.disable()
    reset_process_profiler()
    os.environ.pop("KERNEL_LAUNCHER_PROF", None)
    yield
    runtime.disable()
    reset_process_profiler()
    os.environ.pop("KERNEL_LAUNCHER_PROF", None)


def _matmul_profile(latency_us=100.0, baseline_us=None,
                    problem=(256, 256, 256), config=None):
    builder = get_kernel("matmul")
    config = config or builder.default_config()
    w = builder.make_workload(config, problem, "float32")
    return profile_from_workload(
        w, get_device("tpu-v5e"), "float32", latency_us, kernel="matmul",
        problem_size=problem, config=config, tier="exact",
        baseline_us=baseline_us)


# ------------------------- classification physics ----------------------------

def test_classify_bottleneck_ordering_and_ties():
    assert classify_bottleneck(2.0, 1.0) == "compute"
    assert classify_bottleneck(1.0, 2.0) == "memory"
    assert classify_bottleneck(0.0, 1.0, 3.0) == "collective"
    # ties resolve in declaration order: compute, then memory
    assert classify_bottleneck(1.0, 1.0) == "compute"
    assert classify_bottleneck(0.0, 1.0, 1.0) == "memory"


def test_small_matmul_is_memory_bound_serving_scale_is_compute_bound():
    dev = get_device("tpu-v5e")
    small = _matmul_profile()
    assert small.bottleneck == "memory"
    # no config in the space reaches the f32 ridge point at 256^3
    assert small.arithmetic_intensity < dev.flops_f32 / dev.hbm_bw

    big = _matmul_profile(
        problem=(8192, 8192, 8192),
        config={"block_m": 512, "block_n": 512, "block_k": 1024,
                "grid_order": "nmk", "dim_semantics": "parallel"})
    assert big.bottleneck == "compute"
    assert big.arithmetic_intensity > dev.flops_f32 / dev.hbm_bw


def test_advec_stencil_is_memory_bound():
    builder = get_kernel("advec_u")
    w = builder.make_workload(builder.default_config(), (64, 64, 128),
                              "float32")
    p = profile_from_workload(w, get_device("tpu-v5e"), "float32", 50.0,
                              kernel="advec_u")
    assert p.bottleneck == "memory"
    assert p.arithmetic_intensity < 16.0


def test_bf16_uses_bf16_peak():
    builder = get_kernel("matmul")
    cfg = builder.default_config()
    w = builder.make_workload(cfg, (256, 256, 256), "bfloat16")
    p = profile_from_workload(w, get_device("tpu-v5e"), "bfloat16", 100.0)
    w32 = builder.make_workload(cfg, (256, 256, 256), "float32")
    p32 = profile_from_workload(w32, get_device("tpu-v5e"), "float32", 100.0)
    assert p.compute_us == pytest.approx(p32.compute_us / 2, rel=1e-6)


# ------------------------------ round-trips ----------------------------------

def test_profile_json_roundtrip_and_version_refusal():
    p = _matmul_profile(baseline_us=80.0)
    d = p.to_json()
    assert d["version"] == PROFILE_VERSION
    back = KernelProfile.from_json(d)
    assert back.to_json() == d
    assert back.drift == pytest.approx(100.0 / 80.0, rel=1e-4)

    future = dict(d, version=PROFILE_VERSION + 1)
    with pytest.raises(ProfileVersionError):
        KernelProfile.from_json(future)


def test_baseline_omitted_when_absent():
    d = _matmul_profile().to_json()
    assert "baseline_us" not in d and "drift" not in d


def test_save_load_profiles_roundtrip(tmp_path):
    ps = [_matmul_profile(50.0), _matmul_profile(60.0, baseline_us=50.0)]
    path = save_profiles(tmp_path / "x.prof.json", ps)
    back = load_profiles(path)
    assert [p.to_json() for p in back] == [p.to_json() for p in ps]
    # byte-determinism of the document itself
    again = save_profiles(tmp_path / "y.prof.json", ps)
    assert path.read_bytes() == again.read_bytes()

    bad = {"version": 1, "profiles": [
        dict(ps[0].to_json(), version=PROFILE_VERSION + 7)]}
    (tmp_path / "bad.prof.json").write_text(json.dumps(bad))
    with pytest.raises(ProfileVersionError):
        load_profiles(tmp_path / "bad.prof.json")


# ------------------------------ drift ----------------------------------------

def test_drift_detection_threshold():
    slow = _matmul_profile(100.0, baseline_us=50.0)
    assert slow.drift == pytest.approx(2.0)
    assert slow.has_drift()
    ok = _matmul_profile(60.0, baseline_us=50.0)
    assert not ok.has_drift()          # 1.2x < default 1.5x
    assert ok.has_drift(threshold=1.1)
    assert not _matmul_profile(100.0).has_drift()   # no baseline, no drift


# ------------------------------ sampling -------------------------------------

def test_profiler_sampling_period():
    pr = Profiler(sample_every=4)
    hits = [pr.due("matmul") for _ in range(9)]
    assert hits == [True, False, False, False, True,
                    False, False, False, True]
    # independent streams sample independently
    assert pr.due("advec_u")


def test_profiler_bounds_retained_profiles():
    pr = Profiler(sample_every=1, max_profiles=4)
    for i in range(10):
        pr.record(_matmul_profile(float(i + 1)))
    assert len(pr.profiles) == 4
    assert pr.dropped > 0
    assert pr.profiles[-1].latency_us == 10.0


def test_profile_launch_guards_never_raise():
    pr = Profiler(sample_every=1)
    bare = KernelBuilder("bare")           # no workload hook
    assert pr.profile_launch(bare, {}, (8,), "float32", "tpu-v5e",
                             1.0) is None
    builder = get_kernel("matmul")
    # 96 % 64 != 0 -> the workload hook marks the config infeasible
    bad = dict(builder.default_config(), block_m=64)
    assert pr.profile_launch(builder, bad, (96, 96, 96), "float32",
                             "tpu-v5e", 1.0) is None
    assert pr.profiles == []


def test_prof_requested_env_parsing(monkeypatch):
    monkeypatch.delenv("KERNEL_LAUNCHER_PROF", raising=False)
    assert prof_requested() == 0
    for raw, want in [("0", 0), ("off", 0), ("false", 0),
                      ("1", DEFAULT_SAMPLE_EVERY),
                      ("true", DEFAULT_SAMPLE_EVERY),
                      ("4", 4), ("-3", 1),
                      ("garbage", DEFAULT_SAMPLE_EVERY)]:
        monkeypatch.setenv("KERNEL_LAUNCHER_PROF", raw)
        assert prof_requested() == want, raw


def test_process_profiler_lifecycle(monkeypatch):
    monkeypatch.delenv("KERNEL_LAUNCHER_PROF", raising=False)
    reset_process_profiler()
    assert process_profiler() is None
    monkeypatch.setenv("KERNEL_LAUNCHER_PROF", "8")
    reset_process_profiler()
    pr = process_profiler()
    assert pr is not None and pr.sample_every == 8
    assert process_profiler() is pr        # one shared instance


# ------------------------- telemetry fan-out ---------------------------------

def test_record_emits_metrics_and_counter_events():
    reg, tr = runtime.enable()
    pr = Profiler(sample_every=1)
    pr.record(_matmul_profile(100.0))
    pr.record(_matmul_profile(200.0, baseline_us=50.0))   # 4x drift
    assert pr.drift_events == 1
    snap = reg.snapshot()
    assert snap["counters"][
        "prof.launches{bottleneck=memory,kernel=matmul}"] == 2
    assert snap["counters"]["prof.drift{kernel=matmul}"] == 1
    doc = tr.to_chrome()
    assert validate_trace(doc) == []
    counters = [e for e in doc["traceEvents"] if e["ph"] == "C"]
    assert len(counters) == 2
    assert counters[0]["name"] == "prof.matmul"
    assert set(counters[0]["args"]) >= {"roofline_fraction",
                                        "arithmetic_intensity"}
    assert any(e["ph"] == "i" and e["name"] == "prof.drift"
               for e in doc["traceEvents"])


def test_validate_trace_counter_events():
    base = {"name": "c", "cat": "p", "ph": "C", "ts": 1.0,
            "pid": 1, "tid": 1}
    good = {**base, "args": {"frac": 0.5}}
    assert validate_trace({"traceEvents": [good]}) == []
    for bad_args in ({}, {"frac": "high"}, {"frac": True}):
        errors = validate_trace(
            {"traceEvents": [{**base, "args": bad_args}]})
        assert errors, bad_args
    tr = Tracer()
    with pytest.raises(ValueError):
        tr.counter("prof.matmul", frac="high")
    with pytest.raises(ValueError):
        tr.counter("prof.matmul")


# --------------------------- launch-path wiring ------------------------------

def test_wisdom_kernel_samples_launches_with_exact_baseline(tmp_path):
    builder = get_kernel("matmul")
    w = Wisdom("matmul")
    w.add(WisdomRecord(
        device_kind="tpu-v5e", device_family="tpu-v5",
        problem_size=(64, 64, 64), dtype="float32",
        config=builder.default_config(), score_us=12.0,
        provenance=make_provenance()))
    w.save(tmp_path)

    from repro.core import WisdomKernel
    k = WisdomKernel(get_kernel("matmul"), wisdom_dir=tmp_path,
                     device_kind="tpu-v5e", backend="reference")
    assert k.profiler is None              # detached by default
    pr = Profiler(sample_every=2)
    k.attach_profiler(pr)
    rng = np.random.default_rng(0)
    a = rng.standard_normal((64, 64)).astype(np.float32)
    b = rng.standard_normal((64, 64)).astype(np.float32)
    for _ in range(4):
        k(a, b)
    assert len(pr.profiles) == 2           # launches 0 and 2 sampled
    for p in pr.profiles:
        assert p.kernel == "matmul" and p.tier == "exact"
        assert p.baseline_us == 12.0       # the wisdom-recorded score
        assert p.problem_size == (64, 64, 64)


def test_wisdom_kernel_ambient_profiler_from_env(tmp_path, monkeypatch):
    monkeypatch.setenv("KERNEL_LAUNCHER_PROF", "2")
    reset_process_profiler()
    from repro.core import WisdomKernel
    k = WisdomKernel(get_kernel("matmul"), wisdom_dir=tmp_path,
                     device_kind="tpu-v5e", backend="reference")
    assert k.profiler is process_profiler()
    a = np.ones((64, 64), np.float32)
    k(a, a)
    assert len(k.profiler.profiles) == 1
    assert k.profiler.profiles[0].baseline_us is None   # default tier


def test_serve_engine_profiles_decode_steps():
    import jax
    import jax.numpy as jnp
    from repro.serve import Request, ServeEngine

    class Toy:
        vocab = 13

        def init_cache(self, n, m):
            return {"pos": jnp.zeros((), jnp.int32)}

        def decode_step(self, params, cache, tok):
            logits = jax.nn.one_hot((tok[:, 0] + 1) % self.vocab,
                                    self.vocab)[:, None]
            return logits, {"pos": cache["pos"] + 1}

    params = {"w": np.ones((64, 64), np.float32)}
    pr = Profiler(sample_every=2)
    eng = ServeEngine(Toy(), params=params, n_slots=2, max_seq=16,
                      profiler=StepProfiler(pr, device="tpu-v5e"))
    for rid in range(2):
        eng.submit(Request(rid, np.array([1, 2], np.int32),
                           max_new_tokens=3))
    rep = eng.run()
    assert rep.steps > 0 and pr.profiles
    first = pr.profiles[0]
    assert first.kernel == "serve.decode" and first.tier == "serve"
    assert first.bottleneck == "memory"    # params stream from HBM
    assert first.hbm_bytes == 64 * 64 * 4
    assert first.baseline_us is None       # first sample IS the baseline
    assert all(p.baseline_us == first.latency_us
               for p in pr.profiles[1:])
    # engines without a profiler (and no env) stay detached
    assert ServeEngine(Toy(), params={}).profiler is None


# ------------------------ datasets + guided tuning ---------------------------

def test_shipped_datasets_carry_profile_fields():
    from repro.tunebench import SpaceDataset
    ds = SpaceDataset.load(ADVEC_PATH)
    feas = ds.feasible()
    assert feas and all(e.profile.get("bottleneck") for e in feas)
    c = classify_dataset(ds)
    assert c["bottleneck"] == "memory"
    assert c["distribution"] == {"memory": len(feas)}

    big = classify_dataset(SpaceDataset.load(MATMUL_BIG))
    assert big["bottleneck"] == "compute"
    assert big["distribution"]["memory"] > big["distribution"]["compute"]


def test_dataset_profile_field_roundtrips():
    from repro.tunebench.dataset import SpaceEvaluation
    e = SpaceEvaluation(config={"block": 8}, score_us=1.5, status="ok",
                        profile={"bottleneck": "memory", "flops": 2.0})
    d = e.to_json()
    assert SpaceEvaluation.from_json(d).profile == e.profile
    bare = SpaceEvaluation(config={"block": 8}, score_us=1.5, status="ok")
    assert "profile" not in bare.to_json()   # byte-compat with old files


def test_evaluator_profiles_every_config():
    from repro.tuner.runner import CostModelEvaluator
    builder = get_kernel("matmul")
    ev = CostModelEvaluator(builder, (256, 256, 256), "float32",
                            "tpu-v5e", verify="none")
    res = ev(builder.default_config())
    prof = res.info["profile"]
    assert prof["bottleneck"] == "memory"
    assert prof["flops"] == 2.0 * 256 ** 3


def test_profile_feature_vector_tolerates_garbage():
    assert profile_feature_vector({}) == [0.0] * len(PROFILE_FEATURES)
    v = profile_feature_vector({"compute_us": "NaNsense", "grid": 0,
                                "arithmetic_intensity": 42.0})
    assert len(v) == len(PROFILE_FEATURES)
    assert v[0] == 0.0 and v[3] == pytest.approx(np.log1p(42.0))


def test_costmodel_accepts_profile_features():
    from repro.tunebench import SpaceDataset
    from repro.tuner.costmodel import fit_from_dataset
    ds = SpaceDataset.load(ADVEC_PATH)
    plain = fit_from_dataset(ds)
    model = fit_from_dataset(ds, profile_features=True)
    assert model.n_profile_features == len(PROFILE_FEATURES)
    assert model.profile_lookup
    cfg = ds.feasible()[0].config
    assert np.isfinite(model.predict(cfg))
    assert plain.profile_lookup is None


def test_surrogate_rerank_gate_holds_on_shipped_space():
    from repro.tunebench import SpaceDataset
    r = surrogate_rerank(SpaceDataset.load(ADVEC_PATH))
    names = [s["surrogate"] for s in r["surrogates"]]
    assert names == ["ridge", "profile"]
    for s in r["surrogates"]:
        assert all(0.0 < f <= 1.0 for f in s["fraction_at"].values())
    assert rerank_gate(r) == []            # profile never loses
    from repro.core.param import ConfigSpace
    tiny = SpaceDataset("k", ConfigSpace(), (1,), "float32", "tpu-v5e")
    with pytest.raises(ValueError):
        surrogate_rerank(tiny)             # too few feasible entries


# ------------------------------ reporting ------------------------------------

def test_render_attribution_is_deterministic():
    from repro.tunebench import SpaceDataset
    datasets = [SpaceDataset.load(ADVEC_PATH)]
    a = render_attribution(datasets, rerank=False)
    b = render_attribution(datasets, rerank=False)
    assert a == b
    assert "advec_u" in a and "memory-bound" in a


def test_summarize_and_render_profiles():
    ps = [_matmul_profile(100.0), _matmul_profile(300.0, baseline_us=100.0)]
    s = summarize(ps)
    assert s["matmul"]["launches"] == 2
    assert s["matmul"]["dominant"] == "memory"
    assert s["matmul"]["drifted"] == 1
    text = render_profiles(ps)
    assert "matmul: launches=2" in text and "drifted=1" in text
    assert render_profiles([]) == render_profiles([])


def test_health_report_renders_prof_and_sandbox_sections():
    from repro.obs import MetricsRegistry, render_report
    reg = MetricsRegistry()
    snap0 = reg.snapshot()
    assert "Profiler" not in render_report(snap0)   # sections are opt-in
    reg.counter("sandbox.verdict", status="ok").inc(3)
    reg.counter("oracle.checks", kernel="matmul", status="ok").inc(2)
    reg.counter("prof.launches", kernel="matmul",
                bottleneck="memory").inc(5)
    reg.counter("prof.drift", kernel="matmul").inc()
    text = render_report(reg.snapshot())
    assert "Sandbox & oracle" in text
    assert "sandbox verdicts: n=3 [ok=3]" in text
    assert "oracle matmul: [ok=2]" in text
    assert "Profiler (roofline bottlenecks)" in text
    assert "matmul: profiled=5 memory-bound [memory=5]" in text
    assert "drift-events=1" in text
    assert render_report(reg.snapshot()) == text


# ------------------------------ demo + CLI -----------------------------------

def test_demo_produces_valid_artifacts(tmp_path):
    from repro.prof.demo import run_demo
    art = run_demo(tmp_path / "d")
    assert art["n_profiles"] > 0 and art["drift_events"] >= 1
    profiles = load_profiles(art["profiles"])
    assert {p.kernel for p in profiles} >= {"matmul", "advec_u"}
    trace = json.loads(Path(art["trace"]).read_text())
    assert validate_trace(trace) == []
    assert any(e["ph"] == "C" for e in trace["traceEvents"])
    report = Path(art["report_path"]).read_text()
    assert "Launch profiles" in report
    assert "compute-bound" in report and "memory-bound" in report


def test_cli_report_is_byte_deterministic(tmp_path):
    from repro.prof.cli import main
    glob_arg = str(ADVEC_PATH)
    a, b = tmp_path / "a.txt", tmp_path / "b.txt"
    assert main(["report", "--datasets", glob_arg, "--no-rerank",
                 "--out", str(a)]) == 0
    assert main(["report", "--datasets", glob_arg, "--no-rerank",
                 "--out", str(b)]) == 0
    assert a.read_bytes() == b.read_bytes()
    assert "memory-bound" in a.read_text()


def test_cli_profile_and_diff(tmp_path):
    from repro.prof.cli import main
    out = tmp_path / "p.prof.json"
    assert main(["profile", "--kernel", "matmul",
                 "--problem", "256,256,256", "--out", str(out)]) == 0
    doc = json.loads(out.read_text())
    assert doc["bottleneck"] == "memory"
    # simulated latency is deterministic
    out2 = tmp_path / "q.prof.json"
    main(["profile", "--kernel", "matmul", "--problem", "256,256,256",
          "--out", str(out2)])
    assert out.read_text() == out2.read_text()

    ps = tmp_path / "s.prof.json"
    save_profiles(ps, [_matmul_profile(100.0)])
    assert main(["diff", str(ps), str(ps), "--check"]) == 0
    slow = tmp_path / "slow.prof.json"
    save_profiles(slow, [_matmul_profile(200.0)])
    assert main(["diff", str(ps), str(slow), "--check"]) == 1
