"""Per-arch smoke tests (reduced configs) + decode/forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.models import build_model

ARCHS = list_archs()


def make_batch(cfg, rng, B=2, S=32):
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.cross_attn_period:
        batch["img"] = jax.random.normal(
            rng, (B, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward + grad step, shapes + finiteness."""
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = make_batch(cfg, rng)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    assert float(metrics["tokens"]) == batch["tokens"].size
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    for path, g in jax.tree_util.tree_leaves_with_path(grads):
        assert np.all(np.isfinite(np.asarray(g, np.float32))), path


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_full_config_params(arch):
    """Full config instantiates abstractly with a plausible param count."""
    cfg = get_arch(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    claimed = {"hymba-1.5b": 1.5e9, "llama-3.2-vision-11b": 10.6e9,
               "deepseek-moe-16b": 16.4e9, "deepseek-v2-236b": 236e9,
               "gemma2-2b": 2.6e9, "h2o-danube-1.8b": 1.8e9,
               "codeqwen1.5-7b": 7.3e9, "stablelm-1.6b": 1.6e9,
               "rwkv6-7b": 7.6e9, "whisper-base": 72e6}[arch]
    assert 0.7 * claimed < n < 1.45 * claimed, \
        f"{arch}: {n/1e9:.2f}B vs claimed {claimed/1e9:.2f}B"
    # config's own analytic count should agree with the real tree
    assert abs(cfg.n_params() - n) / n < 0.06


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_matches_forward(arch):
    """Prefill logits (last position) == full-forward logits."""
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init(rng)
    B, S = 2, 16
    batch = make_batch(cfg, rng, B, S)
    tokens = batch["tokens"]
    if cfg.enc_dec:
        x, _ = model.forward(params, tokens, batch["frames"])
    else:
        x, _ = model.forward(params, tokens, img=batch.get("img"))
    full_logits = model._head(params, x[:, -1:])

    cache = model.init_cache(B, 64)
    if cfg.enc_dec:
        logits, cache = model.prefill(params, tokens, cache,
                                      batch["frames"])
    elif cfg.cross_attn_period:
        logits, cache = model.prefill(params, tokens, cache, batch["img"])
    else:
        logits, cache = model.prefill(params, tokens, cache)
    assert int(cache["pos"]) == S
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """decode_step after prefill == forward on the extended sequence."""
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(2)
    params = model.init(rng)
    B, S = 2, 12
    batch = make_batch(cfg, rng, B, S + 1)
    tokens = batch["tokens"]
    cache = model.init_cache(B, 64)
    extra = ()
    if cfg.enc_dec:
        extra = (batch["frames"],)
    elif cfg.cross_attn_period:
        extra = (batch["img"],)
    _, cache = model.prefill(params, tokens[:, :S], cache, *extra)
    dec_logits, cache = model.decode_step(params, cache, tokens[:, S:S + 1])

    if cfg.enc_dec:
        x, _ = model.forward(params, tokens, batch["frames"])
    else:
        x, _ = model.forward(params, tokens, img=batch.get("img"))
    fwd_logits = model._head(params, x[:, -1:])
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(fwd_logits),
                               rtol=5e-3, atol=5e-3)


def test_swa_window_masks_long_context():
    """SWA arch: tokens beyond the window cannot influence the output."""
    cfg = get_arch("h2o-danube-1.8b").reduced(windows=(4,) * 2)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    t1 = jax.random.randint(rng, (1, 16), 0, cfg.vocab)
    t2 = t1.at[:, 0:4].set((t1[:, 0:4] + 7) % cfg.vocab)  # outside window
    x1, _ = model.forward(params, t1)
    x2, _ = model.forward(params, t2)
    np.testing.assert_allclose(np.asarray(x1[:, -1]), np.asarray(x2[:, -1]),
                               rtol=1e-4, atol=1e-4)


def test_gemma2_softcap_bounds_logits():
    cfg = get_arch("gemma2-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # blow up the embedding scale to force big logits
    params["embed"] = params["embed"] * 100.0
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    x, _ = model.forward(params, tokens)
    logits = model._head(params, x)
    real = np.asarray(logits)[..., :cfg.vocab]
    assert np.all(np.abs(real) <= cfg.final_softcap + 1e-3)


def test_moe_aux_losses_present():
    cfg = get_arch("deepseek-moe-16b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = model.loss(params, batch)
    assert "moe_load_balance" in metrics
    assert float(metrics["moe_load_balance"]) > 0
    # perfectly balanced router would give ~1.0; early it should be near
    assert float(metrics["moe_load_balance"]) < 10.0


def test_rwkv_decode_is_constant_memory():
    """RWKV cache has no sequence dimension (O(1) long-context decode)."""
    cfg = get_arch("rwkv6-7b").reduced()
    model = build_model(cfg)
    c1 = jax.eval_shape(lambda: model.init_cache(2, 64))
    c2 = jax.eval_shape(lambda: model.init_cache(2, 1 << 16))
    sz = lambda c: sum(int(np.prod(l.shape)) for l in jax.tree.leaves(c))  # noqa: E731
    assert sz(c1) == sz(c2)
