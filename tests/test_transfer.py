"""Cross-device wisdom transfer: capability model, predictor, confidence
gating, selection tier ordering, and the fleet predict -> verify ->
promote loop (ISSUE 5).

Covers the acceptance criteria: ``select()`` returns transferred records
only when confidence clears the threshold and never lets them shadow an
exact-device measurement; the held-out-device benchmark reaches the
pinned fraction-of-optimum gate with a byte-deterministic report.
"""

import json

import pytest

from repro.core.builder import KernelBuilder
from repro.core.device import get_device
from repro.core.registry import register, unregister
from repro.core.wisdom import (TRANSFER_MIN_CONFIDENCE, Wisdom, WisdomRecord,
                               make_provenance, make_transfer_provenance)
from repro.core.workload import Workload
from repro.distrib import MemoryTransport, PullSync, WisdomStore, merge_wisdom
from repro.distrib.merge import better_record
from repro.fleet import (Coordinator, ControlBus, FleetWorker, ManualClock,
                         publish_latency)
from repro.online.tracker import MISS_TIERS, format_key
from repro.transfer import (DeviceModel, holdout_report, transfer_scenario,
                            transfer_store)
from repro.transfer.cli import main as transfer_cli
from repro.tunebench import DatasetStore, record_space

KERNEL = "transfertestk"


def _make_test_kernel() -> KernelBuilder:
    b = KernelBuilder(KERNEL, source="tests/test_transfer.py")
    b.tune("tile", (256, 512, 1024, 2048, 4608), default=256)
    b.tune("unroll", (1, 2, 4), default=1)

    @b.workload
    def _wl(config, problem, dtype):
        n = 1
        for d in problem:
            n *= int(d)
        tile = config["tile"]
        # tile=4608 -> 85MB working set: beyond the 4x spill grace on
        # tpu-v5e (16MB VMEM) but comfortably inside it on tpu-v4 (32MB)
        # — the feasibility asymmetry cross-device transfer must respect.
        return Workload(flops=2.0 * n * config["unroll"],
                        hbm_bytes=4.0 * n * (1 + 256 / tile),
                        vmem_bytes=tile * tile * 4,
                        grid=max(n // tile, 1), lane_extent=min(tile, 256),
                        unroll_ways=config["unroll"])

    return b


BUILDER = _make_test_kernel()
PROBLEM = (512, 512)
SCENARIO = ("tpu-v4", PROBLEM, "float32")


@pytest.fixture(autouse=True)
def _registered_kernel():
    register(BUILDER)
    yield
    unregister(KERNEL)


def _source_dataset(device="tpu-v5e"):
    return record_space(BUILDER, PROBLEM, "float32", device)


def measured(device="tpu-v4", family="tpu-v4", problem=PROBLEM,
             dtype="float32", score=100.0, config=None):
    return WisdomRecord(device_kind=device, device_family=family,
                        problem_size=tuple(problem), dtype=dtype,
                        config=config or {"tile": 256, "unroll": 1},
                        score_us=score,
                        provenance=make_provenance(strategy="bayes",
                                                   evals=20))


def transferred(device="tpu-v4", family="tpu-v4", problem=PROBLEM,
                dtype="float32", score=50.0, config=None,
                confidence=0.9):
    return WisdomRecord(device_kind=device, device_family=family,
                        problem_size=tuple(problem), dtype=dtype,
                        config=config or {"tile": 1024, "unroll": 2},
                        score_us=score,
                        provenance=make_transfer_provenance(
                            "tpu-v5e", 15, confidence, score))


# ----------------------------- capability model ------------------------------

def test_device_model_ratios_and_similarity():
    m = DeviceModel.between("tpu-v5e", "tpu-v4")
    assert m.vmem_ratio() == pytest.approx(2.0)
    assert m.compute_ratio("bfloat16") == pytest.approx(275e12 / 197e12)
    assert m.bandwidth_ratio() == pytest.approx(1228 / 819)
    # similarity: identical > sibling accelerator > different architecture
    same = DeviceModel.between("tpu-v5e", "tpu-v5e").similarity()
    sibling = m.similarity()
    alien = DeviceModel.between("tpu-v5e", "cpu").similarity()
    assert same == pytest.approx(1.0)
    assert 0.3 < sibling < 0.8
    assert alien < 0.01


# ------------------------------- predictor -----------------------------------

def test_transfer_is_deterministic_and_vmem_aware():
    ds = _source_dataset()
    r1 = transfer_scenario(ds, "tpu-v4")
    r2 = transfer_scenario(ds, "tpu-v4")
    assert json.dumps(r1.record().to_json(), sort_keys=True) == \
        json.dumps(r2.record().to_json(), sort_keys=True)
    assert r1.record().record_id() == r2.record().record_id()
    # tile=4096 is infeasible on the 16MB source, so it was never
    # recorded feasible — but nothing feasible on the source may be
    # predicted infeasible on the *larger* target either.
    assert r1.components["transferable"] == len(ds.feasible())


def test_transfer_reverse_direction_drops_target_infeasible_configs():
    """tpu-v4 -> tpu-v5e shrinks VMEM 2x: source-feasible big-tile
    configs that blow the target's spill grace must not be predicted."""
    ds = _source_dataset("tpu-v4")
    result = transfer_scenario(ds, "tpu-v5e")
    assert result.components["transferable"] < len(ds.feasible())
    for p in result.predictions:
        assert p.config["tile"] < 4608


def test_transfer_refuses_same_device_and_tiny_datasets():
    ds = _source_dataset()
    with pytest.raises(ValueError, match="already recorded"):
        transfer_scenario(ds, "tpu-v5e")
    tiny = record_space(BUILDER, PROBLEM, "float32", "tpu-v5e", limit=2)
    with pytest.raises(ValueError, match="at least 3"):
        transfer_scenario(tiny, "tpu-v4")


def test_confidence_gates_by_device_similarity():
    ds = _source_dataset()
    sibling = transfer_scenario(ds, "tpu-v4")
    alien = transfer_scenario(ds, "cpu")
    assert sibling.eligible()
    assert sibling.confidence >= TRANSFER_MIN_CONFIDENCE
    assert not alien.eligible()
    assert alien.confidence < TRANSFER_MIN_CONFIDENCE
    rec = sibling.record()
    assert rec.is_transferred()
    assert rec.transfer_confidence() == sibling.confidence
    assert rec.device_kind == "tpu-v4"
    assert rec.provenance["source_device"] == "tpu-v5e"
    assert rec.provenance["predicted_us"] == rec.score_us


def test_capability_only_transfer_gated_when_target_vmem_shrinks():
    """Without the workload hook there is no per-config feasibility
    check, so predictions into a *smaller* VMEM must not clear the
    serving gate (a source config sized for the bigger memory might not
    compile on the target); the growing-VMEM direction stays eligible,
    just penalized."""
    grow_ds = _source_dataset("tpu-v5e")     # recorded while registered
    shrink_ds = _source_dataset("tpu-v4")
    unregister(KERNEL)                       # registry lookup now fails
    try:
        grow = transfer_scenario(grow_ds, "tpu-v4")
        shrink = transfer_scenario(shrink_ds, "tpu-v5e")
    finally:
        register(BUILDER)
    assert grow.components["calibration"] == "capability"
    assert grow.eligible()                   # 2x more VMEM: safe to serve
    assert shrink.components["calibration"] == "capability"
    assert not shrink.eligible()             # half the VMEM: gated
    assert shrink.confidence < TRANSFER_MIN_CONFIDENCE


def test_transfer_store_discovers_and_skips_target(tmp_path):
    store = DatasetStore(tmp_path)
    store.save(_source_dataset("tpu-v5e"))
    store.save(_source_dataset("tpu-v4"))
    results = transfer_store(store, "tpu-v4")
    assert [r.source_device for r in results] == ["tpu-v5e"]
    assert results[0].target_device == "tpu-v4"


# --------------------------- selection tier ordering -------------------------

DEFAULT = {"tile": 256, "unroll": 1}


def test_transferred_never_shadows_exact_measurement():
    w = Wisdom(KERNEL)
    w.add(measured(score=100.0, config={"tile": 512, "unroll": 1}))
    w.add(transferred(score=1.0, confidence=0.99,
                      config={"tile": 1024, "unroll": 4}))
    cfg, tier = w.select("tpu-v4", PROBLEM, "float32", DEFAULT)
    assert (tier, cfg["tile"]) == ("exact", 512)


def test_transfer_tier_sits_between_exact_and_fallback():
    w = Wisdom(KERNEL)
    # fuzzy measured candidates on the same device, the family, and others
    w.add(measured(problem=(128, 128), config={"tile": 256, "unroll": 2}))
    w.add(measured(device="tpu-v5e", family="tpu-v5",
                   config={"tile": 256, "unroll": 4}))
    w.add(transferred(config={"tile": 1024, "unroll": 2}))
    cfg, tier = w.select("tpu-v4", PROBLEM, "float32", DEFAULT)
    assert (tier, cfg["tile"]) == ("transfer", 1024)
    # remove the transferred record: scenario-distance fallback returns
    cold = Wisdom(KERNEL, [r for r in w.records if not r.is_transferred()])
    cfg, tier = cold.select("tpu-v4", PROBLEM, "float32", DEFAULT)
    assert tier == "device+dtype"


def test_low_confidence_transfer_is_ignored():
    w = Wisdom(KERNEL)
    w.add(measured(problem=(128, 128), config={"tile": 512, "unroll": 1}))
    w.add(transferred(confidence=TRANSFER_MIN_CONFIDENCE - 0.01))
    cfg, tier = w.select("tpu-v4", PROBLEM, "float32", DEFAULT)
    assert tier == "device+dtype"
    # the gate is tunable per call
    cfg, tier = w.select("tpu-v4", PROBLEM, "float32", DEFAULT,
                         min_transfer_confidence=0.1)
    assert tier == "transfer"
    # only-ineligible-transfers wisdom falls through to the default
    only = Wisdom(KERNEL, [transferred(confidence=0.05)])
    cfg, tier = only.select("tpu-v4", PROBLEM, "float32", DEFAULT)
    assert (tier, cfg) == ("default", DEFAULT)


def test_transfer_tier_requires_device_and_dtype_match():
    w = Wisdom(KERNEL, [transferred(confidence=0.9)])
    _, tier = w.select("tpu-v5e", PROBLEM, "float32", DEFAULT)
    assert tier == "default"        # other device: prediction not for it
    _, tier = w.select("tpu-v4", PROBLEM, "bfloat16", DEFAULT)
    assert tier == "default"        # other dtype
    _, tier = w.select("tpu-v4", (64, 64), "float32", DEFAULT)
    assert tier == "transfer"       # same device+dtype, nearest problem


def test_transfer_tier_is_a_tracked_miss():
    assert "transfer" in MISS_TIERS


# ------------------------------ merge semantics ------------------------------

def test_measured_beats_transferred_in_merge_and_add():
    t = transferred(score=1.0, confidence=0.99)
    m = measured(score=500.0)
    assert better_record(t, m) is m
    assert better_record(m, t) is m
    w = Wisdom(KERNEL, [m])
    w.add(t)                        # keep_best: measurement survives
    assert len(w) == 1 and not w.records[0].is_transferred()
    # and the loser's provenance lands in the winner's lineage
    assert any(e.get("source") == "transfer"
               for e in w.records[0].lineage)
    # two transferred records compete on score as usual
    t2 = transferred(score=0.5, confidence=0.8,
                     config={"tile": 512, "unroll": 4})
    assert better_record(t, t2) is t2


def test_merge_wisdom_promotes_measurement_over_transfer():
    fleet = Wisdom(KERNEL, [transferred(score=10.0)])
    local = Wisdom(KERNEL, [measured(score=80.0)])
    merged = merge_wisdom(fleet, local)
    assert len(merged) == 1
    assert not merged.records[0].is_transferred()


# ------------------------- predict -> verify -> promote ----------------------

def _publish_transferred(transport, rec):
    transport.publish(KERNEL, Wisdom(KERNEL, [rec]).to_doc())


def test_coordinator_enqueues_verification_for_regressed_transfer():
    transport = MemoryTransport()
    bus = ControlBus(transport)
    rec = transferred(score=50.0)
    _publish_transferred(transport, rec)
    coord = Coordinator(bus, n_shards=2, min_misses=2)
    # within tolerance: no verification
    publish_latency(bus, "h1", {KERNEL: {format_key(SCENARIO): 55.0}})
    report = coord.tick()
    assert report.verify == [] and report.planned == []
    # regression: observed far above predicted -> job planned this tick
    publish_latency(bus, "h1", {KERNEL: {format_key(SCENARIO): 90.0}})
    report = coord.tick()
    assert report.verify == [format_key(SCENARIO)]
    assert len(report.planned) == 1


def test_verify_loop_promotes_measured_record_end_to_end():
    transport = MemoryTransport()
    bus = ControlBus(transport)
    _publish_transferred(transport, transferred(score=1.0))
    coord = Coordinator(bus, n_shards=2, min_misses=2,
                        max_evals_per_shard=50)
    publish_latency(bus, "h1", {KERNEL: {format_key(SCENARIO): 900.0}})
    assert len(coord.tick().planned) == 1
    FleetWorker(bus, "w0", clock=ManualClock()).drain()
    report = coord.tick()
    assert len(report.assembled) == 1
    records = [WisdomRecord.from_json(r)
               for r in transport.fetch(KERNEL)["records"]]
    mine = [r for r in records if r.scenario() == (SCENARIO[0], PROBLEM,
                                                   "float32")]
    assert len(mine) == 1
    assert not mine[0].is_transferred()          # promoted: measured won
    assert mine[0].provenance.get("source") == "fleet"
    # the prediction survives as lineage, and the loop is now quiet
    assert any(e.get("source") == "transfer" for e in mine[0].lineage)
    assert coord.tick().verify == []


# --------------------------- serve-path integration --------------------------

def test_pull_sync_picks_up_transferred_wisdom(tmp_path):
    transport = MemoryTransport()
    rec = transferred(score=5.0, confidence=0.9)
    _publish_transferred(transport, rec)
    local = WisdomStore(tmp_path / "local")
    PullSync(local, transport, interval=1).pull()
    wisdom = local.load(KERNEL)
    cfg, tier = wisdom.select("tpu-v4", PROBLEM, "float32", DEFAULT)
    assert tier == "transfer" and cfg == rec.config


# ----------------------------------- CLI -------------------------------------

def test_cli_predict_score_export(tmp_path, capsys):
    store = DatasetStore(tmp_path / "ds")
    store.save(_source_dataset("tpu-v5e"))
    store.save(_source_dataset("tpu-v4"))

    rc = transfer_cli(["predict", "--dataset-dir", str(tmp_path / "ds"),
                       "--target", "tpu-v4",
                       "--wisdom-dir", str(tmp_path / "w")])
    assert rc == 0
    out = capsys.readouterr().out
    assert "tpu-v5e -> tpu-v4" in out and "merged 1 transferred" in out
    wisdom = WisdomStore(tmp_path / "w").load(KERNEL)
    assert len(wisdom) == 1 and wisdom.records[0].is_transferred()

    src = store.path_for(KERNEL, "tpu-v5e", PROBLEM, "float32")
    truth = store.path_for(KERNEL, "tpu-v4", PROBLEM, "float32")
    rc = transfer_cli(["score", "--source", str(src), "--truth", str(truth),
                       "--json", "--check"])
    assert rc == 0
    report = json.loads(capsys.readouterr().out)
    assert report["transfer"]["fraction"] is not None
    assert report["transfer"]["tier"] == "transfer"

    out_path = tmp_path / "export.json"
    rc = transfer_cli(["export", "--dataset-dir", str(tmp_path / "ds"),
                       "--target", "tpu-v4", "--out", str(out_path)])
    assert rc == 0
    doc = json.loads(out_path.read_text())
    assert doc["kernel"] == KERNEL
    assert [r["provenance"]["source"] for r in doc["records"]] == ["transfer"]


def test_cli_predict_rejects_dissimilar_target(tmp_path, capsys):
    store = DatasetStore(tmp_path / "ds")
    store.save(_source_dataset("tpu-v5e"))
    rc = transfer_cli(["predict", "--dataset-dir", str(tmp_path / "ds"),
                       "--target", "cpu"])
    assert rc == 2                  # nothing eligible to serve
    assert "SKIP" in capsys.readouterr().out


# ------------------------- held-out benchmark (ISSUE 5) ----------------------

def test_holdout_report_deterministic_and_gated():
    src = _source_dataset("tpu-v4")
    truth = _source_dataset("tpu-v5e")
    r1 = holdout_report(src, truth)
    r2 = holdout_report(src, truth)
    assert json.dumps(r1, sort_keys=True) == json.dumps(r2, sort_keys=True)
    assert r1["transfer"]["tier"] == "transfer"
    assert r1["fallback"]["tier"] in ("device+dtype", "device",
                                      "family+dtype", "family",
                                      "any+dtype", "any")
    assert r1["transfer"]["fraction"] >= 0.8


def test_acceptance_benchmark_reaches_pinned_threshold():
    """ISSUE 5 acceptance: the shipped held-out-device benchmark passes
    its pinned >=0.80 fraction-of-optimum gate with transfer strictly
    ahead of the cold fallback, and the report is byte-deterministic
    (both asserted inside run())."""
    from benchmarks.transfer_portability import THRESHOLD, build_report, run

    rows = list(run())              # raises on any gate violation
    assert len(rows) > 1
    report = build_report()
    assert report["pass"] and THRESHOLD == 0.80
    for k in report["kernels"]:
        assert k["mean_transfer_fraction"] >= THRESHOLD
        assert k["mean_transfer_fraction"] > k["mean_fallback_fraction"]
