"""End-to-end training: loss decreases, microbatching is exact, crash ->
resume is bit-exact, serving engine generates."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.data import SyntheticTokenDataset
from repro.models import build_model
from repro.optim import AdamW, constant_schedule
from repro.runtime.driver import InjectedFault, TrainDriver
from repro.serve import Request, ServeEngine
from repro.train import init_train_state, make_train_step


def tiny_setup(seed=0, arch="stablelm-1.6b"):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg, remat=True)
    opt = AdamW(lr=constant_schedule(3e-3), weight_decay=0.0)
    ds = SyntheticTokenDataset(vocab=cfg.vocab, seq=64, global_batch=8,
                               seed=seed)
    return cfg, model, opt, ds


def test_loss_decreases():
    cfg, model, opt, ds = tiny_setup()
    step_fn = jax.jit(make_train_step(model, opt))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    losses = []
    for step in range(40):
        state, metrics = step_fn(state, ds.batch(step))
        losses.append(float(metrics["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.25, f"no learning: {first:.3f} -> {last:.3f}"


def test_microbatching_matches_full_batch():
    """Grad accumulation must give the same update as the full batch."""
    cfg, model, opt, ds = tiny_setup()
    state0 = init_train_state(model, opt, jax.random.PRNGKey(1))
    batch = ds.batch(0)
    s1, m1 = jax.jit(make_train_step(model, opt, microbatches=1))(
        jax.tree.map(jnp.copy, state0), batch)
    s4, m4 = jax.jit(make_train_step(model, opt, microbatches=4))(
        jax.tree.map(jnp.copy, state0), batch)
    for (p1, l1), (p4, l4) in zip(
            jax.tree_util.tree_leaves_with_path(s1["params"]),
            jax.tree_util.tree_leaves_with_path(s4["params"])):
        np.testing.assert_allclose(np.asarray(l1, np.float32),
                                   np.asarray(l4, np.float32),
                                   rtol=2e-3, atol=2e-4), p1


def test_crash_resume_bit_exact(tmp_path):
    """Kill training mid-run; the resumed run reaches the same final state
    as an uninterrupted run (deterministic data + checkpoint/restart)."""
    def build(dir_, fault=None):
        cfg, model, opt, ds = tiny_setup(seed=3)
        return TrainDriver(
            model=model, optimizer=opt,
            train_step=jax.jit(make_train_step(model, opt)),
            dataset=ds,
            ckpt=CheckpointManager(dir_, keep=3, save_every=5),
            total_steps=12, watchdog=__import__(
                "repro.runtime", fromlist=["x"]).StepWatchdog(),
            fault_injector=fault, log_every=100)

    # uninterrupted reference
    ref = build(tmp_path / "ref").run(jax.random.PRNGKey(42))

    # crashing run: dies at step 8 (after the step-5 checkpoint)
    def bomb(step):
        if step == 8:
            raise InjectedFault("simulated node failure")

    crash_dir = tmp_path / "crash"
    with pytest.raises(InjectedFault):
        build(crash_dir, fault=bomb).run(jax.random.PRNGKey(42))
    assert CheckpointManager(crash_dir).latest_step() == 5

    resumed = build(crash_dir).run(jax.random.PRNGKey(42))
    for a, b in zip(jax.tree.leaves(ref["state"]["params"]),
                    jax.tree.leaves(resumed["state"]["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serving_engine_generates():
    cfg, model, opt, ds = tiny_setup(arch="h2o-danube-1.8b")
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, n_slots=2, max_seq=64)
    rng = np.random.default_rng(0)
    for rid in range(4):  # 4 requests, 2 slots -> two cohorts
        eng.submit(Request(rid, rng.integers(0, cfg.vocab, size=5,
                                             dtype=np.int32),
                           max_new_tokens=4))
    out = eng.run()
    assert set(out) == {0, 1, 2, 3}
    assert all(len(toks) == 4 for toks in out.values())
    assert eng.batcher.done()


def test_serving_rejects_oversize():
    cfg, model, opt, ds = tiny_setup()
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, n_slots=1, max_seq=16)
    ok = eng.submit(Request(0, np.zeros(10, np.int32), max_new_tokens=10))
    assert not ok
    assert eng.batcher.rejected == [0]


def test_greedy_serving_matches_forward_argmax():
    """The served first token equals argmax of the parallel forward — the
    serving path is consistent with training-path logits."""
    cfg, model, opt, ds = tiny_setup(arch="gemma2-2b")
    params = model.init(jax.random.PRNGKey(5))
    prompt = np.asarray([3, 7, 11, 2], np.int32)
    eng = ServeEngine(model, params, n_slots=1, max_seq=32)
    eng.submit(Request(0, prompt, max_new_tokens=1))
    out = eng.run()
    x, _ = model.forward(params, jnp.asarray(prompt)[None])
    logits = model._head(params, x[:, -1:])
    want = int(np.argmax(np.asarray(logits)[0, 0]))
    assert out[0][0] == want
