"""Per-kernel correctness: shape/dtype sweeps, interpret-mode Pallas vs the
ref.py oracle (the assignment's per-kernel allclose requirement)."""

import numpy as np
import pytest

from repro.core import get_kernel
from repro.tuner.runner import verify_against_reference


def fields(rng, shape, dtype):
    return [rng.standard_normal(shape).astype(dtype) for _ in range(3)]


SCAL = np.array([[1.1, 0.9, 1.3, 0.0]], np.float32)


@pytest.mark.parametrize("shape", [(8, 8, 128), (16, 32, 128),
                                   (32, 16, 256)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_advec_u_shapes_dtypes(rng, shape, dtype):
    import jax.numpy as jnp
    b = get_kernel("advec_u")
    u, v, w = [np.asarray(jnp.asarray(f, dtype))
               for f in fields(rng, shape, np.float32)]
    # a tiling that fits every swept shape
    cfg = b.default_config() | {"block_z": 4, "block_y": 8}
    ok, msg = verify_against_reference(b, cfg, [u, v, w, SCAL])
    assert ok, msg


@pytest.mark.parametrize("config_update", [
    {"block_z": 8, "block_y": 16},
    {"block_z": 4, "block_y": 8, "traversal": "yz"},
    {"unroll_z": 2}, {"unroll_z": 4},
    {"dim_semantics": "parallel"},
])
def test_advec_u_config_sweep(rng, config_update):
    b = get_kernel("advec_u")
    cfg = b.default_config() | config_update
    u, v, w = fields(rng, (32, 32, 128), np.float32)
    ok, msg = verify_against_reference(b, cfg, [u, v, w, SCAL])
    assert ok, msg


@pytest.mark.parametrize("fuse", [True, False])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_diff_uvw(rng, fuse, dtype):
    import jax.numpy as jnp
    b = get_kernel("diff_uvw")
    u, v, w = [np.asarray(jnp.asarray(f, dtype))
               for f in fields(rng, (32, 32, 128), np.float32)]
    e = np.asarray(jnp.asarray(
        rng.standard_normal((32, 32, 128)) ** 2, dtype))
    cfg = b.default_config() | {"fuse_outputs": fuse}
    ok, msg = verify_against_reference(b, cfg, [u, v, w, e, SCAL])
    assert ok, msg


def test_diff_uvw_config_sweep(rng):
    b = get_kernel("diff_uvw")
    u, v, w = fields(rng, (32, 32, 128), np.float32)
    e = rng.standard_normal((32, 32, 128)).astype(np.float32) ** 2
    for cfg in b.space.sample(np.random.default_rng(3), 6):
        # block sizes must tile the 32x32 problem; skip invalid tilings
        if 32 % cfg["block_z"] or 32 % cfg["block_y"] or cfg["block_y"] > 32:
            continue
        ok, msg = verify_against_reference(b, cfg, [u, v, w, e, SCAL])
        assert ok, f"{cfg}: {msg}"


@pytest.mark.parametrize("mnk", [(128, 128, 256), (256, 512, 128),
                                 (64, 128, 1024)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_matmul_shapes_dtypes(rng, mnk, dtype):
    import jax.numpy as jnp
    m, n, k = mnk
    b = get_kernel("matmul")
    a = np.asarray(jnp.asarray(rng.standard_normal((m, k)), dtype))
    bb = np.asarray(jnp.asarray(rng.standard_normal((k, n)), dtype))
    ok, msg = verify_against_reference(b, b.default_config(), [a, bb])
    assert ok, msg


def test_matmul_grid_orders(rng):
    b = get_kernel("matmul")
    a = rng.standard_normal((256, 512)).astype(np.float32)
    bb = rng.standard_normal((512, 256)).astype(np.float32)
    for order in ("mnk", "nmk"):
        cfg = b.default_config() | {"grid_order": order, "block_m": 64,
                                    "block_n": 128, "block_k": 256}
        ok, msg = verify_against_reference(b, cfg, [a, bb])
        assert ok, f"{order}: {msg}"


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
def test_flash_attention_gqa(rng, causal, hq, hkv):
    name = "flash_attention_causal" if causal else "flash_attention_full"
    b = get_kernel(name)
    S, D = 256, 128
    q = rng.standard_normal((hq, S, D)).astype(np.float32)
    k = rng.standard_normal((hkv, S, D)).astype(np.float32)
    v = rng.standard_normal((hkv, S, D)).astype(np.float32)
    ok, msg = verify_against_reference(b, b.default_config(), [q, k, v])
    assert ok, msg


def test_flash_attention_block_sweep(rng):
    b = get_kernel("flash_attention_causal")
    q = rng.standard_normal((2, 512, 128)).astype(np.float32)
    k = rng.standard_normal((2, 512, 128)).astype(np.float32)
    v = rng.standard_normal((2, 512, 128)).astype(np.float32)
    for bq in (128, 256, 512):
        for bk in (128, 256):
            cfg = b.default_config() | {"block_q": bq, "block_k": bk}
            ok, msg = verify_against_reference(b, cfg, [q, k, v])
            assert ok, f"bq={bq} bk={bk}: {msg}"


def test_workloads_defined_for_all_kernels():
    from repro.core import all_kernels
    for name, b in all_kernels().items():
        cfg = b.default_config()
        problem = {"advec_u": (64, 64, 128), "diff_uvw": (64, 64, 128),
                   "matmul": (256, 256, 256),
                   "flash_attention_causal": (8, 2, 512, 128),
                   "flash_attention_full": (8, 2, 512, 128)}[name]
        w = b.make_workload(cfg, problem, "float32")
        assert w.flops > 0 and w.hbm_bytes > 0 and w.vmem_bytes > 0
