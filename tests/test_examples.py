"""ISSUE 4 satellite: every example runs headless, end to end.

Each example executes as a subprocess with a tmpdir working directory
(so relative output paths like capture/checkpoint dirs never touch the
repo) and CPU-only JAX. Examples with CLI knobs run at smoke scale;
the assertions check the banner lines the examples print on success,
not just the exit code.
"""

from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
EXAMPLES = REPO / "examples"

# Pre-existing state (a developer may legitimately have run the README
# quickstart from the repo root): the tests only assert that *they*
# created nothing new in the repo.
_PREEXISTING = {d: (REPO / d).exists() for d in ("captures", "checkpoints",
                                                 "wisdom", "datasets")}

#: example file -> (argv builder, string that must appear in stdout)
CASES = {
    "quickstart.py": (lambda tmp: [], "launch #2: tier=exact"),
    "tune_microhh.py": (lambda tmp: ["--max-evals", "20"],
                        "runtime selection"),
    "online_serving.py": (lambda tmp: [], "promoted after"),
    "serve_lm.py": (lambda tmp: ["--requests", "2", "--slots", "2",
                                 "--max-new", "4"], "tok/s"),
    "train_lm.py": (lambda tmp: ["--steps", "3", "--batch", "4",
                                 "--seq", "64",
                                 "--ckpt-dir", str(tmp / "ckpt")],
                    "final checkpoint"),
}


def test_every_example_is_covered():
    """A new example must get a smoke case (or consciously opt out)."""
    found = {p.name for p in EXAMPLES.glob("*.py")}
    assert found == set(CASES), (
        f"examples without a smoke case: {sorted(found - set(CASES))}; "
        f"stale cases: {sorted(set(CASES) - found)}")


@pytest.mark.parametrize("name", sorted(CASES))
def test_example_runs_headless(name, tmp_path):
    argv, needle = CASES[name]
    env = dict(os.environ,
               PYTHONPATH=str(REPO / "src"),
               JAX_PLATFORMS="cpu")
    # examples must not depend on ambient tuning state
    for var in ("KERNEL_LAUNCHER_CAPTURE", "KERNEL_LAUNCHER_CAPTURE_DIR",
                "KERNEL_LAUNCHER_WISDOM_DIR", "KERNEL_LAUNCHER_ONLINE"):
        env.pop(var, None)
    proc = subprocess.run(
        [sys.executable, str(EXAMPLES / name), *argv(tmp_path)],
        cwd=tmp_path, env=env, capture_output=True, text=True,
        timeout=600)
    assert proc.returncode == 0, (
        f"{name} failed:\n--- stdout ---\n{proc.stdout[-4000:]}\n"
        f"--- stderr ---\n{proc.stderr[-4000:]}")
    assert needle in proc.stdout, (
        f"{name} ran but did not print {needle!r}:\n{proc.stdout[-4000:]}")
    # headless means headless: nothing may escape into the repo
    escaped = [d for d, existed in _PREEXISTING.items()
               if not existed and (REPO / d).exists()]
    assert not escaped, f"{name} wrote into the repo: {escaped}"
