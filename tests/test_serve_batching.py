"""Continuous-batcher scheduling properties + CLI entry points."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.serve.batching import ContinuousBatcher


def test_admission_and_slot_lifecycle():
    b = ContinuousBatcher(n_slots=2, max_seq=64)
    for rid in range(3):
        assert b.submit(rid, prompt_len=4, max_new_tokens=4)
    admitted = b.admit()
    assert [a[0] for a in admitted] == [0, 1]       # two slots filled
    assert b.active_slots == 2
    assert b.admit() == []                           # queue waits
    for _ in range(4):
        b.step()
    assert b.active_slots == 0
    assert sorted(b.finished) == [0, 1]
    admitted = b.admit()                             # third request enters
    assert admitted[0][1] == 2
    assert not b.done()


def test_rejection_of_oversize():
    b = ContinuousBatcher(n_slots=1, max_seq=16)
    assert not b.submit(9, prompt_len=10, max_new_tokens=10)
    assert b.rejected == [9]
    assert b.done()


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_every_accepted_request_eventually_finishes(data):
    """Property: any mix of valid requests drains completely."""
    n_slots = data.draw(st.integers(1, 4))
    b = ContinuousBatcher(n_slots=n_slots, max_seq=32)
    n_req = data.draw(st.integers(1, 10))
    accepted = []
    for rid in range(n_req):
        plen = data.draw(st.integers(1, 20))
        mnew = data.draw(st.integers(1, 20))
        if b.submit(rid, plen, mnew):
            accepted.append(rid)
    for _ in range(10_000):
        if b.done():
            break
        b.admit()
        b.step()
    assert b.done()
    assert sorted(b.finished) == sorted(accepted)


def test_scenario_bucketed_fifo_admission():
    """Admission drains one scenario bucket before switching, and within
    a bucket it is strictly FIFO (ISSUE 9: co-scheduled slots share a
    tuned scenario so launches stay wisdom-exact)."""
    b = ContinuousBatcher(n_slots=2, max_seq=64)
    # interleaved submission across two scenarios
    b.submit(0, 4, 4, scenario="A")
    b.submit(1, 4, 4, scenario="B")
    b.submit(2, 4, 4, scenario="A")
    b.submit(3, 4, 4, scenario="B")
    first = [rid for _, rid, _ in b.admit()]
    assert first == [0, 2]              # bucket A drains first, in order
    for _ in range(8):
        b.step()
    second = [rid for _, rid, _ in b.admit()]
    assert second == [1, 3]             # then bucket B, in order
    assert b.scenario_switches == 1


def test_head_of_line_capacity_blocking():
    """A head request that does not fit the remaining arena blocks its
    bucket — later, smaller requests must not skip past it (skipping
    would starve long requests)."""
    b = ContinuousBatcher(n_slots=2, max_seq=32)
    b.submit(0, 16, 12, scenario="A")   # needs 28 columns
    b.submit(1, 2, 2, scenario="A")     # would fit anywhere
    assert b.admit(arena_pos=8) == []   # 8 + 28 > 32: head blocks bucket
    admitted = [rid for _, rid, _ in b.admit(arena_pos=0)]
    assert admitted == [0, 1]           # fresh arena: FIFO order intact


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_randomized_interleaving_conserves_requests(data):
    """Stress property: under any seeded interleaving of submit / admit /
    advance, every accepted request lives in exactly one of
    {queue, slot, finished}, no request is lost or duplicated, rejected
    requests never reach a slot, and per-scenario admission order equals
    submission order."""
    n_slots = data.draw(st.integers(1, 4))
    max_seq = data.draw(st.sampled_from([16, 32]))
    b = ContinuousBatcher(n_slots=n_slots, max_seq=max_seq)
    accepted, rejected, admitted_order = set(), set(), []
    submitted_order = {}                # scenario -> [rid, ...]
    next_rid = 0

    def check_invariants():
        queued = {q.request_id for q in b.queue}
        in_slots = {s.request_id for s in b.slots if s.active}
        finished = set(b.finished)
        assert len(b.finished) == len(finished)          # no duplicates
        assert queued | in_slots | finished == accepted  # none lost
        assert not (queued & in_slots) and not (queued & finished)
        assert not (in_slots & finished)                 # exactly one place
        assert not (rejected & (queued | in_slots | finished))
        assert sum(s.active for s in b.slots) + sum(
            not s.active for s in b.slots) == n_slots    # slots conserved

    for _ in range(data.draw(st.integers(5, 40))):
        op = data.draw(st.sampled_from(["submit", "admit", "advance"]))
        if op == "submit":
            plen = data.draw(st.integers(1, 20))
            mnew = data.draw(st.integers(1, 20))
            scen = data.draw(st.sampled_from(["A", "B", "C"]))
            if b.submit(next_rid, plen, mnew, scenario=scen):
                accepted.add(next_rid)
                submitted_order.setdefault(scen, []).append(next_rid)
            else:
                rejected.add(next_rid)
            next_rid += 1
        elif op == "admit":
            pos = data.draw(st.integers(0, max_seq - 1))
            for _slot, rid, _plen in b.admit(arena_pos=pos):
                admitted_order.append((b.slots[_slot].scenario, rid))
        else:
            active = [i for i, s in enumerate(b.slots) if s.active]
            if active:
                b.advance(data.draw(st.sampled_from(active)))
        check_invariants()

    # FIFO within each scenario bucket: the admitted rids of a scenario
    # are a prefix of that scenario's submission order.
    for scen, order in submitted_order.items():
        got = [rid for s, rid in admitted_order if s == scen]
        assert got == order[:len(got)]


class _StartAwareToyModel:
    """Decode-only toy with the token-mode contract: advertises
    ``decode_supports_start`` and tolerates ``cache["start"]``.
    Next token = (tok + 1) mod vocab, so outputs are deterministic."""

    vocab = 13
    decode_supports_start = True

    def init_cache(self, n_slots, max_seq):
        import jax.numpy as jnp
        return {"pos": jnp.zeros((), jnp.int32)}

    def decode_step(self, params, cache, tok):
        import jax
        import jax.numpy as jnp
        logits = jax.nn.one_hot((tok[:, 0] + 1) % self.vocab,
                                self.vocab)[:, None]
        return logits, {**cache, "pos": cache["pos"] + 1}


def test_mid_stream_admission_token_mode():
    """Token mode refills freed slots while other slots keep decoding:
    mixed-length traffic must report in-flight admissions, and every
    request still gets exactly ``max_new_tokens`` outputs."""
    from repro.serve import Request, ServeEngine
    eng = ServeEngine(_StartAwareToyModel(), params={}, n_slots=2,
                      max_seq=64)
    assert eng.mode == "token"          # auto picks token for this model
    lengths = {0: 2, 1: 9, 2: 3, 3: 5}  # short ones free mid-stream
    for rid, mnew in lengths.items():
        assert eng.submit(Request(rid, np.array([1, 2], np.int32),
                                  max_new_tokens=mnew,
                                  scenario="tpu-v5e|2x8|int32"))
    out = eng.run()
    assert out.mode == "token"
    assert eng.batcher.done()
    assert {rid: len(out[rid]) for rid in lengths} == lengths
    # greedy toy model: tokens continue the +1 sequence from prompt end
    assert out[0][:2] == [3, 4]
    # rids 2/3 were queued behind a still-running slot -> admitted
    # mid-stream, not at an arena boundary
    assert out.inflight_admissions >= 1
    assert 0.0 < out.occupancy <= 1.0
    assert out.cohorts == 1             # everything fits one arena


def test_cohort_mode_forced_on_token_capable_model():
    """mode="cohort" must override auto-detection — the fallback path
    stays reachable for A/B measurement (benchmarks/serve_throughput)."""
    from repro.serve import Request, ServeEngine
    eng = ServeEngine(_StartAwareToyModel(), params={}, n_slots=2,
                      max_seq=32, mode="cohort")
    assert eng.mode == "cohort"
    for rid in range(3):
        eng.submit(Request(rid, np.array([1], np.int32), max_new_tokens=2))
    out = eng.run()
    assert out.mode == "cohort"
    assert out.cohorts == 2 and out.inflight_admissions == 0
    assert {rid: len(out[rid]) for rid in range(3)} == {0: 2, 1: 2, 2: 2}


def test_tuner_cli_end_to_end(tmp_path, monkeypatch, capture_dir,
                              wisdom_dir, small_fields):
    """python -m repro.tuner.tune over a real capture directory."""
    from repro.core import CAPTURE_ENV, WisdomKernel, get_kernel
    from repro.tuner.tune import main

    u, v, w, _, scal = small_fields
    monkeypatch.setenv(CAPTURE_ENV, "advec_u")
    WisdomKernel(get_kernel("advec_u"), wisdom_dir=wisdom_dir,
                 device_kind="tpu-v5e", backend="reference")(u, v, w, scal)
    monkeypatch.delenv(CAPTURE_ENV)
    rc = main(["--captures", f"{capture_dir}/*.capture.json",
               "--strategy", "anneal", "--budget-evals", "30",
               "--budget-seconds", "30", "--device", "tpu-v5e",
               "--wisdom-dir", str(wisdom_dir)])
    assert rc == 0
    from repro.core import Wisdom
    assert len(Wisdom.load("advec_u", wisdom_dir)) >= 1


def test_tuner_cli_no_captures(tmp_path):
    from repro.tuner.tune import main
    assert main(["--captures", f"{tmp_path}/none/*.json"]) == 1


def test_shipped_wisdom_is_loadable_and_selected():
    """The repo's pre-tuned wisdom/ files drive selection out of the box."""
    from pathlib import Path
    from repro.core import Wisdom, WisdomKernel, get_kernel
    wdir = Path(__file__).resolve().parents[1] / "wisdom"
    if not wdir.exists():
        pytest.skip("wisdom/ not generated")
    w = Wisdom.load("matmul", wdir)
    assert len(w) >= 4
    k = WisdomKernel(get_kernel("matmul"), wisdom_dir=wdir,
                     device_kind="tpu-v5e")
    cfg, tier = k.select_config((4096, 4096, 4096), "bfloat16")
    assert tier == "exact"
    cfg2, tier2 = k.select_config((5000, 5000, 5000), "bfloat16")
    assert tier2 == "device+dtype"        # fuzzy match on the shipped data
