"""Continuous-batcher scheduling properties + CLI entry points."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.serve.batching import ContinuousBatcher


def test_admission_and_slot_lifecycle():
    b = ContinuousBatcher(n_slots=2, max_seq=64)
    for rid in range(3):
        assert b.submit(rid, prompt_len=4, max_new_tokens=4)
    admitted = b.admit()
    assert [a[0] for a in admitted] == [0, 1]       # two slots filled
    assert b.active_slots == 2
    assert b.admit() == []                           # queue waits
    for _ in range(4):
        b.step()
    assert b.active_slots == 0
    assert sorted(b.finished) == [0, 1]
    admitted = b.admit()                             # third request enters
    assert admitted[0][1] == 2
    assert not b.done()


def test_rejection_of_oversize():
    b = ContinuousBatcher(n_slots=1, max_seq=16)
    assert not b.submit(9, prompt_len=10, max_new_tokens=10)
    assert b.rejected == [9]
    assert b.done()


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_every_accepted_request_eventually_finishes(data):
    """Property: any mix of valid requests drains completely."""
    n_slots = data.draw(st.integers(1, 4))
    b = ContinuousBatcher(n_slots=n_slots, max_seq=32)
    n_req = data.draw(st.integers(1, 10))
    accepted = []
    for rid in range(n_req):
        plen = data.draw(st.integers(1, 20))
        mnew = data.draw(st.integers(1, 20))
        if b.submit(rid, plen, mnew):
            accepted.append(rid)
    for _ in range(10_000):
        if b.done():
            break
        b.admit()
        b.step()
    assert b.done()
    assert sorted(b.finished) == sorted(accepted)


def test_tuner_cli_end_to_end(tmp_path, monkeypatch, capture_dir,
                              wisdom_dir, small_fields):
    """python -m repro.tuner.tune over a real capture directory."""
    from repro.core import CAPTURE_ENV, WisdomKernel, get_kernel
    from repro.tuner.tune import main

    u, v, w, _, scal = small_fields
    monkeypatch.setenv(CAPTURE_ENV, "advec_u")
    WisdomKernel(get_kernel("advec_u"), wisdom_dir=wisdom_dir,
                 device_kind="tpu-v5e", backend="reference")(u, v, w, scal)
    monkeypatch.delenv(CAPTURE_ENV)
    rc = main(["--captures", f"{capture_dir}/*.capture.json",
               "--strategy", "anneal", "--budget-evals", "30",
               "--budget-seconds", "30", "--device", "tpu-v5e",
               "--wisdom-dir", str(wisdom_dir)])
    assert rc == 0
    from repro.core import Wisdom
    assert len(Wisdom.load("advec_u", wisdom_dir)) >= 1


def test_tuner_cli_no_captures(tmp_path):
    from repro.tuner.tune import main
    assert main(["--captures", f"{tmp_path}/none/*.json"]) == 1


def test_shipped_wisdom_is_loadable_and_selected():
    """The repo's pre-tuned wisdom/ files drive selection out of the box."""
    from pathlib import Path
    from repro.core import Wisdom, WisdomKernel, get_kernel
    wdir = Path(__file__).resolve().parents[1] / "wisdom"
    if not wdir.exists():
        pytest.skip("wisdom/ not generated")
    w = Wisdom.load("matmul", wdir)
    assert len(w) >= 4
    k = WisdomKernel(get_kernel("matmul"), wisdom_dir=wdir,
                     device_kind="tpu-v5e")
    cfg, tier = k.select_config((4096, 4096, 4096), "bfloat16")
    assert tier == "exact"
    cfg2, tier2 = k.select_config((5000, 5000, 5000), "bfloat16")
    assert tier2 == "device+dtype"        # fuzzy match on the shipped data
