"""Minimal, deterministic stand-in for the ``hypothesis`` package.

The test suite uses a small slice of hypothesis (``@given`` with keyword or
positional strategies, ``@settings(max_examples=..., deadline=...)``, and the
``integers`` / ``floats`` / ``booleans`` / ``sampled_from`` / ``lists`` /
``tuples`` / ``one_of`` / ``data`` strategies plus ``.map``/``.filter``).  When the real package is installed it is
used untouched; on a clean environment ``conftest.py`` installs this module
as ``sys.modules["hypothesis"]`` so collection and execution still work.

Unlike real hypothesis there is no shrinking and no adaptive generation:
each test simply runs ``max_examples`` times with examples drawn from a
seeded ``numpy`` generator, so failures reproduce exactly across runs.
"""

from __future__ import annotations

import hashlib
import types

import numpy as np

__version__ = "0.0-compat"

_DEFAULT_MAX_EXAMPLES = 100


def _seed(name: str, example_idx: int) -> int:
    h = hashlib.sha256(f"{name}:{example_idx}".encode()).digest()
    return int.from_bytes(h[:8], "little")


class SearchStrategy:
    def __init__(self, draw, label=""):
        self._draw = draw
        self._label = label

    def example(self, rng: np.random.Generator):
        return self._draw(rng)

    def map(self, fn):
        return SearchStrategy(lambda rng: fn(self._draw(rng)),
                              f"{self._label}.map")

    def filter(self, predicate):
        def draw(rng):
            for _ in range(1000):
                x = self._draw(rng)
                if predicate(x):
                    return x
            raise _Unsatisfied(f"filter on {self._label} found no example")

        return SearchStrategy(draw, f"{self._label}.filter")

    def __repr__(self):  # pragma: no cover
        return f"SearchStrategy({self._label})"


def integers(min_value, max_value):
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)),
        f"integers({min_value}, {max_value})")


def floats(min_value, max_value, **_):
    return SearchStrategy(
        lambda rng: float(rng.uniform(min_value, max_value)),
        f"floats({min_value}, {max_value})")


def booleans():
    return SearchStrategy(lambda rng: bool(rng.integers(2)), "booleans()")


def sampled_from(elements):
    elements = list(elements)
    return SearchStrategy(
        lambda rng: elements[int(rng.integers(len(elements)))],
        "sampled_from")


def tuples(*strategies):
    return SearchStrategy(
        lambda rng: tuple(s.example(rng) for s in strategies), "tuples")


def one_of(*strategies):
    opts = list(strategies)
    return SearchStrategy(
        lambda rng: opts[int(rng.integers(len(opts)))].example(rng),
        "one_of")


def lists(elements, min_size=0, max_size=10, unique=False):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        out, seen = [], set()
        tries = 0
        while len(out) < n and tries < 1000:
            x = elements.example(rng)
            tries += 1
            if unique:
                key = x if isinstance(x, (int, float, str, bool, tuple)) \
                    else repr(x)
                if key in seen:
                    continue
                seen.add(key)
            out.append(x)
        return out

    return SearchStrategy(draw, "lists")


class DataObject:
    """Interactive draw, as returned by the ``st.data()`` strategy."""

    def __init__(self, rng: np.random.Generator):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.example(self._rng)


def data():
    return SearchStrategy(lambda rng: DataObject(rng), "data()")


def given(*arg_strategies, **kw_strategies):
    def decorate(fn):
        def wrapper():
            n = getattr(wrapper, "_compat_max_examples",
                        getattr(fn, "_compat_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            name = f"{fn.__module__}.{fn.__qualname__}"
            for i in range(n):
                rng = np.random.default_rng(_seed(name, i))
                args = [s.example(rng) for s in arg_strategies]
                kwargs = {k: s.example(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, **kwargs)
                except Exception:
                    print(f"falsifying example ({name}, #{i}): "
                          f"args={args} kwargs={kwargs}")
                    raise

        # NOTE: deliberately no functools.wraps/__wrapped__ — pytest must see
        # a zero-argument signature, not the strategy parameters (it would
        # try to resolve them as fixtures).
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.is_hypothesis_test = True
        return wrapper

    return decorate


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    def decorate(fn):
        fn._compat_max_examples = max_examples
        return fn

    return decorate


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied("assumption not satisfied")
    return True


class _Unsatisfied(Exception):
    pass


class HealthCheck:  # pragma: no cover — accessed by name only
    all = classmethod(lambda cls: [])
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"


strategies = types.ModuleType("hypothesis.strategies")
for _name, _obj in (("integers", integers), ("floats", floats),
                    ("booleans", booleans), ("sampled_from", sampled_from),
                    ("tuples", tuples), ("lists", lists), ("data", data),
                    ("one_of", one_of),
                    ("SearchStrategy", SearchStrategy),
                    ("DataObject", DataObject)):
    setattr(strategies, _name, _obj)
