"""HLO walker + roofline report units."""

import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.configs import get_arch
from repro.roofline import model_flops, roofline_report
from repro.roofline.hlo_parse import (_nbytes, _numel, _shape_dims,
                                      _split_type_opcode, hlo_cost_analysis)


def test_shape_parsing():
    assert _numel("f32[2,3,4]{2,1,0}") == 24
    assert _nbytes("bf16[8,8]") == 128
    assert _nbytes("(f32[4], bf16[2,2])") == 24
    assert _shape_dims("pred[]") == [("pred", 1)]


def test_split_type_opcode_tuple_with_comments():
    rhs = ("(s32[], f32[512,512]{1,0}, /*index=5*/f32[4,4]{1,0}) "
           "while(%tuple), condition=%c, body=%b")
    t, oc, rest = _split_type_opcode(rhs)
    assert oc == "while"
    assert "condition=%c" in rest
    assert _nbytes(t) == 4 + 512 * 512 * 4 + 64


def test_trip_count_multiplication_nested():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def nested(a):
        def outer(c, _):
            c2 = lax.scan(lambda d, __: (d @ d, None), c, None, length=3)[0]
            return c2, None
        return lax.scan(outer, a, None, length=4)[0]

    r = hlo_cost_analysis(jax.jit(nested).lower(x).compile().as_text())
    expect = 12 * 2 * 64**3
    assert r["flops"] == pytest.approx(expect, rel=0.05)


def test_collectives_counted_with_trips():
    import os
    if jax.device_count() < 2:
        pytest.skip("needs >1 device for real collectives")


def test_roofline_report_terms():
    cfg = get_arch("gemma2-2b")
    rep = roofline_report(
        flops_per_chip=1.97e14, bytes_per_chip=8.19e11,
        collective_per_chip={"total": 5e10}, chips=256, cfg=cfg,
        kind="train", global_batch=256, seq=4096)
    assert rep["compute_s"] == pytest.approx(1.0)
    assert rep["memory_s"] == pytest.approx(1.0)
    assert rep["collective_s"] == pytest.approx(1.0)
    assert rep["model_flops"] == pytest.approx(
        6 * cfg.n_active_params() * 256 * 4096)
    assert 0 < rep["roofline_fraction"] < 1


def test_model_flops_moe_uses_active():
    dense = get_arch("codeqwen1.5-7b")
    moe = get_arch("deepseek-moe-16b")
    assert moe.n_active_params() < 0.3 * moe.n_params()
    assert dense.n_active_params() == dense.n_params()
    assert model_flops(moe, "train", 1, 1) == 6 * moe.n_active_params()
    assert model_flops(moe, "decode", 4, 999) == \
        2 * moe.n_active_params() * 4
