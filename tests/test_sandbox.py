"""Crash-isolated sandbox + correctness oracle (ISSUE 7).

Covers the acceptance criteria: every injected fault mode (hang, raise,
segfault, allocation bomb, wrong output) maps onto its structured
verdict without killing the parent process, and a fast-but-wrong config
is rejected by all three wisdom promotion paths — online hot-swap,
fleet shard-winner assembly, and transfer record minting.
"""

import pytest

from repro.core.registry import register, unregister
from repro.core.wisdom import Wisdom
from repro.core.wisdom_kernel import WisdomKernel
from repro.distrib import MemoryTransport
from repro.distrib.sync import transport_wisdom
from repro.fleet import ControlBus, Coordinator, TuningJob, job_id_for
from repro.fleet.jobs import lease_name
from repro.online.promotion import PromotionPipeline
from repro.sandbox import (FAULT_PARAM, CorrectnessOracle, FaultyEvaluator,
                           OracleGate, SandboxedEvaluator, SandboxSettings,
                           SandboxVerdict, clear_verdict_cache,
                           make_faulty_kernel, memory_ceiling,
                           sandboxed_call)
from repro.sandbox.demo import run_demo
from repro.transfer.predictor import TransferPrediction, TransferResult

WRONG = {"scale": 1, FAULT_PARAM: "wrong"}
HONEST = {"scale": 1, FAULT_PARAM: "none"}
PROBLEM = (8, 8)
DTYPE = "float32"
DEVICE = "tpu-v5e"


def _fork(timeout_s=10.0):
    """Fork settings with a generous default ceiling: forking a parent
    that a long test session has grown to multi-GB RSS costs real time
    (page-table copy), so only the hang tests — where hitting the
    ceiling IS the assertion — use a short timeout."""
    return SandboxSettings(timeout_s=timeout_s,
                           memory_bytes=memory_ceiling(128 * 2**20))


@pytest.fixture()
def faulty():
    b = make_faulty_kernel(hang_s=3600.0)
    register(b)
    clear_verdict_cache()
    yield b
    unregister(b.name)
    clear_verdict_cache()


# ------------------------------ the sandbox ----------------------------------

def test_sandboxed_call_returns_payload():
    verdict, out = sandboxed_call(lambda: 41 + 1, _fork())
    assert verdict.ok and verdict.status == "ok"
    assert out == 42
    assert verdict.wall_s >= 0.0


@pytest.mark.parametrize("mode,status", [
    ("none", "ok"),
    ("raise", "crash"),
    ("segv", "crash"),
    ("oom", "oom"),
    ("hang", "timeout"),
])
def test_fault_modes_map_to_verdicts(mode, status):
    ev = SandboxedEvaluator(FaultyEvaluator(hang_s=3600.0),
                            _fork(1.0 if mode == "hang" else 10.0))
    result = ev({"scale": 1, FAULT_PARAM: mode})
    _config, verdict = ev.verdicts[-1]
    assert verdict.status == status
    assert result.info["sandbox"] == status
    if mode == "none":
        assert result.feasible and result.score_us == pytest.approx(101.0)
    else:
        assert not result.feasible
        assert result.error.startswith(f"sandbox:{status}")
    if mode == "segv":
        assert verdict.exit_cause.startswith("signal:")
    if mode == "hang":
        assert verdict.exit_cause == "killed:timeout"


def test_hang_times_out_without_killing_parent():
    """Acceptance: an injected hang is killed at the wall-clock ceiling
    and the parent carries on evaluating."""
    ev = SandboxedEvaluator(FaultyEvaluator(hang_s=3600.0),
                            _fork(timeout_s=1.0))
    hung = ev({"scale": 1, FAULT_PARAM: "hang"})
    assert not hung.feasible and hung.info["sandbox"] == "timeout"
    assert hung.info["wall_s"] < 30.0
    # the parent is fine: the very next evaluation succeeds
    after = SandboxedEvaluator(FaultyEvaluator(hang_s=3600.0),
                               _fork())(HONEST)
    assert after.feasible


def test_inline_sandbox_maps_exceptions_to_verdicts():
    def boom():
        raise RuntimeError("nope")

    verdict, out = sandboxed_call(boom, SandboxSettings(method="inline"))
    assert verdict.status == "crash" and out is None
    assert "RuntimeError" in verdict.detail
    assert verdict.exit_cause == "exception:RuntimeError"

    def hungry():
        raise MemoryError

    verdict, _ = sandboxed_call(hungry, SandboxSettings(method="inline"))
    assert verdict.status == "oom"


def test_verdict_json_roundtrip():
    v = SandboxVerdict("numerics-mismatch", detail="allclose failed",
                       exit_cause="inline", wall_s=0.25,
                       max_err=0.3, rtol=1e-5, atol=1e-5)
    back = SandboxVerdict.from_json(v.to_json())
    assert back == v
    assert not v.ok
    with pytest.raises(ValueError):
        SandboxVerdict("not-a-status")


def test_sandboxed_evaluator_records_to_dataset(faulty):
    from repro.tunebench import SpaceDataset
    ds = SpaceDataset(faulty.name, faulty.space, PROBLEM, DTYPE, DEVICE)
    ev = SandboxedEvaluator(FaultyEvaluator(hang_s=3600.0), _fork(),
                            record_to=ds)
    ev(HONEST)
    ev({"scale": 1, FAULT_PARAM: "raise"})
    ok = ds.lookup(HONEST)
    bad = ds.lookup({"scale": 1, FAULT_PARAM: "raise"})
    assert ok.feasible and ok.verdict == ""         # "ok" is not stored
    assert not bad.feasible and bad.verdict == "crash"
    assert bad.error.startswith("sandbox:crash")
    # the verdict survives the JSON round trip, and plain entries keep
    # their original byte layout (no verdict key at all)
    again = SpaceDataset.from_doc(ds.to_doc())
    assert again.lookup(bad.config).verdict == "crash"
    assert "verdict" not in ok.to_json()


# ------------------------------ the oracle -----------------------------------

def test_oracle_classifies_wrong_output(faulty):
    oracle = CorrectnessOracle(faulty,
                               faulty.make_probe_args(PROBLEM, DTYPE))
    good = oracle.check(HONEST)
    assert good.ok and good.max_err is not None
    assert good.rtol == good.atol == 1e-5
    wrong = oracle.check(WRONG)
    assert wrong.status == "numerics-mismatch"
    assert wrong.max_err > 0.0
    # verdicts are cached per frozen config
    assert oracle.check(WRONG) is wrong


def test_gate_unverifiable_policy():
    gate = OracleGate()
    verdict = gate.check("no-such-kernel", {}, (4,), DTYPE)
    assert verdict.status == "unverifiable"
    assert gate.allows(verdict)                     # default: allow
    strict = OracleGate(on_unverifiable="reject")
    assert not strict.allows(verdict)
    with pytest.raises(ValueError):
        OracleGate(on_unverifiable="maybe")
    # unverifiable (and failing) verdicts never stamp provenance
    assert "verified" not in gate.stamp({}, "k", verdict)


def test_gate_stamps_and_caches_across_instances(faulty):
    gate = OracleGate()
    verdict = gate.check(faulty, HONEST, PROBLEM, DTYPE)
    assert verdict.ok
    stamped = gate.stamp({"strategy": "online"}, faulty.name, verdict)
    assert stamped["verified"] == {"rtol": 1e-5, "atol": 1e-5,
                                   "ref": f"{faulty.name}.reference"}
    assert stamped["strategy"] == "online"
    # the verdict cache is process-wide: a fresh gate answers from it
    # without ever building an oracle (no probe args materialized)
    other = OracleGate()
    assert other.check(faulty, HONEST, PROBLEM, DTYPE) is verdict
    assert other._oracles == {}


# --------------------- promotion paths reject wrong output -------------------

def test_online_promotion_rejects_wrong_winner(faulty, tmp_path):
    kernel = WisdomKernel(faulty, wisdom_dir=tmp_path,
                          device_kind=DEVICE)
    pipeline = PromotionPipeline(kernel, wisdom_dir=tmp_path)
    vetoed = pipeline.promote(DEVICE, PROBLEM, DTYPE, WRONG,
                              score_us=50.5, incumbent_score_us=200.0,
                              n_measurements=3, evals=16,
                              objective="costmodel")
    assert vetoed is None
    assert len(pipeline.rejections) == 1
    rejection = pipeline.rejections[0]
    assert rejection.verdict.status == "numerics-mismatch"
    assert rejection.config == WRONG
    # the wisdom file never saw the wrong config
    assert Wisdom.load(faulty.name, tmp_path).records == []

    promoted = pipeline.promote(DEVICE, PROBLEM, DTYPE, HONEST,
                                score_us=101.0, incumbent_score_us=200.0,
                                n_measurements=3, evals=16,
                                objective="costmodel")
    assert promoted is not None
    assert promoted.record.provenance["verified"]["ref"] == \
        f"{faulty.name}.reference"
    assert promoted.record.oracle_verified() is not None
    records = Wisdom.load(faulty.name, tmp_path).records
    assert [r.config[FAULT_PARAM] for r in records] == ["none"]


def test_fleet_assembly_rejects_wrong_shard_winner(faulty):
    bus = ControlBus(MemoryTransport())
    coord = Coordinator(bus, n_shards=2)
    key = (DEVICE, PROBLEM, DTYPE)
    job = TuningJob(job_id=job_id_for(faulty.name, key),
                    kernel=faulty.name, device_kind=DEVICE,
                    problem=PROBLEM, dtype=DTYPE, n_shards=2, misses=5)
    bus.publish("job", job.job_id, job.to_json())
    for shard, config, score in (("s000", WRONG, 50.5),
                                 ("s001", HONEST, 101.0)):
        bus.publish("result", lease_name(job.job_id, shard), {
            "job": job.job_id, "shard": shard, "worker": "t",
            "strategy": "exhaustive", "evals": 8, "feasible_evals": 8,
            "best_config": dict(config), "best_score_us": score})
    records = coord.assemble()
    # the wrong config won the cross-shard comparison but the oracle
    # vetoed it; the honest runner-up was assembled instead
    assert len(records) == 1
    assert records[0].config == HONEST
    assert records[0].provenance["verified"]["ref"] == \
        f"{faulty.name}.reference"
    done = bus.fetch("done", job.job_id)
    assert done["state"] == "assembled"
    assert [r["config"][FAULT_PARAM] for r in done["rejected"]] == ["wrong"]
    assert done["rejected"][0]["verdict"]["status"] == "numerics-mismatch"
    fleet = transport_wisdom(bus.transport, faulty.name).records
    assert [r.config[FAULT_PARAM] for r in fleet] == ["none"]


def test_transfer_record_falls_back_past_wrong_prediction(faulty):
    def pred(config, us):
        return TransferPrediction(config=dict(config), source_us=us,
                                  smoothed_us=us, rank_us=us,
                                  predicted_us=us)

    result = TransferResult(
        kernel=faulty.name, source_device="tpu-v4", target_device=DEVICE,
        problem_size=PROBLEM, dtype=DTYPE,
        predictions=[pred(WRONG, 50.5), pred(HONEST, 101.0)],
        confidence=0.9, components={"entries": 2,
                                    "calibration": "workload"})
    gate = OracleGate()
    record = result.record(gate=gate)
    assert record.config == HONEST
    assert record.score_us == pytest.approx(101.0)
    assert record.provenance["verified"]["ref"] == \
        f"{faulty.name}.reference"
    # ungated minting still returns the (wrong) top prediction — the
    # gate is what protects the serving path
    assert result.record().config == WRONG
    # a result whose every prediction fails verification refuses to mint
    all_wrong = TransferResult(
        kernel=faulty.name, source_device="tpu-v4", target_device=DEVICE,
        problem_size=PROBLEM, dtype=DTYPE,
        predictions=[pred(WRONG, 50.5)],
        confidence=0.9, components={"entries": 1,
                                    "calibration": "workload"})
    with pytest.raises(ValueError, match="correctness oracle"):
        all_wrong.record(gate=gate)


def test_demo_gauntlet_passes():
    """The CI smoke in-process: inject every fault, run all three
    promotion paths, demand zero bad promotions."""
    report = run_demo(timeout_s=5.0)
    assert report["problems"] == []
    assert report["bad_promotions"] == 0
    assert report["pass"] is True
    assert report["sandbox"]["hang"]["status"] == "timeout"
    assert report["sandbox"]["segv"]["exit_cause"].startswith("signal:")
    assert report["oracle"]["wrong"]["status"] == "numerics-mismatch"
