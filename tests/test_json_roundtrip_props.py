"""Property-based round-trip guarantees for the on-disk JSON schemas
(ISSUE 5 satellite, extended with kernel profiles in ISSUE 8): arbitrary
*valid* wisdom records, dataset entries, and kernel profiles must
survive their migrations plus a full serialize -> deserialize ->
serialize cycle byte-identically. Runs under real ``hypothesis`` when
installed, else the deterministic compat shim
(``tests/_hypothesis_compat.py``)."""

import json

from hypothesis import given, settings, strategies as st

from repro.core.param import ConfigSpace
from repro.core.wisdom import (WISDOM_VERSION, Wisdom, WisdomRecord,
                               migrate_doc)
from repro.tunebench import SpaceDataset, migrate_dataset_doc

DEVICES = [("tpu-v5e", "tpu-v5"), ("tpu-v4", "tpu-v4"), ("gpu-x", "gpu-x"),
           ("cpu", "cpu")]
DTYPES = ["float32", "bfloat16", "float16"]
KEYS = ["block_m", "block_n", "unroll", "order", "semantics"]


def canon(doc: dict) -> str:
    return json.dumps(doc, sort_keys=True)


# ------------------------------ wisdom records -------------------------------

def record_strategy_draw(data) -> WisdomRecord:
    device, family = data.draw(st.sampled_from(DEVICES))
    problem = tuple(data.draw(
        st.lists(st.integers(1, 8192), min_size=1, max_size=4)))
    n_cfg = data.draw(st.integers(1, 4))
    config = {KEYS[i]: data.draw(st.integers(1, 512)) for i in range(n_cfg)}
    prov_keys = data.draw(st.lists(st.sampled_from(
        ["strategy", "host", "user", "note", "objective"]),
        min_size=0, max_size=3, unique=True))
    provenance = {k: f"v-{data.draw(st.integers(0, 99))}" for k in prov_keys}
    provenance["evaluations"] = data.draw(st.integers(0, 10_000))
    lineage = [{"host": f"h{data.draw(st.integers(0, 9))}",
                "date": f"2026-0{data.draw(st.integers(1, 7))}-01"}
               for _ in range(data.draw(st.integers(0, 3)))]
    return WisdomRecord(
        device_kind=device, device_family=family, problem_size=problem,
        dtype=data.draw(st.sampled_from(DTYPES)), config=config,
        score_us=data.draw(st.floats(1e-3, 1e9)),
        provenance=provenance, lineage=lineage)


@settings(max_examples=50, deadline=None)
@given(st.data())
def test_wisdom_doc_roundtrips_byte_identically(data):
    n = data.draw(st.integers(0, 5))
    records = [record_strategy_draw(data) for _ in range(n)]
    w = Wisdom("propk")
    for r in records:
        w.add(r, keep_best=False)
    doc = w.to_doc()
    assert doc["version"] == WISDOM_VERSION

    # migrating a current-version document is a byte-exact no-op
    assert canon(migrate_doc(doc)) == canon(doc)

    # full JSON cycle: dump -> load -> from_json -> to_doc, byte-identical
    wire = json.loads(json.dumps(doc))
    back = Wisdom("propk", [WisdomRecord.from_json(r)
                            for r in wire["records"]])
    assert canon(back.to_doc()) == canon(doc)

    # identity is stable across the cycle too
    assert [r.record_id() for r in back.records] == \
        [r.record_id() for r in w.records]


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_v1_wisdom_doc_migration_is_stable(data):
    """A v1 document (no lineage, no version) migrates to the current
    schema; migrating the migrated document changes nothing further."""
    n = data.draw(st.integers(0, 4))
    records = []
    for _ in range(n):
        r = record_strategy_draw(data)
        d = r.to_json()
        del d["lineage"]
        records.append(d)
    v1 = {"kernel": "propk", "records": records}
    once = migrate_doc(v1)
    assert once["version"] == WISDOM_VERSION
    assert all(rec["lineage"] == [] for rec in once["records"])
    assert canon(migrate_doc(once)) == canon(once)
    # and the original input was not mutated
    assert "version" not in v1
    assert all("lineage" not in rec for rec in v1["records"])


# ------------------------------ dataset entries ------------------------------

def dataset_strategy_draw(data) -> SpaceDataset:
    space = ConfigSpace()
    n_params = data.draw(st.integers(1, 3))
    for i in range(n_params):
        values = sorted(data.draw(st.lists(st.integers(1, 64), min_size=1,
                                           max_size=4, unique=True)))
        space.tune(KEYS[i], values, values[0])
    device, _family = data.draw(st.sampled_from(DEVICES))
    problem = tuple(data.draw(
        st.lists(st.integers(1, 1024), min_size=1, max_size=3)))
    ds = SpaceDataset("propk", space, problem,
                      data.draw(st.sampled_from(DTYPES)), device)
    n_entries = data.draw(st.integers(0, 6))
    for _ in range(n_entries):
        config = {name: data.draw(st.sampled_from(list(p.values)))
                  for name, p in space.params.items()}
        if data.draw(st.booleans()):
            ds.add(config, data.draw(st.floats(1e-3, 1e9)), "ok")
        else:
            ds.add(config, float("inf"), "infeasible", error="vmem")
    return ds


@settings(max_examples=50, deadline=None)
@given(st.data())
def test_dataset_doc_roundtrips_byte_identically(data):
    ds = dataset_strategy_draw(data)
    doc = ds.to_doc()

    # migrating a current-version document is a byte-exact no-op
    assert canon(migrate_dataset_doc(doc)) == canon(doc)

    # full JSON cycle through the wire format
    wire = json.loads(json.dumps(doc))
    back = SpaceDataset.from_doc(wire)
    assert canon(back.to_doc()) == canon(doc)

    # queries agree after the cycle (keys, optimum, feasibility split)
    assert sorted(back.evaluations) == sorted(ds.evaluations)
    b1, b2 = ds.best(), back.best()
    assert (b1 is None) == (b2 is None)
    if b1 is not None:
        assert b1.config == b2.config and b1.score_us == b2.score_us


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_versionless_dataset_doc_migration_is_stable(data):
    ds = dataset_strategy_draw(data)
    doc = ds.to_doc()
    del doc["version"]
    once = migrate_dataset_doc(doc)
    assert once["version"] == 1
    assert canon(migrate_dataset_doc(once)) == canon(once)
    assert "version" not in doc        # input not mutated


# ------------------------------ kernel profiles ------------------------------

def profile_strategy_draw(data) -> "KernelProfile":
    from repro.core.workload import Workload
    from repro.prof import profile_from_workload
    from repro.core.device import DEVICES as DEVICE_SPECS

    device = data.draw(st.sampled_from(sorted(DEVICE_SPECS)))
    w = Workload(
        flops=data.draw(st.floats(1.0, 1e15)),
        hbm_bytes=data.draw(st.floats(1.0, 1e12)),
        vmem_bytes=data.draw(st.integers(0, 64 * 2**20)),
        grid=data.draw(st.integers(1, 1 << 20)))
    n_cfg = data.draw(st.integers(0, 3))
    config = {KEYS[i]: data.draw(st.integers(1, 512)) for i in range(n_cfg)}
    baseline = (data.draw(st.floats(1e-3, 1e6))
                if data.draw(st.booleans()) else None)
    return profile_from_workload(
        w, DEVICE_SPECS[device], data.draw(st.sampled_from(DTYPES)),
        data.draw(st.floats(1e-3, 1e7)),
        kernel=data.draw(st.sampled_from(["matmul", "advec_u", "k"])),
        problem_size=tuple(data.draw(st.lists(st.integers(1, 8192),
                                              min_size=0, max_size=4))),
        config=config,
        tier=data.draw(st.sampled_from(["", "exact", "trial", "serve"])),
        collective_bytes=data.draw(st.floats(0.0, 1e12)),
        baseline_us=baseline)


@settings(max_examples=50, deadline=None)
@given(st.data())
def test_kernel_profile_roundtrips_byte_identically(data):
    """Arbitrary valid profiles survive a full serialize -> deserialize ->
    serialize cycle byte-identically, classification and drift stay
    stable, and future schema versions are refused (ISSUE 8 satellite)."""
    from repro.prof import (BOTTLENECKS, PROFILE_VERSION, KernelProfile,
                            ProfileVersionError)

    p = profile_strategy_draw(data)
    assert p.bottleneck in BOTTLENECKS
    doc = p.to_json()
    assert doc["version"] == PROFILE_VERSION
    assert ("baseline_us" in doc) == (p.baseline_us is not None)

    wire = json.loads(json.dumps(doc))
    back = KernelProfile.from_json(wire)
    assert canon(back.to_json()) == canon(doc)
    assert back.bottleneck == p.bottleneck
    assert back.has_drift() == KernelProfile.from_json(doc).has_drift()

    future = dict(doc, version=PROFILE_VERSION + 1)
    try:
        KernelProfile.from_json(future)
        raise AssertionError("future profile version accepted")
    except ProfileVersionError:
        pass
