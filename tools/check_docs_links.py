#!/usr/bin/env python
"""Fail on broken intra-repo links in README.md and docs/*.md.

Checks every markdown link whose target is a repo-relative path (http(s)
and mailto links are skipped; #anchors are stripped) and exits non-zero
listing each target that does not exist. Run from anywhere:

    python tools/check_docs_links.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def doc_files(root: Path) -> list[Path]:
    files = [root / "README.md"]
    files += sorted((root / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check(root: Path) -> list[str]:
    errors = []
    for md in doc_files(root):
        for n, line in enumerate(md.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(SKIP_PREFIXES):
                    continue
                path = target.split("#", 1)[0]
                if not path:
                    continue
                resolved = (md.parent / path).resolve()
                if not resolved.exists():
                    errors.append(f"{md.relative_to(root)}:{n}: "
                                  f"broken link -> {target}")
    return errors


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    errors = check(root)
    for e in errors:
        print(e)
    n_files = len(doc_files(root))
    print(f"checked {n_files} file(s): "
          f"{'OK' if not errors else f'{len(errors)} broken link(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
