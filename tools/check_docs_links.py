#!/usr/bin/env python
"""Fail on broken intra-repo links and on orphaned docs pages.

Two checks over README.md and docs/*.md:

1. **Broken links** — every markdown link whose target is a repo-relative
   path must exist (http(s) and mailto links are skipped; #anchors are
   stripped).
2. **Reachability** — every page under docs/ must be reachable by
   following intra-repo markdown links from README.md or
   docs/architecture.md (the two entry points readers actually start
   from). A docs page nobody links to is dead documentation: it silently
   rots because no reader path leads to it.

Run from anywhere:

    python tools/check_docs_links.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")

#: Reachability roots: the places a reader enters the docs tree.
ENTRY_POINTS = ("README.md", "docs/architecture.md")


def doc_files(root: Path) -> list[Path]:
    files = [root / "README.md"]
    files += sorted((root / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def md_targets(md: Path) -> list[tuple[int, str, Path]]:
    """(line, raw target, resolved path) for each repo-relative link."""
    out = []
    for n, line in enumerate(md.read_text().splitlines(), 1):
        for target in LINK_RE.findall(line):
            if target.startswith(SKIP_PREFIXES):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            out.append((n, target, (md.parent / path).resolve()))
    return out


def check_links(root: Path) -> list[str]:
    errors = []
    for md in doc_files(root):
        for n, target, resolved in md_targets(md):
            if not resolved.exists():
                errors.append(f"{md.relative_to(root)}:{n}: "
                              f"broken link -> {target}")
    return errors


def check_reachability(root: Path) -> list[str]:
    """Docs pages not linked (transitively) from any entry point."""
    queue = [(root / p).resolve() for p in ENTRY_POINTS
             if (root / p).exists()]
    seen: set[Path] = set(queue)
    while queue:
        md = queue.pop()
        for _, _, resolved in md_targets(md):
            if (resolved.suffix == ".md" and resolved.exists()
                    and resolved not in seen):
                seen.add(resolved)
                queue.append(resolved)
    errors = []
    for md in doc_files(root):
        if md.resolve() not in seen:
            errors.append(
                f"{md.relative_to(root)}: not reachable from "
                f"{' or '.join(ENTRY_POINTS)} — link it from the "
                f"architecture page or the README")
    return errors


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    errors = check_links(root) + check_reachability(root)
    for e in errors:
        print(e)
    n_files = len(doc_files(root))
    print(f"checked {n_files} file(s): "
          f"{'OK' if not errors else f'{len(errors)} problem(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
