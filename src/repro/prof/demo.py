"""End-to-end profiler demo — the ``prof-smoke`` CI job's workload.

Enables observability, attaches a :class:`~repro.prof.Profiler` to real
:class:`~repro.core.WisdomKernel` launches (matmul + the advec_u
stencil, reference backend so it runs on any host), injects one
artificially slow launch so drift detection fires, and writes every
artifact the profiler can produce: the profile document, a Chrome trace
with counter events, a metrics snapshot, and the attribution report
over the shipped recorded spaces.
"""

from __future__ import annotations

from pathlib import Path

import numpy as np

from repro.obs import runtime as obs
from repro.obs.metrics import save_snapshot
from repro.obs.trace import validate_trace

from .profiler import Profiler, save_profiles
from .report import render_attribution, render_profiles


def run_demo(out_dir: str | Path = "prof-demo",
             dataset_glob: str = "benchmarks/datasets/*.space.json") -> dict:
    """Run the instrumented profiler demo; returns artifact paths plus
    the rendered report text.

    Example::

        art = run_demo("/tmp/prof-demo")
        print(art["report"])
    """
    import glob as _glob

    from repro.core.registry import get_kernel
    from repro.core.wisdom_kernel import WisdomKernel
    from repro.tunebench.dataset import SpaceDataset

    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    registry, tracer = obs.enable()
    profiler = Profiler(sample_every=2)

    rng = np.random.default_rng(0)
    mm = WisdomKernel(get_kernel("matmul"), wisdom_dir=out / "wisdom",
                      device_kind="tpu-v5e", backend="reference")
    mm.attach_profiler(profiler)
    a = rng.standard_normal((64, 64)).astype(np.float32)
    b = rng.standard_normal((64, 64)).astype(np.float32)
    for _ in range(6):
        mm(a, b)

    adv = WisdomKernel(get_kernel("advec_u"), wisdom_dir=out / "wisdom",
                       device_kind="tpu-v5e", backend="reference")
    adv.attach_profiler(profiler)
    u = rng.standard_normal((32, 32, 32)).astype(np.float32)
    v = rng.standard_normal((32, 32, 32)).astype(np.float32)
    w = rng.standard_normal((32, 32, 32)).astype(np.float32)
    for _ in range(4):
        adv(u, u, v, w)

    # Drift injection: replay the slowest sampled matmul launch at 10x
    # its latency against the fastest as baseline — the drift path
    # (metric + instant event) must light up in the artifacts.
    samples = [p for p in profiler.profiles if p.kernel == "matmul"]
    if samples:
        base = min(p.latency_us for p in samples)
        slow = samples[-1]
        profiler.record(type(slow)(**{
            **slow.__dict__, "latency_us": base * 10,
            "baseline_us": base, "drift": 10.0}))

    prof_path = save_profiles(out / "profiles.prof.json",
                              profiler.profiles)
    trace_path = tracer.save(out / "trace.json")
    errors = validate_trace(tracer.to_chrome())
    if errors:
        raise AssertionError(f"demo trace invalid: {errors[:3]}")
    snap_path = save_snapshot(registry.snapshot(), out / "snapshot.json")

    datasets = [SpaceDataset.load(p)
                for p in sorted(_glob.glob(dataset_glob))]
    report = (render_profiles(profiler.profiles)
              + "\n" + render_attribution(datasets))
    report_path = out / "report.txt"
    report_path.write_text(report)
    return {
        "profiles": str(prof_path),
        "trace": str(trace_path),
        "snapshot": str(snap_path),
        "report_path": str(report_path),
        "report": report,
        "n_profiles": len(profiler.profiles),
        "drift_events": profiler.drift_events,
    }
