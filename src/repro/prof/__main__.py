"""Entry point for ``python -m repro.prof``."""

from .cli import main

raise SystemExit(main())
