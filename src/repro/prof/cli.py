"""``python -m repro.prof`` — profile / report / roofline / diff / demo.

Operator entry points over kernel profiles:

* ``profile``  — profile one kernel scenario: join the config's workload
  with a (simulated or supplied) latency and print the versioned
  :class:`KernelProfile` JSON;
* ``report``   — render the bottleneck-attribution report from recorded
  tuning-space datasets and/or saved profile documents
  (byte-deterministic — the CI ``cmp`` gate);
* ``roofline`` — print a device's roofline (peaks, ridge points) and,
  given a scenario, where its configs sit;
* ``diff``     — compare two saved profile documents (latency deltas,
  bottleneck changes);
* ``demo``     — run the instrumented demo and write every artifact.

Every command is deterministic given its inputs.
"""

from __future__ import annotations

import argparse
import glob as _glob
import json
import sys

from repro.core.device import get_device

from .profile import profile_from_workload
from .profiler import load_profiles
from .report import render_attribution, render_profiles


def _parse_config(raw: str | None) -> dict | None:
    if not raw:
        return None
    out = {}
    for part in raw.split(","):
        k, _, v = part.partition("=")
        if not _:
            raise SystemExit(f"bad --config item {part!r} (want key=value)")
        try:
            out[k.strip()] = int(v)
        except ValueError:
            out[k.strip()] = v.strip()
    return out


def _problem(raw: str) -> tuple[int, ...]:
    return tuple(int(x) for x in raw.split(",") if x)


def _load_datasets(pattern: str):
    from repro.tunebench.dataset import SpaceDataset
    paths = sorted(_glob.glob(pattern))
    return [SpaceDataset.load(p) for p in paths]


def _cmd_profile(args) -> int:
    from repro.core.registry import get_kernel
    from repro.tuner.costmodel import CostModel

    builder = get_kernel(args.kernel)
    problem = _problem(args.problem)
    device = get_device(args.device)
    config = _parse_config(args.config) or builder.default_config()
    w = builder.make_workload(config, problem, args.dtype)
    if not w.valid:
        print(f"config {config} is infeasible for {problem}")
        return 1
    if args.latency_us is not None:
        latency = float(args.latency_us)
    else:
        key = "|".join(f"{k}={config[k]}" for k in sorted(config))
        key += f"|{problem}|{args.dtype}"
        latency = CostModel(device).time(w, args.dtype,
                                         noise_key=key) * 1e6
    p = profile_from_workload(w, device, args.dtype, latency,
                              kernel=builder.name, problem_size=problem,
                              config=config)
    doc = json.dumps(p.to_json(), indent=2, sort_keys=True)
    print(doc)
    if args.out:
        with open(args.out, "w") as f:
            f.write(doc + "\n")
    print(f"# {p.bottleneck}-bound, roofline fraction "
          f"{p.roofline_fraction:.3f}, AI {p.arithmetic_intensity:.2f}",
          file=sys.stderr)
    return 0


def _cmd_report(args) -> int:
    datasets = _load_datasets(args.datasets) if args.datasets else []
    profiles = []
    for path in args.profiles:
        profiles.extend(load_profiles(path))
    parts = []
    if profiles:
        parts.append(render_profiles(profiles))
    if datasets or not profiles:
        parts.append(render_attribution(datasets,
                                        rerank=not args.no_rerank))
    text = "\n".join(parts)
    sys.stdout.write(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    return 0


def _cmd_roofline(args) -> int:
    device = get_device(args.device)
    vpu_f32 = device.flops_f32 / device.vector_ratio
    rows = [
        ("peak bf16 (MXU)", f"{device.flops_bf16 / 1e12:.1f} TFLOP/s"),
        ("peak f32 (MXU)", f"{device.flops_f32 / 1e12:.1f} TFLOP/s"),
        ("peak f32 (VPU)", f"{vpu_f32 / 1e12:.2f} TFLOP/s"),
        ("HBM bandwidth", f"{device.hbm_bw / 1e9:.0f} GB/s"),
        ("ICI bandwidth", f"{device.ici_bw / 1e9:.0f} GB/s"),
        ("VMEM", f"{device.vmem_bytes // 2**20} MiB"),
        ("ridge AI bf16", f"{device.flops_bf16 / device.hbm_bw:.1f} "
                          f"FLOP/byte"),
        ("ridge AI f32", f"{device.flops_f32 / device.hbm_bw:.1f} "
                         f"FLOP/byte"),
        ("ridge AI f32 VPU", f"{vpu_f32 / device.hbm_bw:.1f} FLOP/byte"),
    ]
    print(f"roofline: {device.kind} (family {device.family}, "
          f"backend {device.backend})"
          + (" — ESTIMATED peaks cloned from the "
             f"{device.backend} baseline; every roof below is a guess"
             if device.estimated else ""))
    for k, v in rows:
        print(f"  {k:18} {v}")
    if args.kernel:
        from repro.core.registry import get_kernel
        builder = get_kernel(args.kernel)
        problem = _problem(args.problem)
        config = _parse_config(args.config) or builder.default_config()
        w = builder.make_workload(config, problem, args.dtype)
        p = profile_from_workload(w, device, args.dtype, 0.0,
                                  kernel=builder.name,
                                  problem_size=problem, config=config)
        print(f"  {builder.name} @ {problem} {args.dtype}: "
              f"AI={p.arithmetic_intensity:.2f} -> {p.bottleneck}-bound "
              f"(compute {p.compute_us:.3f}us vs memory "
              f"{p.memory_us:.3f}us)")
    return 0


def _cmd_diff(args) -> int:
    a = {(p.kernel, p.device_kind, p.problem_size, p.dtype): p
         for p in load_profiles(args.a)}
    b = {(p.kernel, p.device_kind, p.problem_size, p.dtype): p
         for p in load_profiles(args.b)}
    changed = 0
    for key in sorted(set(a) | set(b)):
        ka = a.get(key)
        kb = b.get(key)
        name = f"{key[0]} {key[1]}|{'x'.join(map(str, key[2]))}|{key[3]}"
        if ka is None or kb is None:
            print(f"{name}: only in {'b' if ka is None else 'a'}")
            changed += 1
            continue
        ratio = (kb.latency_us / ka.latency_us
                 if ka.latency_us > 0 else float("inf"))
        mark = ""
        if kb.bottleneck != ka.bottleneck:
            mark += f" bottleneck {ka.bottleneck}->{kb.bottleneck}"
        if abs(ratio - 1.0) > args.tolerance:
            mark += f" latency x{ratio:.3f}"
        if mark:
            print(f"{name}:{mark}")
            changed += 1
        else:
            print(f"{name}: unchanged (x{ratio:.3f})")
    print(f"{changed} profile(s) changed")
    return 1 if (changed and args.check) else 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.prof",
        description="kernel profiles: roofline counters, bottleneck "
                    "attribution, profile-guided tuning")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("profile", help="profile one kernel scenario")
    p.add_argument("--kernel", required=True)
    p.add_argument("--problem", required=True,
                   help="comma-separated problem size, e.g. 256,256,256")
    p.add_argument("--dtype", default="float32")
    p.add_argument("--device", default="tpu-v5e")
    p.add_argument("--config", help="key=value,... (default: the "
                                    "kernel's default config)")
    p.add_argument("--latency-us", type=float,
                   help="measured latency; default: simulate via the "
                        "cost model")
    p.add_argument("--out", help="also write the profile JSON here")

    p = sub.add_parser("report",
                       help="bottleneck-attribution report "
                            "(byte-deterministic)")
    p.add_argument("--datasets",
                   default="benchmarks/datasets/*.space.json",
                   help="recorded tuning-space glob (default: the "
                        "shipped spaces)")
    p.add_argument("--profiles", nargs="*", default=[],
                   help="saved .prof.json documents to summarize")
    p.add_argument("--no-rerank", action="store_true",
                   help="skip the surrogate comparison section")
    p.add_argument("--out", help="also write the report to this path")

    p = sub.add_parser("roofline", help="device roofline + ridge points")
    p.add_argument("--device", default="tpu-v5e")
    p.add_argument("--kernel", help="also place this kernel's config "
                                    "on the roofline")
    p.add_argument("--problem", default="256,256,256")
    p.add_argument("--dtype", default="float32")
    p.add_argument("--config")

    p = sub.add_parser("diff", help="compare two profile documents")
    p.add_argument("a")
    p.add_argument("b")
    p.add_argument("--tolerance", type=float, default=0.10,
                   help="latency ratio considered unchanged "
                        "(default 0.10)")
    p.add_argument("--check", action="store_true",
                   help="exit non-zero if anything changed")

    p = sub.add_parser("demo", help="run the instrumented profiler demo")
    p.add_argument("--out", default="prof-demo",
                   help="artifact directory (default prof-demo)")
    p.add_argument("--datasets",
                   default="benchmarks/datasets/*.space.json")

    args = ap.parse_args(argv)

    if args.cmd == "profile":
        return _cmd_profile(args)
    if args.cmd == "report":
        return _cmd_report(args)
    if args.cmd == "roofline":
        return _cmd_roofline(args)
    if args.cmd == "diff":
        return _cmd_diff(args)
    if args.cmd == "demo":
        from .demo import run_demo
        art = run_demo(args.out, dataset_glob=args.datasets)
        for name in ("profiles", "trace", "snapshot", "report_path"):
            print(f"{name}: {art[name]}")
        print(f"profiles recorded: {art['n_profiles']} "
              f"(drift events: {art['drift_events']})")
        sys.stdout.write("\n" + art["report"])
        return 0
    raise AssertionError(f"unhandled command {args.cmd!r}")


if __name__ == "__main__":          # pragma: no cover
    raise SystemExit(main())
