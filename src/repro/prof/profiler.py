"""The runtime profiler: sampled on the launch path, always-on in tuning.

A :class:`Profiler` collects :class:`~repro.prof.profile.KernelProfile`
records and fans each one out to the telemetry the rest of the stack
already reads: ``prof.*`` metric series on the process registry (which
the fleet metrics bus ships and ``aggregate_fleet_metrics`` merges, so
bottleneck attribution aggregates fleet-wide for free) and Chrome
counter ("C") events on the process tracer (Perfetto renders
roofline-fraction / arithmetic-intensity tracks next to the launch
spans). Drift against the wisdom-recorded baseline raises a
``prof.drift`` counter plus an instant trace marker.

Sampling keeps it launch-path-safe: :meth:`Profiler.due` is one dict
increment + one modulo, and the expensive part (the workload hook) runs
only on sampled launches — ``benchmarks/overhead.py --check`` pins both
the detached-site and the amortized sampled cost. Tuner evaluations
profile every config instead (:func:`Profiler.profile_launch` is pure),
because there the measurement *is* the workload.

``KERNEL_LAUNCHER_PROF=1`` (or ``=N`` for a sample period) attaches a
process-wide profiler to every :class:`~repro.core.WisdomKernel` at
construction, mirroring ``KERNEL_LAUNCHER_OBS`` / ``KERNEL_LAUNCHER_ONLINE``.
"""

from __future__ import annotations

import json
import os
from pathlib import Path

from repro.core.builder import KernelBuilder
from repro.core.device import DeviceSpec, get_device
from repro.core.param import Config
from repro.obs import runtime as obs
from repro.obs.metrics import UNIT_BUCKETS

from .profile import (DRIFT_THRESHOLD, PROFILE_VERSION, KernelProfile,
                      profile_from_workload)

PROF_ENV = "KERNEL_LAUNCHER_PROF"

#: Default sampling period on the serving launch path: profile one
#: launch in 16. Chosen so the amortized workload-hook cost stays far
#: under the pinned ``benchmarks/overhead.py`` sampled-profiling budget.
DEFAULT_SAMPLE_EVERY = 16

#: Bound on in-memory retained profiles (oldest dropped first): a
#: long-lived serving process must not grow without limit. Telemetry
#: (metrics/trace) still sees every sampled launch.
MAX_PROFILES = 4096

_process_profiler: "Profiler | None" = None


def prof_requested() -> int:
    """Sampling period requested via ``KERNEL_LAUNCHER_PROF`` (0 = off).

    ``1``/``true``/``on``/``yes`` select :data:`DEFAULT_SAMPLE_EVERY`;
    an integer > 1 is used as the period directly (``...PROF=4`` →
    profile every 4th launch).

    Example::

        os.environ["KERNEL_LAUNCHER_PROF"] = "8"
        prof_requested()    # -> 8
    """
    raw = os.environ.get(PROF_ENV, "").strip().lower()
    if raw in ("", "0", "false", "off", "no"):
        return 0
    if raw in ("1", "true", "on", "yes"):
        return DEFAULT_SAMPLE_EVERY
    try:
        n = int(raw)
    except ValueError:
        return DEFAULT_SAMPLE_EVERY
    return max(1, n)


def process_profiler() -> "Profiler | None":
    """The ambient per-process profiler (created on first request when
    ``KERNEL_LAUNCHER_PROF`` is set, else None). One shared instance so
    every kernel's samples land in one place, like the obs registry.

    Example::

        pr = process_profiler()
        if pr is not None:
            print(len(pr.profiles), "profiles so far")
    """
    global _process_profiler
    if _process_profiler is None:
        every = prof_requested()
        if every:
            _process_profiler = Profiler(sample_every=every)
    return _process_profiler


def reset_process_profiler() -> None:
    """Drop the ambient per-process profiler so the environment is
    re-read on the next :func:`process_profiler` call — test isolation,
    mirroring ``obs.disable()``.

    Example::

        os.environ["KERNEL_LAUNCHER_PROF"] = "4"
        reset_process_profiler()
        process_profiler().sample_every   # -> 4
    """
    global _process_profiler
    _process_profiler = None


class Profiler:
    """Collects profiles and fans them out to metrics + trace.

    ``sample_every=N`` profiles every Nth launch per kernel (1 = every
    launch, the tuner setting). The profiler itself never times anything
    — callers hand it the latency they already measured, so attaching it
    adds no second clock to the hot path.

    Example::

        pr = Profiler(sample_every=4)
        kernel.attach_profiler(pr)
        ...
        for p in pr.profiles:
            print(p.kernel, p.bottleneck, p.roofline_fraction)
    """

    def __init__(self, sample_every: int = DEFAULT_SAMPLE_EVERY,
                 drift_threshold: float = DRIFT_THRESHOLD,
                 max_profiles: int = MAX_PROFILES) -> None:
        self.sample_every = max(1, int(sample_every))
        self.drift_threshold = float(drift_threshold)
        self.max_profiles = int(max_profiles)
        self.profiles: list[KernelProfile] = []
        self.dropped = 0
        self.drift_events = 0
        self._counts: dict[str, int] = {}

    def due(self, key: str) -> bool:
        """Hot-path sampling decision for launch stream ``key`` (one
        dict increment, one modulo). The first launch of every key is
        sampled, then every ``sample_every``-th after it.

        Example::

            if profiler.due("matmul"):
                ...   # compute the workload, profile this launch
        """
        n = self._counts.get(key, 0)
        self._counts[key] = n + 1
        return n % self.sample_every == 0

    def profile_launch(self, builder: KernelBuilder, config: Config,
                       problem: tuple[int, ...], dtype: str,
                       device: DeviceSpec | str, latency_us: float,
                       tier: str = "",
                       baseline_us: float | None = None
                       ) -> KernelProfile | None:
        """Profile one launch through the kernel's workload hook and
        record it. Returns None (and records nothing) for kernels with
        no workload hook or configs whose workload is invalid — the
        profiler never turns a served launch into an error.

        Example::

            p = pr.profile_launch(builder, cfg, (256, 256, 256),
                                  "float32", "tpu-v5e", latency_us=412.7,
                                  tier="exact", baseline_us=400.0)
        """
        if builder._workload is None:
            return None
        dev = get_device(device) if isinstance(device, str) else device
        try:
            w = builder.make_workload(config, problem, dtype)
        except Exception:  # noqa: BLE001 — profiling must not break serving
            return None
        if not getattr(w, "valid", True):
            return None
        p = profile_from_workload(
            w, dev, dtype, latency_us, kernel=builder.name,
            problem_size=problem, config=config, tier=tier,
            baseline_us=baseline_us)
        self.record(p)
        return p

    def record(self, profile: KernelProfile) -> None:
        """Retain ``profile`` (bounded by ``max_profiles``) and emit its
        telemetry: ``prof.launches{kernel,bottleneck}``,
        ``prof.roofline_fraction{kernel}``, a Chrome counter event, and
        — past ``drift_threshold`` — ``prof.drift{kernel}`` plus an
        instant trace marker.

        Example::

            pr.record(profile_from_workload(w, dev, "float32", 412.7))
        """
        self.profiles.append(profile)
        if len(self.profiles) > self.max_profiles:
            del self.profiles[:len(self.profiles) - self.max_profiles]
            self.dropped += 1
        drifted = profile.has_drift(self.drift_threshold)
        if drifted:
            self.drift_events += 1
        m = obs.metrics()
        if m is not None:
            m.counter("prof.launches", kernel=profile.kernel,
                      bottleneck=profile.bottleneck).inc()
            m.histogram("prof.roofline_fraction", UNIT_BUCKETS,
                        kernel=profile.kernel).observe(
                            min(profile.roofline_fraction, 1.0))
            if drifted:
                m.counter("prof.drift", kernel=profile.kernel).inc()
        tr = obs.tracer()
        if tr is not None:
            tr.counter(f"prof.{profile.kernel}", cat="prof",
                       roofline_fraction=profile.roofline_fraction,
                       arithmetic_intensity=profile.arithmetic_intensity,
                       achieved_flops_frac=profile.achieved_flops_frac,
                       achieved_bw_frac=profile.achieved_bw_frac)
            if drifted:
                tr.instant("prof.drift", cat="prof",
                           kernel=profile.kernel,
                           drift=profile.drift,
                           latency_us=profile.latency_us,
                           baseline_us=profile.baseline_us)


class StepProfiler:
    """Decode-step profiling for :class:`~repro.serve.ServeEngine`.

    A decode step has no per-kernel workload hook, but its roofline is
    well known: every step streams the full parameter set from HBM
    (``hbm_bytes ≈ param bytes``) and does ``2 · params · slots`` FLOPs
    — small-batch decode is memory-bound, and the profile says by how
    much. The engine calls :meth:`due` each step and hands the sampled
    step's measured latency to :meth:`on_step`; the first sampled step
    becomes the drift baseline for the rest of the run.

    Example::

        pr = Profiler()
        eng = ServeEngine(model, params, profiler=StepProfiler(pr))
        eng.run()
        [p for p in pr.profiles if p.kernel == "serve.decode"]
    """

    def __init__(self, profiler: Profiler,
                 sample_every: int | None = None,
                 device: DeviceSpec | str | None = None) -> None:
        self.profiler = profiler
        self.sample_every = max(1, int(sample_every
                                       if sample_every is not None
                                       else profiler.sample_every))
        self._device = device
        self._baseline_us: float | None = None

    def bind(self, params, n_slots: int, max_seq: int) -> None:
        """One-time (at engine construction): derive the decode-step
        roofline counters from the parameter pytree."""
        import jax
        import numpy as np
        leaves = [np.asarray(x) for x in jax.tree.leaves(params)]
        self.param_bytes = float(sum(x.nbytes for x in leaves))
        self.param_count = float(sum(x.size for x in leaves))
        self.n_slots = int(n_slots)
        self.max_seq = int(max_seq)
        self.dtype = (str(leaves[0].dtype) if leaves else "float32")

    def due(self, step: int) -> bool:
        """Whether to time + profile this decode step."""
        return step % self.sample_every == 0

    def on_step(self, latency_us: float) -> KernelProfile | None:
        """Record one sampled decode step as a profile."""
        if not hasattr(self, "param_bytes"):
            return None
        dev = self._device or "cpu"
        dev = get_device(dev) if isinstance(dev, str) else dev
        from repro.core.workload import Workload
        w = Workload(flops=2.0 * self.param_count * self.n_slots,
                     hbm_bytes=self.param_bytes,
                     vmem_bytes=0, grid=1)
        p = profile_from_workload(
            w, dev, self.dtype, latency_us, kernel="serve.decode",
            problem_size=(self.n_slots, self.max_seq),
            tier="serve", baseline_us=self._baseline_us)
        if self._baseline_us is None:
            self._baseline_us = p.latency_us
        self.profiler.record(p)
        return p


def summarize(profiles: list[KernelProfile]) -> dict:
    """Deterministic aggregation for reports: per-kernel launch counts,
    bottleneck distribution, mean roofline fraction / arithmetic
    intensity, and drift counts, keyed and ordered by kernel name.

    Example::

        s = summarize(pr.profiles)
        s["matmul"]["bottleneck"]       # {"compute": 12, "memory": 3}
    """
    by_kernel: dict[str, list[KernelProfile]] = {}
    for p in profiles:
        by_kernel.setdefault(p.kernel, []).append(p)
    out: dict[str, dict] = {}
    for kernel in sorted(by_kernel):
        ps = by_kernel[kernel]
        bn: dict[str, int] = {}
        for p in ps:
            bn[p.bottleneck] = bn.get(p.bottleneck, 0) + 1
        n = len(ps)
        out[kernel] = {
            "launches": n,
            "bottleneck": {k: bn[k] for k in sorted(bn)},
            "dominant": max(sorted(bn), key=lambda k: bn[k]),
            "mean_roofline_fraction": round(
                sum(p.roofline_fraction for p in ps) / n, 6),
            "mean_arithmetic_intensity": round(
                sum(p.arithmetic_intensity for p in ps) / n, 6),
            "mean_latency_us": round(
                sum(p.latency_us for p in ps) / n, 6),
            "drifted": sum(1 for p in ps if p.has_drift()),
            "estimated": sum(1 for p in ps if p.estimated),
        }
    return out


def save_profiles(path: Path | str,
                  profiles: list[KernelProfile]) -> Path:
    """Write a versioned, byte-deterministic profile document.

    Example::

        save_profiles("run.prof.json", pr.profiles)
    """
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    doc = {"version": PROFILE_VERSION,
           "profiles": [p.to_json() for p in profiles]}
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    return path


def load_profiles(path: Path | str) -> list[KernelProfile]:
    """Read a profile document written by :func:`save_profiles`
    (per-profile version checks included).

    Example::

        profiles = load_profiles("run.prof.json")
    """
    path = Path(path)
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "profiles" not in doc:
        raise ValueError(f"{path} is not a profile document")
    return [KernelProfile.from_json(d, source=str(path))
            for d in doc["profiles"]]
