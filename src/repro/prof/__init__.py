"""Kernel profiler: per-launch roofline counters, bottleneck attribution,
and profile-guided tuning.

PR 6's telemetry says *that* a launch happened; this package says *why
it is fast or slow*. A :class:`KernelProfile` joins one launch's
measured latency with the roofline counters the workload hook and
device capability vector already know (FLOPs, HBM/collective bytes,
arithmetic intensity, VMEM pressure), classifies the launch as
compute-/memory-/collective-bound, and flags latency drift against the
wisdom-recorded baseline. The :class:`Profiler` samples the serving
launch path (``WisdomKernel``/``ServeEngine``, every Nth launch,
overhead-gated), runs always-on inside tuner evaluations so recorded
datasets gain per-config profile fields, and fans every profile out to
``prof.*`` metrics and Chrome counter events. :func:`surrogate_rerank`
closes the loop: the recorded counters become regression features for
the tuner's surrogate (``fit_from_dataset(profile_features=True)``),
and ``benchmarks/strategy_bench.py`` gates that the profile-guided
surrogate finds near-optimal configs from fewer evaluations.

``python -m repro.prof`` exposes profile/report/roofline/diff/demo;
``KERNEL_LAUNCHER_PROF=N`` attaches a process-wide profiler ambiently.
"""

from .guided import (DEFAULT_BUDGETS, DEFAULT_TRAIN_EVERY, rerank_gate,
                     surrogate_rerank)
from .profile import (BOTTLENECKS, DRIFT_THRESHOLD, PROFILE_FEATURES,
                      PROFILE_VERSION, KernelProfile, ProfileVersionError,
                      classify_bottleneck, profile_feature_vector,
                      profile_fields, profile_from_workload)
from .profiler import (DEFAULT_SAMPLE_EVERY, PROF_ENV, Profiler,
                       StepProfiler, load_profiles, process_profiler,
                       prof_requested, reset_process_profiler,
                       save_profiles, summarize)
from .report import classify_dataset, render_attribution, render_profiles

__all__ = [
    "BOTTLENECKS",
    "DEFAULT_BUDGETS",
    "DEFAULT_SAMPLE_EVERY",
    "DEFAULT_TRAIN_EVERY",
    "DRIFT_THRESHOLD",
    "KernelProfile",
    "PROF_ENV",
    "PROFILE_FEATURES",
    "PROFILE_VERSION",
    "Profiler",
    "ProfileVersionError",
    "StepProfiler",
    "classify_bottleneck",
    "classify_dataset",
    "load_profiles",
    "process_profiler",
    "prof_requested",
    "profile_feature_vector",
    "profile_fields",
    "profile_from_workload",
    "render_attribution",
    "render_profiles",
    "rerank_gate",
    "reset_process_profiler",
    "save_profiles",
    "summarize",
    "surrogate_rerank",
]
