"""Bottleneck-attribution report — rendering profiles into decisions.

Two deterministic text renderers:

* :func:`render_attribution` reads recorded tuning-space datasets (whose
  entries the always-on tuner profiling stamped with roofline counters)
  and classifies each *scenario* by the bottleneck of its best —
  servable — config, alongside the space-wide bottleneck distribution
  and the profile-guided-surrogate comparison. This is the
  ``python -m repro.prof report`` body and the CI byte-determinism
  artifact.
* :func:`render_profiles` summarizes saved :class:`KernelProfile`
  documents (a serving host's sampled launches) — per-kernel bottleneck
  mix, achieved roofline fraction, and drift counts.

Both are pure functions of their inputs: same documents, same bytes.
"""

from __future__ import annotations

from repro.core.device import get_device

from .guided import rerank_gate, surrogate_rerank
from .profile import KernelProfile
from .profiler import summarize


def _section(lines: list[str], title: str) -> None:
    if lines and lines[-1] != "":
        lines.append("")
    lines.append(title)
    lines.append("-" * len(title))


def classify_dataset(dataset) -> dict:
    """Scenario-level bottleneck attribution for one recorded space.

    The scenario's class is its *best config's* bottleneck — that is the
    config wisdom will serve, so its limiting resource is what an
    operator would provision for. The space-wide distribution is
    reported too (a space can be mostly memory-bound yet have a
    compute-bound optimum: the serving-scale matmul space is exactly
    that).

    Example::

        c = classify_dataset(SpaceDataset.load("matmul....space.json"))
        c["bottleneck"], c["distribution"]   # "compute", {"compute": 16,
                                             #  "memory": 240}
    """
    best = dataset.best()
    dist: dict[str, int] = {}
    intensities = []
    for e in dataset.feasible():
        prof = getattr(e, "profile", None) or {}
        b = prof.get("bottleneck")
        if b:
            dist[b] = dist.get(b, 0) + 1
        if "arithmetic_intensity" in prof:
            intensities.append(float(prof["arithmetic_intensity"]))
    bprof = (getattr(best, "profile", None) or {}) if best else {}
    bound_us = max(float(bprof.get("compute_us", 0.0)),
                   float(bprof.get("memory_us", 0.0)),
                   float(bprof.get("collective_us", 0.0)))
    return {
        "dataset": dataset.name(),
        "kernel": dataset.kernel,
        "scenario": dataset.scenario_key(),
        # Unknown hardware gets baseline-cloned peaks: every roofline
        # number below is then relative to *assumed* roofs.
        "estimated": bool(get_device(dataset.device_kind).estimated),
        "bottleneck": bprof.get("bottleneck", "unprofiled"),
        "best_us": round(best.score_us, 6) if best else None,
        "best_arithmetic_intensity": bprof.get("arithmetic_intensity"),
        "best_roofline_fraction": (round(bound_us / best.score_us, 6)
                                   if best and best.score_us > 0 else None),
        "distribution": {k: dist[k] for k in sorted(dist)},
        "mean_arithmetic_intensity": (
            round(sum(intensities) / len(intensities), 6)
            if intensities else None),
    }


def render_attribution(datasets, rerank: bool = True) -> str:
    """The recorded-space bottleneck report as text (see module
    docstring). ``rerank=False`` skips the surrogate comparison (for
    datasets too small to fit).

    Example::

        print(render_attribution([SpaceDataset.load(p)
                                  for p in sorted(glob("*.space.json"))]))
    """
    datasets = sorted(datasets, key=lambda d: d.name())
    lines: list[str] = []
    _section(lines, "Bottleneck attribution (best config per scenario)")
    if not datasets:
        lines.append("no recorded spaces given")
    for ds in datasets:
        c = classify_dataset(ds)
        dist = " ".join(f"{k}={v}" for k, v in c["distribution"].items())
        ai = c["best_arithmetic_intensity"]
        rf = c["best_roofline_fraction"]
        lines.append(
            f"{c['kernel']} {c['scenario']}: {c['bottleneck']}-bound "
            f"best={c['best_us']:.3f}us "
            f"AI={ai if ai is not None else '?'} "
            f"roofline-frac={f'{rf:.3f}' if rf is not None else '?'} "
            f"[space: {dist or 'unprofiled'}]"
            + (" (estimated peaks)" if c["estimated"] else ""))

    if rerank:
        _section(lines,
                 "Profile-guided surrogate (fraction of optimum @ budget)")
        for ds in datasets:
            try:
                r = surrogate_rerank(ds)
            except ValueError as e:
                lines.append(f"{ds.name()}: skipped ({e})")
                continue
            for row in r["surrogates"]:
                at = " ".join(f"@{b}={row['fraction_at'][str(b)]:.4f}"
                              for b in r["budgets"])
                lines.append(f"{ds.name()} {row['surrogate']:>7}: {at} "
                             f"fit-quality={row['fit_quality']:.3f}")
            problems = rerank_gate(r)
            lines.append(f"{ds.name()}    gate: "
                         f"{'PASS' if not problems else '; '.join(problems)}")
    return "\n".join(lines) + "\n"


def render_profiles(profiles: list[KernelProfile]) -> str:
    """Summarize saved launch profiles as text (per-kernel bottleneck
    mix, mean roofline fraction, drift count).

    Example::

        print(render_profiles(load_profiles("run.prof.json")))
    """
    lines: list[str] = []
    _section(lines, "Launch profiles (per kernel)")
    s = summarize(profiles)
    if not s:
        lines.append("no profiles recorded")
    for kernel, row in s.items():
        dist = " ".join(f"{k}={v}" for k, v in row["bottleneck"].items())
        lines.append(
            f"{kernel}: launches={row['launches']} "
            f"dominant={row['dominant']} [{dist}] "
            f"mean-roofline-frac={row['mean_roofline_fraction']:.3f} "
            f"mean-latency={row['mean_latency_us']:.3f}us "
            f"drifted={row['drifted']}"
            + (f" [estimated peaks: {row['estimated']}/{row['launches']}]"
               if row.get("estimated") else ""))
    return "\n".join(lines) + "\n"
