"""KernelProfile — the versioned join of a measured launch and its roofline.

A profile answers *why* a launch is fast or slow, not just how long it
took: it pairs the measured (or simulated) latency with the
roofline-derived counters the workload hook and device capability vector
already know — FLOPs, HBM bytes, collective bytes, arithmetic intensity,
VMEM pressure — and classifies the launch as compute-, memory-, or
collective-bound by comparing the three roofline time terms
(:func:`classify_bottleneck`). ``roofline_fraction`` says how much of
the roofline bound the launch achieved (1.0 = running at the roof);
``drift`` compares the latency against the wisdom-recorded baseline for
the scenario, so a serving host notices when a tuned config stops
delivering its tuned latency.

Like wisdom files and datasets, the JSON form is versioned
(``PROFILE_VERSION``) and documents from a newer schema are refused
loudly (:class:`ProfileVersionError`). This module is import-leaf
(``repro.core.device`` only), so the tuner's cost model can read profile
feature columns without cycles.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.core.device import DeviceSpec

#: Current schema version for serialized profiles. v1: the initial
#: roofline-counter layout below.
PROFILE_VERSION = 1

#: Latency-vs-baseline ratio at which a profile reports drift: a launch
#: taking 1.5x its wisdom-recorded score is no longer serving its tuned
#: latency (compile regressions, contention, stale wisdom).
DRIFT_THRESHOLD = 1.5

#: Bottleneck classes, in tie-break preference order (ties go to the
#: earlier class, matching ``roofline.analysis.roofline_report``).
BOTTLENECKS = ("compute", "memory", "collective")

#: Numeric feature columns a profile contributes to the tuner surrogate,
#: in order (see :func:`profile_feature_vector`). Deliberately excludes
#: the measured latency and anything derived from it — features must be
#: computable *before* a config runs, or the surrogate is just reading
#: the answer off the measurement.
PROFILE_FEATURES = ("log_compute_us", "log_memory_us", "log_collective_us",
                    "log_arithmetic_intensity", "vmem_fraction", "log_grid")


class ProfileVersionError(ValueError):
    """A serialized profile declares a schema version this build cannot
    handle. Raised for documents from the *future* (version >
    ``PROFILE_VERSION``): silently misreading roofline counters would
    poison every report and surrogate fit built on them, so loading
    refuses loudly instead.

    Example::

        try:
            profiles = load_profiles("fleet-host.prof.json")
        except ProfileVersionError:
            ...   # newer build wrote it; upgrade before reading
    """


def classify_bottleneck(compute_us: float, memory_us: float,
                        collective_us: float = 0.0) -> str:
    """Which roofline term dominates: ``"compute"``, ``"memory"``, or
    ``"collective"``. Ties resolve to the earlier class in
    :data:`BOTTLENECKS`, so classification is deterministic.

    Example::

        classify_bottleneck(120.0, 80.0)     # -> "compute"
        classify_bottleneck(10.0, 45.0, 5.0) # -> "memory"
    """
    terms = dict(zip(BOTTLENECKS, (float(compute_us), float(memory_us),
                                   float(collective_us))))
    return max(BOTTLENECKS, key=lambda k: (terms[k], ))


def _r(x: float) -> float:
    return round(float(x), 6)


@dataclass
class KernelProfile:
    """One profiled launch: measured latency joined with its roofline.

    ``compute_us``/``memory_us``/``collective_us`` are the per-launch
    roofline time terms (FLOPs over peak, HBM bytes over bandwidth,
    collective bytes over link bandwidth); ``bottleneck`` names the
    dominant one; ``roofline_fraction`` is the bound over the measured
    latency (how close to the roof the launch came);
    ``achieved_flops_frac``/``achieved_bw_frac`` are the fractions of
    peak compute / bandwidth actually sustained. ``baseline_us`` is the
    wisdom-recorded score for the scenario when one exists, and
    ``drift`` the latency/baseline ratio (``has_drift()`` applies
    :data:`DRIFT_THRESHOLD`).

    Example::

        p = profile_from_workload(w, device, "float32", latency_us=412.7)
        p.bottleneck          # "compute" for a well-blocked matmul
        p.roofline_fraction   # 0.83 -> 17% left on the table
    """

    kernel: str
    device_kind: str
    problem_size: tuple[int, ...]
    dtype: str
    config: dict = field(default_factory=dict)
    tier: str = ""
    latency_us: float = 0.0
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    vmem_bytes: int = 0
    grid: int = 0
    arithmetic_intensity: float = 0.0
    vmem_fraction: float = 0.0
    compute_us: float = 0.0
    memory_us: float = 0.0
    collective_us: float = 0.0
    bottleneck: str = "compute"
    roofline_fraction: float = 0.0
    achieved_flops_frac: float = 0.0
    achieved_bw_frac: float = 0.0
    baseline_us: float | None = None
    drift: float | None = None
    #: True when the device's peaks are guesses cloned from a backend
    #: baseline (``DeviceSpec.estimated``): every roofline fraction and
    #: bottleneck class below is then relative to *assumed* roofs and
    #: reports must say so.
    estimated: bool = False

    def scenario_key(self) -> tuple:
        return (self.device_kind, self.problem_size, self.dtype)

    def has_drift(self, threshold: float = DRIFT_THRESHOLD) -> bool:
        """Whether the measured latency drifted past ``threshold`` times
        the wisdom-recorded baseline (False when no baseline exists).

        Example::

            if profile.has_drift():
                alert(profile.kernel, profile.drift)
        """
        return self.drift is not None and self.drift >= threshold

    def to_json(self) -> dict:
        """Versioned, JSON-safe, deterministically rounded document."""
        out = {
            "version": PROFILE_VERSION,
            "kernel": self.kernel,
            "device_kind": self.device_kind,
            "problem_size": [int(d) for d in self.problem_size],
            "dtype": self.dtype,
            "config": dict(self.config),
            "tier": self.tier,
            "latency_us": _r(self.latency_us),
            "flops": _r(self.flops),
            "hbm_bytes": _r(self.hbm_bytes),
            "collective_bytes": _r(self.collective_bytes),
            "vmem_bytes": int(self.vmem_bytes),
            "grid": int(self.grid),
            "arithmetic_intensity": _r(self.arithmetic_intensity),
            "vmem_fraction": _r(self.vmem_fraction),
            "compute_us": _r(self.compute_us),
            "memory_us": _r(self.memory_us),
            "collective_us": _r(self.collective_us),
            "bottleneck": self.bottleneck,
            "roofline_fraction": _r(self.roofline_fraction),
            "achieved_flops_frac": _r(self.achieved_flops_frac),
            "achieved_bw_frac": _r(self.achieved_bw_frac),
        }
        if self.baseline_us is not None:
            out["baseline_us"] = _r(self.baseline_us)
        if self.drift is not None:
            out["drift"] = _r(self.drift)
        if self.estimated:
            out["estimated"] = True
        return out

    @staticmethod
    def from_json(d: dict, source: str = "<memory>") -> "KernelProfile":
        """Inverse of :meth:`to_json`; refuses future schema versions.

        Example::

            p = KernelProfile.from_json(json.load(open("x.prof.json")))
        """
        try:
            version = int(d.get("version", 1))
        except (TypeError, ValueError):
            raise ProfileVersionError(
                f"profile {source} declares non-integer version "
                f"{d.get('version')!r}") from None
        if version > PROFILE_VERSION:
            raise ProfileVersionError(
                f"profile {source} has version {version}, but this build "
                f"understands at most {PROFILE_VERSION}")
        baseline = d.get("baseline_us")
        drift = d.get("drift")
        return KernelProfile(
            kernel=str(d["kernel"]),
            device_kind=str(d["device_kind"]),
            problem_size=tuple(int(x) for x in d["problem_size"]),
            dtype=str(d["dtype"]),
            config=dict(d.get("config", {})),
            tier=str(d.get("tier", "")),
            latency_us=float(d.get("latency_us", 0.0)),
            flops=float(d.get("flops", 0.0)),
            hbm_bytes=float(d.get("hbm_bytes", 0.0)),
            collective_bytes=float(d.get("collective_bytes", 0.0)),
            vmem_bytes=int(d.get("vmem_bytes", 0)),
            grid=int(d.get("grid", 0)),
            arithmetic_intensity=float(d.get("arithmetic_intensity", 0.0)),
            vmem_fraction=float(d.get("vmem_fraction", 0.0)),
            compute_us=float(d.get("compute_us", 0.0)),
            memory_us=float(d.get("memory_us", 0.0)),
            collective_us=float(d.get("collective_us", 0.0)),
            bottleneck=str(d.get("bottleneck", "compute")),
            roofline_fraction=float(d.get("roofline_fraction", 0.0)),
            achieved_flops_frac=float(d.get("achieved_flops_frac", 0.0)),
            achieved_bw_frac=float(d.get("achieved_bw_frac", 0.0)),
            baseline_us=None if baseline is None else float(baseline),
            drift=None if drift is None else float(drift),
            estimated=bool(d.get("estimated", False)),
        )


def profile_from_workload(w, device: DeviceSpec, dtype: str,
                          latency_us: float, *, kernel: str = "",
                          problem_size: tuple[int, ...] = (),
                          config: dict | None = None, tier: str = "",
                          collective_bytes: float = 0.0,
                          baseline_us: float | None = None
                          ) -> KernelProfile:
    """Join one launch's measured latency with its roofline counters.

    ``w`` is the kernel's :class:`~repro.core.workload.Workload` for the
    launched config (the same object the analytical cost model consumes,
    so profiling adds no second hardware model); ``device`` supplies the
    peaks from its capability vector. Pure and deterministic — same
    inputs, same profile.

    Example::

        w = builder.make_workload(config, (256, 256, 256), "float32")
        p = profile_from_workload(w, get_device("tpu-v5e"), "float32",
                                  latency_us=412.7, kernel="matmul")
    """
    peak = (device.flops_bf16 if dtype in ("bfloat16", "float16")
            else device.flops_f32)
    compute_us = float(w.flops) / peak * 1e6
    memory_us = float(w.hbm_bytes) / device.hbm_bw * 1e6
    collective_us = float(collective_bytes) / device.ici_bw * 1e6
    bound_us = max(compute_us, memory_us, collective_us)
    lat = float(latency_us)
    ai = float(w.flops) / max(float(w.hbm_bytes), 1.0)
    vmem_frac = float(w.vmem_bytes) / max(float(device.vmem_bytes), 1.0)
    drift = (lat / baseline_us
             if baseline_us is not None and baseline_us > 0 else None)
    return KernelProfile(
        kernel=kernel, device_kind=device.kind,
        problem_size=tuple(int(d) for d in problem_size),
        dtype=dtype, config=dict(config or {}), tier=tier,
        latency_us=_r(lat),
        flops=_r(w.flops), hbm_bytes=_r(w.hbm_bytes),
        collective_bytes=_r(collective_bytes),
        vmem_bytes=int(w.vmem_bytes), grid=int(w.grid),
        arithmetic_intensity=_r(ai), vmem_fraction=_r(vmem_frac),
        compute_us=_r(compute_us), memory_us=_r(memory_us),
        collective_us=_r(collective_us),
        bottleneck=classify_bottleneck(compute_us, memory_us,
                                       collective_us),
        roofline_fraction=_r(bound_us / lat if lat > 0 else 0.0),
        achieved_flops_frac=_r(compute_us / lat if lat > 0 else 0.0),
        achieved_bw_frac=_r(memory_us / lat if lat > 0 else 0.0),
        baseline_us=None if baseline_us is None else _r(baseline_us),
        drift=None if drift is None else _r(drift),
        estimated=bool(device.estimated),
    )


def profile_fields(profile: KernelProfile) -> dict:
    """The compact per-config dict a tuning dataset stores with each
    evaluation: the pre-measurement roofline counters plus the
    bottleneck class — everything the surrogate's feature columns need,
    nothing the entry already records (config, score).

    Example::

        ds.add(config, r.score_us, "ok")           # via EvalResult.info:
        r.info["profile"] = profile_fields(p)      # evaluators do this
    """
    return {
        "flops": _r(profile.flops),
        "hbm_bytes": _r(profile.hbm_bytes),
        "collective_bytes": _r(profile.collective_bytes),
        "vmem_bytes": int(profile.vmem_bytes),
        "grid": int(profile.grid),
        "arithmetic_intensity": _r(profile.arithmetic_intensity),
        "vmem_fraction": _r(profile.vmem_fraction),
        "compute_us": _r(profile.compute_us),
        "memory_us": _r(profile.memory_us),
        "collective_us": _r(profile.collective_us),
        "bottleneck": profile.bottleneck,
    }


def profile_feature_vector(fields: dict) -> list[float]:
    """Numeric surrogate feature columns from a profile-fields dict, in
    :data:`PROFILE_FEATURES` order. Log-compresses the time terms and
    intensities (they span orders of magnitude across a config space)
    and tolerates missing keys (zeros), so a dataset mixing profiled
    and unprofiled entries still fits.

    Example::

        x = profile_feature_vector(entry.profile)   # len == 6
    """
    def lg(key: str) -> float:
        try:
            return math.log1p(max(float(fields.get(key, 0.0)), 0.0))
        except (TypeError, ValueError):
            return 0.0

    try:
        vmem_frac = float(fields.get("vmem_fraction", 0.0))
    except (TypeError, ValueError):
        vmem_frac = 0.0
    return [lg("compute_us"), lg("memory_us"), lg("collective_us"),
            lg("arithmetic_intensity"), vmem_frac, lg("grid")]
