"""Profile-guided tuning: turn recorded roofline counters into search signal.

The plain ridge surrogate (:func:`repro.tuner.costmodel.fit_from_dataset`)
sees only unit-encoded config coordinates — it must *rediscover* hardware
structure from scores. The profiler already computed that structure per
config (roofline compute/memory time terms, arithmetic intensity, VMEM
pressure — all derived from the workload hook, available *before* a
config is ever measured), so the profile-guided surrogate regresses on
it directly. :func:`surrogate_rerank` quantifies the payoff the way
``benchmarks/strategy_bench.py`` gates it: train both surrogates on a
small subsample of recorded scores, rank the whole space by prediction,
replay in rank order, and compare fraction-of-optimum at fixed
evaluation budgets — the performance-counter-guided-search result
(profiles prune tuning spaces) reproduced on our recorded spaces.
"""

from __future__ import annotations

import numpy as np

from .profile import profile_feature_vector

#: Evaluation budgets the re-rank comparison reports (and the benchmark
#: gates): how good is the best config found after replaying the top-K
#: surrogate-ranked candidates. The floor is 8, not smaller: the
#: recorded spaces end in a plateau of near-optimal configs whose
#: *ordering* is decided by the cost model's ±5% measurement noise — a
#: surrogate can learn which configs form the plateau (structure) but
#: not which plateau member the noise blessed (luck), so budgets below
#: the plateau width gate on luck.
DEFAULT_BUDGETS = (8, 16, 32, 64)

#: Every ``train_every``-th feasible entry (in key order) trains the
#: surrogates; the rest of the space is what ranking must generalize
#: to. 8 keeps the training sample small (12.5% of the space) — the
#: regime profile features are for: with scores scarce, hardware
#: structure has to come from somewhere other than the scores.
DEFAULT_TRAIN_EVERY = 8


class _Subset:
    """Adapter giving ``fit_from_dataset`` a reduced training view of a
    dataset (same space, fewer feasible entries)."""

    def __init__(self, dataset, entries):
        self._dataset = dataset
        self._entries = list(entries)

    def space(self):
        return self._dataset.space()

    def feasible(self):
        return list(self._entries)


def surrogate_rerank(dataset, budgets=DEFAULT_BUDGETS,
                     train_every: int = DEFAULT_TRAIN_EVERY) -> dict:
    """Compare plain vs profile-guided surrogate re-ranking on one
    recorded space.

    Both surrogates are fitted on the same deterministic training
    subsample (every ``train_every``-th feasible entry in key order),
    then rank *every* feasible config by predicted score; the recorded
    space is replayed in that order and the best score after each budget
    is reported as a fraction of the space's optimum (1.0 = found it).
    The profile surrogate's ranking may use any config's roofline
    counters — they come from the workload hook, not from measurements,
    so a real tuning session has them for free before evaluating
    anything.

    Returns a deterministic report dict (``surrogates`` rows carry
    ``fraction_at`` per budget and the fit quality).

    Example::

        r = surrogate_rerank(SpaceDataset.load("matmul....space.json"))
        r["surrogates"][1]["fraction_at"]["8"]   # profile surrogate @ 8
    """
    from repro.tuner.costmodel import fit_from_dataset

    feas = dataset.feasible()
    if len(feas) < 8:
        raise ValueError(f"recorded space too small to re-rank "
                         f"({len(feas)} feasible entries)")
    train = feas[::max(1, int(train_every))]
    best = dataset.best()
    optimum = best.score_us
    space = dataset.space()
    full_lookup = {
        space.freeze(e.config):
            np.array(profile_feature_vector(
                getattr(e, "profile", None) or {}))
        for e in feas}
    budgets = [int(b) for b in budgets]
    rows = []
    for name, use_profile in (("ridge", False), ("profile", True)):
        model = fit_from_dataset(_Subset(dataset, train),
                                 profile_features=use_profile)
        if use_profile:
            # Rank with every config's (pre-measurement) counters, not
            # just the training subsample's.
            model.profile_lookup = full_lookup
        ranked = sorted(
            feas, key=lambda e: (model.predict(e.config),
                                 dataset.key_for(e.config)))
        fraction_at = {}
        for b in budgets:
            found = min(e.score_us for e in ranked[:b])
            fraction_at[str(b)] = round(optimum / found, 6)
        rows.append({"surrogate": name,
                     "fraction_at": fraction_at,
                     "fit_quality": round(model.fit_quality(), 6)})
    return {
        "dataset": dataset.name(),
        "feasible": len(feas),
        "train_size": len(train),
        "train_every": int(train_every),
        "budgets": budgets,
        "optimum_us": round(optimum, 6),
        "surrogates": rows,
    }


def rerank_gate(report: dict) -> list[str]:
    """Regression gate over a :func:`surrogate_rerank` report: the
    profile-guided surrogate must meet or beat the plain ridge
    surrogate's fraction-of-optimum at every budget. Returns the list of
    violations (empty = pass) so benchmarks can assert on it.

    Example::

        problems = rerank_gate(surrogate_rerank(ds))
        assert not problems, problems
    """
    by_name = {r["surrogate"]: r for r in report["surrogates"]}
    plain, prof = by_name["ridge"], by_name["profile"]
    out = []
    for b in report["budgets"]:
        fp = prof["fraction_at"][str(b)]
        fr = plain["fraction_at"][str(b)]
        if fp + 1e-9 < fr:
            out.append(f"{report['dataset']}: profile surrogate "
                       f"{fp:.4f} < ridge {fr:.4f} at budget {b}")
    return out
