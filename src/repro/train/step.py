"""Train-step factory: microbatched gradient accumulation (scan), remat'd
model forward, AdamW update.

The step is a pure function (state, batch) -> (state, metrics), jitted by the
launcher with donated state and explicit in/out shardings. Within a jit, XLA
SPMD owns all gradient reductions (data/model/pod axes); the *compressed*
cross-pod synchronization is an outer-loop feature (local-SGD-style) in
``repro.runtime.crosspod`` — see DESIGN.md §6.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.optim.adamw import AdamW

TrainState = dict  # {"params": ..., "opt": ..., "step": int32}


def init_train_state(model, optimizer: AdamW, rng) -> TrainState:
    params = model.init(rng)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def _split_micro(batch: dict, n: int) -> dict:
    """(B, ...) -> (n, B/n, ...) for every leaf."""
    def sp(x):
        b = x.shape[0]
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(model, optimizer: AdamW,
                    microbatches: int = 1,
                    accum_dtype=jnp.float32,
                    online=None, online_warmup_steps: int = 20) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``accum_dtype``: gradient-accumulator dtype. bf16 halves accumulator
    memory and any gradient-sided collective traffic at a small noise cost
    (per-micro grads are still computed at full precision and summed).

    ``online``: optional ``repro.online.OnlineTuner`` (or list of them).
    During the first ``online_warmup_steps`` *eager* steps — warmup, before
    the loop is wrapped in an outer jit — each step sponsors one
    launch-budget slice of background tuning so kernel configs settle
    before the steady-state compiled loop is traced. Inside a jit the hook
    is a trace-time no-op.
    """
    if online is None:
        online = []
    elif not isinstance(online, (list, tuple)):
        online = [online]

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def accumulate(params, batch):
        if microbatches == 1:
            (_, metrics), grads = grad_fn(params, batch)
            return grads, metrics
        mbs = _split_micro(batch, microbatches)

        def body(carry, mb):
            acc, _ = carry
            (_, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(accum_dtype), acc, grads)
            return (acc, metrics), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                             params)
        first = jax.tree.map(lambda x: x[0], mbs)
        dummy_metrics = jax.eval_shape(lambda p, b: model.loss(p, b)[1],
                                       params, first)
        dummy = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                             dummy_metrics)
        (acc, metrics), _ = lax.scan(body, (zeros, dummy), mbs)
        grads = jax.tree.map(lambda g: g / microbatches, acc)
        return grads, metrics

    def train_step(state: TrainState, batch: dict):
        step = state["step"]
        if (online and not isinstance(step, jax.core.Tracer)
                and int(step) < online_warmup_steps):
            for svc in online:
                svc.tick()
        grads, metrics = accumulate(state["params"], batch)
        params, opt, opt_metrics = optimizer.update(grads, state["opt"],
                                                    state["params"])
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return ({"params": params, "opt": opt, "step": state["step"] + 1},
                metrics)

    return train_step
