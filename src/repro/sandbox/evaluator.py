"""Subprocess-isolated evaluation: run a candidate, survive anything.

``sandboxed_call`` runs an arbitrary zero-argument callable in a forked
child process with a wall-clock timeout and an optional address-space
ceiling, and maps whatever happens — a clean return, an exception, a
hang, an allocation bomb, a segfault — onto a
:class:`~repro.sandbox.verdict.SandboxVerdict` instead of propagating
the failure into the caller. ``SandboxedEvaluator`` wraps any tuner
evaluator (the ``Evaluate`` callables from :mod:`repro.tuner.runner`)
with that protection, so a tuning session can walk a space full of
crashing configs and simply record them as infeasible.

The ``fork`` start method is deliberate: nothing is pickled on the way
in (closures over builders and numpy arrays just work), and the child
inherits the warm parent state instead of re-importing jax. The
``inline`` method skips process isolation (exceptions are still mapped
to verdicts) — it is the right default where the evaluator is pure
Python arithmetic (cost model) and forking per config would dominate.
"""

from __future__ import annotations

import multiprocessing
import os
import tempfile
import time
import traceback
from dataclasses import dataclass
from typing import Any, Callable

from repro.obs import runtime as obs
from repro.tuner.costmodel import INFEASIBLE
from repro.tuner.runner import EvalResult

from .verdict import (STATUS_CRASH, STATUS_OK, STATUS_OOM, STATUS_TIMEOUT,
                      SandboxVerdict)

#: Histogram bounds (seconds) for sandbox wall-clock metrics.
SECONDS_BUCKETS = (0.05, 0.1, 0.25, 0.5, 1.0, 2.0, 5.0, 10.0, 30.0, 60.0,
                   120.0, 300.0)

#: Captured child stderr is truncated to this many characters.
STDERR_LIMIT = 4096

DEFAULT_TIMEOUT_S = 30.0


@dataclass(frozen=True)
class SandboxSettings:
    """Isolation knobs for one sandbox.

    ``timeout_s`` is the wall-clock ceiling per call (the child is
    SIGKILLed past it); ``memory_bytes`` caps the child's address space
    via ``RLIMIT_AS`` (None = no ceiling); ``method`` picks ``"fork"``
    (real child process — survives hangs, segfaults, allocation bombs)
    or ``"inline"`` (same process; exceptions still become verdicts but
    hangs/hard crashes are NOT contained — use only for evaluators that
    cannot hang, like the pure-Python cost model).

    Example::

        settings = SandboxSettings(timeout_s=5.0,
                                   memory_bytes=512 * 2**20)
    """

    timeout_s: float = DEFAULT_TIMEOUT_S
    memory_bytes: int | None = None
    method: str = "fork"

    def __post_init__(self) -> None:
        if self.method not in ("fork", "inline"):
            raise ValueError(f"unknown sandbox method {self.method!r}; "
                             f"use 'fork' or 'inline'")


#: Settings promotion gates use for oracle checks by default: in-process
#: (interpret-mode verification cannot hang, and forking a jax-warm
#: parent per check is both slow and thread-unsafe on some platforms).
INLINE = SandboxSettings(method="inline")


def _child_main(fn: Callable[[], Any], conn, stderr_fd: int,
                memory_bytes: int | None) -> None:
    os.dup2(stderr_fd, 2)
    try:
        # Re-point faulthandler at the captured stderr: a test harness in
        # the parent may have enabled it on a dup of the original fd 2,
        # which dup2 above does not touch — a segfaulting child would
        # dump its traceback to the user's terminal instead of the log.
        import faulthandler
        faulthandler.enable(2)
    except Exception:  # pragma: no cover — faulthandler is optional
        pass
    if memory_bytes is not None:
        import resource
        try:
            resource.setrlimit(resource.RLIMIT_AS,
                               (memory_bytes, memory_bytes))
        except (ValueError, OSError):  # pragma: no cover — platform quirk
            pass
    try:
        out = fn()
        conn.send(("ok", out))
    except MemoryError:
        conn.send(("oom", "MemoryError: allocation exceeded the sandbox "
                          "memory ceiling"))
    except BaseException as e:  # noqa: BLE001 — the whole point
        detail = f"{type(e).__name__}: {e}"
        traceback.print_exc()       # lands in the captured stderr file
        conn.send(("crash", detail))
    finally:
        conn.close()


def memory_ceiling(extra_bytes: int = 512 * 2**20) -> int:
    """A usable ``memory_bytes`` value: current address-space size plus
    ``extra_bytes`` headroom.

    ``RLIMIT_AS`` caps *virtual* address space, and a forked child
    inherits the parent's mappings — a jax-warm parent can hold
    gigabytes of (mostly untouched) reservations, so an absolute cap
    like "512 MB" would make every allocation in the child fail during
    sandbox bookkeeping and misreport as a crash. Anchoring the ceiling
    to the parent's current size means "the child may allocate about
    ``extra_bytes`` more than I already have" — which is the ceiling an
    allocation-bomb test actually wants.

    Example::

        settings = SandboxSettings(memory_bytes=memory_ceiling(256 * 2**20))
    """
    current = 0
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmSize:"):
                    current = int(line.split()[1]) * 1024
                    break
    except (OSError, ValueError, IndexError):  # pragma: no cover
        pass
    return current + int(extra_bytes)


def _read_stderr(path: str) -> str:
    try:
        with open(path, "r", errors="replace") as f:
            return f.read(STDERR_LIMIT)
    except OSError:  # pragma: no cover
        return ""


def sandboxed_call(fn: Callable[[], Any],
                   settings: SandboxSettings | None = None
                   ) -> tuple[SandboxVerdict, Any]:
    """Run ``fn`` under ``settings``; return ``(verdict, payload)``.

    ``payload`` is ``fn``'s return value when the verdict is ``ok`` and
    None otherwise. With ``method="fork"`` the return value crosses a
    pipe, so it must be picklable; with ``method="inline"`` anything
    goes (and only exceptions — not hangs or signals — are contained).

    Example::

        verdict, result = sandboxed_call(lambda: evaluator(config),
                                         SandboxSettings(timeout_s=5))
        if verdict.status == "timeout":
            ...
    """
    settings = settings if settings is not None else SandboxSettings()
    if settings.method == "inline":
        t0 = time.perf_counter()
        try:
            out = fn()
            return (SandboxVerdict(STATUS_OK, exit_cause="inline",
                                   wall_s=time.perf_counter() - t0), out)
        except MemoryError:
            return (SandboxVerdict(
                STATUS_OOM, detail="MemoryError",
                exit_cause="exception:MemoryError",
                wall_s=time.perf_counter() - t0), None)
        except Exception as e:  # noqa: BLE001 — map, never propagate
            return (SandboxVerdict(
                STATUS_CRASH, detail=f"{type(e).__name__}: {e}",
                exit_cause=f"exception:{type(e).__name__}",
                stderr=traceback.format_exc()[-STDERR_LIMIT:],
                wall_s=time.perf_counter() - t0), None)

    ctx = multiprocessing.get_context("fork")
    parent_conn, child_conn = ctx.Pipe(duplex=False)
    with tempfile.NamedTemporaryFile(prefix="sandbox-stderr-",
                                     suffix=".log") as errf:
        proc = ctx.Process(target=_child_main,
                           args=(fn, child_conn, errf.fileno(),
                                 settings.memory_bytes))
        t0 = time.perf_counter()
        proc.start()
        child_conn.close()
        proc.join(settings.timeout_s)
        wall_s = time.perf_counter() - t0
        if proc.is_alive():
            proc.kill()
            proc.join(10.0)
            return (SandboxVerdict(
                STATUS_TIMEOUT,
                detail=f"exceeded {settings.timeout_s:g}s wall-clock "
                       f"ceiling",
                exit_cause="killed:timeout", stderr=_read_stderr(errf.name),
                wall_s=wall_s), None)
        stderr = _read_stderr(errf.name)
        tag, payload = None, None
        if parent_conn.poll():
            try:
                tag, payload = parent_conn.recv()
            except (EOFError, OSError):  # pragma: no cover — torn pipe
                tag = None
        parent_conn.close()
        code = proc.exitcode
        cause = (f"signal:{-code}" if code is not None and code < 0
                 else f"exit:{code}")
        if tag == "ok":
            return (SandboxVerdict(STATUS_OK, exit_cause=cause,
                                   stderr=stderr, wall_s=wall_s), payload)
        if tag == "oom":
            return (SandboxVerdict(STATUS_OOM, detail=str(payload),
                                   exit_cause=cause, stderr=stderr,
                                   wall_s=wall_s), None)
        if tag == "crash":
            return (SandboxVerdict(STATUS_CRASH, detail=str(payload),
                                   exit_cause=cause, stderr=stderr,
                                   wall_s=wall_s), None)
        # Died before reporting: a signal (segfault, abort) — or the OS
        # OOM-killer, which the memory ceiling makes attributable.
        if settings.memory_bytes is not None and code == -9:
            return (SandboxVerdict(
                STATUS_OOM, detail="killed under the sandbox memory "
                                   "ceiling", exit_cause=cause,
                stderr=stderr, wall_s=wall_s), None)
        return (SandboxVerdict(
            STATUS_CRASH,
            detail=f"child died without reporting ({cause})",
            exit_cause=cause, stderr=stderr, wall_s=wall_s), None)


class SandboxedEvaluator:
    """Crash-isolation wrapper around any tuner evaluator.

    A drop-in ``Evaluate`` callable: delegates each config to the
    wrapped evaluator under :func:`sandboxed_call` and returns a normal
    :class:`~repro.tuner.runner.EvalResult`. Healthy configs pass
    through untouched; a hang, crash, OOM or raise becomes an
    *infeasible* result whose ``error`` is ``"sandbox:<status>: ..."``
    and whose ``info["sandbox"]`` carries the verdict status — which is
    exactly what dataset recording (:mod:`repro.tunebench`) persists, so
    replayed spaces remember which configs kill workers. Per-config
    verdicts are kept on :attr:`verdicts` for reporting.

    Example::

        ev = SandboxedEvaluator(WallClockEvaluator(builder, args),
                                SandboxSettings(timeout_s=10))
        r = ev(config)          # never raises, never hangs forever
        if not r.feasible and r.info.get("sandbox") == "timeout":
            ...
    """

    def __init__(self, evaluator: Callable[..., EvalResult],
                 settings: SandboxSettings | None = None,
                 record_to=None) -> None:
        self.evaluator = evaluator
        self.settings = settings if settings is not None else SandboxSettings()
        #: Optional dataset recorder (``record(config, EvalResult)``).
        self.record_to = record_to
        #: Verdicts in evaluation order: ``(config, SandboxVerdict)``.
        self.verdicts: list[tuple[dict, SandboxVerdict]] = []

    def _observe(self, verdict: SandboxVerdict) -> None:
        m = obs.metrics()
        if m is not None:
            m.counter("sandbox.verdict", status=verdict.status).inc()
            if verdict.status == STATUS_TIMEOUT:
                m.histogram("sandbox.timeout_s",
                            bounds=SECONDS_BUCKETS).observe(verdict.wall_s)
        tr = obs.tracer()
        if tr is not None and verdict.status != STATUS_OK:
            tr.instant("sandbox." + verdict.status, cat="sandbox",
                       detail=verdict.detail[:200])

    def _record(self, config, result: EvalResult) -> EvalResult:
        if self.record_to is not None:
            self.record_to.record(config, result)
        return result

    def __call__(self, config) -> EvalResult:
        def run() -> tuple:
            r = self.evaluator(config)
            # reduced, picklable payload (info can hold Workloads, which
            # must not cross the fork pipe)
            return (r.score_us, r.feasible, r.verified, r.error)
        tr = obs.tracer()
        if tr is not None:
            with tr.span("sandbox.eval", cat="sandbox",
                         method=self.settings.method):
                verdict, payload = sandboxed_call(run, self.settings)
        else:
            verdict, payload = sandboxed_call(run, self.settings)
        self.verdicts.append((dict(config), verdict))
        self._observe(verdict)
        if verdict.ok:
            score_us, feasible, verified, error = payload
            return self._record(config, EvalResult(
                score_us, feasible, verified=verified, error=error,
                info={"sandbox": STATUS_OK, "wall_s": verdict.wall_s}))
        return self._record(config, EvalResult(
            INFEASIBLE, False,
            error=f"sandbox:{verdict.status}: {verdict.detail}",
            info={"sandbox": verdict.status, "wall_s": verdict.wall_s,
                  "exit_cause": verdict.exit_cause}))
