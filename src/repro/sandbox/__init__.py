"""Crash-isolated evaluation + the correctness oracle gating promotion.

Auto-tuning executes machine-generated kernel variants, and some of
them are *bad*: they hang, segfault, exhaust memory, or — worst —
finish fast with the wrong answer. This package contains the two
defenses every promotion path in the repo runs behind:

* the **sandbox** (:mod:`~repro.sandbox.evaluator`): run any evaluator
  in a killed-on-timeout, memory-capped child process and classify what
  happened as a structured :class:`~repro.sandbox.verdict.SandboxVerdict`
  (``ok`` / ``timeout`` / ``crash`` / ``oom`` / ``numerics-mismatch``),
  with the child's stderr captured for the post-mortem;
* the **oracle** (:mod:`~repro.sandbox.oracle`,
  :mod:`~repro.sandbox.gate`): execute a winning config against the
  kernel's reference implementation on deterministic probe inputs and
  veto any promotion whose output does not match within dtype-aware
  tolerances. Passing records carry a ``verified`` provenance stamp.

The gate is wired into all three promotion paths — online hot-swap,
fleet shard-winner assembly, and cross-device transfer — and
:mod:`~repro.sandbox.faults` provides the fault-injection fixtures the
tests and the ``python -m repro.sandbox check --demo`` CI smoke use to
prove it. See ``docs/sandboxed-evaluation.md``.
"""

from .evaluator import (DEFAULT_TIMEOUT_S, SandboxedEvaluator,
                        SandboxSettings, memory_ceiling, sandboxed_call)
from .faults import (FAULT_MODES, FAULT_PARAM, FaultyEvaluator,
                     make_faulty_kernel)
from .gate import OracleGate, clear_verdict_cache
from .oracle import CorrectnessOracle
from .verdict import (STATUS_CRASH, STATUS_NUMERICS, STATUS_OK, STATUS_OOM,
                      STATUS_TIMEOUT, STATUS_UNVERIFIABLE, VERDICT_STATUSES,
                      SandboxVerdict)

__all__ = [
    "DEFAULT_TIMEOUT_S", "SandboxedEvaluator", "SandboxSettings",
    "memory_ceiling", "sandboxed_call",
    "FAULT_MODES", "FAULT_PARAM", "FaultyEvaluator", "make_faulty_kernel",
    "OracleGate", "clear_verdict_cache",
    "CorrectnessOracle",
    "STATUS_CRASH", "STATUS_NUMERICS", "STATUS_OK", "STATUS_OOM",
    "STATUS_TIMEOUT", "STATUS_UNVERIFIABLE", "VERDICT_STATUSES",
    "SandboxVerdict",
]
