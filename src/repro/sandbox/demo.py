"""End-to-end sandbox/oracle smoke: inject faults, count bad promotions.

``run_demo`` is the engine behind ``python -m repro.sandbox check
--demo`` (the CI sandbox-smoke job). It exercises the whole defense in
one process, with zero accelerator dependence:

1. **sandbox verdicts** — a :class:`~repro.sandbox.faults.FaultyEvaluator`
   is run through a fork :class:`~repro.sandbox.evaluator.SandboxedEvaluator`
   once per fault mode; the demo asserts a hang times out (without
   killing this process), a raise is a crash, an allocation bomb is an
   oom, a SIGSEGV is a crash with a signal exit cause;
2. **oracle verdicts** — the registered faulty kernel's honest config
   passes the :class:`~repro.sandbox.gate.OracleGate` and its ``wrong``
   config (fast but incorrect output) is a ``numerics-mismatch``;
3. **promotion paths** — the wrong config is offered as the winner to
   all three promotion paths (online pipeline, fleet assembly, transfer
   record) and must be rejected by each; the honest config must promote
   with ``verified`` provenance.

The returned report counts ``bad_promotions`` (a wrong config that
became wisdom anywhere); the CLI exits non-zero unless it is 0 and
every expectation held.
"""

from __future__ import annotations

import tempfile
from pathlib import Path

from repro.core.registry import register, unregister
from repro.core.wisdom import Wisdom
from repro.core.wisdom_kernel import WisdomKernel
from repro.distrib.sync import MemoryTransport, transport_wisdom
from repro.fleet.bus import ControlBus
from repro.fleet.coordinator import Coordinator
from repro.fleet.jobs import TuningJob, job_id_for, lease_name
from repro.online.promotion import PromotionPipeline
from repro.transfer.predictor import TransferPrediction, TransferResult

from .evaluator import SandboxedEvaluator, SandboxSettings, memory_ceiling
from .faults import FAULT_PARAM, FaultyEvaluator, make_faulty_kernel
from .gate import OracleGate, clear_verdict_cache
from .verdict import (STATUS_CRASH, STATUS_NUMERICS, STATUS_OK, STATUS_OOM,
                      STATUS_TIMEOUT)

#: Fault mode -> verdict status the fork sandbox must produce for it.
EXPECTED_VERDICTS = {
    "none": STATUS_OK,
    "raise": STATUS_CRASH,
    "segv": STATUS_CRASH,
    "oom": STATUS_OOM,
    "hang": STATUS_TIMEOUT,
}

_PROBLEM = (8, 8)
_DTYPE = "float32"
_DEVICE = "tpu-v5e"
_WRONG = {"scale": 1, FAULT_PARAM: "wrong"}
_HONEST = {"scale": 1, FAULT_PARAM: "none"}


def _verdict_summary(v) -> dict:
    """Deterministic slice of a verdict for the report (no wall times)."""
    out = {"status": v.status}
    if v.exit_cause:
        out["exit_cause"] = v.exit_cause
    if v.max_err is not None:
        out["mismatch"] = True
    return out


def _sandbox_section(timeout_s: float, hang_s: float,
                     headroom_bytes: int) -> tuple[dict, list]:
    """Fault-injected evaluator through the fork sandbox, per mode."""
    problems: list[str] = []
    sandbox = SandboxedEvaluator(
        FaultyEvaluator(hang_s=hang_s),
        SandboxSettings(timeout_s=timeout_s,
                        memory_bytes=memory_ceiling(headroom_bytes)))
    section: dict = {}
    for mode, want in EXPECTED_VERDICTS.items():
        result = sandbox({"scale": 1, FAULT_PARAM: mode})
        _config, verdict = sandbox.verdicts[-1]
        section[mode] = _verdict_summary(verdict)
        if verdict.status != want:
            problems.append(f"sandbox: fault={mode} produced verdict "
                            f"{verdict.status!r}, wanted {want!r}")
        if mode == "none" and not result.feasible:
            problems.append("sandbox: healthy config came back infeasible")
        if mode != "none" and result.feasible:
            problems.append(f"sandbox: fault={mode} came back feasible")
    return section, problems


def _oracle_section(builder, gate: OracleGate) -> tuple[dict, list]:
    problems: list[str] = []
    honest = gate.check(builder, _HONEST, _PROBLEM, _DTYPE)
    wrong = gate.check(builder, _WRONG, _PROBLEM, _DTYPE)
    if honest.status != STATUS_OK:
        problems.append(f"oracle: honest config verdict {honest.status!r} "
                        f"({honest.detail})")
    if wrong.status != STATUS_NUMERICS:
        problems.append(f"oracle: wrong config verdict {wrong.status!r}, "
                        f"wanted {STATUS_NUMERICS!r}")
    return ({"honest": _verdict_summary(honest),
             "wrong": _verdict_summary(wrong)}, problems)


def _online_path(builder, gate: OracleGate,
                 wisdom_dir: Path) -> tuple[dict, list, int]:
    """Wrong config wins the bracket; the pipeline must veto it, then
    promote the honest runner-up with verified provenance."""
    problems: list[str] = []
    kernel = WisdomKernel(builder, wisdom_dir=wisdom_dir,
                          device_kind=_DEVICE)
    pipeline = PromotionPipeline(kernel, wisdom_dir=wisdom_dir,
                                 oracle=gate)
    vetoed = pipeline.promote(_DEVICE, _PROBLEM, _DTYPE, _WRONG,
                              score_us=50.5, incumbent_score_us=200.0,
                              n_measurements=3, evals=16,
                              objective="costmodel")
    promoted = pipeline.promote(_DEVICE, _PROBLEM, _DTYPE, _HONEST,
                                score_us=101.0, incumbent_score_us=200.0,
                                n_measurements=3, evals=16,
                                objective="costmodel")
    if vetoed is not None:
        problems.append("online: wrong config was promoted")
    if not pipeline.rejections:
        problems.append("online: veto was not recorded as a rejection")
    if promoted is None:
        problems.append("online: honest config failed to promote")
    elif promoted.record.provenance.get("verified") is None:
        problems.append("online: promoted record lacks verified provenance")
    bad = sum(1 for rec in Wisdom.load(builder.name, wisdom_dir).records
              if rec.config.get(FAULT_PARAM) != "none")
    if bad:
        problems.append(f"online: {bad} wrong record(s) in the wisdom file")
    return ({"rejections": len(pipeline.rejections),
             "promotions": len(pipeline.promotions),
             "rejected_status": (pipeline.rejections[0].verdict.status
                                 if pipeline.rejections else None)},
            problems, bad)


def _fleet_path(builder, gate: OracleGate) -> tuple[dict, list, int]:
    """Wrong config wins a shard (and the cross-shard comparison); the
    coordinator must fall back to the honest shard winner."""
    problems: list[str] = []
    bus = ControlBus(MemoryTransport())
    coord = Coordinator(bus, n_shards=2, oracle=gate)
    key = (_DEVICE, _PROBLEM, _DTYPE)
    job = TuningJob(job_id=job_id_for(builder.name, key),
                    kernel=builder.name, device_kind=_DEVICE,
                    problem=_PROBLEM, dtype=_DTYPE, n_shards=2,
                    misses=5)
    bus.publish("job", job.job_id, job.to_json())
    shard_results = [
        {"job": job.job_id, "shard": "s000", "worker": "demo-w0",
         "strategy": "exhaustive", "evals": 8, "feasible_evals": 8,
         "best_config": dict(_WRONG), "best_score_us": 50.5},
        {"job": job.job_id, "shard": "s001", "worker": "demo-w1",
         "strategy": "exhaustive", "evals": 8, "feasible_evals": 8,
         "best_config": dict(_HONEST), "best_score_us": 101.0},
    ]
    for doc in shard_results:
        bus.publish("result", lease_name(job.job_id, doc["shard"]), doc)
    records = coord.assemble()
    done = bus.fetch("done", job.job_id)
    if len(records) != 1:
        problems.append(f"fleet: assembled {len(records)} records, wanted 1")
    elif records[0].config.get(FAULT_PARAM) != "none":
        problems.append("fleet: assembled record is the wrong config")
    elif records[0].provenance.get("verified") is None:
        problems.append("fleet: assembled record lacks verified provenance")
    rejected = (done or {}).get("rejected", [])
    if len(rejected) != 1:
        problems.append(f"fleet: done doc records {len(rejected)} "
                        f"rejections, wanted 1")
    bad = sum(1 for rec in transport_wisdom(bus.transport,
                                            builder.name).records
              if rec.config.get(FAULT_PARAM) != "none")
    if bad:
        problems.append(f"fleet: {bad} wrong record(s) in fleet wisdom")
    return ({"assembled": len(records), "rejected": len(rejected),
             "done_state": (done or {}).get("state")},
            problems, bad)


def _transfer_path(builder, gate: OracleGate) -> tuple[dict, list, int]:
    """Wrong config ranks first among predictions; ``record(gate=...)``
    must fall through to the honest runner-up."""
    problems: list[str] = []
    predictions = [
        TransferPrediction(config=dict(_WRONG), source_us=50.5,
                           smoothed_us=50.5, rank_us=50.5,
                           predicted_us=50.5),
        TransferPrediction(config=dict(_HONEST), source_us=101.0,
                           smoothed_us=101.0, rank_us=101.0,
                           predicted_us=101.0),
    ]
    result = TransferResult(
        kernel=builder.name, source_device="tpu-v4",
        target_device=_DEVICE, problem_size=_PROBLEM, dtype=_DTYPE,
        predictions=predictions, confidence=0.9,
        components={"entries": 2, "calibration": "workload"})
    try:
        record = result.record(gate=gate)
    except ValueError as e:
        problems.append(f"transfer: every prediction was vetoed ({e})")
        return {"recorded": None}, problems, 0
    bad = 0
    if record.config.get(FAULT_PARAM) != "none":
        bad = 1
        problems.append("transfer: recorded the wrong config")
    if record.provenance.get("verified") is None:
        problems.append("transfer: record lacks verified provenance")
    return ({"recorded": record.config.get(FAULT_PARAM),
             "score_us": record.score_us}, problems, bad)


def run_demo(timeout_s: float = 5.0,
             memory_mb: int | None = None,
             out_dir: Path | str | None = None) -> dict:
    """Run the whole injected-fault gauntlet; return the verdict report.

    ``report["pass"]`` is True iff every fault produced its expected
    verdict and ``report["bad_promotions"] == 0`` — i.e. no injected
    wrong-output config became wisdom on any promotion path.

    Example::

        report = run_demo(timeout_s=2.0)
        assert report["pass"], report["problems"]
    """
    builder = make_faulty_kernel(hang_s=3600.0)
    register(builder)
    clear_verdict_cache()
    problems: list[str] = []
    bad_promotions = 0
    try:
        # Fork sandboxing first: FaultyEvaluator is pure numpy, and
        # forking before anything warms jax keeps the children trivial.
        headroom = (memory_mb * 2**20 if memory_mb is not None
                    else 256 * 2**20)
        sandbox_report, p = _sandbox_section(timeout_s, hang_s=3600.0,
                                             headroom_bytes=headroom)
        problems += p

        gate = OracleGate()
        oracle_report, p = _oracle_section(builder, gate)
        problems += p

        if out_dir is not None:
            Path(out_dir).mkdir(parents=True, exist_ok=True)
            online_report, p, bad = _online_path(builder, gate,
                                                 Path(out_dir))
        else:
            with tempfile.TemporaryDirectory() as tmp:
                online_report, p, bad = _online_path(builder, gate,
                                                     Path(tmp))
        problems += p
        bad_promotions += bad

        fleet_report, p, bad = _fleet_path(builder, gate)
        problems += p
        bad_promotions += bad

        transfer_report, p, bad = _transfer_path(builder, gate)
        problems += p
        bad_promotions += bad
    finally:
        unregister(builder.name)
        clear_verdict_cache()

    return {
        "kernel": builder.name,
        "timeout_s": timeout_s,
        "sandbox": sandbox_report,
        "oracle": oracle_report,
        "paths": {"online": online_report, "fleet": fleet_report,
                  "transfer": transfer_report},
        "bad_promotions": bad_promotions,
        "problems": problems,
        "pass": not problems and bad_promotions == 0,
    }
