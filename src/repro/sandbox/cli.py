"""``python -m repro.sandbox`` — check candidates and run the fault demo.

Subcommands:

  check     verify one config of a registered kernel against its
            reference oracle (``--kernel/--problem/--dtype/--set``), or
            — with ``--demo`` — run the full injected-fault gauntlet
            (hang/crash/oom/wrong-output candidates through the fork
            sandbox and all three promotion paths) and fail unless zero
            bad promotions happened. ``--out`` writes the verdict
            report as JSON (the CI job uploads it as an artifact).

Examples::

    python -m repro.sandbox check --kernel matmul \
        --problem 256,256,256 --dtype float32 --set block_m=128
    python -m repro.sandbox check --demo --timeout 2 --out report.json
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.core.registry import get_kernel

from .demo import run_demo
from .gate import OracleGate


def _parse_set(pairs: list[str]) -> dict:
    config: dict = {}
    for pair in pairs:
        if "=" not in pair:
            raise SystemExit(f"--set needs name=value, got {pair!r}")
        name, value = pair.split("=", 1)
        for cast in (int, float):
            try:
                value = cast(value)
                break
            except ValueError:
                continue
        config[name] = value
    return config


def _cmd_check_one(args) -> int:
    try:
        builder = get_kernel(args.kernel)
    except KeyError:
        print(f"unknown kernel {args.kernel!r}", file=sys.stderr)
        return 2
    problem = tuple(int(d) for d in args.problem.split(",") if d)
    config = dict(builder.space.default_config())
    config.update(_parse_set(args.set or []))
    gate = OracleGate()
    verdict = gate.check(builder, config, problem, args.dtype)
    doc = {"kernel": args.kernel, "problem": list(problem),
           "dtype": args.dtype, "config": config,
           "verdict": verdict.to_json()}
    if args.out:
        Path(args.out).write_text(json.dumps(doc, indent=2,
                                             sort_keys=True) + "\n")
    print(f"{args.kernel} {problem} {args.dtype}: {verdict.status}"
          + (f" ({verdict.detail})" if verdict.detail else ""))
    return 0 if gate.allows(verdict) else 1


def _cmd_check(args) -> int:
    if not args.demo:
        if not args.kernel:
            print("check needs --kernel (or --demo)", file=sys.stderr)
            return 2
        return _cmd_check_one(args)
    report = run_demo(timeout_s=args.timeout, memory_mb=args.memory_mb)
    if args.out:
        Path(args.out).write_text(json.dumps(report, indent=2,
                                             sort_keys=True) + "\n")
        print(f"verdict report -> {args.out}")
    print(f"sandbox verdicts: "
          + ", ".join(f"{mode}={v['status']}"
                      for mode, v in sorted(report["sandbox"].items())))
    print(f"oracle: honest={report['oracle']['honest']['status']}, "
          f"wrong={report['oracle']['wrong']['status']}")
    for path, doc in sorted(report["paths"].items()):
        print(f"  {path}: {json.dumps(doc, sort_keys=True)}")
    print(f"bad promotions: {report['bad_promotions']}")
    for problem in report["problems"]:
        print(f"FAIL: {problem}", file=sys.stderr)
    print("PASS" if report["pass"] else "FAIL")
    return 0 if report["pass"] else 1


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sandbox",
        description="Crash-isolated evaluation and the correctness "
                    "oracle that gates wisdom promotion.")
    sub = ap.add_subparsers(dest="command", required=True)

    p = sub.add_parser("check",
                       help="oracle-check a config, or run the fault demo")
    p.add_argument("--demo", action="store_true",
                   help="run the injected-fault gauntlet (hang, crash, "
                        "oom, wrong output) through the sandbox and all "
                        "three promotion paths")
    p.add_argument("--kernel", default=None,
                   help="registered kernel to check (non-demo mode)")
    p.add_argument("--problem", default="256,256,256",
                   help="comma-separated problem size")
    p.add_argument("--dtype", default="float32")
    p.add_argument("--set", nargs="*", default=None, metavar="NAME=VALUE",
                   help="config overrides on top of the space default")
    p.add_argument("--timeout", type=float, default=5.0,
                   help="sandbox wall-clock ceiling in seconds (demo)")
    p.add_argument("--memory-mb", type=int, default=None,
                   help="sandbox memory headroom in MiB (demo)")
    p.add_argument("--out", default=None,
                   help="write the verdict report JSON here")
    p.set_defaults(fn=_cmd_check)
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
