"""Fault injection: evaluators and kernels that misbehave on demand.

The sandbox's test fixtures and the CI smoke demo both need candidates
that hang, raise, segfault, allocate without bound, or silently compute
the wrong answer — per config, deterministically. Two injection sites:

* :class:`FaultyEvaluator` — a pure-Python ``Evaluate`` callable whose
  behaviour is driven by the config's ``fault`` value. Exercises
  :class:`~repro.sandbox.evaluator.SandboxedEvaluator` with zero kernel
  machinery (and zero jax state, which keeps fork-based tests clean).
* :func:`make_faulty_kernel` — a registrable
  :class:`~repro.core.builder.KernelBuilder` whose *built kernel*
  misbehaves the same way, with an honest reference and probe. This is
  what proves the :class:`~repro.sandbox.gate.OracleGate` rejects
  wrong-output winners in the real promotion paths: the cost model
  scores the ``wrong`` variant as the *fastest* config, so any ungated
  path would promote it.
"""

from __future__ import annotations

import os
import signal
import time

import numpy as np

from repro.core.builder import KernelBuilder, probe_array
from repro.core.workload import Workload
from repro.tuner.runner import EvalResult

#: The tunable that injects faults. ``none`` behaves; everything else is
#: one of the sandbox's failure modes.
FAULT_PARAM = "fault"
FAULT_MODES = ("none", "wrong", "hang", "raise", "oom", "segv")

#: Cost-model speed multiplier per fault mode. ``wrong`` is the FASTEST
#: config on purpose: an ungated promotion path would pick it.
_COST_FACTOR = {"none": 1.0, "wrong": 0.5, "hang": 0.8, "raise": 0.85,
                "oom": 0.9, "segv": 0.95}


def _misbehave(mode: str, hang_s: float) -> None:
    """Perform the injected fault (never returns for hang/segv)."""
    if mode == "hang":
        time.sleep(hang_s)
    elif mode == "raise":
        raise RuntimeError("injected evaluator fault")
    elif mode == "oom":
        hoard = []
        while True:        # allocation bomb: stopped by RLIMIT_AS
            hoard.append(np.ones((1024, 1024), np.float64))
    elif mode == "segv":
        os.kill(os.getpid(), signal.SIGSEGV)


class FaultyEvaluator:
    """An ``Evaluate`` callable that fails the way the config says.

    ``config["fault"]`` selects the behaviour: ``none`` returns a
    deterministic feasible score, ``hang`` sleeps ``hang_s`` seconds,
    ``raise`` raises, ``oom`` allocates without bound, ``segv`` delivers
    SIGSEGV to its own process, and ``wrong`` returns a feasible score
    (wrong *output* only matters to the oracle, which runs kernels, not
    evaluators).

    Example::

        ev = SandboxedEvaluator(FaultyEvaluator(),
                                SandboxSettings(timeout_s=0.5))
        ev({"fault": "hang"})    # -> infeasible, sandbox:timeout
    """

    def __init__(self, base_score_us: float = 100.0,
                 hang_s: float = 3600.0) -> None:
        self.base_score_us = base_score_us
        self.hang_s = hang_s
        self.calls = 0

    def __call__(self, config) -> EvalResult:
        self.calls += 1
        mode = str(config.get(FAULT_PARAM, "none"))
        _misbehave(mode, self.hang_s)
        scale = int(config.get("scale", 1))
        return EvalResult(self.base_score_us * _COST_FACTOR.get(mode, 1.0)
                          * (1.0 + 0.01 * scale), True)


def make_faulty_kernel(name: str = "faulty_mul2",
                       hang_s: float = 3600.0) -> KernelBuilder:
    """A tunable kernel whose built variant misbehaves per config.

    The honest computation is ``y = 2 * x`` (reference included, probe
    included, workload included — a fully oracle-checkable kernel). The
    ``fault`` tunable corrupts it: ``wrong`` returns a plausibly-scaled
    but incorrect output, ``hang``/``raise``/``oom``/``segv`` do exactly
    that *when the built kernel executes* — i.e. inside the oracle's
    check. Register it with :func:`repro.core.register` (and unregister
    after) to drive end-to-end promotion-gate tests and the CI demo.

    Example::

        builder = make_faulty_kernel()
        register(builder)
        try:
            verdict = OracleGate().check(builder, {"fault": "wrong",
                                                   "scale": 1},
                                         (64, 64), "float32")
            assert verdict.status == "numerics-mismatch"
        finally:
            unregister(builder.name)
    """
    b = KernelBuilder(name, source="repro.sandbox.faults")
    b.tune("scale", (1, 2, 4), default=1)
    b.tune(FAULT_PARAM, FAULT_MODES, default="none")

    @b.problem_size
    def _problem(x):
        return tuple(int(d) for d in x.shape)

    @b.build
    def _build(config, problem, meta, interpret: bool = False):
        mode = str(config[FAULT_PARAM])

        def run(x):
            _misbehave(mode, hang_s)
            out = np.asarray(x, np.float64) * 2.0
            if mode == "wrong":
                # well past any dtype tolerance, but not absurd
                out = out * 1.05 + 0.1
            return out.astype(np.asarray(x).dtype)

        return run

    @b.reference
    def _reference(x):
        return (np.asarray(x, np.float64) * 2.0).astype(
            np.asarray(x).dtype)

    @b.probe
    def _probe(problem, dtype):
        rng = np.random.default_rng(0)
        return (probe_array(rng, problem, dtype),)

    @b.workload
    def _workload(config, problem, dtype):
        n = 1
        for d in problem:
            n *= int(d)
        factor = _COST_FACTOR.get(str(config[FAULT_PARAM]), 1.0)
        scale = int(config["scale"])
        return Workload(
            flops=float(n), hbm_bytes=8.0 * n * factor * (1 + 0.01 * scale),
            vmem_bytes=4096, grid=1,
            notes={"fault": config[FAULT_PARAM]})

    return b
