"""CorrectnessOracle: does a surviving candidate compute the right thing?

The sandbox proves a config *runs*; the oracle proves it runs
*correctly*. Each check executes the built kernel (interpret mode by
default, so it works on any host) on concrete probe arguments and
compares against the kernel's pure-jnp reference via
:func:`repro.tuner.runner.verify_outcome` with dtype-aware rtol/atol —
the KTT-style reference-output validation the tuning literature treats
as a first-class part of any tuning run. Verdicts are cached per config
(the check is deterministic), and the check itself can run inside the
fork sandbox so a segfaulting kernel build cannot take the oracle down.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.builder import KernelBuilder
from repro.core.param import Config
from repro.obs import runtime as obs
from repro.tuner.runner import VerifyOutcome, verify_outcome

from .evaluator import SandboxSettings, sandboxed_call
from .verdict import (STATUS_CRASH, STATUS_NUMERICS, STATUS_OK,
                      SandboxVerdict)

#: Histogram bounds for oracle max-abs-error observations (log-spaced).
ERROR_BUCKETS = (1e-8, 1e-6, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0)


def _outcome_to_verdict(out: VerifyOutcome,
                        base: SandboxVerdict) -> SandboxVerdict:
    if out.ok:
        status, detail = STATUS_OK, ""
    elif out.kind == "build":
        status, detail = STATUS_CRASH, out.error
    else:                           # "structure" or "numerics"
        status, detail = STATUS_NUMERICS, out.error
    return SandboxVerdict(
        status, detail=detail, exit_cause=base.exit_cause,
        stderr=base.stderr, wall_s=base.wall_s,
        max_err=out.max_err, rtol=out.rtol, atol=out.atol)


class CorrectnessOracle:
    """Reference-output validation for one (builder, args) scenario.

    ``check(config)`` returns a :class:`SandboxVerdict`: ``ok`` (with
    ``max_err``/``rtol``/``atol`` filled in), ``numerics-mismatch``,
    ``crash`` (the kernel would not build/run), or — when constructed
    with fork ``settings`` — ``timeout``/``oom`` if the check itself had
    to be killed. Verdicts are cached by frozen config.

    Example::

        oracle = CorrectnessOracle(get_kernel("matmul"),
                                   builder.make_probe_args((256,) * 3,
                                                           "float32"))
        verdict = oracle.check({"block_m": 128, ...})
        assert verdict.ok, verdict.detail
    """

    def __init__(self, builder: KernelBuilder,
                 args: Sequence[np.ndarray],
                 interpret: bool = True,
                 settings: SandboxSettings | None = None) -> None:
        self.builder = builder
        self.args = [np.asarray(a) for a in args]
        self.interpret = interpret
        #: None = verify in-process (interpret-mode execution cannot
        #: hang); pass fork settings to also contain hard crashes.
        self.settings = settings
        self.verdicts: dict[tuple, SandboxVerdict] = {}

    def _observe(self, verdict: SandboxVerdict) -> None:
        m = obs.metrics()
        if m is not None:
            m.counter("oracle.checks", kernel=self.builder.name,
                      status=verdict.status).inc()
            if verdict.max_err is not None:
                m.histogram("oracle.max_err", bounds=ERROR_BUCKETS,
                            kernel=self.builder.name
                            ).observe(verdict.max_err)
        tr = obs.tracer()
        if tr is not None and not verdict.ok:
            tr.instant("oracle." + verdict.status, cat="sandbox",
                       kernel=self.builder.name,
                       detail=verdict.detail[:200])

    def check(self, config: Config) -> SandboxVerdict:
        """The cached verdict for ``config`` (computing it on miss)."""
        key = self.builder.space.freeze(config)
        hit = self.verdicts.get(key)
        if hit is not None:
            return hit

        def run() -> VerifyOutcome:
            return verify_outcome(self.builder, config, self.args,
                                  interpret=self.interpret)

        base, outcome = sandboxed_call(
            run, self.settings if self.settings is not None
            else SandboxSettings(method="inline"))
        if base.ok:
            verdict = _outcome_to_verdict(outcome, base)
        else:
            verdict = base          # timeout / crash / oom of the check
        self.verdicts[key] = verdict
        self._observe(verdict)
        return verdict
