"""SandboxVerdict — the structured outcome of one isolated evaluation.

Every candidate config that goes through the sandbox (or the
correctness oracle) gets exactly one verdict from a closed taxonomy, so
callers branch on a status string instead of parsing tracebacks:

  ``ok``                 ran to completion (oracle: and matched the
                         reference within tolerance)
  ``timeout``            exceeded the wall-clock ceiling; the child was
                         killed, the parent kept running
  ``crash``              raised, aborted, or died on a signal
                         (``exit_cause`` says which; segfaults land here)
  ``oom``                exceeded the memory ceiling (``MemoryError``
                         under ``RLIMIT_AS``, or killed by the OS)
  ``numerics-mismatch``  executed fine but the output disagrees with the
                         reference oracle beyond dtype-aware rtol/atol
  ``unverifiable``       the kernel has no probe/build/reference hooks,
                         so correctness cannot be checked (policy
                         decides whether that blocks promotion)
"""

from __future__ import annotations

from dataclasses import dataclass

STATUS_OK = "ok"
STATUS_TIMEOUT = "timeout"
STATUS_CRASH = "crash"
STATUS_OOM = "oom"
STATUS_NUMERICS = "numerics-mismatch"
STATUS_UNVERIFIABLE = "unverifiable"

#: The closed verdict taxonomy, in severity-neutral declaration order.
VERDICT_STATUSES = (STATUS_OK, STATUS_TIMEOUT, STATUS_CRASH, STATUS_OOM,
                    STATUS_NUMERICS, STATUS_UNVERIFIABLE)


@dataclass
class SandboxVerdict:
    """What happened to one config inside the sandbox/oracle.

    ``detail`` is the human-readable cause (exception text, allclose
    message), ``exit_cause`` the mechanical one (``"exit:N"``,
    ``"signal:N"``, ``"exception:Type"``, ``"inline"``), ``stderr`` the
    captured (truncated) child stderr. The oracle additionally fills
    ``max_err``/``rtol``/``atol`` so provenance and reports can say how
    close the comparison was.

    Example::

        verdict = oracle.check(config)
        if verdict.status == STATUS_NUMERICS:
            print(f"wrong output: {verdict.detail}")
    """

    status: str
    detail: str = ""
    exit_cause: str = ""
    stderr: str = ""
    wall_s: float = 0.0
    max_err: float | None = None
    rtol: float | None = None
    atol: float | None = None

    def __post_init__(self) -> None:
        if self.status not in VERDICT_STATUSES:
            raise ValueError(f"unknown verdict status {self.status!r}; "
                             f"have {VERDICT_STATUSES}")

    @property
    def ok(self) -> bool:
        return self.status == STATUS_OK

    def to_json(self) -> dict:
        out = {"status": self.status, "detail": self.detail,
               "exit_cause": self.exit_cause, "stderr": self.stderr,
               "wall_s": round(self.wall_s, 6)}
        if self.max_err is not None:
            out["max_err"] = self.max_err
        if self.rtol is not None:
            out["rtol"] = self.rtol
        if self.atol is not None:
            out["atol"] = self.atol
        return out

    @staticmethod
    def from_json(d: dict) -> "SandboxVerdict":
        return SandboxVerdict(
            status=str(d["status"]), detail=str(d.get("detail", "")),
            exit_cause=str(d.get("exit_cause", "")),
            stderr=str(d.get("stderr", "")),
            wall_s=float(d.get("wall_s", 0.0)),
            max_err=d.get("max_err"), rtol=d.get("rtol"),
            atol=d.get("atol"))
