"""OracleGate — the mandatory correctness gate on wisdom promotion.

Every path that turns a tuning winner into a served
:class:`~repro.core.wisdom.WisdomRecord` — online hot-swap
(:mod:`repro.online.promotion`), fleet shard-winner assembly
(:mod:`repro.fleet.coordinator`), and the cross-device transfer
predict→verify→promote loop (:mod:`repro.transfer`) — asks one question
first: *does this config compute the right answer?* The gate answers it
by synthesizing deterministic probe arguments for the scenario (the
kernel's ``probe`` hook), running the config through a
:class:`~repro.sandbox.oracle.CorrectnessOracle`, and returning the
verdict. Configs that pass get a ``verified: {rtol, atol, ref}`` stamp
in their record provenance; configs that fail (``numerics-mismatch``,
``crash``, ``timeout``, ``oom``) never become wisdom.

Kernels without probe/build/reference hooks (capability-registered
stubs, synthetic test kernels) yield ``unverifiable``; the
``on_unverifiable`` policy decides whether that blocks promotion
(default ``"allow"`` — a kernel that *cannot* be checked is not the
same as one that failed a check).

Verdicts are cached process-wide: the check is a deterministic function
of (kernel, config, problem, dtype), so every gate instance shares one
cache and repeated promotions of the same winner cost one verification
total.
"""

from __future__ import annotations

from repro.core.builder import KernelBuilder
from repro.core.param import Config
from repro.core.registry import get_kernel

from .evaluator import SandboxSettings
from .oracle import CorrectnessOracle
from .verdict import STATUS_OK, STATUS_UNVERIFIABLE, SandboxVerdict

#: Process-wide verdict cache: (kernel, problem, dtype, frozen config,
#: interpret) -> SandboxVerdict. Shared across OracleGate instances.
_VERDICT_CACHE: dict[tuple, SandboxVerdict] = {}


def clear_verdict_cache() -> None:
    """Drop the process-wide oracle verdict cache (tests that mutate a
    kernel's hooks between checks need this; production never does).

    Example::

        register(make_faulty_kernel())
        clear_verdict_cache()       # stale verdicts from a prior fixture
    """
    _VERDICT_CACHE.clear()


class OracleGate:
    """Shared correctness gate for all three promotion paths.

    ``check`` verifies one (config, problem, dtype) for a kernel and
    returns the :class:`SandboxVerdict`; ``allows`` maps a verdict to a
    promote/reject decision under the ``on_unverifiable`` policy;
    ``stamp`` adds the ``verified`` provenance block to a passing
    record's provenance. ``settings=None`` verifies in-process (the
    interpret-mode check cannot hang); pass fork
    :class:`~repro.sandbox.evaluator.SandboxSettings` to also contain
    kernels that crash the process during the check.

    Example::

        gate = OracleGate()
        verdict = gate.check("matmul", config, (256, 256, 256),
                             "float32")
        if gate.allows(verdict):
            provenance = gate.stamp(provenance, "matmul", verdict)
    """

    def __init__(self, interpret: bool = True,
                 settings: SandboxSettings | None = None,
                 on_unverifiable: str = "allow") -> None:
        if on_unverifiable not in ("allow", "reject"):
            raise ValueError(f"unknown on_unverifiable policy "
                             f"{on_unverifiable!r}; use 'allow' or "
                             f"'reject'")
        self.interpret = interpret
        self.settings = settings
        self.on_unverifiable = on_unverifiable
        #: Every check this gate made: (kernel, scenario-ish key,
        #: SandboxVerdict) in call order — for reports and tests.
        self.checks: list[tuple[str, tuple, SandboxVerdict]] = []
        self._oracles: dict[tuple, CorrectnessOracle] = {}

    # -- verdict production ----------------------------------------------------

    def _resolve(self, kernel) -> tuple[KernelBuilder | None, str]:
        if isinstance(kernel, KernelBuilder):
            return kernel, kernel.name
        try:
            return get_kernel(str(kernel)), str(kernel)
        except KeyError:
            return None, str(kernel)

    def _unverifiable(self, why: str) -> SandboxVerdict:
        return SandboxVerdict(STATUS_UNVERIFIABLE, detail=why)

    def _oracle(self, builder: KernelBuilder, problem: tuple[int, ...],
                dtype: str) -> CorrectnessOracle | SandboxVerdict:
        key = (builder.name, tuple(problem), dtype)
        oracle = self._oracles.get(key)
        if oracle is not None:
            return oracle
        try:
            args = builder.make_probe_args(problem, dtype)
        except Exception as e:  # noqa: BLE001 — probe itself misbehaved
            return self._unverifiable(
                f"probe failed for problem {tuple(problem)}: "
                f"{type(e).__name__}: {e}")
        oracle = CorrectnessOracle(builder, args, interpret=self.interpret,
                                   settings=self.settings)
        self._oracles[key] = oracle
        return oracle

    def check(self, kernel, config: Config, problem: tuple[int, ...],
              dtype: str) -> SandboxVerdict:
        """Verdict for promoting ``config`` for this scenario.

        ``kernel`` is a :class:`KernelBuilder` or a registry name; an
        unregistered name or a kernel lacking probe/build/reference
        hooks yields ``unverifiable`` rather than an error.
        """
        problem = tuple(int(x) for x in problem)
        builder, name = self._resolve(kernel)
        if builder is None:
            verdict = self._unverifiable(
                f"kernel {name!r} is not registered on this host")
        elif not (builder.has_probe() and builder._build is not None
                  and builder._reference is not None):
            verdict = self._unverifiable(
                f"kernel {name!r} has no probe/build/reference hooks")
        else:
            cache_key = (name, problem, dtype,
                         builder.space.freeze(config), self.interpret)
            verdict = _VERDICT_CACHE.get(cache_key)
            if verdict is None:
                oracle = self._oracle(builder, problem, dtype)
                if isinstance(oracle, SandboxVerdict):
                    verdict = oracle
                else:
                    verdict = oracle.check(config)
                _VERDICT_CACHE[cache_key] = verdict
        self.checks.append((name, (problem, dtype), verdict))
        return verdict

    def check_record(self, kernel, record) -> SandboxVerdict:
        """:meth:`check` for a :class:`~repro.core.wisdom.WisdomRecord`
        (scenario taken from the record itself)."""
        return self.check(kernel, record.config, record.problem_size,
                          record.dtype)

    # -- decisions -------------------------------------------------------------

    def allows(self, verdict: SandboxVerdict) -> bool:
        """Whether a verdict lets the config become wisdom."""
        if verdict.status == STATUS_OK:
            return True
        if verdict.status == STATUS_UNVERIFIABLE:
            return self.on_unverifiable == "allow"
        return False

    def stamp(self, provenance: dict, kernel_name: str,
              verdict: SandboxVerdict) -> dict:
        """Provenance with the oracle's ``verified`` block added.

        Only ``ok`` verdicts stamp (anything else returns the input
        unchanged); the block is deterministic — tolerances and the
        reference identity, no floats measured at check time — so
        fleet/transfer records stay byte-identical across hosts.
        """
        if verdict.status != STATUS_OK:
            return dict(provenance)
        out = dict(provenance)
        out["verified"] = {"rtol": verdict.rtol, "atol": verdict.atol,
                           "ref": f"{kernel_name}.reference"}
        return out
