"""Entry point for ``python -m repro.sandbox``."""

from .cli import main

if __name__ == "__main__":
    raise SystemExit(main())
