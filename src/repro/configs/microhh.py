"""The paper's own application: MicroHH CFD kernel scenarios (§5).

16 scenarios = {advec_u, diff_uvw} x {256^3, 512^3} x {float32, bfloat16}
x {tpu-v5e, tpu-v4} — the TPU analogue of the paper's
{advec_u, diff_uvw} x {256^3, 512^3} x {float, double} x {A4000, A100}.
Benchmarks iterate this table to reproduce Figs 2-5 and Tables 3-5.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass

KERNELS = ("advec_u", "diff_uvw")
GRIDS = ((256, 256, 256), (512, 512, 512))
DTYPES = ("float32", "bfloat16")     # paper: float / double
DEVICES = ("tpu-v5e", "tpu-v4")      # paper: A4000 / A100

# smaller grids for fast CI / smoke paths
SMOKE_GRIDS = ((32, 32, 128), (64, 64, 128))


@dataclass(frozen=True)
class Scenario:
    kernel: str
    grid: tuple[int, int, int]
    dtype: str
    device: str

    @property
    def key(self) -> str:
        g = self.grid[0]
        return f"{self.kernel}-{g}^3-{self.dtype}-{self.device}"


def scenarios(grids=GRIDS) -> list[Scenario]:
    return [Scenario(k, g, p, d)
            for k, g, p, d in itertools.product(KERNELS, grids, DTYPES,
                                                DEVICES)]
