"""Assigned-architecture registry: one module per architecture, each
exporting ``CONFIG``. ``get_arch("deepseek-v2-236b")`` returns the full
config; ``get_arch(name).reduced()`` the CPU smoke variant."""

from __future__ import annotations

import importlib

from repro.models.config import ArchConfig

ARCH_MODULES: dict[str, str] = {
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "llama-3.2-vision-11b": "repro.configs.llama_3_2_vision_11b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "codeqwen1.5-7b": "repro.configs.codeqwen1_5_7b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "whisper-base": "repro.configs.whisper_base",
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCH_MODULES)}")
    return importlib.import_module(ARCH_MODULES[name]).CONFIG


def list_archs() -> list[str]:
    return sorted(ARCH_MODULES)
