"""rwkv6-7b [ssm] — Finch, data-dependent decay (arXiv:2404.05892). 32L
d_model=4096 (attention-free) d_ff=14336 vocab=65536; 64 wkv heads of 64.
Decode carries only (state, shift) — O(1) in context length."""

from repro.models.config import ArchConfig, RWKVCfg

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,
    n_kv_heads=64,
    d_head=64,
    d_ff=14336,
    vocab=65536,
    mixer="rwkv",
    rwkv=RWKVCfg(decay_lora=64, head_dim=64),
    pos="none",
    supports_long_context=True,
)
