"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer
(arXiv:2411.13676). 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16. SWA(1024) with global-attention layers {0, 15, 31}; meta
tokens omitted (backbone only)."""

from repro.models.config import ArchConfig, FULL_WINDOW, MambaCfg

_GLOBAL_LAYERS = (0, 15, 31)

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab=32001,
    mixer="mamba+attn",
    mamba=MambaCfg(d_state=16, expand=2, d_conv=4),
    windows=tuple(FULL_WINDOW if i in _GLOBAL_LAYERS else 1024
                  for i in range(32)),
    rope_theta=10000.0,
    supports_long_context=True,   # SWA + 3 global layers; B=1 500k decode ok
)
