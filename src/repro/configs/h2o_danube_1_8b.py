"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention
(arXiv:2401.16818). 24L d_model=2560 32H (GQA kv=8, d_head=80) d_ff=6912
vocab=32000, SWA(4096) all layers — the bounded window makes 500k-context
decode feasible (ring-sized effective cache)."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_head=80,
    d_ff=6912,
    vocab=32000,
    windows=(4096,) * 24,
    supports_long_context=True,
)
