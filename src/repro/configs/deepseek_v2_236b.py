"""deepseek-v2-236b [moe] — MLA + fine-grained MoE (arXiv:2405.04434). 60L
d_model=5120 128H, MLA kv_lora=512 q_lora=1536 (d_nope=128, d_rope=64,
d_v=128); 2 shared + 160 routed top-6 experts of d_expert=1536; dense FFN
(12288) at layer 0; vocab=102400. Decode uses the absorbed-MLA cache."""

from repro.models.config import ArchConfig, MLACfg, MoECfg

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_head=128,
    d_ff=12288,                    # dense FFN width (layer 0)
    vocab=102400,
    mla=MLACfg(q_lora=1536, kv_lora=512, d_nope=128, d_rope=64, d_v=128),
    moe=MoECfg(n_routed=160, top_k=6, d_expert=1536, n_shared=2,
               capacity_factor=1.25, chunk=256),
    dense_layers=(0,),
)
