"""stablelm-1.6b [dense] (hf:stabilityai/stablelm-2-1_6b). 24L d_model=2048
32H (kv=32) d_ff=5632 vocab=100352; LayerNorm and 25% partial rotary."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_head=64,
    d_ff=5632,
    vocab=100352,
    norm="ln",
    rope_frac=0.25,
)
