"""deepseek-moe-16b [moe] — fine-grained MoE (arXiv:2401.06066). 28L
d_model=2048 16H d_ff(dense layer 0)=10944 vocab=102400; 2 shared + 64
routed top-6 experts of d_expert=1408."""

from repro.models.config import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=10944,                    # dense FFN width (layer 0)
    vocab=102400,
    moe=MoECfg(n_routed=64, top_k=6, d_expert=1408, n_shared=2,
               capacity_factor=1.25, chunk=256),
    dense_layers=(0,),
)
