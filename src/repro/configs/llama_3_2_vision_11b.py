"""llama-3.2-vision-11b [vlm] — cross-attention image layers every 5th layer
(hf:meta-llama/Llama-3.2-11B-Vision). 40L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=128256. The vision frontend is a stub: ``input_specs``
provides precomputed patch embeddings (B, 576, d_model)."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=128256,
    cross_attn_period=5,          # 8 gated cross blocks + 32 self layers
    n_img_tokens=576,
    rope_theta=500000.0,
)
