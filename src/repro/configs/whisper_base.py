"""whisper-base [audio] — enc-dec backbone (arXiv:2212.04356). 6+6L
d_model=512 8H d_ff=2048 vocab=51865; LayerNorm, GELU (non-gated MLP),
learned positions. The conv/mel frontend is a stub: ``input_specs`` provides
precomputed frame embeddings (B, 1500, 512). decode/prefill shapes stress
the backbone with synthetic 32k decoder contexts (noted in DESIGN.md)."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,                    # decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_head=64,
    d_ff=2048,
    vocab=51865,
    norm="ln",
    act="gelu",
    gated_mlp=False,
    tie_embeddings=True,          # whisper ties decoder embed / head
    pos="learned",
    max_seq=40960,
    enc_dec=True,
    n_enc_layers=6,
    enc_seq=1500,
)
