"""gemma2-2b [dense] — local/global alternating attention with logit
softcaps (arXiv:2408.00118). 26L d_model=2304 8H (GQA kv=4, d_head=256)
d_ff=9216 vocab=256000; attn softcap 50, final softcap 30; pre+post
(sandwich) norms; tied embeddings; GeGLU."""

from repro.models.config import ArchConfig, FULL_WINDOW

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=9216,
    vocab=256000,
    windows=tuple(4096 if i % 2 == 0 else FULL_WINDOW for i in range(26)),
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norm=True,
    tie_embeddings=True,
    act="gelu",
)
