"""``python -m repro.wisdom`` — operator CLI for wisdom stores.

Beyond-paper (the management counterpart of the paper's §4.3 tuning
script): the paper ships a command-line tool for *producing* wisdom files;
this one is for *operating* them at fleet scale. Subcommands:

  inspect    summarize a store (kernels, scenarios, versions, provenance)
  diff       compare two stores scenario-by-scenario
  merge      merge source stores into a destination (same engine ServeEngine
             pulls through, so CLI and runtime agree byte-for-byte)
  prune      drop redundant/old/off-device records
  validate   report schema problems; exit non-zero if any
  migrate    rewrite old-version files at the current WISDOM_VERSION

Every subcommand works on plain directories, so the CLI composes with
rsync/scp/NFS — the transports operators already have.
"""

from __future__ import annotations

import argparse

from repro.core.wisdom import WISDOM_VERSION, WisdomVersionError

from .merge import merge_stores
from .store import WisdomStore


def _fmt_problem(problem) -> str:
    return "x".join(str(x) for x in problem)


def _cmd_inspect(args) -> int:
    store = WisdomStore(args.dir)
    kernels = [args.kernel] if args.kernel else store.kernels()
    if not kernels:
        print(f"{store.root}: empty store")
        return 0
    for name in kernels:
        try:
            wisdom = store.load(name)
        except WisdomVersionError as e:
            print(f"{name}: UNREADABLE — {e}")
            continue
        version = store.version_of(name)
        print(f"{name}: {len(wisdom)} record(s), version {version}")
        for rec in sorted(wisdom.records, key=lambda r: r.scenario()):
            prov = rec.provenance
            line = (f"  {rec.device_kind} {_fmt_problem(rec.problem_size)} "
                    f"{rec.dtype}: {rec.score_us:.2f}us "
                    f"config={rec.config}")
            if rec.is_transferred():
                line += (f" [transfer from "
                         f"{prov.get('source_device', '?')}, "
                         f"confidence {rec.transfer_confidence():.2f}]")
            if args.verbose:
                line += (f" strategy={prov.get('strategy', '?')}"
                         f" evals={rec.evaluations()}"
                         f" host={prov.get('host', '?')}"
                         f" lineage={len(rec.lineage)}")
            print(line)
    return 0


def _cmd_diff(args) -> int:
    a, b = WisdomStore(args.a), WisdomStore(args.b)
    differs = False
    for name in sorted(set(a.kernels()) | set(b.kernels())):
        recs_a = {r.scenario(): r for r in a.load(name).records}
        recs_b = {r.scenario(): r for r in b.load(name).records}
        for scen in sorted(set(recs_a) | set(recs_b)):
            ra, rb = recs_a.get(scen), recs_b.get(scen)
            where = f"{name} {scen[0]} {_fmt_problem(scen[1])} {scen[2]}"
            if ra is None:
                print(f"only in B: {where} ({rb.score_us:.2f}us)")
            elif rb is None:
                print(f"only in A: {where} ({ra.score_us:.2f}us)")
            elif ra.record_id() != rb.record_id():
                print(f"conflict:  {where} A={ra.score_us:.2f}us "
                      f"B={rb.score_us:.2f}us")
            else:
                continue
            differs = True
    if not differs:
        print("stores are identical (per record identity)")
    return 1 if differs else 0


def _cmd_merge(args) -> int:
    dest = WisdomStore(args.into)
    sources = [WisdomStore(s) for s in args.sources]
    report = merge_stores(dest, *sources)
    print(f"merged {len(sources)} store(s) into {dest.root}: "
          f"{report.summary()}")
    return 0


def _cmd_prune(args) -> int:
    store = WisdomStore(args.dir)
    report = store.prune(kernel=args.kernel, max_age_days=args.max_age_days,
                         device_kind=args.device)
    for name, n in sorted(report.dropped.items()):
        print(f"{name}: dropped {n} record(s)")
    print(f"pruned {report.total} record(s) total")
    return 0


def _cmd_validate(args) -> int:
    store = WisdomStore(args.dir)
    issues = store.validate()
    for issue in issues:
        print(issue)
    print(f"{store.root}: {len(store)} kernel file(s), "
          f"{len(issues)} issue(s)")
    return 1 if issues else 0


def _cmd_migrate(args) -> int:
    store = WisdomStore(args.dir)
    migrated = store.migrate()
    for name in migrated:
        print(f"{name}: migrated to version {WISDOM_VERSION}")
    print(f"{len(migrated)} file(s) migrated, "
          f"{len(store) - len(migrated)} already current")
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.wisdom",
        description="Manage wisdom stores: inspect, diff, merge, prune, "
                    "validate, migrate.")
    sub = ap.add_subparsers(dest="command", required=True)

    def add_dir(p):
        p.add_argument("--dir", default=None,
                       help="wisdom directory (default: "
                            "$KERNEL_LAUNCHER_WISDOM_DIR or ./wisdom)")

    p = sub.add_parser("inspect", help="summarize a wisdom store")
    add_dir(p)
    p.add_argument("kernel", nargs="?", help="limit to one kernel")
    p.add_argument("-v", "--verbose", action="store_true",
                   help="include provenance + lineage counts")
    p.set_defaults(fn=_cmd_inspect)

    p = sub.add_parser("diff", help="compare two stores")
    p.add_argument("a", help="first store directory")
    p.add_argument("b", help="second store directory")
    p.set_defaults(fn=_cmd_diff)

    p = sub.add_parser("merge",
                       help="merge source stores into --into (statistical "
                            "winner per scenario, lineage preserved)")
    p.add_argument("--into", required=True, help="destination store")
    p.add_argument("sources", nargs="+", help="source store directories")
    p.set_defaults(fn=_cmd_merge)

    p = sub.add_parser("prune", help="drop redundant/old/off-device records")
    add_dir(p)
    p.add_argument("--kernel", default=None, help="limit to one kernel")
    p.add_argument("--max-age-days", type=float, default=None,
                   help="drop records older than this many days")
    p.add_argument("--device", default=None,
                   help="keep only records for this device kind")
    p.set_defaults(fn=_cmd_prune)

    p = sub.add_parser("validate", help="report schema problems (exit 1 "
                                        "if any)")
    add_dir(p)
    p.set_defaults(fn=_cmd_validate)

    p = sub.add_parser("migrate",
                       help=f"rewrite old files at version {WISDOM_VERSION}")
    add_dir(p)
    p.set_defaults(fn=_cmd_migrate)

    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return args.fn(args)
    except WisdomVersionError as e:
        # Version skew is an expected operator situation (old binary, newer
        # fleet), not a crash: print the guidance, exit distinctly.
        print(f"error: {e}")
        return 2


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
