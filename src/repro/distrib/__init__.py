"""Fleet-scale wisdom distribution (beyond-paper, builds on §4.4).

The paper's wisdom files are per-kernel JSON written by whoever tuned
last, on one machine; PR 1's online tuner promotes from live traffic but
each process still learns alone. This subsystem makes wisdom a *fleet*
asset:

* :mod:`.store` — :class:`WisdomStore`: a wisdom directory with schema
  versioning (``WISDOM_VERSION``), migration, validation, pruning;
* :mod:`.merge` — combine stores from many hosts, statistical winner per
  (device, problem, dtype) scenario, provenance preserved as lineage;
* :mod:`.sync`  — pluggable transports (directory, in-memory) with
  :class:`PushSync` (publish / promotion broadcast) and :class:`PullSync`
  (periodic fleet pull, wired into ``ServeEngine``);
* :mod:`.cli`   — the ``python -m repro.wisdom`` operator tool
  (inspect/diff/merge/prune/validate/migrate).
"""

from .merge import MergeReport, better_record, merge_stores, merge_wisdom
from .store import CONTROL_PREFIX, PruneReport, ValidationIssue, WisdomStore
from .sync import (DirectoryTransport, MemoryTransport, PullSync, PushSync,
                   Transport, transport_wisdom)

__all__ = [
    "MergeReport", "better_record", "merge_stores", "merge_wisdom",
    "CONTROL_PREFIX", "PruneReport", "ValidationIssue", "WisdomStore",
    "DirectoryTransport", "MemoryTransport", "PullSync", "PushSync",
    "Transport", "transport_wisdom",
]
