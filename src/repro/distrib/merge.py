"""Fleet wisdom merge engine: combine stores from many hosts into one.

Beyond-paper (generalises the §4.4 re-tune keep-best rule to a fleet): the
paper's wisdom files are written by whoever tuned last on one machine; when
many hosts tune concurrently — offline sessions, online promotions — their
stores conflict. Following the aggregate-and-compare methodology of the
KTT line of work (Petrovič et al.) and the HIP auto-tuning study (Lurati
et al.), conflicts are resolved *statistically* per (device, problem,
dtype) scenario:

  1. lower measured ``score_us`` wins (the statistical winner);
  2. equal scores: the record with more recorded evaluations wins (more
     tuning effort behind the number -> more trustworthy);
  3. still equal: lowest ``record_id()`` wins — an arbitrary but fully
     deterministic pick, so every host merging the same inputs in any
     order converges to byte-identical wisdom.

No provenance is discarded: the surviving record's ``lineage`` absorbs the
provenance of every record it beat (see ``core.wisdom.merge_lineage``).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

from repro.core.wisdom import Wisdom, WisdomRecord, merge_lineage

from .store import WisdomStore


@dataclass
class MergeReport:
    """Per-kernel accounting of one merge."""
    kernels: list[str] = field(default_factory=list)
    records_in: int = 0        # total records seen across all inputs
    records_out: int = 0       # records in the merged result
    conflicts: int = 0         # scenarios contested by >1 distinct record
    replaced: int = 0          # scenarios where a non-first input won

    def summary(self) -> str:
        return (f"{len(self.kernels)} kernel(s), {self.records_in} -> "
                f"{self.records_out} records, {self.conflicts} conflict(s), "
                f"{self.replaced} replaced")


def better_record(a: WisdomRecord, b: WisdomRecord) -> WisdomRecord:
    """The statistical winner of two same-scenario records (deterministic
    under argument swap). Also the rule the fleet coordinator applies to
    same-scenario shard winners, so assembly and merge can never disagree
    about which result survives.

    A *measured* record always beats a *transferred* one (predictions
    carry a score, but a prediction displacing a measurement would defeat
    the verification loop — see ``repro.transfer``); two transferred
    records compete on the usual score/evaluations/id key.
    """
    ka = (a.is_transferred(), a.score_us, -a.evaluations(), a.record_id())
    kb = (b.is_transferred(), b.score_us, -b.evaluations(), b.record_id())
    return a if ka <= kb else b


_better = better_record


def merge_wisdom(*inputs: Wisdom, report: MergeReport | None = None) -> Wisdom:
    """Merge several kernels' worth of wisdom for the *same* kernel.

    Input order never affects the result (only which side the report counts
    as "replaced"). Inputs are not mutated.
    """
    if not inputs:
        raise ValueError("merge_wisdom needs at least one input")
    names = {w.kernel_name for w in inputs}
    if len(names) > 1:
        raise ValueError(f"refusing to merge wisdom of different kernels: "
                         f"{sorted(names)}")
    best: dict[tuple, WisdomRecord] = {}
    contested: set[tuple] = set()
    n_in = 0
    replaced = 0
    for w in inputs:
        for rec in w.records:
            n_in += 1
            key = rec.scenario()
            cur = best.get(key)
            if cur is None:
                best[key] = rec
                continue
            if cur.record_id() == rec.record_id():
                # Same result (e.g. already synced): pool the lineages
                # only. Folding the record's own provenance in here would
                # make merging a store with itself a lineage-growing
                # non-no-op, breaking pull/push idempotence.
                if rec.lineage != cur.lineage:
                    best[key] = replace(cur, lineage=merge_lineage(
                        extra=[*cur.lineage, *rec.lineage]))
                continue
            contested.add(key)
            winner = _better(cur, rec)
            if winner.record_id() != cur.record_id():
                replaced += 1
            best[key] = replace(winner, lineage=merge_lineage(cur, rec))
    merged = Wisdom(inputs[0].kernel_name,
                    sorted(best.values(),
                           key=lambda r: (r.scenario(), r.record_id())))
    if report is not None:
        report.kernels.append(merged.kernel_name)
        report.records_in += n_in
        report.records_out += len(merged)
        report.conflicts += len(contested)
        report.replaced += replaced
    return merged


def merge_stores(dest: WisdomStore, *sources: WisdomStore) -> MergeReport:
    """Merge ``sources`` into ``dest`` on disk, kernel by kernel.

    ``dest`` participates as an input (its existing records compete on
    equal terms), so repeated merges are idempotent.
    """
    report = MergeReport()
    kernels = set(dest.kernels())
    for src in sources:
        kernels.update(src.kernels())
    for name in sorted(kernels):
        inputs = [dest.load(name)] + [src.load(name) for src in sources]
        dest.save(merge_wisdom(*inputs, report=report))
    return report
