"""Versioned wisdom store — the on-disk unit of fleet distribution.

Beyond-paper (builds on the §4.4 wisdom-file format): a ``WisdomStore``
wraps one wisdom directory — the thing the paper's workflow ships between
machines — with schema awareness: enumerating kernels, loading through the
``WISDOM_VERSION`` migration path, refusing future-version files loudly,
validating every document, and pruning. It is the local endpoint the merge
engine (:mod:`.merge`) and sync transports (:mod:`.sync`) operate on.
"""

from __future__ import annotations

import datetime
import json
from collections import OrderedDict
from dataclasses import dataclass, field
from pathlib import Path

from repro.core.wisdom import (WISDOM_VERSION, Wisdom, WisdomRecord,
                               WisdomVersionError, default_wisdom_dir,
                               doc_version, migrate_doc)

WISDOM_SUFFIX = ".wisdom.json"

#: Default bound on the per-store LRU of loaded :class:`Wisdom` objects.
#: Serving touches a handful of kernels per process but PullSync re-loads
#: each one every pull interval; caching the parsed object (validated
#: against the file's stat signature) makes the steady state O(1) stat
#: calls instead of O(records) JSON parses per kernel per tick.
DEFAULT_CACHE_KERNELS = 16

#: Transport-name namespace reserved for non-wisdom control documents.
#: The fleet orchestrator (``repro.fleet``) publishes demand tables, job
#: specs, shard leases and shard results through the *same* transports
#: wisdom moves over, under names with this prefix. Kernel names must not
#: use it: ``WisdomStore.kernels`` (and so validate/prune/push) skips it,
#: and ``PullSync`` never merges it.
CONTROL_PREFIX = "fleet--"


@dataclass
class ValidationIssue:
    kernel: str          # kernel name ("" when not determinable)
    path: str
    problem: str

    def __str__(self) -> str:
        return f"{self.path}: {self.problem}"


@dataclass
class PruneReport:
    """What ``WisdomStore.prune`` removed, per kernel."""
    dropped: dict[str, int] = field(default_factory=dict)

    @property
    def total(self) -> int:
        return sum(self.dropped.values())


class WisdomStore:
    """A wisdom directory with schema versioning and fleet-merge support."""

    def __init__(self, root: Path | str | None = None,
                 cache_kernels: int = DEFAULT_CACHE_KERNELS):
        self.root = Path(root) if root is not None else default_wisdom_dir()
        # Bounded LRU of parsed wisdom: kernel -> (stat signature, Wisdom).
        # 0 disables caching entirely (every load re-parses).
        self.cache_kernels = int(cache_kernels)
        self._cache: OrderedDict[str, tuple[tuple | None, Wisdom]] = \
            OrderedDict()

    def __repr__(self) -> str:  # pragma: no cover
        return f"WisdomStore({str(self.root)!r})"

    # -- enumeration ---------------------------------------------------------

    def path_for(self, kernel_name: str) -> Path:
        return Wisdom.path_for(kernel_name, self.root)

    def kernels(self) -> list[str]:
        """Kernel names present in the store, sorted. Control documents
        (``CONTROL_PREFIX`` namespace) sharing the directory are not
        kernels and are excluded."""
        if not self.root.is_dir():
            return []
        return sorted(p.name[:-len(WISDOM_SUFFIX)]
                      for p in self.root.glob(f"*{WISDOM_SUFFIX}")
                      if not p.name.startswith(CONTROL_PREFIX))

    def __contains__(self, kernel_name: str) -> bool:
        return self.path_for(kernel_name).exists()

    def __len__(self) -> int:
        return len(self.kernels())

    # -- load/save -----------------------------------------------------------

    def _stat_key(self, kernel_name: str) -> tuple | None:
        """File identity signature the cache is validated against (None
        when the file is absent). Any writer — this process or another —
        that lands a new file changes (mtime_ns, size, inode) and the
        next load re-parses; ``DirectoryTransport.publish`` and external
        tools therefore cannot serve a stale cache entry."""
        try:
            st = self.path_for(kernel_name).stat()
        except OSError:
            return None
        return (st.st_mtime_ns, st.st_size, st.st_ino)

    def _remember(self, kernel_name: str, key: tuple | None,
                  wisdom: Wisdom) -> None:
        self._cache[kernel_name] = (key, wisdom)
        self._cache.move_to_end(kernel_name)
        while len(self._cache) > self.cache_kernels:
            self._cache.popitem(last=False)

    def load(self, kernel_name: str) -> Wisdom:
        """Load one kernel's wisdom (empty if absent), migrating old schema
        versions in memory and refusing future ones loudly.

        Cached: repeat loads of an unchanged file return the *same*
        parsed :class:`Wisdom` (and its select index) from a bounded LRU,
        validated against the file's stat signature. Callers share the
        object — the in-repo contract is load → mutate → :meth:`save`
        (which refreshes the cache) or read-only use, so sharing is safe;
        a caller wanting an isolated copy goes through
        :meth:`invalidate_cache` or ``Wisdom.load`` directly."""
        if self.cache_kernels <= 0:
            return Wisdom.load(kernel_name, self.root)
        key = self._stat_key(kernel_name)
        hit = self._cache.get(kernel_name)
        from repro.obs import runtime as obs_runtime
        m = obs_runtime.metrics()
        if hit is not None and hit[0] == key:
            self._cache.move_to_end(kernel_name)
            if m is not None:
                m.counter("store.cache", outcome="hit").inc()
            return hit[1]
        wisdom = Wisdom.load(kernel_name, self.root)
        self._remember(kernel_name, key, wisdom)
        if m is not None:
            m.counter("store.cache", outcome="miss").inc()
        return wisdom

    def invalidate_cache(self, kernel_name: str | None = None) -> None:
        """Drop cached parsed wisdom (one kernel, or everything). Only
        needed when a caller wants a private copy or has mutated a loaded
        object without saving it."""
        if kernel_name is None:
            self._cache.clear()
        else:
            self._cache.pop(kernel_name, None)

    def load_doc(self, kernel_name: str) -> dict | None:
        """Raw JSON document for one kernel, or None if absent. No version
        check — for inspection and migration tooling."""
        path = self.path_for(kernel_name)
        if not path.exists():
            return None
        with open(path) as f:
            return json.load(f)

    def save(self, wisdom: Wisdom) -> Path:
        path = wisdom.save(self.root)
        if self.cache_kernels > 0:
            # The object we just wrote IS the freshest parse of the file:
            # re-key the cache to the new stat signature instead of
            # forcing the next load to re-parse what we already hold.
            self._remember(wisdom.kernel_name,
                           self._stat_key(wisdom.kernel_name), wisdom)
        return path

    def version_of(self, kernel_name: str) -> int | None:
        doc = self.load_doc(kernel_name)
        return None if doc is None else doc_version(doc)

    # -- maintenance ---------------------------------------------------------

    def validate(self) -> list[ValidationIssue]:
        """Check every wisdom file; returns [] when the store is healthy.

        Flags unreadable JSON, future schema versions, kernel/filename
        mismatches, and records missing required fields. Never raises — the
        point is a complete report, not the first failure.
        """
        issues: list[ValidationIssue] = []
        for name in self.kernels():
            path = self.path_for(name)
            try:
                with open(path) as f:
                    doc = json.load(f)
            except (OSError, json.JSONDecodeError) as e:
                issues.append(ValidationIssue(name, str(path),
                                              f"unreadable JSON: {e}"))
                continue
            if not isinstance(doc, dict):
                issues.append(ValidationIssue(
                    name, str(path),
                    f"not a JSON object (got {type(doc).__name__})"))
                continue
            if doc.get("kernel") != name:
                issues.append(ValidationIssue(
                    name, str(path),
                    f"kernel field {doc.get('kernel')!r} does not match "
                    f"filename"))
            try:
                doc = migrate_doc(doc, source=str(path))
            except WisdomVersionError as e:
                issues.append(ValidationIssue(name, str(path), str(e)))
                continue
            for i, rec in enumerate(doc.get("records", [])):
                try:
                    WisdomRecord.from_json(rec)
                except (KeyError, TypeError, ValueError) as e:
                    issues.append(ValidationIssue(
                        name, str(path), f"record #{i} malformed: {e!r}"))
        return issues

    def migrate(self) -> list[str]:
        """Rewrite every old-version file at the current ``WISDOM_VERSION``.

        Returns the kernels migrated. Current-version files are left
        untouched (byte-stable); future-version files raise
        :class:`WisdomVersionError` so an old binary can never downgrade a
        newer fleet's store in place.
        """
        migrated = []
        for name in self.kernels():
            doc = self.load_doc(name)
            if doc_version(doc) == WISDOM_VERSION:
                continue
            self.save(Wisdom(name, [
                WisdomRecord.from_json(r)
                for r in migrate_doc(doc, str(self.path_for(name)))["records"]
            ]))
            migrated.append(name)
        return migrated

    def prune(self, kernel: str | None = None,
              max_age_days: float | None = None,
              device_kind: str | None = None) -> PruneReport:
        """Drop redundant records: non-best duplicates per scenario always;
        optionally records older than ``max_age_days`` or for devices other
        than ``device_kind``. Kernel files left empty are removed."""
        cutoff = None
        if max_age_days is not None:
            cutoff = (datetime.datetime.now(datetime.timezone.utc)
                      - datetime.timedelta(days=max_age_days)).isoformat()
        report = PruneReport()
        for name in ([kernel] if kernel is not None else self.kernels()):
            wisdom = self.load(name)
            before = len(wisdom)
            kept = Wisdom(name)
            for rec in wisdom.records:
                if device_kind is not None and rec.device_kind != device_kind:
                    continue
                if cutoff is not None:
                    date = str(rec.provenance.get("date", ""))
                    if date and date < cutoff:
                        continue
                kept.add(rec)           # keep_best dedups per scenario
            dropped = before - len(kept)
            if dropped:
                report.dropped[name] = dropped
                if len(kept):
                    self.save(kept)
                else:
                    self.path_for(name).unlink()
        return report
