"""Wisdom sync: move tuning results between a host and the fleet.

Beyond-paper (the distribution step the paper leaves to "ship the JSON
files"): a *transport* is anywhere wisdom documents can be published and
fetched — a shared directory (NFS mount, object-store FUSE, rsync target)
via :class:`DirectoryTransport`, or an in-process dict via
:class:`MemoryTransport` for deterministic tests. On top of a transport:

* :class:`PushSync` publishes local wisdom, merging into what the fleet
  already has (never clobbering a better remote record), and gives the
  online promotion pipeline its ``broadcast`` hook so a confident winner
  leaves the machine the moment it is promoted;
* :class:`PullSync` merges fleet wisdom into the local store and refreshes
  attached ``WisdomKernel`` selection caches; its :meth:`PullSync.tick` is
  cheap enough to call every decode step (``ServeEngine`` does), actually
  pulling only every ``interval`` ticks.

Both directions go through the merge engine, so sync is idempotent,
order-independent, and can only ever improve a store.
"""

from __future__ import annotations

import contextlib
import json
import os
import tempfile
from pathlib import Path
from typing import Protocol

from repro.core.wisdom import Wisdom, WisdomRecord, migrate_doc
from repro.obs import runtime as obs

from .merge import MergeReport, merge_wisdom
from .store import CONTROL_PREFIX, WISDOM_SUFFIX, WisdomStore


class Transport(Protocol):
    """Where the fleet's wisdom lives, reduced to three operations.

    Names are usually kernel names, but the ``CONTROL_PREFIX`` namespace
    is reserved for the fleet orchestrator's control documents (demand,
    jobs, leases, results) — transports must round-trip those names too;
    the wisdom sync layer simply skips them.
    """

    def list_kernels(self) -> list[str]: ...

    def fetch(self, kernel_name: str) -> dict | None: ...

    def publish(self, kernel_name: str, doc: dict) -> None: ...


class DirectoryTransport:
    """A shared directory of wisdom files as the fleet rendezvous point."""

    def __init__(self, root: Path | str):
        self.store = WisdomStore(root)

    def list_kernels(self) -> list[str]:
        # The raw transport view: control documents included (the store's
        # own kernels() hides them from the wisdom layer).
        root = self.store.root
        if not root.is_dir():
            return []
        return sorted(p.name[:-len(WISDOM_SUFFIX)]
                      for p in root.glob(f"*{WISDOM_SUFFIX}"))

    def fetch(self, kernel_name: str) -> dict | None:
        return self.store.load_doc(kernel_name)

    def publish(self, kernel_name: str, doc: dict) -> None:
        path = self.store.path_for(kernel_name)
        path.parent.mkdir(parents=True, exist_ok=True)
        # Unlike the single-writer local Wisdom.save, a shared directory
        # has many hosts publishing concurrently: the tmp name must be
        # unique per writer or interleaved writes to the same tmp file
        # could get renamed into place as corrupt JSON.
        fd, tmp = tempfile.mkstemp(prefix=f".{kernel_name}.",
                                   suffix=".tmp", dir=path.parent)
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, indent=2, sort_keys=True)
            os.replace(tmp, path)  # atomic
        except BaseException:
            with contextlib.suppress(OSError):
                os.unlink(tmp)
            raise

    def __repr__(self) -> str:  # pragma: no cover
        return f"DirectoryTransport({str(self.store.root)!r})"


class MemoryTransport:
    """In-process transport: {kernel: doc}. Deterministic tests, and the
    reference for what a networked transport must implement."""

    def __init__(self):
        self.docs: dict[str, dict] = {}
        self.publishes = 0
        self.fetches = 0

    def list_kernels(self) -> list[str]:
        return sorted(self.docs)

    def fetch(self, kernel_name: str) -> dict | None:
        self.fetches += 1
        doc = self.docs.get(kernel_name)
        return json.loads(json.dumps(doc)) if doc is not None else None

    def publish(self, kernel_name: str, doc: dict) -> None:
        self.publishes += 1
        self.docs[kernel_name] = json.loads(json.dumps(doc))


def transport_wisdom(transport: Transport, kernel_name: str) -> Wisdom:
    """One kernel's wisdom as the transport currently holds it (empty when
    the fleet has none), migrated to the current schema."""
    doc = transport.fetch(kernel_name)
    if doc is None:
        return Wisdom(kernel_name)
    doc = migrate_doc(doc, source=f"<transport:{kernel_name}>")
    return Wisdom(kernel_name,
                  [WisdomRecord.from_json(r) for r in doc.get("records", [])])


_remote_wisdom = transport_wisdom


class PushSync:
    """Publish local wisdom to the fleet, merge-on-write."""

    def __init__(self, store: WisdomStore, transport: Transport):
        self.store = store
        self.transport = transport

    def push(self, kernel_name: str | None = None) -> MergeReport:
        """Merge local wisdom into the transport's copy and publish.

        Fetch-merge-publish rather than blind upload: a slow host must not
        overwrite a faster record some other host already published.
        """
        report = MergeReport()
        names = ([kernel_name] if kernel_name is not None
                 else self.store.kernels())
        for name in names:
            merged = merge_wisdom(self.store.load(name),
                                  _remote_wisdom(self.transport, name),
                                  report=report)
            self.transport.publish(name, merged.to_doc())
        m = obs.metrics()
        if m is not None:
            m.counter("sync.ops", direction="push").inc()
            m.counter("sync.records",
                      direction="push").inc(report.records_out)
        return report

    def broadcast(self, kernel_name: str, record: WisdomRecord) -> None:
        """Publish one newly-promoted record (the online pipeline's hook).

        Merging a single record is what makes broadcasting safe to run on
        the serving path's promotion tail: one fetch, one publish, and the
        fleet copy still only ever improves.
        """
        merged = merge_wisdom(Wisdom(kernel_name, [record]),
                              _remote_wisdom(self.transport, kernel_name))
        self.transport.publish(kernel_name, merged.to_doc())
        m = obs.metrics()
        if m is not None:
            m.counter("sync.ops", direction="broadcast").inc()
            m.counter("sync.records", direction="broadcast").inc()


class PullSync:
    """Merge fleet wisdom into the local store, hot-refreshing kernels."""

    def __init__(self, store: WisdomStore, transport: Transport,
                 kernels: list | None = None, interval: int = 64):
        if interval < 1:
            raise ValueError(f"interval must be >= 1, got {interval}")
        self.store = store
        self.transport = transport
        #: WisdomKernel objects whose selection caches are refreshed after a
        #: pull that changed their kernel's wisdom.
        self.kernels = list(kernels or [])
        self.interval = interval
        self.pulls = 0
        self.failures = 0
        self.last_error: Exception | None = None
        self._ticks = 0

    def attach(self, kernel) -> None:
        self.kernels.append(kernel)

    def pull(self) -> MergeReport:
        """Fetch every fleet kernel and merge into the local store.

        Two-phase: every transport fetch and in-memory merge completes
        *before* the first local write. A transport that dies mid-pull
        (shared mount hiccup, truncated document) therefore raises with
        the local store byte-identical to its pre-pull state — serving
        hosts never select from a half-synced store.
        """
        report = MergeReport()
        staged: list[Wisdom] = []
        for name in self.transport.list_kernels():
            if name.startswith(CONTROL_PREFIX):
                continue        # fleet control documents are not wisdom
            local = self.store.load(name)
            before = json.dumps(local.to_doc(), sort_keys=True)
            merged = merge_wisdom(local, _remote_wisdom(self.transport, name),
                                  report=report)
            # Full-document comparison: even a lineage-only difference
            # (same winners, pooled provenance history) must be persisted.
            if json.dumps(merged.to_doc(), sort_keys=True) != before:
                staged.append(merged)
        changed: set[str] = set()
        for merged in staged:       # all fetches succeeded: now persist
            self.store.save(merged)
            changed.add(merged.kernel_name)
        self.pulls += 1
        for k in self.kernels:
            if k.builder.name in changed:
                k.refresh_wisdom()
        m = obs.metrics()
        if m is not None:
            m.counter("sync.ops", direction="pull").inc()
            m.counter("sync.records",
                      direction="pull").inc(report.records_out)
            m.counter("sync.kernels_changed").inc(len(changed))
        return report

    def tick(self) -> MergeReport | None:
        """Serving-loop hook: pulls on every ``interval``-th call (first
        call included, so a fresh engine starts from fleet wisdom).

        Failure-isolated: a raising transport must not kill the decode
        step that sponsored the tick, so errors are swallowed here —
        counted in ``failures``, the exception kept in ``last_error`` —
        and the previously served wisdom stays in effect until the next
        due tick retries. Callers who need the error should call
        :meth:`pull` directly.
        """
        due = self._ticks % self.interval == 0
        self._ticks += 1
        if not due:
            return None
        try:
            return self.pull()
        except Exception as e:  # noqa: BLE001 — serving must outlive sync
            self.failures += 1
            self.last_error = e
            m = obs.metrics()
            if m is not None:
                m.counter("sync.failures", direction="pull").inc()
            return None
