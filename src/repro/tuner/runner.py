"""Evaluators: config -> (score, validity) — the tuner's measurement step.

``CostModelEvaluator`` scores a config with the analytical simulated-TPU
model; ``WallClockEvaluator`` actually executes the built kernel (interpret
mode on CPU, native Pallas on TPU) and times it. Both optionally *verify* the
kernel's output against the ``ref.py`` oracle on replayed capture data —
the paper's "output verification" option in Kernel Tuner.

Both evaluators also take ``record_to``: any object with a
``record(config, EvalResult)`` method — in practice a
:class:`~repro.tunebench.SpaceDataset` — receives every evaluation
(feasible or not) as it happens, turning any tuning session into a
recorded search space that can later be replayed without hardware.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Sequence

import jax
import numpy as np

from repro.core.builder import KernelBuilder, args_meta
from repro.core.device import DeviceSpec, current_device_kind, get_device
from repro.core.param import Config
from repro.prof.profile import profile_fields, profile_from_workload

from .costmodel import CostModel, INFEASIBLE

VERIFY_BYTES_LIMIT = 64 * 2**20  # skip in-loop verification beyond this


@dataclass
class EvalResult:
    """Outcome of evaluating one configuration.

    ``score_us`` is the objective value in microseconds (lower is
    better; ``inf`` when infeasible), ``feasible`` says whether the
    config can run at all (restrictions, VMEM, failed verification and
    build errors all make it False — ``error`` says which), and
    ``verified`` records output verification (None = not checked).

    Example::

        r = evaluator({"block_m": 128, "block_n": 128})
        if r.feasible:
            print(f"{r.score_us:.1f}us")
    """

    score_us: float
    feasible: bool
    verified: bool | None = None   # None = not checked
    error: str = ""
    info: dict = field(default_factory=dict)


def _tolerances(dtype: str) -> tuple[float, float]:
    if dtype in ("bfloat16",):
        return 2e-2, 2e-2
    if dtype in ("float16",):
        return 1e-2, 1e-2
    return 1e-5, 1e-5


@dataclass
class VerifyOutcome:
    """Structured result of one reference-oracle comparison.

    ``kind`` classifies the failure: ``""`` (passed), ``"build"`` (the
    kernel could not be built or executed at all), ``"structure"``
    (output tree/shape mismatch) or ``"numerics"`` (executed fine but
    ``allclose`` failed). ``max_err`` is the largest absolute elementwise
    deviation seen across all compared outputs (also populated on
    success, so callers can report how close a passing config was);
    ``rtol``/``atol`` are the dtype-aware tolerances the comparison used.

    Example::

        out = verify_outcome(builder, config, probe_args)
        if not out.ok:
            print(out.kind, out.error, out.max_err)
    """

    ok: bool
    kind: str = ""
    error: str = ""
    max_err: float | None = None
    rtol: float | None = None
    atol: float | None = None


def verify_outcome(builder: KernelBuilder, config: Config,
                   args: Sequence[np.ndarray],
                   interpret: bool = True) -> VerifyOutcome:
    """Execute the built kernel on ``args``, compare with the reference
    oracle, and classify what happened (see :class:`VerifyOutcome`).

    The comparison is dtype-aware (:func:`_tolerances`) and scales the
    absolute tolerance by the reference magnitude, so low-precision
    kernels are judged against realistic accumulation error rather than
    float32 expectations.

    Example::

        out = verify_outcome(get_kernel("matmul"), config, [a, b])
        assert out.ok, out.error
    """
    meta = args_meta(*args)
    dtype = builder.get_dtype(*meta)
    rtol, atol = _tolerances(dtype)
    try:
        fn = builder.make(config, meta, interpret=interpret)
        got = jax.tree.map(np.asarray, fn(*args))
    except Exception as e:  # noqa: BLE001 — any build/run failure = invalid
        return VerifyOutcome(False, kind="build", rtol=rtol, atol=atol,
                             error=f"build/run failed: "
                                   f"{type(e).__name__}: {e}")
    ref_fn = builder.make_reference()
    want = jax.tree.map(np.asarray, ref_fn(*args))
    got_leaves = jax.tree.leaves(got)
    want_leaves = jax.tree.leaves(want)
    if len(got_leaves) != len(want_leaves):
        return VerifyOutcome(False, kind="structure", rtol=rtol, atol=atol,
                             error="output structure mismatch")
    max_err = 0.0
    for g, w in zip(got_leaves, want_leaves):
        if g.shape != w.shape:
            return VerifyOutcome(
                False, kind="structure", rtol=rtol, atol=atol,
                error=f"shape mismatch {g.shape} vs {w.shape}")
        g64 = np.asarray(g, np.float64)
        w64 = np.asarray(w, np.float64)
        max_err = max(max_err, float(np.max(np.abs(g64 - w64)))
                      if g64.size else 0.0)
        scale = max(1.0, float(np.max(np.abs(w64))) if w64.size else 1.0)
        if not np.allclose(g64, w64, rtol=rtol, atol=atol * scale):
            return VerifyOutcome(
                False, kind="numerics", max_err=max_err,
                rtol=rtol, atol=atol,
                error=f"allclose failed, max abs err {max_err:.3e}")
    return VerifyOutcome(True, max_err=max_err, rtol=rtol, atol=atol)


def verify_against_reference(builder: KernelBuilder, config: Config,
                             args: Sequence[np.ndarray],
                             interpret: bool = True) -> tuple[bool, str]:
    """Execute the built kernel on ``args`` and compare with the oracle.

    Compatibility wrapper over :func:`verify_outcome` returning the
    historical ``(ok, message)`` pair.
    """
    out = verify_outcome(builder, config, args, interpret=interpret)
    return out.ok, out.error


class CostModelEvaluator:
    """Default objective on CPU hosts: analytical model + optional verify.

    Scores a config by running the kernel's ``workload`` hook through the
    deterministic simulated-TPU :class:`~repro.tuner.costmodel.CostModel`
    for ``device`` — no execution, so it is safe (and fast) on machines
    without the accelerator. With ``verify_args`` (typically a capture's
    replayed arguments) each distinct config is additionally executed
    once in interpret mode and checked against the reference oracle.

    Example::

        ev = CostModelEvaluator(get_kernel("matmul"), (256, 256, 256),
                                "float32", "tpu-v5e", verify="none")
        score = ev(builder.default_config()).score_us
    """

    def __init__(self, builder: KernelBuilder, problem: tuple[int, ...],
                 dtype: str, device: DeviceSpec | str,
                 verify_args: Sequence[np.ndarray] | None = None,
                 verify: str = "auto", record_to=None) -> None:
        self.builder = builder
        self.problem = tuple(problem)
        self.dtype = dtype
        self.device = get_device(device) if isinstance(device, str) else device
        self.model = CostModel(self.device)
        self.verify_args = verify_args
        self.verify = verify
        #: Optional dataset recorder: ``record(config, EvalResult)`` is
        #: called for every evaluation (see repro.tunebench).
        self.record_to = record_to
        self._verified_cache: dict[tuple, tuple[bool, str]] = {}

    def _should_verify(self) -> bool:
        if self.verify == "none" or self.verify_args is None:
            return False
        if self.verify == "full":
            return True
        nbytes = sum(int(np.asarray(a).nbytes) for a in self.verify_args)
        return nbytes <= VERIFY_BYTES_LIMIT

    def _record(self, config: Config, result: EvalResult) -> EvalResult:
        if self.record_to is not None:
            self.record_to.record(config, result)
        return result

    def __call__(self, config: Config) -> EvalResult:
        if not self.builder.space.is_valid(config):
            return self._record(
                config, EvalResult(INFEASIBLE, False, error="restricted"))
        w = self.builder.make_workload(config, self.problem, self.dtype)
        key = "|".join(f"{k}={config[k]}" for k in sorted(config))
        key += f"|{self.problem}|{self.dtype}"
        t = self.model.time(w, self.dtype, noise_key=key)
        if not np.isfinite(t):
            return self._record(
                config, EvalResult(INFEASIBLE, False, error="vmem overflow",
                                   info={"vmem_bytes": w.vmem_bytes}))
        verified: bool | None = None
        if self._should_verify():
            fkey = self.builder.space.freeze(config)
            if fkey not in self._verified_cache:
                self._verified_cache[fkey] = verify_against_reference(
                    self.builder, config, self.verify_args)
            ok, msg = self._verified_cache[fkey]
            verified = ok
            if not ok:
                return self._record(
                    config, EvalResult(INFEASIBLE, False, verified=False,
                                       error=msg))
        # Always-on profiling: in the tuner the workload is already in
        # hand, so joining it with the score costs one pure function
        # call — every recorded dataset entry gains roofline counters.
        p = profile_from_workload(
            w, self.device, self.dtype, t * 1e6,
            kernel=self.builder.name, problem_size=self.problem,
            config=config)
        return self._record(
            config, EvalResult(t * 1e6, True, verified=verified,
                               info={"workload": w,
                                     "profile": profile_fields(p)}))


class WallClockEvaluator:
    """Measure actual execution time (real hardware, or interpret mode).

    Builds and jits the kernel for each config, runs a warmup plus
    ``repeats`` timed executions on the concrete ``args`` (typically a
    capture's replayed data), and scores the best of the repeats — the
    paper's measured objective. On non-TPU hosts it falls back to Pallas
    interpret mode automatically, so the same tuning script runs
    anywhere (slowly, but with real execution semantics).

    Example::

        cap = load_capture("captures/matmul-....capture.json")
        ev = WallClockEvaluator(get_kernel(cap.kernel_name), cap.args)
        result = ev(config)     # EvalResult with measured score_us
    """

    def __init__(self, builder: KernelBuilder, args: Sequence[np.ndarray],
                 interpret: bool | None = None, repeats: int = 3,
                 verify: bool = True, record_to=None) -> None:
        self.builder = builder
        self.args = [np.asarray(a) for a in args]
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = interpret
        self.repeats = repeats
        self.verify = verify
        #: Optional dataset recorder: ``record(config, EvalResult)`` is
        #: called for every evaluation (see repro.tunebench).
        self.record_to = record_to

    def _record(self, config: Config, result: EvalResult) -> EvalResult:
        if self.record_to is not None:
            self.record_to.record(config, result)
        return result

    def __call__(self, config: Config) -> EvalResult:
        if not self.builder.space.is_valid(config):
            return self._record(
                config, EvalResult(INFEASIBLE, False, error="restricted"))
        meta = args_meta(*self.args)
        if self.verify:
            ok, msg = verify_against_reference(
                self.builder, config, self.args, interpret=self.interpret)
            if not ok:
                return self._record(
                    config, EvalResult(INFEASIBLE, False, verified=False,
                                       error=msg))
        try:
            fn = self.builder.make(config, meta, interpret=self.interpret)
            compiled = jax.jit(fn).lower(*meta).compile()
            compiled(*self.args)  # warmup
            times = []
            for _ in range(self.repeats):
                t0 = time.perf_counter()
                jax.block_until_ready(compiled(*self.args))
                times.append(time.perf_counter() - t0)
            score_us = min(times) * 1e6
            info: dict = {}
            if self.builder._workload is not None:
                problem = self.builder.get_problem_size(*meta)
                dtype = self.builder.get_dtype(*meta)
                w = self.builder.make_workload(config, problem, dtype)
                p = profile_from_workload(
                    w, get_device(current_device_kind()), dtype, score_us,
                    kernel=self.builder.name, problem_size=problem,
                    config=config)
                info["profile"] = profile_fields(p)
            return self._record(
                config, EvalResult(score_us, True,
                                   verified=True if self.verify else None,
                                   info=info))
        except Exception as e:  # noqa: BLE001
            return self._record(
                config, EvalResult(INFEASIBLE, False,
                                   error=f"{type(e).__name__}: {e}"))
