"""Pre-tune all built-in kernels for the simulated device pair and ship the
wisdom files with the repo — so a fresh deployment starts from tuned
configs instead of defaults (the paper's deployment story: wisdom files are
versioned application assets).

  PYTHONPATH=src python -m repro.tuner.pretune --out wisdom
"""

from __future__ import annotations

import argparse

from repro.core import all_kernels
from repro.tuner.tune import tune_kernel

# representative problem sizes per kernel family
PROBLEMS = {
    "advec_u": [(64, 64, 128), (256, 256, 256), (512, 512, 512)],
    "diff_uvw": [(64, 64, 128), (256, 256, 256), (512, 512, 512)],
    "matmul": [(512, 512, 1024), (4096, 4096, 4096), (8192, 8192, 8192)],
    "flash_attention_causal": [(256, 64, 4096, 128), (32, 8, 32768, 128)],
    "flash_attention_full": [(256, 64, 4096, 128)],
}
DEVICES = ("tpu-v5e", "tpu-v4")
DTYPES = ("bfloat16", "float32")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="wisdom")
    ap.add_argument("--strategy", default="bayes")
    ap.add_argument("--evals", type=int, default=120)
    args = ap.parse_args(argv)

    for name, builder in sorted(all_kernels().items()):
        for problem in PROBLEMS.get(name, []):
            for device in DEVICES:
                for dtype in DTYPES:
                    res = tune_kernel(
                        builder, problem, dtype, device,
                        strategy=args.strategy, max_evals=args.evals,
                        time_budget_s=120, wisdom_dir=args.out)
                    print(f"{name} {problem} {dtype} {device}: "
                          f"{res.best_score_us:.1f}us "
                          f"({len(res.evaluations)} evals)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
