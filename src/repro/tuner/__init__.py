"""Auto-tuner (the in-repo Kernel Tuner analogue, paper §3/§4.3).

Strategies: random search, simulated annealing, Bayesian optimization (GP+EI,
pure numpy) — the paper's default is Bayesian optimization with a 15-minute
budget. Objectives: analytical simulated-TPU cost model (default on this
CPU-only container) or wall-clock execution (real TPU / interpret mode).
"""

from .costmodel import (CostModel, FittedCostModel, fit_from_dataset,
                        kernel_time)
from .runner import CostModelEvaluator, WallClockEvaluator, EvalResult
from .strategies import (STRATEGIES, Evaluation, TuningResult,
                         evaluation_from_json, evaluation_to_json,
                         tune_anneal, tune_bayes, tune_exhaustive,
                         tune_random)
from .tune import tune_capture, tune_kernel

__all__ = [
    "CostModel", "FittedCostModel", "fit_from_dataset", "kernel_time",
    "CostModelEvaluator", "WallClockEvaluator", "EvalResult",
    "STRATEGIES", "Evaluation", "TuningResult",
    "evaluation_from_json", "evaluation_to_json",
    "tune_anneal", "tune_bayes", "tune_exhaustive", "tune_random",
    "tune_capture", "tune_kernel",
]
