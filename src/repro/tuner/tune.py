"""Tuning entry points + capture replay (paper §4.3) and the CLI.

``tune_kernel`` tunes one (kernel, problem, dtype, device) scenario and
writes the result into the kernel's wisdom file. ``tune_capture`` replays a
captured launch — the fully-automated path the paper contributes: no
hand-written tuning script, no synthetic input data.

CLI (the paper's "command-line script", §4.3)::

    python -m repro.tuner.tune --captures 'captures/*.capture.json' \
        --strategy bayes --budget-evals 200 --device tpu-v5e
"""

from __future__ import annotations

import argparse
import glob
from pathlib import Path
from typing import Sequence

import numpy as np

from repro.core.builder import KernelBuilder
from repro.core.capture import Capture, load_capture
from repro.core.registry import get_kernel
from repro.core.wisdom import WisdomRecord, make_provenance
from repro.core.device import get_device
from repro.distrib.store import WisdomStore

from .runner import CostModelEvaluator, WallClockEvaluator
from .strategies import STRATEGIES, TuningResult

DEFAULT_BUDGET_EVALS = 200
# The paper's default budget is 15 minutes; on the simulated objective an
# evaluation is ~instant so the eval budget is the binding constraint.
DEFAULT_TIME_BUDGET_S = 15 * 60.0


def tune_kernel(builder: KernelBuilder, problem: tuple[int, ...], dtype: str,
                device_kind: str, strategy: str = "bayes",
                max_evals: int = DEFAULT_BUDGET_EVALS,
                time_budget_s: float | None = DEFAULT_TIME_BUDGET_S,
                verify_args: Sequence[np.ndarray] | None = None,
                objective: str = "costmodel",
                wisdom_dir: Path | str | None = None,
                write_wisdom: bool = True,
                seed: int = 0,
                store: WisdomStore | None = None,
                record_dataset: Path | str | None = None) -> TuningResult:
    """Tune one scenario; optionally record the winner in the wisdom file.

    Writes go through a :class:`~repro.distrib.WisdomStore` (``store``
    wins over ``wisdom_dir``): tuning output gets the same schema
    versioning/migration guarantees the fleet sync layer relies on.

    ``record_dataset`` additionally records *every* evaluation of the
    session (not just the winner) into a
    :class:`~repro.tunebench.SpaceDataset`: pass a directory (one
    scenario-named file per dataset, merged with any prior recording) or
    an explicit ``*.space.json`` path. Recorded spaces feed the
    simulated strategy benchmark (``python -m repro.tunebench``) and
    warm-start fleet shard sessions.

    Example::

        res = tune_kernel(get_kernel("matmul"), (256, 256, 256),
                          "float32", "tpu-v5e", strategy="bayes",
                          max_evals=100, record_dataset="datasets")
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; "
                         f"have {sorted(STRATEGIES)}")
    dataset = dataset_path = None
    if record_dataset is not None:
        # Local import: tunebench builds on the tuner's primitives.
        from repro.tunebench import (DATASET_SUFFIX, DatasetStore,
                                     SpaceDataset)
        path = Path(record_dataset)
        if str(path).endswith(DATASET_SUFFIX) or path.suffix == ".json":
            dataset_path = path
        else:
            dataset_path = DatasetStore(path).path_for(
                builder.name, device_kind, problem, dtype)
        if dataset_path.exists():
            dataset = SpaceDataset.load(dataset_path)   # merge into prior
            # Merging across scenarios (or objectives) would mix
            # incomparable scores under one header — and a foreign param
            # table would crash key derivation mid-session. Refuse now.
            want = (builder.name, tuple(problem), dtype, device_kind,
                    objective)
            have = (dataset.kernel, dataset.problem_size, dataset.dtype,
                    dataset.device_kind, dataset.objective)
            if want != have:
                raise ValueError(
                    f"dataset {dataset_path} records scenario {have}, "
                    f"cannot merge a {want} session into it")
        else:
            dataset = SpaceDataset(builder.name, builder.space, problem,
                                   dtype, device_kind, objective=objective)
    if objective == "costmodel":
        evaluate = CostModelEvaluator(builder, problem, dtype,
                                      get_device(device_kind),
                                      verify_args=verify_args,
                                      record_to=dataset)
    elif objective == "wallclock":
        if verify_args is None:
            raise ValueError("wallclock objective needs concrete args "
                             "(use a capture)")
        evaluate = WallClockEvaluator(builder, verify_args,
                                      record_to=dataset)
    else:
        raise ValueError(f"unknown objective {objective!r}")

    rng = np.random.default_rng(seed)
    result = STRATEGIES[strategy](builder.space, evaluate,
                                  max_evals=max_evals, rng=rng,
                                  time_budget_s=time_budget_s)
    if dataset is not None:
        dataset.provenance.setdefault("recorder", "tune_kernel")
        dataset.save(dataset_path)
    if write_wisdom and result.best_config is not None:
        dev = get_device(device_kind)
        if store is None:
            store = WisdomStore(wisdom_dir)
        wisdom = store.load(builder.name)
        wisdom.add(WisdomRecord(
            device_kind=dev.kind, device_family=dev.family,
            problem_size=tuple(problem), dtype=dtype,
            config=result.best_config, score_us=result.best_score_us,
            provenance=make_provenance(strategy=strategy,
                                       evals=len(result.evaluations),
                                       objective=objective)))
        store.save(wisdom)
    return result


def tune_capture(capture: Path | str | Capture, device_kind: str,
                 strategy: str = "bayes",
                 max_evals: int = DEFAULT_BUDGET_EVALS,
                 time_budget_s: float | None = DEFAULT_TIME_BUDGET_S,
                 objective: str = "costmodel",
                 wisdom_dir: Path | str | None = None,
                 seed: int = 0,
                 store: WisdomStore | None = None,
                 record_dataset: Path | str | None = None) -> TuningResult:
    """Replay a captured launch through the tuner (paper §4.2/§4.3).

    Accepts a capture file path or an already-loaded :class:`Capture`;
    the capture supplies the problem size, dtype and concrete arguments
    (for verification or the wallclock objective), so no hand-written
    tuning script or synthetic data is needed.

    Example::

        res = tune_capture("captures/matmul-1.capture.json", "tpu-v5e",
                           strategy="bayes", max_evals=100)
    """
    cap = capture if isinstance(capture, Capture) else load_capture(capture)
    builder = get_kernel(cap.kernel_name)
    return tune_kernel(builder, cap.problem_size, cap.dtype, device_kind,
                       strategy=strategy, max_evals=max_evals,
                       time_budget_s=time_budget_s, verify_args=cap.args,
                       objective=objective, wisdom_dir=wisdom_dir, seed=seed,
                       store=store, record_dataset=record_dataset)


def plan_captures(paths: Sequence[str], device_kind: str
                  ) -> list[tuple[Capture, list[str]]]:
    """Group capture files into unique tuning scenarios.

    Several captures of the same (kernel, problem, dtype) — re-runs,
    copies rsync'd from many hosts — describe one scenario and must tune
    once, not once per file. Returns ``[(capture, paths)]`` in first-seen
    path order: the loaded representative capture (handed straight to
    :func:`tune_capture`, no second disk parse) plus every path that
    mapped to its scenario.
    """
    plan: dict[tuple, tuple[Capture, list[str]]] = {}
    for p in paths:
        cap = load_capture(p)
        key = (cap.kernel_name, tuple(cap.problem_size), cap.dtype,
               device_kind)
        if key in plan:
            plan[key][1].append(p)
        else:
            plan[key] = (cap, [p])
    return list(plan.values())


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="Replay captured kernel launches through the tuner.")
    ap.add_argument("--captures", default="captures/*.capture.json",
                    help="glob of capture files to replay")
    ap.add_argument("--strategy", default="bayes",
                    choices=sorted(STRATEGIES))
    ap.add_argument("--budget-evals", type=int, default=DEFAULT_BUDGET_EVALS)
    ap.add_argument("--budget-seconds", type=float,
                    default=DEFAULT_TIME_BUDGET_S)
    ap.add_argument("--device", default="tpu-v5e",
                    help="device kind to tune for")
    ap.add_argument("--objective", default="costmodel",
                    choices=("costmodel", "wallclock"))
    ap.add_argument("--wisdom-dir", default=None)
    ap.add_argument("--record-dataset", default=None, metavar="DIR",
                    help="also record every evaluation into a tuning-space "
                         "dataset directory (see docs/tuning-datasets.md)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dry-run", action="store_true",
                    help="print the deduplicated scenario plan and exit "
                         "without tuning")
    args = ap.parse_args(argv)

    paths = sorted(glob.glob(args.captures))
    if not paths:
        print(f"no captures match {args.captures!r}")
        return 1
    plan = plan_captures(paths, args.device)
    dups = len(paths) - len(plan)
    for cap, scenario_paths in plan:
        label = (f"{cap.kernel_name} "
                 f"{'x'.join(str(d) for d in cap.problem_size)} "
                 f"{cap.dtype} on {args.device}")
        if args.dry_run:
            extra = (f" (+{len(scenario_paths) - 1} duplicate(s))"
                     if len(scenario_paths) > 1 else "")
            print(f"would tune {label}: {scenario_paths[0]}{extra}")
            continue
        res = tune_capture(cap, args.device,
                           strategy=args.strategy,
                           max_evals=args.budget_evals,
                           time_budget_s=args.budget_seconds,
                           objective=args.objective,
                           wisdom_dir=args.wisdom_dir, seed=args.seed,
                           record_dataset=args.record_dataset)
        print(f"{scenario_paths[0]}: best={res.best_score_us:.2f}us "
              f"evals={len(res.evaluations)} config={res.best_config}")
        for skipped in scenario_paths[1:]:
            print(f"{skipped}: skipped (same scenario: {label})")
    print(f"{len(plan)} scenario(s) from {len(paths)} capture(s)"
          + (f", {dups} duplicate(s) skipped" if dups else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
