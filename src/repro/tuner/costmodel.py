"""Analytical simulated-TPU cost model — the tuning objective on CPU hosts.

The paper measures wall-clock on real GPUs. This container has no TPU, so the
objective is an analytical model of a TPU core executing one kernel launch
described by a :class:`~repro.core.workload.Workload`:

  t_compute    = flops / (peak · mxu_eff · ilp_eff)
  t_memory     = hbm_bytes · reuse / (bw · stream_eff)
  t            = max(t_compute, t_memory)        (double-buffered overlap)
                 or t_compute + t_memory          (buffers == 1)
  t           += grid · program_overhead          (per-program fixed cost)
  infeasible if the per-program VMEM working set exceeds the core's VMEM
  (the TPU analogue of the paper's register-pressure / launch_bounds axis).

Efficiencies model the hardware structure that makes tuning non-trivial:

  * MXU alignment: each matmul tile dim is padded to the device's
    matmul granule (128 on the TPU systolic array, 16 on GPU tensor
    cores — ``DeviceSpec.matmul_granule``); utilization is
    actual/padded.
  * VPU lane/sublane utilization for elementwise/stencil work.
  * Instruction-level parallelism from unrolling saturates a deep pipeline.
  * Streaming efficiency grows with the contiguous (lane-dim) extent of each
    HBM transfer, saturating at 512 B.

A deterministic, config-hashed multiplicative noise term (σ ≈ 5%) stands in
for the measurement ruggedness real tuning sessions exhibit (paper Fig 3's
scatter); it makes the landscape non-smooth but perfectly reproducible.
"""

from __future__ import annotations

import hashlib
import math
import struct
from dataclasses import dataclass, field

import numpy as np

from repro.core.device import DeviceSpec
from repro.core.param import Config, ConfigSpace
from repro.core.workload import Workload

INFEASIBLE = float("inf")


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _align_eff(dim: int, granule: int) -> float:
    if dim <= 0:
        return 1e-6
    return dim / _round_up(dim, granule)


def _hash_noise(key: str, sigma: float) -> float:
    """Deterministic lognormal-ish multiplicative noise from a string key."""
    h = hashlib.sha256(key.encode()).digest()
    # two uniform floats from the hash -> one gaussian via Box-Muller
    u1 = (struct.unpack("<Q", h[:8])[0] / 2**64) or 1e-12
    u2 = struct.unpack("<Q", h[8:16])[0] / 2**64
    z = math.sqrt(-2.0 * math.log(u1)) * math.cos(2 * math.pi * u2)
    return math.exp(sigma * z)


@dataclass(frozen=True)
class CostModel:
    """Analytical simulated-TPU objective for one device.

    ``time(workload, dtype)`` returns simulated seconds for one launch
    (``INFEASIBLE`` when the working set blows past the spill grace),
    combining roofline compute/memory terms, MXU/VPU alignment and ILP
    efficiencies, per-program overhead, and a deterministic
    config-hashed noise term that makes the landscape rugged but
    perfectly reproducible (see the module docstring for the formulas).

    Example::

        m = CostModel(get_device("tpu-v5e"), noise_sigma=0)
        t = m.time(builder.make_workload(cfg, (256, 256, 256), "float32"),
                   "float32")
    """

    device: DeviceSpec
    noise_sigma: float = 0.05
    pipeline_depth: int = 4      # stages hidden by full unrolling

    def peak_flops(self, dtype: str) -> float:
        if dtype in ("bfloat16", "float16"):
            return self.device.flops_bf16
        return self.device.flops_f32

    # Up to 4x VMEM overflow degrades (the TPU analogue of register
    # spilling: Mosaic falls back to smaller internal tiling / extra HBM
    # round-trips); beyond that the config is genuinely uncompilable.
    spill_grace: float = 4.0
    spill_slope: float = 3.0

    def time(self, w: Workload, dtype: str, noise_key: str = "") -> float:
        """Simulated seconds for one launch; INFEASIBLE when the working
        set exceeds spill_grace x VMEM."""
        if not w.valid:
            return INFEASIBLE
        overflow = w.vmem_bytes / self.device.vmem_bytes - 1.0
        if overflow > self.spill_grace - 1.0:
            return INFEASIBLE
        peak = self.peak_flops(dtype)

        # --- compute term ---
        if w.mxu_tile is not None:
            # matmul-unit tiles pad to the device's granule (128 on the
            # TPU systolic array, 16 on GPU tensor cores)
            g = self.device.matmul_granule
            m, n, k = w.mxu_tile
            eff = (_align_eff(m, g) * _align_eff(n, g)
                   * _align_eff(k, g))
            eff = max(eff, 0.02)
        else:
            # VPU work: (8, 128) native tile
            eff = _align_eff(w.lane_extent, 128) * _align_eff(
                w.sublane_extent, 8)
            # the vector unit peaks below the matmul unit (8x on TPU;
            # per-device on GPU, where CUDA-core f32 is a smaller step)
            peak = peak / self.device.vector_ratio
        ilp = min(1.0, (0.55 + 0.45 * min(w.unroll_ways, self.pipeline_depth)
                        / self.pipeline_depth))
        t_compute = w.flops / (peak * eff * ilp)

        # --- memory term ---
        dtype_bytes = 2 if dtype in ("bfloat16", "float16") else 4
        contig = w.lane_extent * dtype_bytes
        stream_eff = min(1.0, contig / 512.0) ** 0.5
        stream_eff = max(stream_eff, 0.05)
        t_memory = (w.hbm_bytes * max(w.reuse, 1e-6)
                    / (self.device.hbm_bw * stream_eff))

        if w.buffers >= 2:
            t = max(t_compute, t_memory)
            # imperfect overlap: the loser still costs a fraction
            t += 0.08 * min(t_compute, t_memory)
        else:
            t = t_compute + t_memory
        t += w.grid * self.device.program_overhead
        if overflow > 0:
            t *= 1.0 + self.spill_slope * overflow

        if self.noise_sigma > 0 and noise_key:
            t *= _hash_noise(f"{self.device.kind}|{noise_key}",
                             self.noise_sigma)
        return t


def kernel_time(workload: Workload, device: DeviceSpec, dtype: str,
                noise_key: str = "") -> float:
    """One-shot convenience: simulated seconds for one launch on
    ``device`` (a fresh default :class:`CostModel` each call).

    Example::

        t = kernel_time(builder.make_workload(cfg, problem, "float32"),
                        get_device("tpu-v5e"), "float32")
    """
    return CostModel(device).time(workload, dtype, noise_key)


# --------------------- data-driven surrogate (tunebench) ---------------------

@dataclass
class FittedCostModel:
    """Surrogate objective fitted from a recorded tuning-space dataset.

    Ridge regression of log-score on the unit-encoded config (linear +
    quadratic terms), so prediction needs only the config — no workload
    hook, no device table. It is deliberately crude: the point is a
    *cheap, data-grounded* screen (e.g. ranking candidates before live
    trials), not replacing the recorded scores themselves. ``rmse_log``
    reports training error in log-space; compare against
    ``baseline_rmse_log`` (a constant predictor) to judge whether the
    fit learned anything.

    When fitted with ``profile_features=True`` (see
    :func:`fit_from_dataset`) the design matrix additionally carries the
    roofline counters the profiler recorded per config
    (:data:`repro.prof.profile.PROFILE_FEATURES`); predictions look the
    config's counters up by its stable hash (``profile_lookup``), so the
    surrogate generalizes from *hardware structure* — a config's
    predicted compute/memory time terms — rather than raw coordinates.
    Configs the dataset never profiled contribute zero columns, which
    the centered regression treats as "no extra information".

    Example::

        model = fit_from_dataset(SpaceDataset.load("matmul.space.json"))
        ranked = sorted(space.enumerate(), key=model.predict)
    """

    space: ConfigSpace
    weights: np.ndarray
    mean_log: float
    rmse_log: float
    baseline_rmse_log: float
    n_samples: int = 0
    _dim: int = field(default=0)
    profile_lookup: dict | None = None
    n_profile_features: int = 0

    def _features(self, config: Config) -> np.ndarray:
        u = self.space.to_unit(config)
        base = np.concatenate([[1.0], u, u * u])
        if self.profile_lookup is None:
            return base
        extra = self.profile_lookup.get(
            self.space.freeze(config))
        if extra is None:
            extra = np.zeros(self.n_profile_features)
        return np.concatenate([base, extra])

    def predict(self, config: Config) -> float:
        """Predicted objective value (microseconds) for ``config``."""
        return float(math.exp(self._features(config) @ self.weights
                              + self.mean_log))

    def fit_quality(self) -> float:
        """How much structure the fit explains, in [0, 1].

        ``1 - rmse_log / baseline_rmse_log`` clamped to [0, 1]: 0 means
        the surrogate is no better than predicting the mean (it learned
        nothing), values near 1 mean the recorded landscape is almost
        fully captured. The transfer layer folds this into its
        confidence score — a prediction re-ranked through a surrogate
        that learned nothing deserves no trust.
        """
        if self.baseline_rmse_log <= 0:
            return 0.0
        return float(min(1.0, max(0.0,
                                  1.0 - self.rmse_log
                                  / self.baseline_rmse_log)))


def fit_from_dataset(dataset, ridge: float = 1e-3,
                     profile_features: bool = False) -> FittedCostModel:
    """Fit a :class:`FittedCostModel` from a recorded space.

    ``dataset`` is any object with the :class:`~repro.tunebench.SpaceDataset`
    query surface (``space()`` and ``feasible()``); the fit uses every
    feasible entry. Raises ``ValueError`` with fewer than 3 feasible
    evaluations — below that a surrogate is noise.

    ``profile_features=True`` appends each entry's recorded roofline
    counters (``entry.profile``, written by the always-on profiler in
    the tuner evaluators) as extra regression columns — the
    profile-guided surrogate. Datasets recorded before the profiler
    existed fit exactly as without the flag (all-zero columns carry no
    signal), so the flag is always safe to pass.

    Example::

        ds = SpaceDataset.load("datasets/matmul--....space.json")
        model = fit_from_dataset(ds, profile_features=True)
        model.predict({"block_m": 128, ...})
    """
    feas = dataset.feasible()
    if len(feas) < 3:
        raise ValueError(
            f"need at least 3 feasible evaluations to fit, have {len(feas)}")
    space = dataset.space()
    x = np.stack([np.concatenate([[1.0], u, u * u]) for u in
                  (space.to_unit(e.config) for e in feas)])
    lookup = None
    n_prof = 0
    if profile_features:
        # Import here: repro.prof depends on core only, but keeping the
        # tuner importable without it preserves layer independence.
        from repro.prof.profile import (PROFILE_FEATURES,
                                        profile_feature_vector)
        n_prof = len(PROFILE_FEATURES)
        cols = np.array([profile_feature_vector(
            getattr(e, "profile", None) or {}) for e in feas])
        x = np.concatenate([x, cols], axis=1)
        lookup = {space.freeze(e.config): cols[i]
                  for i, e in enumerate(feas)}
    y = np.log(np.array([e.score_us for e in feas]))
    mean_log = float(y.mean())
    yc = y - mean_log
    # ridge: (X'X + lam I) w = X'y  (bias column unpenalized via lam on all
    # is fine at this scale)
    dim = x.shape[1]
    gram = x.T @ x + ridge * np.eye(dim)
    weights = np.linalg.solve(gram, x.T @ yc)
    resid = x @ weights - yc
    return FittedCostModel(
        space=space, weights=weights, mean_log=mean_log,
        rmse_log=float(np.sqrt(np.mean(resid**2))),
        baseline_rmse_log=float(np.sqrt(np.mean(yc**2))),
        n_samples=len(feas), _dim=dim,
        profile_lookup=lookup, n_profile_features=n_prof)
