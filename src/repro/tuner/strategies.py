"""Search-space optimization strategies (paper §3, §4.3, Fig 3).

The paper's default is Bayesian optimization (15-minute budget); random
search is the unbiased baseline used for the Fig 2 histograms. We implement
both, plus simulated annealing and capped exhaustive enumeration. The GP is
pure numpy (RBF kernel, expected-improvement acquisition).

All strategies accept a warm-start ``history`` (evaluations recorded by an
earlier, interrupted session): the session *replays* those scores instead
of re-measuring, so a resumed run makes exactly the same proposals — rng
draws and model fits see identical state — and continues where the dead
session stopped. ``evaluation_to_json`` / ``evaluation_from_json`` are the
serialized form (the fleet worker checkpoints them through the sync
transport).
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from repro.core.param import Config, ConfigSpace

from .runner import EvalResult

Evaluate = Callable[[Config], EvalResult]


@dataclass
class Evaluation:
    """One evaluated config inside a tuning session.

    The session-level record (config, score, feasibility, cumulative
    wall time when measured) — what trajectories are computed from,
    what fleet workers checkpoint, and what warm-start ``history``
    lists are made of.

    Example::

        e = Evaluation(config={"x": 3}, score_us=12.5, feasible=True,
                       wall_s=0.0)
    """

    config: Config
    score_us: float
    feasible: bool
    wall_s: float          # cumulative session wall time when evaluated
    error: str = ""


def evaluation_to_json(e: Evaluation) -> dict:
    """Serialize an :class:`Evaluation` for transport/checkpointing.

    The wire form fleet workers publish on the ``state`` channel and
    datasets/warm-starts round-trip through; inverse of
    :func:`evaluation_from_json`.

    Example::

        doc = evaluation_to_json(e)
        assert evaluation_from_json(doc) == e
    """
    return {"config": dict(e.config), "score_us": e.score_us,
            "feasible": bool(e.feasible), "wall_s": e.wall_s,
            "error": e.error}


def evaluation_from_json(d: dict) -> Evaluation:
    """Rebuild an :class:`Evaluation` from its JSON wire form.

    Tolerates missing optional fields (``wall_s``, ``error``) so
    checkpoints written by older workers still load.

    Example::

        history = [evaluation_from_json(d) for d in state["evaluations"]]
        tune_bayes(space, evaluate, history=history, ...)
    """
    return Evaluation(config=dict(d["config"]),
                      score_us=float(d["score_us"]),
                      feasible=bool(d["feasible"]),
                      wall_s=float(d.get("wall_s", 0.0)),
                      error=str(d.get("error", "")))


@dataclass
class TuningResult:
    """What one tuning session found: the winner plus the full log.

    ``best_config`` is None when nothing feasible was seen (then
    ``best_score_us`` is ``inf``). ``evaluations`` is the complete
    session log in evaluation order — the raw material for convergence
    trajectories, dataset recording, and warm starts.

    Example::

        res = tune_bayes(space, evaluate, max_evals=100)
        print(res.best_score_us, len(res.evaluations))
        for wall_s, best in res.trajectory():
            ...
    """

    strategy: str
    best_config: Config | None
    best_score_us: float
    evaluations: list[Evaluation] = field(default_factory=list)
    wall_s: float = 0.0

    @property
    def feasible_evaluations(self) -> list[Evaluation]:
        return [e for e in self.evaluations if e.feasible]

    def trajectory(self) -> list[tuple[float, float]]:
        """(wall_s, best-so-far score) pairs — the Fig 3 dashed line."""
        out, best = [], float("inf")
        for e in self.evaluations:
            if e.feasible and e.score_us < best:
                best = e.score_us
            if math.isfinite(best):
                out.append((e.wall_s, best))
        return out


class _Session:
    """Shared bookkeeping: dedup, budget, best-so-far."""

    MAX_CONSECUTIVE_DUPS = 300   # space likely exhausted beyond this

    def __init__(self, space: ConfigSpace, evaluate: Evaluate,
                 max_evals: int, time_budget_s: float | None,
                 history: Sequence[Evaluation] | None = None):
        self.space = space
        self.evaluate = evaluate
        self.max_evals = max_evals
        self.time_budget_s = time_budget_s
        self.t0 = time.perf_counter()
        self.seen: dict[tuple, Evaluation] = {}
        self.evals: list[Evaluation] = []
        self.best: Evaluation | None = None
        self._dups = 0
        # Warm start: recorded evaluations from an interrupted session,
        # consumed (instead of re-measured) when the strategy re-proposes
        # the same config. The strategy itself replays its decision
        # sequence from a fresh rng, so a same-seed resume walks the same
        # prefix for free and continues live past it.
        self._replay: dict[tuple, Evaluation] = {
            space.freeze(e.config): e for e in (history or [])}

    def exhausted(self) -> bool:
        if len(self.evals) >= self.max_evals:
            return True
        if self._dups >= self.MAX_CONSECUTIVE_DUPS:
            return True   # the whole valid space has (likely) been seen
        if (self.time_budget_s is not None
                and time.perf_counter() - self.t0 >= self.time_budget_s):
            return True
        return False

    def run(self, config: Config) -> Evaluation:
        key = self.space.freeze(config)
        if key in self.seen:
            self._dups += 1
            return self.seen[key]
        self._dups = 0
        recorded = self._replay.pop(key, None)
        if recorded is not None:
            ev = recorded
        else:
            r = self.evaluate(config)
            ev = Evaluation(config=dict(config), score_us=r.score_us,
                            feasible=r.feasible,
                            wall_s=time.perf_counter() - self.t0,
                            error=r.error)
        self.seen[key] = ev
        self.evals.append(ev)
        if ev.feasible and (self.best is None
                            or ev.score_us < self.best.score_us):
            self.best = ev
        return ev

    def feasible(self) -> list[Evaluation]:
        return [e for e in self.evals if e.feasible]

    def result(self, strategy: str) -> TuningResult:
        return TuningResult(
            strategy=strategy,
            best_config=dict(self.best.config) if self.best else None,
            best_score_us=self.best.score_us if self.best else float("inf"),
            evaluations=self.evals,
            wall_s=time.perf_counter() - self.t0)


def tune_random(space: ConfigSpace, evaluate: Evaluate, max_evals: int = 200,
                rng: np.random.Generator | None = None,
                time_budget_s: float | None = None,
                history: Sequence[Evaluation] | None = None) -> TuningResult:
    """Random search — the unbiased baseline (paper Fig 2's histograms).

    Rejection-samples valid configs uniformly; when the budget covers
    the whole space it switches to shuffled exhaustive enumeration so
    small spaces are covered without duplicate proposals.

    Example::

        res = tune_random(builder.space, evaluator, max_evals=200,
                          rng=np.random.default_rng(0))
    """
    rng = rng or np.random.default_rng(0)
    if space.cardinality() <= max_evals:
        # budget covers the whole space: shuffled exhaustive enumeration
        s = _Session(space, evaluate, max_evals, time_budget_s, history)
        cfgs = list(space.enumerate())
        rng.shuffle(cfgs)
        for cfg in cfgs:
            if s.exhausted():
                break
            s.run(cfg)
        return s.result("random")
    s = _Session(space, evaluate, max_evals, time_budget_s, history)
    while not s.exhausted():
        cfg = space.sample(rng, 1)[0]
        s.run(cfg)
    return s.result("random")


def tune_exhaustive(space: ConfigSpace, evaluate: Evaluate,
                    limit: int = 100_000,
                    history: Sequence[Evaluation] | None = None
                    ) -> TuningResult:
    """Enumerate the valid space in lexicographic order (capped).

    The only strategy guaranteed to find the true optimum — when the
    space fits the ``limit``. Used for small spaces, fleet shards, and
    recording complete tuning-space datasets.

    Example::

        res = tune_exhaustive(builder.space, evaluator, limit=1000)
        assert res.best_config is not None
    """
    s = _Session(space, evaluate, limit, None, history)
    for cfg in space.enumerate(limit=limit):
        if s.exhausted():
            break
        s.run(cfg)
    return s.result("exhaustive")


def tune_anneal(space: ConfigSpace, evaluate: Evaluate, max_evals: int = 200,
                rng: np.random.Generator | None = None,
                time_budget_s: float | None = None,
                t0: float = 0.3, t1: float = 0.01,
                history: Sequence[Evaluation] | None = None) -> TuningResult:
    """Simulated annealing over single-parameter mutations.

    A local search that accepts worse neighbors with probability
    ``exp(-relative_regression / temperature)``; the temperature decays
    geometrically from ``t0`` to ``t1`` over the eval budget, and the
    walk periodically restarts from the incumbent best. Strong on
    rugged landscapes where most of the space is bad but optima cluster.

    Example::

        res = tune_anneal(builder.space, evaluator, max_evals=200,
                          rng=np.random.default_rng(0))
    """
    rng = rng or np.random.default_rng(0)
    s = _Session(space, evaluate, max_evals, time_budget_s, history)
    cur = s.run(space.default_config())
    tries = 0
    while not s.exhausted():
        frac = len(s.evals) / max(s.max_evals, 1)
        temp = t0 * (t1 / t0) ** frac
        cand = space.neighbor(cur.config, rng)
        ev = s.run(cand)
        tries += 1
        if not cur.feasible:
            cur = ev
            continue
        if ev.feasible:
            # relative-improvement acceptance
            delta = (ev.score_us - cur.score_us) / max(cur.score_us, 1e-9)
            if delta <= 0 or rng.random() < math.exp(-delta / temp):
                cur = ev
        if tries % 50 == 0 and s.best is not None:
            cur = s.best  # periodic restart from incumbent
    return s.result("anneal")


# ----------------------------- Bayesian (GP-EI) -----------------------------

def _rbf(a: np.ndarray, b: np.ndarray, ls: float) -> np.ndarray:
    d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
    return np.exp(-0.5 * d2 / ls**2)


def _gp_posterior(x: np.ndarray, y: np.ndarray, xq: np.ndarray,
                  ls: float = 0.25, noise: float = 1e-3
                  ) -> tuple[np.ndarray, np.ndarray]:
    k = _rbf(x, x, ls) + noise * np.eye(len(x))
    kq = _rbf(xq, x, ls)
    try:
        chol = np.linalg.cholesky(k)
    except np.linalg.LinAlgError:
        chol = np.linalg.cholesky(k + 1e-6 * np.eye(len(x)))
    alpha = np.linalg.solve(chol.T, np.linalg.solve(chol, y))
    mean = kq @ alpha
    v = np.linalg.solve(chol, kq.T)
    var = np.clip(1.0 - (v**2).sum(0), 1e-12, None)
    return mean, var


def _expected_improvement(mean: np.ndarray, var: np.ndarray,
                          best: float) -> np.ndarray:
    std = np.sqrt(var)
    z = (best - mean) / std
    cdf = 0.5 * (1 + np.vectorize(math.erf)(z / math.sqrt(2)))
    pdf = np.exp(-0.5 * z**2) / math.sqrt(2 * math.pi)
    return (best - mean) * cdf + std * pdf


def tune_bayes(space: ConfigSpace, evaluate: Evaluate, max_evals: int = 200,
               rng: np.random.Generator | None = None,
               time_budget_s: float | None = None,
               n_init: int = 12, pool: int = 256,
               history: Sequence[Evaluation] | None = None) -> TuningResult:
    """Bayesian optimization: GP + expected improvement over the
    unit-encoded config space (the paper's default strategy, per
    Willemsen et al. [28]).

    After ``n_init`` seeding evaluations, each step fits a pure-numpy
    RBF Gaussian process to the (log-scored, normalized) feasible
    history and evaluates the candidate — drawn from a random pool plus
    neighbors of the incumbent — with the highest expected improvement.
    The strategy of choice when evaluations are expensive.

    Example::

        res = tune_bayes(builder.space, evaluator, max_evals=200,
                         rng=np.random.default_rng(0))
    """
    rng = rng or np.random.default_rng(0)
    s = _Session(space, evaluate, max_evals, time_budget_s, history)
    # Latin-ish init: default + random
    s.run(space.default_config())
    for cfg in space.sample(rng, max(n_init - 1, 1)):
        if s.exhausted():
            break
        s.run(cfg)
    while not s.exhausted():
        feas = [e for e in s.evals if e.feasible]
        if len(feas) < 3:
            s.run(space.sample(rng, 1)[0])
            continue
        # Fit GP on (up to) the most recent 160 feasible evals, log-scores
        feas = feas[-160:]
        x = np.stack([space.to_unit(e.config) for e in feas])
        y = np.log(np.array([e.score_us for e in feas]))
        mu, sd = y.mean(), y.std() + 1e-9
        yn = (y - mu) / sd
        # candidate pool: random + neighbors of the incumbent
        cands = space.sample(rng, pool // 2)
        if s.best is not None:
            cands += [space.neighbor(s.best.config, rng)
                      for _ in range(pool // 2)]
        seen_keys = set(s.seen)
        cands = [c for c in cands if space.freeze(c) not in seen_keys]
        if not cands:
            s.run(space.sample(rng, 1)[0])
            continue
        xq = np.stack([space.to_unit(c) for c in cands])
        mean, var = _gp_posterior(x, yn, xq)
        ei = _expected_improvement(mean, var, yn.min())
        s.run(cands[int(np.argmax(ei))])
    return s.result("bayes")


#: Strategy registry: name -> callable, the lookup every CLI flag, job
#: spec, and harness strategy list goes through. All entries share the
#: signature ``(space, evaluate, ..., history=None) -> TuningResult``
#: (``tune_exhaustive`` takes ``limit`` instead of ``max_evals``/``rng``).
#: E.g. ``STRATEGIES["bayes"](space, evaluate, max_evals=100)``.
STRATEGIES: dict[str, Callable[..., TuningResult]] = {
    "random": tune_random,
    "bayes": tune_bayes,
    "anneal": tune_anneal,
    "exhaustive": tune_exhaustive,
}
