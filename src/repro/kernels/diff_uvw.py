"""diff_uvw — the paper's second MicroHH kernel (§5.2): Smagorinsky-style
diffusion of (u, v, w) with a variable eddy viscosity, halo-1 stencil.

Extra tunable vs advec_u: ``fuse_outputs`` — compute all three tendencies in
one kernel (inputs read once) vs three single-output passes (lower VMEM
pressure, 3x input traffic). This is the TPU-shaped analogue of the paper's
observation that algorithmic variants belong in the search space.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import numpy as np

from repro.core import KernelBuilder, Workload, register
from repro.core.builder import probe_array

from . import ref as _ref
from ._lowering import lowering_kwargs
from ._stencil_common import (FieldView, HALO_BLK, check_blocks, field_specs,
                              out_spec, stencil_grid, stencil_hbm_bytes,
                              stencil_vmem_bytes)


builder = KernelBuilder("diff_uvw", source="repro.kernels.diff_uvw")
builder.tune("block_z", (4, 8, 16, 32), default=16)
builder.tune("block_y", (8, 16, 32, 64, 128, 256), default=32)
builder.tune("traversal", ("zy", "yz"), default="zy")
builder.tune("unroll_z", (1, 2, 4), default=1)
builder.tune("fuse_outputs", (True, False), default=True)
builder.tune("dim_semantics", ("arbitrary", "parallel"), default="arbitrary")
builder.restriction("block_z % unroll_z == 0")


@builder.problem_size
def _problem(u, v, w, evisc, scal):
    return tuple(int(d) for d in u.shape)


def _axis_shifts(view: FieldView, rows):
    return (lambda s: view.sx(s, rows), lambda s: view.sy(s, rows),
            lambda s: view.sz(s, rows))


def _fused_kernel(unroll_z, *refs):
    (scal_ref,
     u_refs, v_refs, w_refs, e_refs,
     ut_ref, vt_ref, wt_ref) = (refs[0], refs[1:6], refs[6:11], refs[11:16],
                                refs[16:21], refs[21], refs[22], refs[23])
    dxi, dyi, dzi = scal_ref[0, 0], scal_ref[0, 1], scal_ref[0, 2]
    views = [FieldView.from_refs(*rs) for rs in (u_refs, v_refs, w_refs,
                                                 e_refs)]
    fu, fv, fw, fe = views
    bz = fu.bz
    rows_per = bz // unroll_z
    for c in range(unroll_z):
        rows = slice(c * rows_per, (c + 1) * rows_per)
        se = _axis_shifts(fe, rows)
        for view, out in ((fu, ut_ref), (fv, vt_ref), (fw, wt_ref)):
            sf = _axis_shifts(view, rows)
            ft = _ref.diff_field(*sf, *se, dxi, dyi, dzi)
            out[rows] = ft.astype(out.dtype)


def _single_kernel(unroll_z, *refs):
    (scal_ref, f_refs, e_refs, out_ref) = (refs[0], refs[1:6], refs[6:11],
                                           refs[11])
    dxi, dyi, dzi = scal_ref[0, 0], scal_ref[0, 1], scal_ref[0, 2]
    ff = FieldView.from_refs(*f_refs)
    fe = FieldView.from_refs(*e_refs)
    bz = ff.bz
    rows_per = bz // unroll_z
    for c in range(unroll_z):
        rows = slice(c * rows_per, (c + 1) * rows_per)
        ft = _ref.diff_field(*_axis_shifts(ff, rows), *_axis_shifts(fe, rows),
                             dxi, dyi, dzi)
        out_ref[rows] = ft.astype(out_ref.dtype)


def _compiler_kwargs(config, interpret):
    # Gated on the active DeviceSpec.backend (not on whether pltpu
    # merely imports): Mosaic dimension_semantics reach only a TPU
    # lowering, Triton warps/stages only a GPU one.
    return lowering_kwargs(
        dimension_semantics=(config["dim_semantics"],) * 2,
        num_warps=8 if config["block_y"] >= 64 else 4,
        num_stages=min(4, 1 + config["unroll_z"]),
        interpret=interpret)


@builder.build
def _build(config, problem, meta, interpret: bool = False):
    nz, ny, nx = problem
    bz, by = config["block_z"], config["block_y"]
    if not check_blocks(problem, bz, by):
        raise ValueError(f"blocks ({bz},{by}) do not tile problem {problem}")
    grid, to_zy = stencil_grid(problem, bz, by, config["traversal"])
    scal_spec = pl.BlockSpec((1, 4), lambda a, b: (0, 0))
    fspecs = field_specs(problem, bz, by, to_zy)
    ospec = out_spec(problem, bz, by, to_zy)
    dtype = meta[0].dtype
    oshape = jax.ShapeDtypeStruct((nz, ny, nx), dtype)
    kwargs = _compiler_kwargs(config, interpret)

    if config["fuse_outputs"]:
        call = pl.pallas_call(
            functools.partial(_fused_kernel, config["unroll_z"]),
            grid=grid,
            in_specs=[scal_spec] + fspecs * 4,
            out_specs=[ospec] * 3,
            out_shape=[oshape] * 3,
            interpret=interpret, **kwargs)

        def run(u, v, w, evisc, scal):
            reps = lambda f: (f,) * 5  # noqa: E731
            return tuple(call(scal, *reps(u), *reps(v), *reps(w),
                              *reps(evisc)))

        return run

    call = pl.pallas_call(
        functools.partial(_single_kernel, config["unroll_z"]),
        grid=grid,
        in_specs=[scal_spec] + fspecs * 2,
        out_specs=ospec,
        out_shape=oshape,
        interpret=interpret, **kwargs)

    def run(u, v, w, evisc, scal):
        reps = lambda f: (f,) * 5  # noqa: E731
        return tuple(call(scal, *reps(f), *reps(evisc))
                     for f in (u, v, w))

    return run


builder.reference(_ref.diff_uvw_ref)


@builder.probe
def _probe(problem, dtype):
    rng = np.random.default_rng(0)
    u, v, w = (probe_array(rng, problem, dtype) for _ in range(3))
    # eddy viscosity is physically nonnegative
    evisc = np.abs(probe_array(rng, problem, dtype)) + np.asarray(
        0.1, dtype=u.dtype)
    scal = np.array([[1.1, 0.9, 1.3, 0.0]], np.float32)
    return u, v, w, evisc, scal


@builder.workload
def _workload(config, problem, dtype):
    nz, ny, nx = problem
    bz, by = config["block_z"], config["block_y"]
    if not check_blocks(problem, bz, by):
        return Workload(0, 0, 0, 0, valid=False)
    b = 2 if dtype in ("bfloat16", "float16") else 4
    pts = nz * ny * nx
    flops = pts * _ref.DIFF_FLOPS_PER_POINT_PER_FIELD * 3
    grid = (nz // bz) * (ny // by)
    reuse = 0.92 if config["traversal"] == "zy" else 1.06
    if config["dim_semantics"] == "parallel":
        reuse *= 0.98
    if config["fuse_outputs"]:
        vmem = stencil_vmem_bytes(problem, bz, by, 4, 3, 4)
        hbm = stencil_hbm_bytes(problem, bz, by, 4, 3, b)
    else:
        # three passes: each reads its field + evisc, writes one output
        vmem = stencil_vmem_bytes(problem, bz, by, 2, 1, 4)
        hbm = 3 * stencil_hbm_bytes(problem, bz, by, 2, 1, b)
        grid *= 3
    return Workload(
        flops=flops, hbm_bytes=hbm, vmem_bytes=int(vmem), grid=grid,
        mxu_tile=None, lane_extent=nx, sublane_extent=by,
        unroll_ways=config["unroll_z"], reuse=reuse,
        notes={"bz": bz, "by": by, "fused": config["fuse_outputs"]})


register(builder)
