"""Backend-aware Pallas lowering: one place that decides compiler params.

Every kernel's ``_build`` needs the same decision: which compiler-param
object (if any) may ride along with ``pl.pallas_call``. The old guard —
"``pltpu`` imported, so pass TPU params" — was wrong on any machine
where the TPU package *imports* but the active device is a GPU or a CPU
host: Mosaic-only kwargs (``dimension_semantics``) would reach a Triton
or interpreter lowering and fail. The decision belongs to the active
:class:`~repro.core.device.DeviceSpec`'s ``backend``, not to what
happens to be importable.

:func:`lowering_kwargs` is that decision:

* backend ``"tpu"``   -> Mosaic ``TPUCompilerParams(dimension_semantics)``
* backend ``"gpu"``   -> ``TritonCompilerParams(num_warps, num_stages)``
* backend ``"cpu"``   -> no params (the interpreter takes none)
* ``interpret=True``  -> no params, on any backend (the CI story: GPU
  and TPU lowerings both run under the Pallas interpreter on hosts
  without the hardware, and the interpreter rejects backend params)

Kernels still own their *structural* backend choices (scratch memory,
grid shape); this module only centralizes the compiler-param gate so no
kernel can re-grow the ``pltpu is None`` bug.
"""

from __future__ import annotations

from repro.core.device import current_device

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None

try:
    from jax.experimental.pallas import triton as pltriton
except Exception:  # pragma: no cover
    pltriton = None

__all__ = ["active_backend", "lowering_kwargs"]


def active_backend() -> str:
    """The active device's lowering backend ("tpu" | "gpu" | "cpu")."""
    return current_device().backend


def _tpu_params(dimension_semantics):
    cp = getattr(pltpu, "CompilerParams",
                 getattr(pltpu, "TPUCompilerParams", None))
    if cp is None:  # pragma: no cover — very old pallas
        return {}
    return {"compiler_params":
            cp(dimension_semantics=tuple(dimension_semantics))}


def _gpu_params(num_warps, num_stages):
    cp = getattr(pltriton, "CompilerParams",
                 getattr(pltriton, "TritonCompilerParams", None))
    if cp is None:  # pragma: no cover — pallas without a Triton backend
        return {}
    kw = {}
    if num_warps is not None:
        kw["num_warps"] = int(num_warps)
    if num_stages is not None:
        kw["num_stages"] = int(num_stages)
    return {"compiler_params": cp(**kw)}


def lowering_kwargs(*, dimension_semantics=(), num_warps=None,
                    num_stages=None, interpret: bool = False,
                    backend: str | None = None) -> dict:
    """The ``pl.pallas_call`` kwargs the active backend accepts.

    ``dimension_semantics`` feeds the Mosaic (TPU) params; ``num_warps``
    and ``num_stages`` feed the Triton (GPU) params — callers pass both
    sets and exactly one (or neither) is used. Returns ``{}`` under
    ``interpret`` and on backends whose param class is unavailable, so
    the call site never needs its own availability guard.

    Example::

        kwargs = lowering_kwargs(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
            num_warps=4, num_stages=2, interpret=interpret)
        pl.pallas_call(body, grid=grid, ..., **kwargs)
    """
    if interpret:
        return {}
    b = backend if backend is not None else active_backend()
    if b == "tpu" and pltpu is not None and dimension_semantics:
        return _tpu_params(dimension_semantics)
    if b == "gpu" and pltriton is not None:
        return _gpu_params(num_warps, num_stages)
    return {}
