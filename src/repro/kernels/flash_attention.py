"""Flash attention (forward) with tunable (block_q, block_k) — the LM-stack
hot-spot that integrates Kernel Launcher into the model framework.

Layout: heads are flattened into the leading axis — q: (B*Hq, S, D),
k/v: (B*Hkv, S, D). GQA is handled *inside* the index map (kv head =
q head // group), so grouped kv is never materialized. Online softmax state
lives in f32 VMEM scratch; the k axis is the innermost, "arbitrary" grid
dimension. Fully-masked causal blocks are skipped with ``pl.when``.

Two builders are registered (causal / full) because causality changes the
problem's workload, not just a value — they tune and store wisdom
independently.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import KernelBuilder, Workload, register
from repro.core.builder import probe_array

from . import ref as _ref
from ._lowering import active_backend, lowering_kwargs

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None

NEG_INF = -1e30


def _fa_kernel(causal: bool, scale: float, nk: int, bq: int, bk: int,
               q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def body():
        q = q_ref[0].astype(jnp.float32)            # (bq, D)
        k = k_ref[0].astype(jnp.float32)            # (bk, D)
        v = v_ref[0].astype(jnp.float32)            # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if causal:
            q_pos = qb * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                       (bq, bk), 0)
            k_pos = kb * bk + jax.lax.broadcasted_iota(jnp.int32,
                                                       (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[:, :1]                        # (bq, 1)
        l_prev = l_ref[:, :1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                       # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)              # (bq, 1)
        l_new = alpha * l_prev + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    if causal:
        # skip blocks entirely above the diagonal
        pl.when(qb * bq + bq - 1 >= kb * bk)(body)
    else:
        body()

    @pl.when(kb == nk - 1)
    def _store():
        l = l_ref[:, :1]
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def _make_builder(causal: bool) -> KernelBuilder:
    name = "flash_attention_causal" if causal else "flash_attention_full"
    b = KernelBuilder(name, source="repro.kernels.flash_attention")
    b.tune("block_q", (128, 256, 512, 1024), default=128)
    b.tune("block_k", (128, 256, 512, 1024), default=128)
    b.tune("dim_semantics", ("arbitrary", "parallel"), default="arbitrary")

    @b.problem_size
    def _problem(q, k, v):
        bh, s, d = q.shape
        return (int(bh), int(k.shape[0]), int(s), int(d))

    @b.build
    def _build(config, problem, meta, interpret: bool = False):
        BH, BHkv, S, D = problem
        group = BH // BHkv
        bq = min(config["block_q"], S)
        bk = min(config["block_k"], S)
        if S % bq or S % bk:
            raise ValueError(f"blocks ({bq},{bk}) do not tile seq {S}")
        gq, gk = S // bq, S // bk
        scale = 1.0 / (D ** 0.5)

        if active_backend() == "gpu":
            # No Triton lowering yet (see docs/gpu-backend.md's lowering
            # matrix); ops.attention never routes here on GPU devices.
            raise NotImplementedError(
                f"{name} has no GPU lowering; use kernels.ops.attention, "
                f"which falls back to the reference path on GPU")
        kwargs = lowering_kwargs(
            dimension_semantics=(config["dim_semantics"],) * 2
            + ("arbitrary",),
            interpret=interpret)
        if pltpu is None:  # pragma: no cover
            raise RuntimeError("pallas TPU backend unavailable")

        call = pl.pallas_call(
            functools.partial(_fa_kernel, causal, scale, gk, bq, bk),
            grid=(BH, gq, gk),
            in_specs=[
                pl.BlockSpec((1, bq, D), lambda h, iq, ik: (h, iq, 0)),
                pl.BlockSpec((1, bk, D),
                             lambda h, iq, ik, g=group: (h // g, ik, 0)),
                pl.BlockSpec((1, bk, D),
                             lambda h, iq, ik, g=group: (h // g, ik, 0)),
            ],
            out_specs=pl.BlockSpec((1, bq, D), lambda h, iq, ik: (h, iq, 0)),
            out_shape=jax.ShapeDtypeStruct((BH, S, D), meta[0].dtype),
            scratch_shapes=[
                pltpu.VMEM((bq, D), jnp.float32),
                pltpu.VMEM((bq, 128), jnp.float32),
                pltpu.VMEM((bq, 128), jnp.float32),
            ],
            interpret=interpret, **kwargs)
        return call

    b.reference(_ref.flash_attention_ref_factory(causal))

    @b.probe
    def _probe(problem, dtype):
        BH, BHkv, S, D = problem
        rng = np.random.default_rng(0)
        scale = 1.0 / (D ** 0.5)
        return (probe_array(rng, (BH, S, D), dtype, scale),
                probe_array(rng, (BHkv, S, D), dtype, scale),
                probe_array(rng, (BHkv, S, D), dtype, scale))

    @b.workload
    def _workload(config, problem, dtype, _causal=causal):
        BH, BHkv, S, D = problem
        bq = min(config["block_q"], S)
        bk = min(config["block_k"], S)
        if S % bq or S % bk:
            return Workload(0, 0, 0, 0, valid=False)
        byt = 2 if dtype in ("bfloat16", "float16") else 4
        gq, gk = S // bq, S // bk
        frac = 0.5 + 0.5 / gk if _causal else 1.0   # causal block skipping
        flops = 4.0 * BH * S * S * D * frac
        # q/o once; k/v streamed once per q block
        hbm = (2 * BH * S * D + 2 * BHkv * S * D * gq * frac) * byt
        vmem = ((bq * D + 2 * bk * D) * byt * 2
                + bq * D * 4 + 2 * bq * 128 * 4 + bq * D * byt)
        return Workload(
            flops=flops, hbm_bytes=float(hbm), vmem_bytes=int(vmem),
            grid=int(BH * gq * gk * frac) + 1,
            mxu_tile=(bq, bk, D), lane_extent=D, sublane_extent=bq,
            reuse=1.0, notes={"bq": bq, "bk": bk})

    register(b)
    return b


causal_builder = _make_builder(True)
full_builder = _make_builder(False)
