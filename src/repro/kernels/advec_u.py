"""advec_u — the paper's first MicroHH kernel (§5.2), as a tunable Pallas
TPU kernel: flux-form advection with 5th-order interpolation on a periodic
3-D grid.

TPU adaptation of the paper's Table 2 parameters (see DESIGN.md §2):
  block_z/block_y      <- Block size X/Y/Z   (X stays whole: lane dim)
  traversal            <- Unravel permutation (grid-major order)
  unroll_z             <- Loop unrolling / tile factor
  dim_semantics        <- scheduling freedom given to Mosaic
The paper's register-pressure axis (min blocks per SM) becomes the VMEM
feasibility restriction enforced by the workload model.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import numpy as np

from repro.core import KernelBuilder, Workload, register
from repro.core.builder import probe_array

from . import ref as _ref
from ._lowering import lowering_kwargs
from ._stencil_common import (FieldView, HALO_BLK, check_blocks, field_specs,
                              out_spec, stencil_grid, stencil_hbm_bytes,
                              stencil_vmem_bytes)


builder = KernelBuilder("advec_u", source="repro.kernels.advec_u")
builder.tune("block_z", (4, 8, 16, 32), default=16)
builder.tune("block_y", (8, 16, 32, 64, 128, 256), default=32)
builder.tune("traversal", ("zy", "yz"), default="zy")
builder.tune("unroll_z", (1, 2, 4), default=1)
builder.tune("dim_semantics", ("arbitrary", "parallel"), default="arbitrary")
builder.restriction("block_z % unroll_z == 0")


@builder.problem_size
def _problem(u, v, w, scal):
    return tuple(int(d) for d in u.shape)


def _kernel_body(unroll_z: int, refs):
    (scal_ref,
     u_c, u_zl, u_zh, u_yl, u_yh,
     v_c, v_zl, v_zh, v_yl, v_yh,
     w_c, w_zl, w_zh, w_yl, w_yh,
     out_ref) = refs
    fu = FieldView.from_refs(u_c, u_zl, u_zh, u_yl, u_yh)
    fv = FieldView.from_refs(v_c, v_zl, v_zh, v_yl, v_yh)
    fw = FieldView.from_refs(w_c, w_zl, w_zh, w_yl, w_yh)
    dxi = scal_ref[0, 0]
    dyi = scal_ref[0, 1]
    dzi = scal_ref[0, 2]
    bz = fu.bz
    rows_per = bz // unroll_z
    for c in range(unroll_z):           # python loop == unrolled code
        rows = slice(c * rows_per, (c + 1) * rows_per)
        ut = _ref.advec_terms(
            su_x=lambda s: fu.sx(s, rows), su_y=lambda s: fu.sy(s, rows),
            su_z=lambda s: fu.sz(s, rows), sv_y=lambda s: fv.sy(s, rows),
            sw_z=lambda s: fw.sz(s, rows), dxi=dxi, dyi=dyi, dzi=dzi)
        out_ref[rows] = ut.astype(out_ref.dtype)


@builder.build
def _build(config, problem, meta, interpret: bool = False):
    nz, ny, nx = problem
    bz, by = config["block_z"], config["block_y"]
    if not check_blocks(problem, bz, by):
        raise ValueError(f"blocks ({bz},{by}) do not tile problem {problem}")
    grid, to_zy = stencil_grid(problem, bz, by, config["traversal"])
    scal_spec = pl.BlockSpec((1, 4), lambda a, b: (0, 0))
    fspecs = field_specs(problem, bz, by, to_zy)
    in_specs = [scal_spec] + fspecs * 3
    # Compiler params are gated on the active DeviceSpec.backend (not on
    # whether pltpu merely imports): Mosaic dimension_semantics on TPU,
    # Triton warps/stages on GPU, nothing under interpret.
    kwargs = lowering_kwargs(
        dimension_semantics=(config["dim_semantics"],) * 2,
        num_warps=8 if by >= 64 else 4,
        num_stages=min(4, 1 + config["unroll_z"]),
        interpret=interpret)

    dtype = meta[0].dtype
    call = pl.pallas_call(
        functools.partial(_pallas_entry, config["unroll_z"]),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec(problem, bz, by, to_zy),
        out_shape=jax.ShapeDtypeStruct((nz, ny, nx), dtype),
        interpret=interpret,
        **kwargs,
    )

    def run(u, v, w, scal):
        return call(scal, u, u, u, u, u, v, v, v, v, v, w, w, w, w, w)

    return run


def _pallas_entry(unroll_z, *refs):
    _kernel_body(unroll_z, refs)


builder.reference(_ref.advec_u_ref)


@builder.probe
def _probe(problem, dtype):
    rng = np.random.default_rng(0)
    u, v, w = (probe_array(rng, problem, dtype) for _ in range(3))
    scal = np.array([[1.1, 0.9, 1.3, 0.0]], np.float32)
    return u, v, w, scal


@builder.workload
def _workload(config, problem, dtype):
    nz, ny, nx = problem
    bz, by = config["block_z"], config["block_y"]
    if not check_blocks(problem, bz, by):
        return Workload(0, 0, 0, 0, valid=False)
    b = 2 if dtype in ("bfloat16", "float16") else 4
    pts = nz * ny * nx
    # compute in f32 inside the kernel -> VMEM holds f32 working set
    vmem = stencil_vmem_bytes(problem, bz, by, n_in_fields=3,
                              n_out_fields=1, dtype_bytes=4)
    hbm = stencil_hbm_bytes(problem, bz, by, 3, 1, b)
    grid = (nz // bz) * (ny // by)
    # y-minor traversal streams HBM-adjacent blocks consecutively
    reuse = 0.92 if config["traversal"] == "zy" else 1.06
    if config["dim_semantics"] == "parallel":
        reuse *= 0.98  # scheduler may overlap epilogues
    return Workload(
        flops=pts * _ref.ADVEC_FLOPS_PER_POINT,
        hbm_bytes=hbm, vmem_bytes=int(vmem), grid=grid,
        mxu_tile=None, lane_extent=nx, sublane_extent=by,
        unroll_ways=config["unroll_z"], reuse=reuse,
        notes={"bz": bz, "by": by})


register(builder)
