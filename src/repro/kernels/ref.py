"""Pure-jnp oracles for every kernel.

These are (a) the correctness references the tuner verifies against (paper:
Kernel Tuner's output verification), and (b) the execution path on non-TPU
hosts (``REPRO_KERNEL_BACKEND=reference``). The *term* functions here are the
single source of truth for the stencil math — the Pallas kernels call the
same functions with block-local shift closures, so kernel and oracle cannot
drift apart.

All stencils are periodic in every axis (MicroHH is periodic in x/y; we use
fully periodic fields so halo handling is uniform).
"""

from __future__ import annotations

import functools

import jax.numpy as jnp

# --------------------------------------------------------------------------
# advec_u: 2nd-order flux-form advection with 5th-order interpolation
# (paper §5.2 kernel 1). Collocated periodic grid.
# --------------------------------------------------------------------------

_C0, _C1, _C2 = 37.0 / 60.0, -8.0 / 60.0, 1.0 / 60.0


def advec_terms(su_x, su_y, su_z, sv_y, sw_z, dxi, dyi, dzi):
    """Advection tendency of u. Each ``s*`` is a shift closure s(offset)
    returning the field shifted by ``offset`` cells along one axis
    (result[idx] = field[idx + offset], periodic)."""

    def interp(s, o):
        # 5th-order interpolation to the face between cells o-1 and o
        return (_C0 * (s(o - 1) + s(o)) + _C1 * (s(o - 2) + s(o + 1))
                + _C2 * (s(o - 3) + s(o + 2)))

    fx_p = 0.5 * (su_x(0) + su_x(1)) * interp(su_x, 1)
    fx_m = 0.5 * (su_x(-1) + su_x(0)) * interp(su_x, 0)
    fy_p = 0.5 * (sv_y(0) + sv_y(1)) * interp(su_y, 1)
    fy_m = 0.5 * (sv_y(-1) + sv_y(0)) * interp(su_y, 0)
    fz_p = 0.5 * (sw_z(0) + sw_z(1)) * interp(su_z, 1)
    fz_m = 0.5 * (sw_z(-1) + sw_z(0)) * interp(su_z, 0)
    return -(dxi * (fx_p - fx_m) + dyi * (fy_p - fy_m)
             + dzi * (fz_p - fz_m))


ADVEC_FLOPS_PER_POINT = 78  # counted from advec_terms


def _roll_shift(f, axis):
    return lambda s: f if s == 0 else jnp.roll(f, -s, axis)


def advec_u_ref(u, v, w, scal):
    """Oracle. scal is a (1, 4) f32 array [dxi, dyi, dzi, 0]."""
    dxi, dyi, dzi = scal[0, 0], scal[0, 1], scal[0, 2]
    u32 = u.astype(jnp.float32)
    v32 = v.astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    ut = advec_terms(
        su_x=_roll_shift(u32, 2), su_y=_roll_shift(u32, 1),
        su_z=_roll_shift(u32, 0), sv_y=_roll_shift(v32, 1),
        sw_z=_roll_shift(w32, 0), dxi=dxi, dyi=dyi, dzi=dzi)
    return ut.astype(u.dtype)


# --------------------------------------------------------------------------
# diff_uvw: 2nd-order Smagorinsky-style diffusion of all three velocity
# components with a variable eddy viscosity (paper §5.2 kernel 2).
# --------------------------------------------------------------------------


def diff_term(sf, se, di):
    """One-axis variable-viscosity diffusion: d/dx( ev * du/dx )."""
    ev_p = 0.5 * (se(0) + se(1))
    ev_m = 0.5 * (se(-1) + se(0))
    return (di * di) * (ev_p * (sf(1) - sf(0)) - ev_m * (sf(0) - sf(-1)))


def diff_field(sf_x, sf_y, sf_z, se_x, se_y, se_z, dxi, dyi, dzi):
    return (diff_term(sf_x, se_x, dxi) + diff_term(sf_y, se_y, dyi)
            + diff_term(sf_z, se_z, dzi))


DIFF_FLOPS_PER_POINT_PER_FIELD = 27


def diff_uvw_ref(u, v, w, evisc, scal):
    dxi, dyi, dzi = scal[0, 0], scal[0, 1], scal[0, 2]
    e32 = evisc.astype(jnp.float32)
    se = [_roll_shift(e32, ax) for ax in (2, 1, 0)]
    outs = []
    for f in (u, v, w):
        f32 = f.astype(jnp.float32)
        sf = [_roll_shift(f32, ax) for ax in (2, 1, 0)]
        ft = diff_field(sf[0], sf[1], sf[2], se[0], se[1], se[2],
                        dxi, dyi, dzi)
        outs.append(ft.astype(f.dtype))
    return tuple(outs)


# --------------------------------------------------------------------------
# matmul
# --------------------------------------------------------------------------


def matmul_ref(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


# --------------------------------------------------------------------------
# attention (full-featured oracle: GQA, causal, sliding window, softcap)
# --------------------------------------------------------------------------


BLOCKWISE_THRESHOLD = 1024  # blockwise path when Sq and Sk both reach this


def attention_ref(q, k, v, *, causal: bool = True,
                  window=None,
                  softcap: float | None = None,
                  scale: float | None = None,
                  kv_offset: int = 0):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, Dv). GQA via head repetition.

    ``window`` may be a static int or a traced scalar (0/None = full).
    ``kv_offset``: absolute position of q[0] minus position of k[0].
    Long sequences dispatch to the blockwise online-softmax path — the XLA
    equivalent of the Pallas flash kernel (O(S·chunk) memory)."""
    Sq, Sk = q.shape[2], k.shape[2]
    if Sq >= BLOCKWISE_THRESHOLD and Sk >= BLOCKWISE_THRESHOLD:
        return blockwise_attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, kv_offset=kv_offset)
    return _naive_attention_ref(q, k, v, causal=causal, window=window,
                                softcap=softcap, scale=scale,
                                kv_offset=kv_offset)


def _naive_attention_ref(q, k, v, *, causal, window, softcap, scale,
                         kv_offset):
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    if Hq != Hkv:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    Sk = k.shape[2]
    q_pos = jnp.arange(Sq)[:, None] + kv_offset
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        win = jnp.asarray(window)
        mask &= jnp.where(win > 0, (q_pos - k_pos) < win, True)
    s = jnp.where(mask[None, None], s, -1e30)
    # fully-masked rows produce 0 (matches the blockwise/flash convention)
    p = jnp.where(mask[None, None], jnp.exp(s - s.max(-1, keepdims=True)),
                  0.0)
    p = p / (p.sum(-1, keepdims=True) + 1e-30)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def blockwise_attention_ref(q, k, v, *, causal: bool = True, window=None,
                            softcap: float | None = None,
                            scale: float | None = None, kv_offset: int = 0,
                            q_chunk: int = 512, k_chunk: int = 1024):
    """Flash-style attention in pure jnp: double chunked scan with online
    softmax, O(Sq·k_chunk) live memory instead of O(Sq·Sk). Same math as
    :func:`_naive_attention_ref` up to fp reassociation."""
    import jax

    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    Sk, Dv = k.shape[2], v.shape[3]
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    if Hq != Hkv:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    # Pad both sequence dims up to chunk multiples instead of shrinking the
    # chunk: a tiny chunk explodes the scan's saved-carry count under
    # autodiff (nk residual copies of the accumulator).
    qc, kc = min(q_chunk, Sq), min(k_chunk, Sk)
    Sq_p = -(-Sq // qc) * qc
    Sk_p = -(-Sk // kc) * kc
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sq_p - Sq), (0, 0)))
    if Sk_p != Sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sk_p - Sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sk_p - Sk), (0, 0)))
    nq, nk = Sq_p // qc, Sk_p // kc

    # keep the HBM-resident copies in the input dtype; cast per chunk
    # inside the loop (a full-sequence f32 copy of q/k/v dominated the
    # prefill memory footprint otherwise — see EXPERIMENTS.md §Perf)
    qf = jnp.moveaxis(q.reshape(B, Hq, nq, qc, D), 2, 0)
    kf = jnp.moveaxis(k.reshape(B, Hq, nk, kc, D), 2, 0)
    vf = jnp.moveaxis(v.reshape(B, Hq, nk, kc, Dv), 2, 0)
    q_pos = (jnp.arange(Sq_p) + kv_offset).reshape(nq, qc)
    k_pos = jnp.arange(Sk_p).reshape(nk, kc)
    k_valid = Sk

    win = None if window is None else jnp.asarray(window)

    def one_q_chunk(args):
        qi, qp = args                                  # (B,H,qc,D), (qc,)
        qi = qi.astype(jnp.float32)

        @jax.checkpoint
        def body(carry, inp):
            m, l, acc = carry
            ki, vi, kp = inp
            ki = ki.astype(jnp.float32)
            vi = vi.astype(jnp.float32)
            s = jnp.einsum("bhqd,bhkd->bhqk", qi, ki) * scale
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            mask = kp[None, :] < k_valid            # padded keys masked
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if win is not None:
                mask &= jnp.where(win > 0,
                                  (qp[:, None] - kp[None, :]) < win, True)
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1, keepdims=True))
            # explicit zero for masked entries: in a fully-masked chunk
            # s == m_new == -1e30 and exp(s - m_new) would be 1, not 0
            p = jnp.where(mask[None, None], jnp.exp(s - m_new), 0.0)
            alpha = jnp.exp(m - m_new)
            l = alpha * l + p.sum(-1, keepdims=True)
            acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, vi)
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hq, qc, 1), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hq, qc, 1), jnp.float32)
        a0 = jnp.zeros((B, Hq, qc, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kf, vf, k_pos))
        return acc / jnp.maximum(l, 1e-30)

    out = jax.lax.map(one_q_chunk, (qf, q_pos))        # (nq, B, H, qc, Dv)
    out = jnp.moveaxis(out, 0, 2).reshape(B, Hq, Sq_p, Dv)
    return out[:, :, :Sq].astype(q.dtype)


def flash_attention_ref_factory(causal: bool):
    """Oracle matching the Pallas flash kernel's flattened-head layout:
    q: (BH, S, D), k/v: (BHkv, S, D)."""

    def ref(q, k, v):
        BH, S, D = q.shape
        BHkv = k.shape[0]
        group = BH // BHkv
        k_e = jnp.repeat(k, group, axis=0)
        v_e = jnp.repeat(v, group, axis=0)
        o = attention_ref(q[:, None], k_e[:, None], v_e[:, None],
                          causal=causal)
        return o[:, 0]

    return ref
