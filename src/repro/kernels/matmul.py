"""Blocked matmul with a tunable (block_m, block_n, block_k) tiling and grid
order — the canonical MXU kernel, used by the quickstart example and as the
simplest end-to-end demonstration of the Kernel Launcher flow.

Accumulation in an f32 VMEM scratch across the (innermost, "arbitrary") k
axis; the grid-order parameter is the TPU analogue of the paper's unravel
permutation (it changes which operand streams and which stays resident).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import KernelBuilder, Workload, register
from repro.core.builder import probe_array

from . import ref as _ref
from ._lowering import active_backend, lowering_kwargs

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None


builder = KernelBuilder("matmul", source="repro.kernels.matmul")
builder.tune("block_m", (64, 128, 256, 512), default=128)
builder.tune("block_n", (64, 128, 256, 512), default=128)
builder.tune("block_k", (128, 256, 512, 1024), default=256)
builder.tune("grid_order", ("mnk", "nmk"), default="mnk")
builder.tune("dim_semantics", ("parallel", "arbitrary"), default="parallel")


@builder.problem_size
def _problem(a, b):
    (m, k), (_, n) = a.shape, b.shape
    return (m, n, k)


def _mm_kernel(nk: int, a_ref, b_ref, o_ref, acc_ref):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(kb == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _mm_gpu_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...],
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


def _build_gpu(config, problem, meta, interpret: bool):
    """Triton-shaped lowering: a 2-D (m, n) grid with the full K stripe
    per program — accumulation stays in registers, so no TPU VMEM
    scratch is involved. ``block_k`` survives as the software-pipelining
    depth (``num_stages``) instead of a grid axis."""
    m, n, k = problem
    bm, bn, bk = config["block_m"], config["block_n"], config["block_k"]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn:
        raise ValueError(f"blocks ({bm},{bn}) do not tile {problem}")
    if config["grid_order"] == "mnk":
        grid = (m // bm, n // bn)
        ij = lambda p0, p1: (p0, p1)  # noqa: E731
    else:
        grid = (n // bn, m // bm)
        ij = lambda p0, p1: (p1, p0)  # noqa: E731

    kwargs = lowering_kwargs(
        num_warps=8 if bm * bn >= 256 * 128 else 4,
        num_stages=2 if bk >= 512 else 3,
        interpret=interpret, backend="gpu")
    return pl.pallas_call(
        _mm_gpu_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, k), lambda p0, p1: (ij(p0, p1)[0], 0)),
                  pl.BlockSpec((k, bn), lambda p0, p1: (0, ij(p0, p1)[1]))],
        out_specs=pl.BlockSpec((bm, bn), lambda p0, p1: ij(p0, p1)),
        out_shape=jax.ShapeDtypeStruct((m, n), meta[0].dtype),
        interpret=interpret, **kwargs)


@builder.build
def _build(config, problem, meta, interpret: bool = False):
    backend = active_backend()
    if backend == "gpu":
        return _build_gpu(config, problem, meta, interpret)
    m, n, k = problem
    bm, bn, bk = config["block_m"], config["block_n"], config["block_k"]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"blocks ({bm},{bn},{bk}) do not tile {problem}")
    gm, gn, gk = m // bm, n // bn, k // bk
    if config["grid_order"] == "mnk":
        grid = (gm, gn, gk)
        ij = lambda p0, p1: (p0, p1)  # noqa: E731
    else:
        grid = (gn, gm, gk)
        ij = lambda p0, p1: (p1, p0)  # noqa: E731

    def a_map(p0, p1, p2):
        i, _ = ij(p0, p1)
        return (i, p2)

    def b_map(p0, p1, p2):
        _, j = ij(p0, p1)
        return (p2, j)

    def o_map(p0, p1, p2):
        i, j = ij(p0, p1)
        return (i, j)

    kwargs = lowering_kwargs(
        dimension_semantics=(config["dim_semantics"],
                             config["dim_semantics"], "arbitrary"),
        interpret=interpret, backend=backend)

    dtype = meta[0].dtype
    if pltpu is None:  # pragma: no cover
        raise RuntimeError("pallas TPU backend unavailable")
    scratch = [pltpu.VMEM((bm, bn), jnp.float32)]

    call = pl.pallas_call(
        functools.partial(_mm_kernel, gk),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), a_map),
                  pl.BlockSpec((bk, bn), b_map)],
        out_specs=pl.BlockSpec((bm, bn), o_map),
        out_shape=jax.ShapeDtypeStruct((m, n), dtype),
        scratch_shapes=scratch,
        interpret=interpret, **kwargs)

    return call


builder.reference(_ref.matmul_ref)


@builder.probe
def _probe(problem, dtype):
    m, n, k = problem
    rng = np.random.default_rng(0)
    return (probe_array(rng, (m, k), dtype),
            probe_array(rng, (k, n), dtype))


@builder.workload
def _workload(config, problem, dtype):
    m, n, k = problem
    bm = min(config["block_m"], m)
    bn = min(config["block_n"], n)
    bk = min(config["block_k"], k)
    if m % bm or n % bn or k % bk:
        return Workload(0, 0, 0, 0, valid=False)
    b = 2 if dtype in ("bfloat16", "float16") else 4
    grid = (m // bm) * (n // bn) * (k // bk)
    # A re-read per n-block, B re-read per m-block, C written once.
    hbm = m * k * b * (n // bn) + k * n * b * (m // bm) + m * n * b
    vmem = (bm * bk + bk * bn) * b * 2 + bm * bn * 4 + bm * bn * b
    return Workload(
        flops=2.0 * m * n * k, hbm_bytes=float(hbm), vmem_bytes=int(vmem),
        grid=grid, mxu_tile=(bm, bn, bk), lane_extent=bn,
        sublane_extent=bm, unroll_ways=1,
        reuse=1.0 if config["grid_order"] == "mnk" else 1.02,
        notes={"bm": bm, "bn": bn, "bk": bk})


register(builder)
