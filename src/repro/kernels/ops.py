"""Public jit-friendly entry points for all kernels.

Each op routes through a module-level :class:`WisdomKernel` — the runtime
selection + compilation layer (paper §4.5). On TPU the Pallas kernel runs
with the wisdom-selected configuration; on other hosts (or for feature
combinations the Pallas kernel does not support) the ``ref.py`` oracle runs
instead. Model code only ever calls these functions.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import WisdomKernel, resolve_backend
from repro.core.device import current_device

from . import advec_u as _advec_mod
from . import diff_uvw as _diff_mod
from . import flash_attention as _fa_mod
from . import matmul as _mm_mod
from . import ref

advec_u_kernel = WisdomKernel(_advec_mod.builder)
diff_uvw_kernel = WisdomKernel(_diff_mod.builder)
matmul_kernel = WisdomKernel(_mm_mod.builder)
fa_causal_kernel = WisdomKernel(_fa_mod.causal_builder)
fa_full_kernel = WisdomKernel(_fa_mod.full_builder)

_ALL_KERNELS = (advec_u_kernel, diff_uvw_kernel, matmul_kernel,
                fa_causal_kernel, fa_full_kernel)


def reload_wisdom() -> None:
    """Invalidate cached wisdom on all ops (after re-tuning)."""
    for k in _ALL_KERNELS:
        k.invalidate()


def pack_scalars(dxi: float, dyi: float, dzi: float):
    return jnp.asarray([[dxi, dyi, dzi, 0.0]], dtype=jnp.float32)


def advec_u(u, v, w, dxi: float, dyi: float, dzi: float):
    """Advection tendency of u (paper kernel 1)."""
    return advec_u_kernel(u, v, w, pack_scalars(dxi, dyi, dzi))


def diff_uvw(u, v, w, evisc, dxi: float, dyi: float, dzi: float):
    """Diffusion tendencies (ut, vt, wt) (paper kernel 2)."""
    return diff_uvw_kernel(u, v, w, evisc, pack_scalars(dxi, dyi, dzi))


def matmul(a, b):
    return matmul_kernel(a, b)


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              softcap: float | None = None, scale: float | None = None,
              kv_offset: int = 0):
    """Multi-head attention, q: (B, Hq, Sq, D), k/v: (B, Hkv, Sk, D).

    Routes to the Pallas flash kernel when the feature set and shapes allow;
    otherwise the full-featured jnp oracle (always the case on CPU hosts).
    """
    B, Hq, Sq, D = q.shape
    Sk = k.shape[2]
    default_scale = scale is None or abs(scale - D ** -0.5) < 1e-12
    flashable = (
        resolve_backend() in ("pallas", "interpret")
        # flash has a TPU (Mosaic) lowering only — on GPU devices the
        # full-featured jnp oracle serves instead (docs/gpu-backend.md)
        and current_device().backend != "gpu"
        and window is None and softcap is None and default_scale
        and kv_offset == 0 and Sq == Sk
        and Sq % 128 == 0 and D % 128 == 0
    )
    if not flashable:
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 softcap=softcap, scale=scale,
                                 kv_offset=kv_offset)
    Hkv = k.shape[1]
    qf = q.reshape(B * Hq, Sq, D)
    kf = k.reshape(B * Hkv, Sk, D)
    vf = v.reshape(B * Hkv, Sk, D)
    kernel = fa_causal_kernel if causal else fa_full_kernel
    of = kernel(qf, kf, vf)
    return of.reshape(B, Hq, Sq, D)
