"""Pallas TPU kernels for the compute hot-spots the paper tunes
(advec_u, diff_uvw) and the LM-stack hot-spots this framework tunes the same
way (flash attention, matmul). Each kernel is a KernelBuilder registered with
the Kernel Launcher core; ``ops`` holds the public entry points, ``ref`` the
pure-jnp oracles.
"""

from . import ops, ref  # noqa: F401

__all__ = ["ops", "ref"]
