"""Shared machinery for 3-D periodic stencil kernels (advec_u, diff_uvw).

TPU adaptation of the paper's MicroHH kernels: the X axis is the contiguous
lane dimension and is kept whole inside each block; the grid tiles (Z, Y).
Halos are passed as *separate side-slab refs* (fixed thickness
``HALO_BLK = 4`` ≥ the stencil radius 3) with wrapped (periodic) index maps —
TPU has no overlapping BlockSpec reads, so each field arrives as five refs:

    center (bz, by, X), z-lo (4, by, X), z-hi, y-lo (bz, 4, X), y-hi

The stencil math only ever shifts along one axis at a time, so no corner
slabs are needed. Inside the kernel, per-axis extended views are assembled by
concatenation and shifts become static slices; X shifts are periodic
``jnp.roll`` over the full lane extent.
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp
from jax.experimental import pallas as pl

HALO_BLK = 4          # side-slab thickness (covers stencil radius <= 4)
STENCIL_RADIUS = 3    # 5th-order interpolation reach


def divides(a: int, b: int) -> bool:
    return b % a == 0


def stencil_grid(problem: tuple[int, int, int], bz: int, by: int,
                 traversal: str) -> tuple[tuple[int, int], Callable]:
    """Returns (grid, to_zy) where to_zy maps grid program ids -> (iz, iy)."""
    nz, ny, _ = problem
    gz, gy = nz // bz, ny // by
    if traversal == "zy":        # z major, y minor (y-adjacent = HBM-adjacent)
        return (gz, gy), lambda a, b: (a, b)
    elif traversal == "yz":      # y major, z minor
        return (gy, gz), lambda a, b: (b, a)
    raise ValueError(f"bad traversal {traversal!r}")


def field_specs(problem: tuple[int, int, int], bz: int, by: int,
                to_zy: Callable) -> list[pl.BlockSpec]:
    """The five BlockSpecs (center, z-lo, z-hi, y-lo, y-hi) for one field."""
    nz, ny, nx = problem
    hz, hy = nz // HALO_BLK, ny // HALO_BLK
    rz, ry = bz // HALO_BLK, by // HALO_BLK

    def center(a, b):
        iz, iy = to_zy(a, b)
        return (iz, iy, 0)

    def z_lo(a, b):
        iz, iy = to_zy(a, b)
        return ((iz * rz - 1) % hz, iy, 0)

    def z_hi(a, b):
        iz, iy = to_zy(a, b)
        return ((iz * rz + rz) % hz, iy, 0)

    def y_lo(a, b):
        iz, iy = to_zy(a, b)
        return (iz, (iy * ry - 1) % hy, 0)

    def y_hi(a, b):
        iz, iy = to_zy(a, b)
        return (iz, (iy * ry + ry) % hy, 0)

    return [
        pl.BlockSpec((bz, by, nx), center),
        pl.BlockSpec((HALO_BLK, by, nx), z_lo),
        pl.BlockSpec((HALO_BLK, by, nx), z_hi),
        pl.BlockSpec((bz, HALO_BLK, nx), y_lo),
        pl.BlockSpec((bz, HALO_BLK, nx), y_hi),
    ]


def out_spec(problem: tuple[int, int, int], bz: int, by: int,
             to_zy: Callable) -> pl.BlockSpec:
    nx = problem[2]

    def center(a, b):
        iz, iy = to_zy(a, b)
        return (iz, iy, 0)

    return pl.BlockSpec((bz, by, nx), center)


class FieldView:
    """Kernel-side view of one field: center + per-axis extended arrays.
    Takes plain (already loaded, already cast) block arrays."""

    def __init__(self, center, zlo, zhi, ylo, yhi):
        self.c = center
        self.ext_z = jnp.concatenate([zlo, self.c, zhi], axis=0)
        self.ext_y = jnp.concatenate([ylo, self.c, yhi], axis=1)
        self.bz = self.c.shape[0]
        self.by = self.c.shape[1]

    @classmethod
    def from_refs(cls, center_ref, zlo_ref, zhi_ref, ylo_ref, yhi_ref,
                  dtype=jnp.float32):
        return cls(*(r[...].astype(dtype)
                     for r in (center_ref, zlo_ref, zhi_ref,
                               ylo_ref, yhi_ref)))

    def sx(self, s: int, rows: slice | None = None):
        """Shift along x by s cells (periodic over the full lane extent)."""
        a = self.c if rows is None else self.c[rows]
        return a if s == 0 else jnp.roll(a, -s, axis=2)

    def sy(self, s: int, rows: slice | None = None):
        a = self.ext_y if rows is None else self.ext_y[rows]
        return a[:, HALO_BLK + s: HALO_BLK + s + self.by, :]

    def sz(self, s: int, rows: slice | None = None):
        lo = HALO_BLK + s + (0 if rows is None else rows.start)
        n = self.bz if rows is None else rows.stop - rows.start
        return self.ext_z[lo: lo + n]


def check_blocks(problem: tuple[int, int, int], bz: int, by: int) -> bool:
    """Static feasibility of a (bz, by) tiling for a (nz, ny, nx) problem."""
    nz, ny, _ = problem
    return (divides(HALO_BLK, bz) and divides(HALO_BLK, by)
            and bz <= nz and by <= ny
            and divides(bz, nz) and divides(by, ny)
            and divides(HALO_BLK, nz) and divides(HALO_BLK, ny))


def stencil_vmem_bytes(problem, bz: int, by: int, n_in_fields: int,
                       n_out_fields: int, dtype_bytes: int,
                       buffers: int = 2) -> int:
    """Per-program VMEM working set for the 5-ref stencil layout."""
    nx = problem[2]
    per_field = (bz * by + 2 * HALO_BLK * by + 2 * bz * HALO_BLK) * nx
    out = bz * by * nx
    return (n_in_fields * per_field + n_out_fields * out) \
        * dtype_bytes * buffers


def stencil_hbm_bytes(problem, bz: int, by: int, n_in_fields: int,
                      n_out_fields: int, dtype_bytes: int) -> float:
    nz, ny, nx = problem
    pts = nz * ny * nx
    halo_overhead = 2 * HALO_BLK / bz + 2 * HALO_BLK / by
    return (n_in_fields * pts * (1.0 + halo_overhead)
            + n_out_fields * pts) * dtype_bytes
