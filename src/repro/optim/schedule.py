"""Learning-rate schedules."""

from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    return lambda count: jnp.asarray(lr, jnp.float32)


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    def fn(count):
        c = count.astype(jnp.float32)
        warm = peak * c / max(warmup, 1)
        prog = jnp.clip((c - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor_frac + (1 - floor_frac)
                      * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(c < warmup, warm, cos)
    return fn
