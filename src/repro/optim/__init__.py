from .adamw import AdamW, clip_by_global_norm
from .schedule import cosine_schedule, constant_schedule
from .compression import compress_int8, decompress_int8, CompressionState

__all__ = ["AdamW", "clip_by_global_norm", "cosine_schedule",
           "constant_schedule", "compress_int8", "decompress_int8",
           "CompressionState"]
