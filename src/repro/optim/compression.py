"""Int8 gradient compression with error feedback — the distributed-
optimization trick for the slow cross-pod axis (DESIGN.md §6).

Per-tensor symmetric int8 quantization; the quantization error is carried in
a residual ("error feedback") so the compression is unbiased over time. The
train step applies compress -> (cross-pod reduce) -> decompress around the
pod-axis gradient reduction; within-pod reductions stay full-precision (fast
ICI). Works standalone too (tested without a mesh).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class CompressionState:
    """Error-feedback residuals, one per gradient leaf."""
    residual: dict

    @staticmethod
    def init(grads) -> "CompressionState":
        return CompressionState(jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def compress_int8(g: jax.Array, residual: jax.Array | None = None):
    """-> (q int8, scale f32 scalar, new_residual)."""
    g32 = g.astype(jnp.float32)
    if residual is not None:
        g32 = g32 + residual
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    err = g32 - q.astype(jnp.float32) * scale
    return q, scale, err


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, state: CompressionState | None):
    res = state.residual if state is not None else jax.tree.map(
        lambda _: None, grads, is_leaf=lambda x: x is None)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(state.residual) if state is not None \
        else [None] * len(flat_g)
    qs, scales, errs = [], [], []
    for g, r in zip(flat_g, flat_r):
        q, s, e = compress_int8(g, r)
        qs.append(q)
        scales.append(s)
        errs.append(e)
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, scales),
            CompressionState(jax.tree.unflatten(treedef, errs)))


def decompress_tree(qs, scales):
    return jax.tree.map(decompress_int8, qs, scales)
