"""AdamW with f32 moments (sharded like the parameters) and global-norm
clipping. Pure-pytree API (no optax dependency, per the build-everything
rule)."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gnorm


@dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0

    def init(self, params) -> dict:
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return {
            "m": jax.tree.map(f32, params),
            "v": jax.tree.map(f32, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def _lr(self, count):
        return self.lr(count) if callable(self.lr) else self.lr

    def update(self, grads, state: dict, params) -> tuple:
        """Returns (new_params, new_state, metrics)."""
        gnorm = jnp.zeros((), jnp.float32)
        if self.clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        count = state["count"] + 1
        lr = self._lr(count)
        c1 = 1.0 - self.b1 ** count.astype(jnp.float32)
        c2 = 1.0 - self.b2 ** count.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = self.b1 * m + (1 - self.b1) * g32
            v = self.b2 * v + (1 - self.b2) * g32 * g32
            step = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            return m, v, (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_m = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_p = jax.tree.unflatten(treedef, [o[2] for o in out])
        new_state = {"m": new_m, "v": new_v, "count": count}
        return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
