"""``python -m repro.transfer`` — predict, score, export.

Subcommands:

  predict   transfer recorded source spaces to an untuned target device;
            prints the ranked results and (with ``--wisdom-dir``) merges
            the eligible ``transfer``-provenance records into a local
            wisdom store (measured records always survive the merge)
  score     held-out evaluation: transfer a source dataset and look the
            chosen config up in a *truth* recording of the same scenario
            on the target device (fraction-of-optimum, vs cold fallback)
  export    write the transferred records for one kernel as a wisdom
            JSON document (publishable to any sync transport)

The loop end to end::

    python -m repro.tunebench record --kernel matmul \
        --problem 256,256,256 --device tpu-v4 --out datasets/
    python -m repro.transfer predict --dataset-dir datasets/ \
        --target tpu-v5e --wisdom-dir wisdom/
    python -m repro.transfer score \
        --source datasets/matmul--tpu-v4--256x256x256--float32.space.json \
        --truth  datasets/matmul--tpu-v5e--256x256x256--float32.space.json
"""

from __future__ import annotations

import argparse
import glob
import json
import sys
from pathlib import Path

from repro.core.wisdom import TRANSFER_MIN_CONFIDENCE, Wisdom
from repro.distrib.merge import merge_wisdom
from repro.distrib.store import WisdomStore
from repro.sandbox.gate import OracleGate
from repro.tunebench.dataset import DATASET_SUFFIX, DatasetStore, SpaceDataset

from .predictor import TransferResult, transfer_scenario
from .score import dump_holdout_report, holdout_report


def _load_sources(args) -> list[SpaceDataset]:
    if args.dataset_dir:
        store = DatasetStore(args.dataset_dir)
        paths = [p for _k, dev, _pr, _dt, p in
                 store.scenarios(kernel=args.kernel)
                 if dev != args.target]
    else:
        paths = []
        for pat in args.datasets:
            paths.extend(sorted(glob.glob(pat)))
        paths = list(dict.fromkeys(paths))
    out = []
    for p in paths:
        ds = SpaceDataset.load(p)
        if ds.device_kind == args.target:
            continue
        if args.kernel and ds.kernel != args.kernel:
            continue
        out.append(ds)
    return out


def _result_line(r: TransferResult, threshold: float) -> str:
    top = r.best()
    gate = "ok  " if r.confidence >= threshold and top is not None else "SKIP"
    predicted = f"{top.predicted_us:.2f}us" if top is not None else "-"
    problem = "x".join(str(d) for d in r.problem_size)
    return (f"  {gate} {r.kernel} {problem} {r.dtype} "
            f"{r.source_device} -> {r.target_device}: "
            f"predicted {predicted}, confidence {r.confidence:.3f} "
            f"(sim {r.components['similarity']:.3f}, "
            f"fit {r.components['fit_quality']:.3f}, "
            f"{r.components['calibration']})")


def _cmd_predict(args) -> int:
    sources = _load_sources(args)
    if not sources:
        print("no source datasets (or all are already recorded on "
              f"{args.target!r})", file=sys.stderr)
        return 1
    threshold = (TRANSFER_MIN_CONFIDENCE if args.min_confidence is None
                 else args.min_confidence)
    results = []
    for ds in sources:
        try:
            results.append(transfer_scenario(ds, args.target))
        except ValueError as e:
            print(f"  skip {ds.name()}: {e}", file=sys.stderr)
    if args.json:
        print(json.dumps([r.to_json() for r in results],
                         indent=2, sort_keys=True))
    else:
        print(f"transfer -> {args.target} "
              f"(confidence threshold {threshold:.2f}):")
        for r in results:
            print(_result_line(r, threshold))
    eligible = [r for r in results if r.eligible(args.min_confidence)]
    if args.wisdom_dir:
        gate = None if args.no_verify else OracleGate()
        store = WisdomStore(args.wisdom_dir)
        by_kernel: dict[str, list] = {}
        for r in eligible:
            try:
                by_kernel.setdefault(r.kernel, []).append(
                    r.record(gate=gate))
            except ValueError as e:
                print(f"  reject {r.kernel} "
                      f"{'x'.join(str(d) for d in r.problem_size)} "
                      f"{r.dtype}: {e}", file=sys.stderr)
        for kernel, records in sorted(by_kernel.items()):
            merged = merge_wisdom(store.load(kernel),
                                  Wisdom(kernel, records))
            store.save(merged)
            print(f"merged {len(records)} transferred record(s) into "
                  f"{store.path_for(kernel)}")
    if not eligible:
        print("nothing eligible to serve (confidence below threshold)",
              file=sys.stderr)
        return 2
    return 0


def _cmd_score(args) -> int:
    source = SpaceDataset.load(args.source)
    truth = SpaceDataset.load(args.truth)
    report = holdout_report(source, truth)
    if args.json:
        sys.stdout.write(dump_holdout_report(report))
        return 0
    t, f = report["transfer"], report["fallback"]
    print(f"{report['kernel']} {report['scenario']}: "
          f"{report['source_device']} -> {report['target_device']}")
    print(f"  optimum        {report['optimum_us']}us")
    print(f"  transfer       fraction {t['fraction']} (tier {t['tier']}, "
          f"confidence {report['confidence']:.3f})")
    print(f"  cold fallback  fraction {f['fraction']} (tier {f['tier']})")
    print(f"  default        fraction {report['default']['fraction']}")
    if args.check and (t["fraction"] is None
                       or t["fraction"] < args.threshold):
        print(f"FAIL: transfer fraction below {args.threshold}",
              file=sys.stderr)
        return 1
    return 0


def _cmd_export(args) -> int:
    sources = _load_sources(args)
    kernels = sorted({ds.kernel for ds in sources})
    if len(kernels) != 1:
        print(f"export needs exactly one kernel (have {kernels}); "
              f"use --kernel", file=sys.stderr)
        return 1
    gate = None if args.no_verify else OracleGate()
    records = []
    for ds in sources:
        try:
            result = transfer_scenario(ds, args.target)
        except ValueError:
            continue
        if result.eligible(args.min_confidence):
            try:
                records.append(result.record(gate=gate))
            except ValueError as e:
                print(f"  reject {ds.name()}: {e}", file=sys.stderr)
    doc = Wisdom(kernels[0], records).to_doc()
    text = json.dumps(doc, indent=2, sort_keys=True) + "\n"
    if args.out and args.out != "-":
        Path(args.out).write_text(text)
        print(f"{len(records)} transferred record(s) -> {args.out}")
    else:
        sys.stdout.write(text)
    return 0 if records else 2


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.transfer",
        description="Cross-device wisdom transfer: serve good configs on "
                    "devices never tuned.")
    sub = ap.add_subparsers(dest="command", required=True)

    def _sources(p):
        p.add_argument("--dataset-dir", default=None,
                       help="DatasetStore directory of recorded spaces")
        p.add_argument("--datasets", nargs="+",
                       default=[f"datasets/*{DATASET_SUFFIX}"],
                       help="dataset globs (ignored with --dataset-dir)")
        p.add_argument("--kernel", default=None,
                       help="restrict to one kernel")
        p.add_argument("--target", required=True,
                       help="target device kind, e.g. tpu-v4")
        p.add_argument("--min-confidence", type=float, default=None,
                       help="override the serving confidence gate")
        p.add_argument("--no-verify", action="store_true",
                       help="skip the correctness-oracle check on "
                            "records (verified provenance is then "
                            "omitted)")

    p = sub.add_parser("predict",
                       help="transfer recorded spaces to a target device")
    _sources(p)
    p.add_argument("--wisdom-dir", default=None,
                   help="merge eligible transferred records into this "
                        "wisdom store")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_predict)

    p = sub.add_parser("score",
                       help="held-out evaluation against a truth recording")
    p.add_argument("--source", required=True,
                   help="source device dataset (*.space.json)")
    p.add_argument("--truth", required=True,
                   help="target device recording of the same scenario")
    p.add_argument("--threshold", type=float, default=0.8)
    p.add_argument("--check", action="store_true",
                   help="exit non-zero when transfer fraction is below "
                        "--threshold")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_score)

    p = sub.add_parser("export",
                       help="transferred records as a wisdom JSON document")
    _sources(p)
    p.add_argument("--out", default="-",
                   help="output path ('-' for stdout)")
    p.set_defaults(fn=_cmd_export)
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
