"""Cross-device wisdom transfer: serve good configs on devices never tuned.

Beyond-paper subsystem. The paper's headline result is portability —
wisdom captured "for different GPUs, input domains, and precisions" —
yet selection on a device family with no recorded tuning runs degrades
to coarse scenario-distance fallback. This package closes that gap by
*predicting* instead of re-tuning, following the cross-vendor transfer
results of Lurati et al. ("Bringing Auto-tuning to HIP") and the
surrogate-ranking results of Schoonhoven et al. ("Benchmarking
optimization algorithms for auto-tuning GPU kernels"):

* :mod:`.model`     — :class:`DeviceModel`: capability-vector ratios and
  similarity between a tuned source device and an untuned target;
* :mod:`.predictor` — re-rank a source device's recorded tuning space
  through the ridge surrogate, calibrated per config by the capability
  model, into ``transfer``-provenance wisdom records with a confidence
  score; ``Wisdom.select`` serves them from a dedicated tier (below
  exact measurements, above scenario-distance fallback) only above
  :data:`~repro.core.wisdom.TRANSFER_MIN_CONFIDENCE`;
* :mod:`.score`     — held-out-device evaluation (fraction-of-optimum
  vs the cold fallback baseline), the protocol
  ``benchmarks/transfer_portability.py`` and CI's ``transfer-smoke`` run;
* :mod:`.cli`       — ``python -m repro.transfer``
  (predict / score / export).

The prediction is not the end of the loop: serving hosts report observed
latency on the fleet control bus, and the fleet coordinator enqueues
*verification* tuning jobs for transferred records whose predictions do
not hold (``Coordinator.check_transfers``) — the assembled measured
record then beats the transferred one in every merge
(predict -> verify -> promote). Docs: ``docs/transfer-tuning.md``.
"""

from .model import DeviceModel
from .predictor import (TransferPrediction, TransferResult,
                        transfer_scenario, transfer_store)
from .score import (dump_holdout_report, fraction_of_optimum,
                    holdout_report)

__all__ = [
    "DeviceModel",
    "TransferPrediction", "TransferResult", "transfer_scenario",
    "transfer_store",
    "dump_holdout_report", "fraction_of_optimum", "holdout_report",
]
