"""Entry point: ``python -m repro.transfer``."""

from .cli import main

if __name__ == "__main__":
    raise SystemExit(main())
