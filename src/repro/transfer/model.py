"""Device capability model: how performance moves between device families.

Cross-vendor auto-tuning studies (Lurati et al., "Bringing Auto-tuning to
HIP"; the paper's own A4000/A100 portability tables) show tuned configs
transfer with a quality loss that tracks how *similar* the two devices
are along a handful of capability axes: compute throughput, memory
bandwidth, on-chip memory capacity, launch overhead. :class:`DeviceModel`
reduces a (source, target) device pair to exactly those ratios
(:func:`repro.core.device.capability_vector`), which the transfer
predictor uses two ways:

* **calibration** — scale a source-grounded score prediction to the
  target's balance point (compute-bound work moves with the FLOP/s
  ratio, streaming work with the bandwidth ratio);
* **similarity** — a scalar in (0, 1] that decays with the norm of the
  log capability ratios, feeding the confidence gate: predicting
  tpu-v5e -> tpu-v4 is credible, predicting tpu -> cpu is not.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.device import (CAPABILITY_AXES, DeviceSpec,
                               capability_vector, get_device)

__all__ = ["BACKEND_MISMATCH_PENALTY", "ESTIMATED_SIMILARITY_CAP",
           "DeviceModel"]

#: Similarity multiplier when source and target use different lowering
#: backends (tpu vs gpu vs cpu). Capability ratios cannot see an
#: instruction-set change — a GPU with TPU-like peaks still runs a
#: Triton lowering with a different tiling granule, scheduling model,
#: and memory hierarchy — so cross-backend predictions carry a flat
#: penalty on top of the ratio-derived similarity. The paper's pair
#: (A4000 -> A100) transfers *within* a backend; across backends the
#: confidence must reflect that the evidence is one abstraction weaker.
BACKEND_MISMATCH_PENALTY = 0.5

#: Similarity ceiling when either spec is ``estimated`` (unknown
#: hardware whose peaks were cloned from a backend baseline). The cap
#: is chosen so the best possible confidence — sqrt(cap) x 1.0 ≈ 0.22 —
#: stays below ``TRANSFER_MIN_CONFIDENCE`` (0.30): a prediction scaled
#: through guessed capability ratios must never be *served*, only
#: surfaced for verification.
ESTIMATED_SIMILARITY_CAP = 0.05


@dataclass(frozen=True)
class DeviceModel:
    """Capability ratios between a tuned *source* device and an untuned
    *target* device.

    All quantities derive from the two specs' capability vectors; the
    model is symmetric up to inversion and completely deterministic.

    Example::

        m = DeviceModel.between("tpu-v5e", "tpu-v4")
        m.similarity()          # ~0.5: close TPU siblings
        m.compute_ratio("bfloat16"), m.bandwidth_ratio()
    """

    source: DeviceSpec
    target: DeviceSpec

    @staticmethod
    def between(source_kind: str, target_kind: str) -> "DeviceModel":
        """Build a model from two device kind strings (table lookup or
        prefix-derived spec for unknown real hardware)."""
        return DeviceModel(get_device(source_kind), get_device(target_kind))

    # -- ratios (target / source: >1 means the target is stronger) ------------

    def ratios(self) -> dict[str, float]:
        """Per-axis target/source capability ratios, keyed by
        ``CAPABILITY_AXES``."""
        src = capability_vector(self.source)
        tgt = capability_vector(self.target)
        return {axis: t / s for axis, s, t in
                zip(CAPABILITY_AXES, src, tgt)}

    def compute_ratio(self, dtype: str) -> float:
        """FLOP/s ratio at ``dtype`` precision (compute-bound scaling)."""
        if dtype in ("bfloat16", "float16"):
            return self.target.flops_bf16 / self.source.flops_bf16
        return self.target.flops_f32 / self.source.flops_f32

    def bandwidth_ratio(self) -> float:
        """HBM bandwidth ratio (memory-bound scaling)."""
        return self.target.hbm_bw / self.source.hbm_bw

    def vmem_ratio(self) -> float:
        """On-chip memory ratio — the *feasibility* axis: configs sized
        for a larger VMEM overflow a smaller one."""
        return self.target.vmem_bytes / self.source.vmem_bytes

    def blend_ratio(self, dtype: str) -> float:
        """Capability-only time-scaling guess when no workload model is
        available: the geometric mean of the compute and bandwidth
        scalings (a kernel is somewhere between compute- and
        memory-bound; without its workload we cannot know where)."""
        return 1.0 / math.sqrt(self.compute_ratio(dtype)
                               * self.bandwidth_ratio())

    # -- similarity ------------------------------------------------------------

    def backend_penalty(self) -> float:
        """1.0 when source and target share a lowering backend,
        :data:`BACKEND_MISMATCH_PENALTY` otherwise. Exposed separately
        so the predictor can record it in a result's components — the
        regression surface for "no cross-backend record is ever served
        without the penalty applied"."""
        if self.source.backend == self.target.backend:
            return 1.0
        return BACKEND_MISMATCH_PENALTY

    def estimated(self) -> bool:
        """True when either endpoint's peaks are guesses (see
        ``DeviceSpec.estimated``)."""
        return bool(self.source.estimated or self.target.estimated)

    def similarity(self) -> float:
        """Capability similarity in (0, 1]: ``exp(-rms(log2 ratios))``,
        times :meth:`backend_penalty` for cross-backend pairs, capped at
        :data:`ESTIMATED_SIMILARITY_CAP` when either spec is estimated.

        1.0 for identical specs; ~0.5 for the shipped tpu-v5e/tpu-v4
        pair (sibling accelerators, 1.4-2x apart per axis); ~0.2 for
        tpu-v5e -> gpu-a100 (comparable peaks, different backend);
        effectively 0 for tpu -> cpu (orders of magnitude apart
        everywhere, and a different backend on top). The RMS over axes
        keeps the scale independent of how many capability axes exist.
        The estimated cap floors the resulting confidence below the
        serving gate — ratios against guessed peaks are not evidence.
        """
        logs = [math.log2(r) for r in self.ratios().values()]
        rms = math.sqrt(sum(x * x for x in logs) / len(logs))
        sim = math.exp(-rms) * self.backend_penalty()
        if self.estimated():
            sim = min(sim, ESTIMATED_SIMILARITY_CAP)
        return sim
