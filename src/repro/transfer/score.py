"""Transfer quality scoring against held-out ground truth.

Methodology (the held-out-device protocol of the cross-device tuning
literature): record a scenario's space on the *target* device, hide it
from the transfer engine, transfer from a *source* device's recorded
space, then look the chosen configs up in the hidden recording:

    fraction_of_optimum = target_optimum_us / score(chosen config)

1.0 means transfer found the target's true optimum; a config that is
infeasible (or unrecorded) on the target scores 0. The report compares
the transfer tier against the *cold fallback* — what ``Wisdom.select``
would serve with no transferred record, i.e. the scenario-distance
fallback onto source-device wisdom — which is exactly the baseline a
device family without tuning runs degrades to today.
"""

from __future__ import annotations

import json

from repro.core.device import get_device
from repro.core.param import Config
from repro.core.wisdom import Wisdom, WisdomRecord, make_provenance
from repro.tunebench.dataset import SpaceDataset

from .predictor import transfer_scenario

__all__ = ["fraction_of_optimum", "holdout_report", "dump_holdout_report"]

#: Report schema version (bump on structural changes).
HOLDOUT_REPORT_VERSION = 1


def fraction_of_optimum(dataset: SpaceDataset, config: Config
                        ) -> float | None:
    """How close ``config`` comes to ``dataset``'s recorded optimum.

    Returns ``optimum_us / score_us`` in (0, 1] for a feasible recorded
    config, 0.0 for one the dataset knows to be infeasible (or never
    recorded — on an exhaustively recorded space that means restricted),
    and None when the dataset has no feasible entry at all.
    """
    best = dataset.best()
    if best is None:
        return None
    entry = dataset.lookup(config)
    if entry is None or not entry.feasible:
        return 0.0
    return best.score_us / entry.score_us


def _measured_record(dataset: SpaceDataset) -> WisdomRecord:
    """The wisdom record a tuning session on ``dataset``'s device would
    have written (its recorded optimum), with deterministic provenance."""
    best = dataset.best()
    if best is None:
        raise ValueError(f"dataset {dataset.name()} has no feasible entry")
    prov = make_provenance(strategy="exhaustive",
                           evals=len(dataset.evaluations),
                           objective=dataset.objective)
    # Determinism: strip the host/time fields make_provenance collected.
    prov = {k: prov[k] for k in ("strategy", "evaluations", "objective")}
    prov["source"] = "recorded"
    dev = get_device(dataset.device_kind)
    return WisdomRecord(
        device_kind=dev.kind, device_family=dev.family,
        problem_size=tuple(dataset.problem_size), dtype=dataset.dtype,
        config=dict(best.config), score_us=round(best.score_us, 6),
        provenance=prov)


def holdout_report(source: SpaceDataset, truth: SpaceDataset,
                   builder=None) -> dict:
    """Score one held-out-device transfer: source space -> target truth.

    ``truth`` is the target device's recording of the *same* kernel,
    problem size and dtype (recorded for evaluation, hidden from the
    predictor). The report carries the fraction-of-optimum reached by
    the transferred config, by the cold scenario-distance fallback, and
    by the default config, plus the selection tiers that produced them —
    all deterministic, no timestamps.

    Example::

        report = holdout_report(v5e_dataset, v4_dataset)
        assert report["transfer"]["fraction"] >= 0.8
    """
    if (source.kernel, tuple(source.problem_size), source.dtype) != \
            (truth.kernel, tuple(truth.problem_size), truth.dtype):
        raise ValueError(
            f"source {source.name()} and truth {truth.name()} are not the "
            f"same (kernel, problem, dtype) scenario")
    result = transfer_scenario(source, truth.device_kind, builder=builder)
    wisdom = Wisdom(source.kernel, [_measured_record(source)])
    if result.eligible():
        wisdom.add(result.record())
    default = truth.space().default_config()

    def scored(min_conf: float | None) -> dict:
        cfg, tier = wisdom.select(
            truth.device_kind, truth.problem_size, truth.dtype, default,
            min_transfer_confidence=min_conf)
        frac = fraction_of_optimum(truth, cfg)
        entry = truth.lookup(cfg)
        return {
            "tier": tier,
            "config": dict(cfg),
            "fraction": round(frac, 6) if frac is not None else None,
            "score_us": (round(entry.score_us, 6)
                         if entry is not None and entry.feasible else None),
        }

    optimum = truth.best()
    return {
        "version": HOLDOUT_REPORT_VERSION,
        "kernel": source.kernel,
        "scenario": truth.scenario_key(),
        "source_device": source.device_kind,
        "target_device": truth.device_kind,
        "confidence": result.confidence,
        "components": dict(result.components),
        "optimum_us": (round(optimum.score_us, 6)
                       if optimum is not None else None),
        "transfer": scored(None),
        # min_transfer_confidence=2.0 disables the transfer tier (no
        # confidence reaches 2): exactly the cold pre-transfer behavior.
        "fallback": scored(2.0),
        "default": {
            "config": dict(default),
            "fraction": (round(fraction_of_optimum(truth, default), 6)
                         if optimum is not None else None),
        },
    }


def dump_holdout_report(report: dict) -> str:
    """Canonical byte form of a holdout report (sorted keys, two-space
    indent, trailing newline) — byte-identical for equal reports, which
    is what the CI ``transfer-smoke`` job asserts."""
    return json.dumps(report, indent=2, sort_keys=True) + "\n"
