"""Transfer predictor: recorded source spaces -> ranked target configs.

The pipeline per (kernel, scenario):

  1. fit the :func:`repro.tuner.costmodel.fit_from_dataset` ridge
     surrogate on the *source* device's recorded space — a smoothed,
     data-grounded view of the landscape (raw scores carry measurement
     ruggedness that does not transfer; the fitted trend does);
  2. calibrate each feasible config's surrogate score to the target
     device through the :class:`~repro.transfer.model.DeviceModel`
     capability ratios — with the kernel's workload hook available, the
     per-config compute/memory balance picks the exact blend (and VMEM
     overflow on the target marks the config infeasible there); without
     it, the capability-only geometric blend stands in;
  3. rank, keep the winner, and score *confidence* — device similarity x
     (surrogate fit quality, space coverage) — which decides whether the
     resulting ``transfer``-provenance record is eligible to serve
     (``Wisdom.select`` gates on it) and how urgent verification is.

Everything is deterministic: same dataset + same target -> byte-identical
records on any host (transfer provenance carries no timestamps).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.core.device import get_device
from repro.core.param import Config
from repro.core.registry import get_kernel
from repro.core.wisdom import (TRANSFER_MIN_CONFIDENCE, WisdomRecord,
                               make_transfer_provenance)
from repro.tuner.costmodel import CostModel, fit_from_dataset
from repro.tunebench.dataset import SpaceDataset

__all__ = ["TransferPrediction", "TransferResult", "transfer_scenario",
           "transfer_store"]

#: Confidence mix: sqrt(similarity) x (base + fit-quality + coverage
#: terms). Multiplicative in similarity so a dissimilar device pair can
#: never be rescued by a good fit; the additive terms reward a surrogate
#: that learned the landscape and a space that was densely recorded.
CONFIDENCE_BASE = 0.30
CONFIDENCE_FIT_WEIGHT = 0.50
CONFIDENCE_COVERAGE_WEIGHT = 0.20

#: Confidence penalty when the kernel's workload hook is unavailable and
#: calibration had to fall back to the capability-only blend ratio.
#: Additionally scaled by the VMEM ratio when the target's on-chip
#: memory is *smaller* than the source's: without the workload hook
#: there is no per-config feasibility check, and a source config sized
#: for the bigger memory may not compile on the target at all — the
#: shrinking-memory direction must not clear the serving gate blind.
CAPABILITY_ONLY_FACTOR = 0.8

#: Cap on space enumeration when computing recorded coverage.
_COVERAGE_CAP = 4096


@dataclass
class TransferPrediction:
    """One source config's predicted standing on the target device.

    ``source_us`` is the *recorded* source score, ``smoothed_us`` the
    ridge surrogate's view of it (measurement ruggedness does not
    transfer; the fitted trend does). Ranking uses the smoothed score
    calibrated through the capability model (``rank_us``); the
    ``predicted_us`` the record carries — what observed serve latency is
    verified against — calibrates the recorded score instead, because
    the surrogate's absolute level extrapolates poorly at space corners
    while the recorded value is ground truth for that exact config.
    """

    config: Config
    source_us: float         # recorded on the source device
    smoothed_us: float       # ridge-surrogate fit of the source score
    rank_us: float           # smoothed_us x calibration ratio (sort key)
    predicted_us: float      # source_us x calibration ratio (verify target)

    def to_json(self) -> dict:
        return {"config": dict(self.config),
                "source_us": round(self.source_us, 6),
                "smoothed_us": round(self.smoothed_us, 6),
                "rank_us": round(self.rank_us, 6),
                "predicted_us": round(self.predicted_us, 6)}


@dataclass
class TransferResult:
    """Everything the transfer of one scenario produced.

    Carries the ranked predictions, the confidence score with its
    components, and enough identity to mint a ``transfer``-provenance
    :class:`~repro.core.wisdom.WisdomRecord` via :meth:`record`.

    Example::

        result = transfer_scenario(dataset, "tpu-v4")
        if result.eligible():
            wisdom.add(result.record())
    """

    kernel: str
    source_device: str
    target_device: str
    problem_size: tuple[int, ...]
    dtype: str
    predictions: list[TransferPrediction]
    confidence: float
    components: dict = field(default_factory=dict)

    def best(self) -> TransferPrediction | None:
        """The top-ranked prediction (None when nothing transferred —
        e.g. every source config overflows the target's VMEM)."""
        return self.predictions[0] if self.predictions else None

    def eligible(self, min_confidence: float | None = None) -> bool:
        """Whether the result clears the serving gate (defaults to
        :data:`~repro.core.wisdom.TRANSFER_MIN_CONFIDENCE`)."""
        threshold = (TRANSFER_MIN_CONFIDENCE if min_confidence is None
                     else float(min_confidence))
        return self.best() is not None and self.confidence >= threshold

    def record(self, gate=None) -> WisdomRecord:
        """The transferred wisdom record for the target device (raises
        ``ValueError`` when there is no prediction at all).

        With a :class:`~repro.sandbox.gate.OracleGate`, predictions are
        walked in rank order and the first one whose config passes the
        correctness oracle becomes the record — a top-ranked config that
        computes the wrong answer on this host falls through to the
        runner-up instead of being served. Raises ``ValueError`` when
        the gate vetoes every prediction.
        """
        top = self.best()
        if top is None:
            raise ValueError(
                f"no transferable config for {self.kernel} "
                f"{self.source_device} -> {self.target_device}")
        verdict = None
        if gate is not None:
            top = None
            for pred in self.predictions:
                verdict = gate.check(self.kernel, pred.config,
                                     self.problem_size, self.dtype)
                if gate.allows(verdict):
                    top = pred
                    break
            if top is None:
                raise ValueError(
                    f"every transferable config for {self.kernel} "
                    f"{self.source_device} -> {self.target_device} failed "
                    f"the correctness oracle")
        target = get_device(self.target_device)
        backends = self.components.get("backends", "")
        cross = backends and len(set(backends.split("->"))) > 1
        provenance = make_transfer_provenance(
            source_device=self.source_device,
            source_entries=int(self.components.get("entries", 0)),
            confidence=self.confidence,
            predicted_us=round(top.predicted_us, 6),
            predictor=self.components.get("calibration", "capability"),
            backends=backends if cross else "")
        if gate is not None:
            provenance = gate.stamp(provenance, self.kernel, verdict)
        return WisdomRecord(
            device_kind=target.kind, device_family=target.family,
            problem_size=tuple(self.problem_size), dtype=self.dtype,
            config=dict(top.config),
            score_us=round(top.predicted_us, 6),
            provenance=provenance)

    def to_json(self, top: int = 5) -> dict:
        return {
            "kernel": self.kernel,
            "source_device": self.source_device,
            "target_device": self.target_device,
            "problem_size": list(self.problem_size),
            "dtype": self.dtype,
            "confidence": self.confidence,
            "components": dict(self.components),
            "predictions": [p.to_json() for p in self.predictions[:top]],
        }


def _coverage(dataset: SpaceDataset) -> float:
    """Fraction of the (capped) valid space the recording covers."""
    total = dataset.space().valid_cardinality(cap=_COVERAGE_CAP)
    if total <= 0:
        return 0.0
    return min(1.0, len(dataset.evaluations) / total)


def transfer_scenario(dataset: SpaceDataset, target_kind: str,
                      builder=None) -> TransferResult:
    """Transfer one recorded scenario to an untuned target device.

    ``builder`` supplies the kernel's workload hook for per-config
    calibration; when omitted it is looked up in the registry, and when
    the kernel is unknown on this host the capability-only blend is used
    (with a confidence penalty). Raises ``ValueError`` for a
    source == target transfer (nothing to predict — the dataset already
    *is* the target's ground truth) and when the dataset has too few
    feasible entries to fit the surrogate.

    Example::

        ds = SpaceDataset.load("matmul--tpu-v5e--256x256x256--float32"
                               ".space.json")
        result = transfer_scenario(ds, "tpu-v4")
        result.record()     # transfer-provenance WisdomRecord
    """
    if dataset.device_kind == target_kind:
        raise ValueError(
            f"dataset {dataset.name()} is already recorded on "
            f"{target_kind}; transfer needs a different target device")
    source = get_device(dataset.device_kind)
    target = get_device(target_kind)
    from .model import DeviceModel
    model = DeviceModel(source, target)
    fitted = fit_from_dataset(dataset)
    if builder is None:
        try:
            builder = get_kernel(dataset.kernel)
        except KeyError:
            builder = None
    calibration = "workload" if builder is not None else "capability"
    source_cost = CostModel(source, noise_sigma=0.0)
    target_cost = CostModel(target, noise_sigma=0.0)

    predictions: list[tuple[str, TransferPrediction]] = []
    for entry in dataset.feasible():
        base = fitted.predict(entry.config)
        if builder is not None:
            w = builder.make_workload(entry.config, dataset.problem_size,
                                      dataset.dtype)
            ts = source_cost.time(w, dataset.dtype)
            tt = target_cost.time(w, dataset.dtype)
            if not (math.isfinite(ts) and math.isfinite(tt)) or ts <= 0:
                continue        # infeasible on the target (e.g. VMEM)
            ratio = tt / ts
        else:
            ratio = model.blend_ratio(dataset.dtype)
        predictions.append((dataset.key_for(entry.config),
                            TransferPrediction(
                                config=dict(entry.config),
                                source_us=entry.score_us,
                                smoothed_us=base,
                                rank_us=base * ratio,
                                predicted_us=entry.score_us * ratio)))
    # Rank by calibrated smoothed target time; the config-hash key makes
    # equal predictions resolve identically on every host.
    predictions.sort(key=lambda kp: (kp[1].rank_us, kp[0]))
    ranked = [p for _k, p in predictions]

    fit_quality = fitted.fit_quality()
    similarity = model.similarity()
    coverage = _coverage(dataset)
    confidence = (math.sqrt(similarity)
                  * (CONFIDENCE_BASE
                     + CONFIDENCE_FIT_WEIGHT * fit_quality
                     + CONFIDENCE_COVERAGE_WEIGHT * coverage))
    if calibration == "capability":
        confidence *= CAPABILITY_ONLY_FACTOR * min(1.0, model.vmem_ratio())
    confidence = round(min(1.0, max(0.0, confidence)), 6)
    return TransferResult(
        kernel=dataset.kernel,
        source_device=dataset.device_kind, target_device=target_kind,
        problem_size=tuple(dataset.problem_size), dtype=dataset.dtype,
        predictions=ranked, confidence=confidence,
        components={
            "similarity": round(similarity, 6),
            "fit_quality": round(fit_quality, 6),
            "coverage": round(coverage, 6),
            "calibration": calibration,
            "entries": len(dataset.evaluations),
            "transferable": len(ranked),
            # Cross-backend bookkeeping: similarity above already
            # *includes* the penalty (and the estimated-spec floor);
            # recording the factor separately makes "the penalty was
            # applied" auditable on every result and record.
            "backends": f"{source.backend}->{target.backend}",
            "backend_penalty": round(model.backend_penalty(), 6),
            "estimated": model.estimated(),
        })


def transfer_store(store, target_kind: str, kernel: str | None = None
                   ) -> list[TransferResult]:
    """Transfer every recorded scenario in a
    :class:`~repro.tunebench.DatasetStore` to ``target_kind``.

    Scenarios already recorded *on* the target device are skipped (they
    need no prediction), as are datasets too small to fit the surrogate.
    Results come back in deterministic filename order.

    Example::

        results = transfer_store(DatasetStore("datasets"), "tpu-v4")
        records = [r.record() for r in results if r.eligible()]
    """
    results: list[TransferResult] = []
    for kern, dev, _problem, _dtype, path in store.scenarios(kernel=kernel):
        if dev == target_kind:
            continue
        dataset = SpaceDataset.load(path)
        try:
            results.append(transfer_scenario(dataset, target_kind))
        except ValueError:
            continue            # too few feasible entries to fit
    return results
