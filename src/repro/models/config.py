"""Architecture configuration schema for the LM model zoo.

One frozen dataclass describes every assigned architecture; the generic
decoder in ``transformer.py`` (and the enc-dec stack in ``encdec.py``)
consume it. Non-uniform per-layer behavior (SWA vs global windows) is
expressed as *data* (per-layer window vector) so a single scanned layer body
covers heterogeneous stacks — required to keep HLO size O(1) in depth.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Literal

FULL_WINDOW = 0  # sentinel in per-layer window vectors: full attention


@dataclass(frozen=True)
class MoECfg:
    n_routed: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3
    chunk: int = 256             # seq chunk for capacity dispatch


@dataclass(frozen=True)
class MLACfg:
    q_lora: int
    kv_lora: int
    d_nope: int
    d_rope: int
    d_v: int


@dataclass(frozen=True)
class MambaCfg:
    d_state: int = 16
    expand: int = 2
    d_conv: int = 4


@dataclass(frozen=True)
class RWKVCfg:
    decay_lora: int = 64
    head_dim: int = 64


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense|moe|hybrid|ssm|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int

    # mixer selection
    mixer: Literal["attn", "mamba+attn", "rwkv"] = "attn"

    # attention details
    windows: tuple[int, ...] = ()        # per-layer; FULL_WINDOW = full attn
    attn_softcap: float | None = None
    final_softcap: float | None = None
    qkv_bias: bool = False
    rope_frac: float = 1.0
    rope_theta: float = 10000.0

    # norms / MLP
    norm: Literal["rms", "ln"] = "rms"
    act: Literal["silu", "gelu"] = "silu"
    gated_mlp: bool = True
    post_norm: bool = False              # gemma2 sandwich norms
    tie_embeddings: bool = False

    # positions
    pos: Literal["rope", "learned", "none"] = "rope"
    max_seq: int = 1 << 20

    # optional submodules
    moe: MoECfg | None = None
    dense_layers: tuple[int, ...] = ()   # FFN stays dense at these layers
    mla: MLACfg | None = None
    mamba: MambaCfg | None = None
    rwkv: RWKVCfg | None = None

    # vision cross-attention (mllama-style)
    cross_attn_period: int = 0           # every Nth layer is a cross block
    n_img_tokens: int = 0

    # encoder-decoder (whisper-style); decoder uses the main fields
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500                  # stubbed frame-embedding length

    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # capability flags
    supports_long_context: bool = False  # sub-quadratic decode at 500k

    def __post_init__(self):
        if self.windows and len(self.windows) != self.n_layers:
            raise ValueError(
                f"{self.name}: windows has {len(self.windows)} entries, "
                f"need n_layers={self.n_layers}")

    # ---- derived ----

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the LM head / logits
        shard cleanly over the model axis (production-standard padding;
        padded columns are masked out of the loss and decode argmax)."""
        return -(-self.vocab // 256) * 256

    @property
    def layer_windows(self) -> tuple[int, ...]:
        return self.windows if self.windows else (FULL_WINDOW,) * self.n_layers

    @property
    def d_q(self) -> int:
        return self.n_heads * self.d_head

    @property
    def d_kv(self) -> int:
        return self.n_kv_heads * self.d_head

    def n_params(self) -> int:
        """Total parameter count (embedding included)."""
        d, f, L = self.d_model, self.d_ff, self.n_layers
        v = self.padded_vocab
        total = v * d + (0 if self.tie_embeddings else d * v) + d
        if self.enc_dec:
            total += (self.max_seq + self.enc_seq) * d + d  # pos tables
        elif self.pos == "learned":
            total += self.max_seq * d

        def mlp(ff: int) -> int:
            return (3 if self.gated_mlp else 2) * d * ff

        for i in range(L):
            per = 2 * d + (2 * d if self.post_norm else 0)  # norms
            if self.mixer == "rwkv":
                c = self.rwkv or RWKVCfg()
                per += 5 * d * d                       # r, k, v, g, o
                per += d * c.decay_lora + c.decay_lora * d + 2 * d
                per += d * f + f * d + d * d           # channel mix
            else:
                if self.mla is not None:
                    m = self.mla
                    per += d * m.q_lora
                    per += m.q_lora * self.n_heads * (m.d_nope + m.d_rope)
                    per += d * (m.kv_lora + m.d_rope)
                    per += m.kv_lora * self.n_heads * (m.d_nope + m.d_v)
                    per += self.n_heads * m.d_v * d
                else:
                    per += d * self.d_q + 2 * d * self.d_kv + self.d_q * d
                if self.mixer == "mamba+attn":
                    mb = self.mamba or MambaCfg()
                    di = mb.expand * d
                    per += d * 2 * di + di * d          # in/out proj
                    per += di * (2 * mb.d_state + 1)    # B, C, dt proj
                    per += di * mb.d_conv + di * mb.d_state + di
                if self.moe is not None and i not in self.dense_layers:
                    e = self.moe
                    per += d * e.n_routed
                    per += (e.n_routed + e.n_shared) * mlp(e.d_expert)
                else:
                    per += mlp(f)
            total += per
        if self.cross_attn_period:
            n_cross = L // self.cross_attn_period
            total += n_cross * (d * self.d_q + 2 * d * self.d_kv
                                + self.d_q * d + 3 * d)
        if self.enc_dec:
            # decoder cross-attention blocks (one per decoder layer)
            total += L * (d * self.d_q + 2 * d * self.d_kv
                          + self.d_q * d + 2 * d)
            # encoder stack
            total += self.n_enc_layers * (
                d * self.d_q + 2 * d * self.d_kv + self.d_q * d
                + mlp(f) + 4 * d)
        return total

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top-k + shared only)."""
        if self.moe is None:
            return self.n_params()
        e = self.moe
        expert = (3 if self.gated_mlp else 2) * self.d_model * e.d_expert
        n_moe = self.n_layers - len(self.dense_layers)
        inactive = n_moe * (e.n_routed - e.top_k) * expert
        return self.n_params() - inactive

    def reduced(self, **overrides) -> "ArchConfig":
        """A tiny same-family variant for CPU smoke tests."""
        d = {
            "n_layers": overrides.get("n_layers", min(self.n_layers, 2)),
            "d_model": 64,
            "n_heads": 4,
            "n_kv_heads": min(self.n_kv_heads, 2) if self.n_kv_heads
            else self.n_kv_heads,
            "d_head": 16,
            "d_ff": 128,
            "vocab": 256,
            "max_seq": 512,
            "param_dtype": "float32",
            "compute_dtype": "float32",
        }
        if self.windows:
            w = [min(x, 8) if x else 0 for x in self.windows[:d["n_layers"]]]
            # keep at least one full-attn layer if the original had one
            if any(x == FULL_WINDOW for x in self.windows):
                w[-1] = FULL_WINDOW
            d["windows"] = tuple(w)
        if self.moe is not None:
            # capacity_factor 4 => no token drops, so decode == forward
            # exactly (capacity dropping is train-time-only behavior)
            d["moe"] = replace(self.moe, n_routed=4, top_k=2, d_expert=32,
                               n_shared=min(self.moe.n_shared, 1), chunk=16,
                               capacity_factor=4.0)
            d["dense_layers"] = tuple(x for x in self.dense_layers
                                      if x < d["n_layers"])
        if self.mla is not None:
            d["mla"] = MLACfg(q_lora=32, kv_lora=16, d_nope=16, d_rope=8,
                              d_v=16)
        if self.mamba is not None:
            d["mamba"] = replace(self.mamba, d_state=4)
        if self.rwkv is not None:
            d["rwkv"] = RWKVCfg(decay_lora=8, head_dim=16)
        if self.cross_attn_period:
            d["cross_attn_period"] = 2
            d["n_img_tokens"] = 8
        if self.enc_dec:
            d["n_enc_layers"] = 2
            d["enc_seq"] = 16
        d.update(overrides)
        return replace(self, **d)
