"""Multi-head Latent Attention (DeepSeek-V2) — train forward + *absorbed*
decode.

Train/prefill expands the latent kv to per-head K/V (compute-friendly, remat
under scan). Decode uses the absorbed formulation: queries are projected into
the kv-latent space, so attention runs against the cached (S, kv_lora) latent
plus the shared (S, d_rope) rope key — the cache never expands to per-head
K/V. That is the memory trick that makes the 32k/128-batch decode cell fit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .common import (KeyGen, apply_rope, constrain_batch,
                     dense_init, dt, init_norm, apply_norm)
from .config import ArchConfig


def init_mla(keys: KeyGen, cfg: ArchConfig,
             stack: tuple[int, ...] = ()) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    dtype = dt(cfg)
    return {
        "wq_a": dense_init(keys(), (*stack, d, m.q_lora), dtype),
        "q_norm": {"scale": jnp.ones((*stack, m.q_lora), jnp.float32)},
        "wq_b": dense_init(keys(), (*stack, m.q_lora,
                                    h * (m.d_nope + m.d_rope)), dtype),
        "wkv_a": dense_init(keys(), (*stack, d, m.kv_lora + m.d_rope), dtype),
        "kv_norm": {"scale": jnp.ones((*stack, m.kv_lora), jnp.float32)},
        "wk_b": dense_init(keys(), (*stack, m.kv_lora, h * m.d_nope), dtype),
        "wv_b": dense_init(keys(), (*stack, m.kv_lora, h * m.d_v), dtype),
        "wo": dense_init(keys(), (*stack, h * m.d_v, d), dtype),
    }


def _rms(x, scale):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * lax.rsqrt(var + 1e-6) * scale).astype(x.dtype)


def _queries(cfg: ArchConfig, p: dict, x: jax.Array, positions: jax.Array):
    m = cfg.mla
    B, S, _ = x.shape
    h = cfg.n_heads
    cq = _rms(jnp.einsum("bsd,dq->bsq", x, p["wq_a"].astype(x.dtype)),
              p["q_norm"]["scale"])
    q = jnp.einsum("bsq,qe->bse", cq, p["wq_b"].astype(x.dtype))
    q = constrain_batch(q.reshape(B, S, h, m.d_nope + m.d_rope),
                        head_dim=2)
    q_nope, q_pe = q[..., :m.d_nope], q[..., m.d_nope:]
    q_pe = apply_rope(q_pe, positions, 1.0, cfg.rope_theta)
    return q_nope, q_pe


def _latents(cfg: ArchConfig, p: dict, x: jax.Array, positions: jax.Array):
    m = cfg.mla
    kv = jnp.einsum("bsd,dq->bsq", x, p["wkv_a"].astype(x.dtype))
    c_kv = _rms(kv[..., :m.kv_lora], p["kv_norm"]["scale"])
    k_pe = apply_rope(kv[..., m.kv_lora:], positions, 1.0, cfg.rope_theta)
    return c_kv, k_pe           # (B, S, kv_lora), (B, S, d_rope)


def _mla_core(cfg: ArchConfig, p: dict, x: jax.Array):
    from repro.kernels import ref as kref

    m = cfg.mla
    B, S, _ = x.shape
    h = cfg.n_heads
    positions = jnp.arange(S)
    q_nope, q_pe = _queries(cfg, p, x, positions)     # (B,S,h,*)
    c_kv, k_pe = _latents(cfg, p, x, positions)
    k_nope = jnp.einsum("bsq,qe->bse", c_kv, p["wk_b"].astype(x.dtype))
    k_nope = constrain_batch(k_nope.reshape(B, S, h, m.d_nope),
                             head_dim=2)
    v = jnp.einsum("bsq,qe->bse", c_kv, p["wv_b"].astype(x.dtype))
    v = constrain_batch(v.reshape(B, S, h, m.d_v), head_dim=2)

    # Fold (nope ++ rope) into one head dim so the blockwise flash path
    # applies; the shared rope key broadcasts across heads.
    scale = (m.d_nope + m.d_rope) ** -0.5
    qq = jnp.concatenate([q_nope, q_pe], axis=-1)     # (B,S,h,dn+dr)
    kk = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :],
                                  (B, S, h, m.d_rope))], axis=-1)
    o = kref.attention_ref(qq.transpose(0, 2, 1, 3),
                           kk.transpose(0, 2, 1, 3),
                           v.transpose(0, 2, 1, 3),
                           causal=True, scale=scale)
    o = o.transpose(0, 2, 1, 3).astype(x.dtype).reshape(B, S, h * m.d_v)
    out = jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype))
    return out, c_kv, k_pe


def mla_forward(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    """Training: expand latents to per-head K/V."""
    return _mla_core(cfg, p, x)[0]


def mla_prefill(cfg: ArchConfig, p: dict, x: jax.Array, c_kv_cache,
                k_pe_cache) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Parallel prefill writing the *latent* cache for positions [0, S)."""
    out, c_kv, k_pe = _mla_core(cfg, p, x)
    c_kv_cache = lax.dynamic_update_slice_in_dim(
        c_kv_cache, c_kv.astype(c_kv_cache.dtype), 0, axis=1)
    k_pe_cache = lax.dynamic_update_slice_in_dim(
        k_pe_cache, k_pe.astype(k_pe_cache.dtype), 0, axis=1)
    return out, c_kv_cache, k_pe_cache


# --------------------------------------------------------------- decode ----

def init_mla_cache(cfg: ArchConfig, n_layers: int, batch: int, max_seq: int,
                   dtype) -> dict:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((n_layers, batch, max_seq, m.kv_lora), dtype),
        "k_pe": jnp.zeros((n_layers, batch, max_seq, m.d_rope), dtype),
    }


def mla_decode(cfg: ArchConfig, p: dict, x: jax.Array, c_kv_cache: jax.Array,
               k_pe_cache: jax.Array, pos: jax.Array
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Absorbed one-token decode. x: (B, 1, D); caches: (B, S, kv_lora) and
    (B, S, d_rope)."""
    m = cfg.mla
    B = x.shape[0]
    h = cfg.n_heads
    positions = jnp.full((1,), pos)
    q_nope, q_pe = _queries(cfg, p, x, positions)   # (B,1,h,*)
    c_kv, k_pe = _latents(cfg, p, x, positions)     # (B,1,kv_lora/d_rope)
    c_kv_cache = lax.dynamic_update_slice_in_dim(
        c_kv_cache, c_kv.astype(c_kv_cache.dtype), pos, axis=1)
    k_pe_cache = lax.dynamic_update_slice_in_dim(
        k_pe_cache, k_pe.astype(k_pe_cache.dtype), pos, axis=1)

    # absorb W^UK into the query: q_c (B, 1, h, kv_lora)
    cd = c_kv_cache.dtype
    wk_b = p["wk_b"].astype(cd).reshape(m.kv_lora, h, m.d_nope)
    q_c = jnp.einsum("bqhd,lhd->bqhl", q_nope.astype(cd), wk_b,
                     preferred_element_type=jnp.float32)

    # cache stays in storage dtype: f32 accumulation via
    # preferred_element_type (a cast here would clone the whole cache)
    scale = (m.d_nope + m.d_rope) ** -0.5
    s = (jnp.einsum("bqhl,bkl->bhqk", q_c.astype(cd), c_kv_cache,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bqhd,bkd->bhqk", q_pe.astype(cd), k_pe_cache,
                      preferred_element_type=jnp.float32)) * scale
    k_pos = jnp.arange(c_kv_cache.shape[1])
    s = jnp.where((k_pos <= pos)[None, None, None, :], s, -1e30)
    attn = jax.nn.softmax(s, axis=-1)
    # attend in latent space, then expand through W^UV
    o_lat = jnp.einsum("bhqk,bkl->bqhl", attn.astype(cd), c_kv_cache,
                       preferred_element_type=jnp.float32)  # (B,1,h,lora)
    wv_b = p["wv_b"].astype(jnp.float32).reshape(m.kv_lora, h, m.d_v)
    o = jnp.einsum("bqhl,lhd->bqhd", o_lat, wv_b)
    o = o.astype(x.dtype).reshape(B, 1, h * m.d_v)
    out = jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype))
    return out, c_kv_cache, k_pe_cache
