"""RWKV-6 (Finch) mixer: token-shift mixing, data-dependent decay via a
low-rank projection, per-head wkv state recurrence; plus the RWKV
channel-mix FFN. Attention-free — decode carries only (state, prev-token),
which is what makes the 500k-context cell O(1) in sequence length.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .common import KeyGen, dense_init, dt, zeros
from .config import ArchConfig


def n_rwkv_heads(cfg: ArchConfig) -> int:
    return cfg.d_model // cfg.rwkv.head_dim


def init_rwkv(keys: KeyGen, cfg: ArchConfig,
              stack: tuple[int, ...] = ()) -> dict:
    c = cfg.rwkv
    d = cfg.d_model
    dtype = dt(cfg)
    return {
        # time-mix
        "mu": zeros((*stack, 5, d), jnp.float32),        # r,k,v,w,g mixing
        "w_r": dense_init(keys(), (*stack, d, d), dtype),
        "w_k": dense_init(keys(), (*stack, d, d), dtype),
        "w_v": dense_init(keys(), (*stack, d, d), dtype),
        "w_g": dense_init(keys(), (*stack, d, d), dtype),
        "w_o": dense_init(keys(), (*stack, d, d), dtype),
        "decay_base": zeros((*stack, d), jnp.float32),
        "decay_a": dense_init(keys(), (*stack, d, c.decay_lora), dtype),
        "decay_b": dense_init(keys(), (*stack, c.decay_lora, d), dtype),
        "bonus": zeros((*stack, d), jnp.float32),        # u
        "ln_x": {"scale": jnp.ones((*stack, d), jnp.float32)},
        # channel-mix
        "mu_c": zeros((*stack, 2, d), jnp.float32),
        "cm_r": dense_init(keys(), (*stack, d, d), dtype),
        "cm_k": dense_init(keys(), (*stack, d, cfg.d_ff), dtype),
        "cm_v": dense_init(keys(), (*stack, cfg.d_ff, d), dtype),
    }


def _shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """Previous-token view of x: (B, S, D). prev: (B, D) carried context."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([prev[:, None, :], x[:, :-1]], axis=1)


def _mix(x, xx, mu):
    return x + (xx - x) * mu.astype(x.dtype)


def _decay(cfg: ArchConfig, p: dict, xw: jax.Array) -> jax.Array:
    """Data-dependent per-channel decay in (0, 1): w = exp(-exp(...))."""
    lora = jnp.einsum("bsd,dl->bsl", xw, p["decay_a"].astype(xw.dtype))
    lora = jnp.einsum("bsl,ld->bsd", jnp.tanh(lora),
                      p["decay_b"].astype(xw.dtype))
    logit = p["decay_base"].astype(jnp.float32) + lora.astype(jnp.float32)
    return jnp.exp(-jnp.exp(logit))


def _group_norm(p, y):
    """Per-head group norm of the wkv output. y: (B, S, H, hd)."""
    y32 = y.astype(jnp.float32)
    mean = y32.mean(-1, keepdims=True)
    var = y32.var(-1, keepdims=True)
    yn = (y32 - mean) * lax.rsqrt(var + 1e-5)
    B, S, H, hd = y.shape
    scale = p["ln_x"]["scale"].reshape(H, hd)
    return (yn * scale).reshape(B, S, H * hd)


def _rkvwg(cfg, p, x, xx):
    mu = p["mu"]
    r = jnp.einsum("bsd,de->bse", _mix(x, xx, mu[0]), p["w_r"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", _mix(x, xx, mu[1]), p["w_k"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", _mix(x, xx, mu[2]), p["w_v"].astype(x.dtype))
    w = _decay(cfg, p, _mix(x, xx, mu[3]))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", _mix(x, xx, mu[4]),
                               p["w_g"].astype(x.dtype)))
    return r, k, v, w, g


def _wkv_step(u, h, r_t, k_t, v_t, w_t):
    """h: (B, H, hd, hd) state [k-dim, v-dim]; r/k/v/w_t: (B, H, hd)."""
    kv = k_t[..., :, None] * v_t[..., None, :]           # (B,H,hd,hd)
    y = jnp.einsum("bhk,bhkv->bhv", r_t, h + u[..., :, None] * kv)
    h = w_t[..., :, None] * h + kv
    return h, y


def rwkv_time_mix(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    return _time_mix_core(cfg, p, x)[0]


def rwkv_time_mix_prefill(cfg: ArchConfig, p: dict, x: jax.Array):
    """Returns (out, final wkv state, last-token shift context)."""
    return _time_mix_core(cfg, p, x)


def _time_mix_core(cfg: ArchConfig, p: dict, x: jax.Array):
    H = n_rwkv_heads(cfg)
    hd = cfg.rwkv.head_dim
    B, S, D = x.shape
    xx = _shift(x)
    r, k, v, w, g = _rkvwg(cfg, p, x, xx)
    to_h = lambda a: a.astype(jnp.float32).reshape(B, S, H, hd)  # noqa: E731
    r, k, v, w = to_h(r), to_h(k), to_h(v), to_h(w)
    u = p["bonus"].astype(jnp.float32).reshape(H, hd)[None]

    def step(h, inp):
        r_t, k_t, v_t, w_t = inp
        return _wkv_step(u, h, r_t, k_t, v_t, w_t)

    h0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    xs = tuple(a.swapaxes(0, 1) for a in (r, k, v, w))
    h_final, ys = lax.scan(step, h0, xs)                  # (S, B, H, hd)
    y = ys.swapaxes(0, 1).reshape(B, S, H, hd)
    y = _group_norm(p, y).astype(x.dtype) * g
    out = jnp.einsum("bsd,de->bse", y, p["w_o"].astype(x.dtype))
    return out, h_final, x[:, -1]


def rwkv_channel_mix_prefill(cfg: ArchConfig, p: dict, x: jax.Array):
    return rwkv_channel_mix(cfg, p, x), x[:, -1]


def rwkv_channel_mix(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    xx = _shift(x)
    mu = p["mu_c"]
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", _mix(x, xx, mu[0]),
                                  p["cm_r"].astype(x.dtype)))
    k = jnp.einsum("bsd,df->bsf", _mix(x, xx, mu[1]),
                   p["cm_k"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    return r * jnp.einsum("bsf,fd->bsd", k, p["cm_v"].astype(x.dtype))


# --------------------------------------------------------------- decode ----

def init_rwkv_cache(cfg: ArchConfig, n_layers: int, batch: int,
                    dtype) -> dict:
    H, hd = n_rwkv_heads(cfg), cfg.rwkv.head_dim
    d = cfg.d_model
    return {
        "wkv": jnp.zeros((n_layers, batch, H, hd, hd), jnp.float32),
        "prev_t": jnp.zeros((n_layers, batch, d), dtype),   # time-mix shift
        "prev_c": jnp.zeros((n_layers, batch, d), dtype),   # channel-mix shift
    }


def rwkv_time_mix_decode(cfg: ArchConfig, p: dict, x: jax.Array,
                         wkv_state, prev_t):
    """x: (B, 1, D). Returns (out, new_wkv, new_prev_t)."""
    H, hd = n_rwkv_heads(cfg), cfg.rwkv.head_dim
    B = x.shape[0]
    xx = _shift(x, prev=prev_t.astype(x.dtype))
    r, k, v, w, g = _rkvwg(cfg, p, x, xx)
    to_h = lambda a: a.astype(jnp.float32).reshape(B, H, hd)  # noqa: E731
    u = p["bonus"].astype(jnp.float32).reshape(H, hd)[None]
    h, y = _wkv_step(u, wkv_state, to_h(r[:, 0]), to_h(k[:, 0]),
                     to_h(v[:, 0]), to_h(w[:, 0]))
    y = y.reshape(B, 1, H, hd)
    y = _group_norm(p, y).astype(x.dtype) * g
    out = jnp.einsum("bsd,de->bse", y, p["w_o"].astype(x.dtype))
    return out, h, x[:, -1]


def rwkv_channel_mix_decode(cfg: ArchConfig, p: dict, x: jax.Array, prev_c):
    xx = _shift(x, prev=prev_c.astype(x.dtype))
    mu = p["mu_c"]
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", _mix(x, xx, mu[0]),
                                  p["cm_r"].astype(x.dtype)))
    k = jnp.einsum("bsd,df->bsf", _mix(x, xx, mu[1]),
                   p["cm_k"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    out = r * jnp.einsum("bsf,fd->bsd", k, p["cm_v"].astype(x.dtype))
    return out, x[:, -1]
