"""Selective SSM (Mamba) mixer — used by hymba's parallel attn+mamba heads.

Training runs the selective scan over the sequence with ``lax.scan`` (state
(B, d_inner, d_state) carried); decode is a single recurrence step with the
state held in the serve cache. The short causal depthwise conv is expressed
as a sum of shifted views (no conv primitive needed).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .common import KeyGen, dense_init, dt, zeros
from .config import ArchConfig


def d_inner(cfg: ArchConfig) -> int:
    return cfg.mamba.expand * cfg.d_model


def init_mamba(keys: KeyGen, cfg: ArchConfig,
               stack: tuple[int, ...] = ()) -> dict:
    m = cfg.mamba
    d, di = cfg.d_model, d_inner(cfg)
    dtype = dt(cfg)
    return {
        "w_in": dense_init(keys(), (*stack, d, 2 * di), dtype),
        "conv_w": dense_init(keys(), (*stack, m.d_conv, di), dtype,
                             in_axis=-2),
        "w_bcdt": dense_init(keys(), (*stack, di, 2 * m.d_state + 1), dtype),
        "dt_bias": zeros((*stack, di), jnp.float32),
        "A_log": zeros((*stack, di, m.d_state), jnp.float32),
        "D_skip": jnp.ones((*stack, di), jnp.float32),
        "w_out": dense_init(keys(), (*stack, di, d), dtype),
    }


def _split_xz(cfg, p, x):
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(x.dtype))
    di = d_inner(cfg)
    return xz[..., :di], xz[..., di:]


def _conv(p, xp, prev_window=None):
    """Causal depthwise conv along seq; xp: (B, S, di).
    prev_window: (B, d_conv-1, di) trailing context for decode."""
    w = p["conv_w"].astype(xp.dtype)                  # (d_conv, di)
    d_conv = w.shape[0]
    if prev_window is not None:
        xp_full = jnp.concatenate([prev_window.astype(xp.dtype), xp], axis=1)
    else:
        xp_full = jnp.pad(xp, ((0, 0), (d_conv - 1, 0), (0, 0)))
    S = xp.shape[1]
    out = sum(xp_full[:, i:i + S, :] * w[d_conv - 1 - i]
              for i in range(d_conv))
    return jax.nn.silu(out)


def _ssm_inputs(cfg, p, xc):
    m = cfg.mamba
    bcdt = jnp.einsum("bse,ec->bsc", xc, p["w_bcdt"].astype(xc.dtype))
    # note: B/C here are per-token, shared across channels (standard mamba
    # uses x->B,C of size d_state from d_inner)
    B_t = bcdt[..., :m.d_state].astype(jnp.float32)          # (B,S,N)
    C_t = bcdt[..., m.d_state:2 * m.d_state].astype(jnp.float32)
    dt_t = bcdt[..., -1:].astype(jnp.float32)                # (B,S,1) logits
    return B_t, C_t, dt_t


def mamba_forward(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    return _mamba_core(cfg, p, x)[0]


def mamba_prefill(cfg: ArchConfig, p: dict, x: jax.Array
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Parallel prefill. Returns (out, final ssm state, conv window)."""
    return _mamba_core(cfg, p, x)


MAMBA_CHUNK = 128   # parallel (associative-scan) span; sequential across


def _chunked_selective_scan(xc32, B_t, C_t, dt_ch, A, d_state: int):
    """Selective scan h_t = exp(dt_t A) h_{t-1} + (dt_t x_t) B_t^T,
    y_t = h_t C_t — chunked: ``lax.associative_scan`` inside chunks of
    MAMBA_CHUNK (one big vectorized op instead of an S-trip while loop),
    sequential carry across chunks, remat per chunk. This is the
    TPU-friendly form: S/128 loop trips instead of S, and backward saves
    only chunk-boundary states (see EXPERIMENTS.md §Perf, hymba cell).
    """
    Bb, S, di = xc32.shape
    q = MAMBA_CHUNK if S >= MAMBA_CHUNK else S
    while S % q:
        q //= 2
    nc = S // q

    def resh(a):  # (B, S, ...) -> (nc, B, q, ...)
        return jnp.moveaxis(a.reshape(Bb, nc, q, *a.shape[2:]), 1, 0)

    xs = (resh(xc32), resh(B_t), resh(C_t), resh(dt_ch))

    @jax.checkpoint
    def chunk(h0, inp):
        xq, bq, cq, dtq = inp                      # (B,q,di),(B,q,N),...
        a = jnp.exp(dtq[..., None] * A[None, None])        # (B,q,di,N)
        b = (dtq * xq)[..., None] * bq[:, :, None, :]      # (B,q,di,N)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        a_cum, b_cum = lax.associative_scan(combine, (a, b), axis=1)
        h = a_cum * h0[:, None] + b_cum                    # (B,q,di,N)
        y = jnp.einsum("bqdn,bqn->bqd", h, cq)
        return h[:, -1], y

    h0 = jnp.zeros((Bb, di, d_state), jnp.float32)
    h_final, ys = lax.scan(chunk, h0, xs)          # ys: (nc, B, q, di)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, S, di)
    return y, h_final


def _mamba_core(cfg: ArchConfig, p: dict, x: jax.Array):
    m = cfg.mamba
    Bb, S, D = x.shape
    di = d_inner(cfg)
    xp, z = _split_xz(cfg, p, x)
    xc = _conv(p, xp)                                        # (B, S, di)
    B_t, C_t, dt_t = _ssm_inputs(cfg, p, xc)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # (di, N)
    # per-channel dt via learned bias: (B, S, di)
    dt_ch = jax.nn.softplus(
        dt_t + p["dt_bias"].astype(jnp.float32)[None, None, :])

    y, h_final = _chunked_selective_scan(
        xc.astype(jnp.float32), B_t, C_t, dt_ch, A, m.d_state)
    y = y + xc.astype(jnp.float32) * p["D_skip"].astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(x.dtype))
    conv_win = xp[:, S - (m.d_conv - 1):, :]                 # (B, dc-1, di)
    return out, h_final, conv_win


# --------------------------------------------------------------- decode ----

def init_mamba_cache(cfg: ArchConfig, n_layers: int, batch: int,
                     dtype) -> dict:
    m = cfg.mamba
    di = cfg.mamba.expand * cfg.d_model
    return {
        "ssm": jnp.zeros((n_layers, batch, di, m.d_state), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, m.d_conv - 1, di), dtype),
    }


def mamba_decode(cfg: ArchConfig, p: dict, x: jax.Array, ssm_state, conv_win
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One token. x: (B, 1, D); ssm_state: (B, di, N);
    conv_win: (B, d_conv-1, di)."""
    m = cfg.mamba
    xp, z = _split_xz(cfg, p, x)                             # (B,1,di)
    xc = _conv(p, xp, prev_window=conv_win)                  # (B,1,di)
    new_win = jnp.concatenate([conv_win[:, 1:], xp.astype(conv_win.dtype)],
                              axis=1)
    B_t, C_t, dt_t = _ssm_inputs(cfg, p, xc)
    dt_ch = jax.nn.softplus(
        dt_t + p["dt_bias"].astype(jnp.float32)[None, None, :])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xt = xc[:, 0].astype(jnp.float32)
    bt, ct, dtt = B_t[:, 0], C_t[:, 0], dt_ch[:, 0]
    decay = jnp.exp(dtt[..., None] * A[None])
    h = decay * ssm_state + (dtt * xt)[..., None] * bt[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, ct)[:, None, :]          # (B,1,di)
    y = y + xc.astype(jnp.float32) * p["D_skip"].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(x.dtype))
    return out, h, new_win
