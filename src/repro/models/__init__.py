"""Model zoo: a composable decoder LM + enc-dec stack covering all assigned
architectures. ``build_model(cfg)`` returns the right stack for a config."""

from .config import (ArchConfig, FULL_WINDOW, MLACfg, MambaCfg, MoECfg,
                     RWKVCfg)
from .encdec import EncDecLM
from .transformer import DecoderLM


def build_model(cfg: ArchConfig, remat: bool = False):
    return EncDecLM(cfg, remat=remat) if cfg.enc_dec \
        else DecoderLM(cfg, remat=remat)


__all__ = ["ArchConfig", "FULL_WINDOW", "MLACfg", "MambaCfg", "MoECfg",
           "RWKVCfg", "DecoderLM", "EncDecLM", "build_model"]
