"""Fine-grained mixture-of-experts (DeepSeek-MoE / DeepSeek-V2 style):
shared experts + routed top-k experts with capacity-bucketed einsum dispatch.

The dispatch/combine one-hots lower to all-to-alls when the expert axis is
sharded over the ``model`` mesh axis (expert parallelism). The sequence is
processed in chunks (``moe.chunk``) via ``lax.scan`` so dispatch tensors stay
VMEM-sized; capacity is per-chunk. Router aux losses (load-balance + z-loss)
are returned for the train loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .common import KeyGen, dense_init, dt
from .config import ArchConfig, MoECfg


def init_moe(keys: KeyGen, cfg: ArchConfig,
             stack: tuple[int, ...] = ()) -> dict:
    e = cfg.moe
    d = cfg.d_model
    dtype = dt(cfg)
    p = {
        "router": dense_init(keys(), (*stack, d, e.n_routed), jnp.float32),
        "w_in": dense_init(keys(), (*stack, e.n_routed, d, e.d_expert),
                           dtype, in_axis=-2),
        "w_gate": dense_init(keys(), (*stack, e.n_routed, d, e.d_expert),
                             dtype, in_axis=-2),
        "w_out": dense_init(keys(), (*stack, e.n_routed, e.d_expert, d),
                            dtype, in_axis=-2),
    }
    if e.n_shared:
        sh = e.n_shared * e.d_expert
        p["shared_in"] = dense_init(keys(), (*stack, d, sh), dtype)
        p["shared_gate"] = dense_init(keys(), (*stack, d, sh), dtype)
        p["shared_out"] = dense_init(keys(), (*stack, sh, d), dtype)
    return p


def _capacity(e: MoECfg, chunk_tokens: int) -> int:
    cap = int(chunk_tokens * e.top_k / e.n_routed * e.capacity_factor)
    return max(4, ((cap + 3) // 4) * 4)


def moe_ffn(cfg: ArchConfig, p: dict, x: jax.Array
            ) -> tuple[jax.Array, dict]:
    """x: (B, S, D) -> (out, aux). Chunked over S."""
    e = cfg.moe
    B, S, D = x.shape
    chunk = min(e.chunk, S)
    while S % chunk:
        chunk //= 2
    n_chunks = S // chunk
    cap = _capacity(e, chunk)

    xs = x.reshape(B, n_chunks, chunk, D).swapaxes(0, 1)  # (n, B, C, D)

    @jax.checkpoint
    def body(carry, xc):
        # remat: dispatch/combine one-hots are huge; recompute in backward
        lb_sum, z_sum = carry
        yc, lb, z = _moe_chunk(cfg, p, xc, cap)
        return (lb_sum + lb, z_sum + z), yc

    (lb_sum, z_sum), ys = lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), xs)
    y = ys.swapaxes(0, 1).reshape(B, S, D)

    if e.n_shared:
        h = jnp.einsum("bsd,df->bsf", x, p["shared_in"].astype(x.dtype))
        g = jnp.einsum("bsd,df->bsf", x, p["shared_gate"].astype(x.dtype))
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * h,
                           p["shared_out"].astype(x.dtype))
    aux = {"moe_load_balance": lb_sum / n_chunks,
           "moe_z_loss": z_sum / n_chunks}
    return y, aux


def _moe_chunk(cfg: ArchConfig, p: dict, xc: jax.Array, cap: int):
    """One seq chunk: xc (B, C, D)."""
    e = cfg.moe
    B, C, D = xc.shape
    E, K = e.n_routed, e.top_k

    # router matmul in the activation dtype (a f32 cast of xc here would
    # drag a full-width f32 copy of the hidden through the model-axis
    # all-gather); only the small (B, C, E) logits are upcast.
    logits = jnp.einsum("bcd,de->bce", xc,
                        p["router"].astype(xc.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (B, C, E)
    gate, sel = lax.top_k(probs, K)                          # (B, C, K)
    gate = gate / (gate.sum(-1, keepdims=True) + 1e-9)       # renorm (dsv2)

    # aux losses
    me = probs.mean(axis=(0, 1))                             # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[sel.reshape(-1)].add(
        1.0 / (B * C * K))
    load_balance = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # capacity-bucketed dispatch (Switch-style, per (batch, chunk))
    onehot = jax.nn.one_hot(sel, E, dtype=jnp.float32)       # (B, C, K, E)
    # position of each (token, k) within its expert's bucket, in (C*K) order
    flat = onehot.reshape(B, C * K, E)
    pos_in_e = jnp.cumsum(flat, axis=1) - flat               # (B, C*K, E)
    pos_in_e = (pos_in_e * flat).sum(-1).astype(jnp.int32)   # (B, C*K)
    keep = (pos_in_e < cap).astype(jnp.float32)
    slot = jax.nn.one_hot(pos_in_e, cap, dtype=jnp.float32)  # (B, C*K, cap)
    # dispatch[b, ck, e, cap]
    dispatch = flat[..., None] * slot[..., None, :] * keep[..., None, None]
    combine = dispatch.reshape(B, C, K, E, cap) \
        * gate[..., None, None]                              # weight per slot
    dispatch = dispatch.reshape(B, C, K, E, cap).sum(2)      # (B, C, E, cap)
    combine = combine.sum(2)                                 # (B, C, E, cap)

    cd = xc.dtype
    xe = jnp.einsum("bceg,bcd->begd", dispatch.astype(cd), xc)  # (B,E,cap,D)
    h = jnp.einsum("begd,edf->begf", xe, p["w_in"].astype(cd))
    g = jnp.einsum("begd,edf->begf", xe, p["w_gate"].astype(cd))
    oe = jnp.einsum("begf,efd->begd", jax.nn.silu(g) * h,
                    p["w_out"].astype(cd))                   # (B,E,cap,D)
    yc = jnp.einsum("bceg,begd->bcd", combine.astype(cd), oe)
    return yc, load_balance, z_loss
