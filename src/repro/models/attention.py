"""GQA attention block: train/prefill forward + cached decode step.

Supports per-layer sliding windows *as data* (window scalar array; 0 = full
attention) so heterogeneous stacks (gemma2 alternating, hymba's 3 global
layers) run under one scanned layer body. Softcap per config. The underlying
attention math routes through ``repro.kernels.ops.attention`` (Pallas flash
kernel on TPU, oracle elsewhere).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops, ref as kref

from .common import (KeyGen, apply_rope, constrain_batch,
                     dense_init, dt, zeros)
from .config import ArchConfig


def init_attn(keys: KeyGen, cfg: ArchConfig,
              stack: tuple[int, ...] = ()) -> dict:
    dtype = dt(cfg)
    d = cfg.d_model
    p = {
        "wq": dense_init(keys(), (*stack, d, cfg.d_q), dtype),
        "wk": dense_init(keys(), (*stack, d, cfg.d_kv), dtype),
        "wv": dense_init(keys(), (*stack, d, cfg.d_kv), dtype),
        "wo": dense_init(keys(), (*stack, cfg.d_q, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros((*stack, cfg.d_q), dtype)
        p["bk"] = zeros((*stack, cfg.d_kv), dtype)
        p["bv"] = zeros((*stack, cfg.d_kv), dtype)
    return p


def _qkv(cfg: ArchConfig, p: dict, x: jax.Array,
         positions: jax.Array, rope: bool = True):
    B, S, _ = x.shape
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = constrain_batch(q.reshape(B, S, cfg.n_heads, cfg.d_head),
                        head_dim=2)
    k = constrain_batch(k.reshape(B, S, cfg.n_kv_heads, cfg.d_head),
                        head_dim=2)
    v = constrain_batch(v.reshape(B, S, cfg.n_kv_heads, cfg.d_head),
                        head_dim=2)
    if rope and cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_frac, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_frac, cfg.rope_theta)
    # -> (B, H, S, D)
    return (q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3))


def _attn_core(cfg: ArchConfig, p: dict, x: jax.Array, window,
               causal: bool):
    B, S, _ = x.shape
    positions = jnp.arange(S)
    q, k, v = _qkv(cfg, p, x, positions)
    static_window = isinstance(window, int) or window is None
    if static_window:
        win = None if not window else int(window)
        o = ops.attention(q, k, v, causal=causal, window=win,
                          softcap=cfg.attn_softcap)
    else:
        o = _masked_attention(q, k, v, window, causal, cfg.attn_softcap)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.d_q)
    out = constrain_batch(
        jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype)))
    return out, k, v


def attn_forward(cfg: ArchConfig, p: dict, x: jax.Array,
                 window: jax.Array | int | None = None,
                 causal: bool = True) -> jax.Array:
    """Full-sequence attention (training). ``window`` may be a traced
    scalar (0 = full); traced windows always use the masked oracle."""
    return _attn_core(cfg, p, x, window, causal)[0]


def attn_prefill(cfg: ArchConfig, p: dict, x: jax.Array, cache_k, cache_v,
                 window: jax.Array | int | None = None
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Parallel prefill: forward + write K/V for positions [0, S) into the
    cache. Returns (out, new_cache_k, new_cache_v)."""
    out, k, v = _attn_core(cfg, p, x, window, causal=True)
    cache_k = lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), 0, axis=2)
    cache_v = lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), 0, axis=2)
    return out, cache_k, cache_v


def _masked_attention(q, k, v, window, causal: bool,
                      softcap: float | None) -> jax.Array:
    """Oracle attention with a *traced* window scalar (0 = full attn);
    dispatches through the blockwise path for long sequences."""
    return kref.attention_ref(q, k, v, causal=causal, window=window,
                              softcap=softcap)


# --------------------------------------------------------------- decode ----

def init_kv_cache(cfg: ArchConfig, n_layers: int, batch: int, max_seq: int,
                  dtype) -> dict:
    shape = (n_layers, batch, cfg.n_kv_heads, max_seq, cfg.d_head)
    return {"k": zeros(shape, dtype), "v": zeros(shape, dtype)}


def attn_decode(cfg: ArchConfig, p: dict, x: jax.Array, cache_k: jax.Array,
                cache_v: jax.Array, pos: jax.Array,
                window: jax.Array | int | None = None,
                start: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x: (B, 1, D); cache_k/v: (B, Hkv, S, D);
    pos: scalar — index where the new token is written. ``start``,
    when given, is a (B,) vector of per-slot window origins for
    token-level continuous batching: slot b attends only to cache
    positions in [start[b], pos], hiding the previous occupant's stale
    K/V (always below start[b], since the arena cursor only advances).
    Returns (out, new_cache_k, new_cache_v)."""
    B = x.shape[0]
    positions = jnp.full((1,), pos)
    q, k, v = _qkv(cfg, p, x, positions)
    cache_k = lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype),
                                              pos, axis=2)
    cache_v = lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype),
                                              pos, axis=2)
    S = cache_k.shape[2]
    win = window if window is not None else 0
    o = _decode_attention(q, cache_k, cache_v, pos, win, cfg.attn_softcap,
                          start=start)
    o = o.transpose(0, 2, 1, 3).reshape(B, 1, cfg.d_q)
    out = jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype))
    return out, cache_k, cache_v


def _decode_attention(q, cache_k, cache_v, pos, window,
                      softcap: float | None,
                      start: jax.Array | None = None) -> jax.Array:
    """q: (B, Hq, 1, D) vs full cache; masks unwritten and out-of-window
    positions, plus per-batch positions below ``start`` (stale cache
    from a slot's previous occupant). ``window`` may be traced (0 =
    unlimited). Masking (not zeroing) is load-bearing for slot reuse: a
    zeroed K row still gets softmax weight exp(0), so stale rows must be
    excluded from the normalization, and rotary phases stay correct
    because only relative distances inside [start, pos] survive.

    The cache stays in its storage dtype — an ``astype(f32)`` here gets
    hoisted by the compiler into a full f32 copy of the *whole stacked
    cache* (2x the serving HBM); f32 accumulation comes from
    ``preferred_element_type`` instead (EXPERIMENTS.md §Perf)."""
    B, Hq, _, D = q.shape
    Hkv = cache_k.shape[1]
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, D).astype(cache_k.dtype)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, cache_k,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = jnp.arange(cache_k.shape[2])
    valid = k_pos <= pos
    valid &= jnp.where(window > 0, (pos - k_pos) < window, True)
    if start is None:
        mask = valid[None, None, None, :]
    else:
        mask = (valid[None, :]
                & (k_pos[None, :] >= start[:, None]))[:, None, None, :]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p.astype(cache_v.dtype), cache_v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Hq, 1, D).astype(q.dtype)


# ----------------------------------------------------------- cross-attn ----

def init_cross_attn(keys: KeyGen, cfg: ArchConfig,
                    stack: tuple[int, ...] = ()) -> dict:
    dtype = dt(cfg)
    d = cfg.d_model
    return {
        "wq": dense_init(keys(), (*stack, d, cfg.d_q), dtype),
        "wk": dense_init(keys(), (*stack, d, cfg.d_kv), dtype),
        "wv": dense_init(keys(), (*stack, d, cfg.d_kv), dtype),
        "wo": dense_init(keys(), (*stack, cfg.d_q, d), dtype),
        "gate": zeros((*stack,), jnp.float32),   # mllama tanh gate
    }


def cross_kv(cfg: ArchConfig, p: dict, memory: jax.Array):
    """Precompute cross-attention K/V from encoder/image memory (B, M, D)."""
    B, M, _ = memory.shape
    k = jnp.einsum("bmd,de->bme", memory, p["wk"].astype(memory.dtype))
    v = jnp.einsum("bmd,de->bme", memory, p["wv"].astype(memory.dtype))
    k = k.reshape(B, M, cfg.n_kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    v = v.reshape(B, M, cfg.n_kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    return k, v


def cross_attn_forward(cfg: ArchConfig, p: dict, x: jax.Array,
                       k: jax.Array, v: jax.Array,
                       gated: bool = True) -> jax.Array:
    """x: (B, S, D) queries; k/v: (B, Hkv, M, D) precomputed memory."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(x.dtype))
    q = q.reshape(B, S, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    o = kref.attention_ref(q, k, v, causal=False)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.d_q)
    out = jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype))
    if gated:
        out = out * jnp.tanh(p["gate"]).astype(out.dtype)
    return out
