"""Shared layer primitives: init, norms, rotary embeddings, MLPs, losses.

Parameters are plain nested dicts of jnp arrays; every init function takes a
PRNG key and returns the dict. Layer-stacked parameters carry a leading
``(L, ...)`` axis and are consumed by ``lax.scan`` in transformer.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .config import ArchConfig


def dt(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


def cdt(cfg: ArchConfig):
    return jnp.dtype(cfg.compute_dtype)


# ------------------------------------------------------------ sharding ----

def _active_mesh():
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # pragma: no cover
        return None


def constrain_batch(x: jax.Array, head_dim: int | None = None) -> jax.Array:
    """Pin the leading (batch) dim to the data axes of the active mesh,
    keeping the head axis model-sharded where it divides evenly.

    Head-split reshapes like (B, S, H*Dh) -> (B, S, H, Dh) lose their
    sharding when H*Dh's model-sharding does not align to head boundaries
    (e.g. hymba's 25x64 heads over 16 shards); XLA then silently
    *replicates* the tensor — 16x redundant attention compute/memory.
    Anchoring the batch dim here keeps activations batch-sharded through
    every mixer; for aligned head counts (codeqwen 32, deepseek-v2 128)
    ``head_dim`` keeps tensor parallelism on the heads instead of forcing
    an all-gather. No-op outside a mesh context (tests, single host)."""
    mesh = _active_mesh()
    if mesh is None or x.ndim == 0:
        return x
    from jax.sharding import PartitionSpec as P
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not dp:
        return x
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    if size == 1 or x.shape[0] % size:
        return x
    entries: list = [dp] + [None] * (x.ndim - 1)
    if head_dim is not None and "model" in mesh.axis_names \
            and x.shape[head_dim] % mesh.shape["model"] == 0:
        entries[head_dim] = "model"
    return lax.with_sharding_constraint(x, P(*entries))


# ---------------------------------------------------------------- init ----

def dense_init(key, shape, dtype, in_axis: int = -2) -> jax.Array:
    """Variance-scaling (fan-in) normal init; works for stacked (L, ...)
    weights by measuring fan-in on ``in_axis``."""
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def zeros(shape, dtype) -> jax.Array:
    return jnp.zeros(shape, dtype)


def ones(shape, dtype) -> jax.Array:
    return jnp.ones(shape, dtype)


class KeyGen:
    """Split-on-demand PRNG key stream."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


# ---------------------------------------------------------------- norms ----

def init_norm(cfg: ArchConfig, d: int) -> dict:
    p = {"scale": ones((d,), jnp.float32)}
    if cfg.norm == "ln":
        p["bias"] = zeros((d,), jnp.float32)
    return p


def apply_norm(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    x32 = x.astype(jnp.float32)
    if cfg.norm == "rms":
        var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
        y = x32 * lax.rsqrt(var + 1e-6) * p["scale"]
    else:
        mean = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mean) * lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


# --------------------------------------------------------------- rotary ----

def rope_freqs(d_rot: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_rot, 2, dtype=jnp.float32)
                            / d_rot))


def apply_rope(x: jax.Array, positions: jax.Array, rope_frac: float,
               theta: float) -> jax.Array:
    """x: (..., S, H, D) or (..., S, D); positions: (..., S) or (S,).
    Rotates the first ``rope_frac * D`` dims (partial rotary, stablelm)."""
    d = x.shape[-1]
    d_rot = int(d * rope_frac)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    rot, keep = x[..., :d_rot], x[..., d_rot:]
    freqs = rope_freqs(d_rot, theta)                        # (d_rot/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs
    if x.ndim - positions.ndim == 3:                        # head axis present
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    r1, r2 = rot[..., ::2], rot[..., 1::2]
    o1 = r1 * cos - r2 * sin
    o2 = r2 * cos + r1 * sin
    rotated = jnp.stack([o1, o2], axis=-1).reshape(rot.shape)
    return jnp.concatenate([rotated.astype(x.dtype), keep], axis=-1)


# ------------------------------------------------------------------ MLP ----

def init_mlp(keys: KeyGen, cfg: ArchConfig, d_in: int, d_ff: int,
             stack: tuple[int, ...] = ()) -> dict:
    dtype = dt(cfg)
    p = {"w_in": dense_init(keys(), (*stack, d_in, d_ff), dtype),
         "w_out": dense_init(keys(), (*stack, d_ff, d_in), dtype)}
    if cfg.gated_mlp:
        p["w_gate"] = dense_init(keys(), (*stack, d_in, d_ff), dtype)
    return p


def _act(cfg: ArchConfig, x):
    return jax.nn.silu(x) if cfg.act == "silu" else jax.nn.gelu(x)


def apply_mlp(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["w_in"].astype(x.dtype))
    if cfg.gated_mlp:
        g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(x.dtype))
        h = _act(cfg, g) * h
    else:
        h = _act(cfg, h)
    return jnp.einsum("...f,fd->...d", h, p["w_out"].astype(x.dtype))


# ----------------------------------------------------------------- loss ----

def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def chunked_ce_loss(x: jax.Array, head: jax.Array, labels: jax.Array,
                    mask: jax.Array | None = None,
                    final_softcap: float | None = None,
                    chunk: int = 512,
                    valid_vocab: int | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Cross entropy over seq chunks so (B, S, V) never materializes.

    x: (B, S, D) final hidden states; head: (D, V); labels: (B, S).
    ``valid_vocab``: real vocab size — columns beyond it (padding for clean
    TP sharding) are excluded from the logsumexp.
    Returns (sum_nll, sum_weight); caller divides.
    """
    B, S, D = x.shape
    V = head.shape[1]
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    n_chunks = S // chunk
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    vocab_ok = None
    if valid_vocab is not None and valid_vocab < V:
        vocab_ok = (jnp.arange(V) < valid_vocab)

    xs = (x.reshape(B, n_chunks, chunk, D).swapaxes(0, 1),
          labels.reshape(B, n_chunks, chunk).swapaxes(0, 1),
          mask.reshape(B, n_chunks, chunk).swapaxes(0, 1))

    @jax.checkpoint
    def body(carry, inp):
        # remat: the (B, chunk, V) logits must not be saved for backward —
        # they dominate training memory otherwise.
        nll_sum, w_sum = carry
        xc, yc, mc = inp
        logits = jnp.einsum("bsd,dv->bsv", xc, head.astype(xc.dtype))
        logits = softcap(logits.astype(jnp.float32), final_softcap)
        if vocab_ok is not None:
            logits = jnp.where(vocab_ok, logits, -1e30)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return (nll_sum + nll.sum(), w_sum + mc.sum()), None

    (nll_sum, w_sum), _ = lax.scan(body, (jnp.zeros((), jnp.float32),
                                          jnp.zeros((), jnp.float32)), xs)
    return nll_sum, w_sum
