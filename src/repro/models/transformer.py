"""Generic decoder LM covering the assigned architecture families.

One scanned layer body per family keeps HLO size O(1) in depth:

  * plain/dense (h2o-danube, codeqwen, stablelm, gemma2): GQA attention with
    per-layer windows *as data* + gated MLP;
  * mamba+attn (hymba): parallel attention and SSM heads per layer;
  * rwkv (rwkv6): time-mix + channel-mix;
  * moe (deepseek-moe, deepseek-v2): dense-FFN prefix layers outside the
    scan, MoE layers scanned; deepseek-v2 additionally swaps GQA for MLA;
  * vision (llama-3.2-vision): period-grouped scan — each group is one
    gated cross-attention block + (period-1) self-attention layers.

Public surface: init / forward / loss / init_cache / prefill / decode_step.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from jax.ad_checkpoint import checkpoint_name

import jax
import jax.numpy as jnp
from jax import lax

from . import attention as A
from . import mamba as M
from . import mla as ML
from . import moe as MO
from . import rwkv as R
from .common import (KeyGen, apply_mlp, apply_norm, chunked_ce_loss,
                     constrain_batch, dt, embed_init, init_mlp, init_norm,
                     dense_init, softcap)
from .config import ArchConfig, FULL_WINDOW

Params = dict
Cache = dict


@dataclasses.dataclass(frozen=True)
class DecoderLM:
    cfg: ArchConfig
    remat: bool = False     # activation-checkpoint each scanned layer

    def _maybe_remat(self, body):
        if not self.remat:
            return body
        # Save the (cheap, bf16) post-collective block outputs so the
        # backward pass does not re-run the forward's TP all-reduces /
        # all-gathers — collective traffic is the scarce resource, HBM for
        # two (B,S,D) residuals per layer is not (EXPERIMENTS.md §Perf).
        policy = jax.checkpoint_policies.save_only_these_names(
            "mixer_out", "ffn_out")
        return jax.checkpoint(body, policy=policy)

    # ------------------------------------------------------------ init ----

    def init(self, rng) -> Params:
        cfg = self.cfg
        keys = KeyGen(rng)
        dtype = dt(cfg)
        p: Params = {
            "embed": embed_init(keys(), (cfg.padded_vocab, cfg.d_model),
                                dtype),
            "final_norm": init_norm(cfg, cfg.d_model),
        }
        if not cfg.tie_embeddings:
            p["head"] = dense_init(keys(), (cfg.d_model, cfg.padded_vocab),
                                   dtype)
        if cfg.pos == "learned":
            p["pos_embed"] = embed_init(keys(), (cfg.max_seq, cfg.d_model),
                                        dtype)
        if cfg.cross_attn_period:
            p.update(self._init_vision(keys))
        elif cfg.mixer == "rwkv":
            p["layers"] = self._init_rwkv_stack(keys, cfg.n_layers)
        elif cfg.moe is not None:
            n_dense = len(cfg.dense_layers)
            assert cfg.dense_layers == tuple(range(n_dense)), \
                "dense MoE layers must be a prefix"
            p["dense_prefix"] = [
                self._init_block(keys, moe=False) for _ in range(n_dense)]
            p["layers"] = self._init_block(
                keys, moe=True, stack=(cfg.n_layers - n_dense,))
        else:
            p["layers"] = self._init_block(keys, moe=False,
                                           stack=(cfg.n_layers,))
        return p

    def _init_block(self, keys: KeyGen, moe: bool,
                    stack: tuple[int, ...] = ()) -> dict:
        cfg = self.cfg
        blk: dict = {"ln1": self._norm_stack(stack),
                     "ln2": self._norm_stack(stack)}
        if cfg.post_norm:
            blk["post_ln1"] = self._norm_stack(stack)
            blk["post_ln2"] = self._norm_stack(stack)
        if cfg.mla is not None:
            blk["mla"] = ML.init_mla(keys, cfg, stack)
        else:
            blk["attn"] = A.init_attn(keys, cfg, stack)
        if cfg.mixer == "mamba+attn":
            blk["mamba"] = M.init_mamba(keys, cfg, stack)
        if moe:
            blk["moe"] = MO.init_moe(keys, cfg, stack)
        else:
            blk["mlp"] = init_mlp(keys, cfg, cfg.d_model, cfg.d_ff, stack)
        return blk

    def _norm_stack(self, stack: tuple[int, ...]) -> dict:
        cfg = self.cfg
        p = {"scale": jnp.ones((*stack, cfg.d_model), jnp.float32)}
        if cfg.norm == "ln":
            p["bias"] = jnp.zeros((*stack, cfg.d_model), jnp.float32)
        return p

    def _init_rwkv_stack(self, keys: KeyGen, n: int) -> dict:
        cfg = self.cfg
        blk = {"ln1": self._norm_stack((n,)), "ln2": self._norm_stack((n,))}
        blk["rwkv"] = R.init_rwkv(keys, cfg, (n,))
        return blk

    def _init_vision(self, keys: KeyGen) -> dict:
        cfg = self.cfg
        period = cfg.cross_attn_period
        groups = cfg.n_layers // period
        n_self = period - 1
        return {
            "cross": {
                "ln": self._norm_stack((groups,)),
                "attn": A.init_cross_attn(keys, cfg, (groups,)),
                "ln2": self._norm_stack((groups,)),
                "mlp": init_mlp(keys, cfg, cfg.d_model, cfg.d_ff, (groups,)),
            },
            "layers": self._init_block(keys, moe=False,
                                       stack=(groups, n_self)),
        }

    # --------------------------------------------------------- forward ----

    def _windows(self) -> jax.Array:
        return jnp.asarray(self.cfg.layer_windows, jnp.int32)

    def _embed(self, p: Params, tokens: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = p["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
        if cfg.pos == "learned":
            S = tokens.shape[1]
            x = x + p["pos_embed"][:S].astype(x.dtype)
        return constrain_batch(x)

    def _head(self, p: Params, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        head = p["embed"].T if cfg.tie_embeddings else p["head"]
        logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
        logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
        if cfg.padded_vocab != cfg.vocab:
            logits = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab,
                               logits, -1e30)
        return logits

    def _block_fwd(self, blk: dict, x: jax.Array, window) -> tuple:
        """One (possibly scanned) decoder block. Returns (x, aux)."""
        cfg = self.cfg
        h = apply_norm(cfg, blk["ln1"], x)
        if cfg.mla is not None:
            mix = ML.mla_forward(cfg, blk["mla"], h)
        else:
            mix = A.attn_forward(cfg, blk["attn"], h, window=window)
        if cfg.mixer == "mamba+attn":
            mix = mix + M.mamba_forward(cfg, blk["mamba"], h)
        if cfg.post_norm:
            mix = apply_norm(cfg, blk["post_ln1"], mix)
        mix = checkpoint_name(mix, "mixer_out")
        x = x + mix
        h = apply_norm(cfg, blk["ln2"], x)
        if "moe" in blk:
            y, aux = MO.moe_ffn(cfg, blk["moe"], h)
        else:
            y = apply_mlp(cfg, blk["mlp"], h)
            aux = {"moe_load_balance": jnp.zeros((), jnp.float32),
                   "moe_z_loss": jnp.zeros((), jnp.float32)}
        if cfg.post_norm:
            y = apply_norm(cfg, blk["post_ln2"], y)
        y = checkpoint_name(y, "ffn_out")
        return x + y, aux

    def forward(self, p: Params, tokens: jax.Array,
                img: jax.Array | None = None) -> tuple[jax.Array, dict]:
        """Full-sequence forward to final hidden states (B, S, D)."""
        cfg = self.cfg
        x = self._embed(p, tokens)
        zero_aux = {"moe_load_balance": jnp.zeros((), jnp.float32),
                    "moe_z_loss": jnp.zeros((), jnp.float32)}

        if cfg.cross_attn_period:
            x = self._vision_fwd(p, x, img)
            aux = zero_aux
        elif cfg.mixer == "rwkv":
            def body(xc, blk):
                h = apply_norm(cfg, blk["ln1"], xc)
                xc = xc + R.rwkv_time_mix(cfg, blk["rwkv"], h)
                h = apply_norm(cfg, blk["ln2"], xc)
                xc = xc + R.rwkv_channel_mix(cfg, blk["rwkv"], h)
                return xc, None
            x, _ = lax.scan(self._maybe_remat(body), x, p["layers"])
            aux = zero_aux
        else:
            aux_tot = zero_aux
            windows = self._windows()
            n_dense = len(cfg.dense_layers) if cfg.moe is not None else 0
            for i in range(n_dense):
                x, _ = self._block_fwd(p["dense_prefix"][i], x,
                                       int(cfg.layer_windows[i]))

            def body(xc, inp):
                blk, win = inp
                xc, aux_l = self._block_fwd(blk, xc, win)
                return xc, aux_l

            x, auxs = lax.scan(self._maybe_remat(body), x,
                               (p["layers"], windows[n_dense:]))
            aux = {k: auxs[k].sum() for k in aux_tot}
        return apply_norm(cfg, p["final_norm"], x), aux

    def _vision_fwd(self, p: Params, x: jax.Array,
                    img: jax.Array) -> jax.Array:
        cfg = self.cfg
        if img is None:
            raise ValueError(f"{cfg.name} needs image embeddings")
        img = img.astype(x.dtype)

        def group(xc, inp):
            cross, selfs = inp
            # gated cross-attention block
            h = apply_norm(cfg, cross["ln"], xc)
            k, v = A.cross_kv(cfg, cross["attn"], img)
            xc = xc + A.cross_attn_forward(cfg, cross["attn"], h, k, v)
            h = apply_norm(cfg, cross["ln2"], xc)
            xc = xc + apply_mlp(cfg, cross["mlp"], h) \
                * jnp.tanh(cross["attn"]["gate"]).astype(xc.dtype)

            def self_layer(xi, blk):
                xi, _ = self._block_fwd(blk, xi, FULL_WINDOW)
                return xi, None

            xc, _ = lax.scan(self_layer, xc, selfs)
            return xc, None

        x, _ = lax.scan(self._maybe_remat(group), x,
                        (p["cross"], p["layers"]))
        return x

    # ------------------------------------------------------------ loss ----

    def loss(self, p: Params, batch: dict) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        x, aux = self.forward(p, batch["tokens"], img=batch.get("img"))
        head = p["embed"].T if cfg.tie_embeddings else p["head"]
        nll, w = chunked_ce_loss(x, head, batch["labels"],
                                 batch.get("mask"),
                                 final_softcap=cfg.final_softcap,
                                 valid_vocab=cfg.vocab)
        ce = nll / jnp.maximum(w, 1.0)
        total = ce
        metrics = {"ce": ce, "tokens": w}
        if cfg.moe is not None:
            total = total + cfg.moe.router_aux_weight * aux["moe_load_balance"]
            total = total + cfg.moe.router_z_weight * aux["moe_z_loss"]
            metrics.update(aux)
        metrics["loss"] = total
        return total, metrics

    # ---------------------------------------------------------- decode ----

    def init_cache(self, batch: int, max_seq: int) -> Cache:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.compute_dtype)
        cache: Cache = {"pos": jnp.zeros((), jnp.int32)}
        if cfg.mixer == "rwkv":
            cache["rwkv"] = R.init_rwkv_cache(cfg, cfg.n_layers, batch, dtype)
            return cache
        n_dense = len(cfg.dense_layers) if cfg.moe is not None else 0
        n_scan = cfg.n_layers - n_dense
        if cfg.cross_attn_period:
            period = cfg.cross_attn_period
            groups = cfg.n_layers // period
            cache["kv"] = A.init_kv_cache(cfg, groups * (period - 1), batch,
                                          max_seq, dtype)
            cache["cross_kv"] = {
                "k": jnp.zeros((groups, batch, cfg.n_kv_heads,
                                cfg.n_img_tokens, cfg.d_head), dtype),
                "v": jnp.zeros((groups, batch, cfg.n_kv_heads,
                                cfg.n_img_tokens, cfg.d_head), dtype)}
            return cache
        if cfg.mla is not None:
            cache["mla"] = ML.init_mla_cache(cfg, n_scan, batch, max_seq,
                                             dtype)
            if n_dense:
                cache["mla_dense"] = ML.init_mla_cache(cfg, n_dense, batch,
                                                       max_seq, dtype)
        else:
            cache["kv"] = A.init_kv_cache(cfg, n_scan, batch, max_seq, dtype)
            if n_dense:
                cache["kv_dense"] = A.init_kv_cache(cfg, n_dense, batch,
                                                    max_seq, dtype)
        if cfg.mixer == "mamba+attn":
            cache["mamba"] = M.init_mamba_cache(cfg, cfg.n_layers, batch,
                                                dtype)
        return cache

    def _block_decode(self, blk: dict, x, window, pos, kv=None, mla=None,
                      mamba=None, start=None):
        """One-layer decode. Returns (x, new_kv, new_mla, new_mamba).
        ``start`` (per-slot attention-window origins, token-level
        serving) only reaches the plain-attention path — the
        :attr:`decode_supports_start` gate keeps it None elsewhere."""
        cfg = self.cfg
        h = apply_norm(cfg, blk["ln1"], x)
        new_kv = new_mla = new_mamba = None
        if cfg.mla is not None:
            mix, ckv, kpe = ML.mla_decode(cfg, blk["mla"], h, mla[0], mla[1],
                                          pos)
            new_mla = (ckv, kpe)
        else:
            mix, ck, cv = A.attn_decode(cfg, blk["attn"], h, kv[0], kv[1],
                                        pos, window=window, start=start)
            new_kv = (ck, cv)
        if cfg.mixer == "mamba+attn":
            mo, ssm, win = M.mamba_decode(cfg, blk["mamba"], h, mamba[0],
                                          mamba[1])
            mix = mix + mo
            new_mamba = (ssm, win)
        if cfg.post_norm:
            mix = apply_norm(cfg, blk["post_ln1"], mix)
        x = x + mix
        h = apply_norm(cfg, blk["ln2"], x)
        if "moe" in blk:
            y, _ = MO.moe_ffn(cfg, blk["moe"], h)
        else:
            y = apply_mlp(cfg, blk["mlp"], h)
        if cfg.post_norm:
            y = apply_norm(cfg, blk["post_ln2"], y)
        return x + y, new_kv, new_mla, new_mamba

    @property
    def decode_supports_start(self) -> bool:
        """Whether :meth:`decode_step` honors a per-slot ``cache["start"]``
        vector (token-level continuous batching, ``repro.serve``). True
        only for plain rotary/positionless attention stacks: recurrent
        mixers (rwkv, mamba+attn) carry state that a mask cannot scope to
        one slot's window, cross-attention and MLA caches are not
        start-masked, and learned positional embeddings index absolute
        arena positions. ``ServeEngine(mode="auto")`` reads this to pick
        token-level vs cohort scheduling."""
        cfg = self.cfg
        return (cfg.mixer == "attn" and cfg.mla is None
                and not cfg.cross_attn_period and cfg.pos != "learned")

    def decode_step(self, p: Params, cache: Cache, tokens: jax.Array
                    ) -> tuple[jax.Array, Cache]:
        """tokens: (B, 1) -> (logits (B, 1, V), new cache). An optional
        ``cache["start"]`` (B,) vector scopes each batch row's attention
        to cache positions [start[b], pos] — see
        :attr:`decode_supports_start`."""
        cfg = self.cfg
        pos = cache["pos"]
        x = self._embed_at(p, tokens, pos)
        cache = dict(cache)

        if cfg.mixer == "rwkv":
            x, cache["rwkv"] = self._rwkv_decode(p, x, cache["rwkv"])
        elif cfg.cross_attn_period:
            x, cache = self._vision_decode(p, x, cache, pos)
        else:
            x, cache = self._stack_decode(p, x, cache, pos,
                                          start=cache.get("start"))
        x = apply_norm(cfg, p["final_norm"], x)
        logits = self._head(p, x)
        cache["pos"] = pos + 1
        return logits, cache

    def _embed_at(self, p: Params, tokens, pos):
        cfg = self.cfg
        x = p["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
        if cfg.pos == "learned":
            pe = lax.dynamic_slice_in_dim(p["pos_embed"], pos,
                                          tokens.shape[1], axis=0)
            x = x + pe.astype(x.dtype)
        return x

    def _rwkv_decode(self, p, x, rc):
        cfg = self.cfg

        def body(xc, inp):
            blk, wkv, prev_t, prev_c = inp
            h = apply_norm(cfg, blk["ln1"], xc)
            o, wkv, prev_t = R.rwkv_time_mix_decode(cfg, blk["rwkv"], h,
                                                    wkv, prev_t)
            xc = xc + o
            h = apply_norm(cfg, blk["ln2"], xc)
            o, prev_c = R.rwkv_channel_mix_decode(cfg, blk["rwkv"], h, prev_c)
            return xc + o, (wkv, prev_t, prev_c)

        x, (wkv, pt, pc) = lax.scan(
            body, x, (p["layers"], rc["wkv"], rc["prev_t"], rc["prev_c"]))
        return x, {"wkv": wkv, "prev_t": pt, "prev_c": pc}

    def _stack_decode(self, p, x, cache, pos, start=None):
        cfg = self.cfg
        windows = self._windows()
        n_dense = len(cfg.dense_layers) if cfg.moe is not None else 0
        use_mla = cfg.mla is not None

        for i in range(n_dense):
            blk = p["dense_prefix"][i]
            if use_mla:
                md = cache["mla_dense"]
                x, _, nm, _ = self._block_decode(
                    blk, x, int(cfg.layer_windows[i]), pos,
                    mla=(md["c_kv"][i], md["k_pe"][i]))
                cache["mla_dense"] = {
                    "c_kv": md["c_kv"].at[i].set(nm[0]),
                    "k_pe": md["k_pe"].at[i].set(nm[1])}
            else:
                kd = cache["kv_dense"]
                x, nk, _, _ = self._block_decode(
                    blk, x, int(cfg.layer_windows[i]), pos,
                    kv=(kd["k"][i], kd["v"][i]), start=start)
                cache["kv_dense"] = {"k": kd["k"].at[i].set(nk[0]),
                                     "v": kd["v"].at[i].set(nk[1])}

        has_mamba = cfg.mixer == "mamba+attn"

        def body(xc, inp):
            blk, win, kv_l, mla_l, mamba_l = inp
            xc, nkv, nmla, nmb = self._block_decode(
                blk, xc, win, pos, kv=kv_l, mla=mla_l, mamba=mamba_l,
                start=start)
            return xc, (nkv, nmla, nmb)

        if use_mla:
            mla_xs = (cache["mla"]["c_kv"], cache["mla"]["k_pe"])
            kv_xs = None
        else:
            kv_xs = (cache["kv"]["k"], cache["kv"]["v"])
            mla_xs = None
        mamba_xs = (cache["mamba"]["ssm"], cache["mamba"]["conv"]) \
            if has_mamba else None

        xs = (p["layers"], windows[n_dense:], kv_xs, mla_xs, mamba_xs)
        x, (nkv, nmla, nmb) = lax.scan(body, x, xs)
        if use_mla:
            cache["mla"] = {"c_kv": nmla[0], "k_pe": nmla[1]}
        else:
            cache["kv"] = {"k": nkv[0], "v": nkv[1]}
        if has_mamba:
            cache["mamba"] = {"ssm": nmb[0], "conv": nmb[1]}
        return x, cache

    def _vision_decode(self, p, x, cache, pos):
        cfg = self.cfg
        period = cfg.cross_attn_period
        n_self = period - 1
        kv = cache["kv"]
        groups = kv["k"].shape[0] // n_self
        kshape = kv["k"].shape
        k_g = kv["k"].reshape(groups, n_self, *kshape[1:])
        v_g = kv["v"].reshape(groups, n_self, *kshape[1:])

        def group(xc, inp):
            cross, selfs, ck, cv, kg, vg = inp
            h = apply_norm(cfg, cross["ln"], xc)
            xc = xc + A.cross_attn_forward(cfg, cross["attn"], h, ck, cv)
            h = apply_norm(cfg, cross["ln2"], xc)
            xc = xc + apply_mlp(cfg, cross["mlp"], h) \
                * jnp.tanh(cross["attn"]["gate"]).astype(xc.dtype)

            def self_layer(xi, inp2):
                blk, kl, vl = inp2
                xi, nkv, _, _ = self._block_decode(blk, xi, FULL_WINDOW, pos,
                                                   kv=(kl, vl))
                return xi, nkv

            xc, (nk, nv) = lax.scan(self_layer, xc, (selfs, kg, vg))
            return xc, (nk, nv)

        x, (nk, nv) = lax.scan(
            group, x, (p["cross"], p["layers"], cache["cross_kv"]["k"],
                       cache["cross_kv"]["v"], k_g, v_g))
        cache["kv"] = {"k": nk.reshape(kshape), "v": nv.reshape(kshape)}
        return x, cache

    # --------------------------------------------------------- prefill ----

    def prefill(self, p: Params, tokens: jax.Array, cache: Cache,
                img: jax.Array | None = None) -> tuple[jax.Array, Cache]:
        """Parallel prefill: full-sequence forward with cache writes.
        Returns (last-position logits (B, 1, V), filled cache)."""
        cfg = self.cfg
        B, S = tokens.shape
        x = self._embed(p, tokens)
        cache = dict(cache)

        if cfg.mixer == "rwkv":
            def body(xc, blk):
                h = apply_norm(cfg, blk["ln1"], xc)
                o, wkv, pt = R.rwkv_time_mix_prefill(cfg, blk["rwkv"], h)
                xc = xc + o
                h = apply_norm(cfg, blk["ln2"], xc)
                o, pc = R.rwkv_channel_mix_prefill(cfg, blk["rwkv"], h)
                return xc + o, (wkv, pt.astype(x.dtype), pc.astype(x.dtype))
            x, (wkv, pt, pc) = lax.scan(body, x, p["layers"])
            cache["rwkv"] = {"wkv": wkv, "prev_t": pt, "prev_c": pc}
        elif cfg.cross_attn_period:
            x, cache = self._vision_prefill(p, x, cache, img)
        else:
            x, cache = self._stack_prefill(p, x, cache)
        cache["pos"] = cache["pos"] + S
        x = apply_norm(cfg, p["final_norm"], x)
        logits = self._head(p, x[:, -1:])
        return logits, cache

    def _block_prefill(self, blk: dict, x, window, kv=None, mla=None,
                       mamba_on: bool = False):
        cfg = self.cfg
        h = apply_norm(cfg, blk["ln1"], x)
        new_kv = new_mla = new_mamba = None
        if cfg.mla is not None:
            mix, ckv, kpe = ML.mla_prefill(cfg, blk["mla"], h, mla[0], mla[1])
            new_mla = (ckv, kpe)
        else:
            mix, ck, cv = A.attn_prefill(cfg, blk["attn"], h, kv[0], kv[1],
                                         window=window)
            new_kv = (ck, cv)
        if mamba_on:
            mo, ssm, win = M.mamba_prefill(cfg, blk["mamba"], h)
            mix = mix + mo
            new_mamba = (ssm, win.astype(h.dtype))
        if cfg.post_norm:
            mix = apply_norm(cfg, blk["post_ln1"], mix)
        x = x + mix
        h = apply_norm(cfg, blk["ln2"], x)
        if "moe" in blk:
            y, _ = MO.moe_ffn(cfg, blk["moe"], h)
        else:
            y = apply_mlp(cfg, blk["mlp"], h)
        if cfg.post_norm:
            y = apply_norm(cfg, blk["post_ln2"], y)
        return x + y, new_kv, new_mla, new_mamba

    def _stack_prefill(self, p, x, cache):
        cfg = self.cfg
        windows = self._windows()
        n_dense = len(cfg.dense_layers) if cfg.moe is not None else 0
        use_mla = cfg.mla is not None
        has_mamba = cfg.mixer == "mamba+attn"

        for i in range(n_dense):
            blk = p["dense_prefix"][i]
            if use_mla:
                md = cache["mla_dense"]
                x, _, nm, _ = self._block_prefill(
                    blk, x, int(cfg.layer_windows[i]),
                    mla=(md["c_kv"][i], md["k_pe"][i]))
                cache["mla_dense"] = {"c_kv": md["c_kv"].at[i].set(nm[0]),
                                      "k_pe": md["k_pe"].at[i].set(nm[1])}
            else:
                kd = cache["kv_dense"]
                x, nk, _, _ = self._block_prefill(
                    blk, x, int(cfg.layer_windows[i]),
                    kv=(kd["k"][i], kd["v"][i]))
                cache["kv_dense"] = {"k": kd["k"].at[i].set(nk[0]),
                                     "v": kd["v"].at[i].set(nk[1])}

        def body(xc, inp):
            blk, win, kv_l, mla_l = inp
            xc, nkv, nmla, nmb = self._block_prefill(
                blk, xc, win, kv=kv_l, mla=mla_l, mamba_on=has_mamba)
            return xc, (nkv, nmla, nmb)

        kv_xs = None if use_mla else (cache["kv"]["k"], cache["kv"]["v"])
        mla_xs = (cache["mla"]["c_kv"], cache["mla"]["k_pe"]) if use_mla \
            else None
        x, (nkv, nmla, nmb) = lax.scan(
            body, x, (p["layers"], windows[n_dense:], kv_xs, mla_xs))
        if use_mla:
            cache["mla"] = {"c_kv": nmla[0], "k_pe": nmla[1]}
        else:
            cache["kv"] = {"k": nkv[0], "v": nkv[1]}
        if has_mamba:
            cache["mamba"] = {"ssm": nmb[0], "conv": nmb[1]}
        return x, cache

    def _vision_prefill(self, p, x, cache, img):
        cfg = self.cfg
        if img is None:
            raise ValueError(f"{cfg.name} needs image embeddings")
        img = img.astype(x.dtype)
        period = cfg.cross_attn_period
        n_self = period - 1
        kv = cache["kv"]
        kshape = kv["k"].shape
        groups = kshape[0] // n_self
        k_g = kv["k"].reshape(groups, n_self, *kshape[1:])
        v_g = kv["v"].reshape(groups, n_self, *kshape[1:])

        def group(xc, inp):
            cross, selfs, kg, vg = inp
            h = apply_norm(cfg, cross["ln"], xc)
            ck, cv = A.cross_kv(cfg, cross["attn"], img)
            xc = xc + A.cross_attn_forward(cfg, cross["attn"], h, ck, cv)
            h = apply_norm(cfg, cross["ln2"], xc)
            xc = xc + apply_mlp(cfg, cross["mlp"], h) \
                * jnp.tanh(cross["attn"]["gate"]).astype(xc.dtype)

            def self_layer(xi, inp2):
                blk, kl, vl = inp2
                xi, nkv, _, _ = self._block_prefill(blk, xi, FULL_WINDOW,
                                                    kv=(kl, vl))
                return xi, nkv

            xc, (nk, nv) = lax.scan(self_layer, xc, (selfs, kg, vg))
            return xc, (nk, nv, ck, cv)

        x, (nk, nv, ck, cv) = lax.scan(group, x,
                                       (p["cross"], p["layers"], k_g, v_g))
        cache["kv"] = {"k": nk.reshape(kshape), "v": nv.reshape(kshape)}
        cache["cross_kv"] = {"k": ck, "v": cv}
        return x, cache
