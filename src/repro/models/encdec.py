"""Encoder-decoder backbone (whisper-base). The conv/mel frontend is a stub
per the assignment: ``input_specs`` feeds precomputed frame embeddings
(B, enc_seq, d_model) straight into the encoder. Encoder = non-causal
self-attention stack; decoder = causal self-attention + cross-attention to
the encoder output. Cross K/V are computed once at prefill and cached.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from . import attention as A
from .common import (KeyGen, apply_mlp, apply_norm, chunked_ce_loss, dt,
                     embed_init, init_mlp, softcap, dense_init)
from .config import ArchConfig, FULL_WINDOW

Params = dict
Cache = dict


@dataclasses.dataclass(frozen=True)
class EncDecLM:
    cfg: ArchConfig
    remat: bool = False

    def _maybe_remat(self, body):
        import jax as _jax
        return _jax.checkpoint(body) if self.remat else body

    # ------------------------------------------------------------ init ----

    def _norm_stack(self, stack: tuple[int, ...]) -> dict:
        cfg = self.cfg
        p = {"scale": jnp.ones((*stack, cfg.d_model), jnp.float32)}
        if cfg.norm == "ln":
            p["bias"] = jnp.zeros((*stack, cfg.d_model), jnp.float32)
        return p

    def init(self, rng) -> Params:
        cfg = self.cfg
        keys = KeyGen(rng)
        dtype = dt(cfg)
        ne, nd = cfg.n_enc_layers, cfg.n_layers
        p: Params = {
            "embed": embed_init(keys(), (cfg.padded_vocab, cfg.d_model),
                                dtype),
            "dec_pos": embed_init(keys(), (cfg.max_seq, cfg.d_model), dtype),
            "enc_pos": embed_init(keys(), (cfg.enc_seq, cfg.d_model), dtype),
            "final_norm": self._norm_stack(()),
            "enc_final_norm": self._norm_stack(()),
            "encoder": {
                "ln1": self._norm_stack((ne,)),
                "attn": A.init_attn(keys, cfg, (ne,)),
                "ln2": self._norm_stack((ne,)),
                "mlp": init_mlp(keys, cfg, cfg.d_model, cfg.d_ff, (ne,)),
            },
            "decoder": {
                "ln1": self._norm_stack((nd,)),
                "attn": A.init_attn(keys, cfg, (nd,)),
                "ln_x": self._norm_stack((nd,)),
                "cross": A.init_cross_attn(keys, cfg, (nd,)),
                "ln2": self._norm_stack((nd,)),
                "mlp": init_mlp(keys, cfg, cfg.d_model, cfg.d_ff, (nd,)),
            },
        }
        if not cfg.tie_embeddings:
            p["head"] = dense_init(keys(), (cfg.d_model, cfg.padded_vocab),
                                   dtype)
        return p

    # ---------------------------------------------------------- encode ----

    def encode(self, p: Params, frames: jax.Array) -> jax.Array:
        """frames: (B, T, D) stubbed frontend embeddings -> memory."""
        cfg = self.cfg
        x = frames.astype(jnp.dtype(cfg.compute_dtype))
        x = x + p["enc_pos"][:x.shape[1]].astype(x.dtype)

        def body(xc, blk):
            h = apply_norm(cfg, blk["ln1"], xc)
            xc = xc + A.attn_forward(cfg, blk["attn"], h, causal=False)
            h = apply_norm(cfg, blk["ln2"], xc)
            return xc + apply_mlp(cfg, blk["mlp"], h), None

        x, _ = lax.scan(self._maybe_remat(body), x, p["encoder"])
        return apply_norm(cfg, p["enc_final_norm"], x)

    # --------------------------------------------------------- forward ----

    def _dec_embed(self, p, tokens, pos0=0):
        cfg = self.cfg
        x = p["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
        S = tokens.shape[1]
        if isinstance(pos0, int) and pos0 == 0:
            pe = p["dec_pos"][:S]
        else:
            pe = lax.dynamic_slice_in_dim(p["dec_pos"], pos0, S, axis=0)
        return x + pe.astype(x.dtype)

    def _head(self, p, x):
        cfg = self.cfg
        head = p["embed"].T if cfg.tie_embeddings else p["head"]
        logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
        logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
        if cfg.padded_vocab != cfg.vocab:
            logits = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab,
                               logits, -1e30)
        return logits

    def forward(self, p: Params, tokens: jax.Array,
                frames: jax.Array) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        memory = self.encode(p, frames)
        x = self._dec_embed(p, tokens)

        def body(xc, blk):
            h = apply_norm(cfg, blk["ln1"], xc)
            xc = xc + A.attn_forward(cfg, blk["attn"], h, causal=True)
            h = apply_norm(cfg, blk["ln_x"], xc)
            ck, cv = A.cross_kv(cfg, blk["cross"], memory)
            xc = xc + A.cross_attn_forward(cfg, blk["cross"], h, ck, cv,
                                           gated=False)
            h = apply_norm(cfg, blk["ln2"], xc)
            return xc + apply_mlp(cfg, blk["mlp"], h), None

        x, _ = lax.scan(self._maybe_remat(body), x, p["decoder"])
        x = apply_norm(cfg, p["final_norm"], x)
        aux = {"moe_load_balance": jnp.zeros((), jnp.float32),
               "moe_z_loss": jnp.zeros((), jnp.float32)}
        return x, aux

    def loss(self, p: Params, batch: dict) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        x, _ = self.forward(p, batch["tokens"], batch["frames"])
        head = p["embed"].T if cfg.tie_embeddings else p["head"]
        nll, w = chunked_ce_loss(x, head, batch["labels"],
                                 batch.get("mask"), valid_vocab=cfg.vocab)
        ce = nll / jnp.maximum(w, 1.0)
        return ce, {"ce": ce, "loss": ce, "tokens": w}

    # ---------------------------------------------------------- decode ----

    def init_cache(self, batch: int, max_seq: int) -> Cache:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.compute_dtype)
        nd = cfg.n_layers
        return {
            "pos": jnp.zeros((), jnp.int32),
            "kv": A.init_kv_cache(cfg, nd, batch, max_seq, dtype),
            "cross_kv": {
                "k": jnp.zeros((nd, batch, cfg.n_kv_heads, cfg.enc_seq,
                                cfg.d_head), dtype),
                "v": jnp.zeros((nd, batch, cfg.n_kv_heads, cfg.enc_seq,
                                cfg.d_head), dtype)},
        }

    def prefill(self, p: Params, tokens: jax.Array, cache: Cache,
                frames: jax.Array) -> tuple[jax.Array, Cache]:
        cfg = self.cfg
        memory = self.encode(p, frames)
        x = self._dec_embed(p, tokens)
        cache = dict(cache)

        def body(xc, inp):
            blk, kl, vl = inp
            h = apply_norm(cfg, blk["ln1"], xc)
            o, nk, nv = A.attn_prefill(cfg, blk["attn"], h, kl, vl)
            xc = xc + o
            h = apply_norm(cfg, blk["ln_x"], xc)
            ck, cv = A.cross_kv(cfg, blk["cross"], memory)
            xc = xc + A.cross_attn_forward(cfg, blk["cross"], h, ck, cv,
                                           gated=False)
            h = apply_norm(cfg, blk["ln2"], xc)
            return xc + apply_mlp(cfg, blk["mlp"], h), (nk, nv, ck, cv)

        kv = cache["kv"]
        x, (nk, nv, ck, cv) = lax.scan(body, x,
                                       (p["decoder"], kv["k"], kv["v"]))
        cache["kv"] = {"k": nk, "v": nv}
        cache["cross_kv"] = {"k": ck, "v": cv}
        cache["pos"] = cache["pos"] + tokens.shape[1]
        x = apply_norm(cfg, p["final_norm"], x)
        return self._head(p, x[:, -1:]), cache

    def decode_step(self, p: Params, cache: Cache, tokens: jax.Array
                    ) -> tuple[jax.Array, Cache]:
        cfg = self.cfg
        pos = cache["pos"]
        x = self._dec_embed(p, tokens, pos0=pos)
        cache = dict(cache)

        def body(xc, inp):
            blk, kl, vl, ck, cv = inp
            h = apply_norm(cfg, blk["ln1"], xc)
            o, nk, nv = A.attn_decode(cfg, blk["attn"], h, kl, vl, pos)
            xc = xc + o
            h = apply_norm(cfg, blk["ln_x"], xc)
            xc = xc + A.cross_attn_forward(cfg, blk["cross"], h, ck, cv,
                                           gated=False)
            h = apply_norm(cfg, blk["ln2"], xc)
            return xc + apply_mlp(cfg, blk["mlp"], h), (nk, nv)

        kv, xkv = cache["kv"], cache["cross_kv"]
        x, (nk, nv) = lax.scan(
            body, x, (p["decoder"], kv["k"], kv["v"], xkv["k"], xkv["v"]))
        cache["kv"] = {"k": nk, "v": nv}
        cache["pos"] = pos + 1
        x = apply_norm(cfg, p["final_norm"], x)
        return self._head(p, x), cache
