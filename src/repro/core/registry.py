"""Global kernel registry.

Kernels register their builders at import time; the tuner CLI and the replay
machinery look kernels up by name (captures store only the kernel name).
"""

from __future__ import annotations

import importlib

from .builder import KernelBuilder

_REGISTRY: dict[str, KernelBuilder] = {}

# Modules that define built-in kernels (imported lazily so `repro.core` does
# not pull Pallas in unless needed).
_BUILTIN_KERNEL_MODULES = (
    "repro.kernels.advec_u",
    "repro.kernels.diff_uvw",
    "repro.kernels.matmul",
    "repro.kernels.flash_attention",
)


def register(builder: KernelBuilder) -> KernelBuilder:
    if builder.name in _REGISTRY:
        # idempotent re-registration from module reload
        existing = _REGISTRY[builder.name]
        if existing is not builder and existing.source != builder.source:
            raise ValueError(f"kernel name collision: {builder.name!r}")
    _REGISTRY[builder.name] = builder
    return builder


def unregister(name: str) -> None:
    """Remove a kernel registration (no-op when absent). For tests and
    hosts that register synthetic kernels and must leave registry-wide
    iteration (``all_kernels``) clean afterwards."""
    _REGISTRY.pop(name, None)


def load_builtin_kernels() -> None:
    for mod in _BUILTIN_KERNEL_MODULES:
        importlib.import_module(mod)


def get_kernel(name: str) -> KernelBuilder:
    if name not in _REGISTRY:
        load_builtin_kernels()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown kernel {name!r}; registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_kernels() -> dict[str, KernelBuilder]:
    load_builtin_kernels()
    return dict(_REGISTRY)
