"""Tunable-parameter configuration spaces (paper §4.1).

A :class:`ConfigSpace` holds named tunable parameters with finite value sets,
plus boolean *restrictions* over the joint space — the same model Kernel
Launcher / Kernel Tuner use. Restrictions may be Python callables
``config -> bool`` or strings evaluated with the config as the namespace
(mirroring the paper's "boolean expressions").
"""

from __future__ import annotations

import hashlib
import itertools
import json
import math
from dataclasses import dataclass
from typing import Any, Callable, Iterator, Sequence

import numpy as np

Config = dict[str, Any]


@dataclass(frozen=True)
class TunableParam:
    """One tunable parameter: a name, its allowed values, and a default."""

    name: str
    values: tuple
    default: Any

    def __post_init__(self):
        if len(self.values) == 0:
            raise ValueError(f"parameter {self.name!r} has no values")
        if self.default not in self.values:
            raise ValueError(
                f"default {self.default!r} for {self.name!r} not in values"
            )

    def index_of(self, value) -> int:
        return self.values.index(value)


class ConfigSpace:
    """The joint (cartesian) space of all tunable parameters + restrictions."""

    def __init__(self) -> None:
        self._params: dict[str, TunableParam] = {}
        self._restrictions: list[Callable[[Config], bool]] = []
        self._restriction_srcs: list[str] = []

    # -- construction -------------------------------------------------------

    def tune(self, name: str, values: Sequence, default=None) -> TunableParam:
        """Declare a tunable parameter (paper Listing 3, ``builder.tune``)."""
        if name in self._params:
            raise ValueError(f"duplicate tunable parameter {name!r}")
        values = tuple(values)
        if default is None:
            default = values[0]
        p = TunableParam(name, values, default)
        self._params[name] = p
        return p

    def restrict(self, expr: str | Callable[[Config], bool]) -> None:
        """Add a search-space restriction (boolean expression or callable)."""
        if callable(expr):
            self._restrictions.append(expr)
            self._restriction_srcs.append(getattr(expr, "__name__", "<fn>"))
        else:
            code = compile(expr, "<restriction>", "eval")

            def _check(config: Config, _code=code) -> bool:
                return bool(eval(_code, {"__builtins__": {}, "min": min,
                                         "max": max, "abs": abs}, dict(config)))

            self._restrictions.append(_check)
            self._restriction_srcs.append(expr)

    # -- introspection ------------------------------------------------------

    @property
    def params(self) -> dict[str, TunableParam]:
        return dict(self._params)

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(self._params)

    def default_config(self) -> Config:
        return {p.name: p.default for p in self._params.values()}

    def cardinality(self) -> int:
        """Size of the unrestricted cartesian space."""
        return math.prod(len(p.values) for p in self._params.values())

    def is_valid(self, config: Config) -> bool:
        for name, p in self._params.items():
            if name not in config or config[name] not in p.values:
                return False
        return all(r(config) for r in self._restrictions)

    def check(self, config: Config) -> None:
        if not self.is_valid(config):
            raise ValueError(f"invalid config for space: {config}")

    # -- iteration / sampling ----------------------------------------------

    def enumerate(self, limit: int | None = None) -> Iterator[Config]:
        """Yield valid configs in lexicographic order (optionally capped)."""
        names = list(self._params)
        count = 0
        for combo in itertools.product(
            *(p.values for p in self._params.values())
        ):
            cfg = dict(zip(names, combo))
            if all(r(cfg) for r in self._restrictions):
                yield cfg
                count += 1
                if limit is not None and count >= limit:
                    return

    def valid_cardinality(self, cap: int = 1_000_000) -> int:
        n = 0
        for _ in self.enumerate(limit=cap):
            n += 1
        return n

    def sample(self, rng: np.random.Generator, n: int = 1,
               max_tries: int = 10_000) -> list[Config]:
        """Rejection-sample ``n`` valid configs."""
        out: list[Config] = []
        tries = 0
        names = list(self._params)
        while len(out) < n and tries < max_tries * n:
            cfg = {
                name: p.values[int(rng.integers(len(p.values)))]
                for name, p in self._params.items()
            }
            tries += 1
            if all(r(cfg) for r in self._restrictions):
                out.append(cfg)
        if len(out) < n:
            raise RuntimeError(
                f"could not sample {n} valid configs in {tries} tries "
                f"({len(names)} params)"
            )
        return out

    def neighbor(self, config: Config, rng: np.random.Generator,
                 max_tries: int = 200) -> Config:
        """Random single-parameter mutation (for local-search strategies)."""
        names = list(self._params)
        for _ in range(max_tries):
            cfg = dict(config)
            name = names[int(rng.integers(len(names)))]
            p = self._params[name]
            if len(p.values) == 1:
                continue
            cur = p.index_of(cfg[name])
            # move to an adjacent value preferentially, else any other value
            if rng.random() < 0.7:
                step = -1 if rng.random() < 0.5 else 1
                idx = min(max(cur + step, 0), len(p.values) - 1)
            else:
                idx = int(rng.integers(len(p.values)))
            if idx == cur:
                continue
            cfg[name] = p.values[idx]
            if all(r(cfg) for r in self._restrictions):
                return cfg
        return dict(config)

    # -- numeric encoding (for model-based strategies) ----------------------

    def to_unit(self, config: Config) -> np.ndarray:
        """Encode a config as a point in [0,1]^d (value-index scaled)."""
        vec = np.zeros(len(self._params), dtype=np.float64)
        for i, (name, p) in enumerate(self._params.items()):
            hi = max(len(p.values) - 1, 1)
            vec[i] = p.index_of(config[name]) / hi
        return vec

    def from_unit(self, vec: np.ndarray) -> Config:
        cfg: Config = {}
        for i, (name, p) in enumerate(self._params.items()):
            hi = max(len(p.values) - 1, 1)
            idx = int(round(float(np.clip(vec[i], 0.0, 1.0)) * hi))
            cfg[name] = p.values[idx]
        return cfg

    def freeze(self, config: Config) -> tuple:
        """Hashable canonical form of a config."""
        return tuple((k, config[k]) for k in self._params)

    # -- sharding (fleet job partitioning) -----------------------------------

    def config_hash(self, config: Config) -> int:
        """Stable 64-bit hash of a config's canonical JSON form.

        ``hash()`` is process-randomized; shard membership must agree
        between the coordinator that planned a job and every worker that
        claims one of its shards, across processes, hosts and runs.
        """
        body = json.dumps([[k, config[k]] for k in self._params],
                          default=str)
        return int.from_bytes(hashlib.sha256(body.encode()).digest()[:8],
                              "little")

    def shard(self, index: int, n_shards: int) -> "ConfigSpace":
        """Deterministic partition member ``index`` of ``n_shards``.

        Returns a new space with the same parameters and restrictions plus
        a membership restriction: a config belongs to exactly one shard
        (``config_hash % n_shards``), so the shards are disjoint and their
        union is exactly this space's valid set. Workers tuning different
        shards of one job therefore never duplicate an evaluation, and
        re-planning the same job yields byte-identical shards.
        """
        if not 0 <= index < n_shards:
            raise ValueError(f"shard index {index} not in [0, {n_shards})")
        sub = ConfigSpace()
        for p in self._params.values():
            sub.tune(p.name, p.values, p.default)
        for fn, src in zip(self._restrictions, self._restriction_srcs):
            sub._restrictions.append(fn)
            sub._restriction_srcs.append(src)
        if n_shards > 1:
            def _member(config: Config) -> bool:
                return self.config_hash(config) % n_shards == index
            sub._restrictions.append(_member)
            sub._restriction_srcs.append(f"shard {index}/{n_shards}")
        return sub

    def __repr__(self) -> str:  # pragma: no cover
        return (f"ConfigSpace({list(self._params)}, "
                f"|space|={self.cardinality()}, "
                f"restrictions={self._restriction_srcs})")
