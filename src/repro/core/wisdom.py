"""Wisdom files (paper §4.4) and runtime selection heuristic (paper §4.5).

A wisdom file is a human-readable JSON document per kernel holding one record
per tuning session: the best configuration found for one (device, problem
size, dtype) *scenario*, plus provenance. Re-tuning appends/refreshes records.

Selection heuristic — the paper's §4.5 list, extended with dtype as a
scenario component (our precision analogue of the paper's float/double):

  1. record matching device kind AND problem size (preferring same dtype);
  2. else, same device kind, problem size closest in Euclidean distance;
  3. else, same device *family*, closest problem size;
  4. else, any record, closest problem size;
  5. else (empty/missing wisdom), the default configuration.
"""

from __future__ import annotations

import datetime
import getpass
import json
import math
import os
import platform
import socket
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Any, Sequence

import jax

from .device import get_device

WISDOM_VERSION = 1
WISDOM_DIR_ENV = "KERNEL_LAUNCHER_WISDOM_DIR"


def default_wisdom_dir() -> Path:
    return Path(os.environ.get(WISDOM_DIR_ENV, Path.cwd() / "wisdom"))


def make_provenance(strategy: str = "", evals: int = 0,
                    objective: str = "") -> dict:
    """Provenance block stored with each record (paper §4.4)."""
    try:
        user = getpass.getuser()
    except Exception:  # pragma: no cover
        user = "unknown"
    return {
        "date": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "host": socket.gethostname(),
        "user": user,
        "platform": platform.platform(),
        "jax_version": jax.__version__,
        "strategy": strategy,
        "evaluations": evals,
        "objective": objective,
    }


@dataclass
class WisdomRecord:
    device_kind: str
    device_family: str
    problem_size: tuple[int, ...]
    dtype: str
    config: dict[str, Any]
    score_us: float                      # best objective value (lower=better)
    provenance: dict = field(default_factory=dict)

    def to_json(self) -> dict:
        d = asdict(self)
        d["problem_size"] = list(self.problem_size)
        return d

    @staticmethod
    def from_json(d: dict) -> "WisdomRecord":
        return WisdomRecord(
            device_kind=d["device_kind"],
            device_family=d["device_family"],
            problem_size=tuple(int(x) for x in d["problem_size"]),
            dtype=d["dtype"],
            config=dict(d["config"]),
            score_us=float(d["score_us"]),
            provenance=dict(d.get("provenance", {})),
        )

    def scenario(self) -> tuple:
        return (self.device_kind, self.problem_size, self.dtype)


def _distance(a: Sequence[int], b: Sequence[int]) -> float:
    """Scale-normalized distance between problem sizes.

    Euclidean distance over per-dimension log2 ratios rather than raw
    extents: a 4096-wide axis would otherwise drown out every other
    dimension in the tier 2–4 nearest-scenario comparisons, making e.g. a
    2x change on a size-8 axis (which matters enormously for tiling) count
    for nothing next to a 5% change on the 4096 axis. Log ratios weigh
    relative change equally per dimension. Missing dimensions (rank
    mismatch) are padded with 1, i.e. treated as a degenerate axis.
    """
    n = max(len(a), len(b))
    a = tuple(a) + (1,) * (n - len(a))
    b = tuple(b) + (1,) * (n - len(b))
    return math.sqrt(sum(
        math.log2(max(x, 1) / max(y, 1)) ** 2 for x, y in zip(a, b)))


class Wisdom:
    """All tuning results for one kernel (one file per kernel, paper §4.4)."""

    def __init__(self, kernel_name: str,
                 records: list[WisdomRecord] | None = None):
        self.kernel_name = kernel_name
        self.records: list[WisdomRecord] = list(records or [])

    # -- persistence ---------------------------------------------------------

    @staticmethod
    def path_for(kernel_name: str, wisdom_dir: Path | str | None = None) -> Path:
        d = Path(wisdom_dir) if wisdom_dir is not None else default_wisdom_dir()
        return d / f"{kernel_name}.wisdom.json"

    @staticmethod
    def load(kernel_name: str, wisdom_dir: Path | str | None = None) -> "Wisdom":
        path = Wisdom.path_for(kernel_name, wisdom_dir)
        if not path.exists():
            return Wisdom(kernel_name)
        with open(path) as f:
            doc = json.load(f)
        if doc.get("kernel") != kernel_name:
            raise ValueError(
                f"wisdom file {path} is for kernel {doc.get('kernel')!r}, "
                f"not {kernel_name!r}")
        recs = [WisdomRecord.from_json(r) for r in doc.get("records", [])]
        return Wisdom(kernel_name, recs)

    def save(self, wisdom_dir: Path | str | None = None) -> Path:
        path = Wisdom.path_for(self.kernel_name, wisdom_dir)
        path.parent.mkdir(parents=True, exist_ok=True)
        doc = {
            "kernel": self.kernel_name,
            "version": WISDOM_VERSION,
            "records": [r.to_json() for r in self.records],
        }
        tmp = path.with_suffix(".tmp")
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        os.replace(tmp, path)  # atomic
        return path

    # -- mutation ------------------------------------------------------------

    def add(self, record: WisdomRecord, keep_best: bool = True) -> None:
        """Add a tuning result. If a record for the same scenario exists and
        ``keep_best``, keep whichever scored better (re-tuning semantics)."""
        if keep_best:
            for i, r in enumerate(self.records):
                if r.scenario() == record.scenario():
                    if record.score_us < r.score_us:
                        self.records[i] = record
                    return
        self.records.append(record)

    # -- selection (paper §4.5) ----------------------------------------------

    def select(self, device_kind: str, problem_size: Sequence[int],
               dtype: str, default_config: dict) -> tuple[dict, str]:
        """Pick a config for a scenario. Returns (config, match_tier)."""
        problem = tuple(int(x) for x in problem_size)
        family = get_device(device_kind).family

        def best(cands: list[WisdomRecord]) -> WisdomRecord | None:
            if not cands:
                return None
            return min(cands, key=lambda r: (_distance(r.problem_size, problem),
                                             r.score_us))

        tiers: list[tuple[str, list[WisdomRecord]]] = []
        exact = [r for r in self.records
                 if r.device_kind == device_kind
                 and r.problem_size == problem and r.dtype == dtype]
        tiers.append(("exact", exact))
        same_dev = [r for r in self.records
                    if r.device_kind == device_kind and r.dtype == dtype]
        tiers.append(("device+dtype", same_dev))
        same_dev_any = [r for r in self.records if r.device_kind == device_kind]
        tiers.append(("device", same_dev_any))
        fam = [r for r in self.records
               if r.device_family == family and r.dtype == dtype]
        tiers.append(("family+dtype", fam))
        fam_any = [r for r in self.records if r.device_family == family]
        tiers.append(("family", fam_any))
        any_dtype = [r for r in self.records if r.dtype == dtype]
        tiers.append(("any+dtype", any_dtype))
        tiers.append(("any", list(self.records)))

        for tier_name, cands in tiers:
            rec = best(cands)
            if rec is not None:
                return dict(rec.config), tier_name
        return dict(default_config), "default"

    def __len__(self) -> int:
        return len(self.records)

    def __repr__(self) -> str:  # pragma: no cover
        return f"Wisdom({self.kernel_name!r}, {len(self.records)} records)"
