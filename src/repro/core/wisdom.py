"""Wisdom files (paper §4.4) and runtime selection heuristic (paper §4.5).

A wisdom file is a human-readable JSON document per kernel holding one record
per tuning session: the best configuration found for one (device, problem
size, dtype) *scenario*, plus provenance. Re-tuning appends/refreshes records.

Beyond the paper, the format is *versioned* (``WISDOM_VERSION``, with a
migration path for old files and a loud refusal of files from the future)
and each record carries a *lineage*: the provenance blocks of every record
it superseded, locally or during a fleet merge (``repro.distrib``). See
``docs/wisdom-format.md`` for the field-by-field schema.

Selection heuristic — the paper's §4.5 list, extended with dtype as a
scenario component (our precision analogue of the paper's float/double)
and with a *transfer* tier for cross-device predictions
(``repro.transfer``):

  1. measured record matching device kind AND problem size (preferring
     same dtype);
  2. else, a *transferred* record for this device kind and dtype whose
     confidence clears ``TRANSFER_MIN_CONFIDENCE`` (closest problem
     size) — predictions outrank scenario-distance fallback but never
     shadow a measurement;
  3. else, same device kind, problem size closest in Euclidean distance;
  4. else, same device *family*, closest problem size;
  5. else, any measured record, closest problem size;
  6. else (empty/missing wisdom), the default configuration.
"""

from __future__ import annotations

import datetime
import getpass
import hashlib
import json
import math
import os
import platform
import socket
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Any, Sequence

import jax

from .device import get_device
from .scenario import SELECT_TIERS

# The canonical tier names live in core/scenario.py (shared with the
# online tracker's MISS_TIERS/HIT_TIERS and the observability report);
# select() produces exactly these, in exactly this order.
(T_EXACT, T_TRANSFER, T_DEVICE_DTYPE, T_DEVICE, T_FAMILY_DTYPE, T_FAMILY,
 T_ANY_DTYPE, T_ANY, T_DEFAULT) = SELECT_TIERS

#: Current on-disk schema version. v1: unversioned-or-``version: 1`` files
#: without lineage; v2 adds per-record ``lineage`` (provenance history).
WISDOM_VERSION = 2
WISDOM_DIR_ENV = "KERNEL_LAUNCHER_WISDOM_DIR"

#: Lineage entries kept per record after a merge (oldest dropped first).
LINEAGE_MAX = 16

#: Minimum transfer confidence a predicted record needs before ``select``
#: will serve it. Calibrated against the shipped tpu-v5e -> tpu-v4 pair
#: (well above threshold) and tpu -> cpu (far below): see
#: ``repro.transfer.confidence`` and docs/transfer-tuning.md.
TRANSFER_MIN_CONFIDENCE = 0.30


class WisdomVersionError(ValueError):
    """A wisdom file declares a schema version this build cannot handle.

    Raised for files from the *future* (version > ``WISDOM_VERSION``):
    silently dropping or partially reading them could discard or corrupt
    fleet tuning results, so loading refuses loudly instead.
    """


def default_wisdom_dir() -> Path:
    return Path(os.environ.get(WISDOM_DIR_ENV, Path.cwd() / "wisdom"))


def make_provenance(strategy: str = "", evals: int = 0,
                    objective: str = "") -> dict:
    """Provenance block stored with each record (paper §4.4).

    Every host lookup degrades to ``"unknown"`` instead of raising:
    sandboxed containers routinely lack a passwd entry (``getpass``), a
    resolvable hostname (``socket``), or readable platform metadata, and a
    wisdom write must never crash over missing provenance cosmetics.
    """
    try:
        user = getpass.getuser()
    except Exception:  # pragma: no cover
        user = "unknown"
    try:
        host = socket.gethostname()
    except Exception:  # pragma: no cover
        host = "unknown"
    try:
        plat = platform.platform()
    except Exception:  # pragma: no cover
        plat = "unknown"
    return {
        "date": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "host": host,
        "user": user,
        "platform": plat,
        "jax_version": jax.__version__,
        "strategy": strategy,
        "evaluations": evals,
        "objective": objective,
    }


def make_fleet_provenance(strategy: str, evals: int, objective: str,
                          job_id: str, n_shards: int,
                          round_: int = 0) -> dict:
    """Provenance for a coordinator-assembled fleet tuning record.

    Deliberately *deterministic* — no timestamp, host, or user: a fleet
    job's result is a pure function of (demand, config space, cost model),
    and any coordinator assembling the same shard results must produce a
    byte-identical record (``record_id`` hashes provenance). The job id
    and shard count say where the number came from instead.
    """
    return {
        "source": "fleet",
        "strategy": strategy,
        "evaluations": int(evals),
        "objective": objective,
        "job": job_id,
        "shards": int(n_shards),
        "round": int(round_),
        "jax_version": jax.__version__,
    }


def make_transfer_provenance(source_device: str, source_entries: int,
                             confidence: float, predicted_us: float,
                             predictor: str = "ridge+capability",
                             round_: int = 0,
                             backends: str = "") -> dict:
    """Provenance for a cross-device *transferred* record (repro.transfer).

    Deterministic like fleet provenance — no timestamp, host, or user: a
    transferred record is a pure function of (source dataset, capability
    model, predictor), so any host transferring the same recorded space
    to the same target produces a byte-identical record. ``confidence``
    is the gate ``Wisdom.select`` applies before serving the prediction;
    ``predicted_us`` is what the fleet verification loop compares
    observed serve latency against. ``backends`` (e.g. ``"tpu->gpu"``)
    marks a cross-backend prediction — its confidence already carries
    the backend-mismatch penalty; omitted (and absent from the dict, to
    keep pre-GPU records byte-identical) for same-backend transfers.
    """
    prov = {
        "source": "transfer",
        "source_device": source_device,
        "source_entries": int(source_entries),
        "confidence": round(float(confidence), 6),
        "predicted_us": round(float(predicted_us), 6),
        "predictor": predictor,
        "round": int(round_),
        "jax_version": jax.__version__,
    }
    if backends:
        prov["backends"] = backends
    return prov


def merge_lineage(*records: "WisdomRecord", extra: Sequence[dict] = ()
                  ) -> list[dict]:
    """Combine the provenance history of ``records`` into one lineage list.

    Collects every record's own provenance plus its existing lineage,
    deduplicates, orders chronologically (ties broken by canonical JSON so
    the result is identical regardless of merge order), and keeps the most
    recent ``LINEAGE_MAX`` entries.
    """
    entries: list[dict] = []
    for r in records:
        if r.provenance:
            entries.append(dict(r.provenance))
        entries.extend(dict(e) for e in r.lineage)
    entries.extend(dict(e) for e in extra)
    seen: set[str] = set()
    unique: list[dict] = []
    for e in entries:
        key = json.dumps(e, sort_keys=True)
        if key not in seen:
            seen.add(key)
            unique.append(e)
    unique.sort(key=lambda e: (str(e.get("date", "")),
                               json.dumps(e, sort_keys=True)))
    return unique[-LINEAGE_MAX:]


@dataclass
class WisdomRecord:
    device_kind: str
    device_family: str
    problem_size: tuple[int, ...]
    dtype: str
    config: dict[str, Any]
    score_us: float                      # best objective value (lower=better)
    provenance: dict = field(default_factory=dict)
    #: Provenance blocks of records this one superseded (re-tune keep-best,
    #: fleet merge). Chronological, capped at LINEAGE_MAX. Schema v2.
    lineage: list[dict] = field(default_factory=list)

    def to_json(self) -> dict:
        d = asdict(self)
        d["problem_size"] = list(self.problem_size)
        return d

    @staticmethod
    def from_json(d: dict) -> "WisdomRecord":
        return WisdomRecord(
            device_kind=d["device_kind"],
            device_family=d["device_family"],
            problem_size=tuple(int(x) for x in d["problem_size"]),
            dtype=d["dtype"],
            config=dict(d["config"]),
            score_us=float(d["score_us"]),
            provenance=dict(d.get("provenance", {})),
            lineage=[dict(e) for e in d.get("lineage", [])],
        )

    def scenario(self) -> tuple:
        return (self.device_kind, self.problem_size, self.dtype)

    def evaluations(self) -> int:
        """Tuning-effort weight used for statistical tie-breaks in merges."""
        try:
            return int(self.provenance.get("evaluations", 0))
        except (TypeError, ValueError):
            return 0

    def is_transferred(self) -> bool:
        """True for records *predicted* by the cross-device transfer layer
        rather than measured. Transferred records live in their own
        selection tier (below exact, above scenario-distance fallback)
        and always lose to a measured record for the same scenario."""
        return self.provenance.get("source") == "transfer"

    def transfer_confidence(self) -> float:
        """The transfer predictor's confidence in [0, 1] (0.0 for
        measured records and malformed provenance): the quantity
        ``select`` gates on before serving a transferred record."""
        try:
            return float(self.provenance.get("confidence", 0.0))
        except (TypeError, ValueError):
            return 0.0

    def oracle_verified(self) -> dict | None:
        """The correctness-oracle provenance stamp, or None.

        Records promoted through a :class:`repro.sandbox.gate.OracleGate`
        carry ``provenance["verified"] = {"rtol", "atol", "ref"}`` — the
        dtype-aware tolerances the config's output met against the named
        reference oracle. Absent (None) means the record predates the
        gate or its kernel was unverifiable."""
        v = self.provenance.get("verified")
        return dict(v) if isinstance(v, dict) else None

    def record_id(self) -> str:
        """Stable content identity of this tuning result.

        Hash of scenario + config + score + provenance (lineage excluded:
        two hosts holding the same result with different merge histories
        still refer to the same record). Used for cross-store deduplication
        and as the last, fully deterministic merge tie-break. Cached — the
        identity fields are never mutated after construction (only
        ``lineage`` is, and it does not participate).
        """
        cached = self.__dict__.get("_record_id")
        if cached is not None:
            return cached
        body = json.dumps({
            "device_kind": self.device_kind,
            "device_family": self.device_family,
            "problem_size": list(self.problem_size),
            "dtype": self.dtype,
            "config": self.config,
            "score_us": self.score_us,
            "provenance": self.provenance,
        }, sort_keys=True)
        rid = hashlib.sha256(body.encode()).hexdigest()[:16]
        self.__dict__["_record_id"] = rid
        return rid


def _distance(a: Sequence[int], b: Sequence[int]) -> float:
    """Scale-normalized distance between problem sizes.

    Euclidean distance over per-dimension log2 ratios rather than raw
    extents: a 4096-wide axis would otherwise drown out every other
    dimension in the tier 2–4 nearest-scenario comparisons, making e.g. a
    2x change on a size-8 axis (which matters enormously for tiling) count
    for nothing next to a 5% change on the 4096 axis. Log ratios weigh
    relative change equally per dimension. Missing dimensions (rank
    mismatch) are padded with 1, i.e. treated as a degenerate axis.
    """
    n = max(len(a), len(b))
    a = tuple(a) + (1,) * (n - len(a))
    b = tuple(b) + (1,) * (n - len(b))
    return math.sqrt(sum(
        math.log2(max(x, 1) / max(y, 1)) ** 2 for x, y in zip(a, b)))


def _metrics():
    """The process metrics registry, or None (obs disabled / not loaded).

    Imported lazily: ``repro.obs`` imports ``repro.core.scenario`` for its
    tier vocabulary, so a module-level import here could deadlock package
    initialization depending on which package is imported first."""
    try:
        from repro.obs import runtime as obs_runtime
    except ImportError:  # pragma: no cover - obs is part of this repo
        return None
    return obs_runtime.metrics()


class WisdomIndex:
    """Hash index over one kernel's records — the §4.5 select hot path.

    ``Wisdom.select_record`` historically re-filtered every record per
    call, so select latency grew linearly with the store exactly as the
    fleet succeeded at filling it. The index buckets records once:

    * ``exact``: (device_kind, problem_size, dtype) → measured records,
      giving O(1) dict hops for the common serve-time exact hit;
    * one bucket family per fallback tier (device+dtype, device,
      family+dtype, family, dtype, all-measured), so a fallback select
      scans only its tier's candidates, not the whole store;
    * ``transferred``: (device_kind, dtype) → predicted records (the
      confidence gate stays per-query, it depends on the threshold);
    * ``scenario_slot``: scenario → first list position, which turns
      ``Wisdom.add``'s keep-best duplicate scan into one lookup.

    Buckets map ``id(record) → record`` so membership updates during
    ``add()`` are O(1) and iteration order stays insertion order (the
    tie-break never depends on it — selection orders by distance, score,
    record_id). The index is derived state: :meth:`Wisdom.index` rebuilds
    it whenever ``Wisdom.records`` was rebound or resized behind its
    back, so direct list mutation stays legal, just unindexed-until-read.
    """

    __slots__ = ("source", "size", "scenario_slot", "exact",
                 "by_device_dtype", "by_device", "by_family_dtype",
                 "by_family", "by_dtype", "measured", "transferred")

    def __init__(self, records: Sequence["WisdomRecord"] = ()):
        self.source = records          # identity-checked by Wisdom.index()
        self.size = 0
        self.scenario_slot: dict[tuple, int] = {}
        self.exact: dict[tuple, dict] = {}
        self.by_device_dtype: dict[tuple, dict] = {}
        self.by_device: dict[str, dict] = {}
        self.by_family_dtype: dict[tuple, dict] = {}
        self.by_family: dict[str, dict] = {}
        self.by_dtype: dict[str, dict] = {}
        self.measured: dict[int, "WisdomRecord"] = {}
        self.transferred: dict[tuple, dict] = {}
        for position, rec in enumerate(records):
            self.insert(rec, position)

    def insert(self, rec: "WisdomRecord", position: int) -> None:
        """Index ``rec`` living at ``records[position]``."""
        self.scenario_slot.setdefault(rec.scenario(), position)
        key = id(rec)
        if rec.is_transferred():
            self.transferred.setdefault(
                (rec.device_kind, rec.dtype), {})[key] = rec
        else:
            self.exact.setdefault(rec.scenario(), {})[key] = rec
            self.by_device_dtype.setdefault(
                (rec.device_kind, rec.dtype), {})[key] = rec
            self.by_device.setdefault(rec.device_kind, {})[key] = rec
            self.by_family_dtype.setdefault(
                (rec.device_family, rec.dtype), {})[key] = rec
            self.by_family.setdefault(rec.device_family, {})[key] = rec
            self.by_dtype.setdefault(rec.dtype, {})[key] = rec
            self.measured[key] = rec
        self.size += 1

    def replace(self, old: "WisdomRecord", new: "WisdomRecord",
                position: int) -> None:
        """Swap ``old`` for ``new`` at the same list position (keep-best
        resolution in :meth:`Wisdom.add`). ``scenario_slot`` is untouched:
        both records share the scenario and the position."""
        key = id(old)
        if old.is_transferred():
            self.transferred[(old.device_kind, old.dtype)].pop(key, None)
        else:
            self.exact[old.scenario()].pop(key, None)
            self.by_device_dtype[(old.device_kind, old.dtype)].pop(key, None)
            self.by_device[old.device_kind].pop(key, None)
            self.by_family_dtype[(old.device_family, old.dtype)].pop(
                key, None)
            self.by_family[old.device_family].pop(key, None)
            self.by_dtype[old.dtype].pop(key, None)
            self.measured.pop(key, None)
        self.size -= 1
        self.insert(new, position)


def doc_version(doc: dict) -> int:
    """Schema version a wisdom document declares (pre-versioning files
    count as v1)."""
    try:
        return int(doc.get("version", 1))
    except (TypeError, ValueError):
        raise WisdomVersionError(
            f"wisdom document declares non-integer version "
            f"{doc.get('version')!r}") from None


def migrate_doc(doc: dict, source: str = "<memory>") -> dict:
    """Migrate a wisdom document to the current ``WISDOM_VERSION``.

    Returns a new document (the input is not mutated). v1 -> v2 adds the
    empty per-record ``lineage`` list. Documents from a *newer* schema
    raise :class:`WisdomVersionError` — refusing loudly beats silently
    dropping fields a future writer considered essential.
    """
    version = doc_version(doc)
    if version > WISDOM_VERSION:
        raise WisdomVersionError(
            f"wisdom document {source} has version {version}, but this "
            f"build understands at most {WISDOM_VERSION}; upgrade before "
            f"loading it (records were NOT read)")
    out = json.loads(json.dumps(doc))     # deep copy, JSON-clean
    if version < 2:
        for rec in out.get("records", []):
            rec.setdefault("lineage", [])
    out["version"] = WISDOM_VERSION
    return out


class Wisdom:
    """All tuning results for one kernel (one file per kernel, paper §4.4)."""

    def __init__(self, kernel_name: str,
                 records: list[WisdomRecord] | None = None):
        self.kernel_name = kernel_name
        self.records: list[WisdomRecord] = list(records or [])
        self._index: WisdomIndex | None = None

    def index(self) -> WisdomIndex:
        """The :class:`WisdomIndex` over :attr:`records`, (re)built lazily.

        Staleness check: the index remembers which list object it was
        built from and how many records it indexed; rebinding ``records``
        or changing its length invalidates it. In-place *replacement*
        behind our back (``w.records[i] = other``) is not detected —
        every in-repo mutation goes through :meth:`add`, which maintains
        the index incrementally."""
        idx = self._index
        if (idx is None or idx.source is not self.records
                or idx.size != len(self.records)):
            idx = self._index = WisdomIndex(self.records)
        return idx

    # -- persistence ---------------------------------------------------------

    @staticmethod
    def path_for(kernel_name: str, wisdom_dir: Path | str | None = None) -> Path:
        d = Path(wisdom_dir) if wisdom_dir is not None else default_wisdom_dir()
        return d / f"{kernel_name}.wisdom.json"

    @staticmethod
    def load(kernel_name: str, wisdom_dir: Path | str | None = None) -> "Wisdom":
        path = Wisdom.path_for(kernel_name, wisdom_dir)
        if not path.exists():
            return Wisdom(kernel_name)
        with open(path) as f:
            doc = json.load(f)
        if not isinstance(doc, dict):
            raise ValueError(
                f"wisdom file {path} is not a JSON object "
                f"(got {type(doc).__name__})")
        if doc.get("kernel") != kernel_name:
            raise ValueError(
                f"wisdom file {path} is for kernel {doc.get('kernel')!r}, "
                f"not {kernel_name!r}")
        doc = migrate_doc(doc, source=str(path))
        recs = [WisdomRecord.from_json(r) for r in doc.get("records", [])]
        return Wisdom(kernel_name, recs)

    def to_doc(self) -> dict:
        return {
            "kernel": self.kernel_name,
            "version": WISDOM_VERSION,
            "records": [r.to_json() for r in self.records],
        }

    def save(self, wisdom_dir: Path | str | None = None) -> Path:
        path = Wisdom.path_for(self.kernel_name, wisdom_dir)
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(".tmp")
        with open(tmp, "w") as f:
            json.dump(self.to_doc(), f, indent=2, sort_keys=True)
        os.replace(tmp, path)  # atomic
        return path

    # -- mutation ------------------------------------------------------------

    def add(self, record: WisdomRecord, keep_best: bool = True) -> None:
        """Add a tuning result. If a record for the same scenario exists and
        ``keep_best``, keep whichever scored better (re-tuning semantics);
        the survivor absorbs both records' provenance into its lineage.

        The same-scenario lookup goes through the index's
        ``scenario_slot`` map (one dict hop), not a list scan, so bulk
        re-adds (fleet merge echoes, prune rebuilds) are O(1) per record
        instead of O(n)."""
        if keep_best:
            idx = self.index()
            i = idx.scenario_slot.get(record.scenario())
            if i is not None:
                r = self.records[i]
                if r.record_id() == record.record_id():
                    # Same result re-added (e.g. a sync echo): pool
                    # lineages only, keep re-adds a no-op otherwise.
                    if record.lineage != r.lineage:
                        r.lineage = merge_lineage(
                            extra=[*r.lineage, *record.lineage])
                    return
                # Measured beats transferred regardless of score (a
                # prediction must never displace a real measurement
                # — that is what verification jobs are for, see
                # repro.transfer); equal scores fall through to
                # record_id so the survivor is insertion-order
                # independent, like select() and better_record.
                winner, loser = ((record, r)
                                 if ((record.is_transferred(),
                                      record.score_us,
                                      -record.evaluations(),
                                      record.record_id())
                                     < (r.is_transferred(), r.score_us,
                                        -r.evaluations(),
                                        r.record_id()))
                                 else (r, record))
                winner.lineage = merge_lineage(winner, loser)
                self.records[i] = winner
                if winner is not r:
                    idx.replace(r, winner, i)
                return
            self.records.append(record)
            idx.insert(record, len(self.records) - 1)
            return
        self.records.append(record)
        # keep_best=False appends allow duplicate scenarios; extend the
        # index only if it is live and current, else let it rebuild.
        idx = self._index
        if (idx is not None and idx.source is self.records
                and idx.size == len(self.records) - 1):
            idx.insert(record, len(self.records) - 1)

    # -- selection (paper §4.5) ----------------------------------------------

    def select(self, device_kind: str, problem_size: Sequence[int],
               dtype: str, default_config: dict,
               min_transfer_confidence: float | None = None
               ) -> tuple[dict, str]:
        """Pick a config for a scenario. Returns (config, match_tier).
        Thin wrapper over :meth:`select_record` for callers that only
        need the config dict; callers that want the matched record
        itself (its score, provenance, transfer confidence) use
        ``select_record`` directly.

        Measured records go through the paper's §4.5 fuzzy tiers.
        *Transferred* records (cross-device predictions, see
        ``repro.transfer``) participate only in their own ``"transfer"``
        tier — same device kind and dtype, confidence at least
        ``min_transfer_confidence`` (default
        :data:`TRANSFER_MIN_CONFIDENCE`) — which sits directly below
        ``"exact"``: a confident prediction for this device beats *every*
        scenario-distance fallback, including a same-device measurement
        for a different problem size (both extrapolate; the prediction
        was at least calibrated for this hardware and ranks by problem
        distance within its tier), but it never shadows a real
        measurement for the exact scenario.
        """
        rec, tier = self.select_record(device_kind, problem_size, dtype,
                                       min_transfer_confidence)
        if rec is None:
            return dict(default_config), tier
        return dict(rec.config), tier

    def select_record(self, device_kind: str, problem_size: Sequence[int],
                      dtype: str,
                      min_transfer_confidence: float | None = None
                      ) -> tuple["WisdomRecord | None", str]:
        """The §4.5 heuristic, returning the matched record itself.

        Returns (record, tier); record is None only for the "default"
        tier (empty/unusable wisdom), where the caller supplies its own
        default configuration. This is the full-information form: the
        telemetry layer reads the record's transfer confidence and score
        off it, and ``select`` above reduces it to a config dict.

        Routed through :class:`WisdomIndex`: the exact tier is two dict
        hops, each fallback tier touches only its own candidates — select
        cost no longer grows with the store. Property-tested byte-equal
        to the historical scan (:meth:`select_record_linear`) in
        ``tests/test_wisdom_index_props.py``.
        """
        problem = tuple(int(x) for x in problem_size)
        family = get_device(device_kind).family
        threshold = (TRANSFER_MIN_CONFIDENCE
                     if min_transfer_confidence is None
                     else float(min_transfer_confidence))
        idx = self.index()

        def best(cands) -> WisdomRecord | None:
            if not cands:
                return None
            # record_id as the last key: equal-distance equal-score
            # candidates must resolve the same way on every host, not by
            # whatever order records happened to be inserted or merged.
            return min(cands, key=lambda r: (_distance(r.problem_size,
                                                       problem),
                                             r.score_us, r.record_id()))

        empty: dict = {}
        transferred = [
            r for r in idx.transferred.get((device_kind, dtype),
                                           empty).values()
            if r.transfer_confidence() >= threshold]
        tiers = (
            (T_EXACT,
             idx.exact.get((device_kind, problem, dtype), empty).values()),
            (T_TRANSFER, transferred),
            (T_DEVICE_DTYPE,
             idx.by_device_dtype.get((device_kind, dtype), empty).values()),
            (T_DEVICE, idx.by_device.get(device_kind, empty).values()),
            (T_FAMILY_DTYPE,
             idx.by_family_dtype.get((family, dtype), empty).values()),
            (T_FAMILY, idx.by_family.get(family, empty).values()),
            (T_ANY_DTYPE, idx.by_dtype.get(dtype, empty).values()),
            (T_ANY, idx.measured.values()),
        )

        result: tuple[WisdomRecord | None, str] = (None, T_DEFAULT)
        for tier_name, cands in tiers:
            rec = best(cands)
            if rec is not None:
                result = (rec, tier_name)
                break
        m = _metrics()
        if m is not None:
            outcome = ("hit" if result[1] == T_EXACT
                       else "default" if result[0] is None else "fallback")
            m.counter("select.index_hit", kernel=self.kernel_name,
                      outcome=outcome).inc()
        return result

    def select_record_linear(self, device_kind: str,
                             problem_size: Sequence[int], dtype: str,
                             min_transfer_confidence: float | None = None
                             ) -> tuple["WisdomRecord | None", str]:
        """The historical O(n) linear-scan §4.5 selection, kept verbatim
        as the *reference oracle*: ``tests/test_wisdom_index_props.py``
        asserts the indexed :meth:`select_record` returns a byte-identical
        (record_id, tier) for arbitrary record sets. Not for production
        use — it re-filters every record per call."""
        problem = tuple(int(x) for x in problem_size)
        family = get_device(device_kind).family
        threshold = (TRANSFER_MIN_CONFIDENCE
                     if min_transfer_confidence is None
                     else float(min_transfer_confidence))
        measured = [r for r in self.records if not r.is_transferred()]
        transferred = [r for r in self.records
                       if r.is_transferred()
                       and r.device_kind == device_kind
                       and r.dtype == dtype
                       and r.transfer_confidence() >= threshold]

        def best(cands: list[WisdomRecord]) -> WisdomRecord | None:
            if not cands:
                return None
            return min(cands, key=lambda r: (_distance(r.problem_size,
                                                       problem),
                                             r.score_us, r.record_id()))

        tiers: list[tuple[str, list[WisdomRecord]]] = []
        exact = [r for r in measured
                 if r.device_kind == device_kind
                 and r.problem_size == problem and r.dtype == dtype]
        tiers.append((T_EXACT, exact))
        tiers.append((T_TRANSFER, transferred))
        same_dev = [r for r in measured
                    if r.device_kind == device_kind and r.dtype == dtype]
        tiers.append((T_DEVICE_DTYPE, same_dev))
        same_dev_any = [r for r in measured if r.device_kind == device_kind]
        tiers.append((T_DEVICE, same_dev_any))
        fam = [r for r in measured
               if r.device_family == family and r.dtype == dtype]
        tiers.append((T_FAMILY_DTYPE, fam))
        fam_any = [r for r in measured if r.device_family == family]
        tiers.append((T_FAMILY, fam_any))
        any_dtype = [r for r in measured if r.dtype == dtype]
        tiers.append((T_ANY_DTYPE, any_dtype))
        tiers.append((T_ANY, measured))

        for tier_name, cands in tiers:
            rec = best(cands)
            if rec is not None:
                return rec, tier_name
        return None, T_DEFAULT

    def __len__(self) -> int:
        return len(self.records)

    def __repr__(self) -> str:  # pragma: no cover
        return f"Wisdom({self.kernel_name!r}, {len(self.records)} records)"
