"""Compile-time kernel selection — the *baseline* the paper compares
against (paper §3: Kernel Tuner's generated C headers).

``export_header`` bakes the best known config per device into a static
table (one "header" per kernel, JSON + a C-header-style rendering for
fidelity); ``StaticKernel`` consumes the baked table the way a Make/CMake
target would: the config is fixed at "build" time for one device, with **no
problem-size dispatch and no fuzzy matching** — exactly the limitation the
paper's runtime selection removes (recompile per GPU, one config per
build). Benchmarked against WisdomKernel in §Paper/C3.
"""

from __future__ import annotations

import json
import os
from pathlib import Path

from .builder import KernelBuilder, args_meta
from .param import Config
from .wisdom import Wisdom


def export_header(kernel_name: str, device_kind: str,
                  wisdom_dir: Path | str | None = None,
                  out_dir: Path | str = "generated",
                  reference_problem: tuple[int, ...] | None = None) -> Path:
    """Bake the best config for (kernel, device) into a static header.

    Mirrors Kernel Tuner's ``store_defaults``-style export: if multiple
    problem sizes were tuned, the one closest to ``reference_problem``
    (or the best-scoring record) wins — the compile-time approach cannot
    dispatch on problem size at run time."""
    wisdom = Wisdom.load(kernel_name, wisdom_dir)
    recs = [r for r in wisdom.records if r.device_kind == device_kind]
    if not recs:
        raise FileNotFoundError(
            f"no wisdom for {kernel_name!r} on {device_kind!r}; tune first")
    if reference_problem is not None:
        cfg, _ = wisdom.select(device_kind, reference_problem,
                               recs[0].dtype, recs[0].config)
    else:
        cfg = min(recs, key=lambda r: r.score_us).config

    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    doc = {"kernel": kernel_name, "device": device_kind, "config": cfg}
    jpath = out / f"{kernel_name}-{device_kind}.header.json"
    with open(jpath, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    # C-header rendering, for fidelity with the paper's workflow
    hpath = out / f"{kernel_name}-{device_kind}.h"
    guard = f"{kernel_name}_{device_kind}".upper().replace("-", "_")
    lines = [f"#ifndef {guard}_H", f"#define {guard}_H", ""]
    for k, v in sorted(cfg.items()):
        macro = f"{kernel_name}_{k}".upper().replace("-", "_")
        if isinstance(v, bool):
            v = int(v)
        if isinstance(v, str):
            v = f'"{v}"'
        lines.append(f"#define {macro} {v}")
    lines += ["", "#endif", ""]
    hpath.write_text("\n".join(lines))
    return jpath


def load_header(path: Path | str) -> dict:
    with open(path) as f:
        return json.load(f)


class StaticKernel:
    """Compile-time-selected kernel: one fixed config per build/device.
    No wisdom lookups, no per-problem dispatch — the paper's baseline."""

    def __init__(self, builder: KernelBuilder, header_path: Path | str,
                 backend: str | None = None):
        import jax

        self.builder = builder
        doc = load_header(header_path)
        if doc["kernel"] != builder.name:
            raise ValueError(
                f"header is for {doc['kernel']!r}, not {builder.name!r}")
        self.config: Config = doc["config"]
        self.device = doc["device"]
        self._backend = backend
        self._compiled: dict = {}

    def __call__(self, *args):
        import jax

        from .wisdom_kernel import resolve_backend

        backend = resolve_backend(self._backend)
        meta = args_meta(*args)
        if backend == "reference":
            fn = self.builder.make_reference()
        else:
            fn = self.builder.make(self.config, meta,
                                   interpret=backend == "interpret")
        key = tuple((m.shape, str(m.dtype)) for m in meta)
        if key not in self._compiled:
            self._compiled[key] = jax.jit(fn).lower(*meta).compile()
        return self._compiled[key](*args)
