"""Scenario keys and the canonical §4.5 selection-tier vocabulary.

A *scenario* is the (device kind, problem size, dtype) triple the paper's
selection heuristic matches wisdom records against. This module is the
single source of truth for

* the canonical string form of a scenario key (``format_key`` /
  ``parse_key``) — the representation that survives JSON transport and
  keys every metric, demand record, and dataset file; and
* the selection-tier names ``Wisdom.select`` can return, partitioned into
  *hits* and *misses* (previously duplicated between ``core/wisdom.py``
  string literals and ``online/tracker.py`` constants).

Everything here is import-leaf: no repro module is imported, so the
observability layer, the online tracker, and the wisdom heuristic can all
share one vocabulary without cycles.
"""

from __future__ import annotations

ScenarioKey = tuple[str, tuple[int, ...], str]   # (device_kind, problem, dtype)

#: Separator for the canonical string form of a ScenarioKey. Device kinds
#: and dtypes never contain it (enforced by ``format_key``).
_KEY_SEP = "|"

#: The §4.5 selection tiers, best first — exactly the order
#: ``Wisdom.select`` tries them. "exact" is a measured record for the
#: scenario; "transfer" a confidence-gated cross-device prediction;
#: the fuzzy tiers relax device/size/dtype matching step by step;
#: "default" is the empty-wisdom fallback.
SELECT_TIERS = ("exact", "transfer", "device+dtype", "device",
                "family+dtype", "family", "any+dtype", "any", "default")

#: Tiers a launch can report beyond selection: the caller forced a config,
#: or the online tuner diverted the launch to a candidate.
LAUNCH_TIERS = SELECT_TIERS + ("forced", "trial")

#: Selection tiers that count as wisdom misses (paper §4.5 tiers 2-5: any
#: fuzzy device/size/dtype match, and the empty-wisdom default). The
#: "transfer" tier counts too: a transferred record serves traffic well,
#: but it is a *prediction* — demand must keep flowing so the fleet
#: verification loop eventually replaces it with a measurement.
MISS_TIERS = frozenset(t for t in SELECT_TIERS if t != "exact")

#: Tiers that are *not* tuning demand: an exact record already exists, the
#: caller forced a config, or the launch was an online trial itself.
HIT_TIERS = frozenset({"exact", "forced", "trial"})


def format_key(key: ScenarioKey) -> str:
    """Canonical, round-trippable string form of a scenario key.

    ``("tpu-v5e", (256, 256), "float32")`` -> ``"tpu-v5e|256x256|float32"``.
    The tuple form does not survive JSON (tuples come back as lists, and
    dict keys cannot be tuples at all), so everything that moves demand
    records across a transport keys them by this string instead.
    """
    device_kind, problem, dtype = key
    device_kind, dtype = str(device_kind), str(dtype)
    for part in (device_kind, dtype):
        if _KEY_SEP in part:
            raise ValueError(f"scenario component {part!r} contains "
                             f"{_KEY_SEP!r}")
    dims = "x".join(str(int(d)) for d in problem)
    return _KEY_SEP.join((device_kind, dims, dtype))


def parse_key(s: str) -> ScenarioKey:
    """Inverse of :func:`format_key` (hashable tuples, ints restored)."""
    parts = s.split(_KEY_SEP)
    if len(parts) != 3:
        raise ValueError(f"malformed scenario key {s!r}")
    device_kind, dims, dtype = parts
    problem = tuple(int(d) for d in dims.split("x")) if dims else ()
    return (device_kind, problem, dtype)
