"""Kernel-launch capture (paper §4.2).

Setting ``KERNEL_LAUNCHER_CAPTURE`` to a comma-separated list of kernel names
(or ``*``) makes :class:`~repro.core.wisdom_kernel.WisdomKernel` export, on
launch, everything needed to *replay* that launch offline: the kernel name,
problem size, dtype, argument arrays (real application data — the paper's key
point: no synthetic input generation), and launch metadata.

Captures are ``<name>-<problem>-<dtype>.capture.json`` + a sibling ``.npz``
holding the arrays.
"""

from __future__ import annotations

import datetime
import json
import os
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any

import numpy as np

CAPTURE_ENV = "KERNEL_LAUNCHER_CAPTURE"
CAPTURE_DIR_ENV = "KERNEL_LAUNCHER_CAPTURE_DIR"
CAPTURE_VERSION = 1


def capture_requested(kernel_name: str) -> bool:
    spec = os.environ.get(CAPTURE_ENV, "")
    if not spec:
        return False
    names = [s.strip() for s in spec.split(",") if s.strip()]
    return "*" in names or kernel_name in names


def capture_dir() -> Path:
    return Path(os.environ.get(CAPTURE_DIR_ENV, Path.cwd() / "captures"))


@dataclass
class Capture:
    kernel_name: str
    problem_size: tuple[int, ...]
    dtype: str
    args: list[np.ndarray]
    meta: dict[str, Any]
    path: Path | None = None

    @property
    def nbytes(self) -> int:
        return sum(int(a.nbytes) for a in self.args)


def _slug(problem: tuple[int, ...], dtype: str) -> str:
    return "x".join(str(p) for p in problem) + "-" + dtype


def write_capture(kernel_name: str, problem_size: tuple[int, ...],
                  dtype: str, args, out_dir: Path | str | None = None,
                  extra_meta: dict | None = None) -> Path:
    """Serialize one launch. Returns the json path. Timing of this function
    is the paper's Table 3 'capture time'."""
    t0 = time.perf_counter()
    d = Path(out_dir) if out_dir is not None else capture_dir()
    d.mkdir(parents=True, exist_ok=True)
    arrays = [np.asarray(a) for a in args]
    base = f"{kernel_name}-{_slug(problem_size, dtype)}"
    npz_path = d / f"{base}.npz"
    json_path = d / f"{base}.capture.json"
    np.savez(npz_path, **{f"arg{i}": a for i, a in enumerate(arrays)})
    meta = {
        "version": CAPTURE_VERSION,
        "kernel": kernel_name,
        "problem_size": list(problem_size),
        "dtype": dtype,
        "num_args": len(arrays),
        "arg_shapes": [list(a.shape) for a in arrays],
        "arg_dtypes": [str(a.dtype) for a in arrays],
        "nbytes": int(sum(a.nbytes for a in arrays)),
        "npz": npz_path.name,
        "captured_at": datetime.datetime.now(
            datetime.timezone.utc).isoformat(),
        "capture_seconds": None,   # filled below
    }
    meta.update(extra_meta or {})
    meta["capture_seconds"] = time.perf_counter() - t0
    tmp = json_path.with_suffix(".tmp")
    with open(tmp, "w") as f:
        json.dump(meta, f, indent=2)
    os.replace(tmp, json_path)
    return json_path


def load_capture(json_path: Path | str) -> Capture:
    json_path = Path(json_path)
    with open(json_path) as f:
        meta = json.load(f)
    with np.load(json_path.parent / meta["npz"]) as z:
        args = [z[f"arg{i}"] for i in range(meta["num_args"])]
    return Capture(
        kernel_name=meta["kernel"],
        problem_size=tuple(int(x) for x in meta["problem_size"]),
        dtype=meta["dtype"],
        args=args,
        meta=meta,
        path=json_path,
    )


def list_captures(in_dir: Path | str | None = None) -> list[Path]:
    d = Path(in_dir) if in_dir is not None else capture_dir()
    if not d.exists():
        return []
    return sorted(d.glob("*.capture.json"))
