"""WisdomKernel — runtime kernel selection + runtime compilation (paper §4.5).

``WisdomKernel(builder)`` is the launchable object (paper Listing 3): calling
it with kernel arguments (a) derives the problem size from the arguments,
(b) optionally *captures* the launch, (c) selects the best known configuration
from the wisdom file via the fuzzy-match heuristic, and (d) compiles the
chosen configuration just-in-time, caching the executable for subsequent
launches of the same scenario.

Works both eagerly (concrete arrays: AOT-compiled executables, timing stats)
and under an outer ``jax.jit`` trace (model integration: selection happens at
trace time from static shapes, the built kernel is inlined).

An :class:`repro.online.OnlineTuner` may be attached (explicitly via
``attach_online`` / ``repro.online.enable_online_tuning``, or automatically
when ``KERNEL_LAUNCHER_ONLINE=1``): every eager launch then reports its
selection tier, a small epsilon fraction of launches runs a candidate
config instead of the incumbent ("trial" tier), and confident winners are
promoted into the wisdom file live. Traced launches never participate —
the outer jit owns those.
"""

from __future__ import annotations

import os
import time
from pathlib import Path
from typing import Callable

import jax
import numpy as np

from repro.obs import runtime as obs
from repro.obs.metrics import UNIT_BUCKETS

from .builder import ArgsMeta, KernelBuilder, args_meta
from .capture import capture_requested, write_capture
from .compile_cache import CompileCache, LaunchStats
from .device import current_device_kind
from .param import Config
from .scenario import format_key
from .wisdom import Wisdom


def online_requested() -> bool:
    """KERNEL_LAUNCHER_ONLINE=1 auto-attaches an online tuner per kernel."""
    return os.environ.get("KERNEL_LAUNCHER_ONLINE", "").lower() in (
        "1", "true", "on", "yes")

BACKEND_ENV = "REPRO_KERNEL_BACKEND"
_VALID_BACKENDS = ("auto", "pallas", "interpret", "reference")


def resolve_backend(backend: str | None = None) -> str:
    b = backend or os.environ.get(BACKEND_ENV, "auto")
    if b not in _VALID_BACKENDS:
        raise ValueError(f"bad backend {b!r}; want one of {_VALID_BACKENDS}")
    if b == "auto":
        # Real accelerators run the Pallas lowering (Mosaic on TPU,
        # Triton on GPU — see kernels/_lowering.py); hosts without one
        # serve the reference oracle.
        b = ("pallas" if jax.default_backend() in ("tpu", "gpu")
             else "reference")
    return b


class WisdomKernel:
    def __init__(self, builder: KernelBuilder,
                 wisdom_dir: Path | str | None = None,
                 device_kind: str | None = None,
                 backend: str | None = None) -> None:
        self.builder = builder
        self.wisdom_dir = wisdom_dir
        self._device_kind = device_kind
        self._backend = backend
        self._wisdom: Wisdom | None = None
        self._wisdom_read_s = 0.0
        self._selection_cache: dict[tuple, tuple[Config, str]] = {}
        self.compile_cache = CompileCache()
        self.stats: list[LaunchStats] = []
        #: §4.5 match tier of every launch (traced ones included), tallied
        #: so callers can read selection quality without observability
        #: enabled; ``last_tier`` is the most recent launch's tier.
        self.tier_counts: dict[str, int] = {}
        self.last_tier: str | None = None
        self.online = None
        if online_requested():
            from repro.online import OnlineTuner  # deferred: avoids cycle
            self.online = OnlineTuner(self, wisdom_dir=wisdom_dir)
        #: Sampled launch profiler (see ``repro.prof``) — None unless
        #: attached explicitly or via KERNEL_LAUNCHER_PROF; the per-launch
        #: cost of the disabled site is one attribute check.
        self.profiler = None
        self._profile_baselines: dict[tuple, float | None] = {}
        if os.environ.get("KERNEL_LAUNCHER_PROF"):
            from repro.prof.profiler import process_profiler  # deferred
            self.profiler = process_profiler()

    # -- pieces ---------------------------------------------------------------

    @property
    def device_kind(self) -> str:
        return self._device_kind or current_device_kind()

    def _load_wisdom(self) -> Wisdom:
        if self._wisdom is None:
            t0 = time.perf_counter()
            self._wisdom = Wisdom.load(self.builder.name, self.wisdom_dir)
            self._wisdom_read_s = time.perf_counter() - t0
        return self._wisdom

    def invalidate(self) -> None:
        """Drop cached wisdom + selections (e.g. after re-tuning)."""
        self._wisdom = None
        self._selection_cache.clear()
        self.compile_cache.clear()

    def refresh_wisdom(self) -> None:
        """Re-read wisdom and re-run selection, keeping compiled
        executables — the hot-swap path for online promotion (the promoted
        variant is prewarmed, old variants stay valid for forced use)."""
        self._wisdom = None
        self._selection_cache.clear()

    def attach_online(self, tuner) -> None:
        """Attach an online tuning service (see ``repro.online``)."""
        self.online = tuner

    def attach_profiler(self, profiler) -> None:
        """Attach a :class:`repro.prof.Profiler`: every Nth eager launch
        gets a roofline profile (bottleneck class, achieved fraction of
        peak, drift vs the wisdom-recorded baseline)."""
        self.profiler = profiler

    def prewarm(self, meta: ArgsMeta, config: Config) -> bool:
        """Compile+cache ``config`` for the scenario described by ``meta``
        ahead of any launch. Returns True if a compilation happened."""
        backend = resolve_backend(self._backend)
        problem = self.builder.get_problem_size(*meta)
        dtype = self.builder.get_dtype(*meta)
        key = (self.device_kind, backend, problem, dtype,
               self.builder.space.freeze(config))
        fn = self._instantiate(config, meta, backend)
        _, _, cached = self.compile_cache.get_or_compile(
            key, lambda: jax.jit(fn).lower(*meta).compile())
        return not cached

    def select_config(self, problem: tuple[int, ...], dtype: str
                      ) -> tuple[Config, str]:
        key = (self.device_kind, problem, dtype)
        if key in self._selection_cache:
            return self._selection_cache[key]
        wisdom = self._load_wisdom()
        rec, tier = wisdom.select_record(self.device_kind, problem, dtype)
        cfg = (dict(rec.config) if rec is not None
               else self.builder.default_config())
        # Exact-tier wisdom scores are this scenario's drift baseline:
        # the latency the config was promoted at. Fuzzy/transferred
        # matches came from a different scenario, so no baseline.
        self._profile_baselines[key] = (
            float(rec.score_us) if rec is not None and tier == "exact"
            and rec.score_us > 0 else None)
        m = obs.metrics()
        if m is not None and rec is not None and rec.is_transferred():
            m.histogram("select.transfer_confidence", UNIT_BUCKETS,
                        kernel=self.builder.name).observe(
                            rec.transfer_confidence())
        self._selection_cache[key] = (cfg, tier)
        return cfg, tier

    def _observe_selection(self, problem: tuple[int, ...], dtype: str,
                           tier: str) -> None:
        """Always-on tier tally + (when enabled) per-scenario metrics."""
        self.tier_counts[tier] = self.tier_counts.get(tier, 0) + 1
        self.last_tier = tier
        m = obs.metrics()
        if m is not None:
            m.counter("select.tier", kernel=self.builder.name,
                      scenario=format_key((self.device_kind, problem,
                                           dtype)),
                      tier=tier).inc()

    # -- launch ---------------------------------------------------------------

    def __call__(self, *args, config: Config | None = None):
        meta = args_meta(*args)
        problem = self.builder.get_problem_size(*meta)
        dtype = self.builder.get_dtype(*meta)
        backend = resolve_backend(self._backend)

        traced = any(isinstance(a, jax.core.Tracer) for a in args)
        if not traced and capture_requested(self.builder.name):
            write_capture(self.builder.name, problem, dtype, args,
                          extra_meta={"device_kind": self.device_kind,
                                      "source": self.builder.source})

        t_sel0 = time.perf_counter()
        if config is None:
            config, tier = self.select_config(problem, dtype)
        else:
            tier = "forced"
        online = self.online
        if online is not None and not traced and tier != "forced":
            trial = online.before_launch(problem, dtype, meta, config, tier)
            if trial is not None:
                config, tier = dict(trial), "trial"
        select_s = time.perf_counter() - t_sel0
        self._observe_selection(problem, dtype, tier)

        fn = self._instantiate(config, meta, backend)

        if traced:
            # Inside an outer trace: inline; the outer jit owns compilation.
            # Online tuning still gets to see the (trace-time) selection so
            # demand from jitted launch streams is tracked; tuning work for
            # it runs via OnlineTuner.tick(), not launch hooks.
            if online is not None and tier != "forced":
                online.observe_traced(problem, dtype, meta, config, tier)
            return fn(*args)

        key = (self.device_kind, backend, problem, dtype,
               self.builder.space.freeze(config))

        def _compile() -> Callable:
            return jax.jit(fn).lower(*meta).compile()

        compiled, compile_s, cached = self.compile_cache.get_or_compile(
            key, _compile)
        t0 = time.perf_counter()
        out = compiled(*[np.asarray(a) if not hasattr(a, "dtype") else a
                         for a in args])
        out = jax.block_until_ready(out)
        launch_s = time.perf_counter() - t0
        self.stats.append(LaunchStats(
            kernel=self.builder.name, cached=cached,
            wisdom_read_s=0.0 if cached else self._wisdom_read_s,
            select_s=select_s, compile_s=compile_s, launch_s=launch_s,
            tier=tier, config=dict(config)))
        m = obs.metrics()
        if m is not None:
            name = self.builder.name
            m.counter("launch.count", kernel=name).inc()
            m.counter("compile.cache", kernel=name,
                      outcome="hit" if cached else "miss").inc()
            m.histogram("select.latency_us",
                        kernel=name).observe(select_s * 1e6)
            m.histogram("launch.latency_us",
                        kernel=name).observe(launch_s * 1e6)
            if not cached:
                m.histogram("compile.latency_us",
                            kernel=name).observe(compile_s * 1e6)
        tr = obs.tracer()
        if tr is not None:
            # Record the finished launch as one complete event (the work
            # already happened; re-running it under a context manager
            # would distort the hot path). ts/dur reconstruct the span.
            t_end = tr._now_us()
            dur = round((select_s + compile_s + launch_s) * 1e6, 3)
            tr.events.append({
                "name": "launch", "cat": "kernel", "ph": "X",
                "ts": round(t_end - dur, 3), "dur": dur,
                "pid": tr.pid, "tid": tr._tid(),
                "args": {"kernel": self.builder.name, "tier": tier,
                         "scenario": format_key((self.device_kind,
                                                 problem, dtype)),
                         "cached": cached,
                         "compile_us": round(compile_s * 1e6, 3),
                         "launch_us": round(launch_s * 1e6, 3)}})
        profiler = self.profiler
        if profiler is not None and profiler.due(self.builder.name):
            profiler.profile_launch(
                self.builder, config, problem, dtype, self.device_kind,
                launch_s * 1e6, tier=tier,
                baseline_us=self._profile_baselines.get(
                    (self.device_kind, problem, dtype)))
        if online is not None:
            online.after_launch(problem, dtype, config, tier, launch_s)
        return out

    def _instantiate(self, config: Config, meta, backend: str) -> Callable:
        if backend == "reference":
            return self.builder.make_reference()
        interpret = backend == "interpret"
        return self.builder.make(config, meta, interpret=interpret)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"WisdomKernel({self.builder.name!r}, "
                f"device={self.device_kind!r})")
