"""Workload descriptors — what a kernel configuration *does* to the hardware.

Each KernelBuilder provides ``workload(config, problem, dtype)`` returning a
:class:`Workload`; the analytical cost model turns (Workload, DeviceSpec) into
a simulated kernel time. This is the TPU adaptation of the paper's wall-clock
benchmark loop for a CPU-only container — see DESIGN.md §2.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Workload:
    """Per-launch hardware demand for one kernel configuration."""

    flops: float                 # useful floating-point ops for the launch
    hbm_bytes: float             # HBM bytes moved (incl. halo / re-fetch waste)
    vmem_bytes: int              # per-program VMEM working set (all buffers)
    grid: int                    # number of grid programs
    # Effective matmul tile (m, n, k) for MXU-alignment efficiency;
    # None for VPU-only (elementwise / stencil) kernels.
    mxu_tile: tuple[int, int, int] | None = None
    # Innermost contiguous extent in elements (lane dimension utilization).
    lane_extent: int = 128
    # Second-minor extent (sublane utilization, 8 for f32 / 16 for bf16).
    sublane_extent: int = 8
    unroll_ways: int = 1         # instruction-level parallelism factor
    reuse: float = 1.0           # >1.0 == extra HBM traffic (halo waste etc.)
    buffers: int = 2             # multiple-buffering depth (1 = no overlap)
    valid: bool = True           # False: config infeasible for this problem
    notes: dict = field(default_factory=dict)

    def scaled(self, **kw) -> "Workload":
        d = self.__dict__ | kw
        return Workload(**d)
