"""Kernel Launcher core — the paper's primary contribution, in JAX.

Public API (mirrors the C++ library's surface, paper §4):

    builder = KernelBuilder("vector_add")
    builder.tune("block_size", [128, 256, 512])
    @builder.problem_size
    def _(c, a, b, n): ...
    @builder.build
    def _(config, problem, meta): ...   # -> pallas_call closure
    kernel = WisdomKernel(builder)
    out = kernel(c, a, b, n)            # capture/select/compile/launch
"""

from .builder import ArgsMeta, KernelBuilder, args_meta
from .capture import (Capture, capture_dir, capture_requested, list_captures,
                      load_capture, write_capture, CAPTURE_ENV)
from .compile_cache import CompileCache, LaunchStats
from .device import (DEVICES, DeviceSpec, current_device, current_device_kind,
                     get_device, TPU_V4, TPU_V5E, DEVICE_ENV)
from .param import Config, ConfigSpace, TunableParam
from .registry import all_kernels, get_kernel, load_builtin_kernels, register
from .wisdom import (Wisdom, WisdomIndex, WisdomRecord, WisdomVersionError,
                     WISDOM_VERSION, make_provenance, default_wisdom_dir,
                     merge_lineage, migrate_doc, doc_version)
from .wisdom_kernel import WisdomKernel, resolve_backend, BACKEND_ENV
from .workload import Workload

__all__ = [
    "ArgsMeta", "KernelBuilder", "args_meta",
    "Capture", "capture_dir", "capture_requested", "list_captures",
    "load_capture", "write_capture", "CAPTURE_ENV",
    "CompileCache", "LaunchStats",
    "DEVICES", "DeviceSpec", "current_device", "current_device_kind",
    "get_device", "TPU_V4", "TPU_V5E", "DEVICE_ENV",
    "Config", "ConfigSpace", "TunableParam",
    "all_kernels", "get_kernel", "load_builtin_kernels", "register",
    "Wisdom", "WisdomIndex", "WisdomRecord", "WisdomVersionError",
    "WISDOM_VERSION",
    "make_provenance", "default_wisdom_dir", "merge_lineage", "migrate_doc",
    "doc_version",
    "WisdomKernel", "resolve_backend", "BACKEND_ENV",
    "Workload",
]
