"""KernelBuilder — tunable kernel definitions (paper §4.1, Listing 3).

The builder consolidates, in one place in the host code:

  * the configuration space (``tune`` / ``restriction``),
  * the compilation specification (``build``: config + problem -> callable;
    for Pallas kernels this constructs the ``pl.pallas_call`` with
    config-derived BlockSpecs),
  * the launch geometry (``problem_size``: derived from the kernel
    arguments, not passed by the caller — paper §4.6),
  * the reference oracle (``reference``) used for output verification,
  * the hardware-demand model (``workload``) used by the analytical
    objective on non-TPU hosts.
"""

from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .param import Config, ConfigSpace
from .workload import Workload

ArgsMeta = tuple  # tuple[jax.ShapeDtypeStruct, ...]


def probe_array(rng: np.random.Generator, shape: Sequence[int], dtype: str,
                scale: float = 1.0) -> np.ndarray:
    """Deterministic random array for a kernel's ``probe`` hook.

    Draws standard-normal values from ``rng`` and casts through jnp so
    non-numpy dtypes (``bfloat16``) work on any host. Probe hooks exist
    so the correctness oracle can synthesize concrete arguments for a
    scenario that was never captured (``problem_size`` is not
    invertible); seeding ``rng`` per scenario keeps the check
    reproducible everywhere.
    """
    x = rng.standard_normal(tuple(int(d) for d in shape)) * scale
    return np.asarray(jnp.asarray(x).astype(dtype))


def args_meta(*args) -> ArgsMeta:
    """Abstract (shape, dtype) view of concrete or abstract arguments."""
    out = []
    for a in args:
        if isinstance(a, jax.ShapeDtypeStruct):
            out.append(a)
        elif hasattr(a, "shape") and hasattr(a, "dtype"):
            out.append(jax.ShapeDtypeStruct(a.shape, a.dtype))
        else:  # python scalar
            out.append(jax.ShapeDtypeStruct((), jnp.asarray(a).dtype))
    return tuple(out)


class KernelBuilder:
    """Tunable kernel definition. See Listing 3 of the paper for the shape
    of the API this mirrors."""

    def __init__(self, name: str, source: str = "") -> None:
        self.name = name
        self.source = source            # human-readable origin (module path)
        self.space = ConfigSpace()
        self._build: Callable[[Config, tuple, ArgsMeta], Callable] | None = None
        self._reference: Callable | None = None
        self._problem_size: Callable[..., tuple[int, ...]] | None = None
        self._workload: Callable[[Config, tuple, str], Workload] | None = None
        self._probe: Callable[[tuple[int, ...], str], Sequence] | None = None

    # -- space construction (chainable, like the C++ API) --------------------

    def tune(self, name: str, values: Sequence, default=None) -> "KernelBuilder":
        self.space.tune(name, values, default)
        return self

    def restriction(self, expr) -> "KernelBuilder":
        self.space.restrict(expr)
        return self

    # -- registration decorators ---------------------------------------------

    def problem_size(self, fn: Callable[..., tuple[int, ...]]):
        """fn(*args_meta) -> problem-size vector (paper §4.4: interpretation
        is kernel-defined, e.g. (n, k, m) for matmul)."""
        self._problem_size = fn
        return fn

    def build(self, fn: Callable[..., Callable]):
        """fn(config, problem, meta, interpret=False) -> callable(*arrays).
        The callable is what gets jitted+compiled at runtime (paper: NVRTC
        compile); ``interpret=True`` must produce the Pallas interpret-mode
        variant (CPU-executable kernel body)."""
        self._build = fn
        return fn

    def reference(self, fn: Callable):
        """Pure-jnp oracle; also the non-TPU execution path."""
        self._reference = fn
        return fn

    def workload(self, fn: Callable[[Config, tuple, str], Workload]):
        """fn(config, problem, dtype) -> Workload for the cost model."""
        self._workload = fn
        return fn

    def probe(self, fn: Callable[[tuple[int, ...], str], Sequence]):
        """fn(problem, dtype) -> concrete argument arrays for the scenario.

        The inverse of ``problem_size`` the correctness oracle needs: a
        promotion gate only knows (problem, dtype), not the original
        captured arguments, so the probe synthesizes deterministic
        inputs (use :func:`probe_array` with a fixed seed) that the
        built kernel and the reference are both run on."""
        self._probe = fn
        return fn

    # -- accessors ------------------------------------------------------------

    def get_problem_size(self, *args) -> tuple[int, ...]:
        meta = args_meta(*args)
        if self._problem_size is None:
            # default: shape of the first argument
            return tuple(int(d) for d in meta[0].shape)
        return tuple(int(x) for x in self._problem_size(*meta))

    def get_dtype(self, *args) -> str:
        meta = args_meta(*args)
        return str(jnp.dtype(meta[0].dtype).name)

    def make(self, config: Config, meta: ArgsMeta,
             interpret: bool = False) -> Callable:
        if self._build is None:
            raise ValueError(f"kernel {self.name!r} has no build fn")
        self.space.check(config)
        problem = self.get_problem_size(*meta)
        return self._build(dict(config), problem, meta, interpret=interpret)

    def make_reference(self) -> Callable:
        if self._reference is None:
            raise ValueError(f"kernel {self.name!r} has no reference fn")
        return self._reference

    def make_workload(self, config: Config, problem: tuple[int, ...],
                      dtype: str) -> Workload:
        if self._workload is None:
            raise ValueError(f"kernel {self.name!r} has no workload fn")
        return self._workload(dict(config), tuple(problem), dtype)

    def has_probe(self) -> bool:
        """Whether this kernel can synthesize oracle-check arguments."""
        return self._probe is not None

    def make_probe_args(self, problem: tuple[int, ...],
                        dtype: str) -> list[np.ndarray]:
        """Deterministic concrete arguments for (problem, dtype) — what
        the correctness oracle feeds both the built kernel and the
        reference. Raises ``ValueError`` when the kernel registered no
        probe hook (the caller should treat the config as unverifiable
        rather than guessing argument shapes)."""
        if self._probe is None:
            raise ValueError(f"kernel {self.name!r} has no probe fn")
        args = self._probe(tuple(int(x) for x in problem), str(dtype))
        return [np.asarray(a) for a in args]

    def default_config(self) -> Config:
        return self.space.default_config()

    def __repr__(self) -> str:  # pragma: no cover
        return f"KernelBuilder({self.name!r}, space={self.space!r})"
