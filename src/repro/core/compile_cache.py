"""Compiled-kernel cache (paper §4.5 / Fig 5).

The paper caches NVRTC-compiled kernels per (kernel, problem size); we cache
AOT-compiled XLA executables per (kernel, device, problem, dtype, config).
Timings of the miss path are split the same way Fig 5 splits them:
wisdom read / compile / load / launch.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable


@dataclass
class LaunchStats:
    """Per-launch timing record (seconds)."""
    kernel: str
    cached: bool
    wisdom_read_s: float = 0.0
    select_s: float = 0.0
    compile_s: float = 0.0     # trace+lower+compile ("NVRTC" analogue)
    load_s: float = 0.0        # executable construction ("cuModuleLoad")
    launch_s: float = 0.0      # dispatch + wait ("cuLaunchKernel")
    tier: str = ""
    config: dict = field(default_factory=dict)


class CompileCache:
    def __init__(self) -> None:
        self._cache: dict[Any, Callable] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key) -> Callable | None:
        with self._lock:
            fn = self._cache.get(key)
            if fn is not None:
                self.hits += 1
            return fn

    def put(self, key, fn: Callable) -> None:
        with self._lock:
            self._cache[key] = fn
            self.misses += 1

    def get_or_compile(self, key, compile_fn: Callable[[], Callable]
                       ) -> tuple[Callable, float, bool]:
        """Returns (callable, compile_seconds, was_cached)."""
        fn = self.get(key)
        if fn is not None:
            return fn, 0.0, True
        t0 = time.perf_counter()
        fn = compile_fn()
        dt = time.perf_counter() - t0
        self.put(key, fn)
        return fn, dt, False

    def clear(self) -> None:
        with self._lock:
            self._cache.clear()
            self.hits = 0
            self.misses = 0

    def __len__(self) -> int:
        return len(self._cache)
