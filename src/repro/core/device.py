"""Device descriptors.

The paper keys wisdom records by (GPU, architecture) — e.g. ("A100",
"Ampere"). Our analogue is (device *kind*, device *family*). On real TPUs the
kind comes from ``jax.devices()[0].device_kind``; on this CPU-only container
the simulated device pair stands in for the paper's A4000/A100 pair, and the
active kind can be forced with ``KERNEL_LAUNCHER_DEVICE``.

The numeric fields feed the analytical cost model (tuner/costmodel.py).
"""

from __future__ import annotations

import os
from dataclasses import dataclass

import jax

DEVICE_ENV = "KERNEL_LAUNCHER_DEVICE"


@dataclass(frozen=True)
class DeviceSpec:
    kind: str            # e.g. "tpu-v5e"
    family: str          # e.g. "tpu-v5"
    flops_bf16: float    # peak FLOP/s, bf16 on the MXU
    flops_f32: float     # peak FLOP/s, f32
    hbm_bw: float        # HBM bytes/s
    vmem_bytes: int      # per-core VMEM capacity
    ici_bw: float        # per-link interconnect bytes/s
    program_overhead: float  # seconds of fixed overhead per grid program
    num_cores: int = 1


# Simulated pair (stands in for the paper's A4000 / A100, same-vendor,
# different balance point). v5e numbers match the roofline constants in
# EXPERIMENTS.md; v4 is the higher-bandwidth sibling.
TPU_V5E = DeviceSpec(
    kind="tpu-v5e", family="tpu-v5",
    flops_bf16=197e12, flops_f32=98.5e12,
    hbm_bw=819e9, vmem_bytes=16 * 2**20, ici_bw=50e9,
    program_overhead=1.2e-6,
)
TPU_V4 = DeviceSpec(
    kind="tpu-v4", family="tpu-v4",
    flops_bf16=275e12, flops_f32=137.5e12,
    hbm_bw=1228e9, vmem_bytes=32 * 2**20, ici_bw=100e9,
    program_overhead=1.0e-6,
)
CPU_HOST = DeviceSpec(
    kind="cpu", family="cpu",
    flops_bf16=5e11, flops_f32=5e11,
    hbm_bw=4e10, vmem_bytes=1 * 2**20, ici_bw=1e9,
    program_overhead=1e-7,
)

DEVICES: dict[str, DeviceSpec] = {
    d.kind: d for d in (TPU_V5E, TPU_V4, CPU_HOST)
}


def get_device(kind: str) -> DeviceSpec:
    if kind in DEVICES:
        return DEVICES[kind]
    # Unknown real hardware: derive family from the kind string prefix.
    family = "-".join(kind.split("-")[:2]) if "-" in kind else kind
    return DeviceSpec(kind=kind, family=family,
                      flops_bf16=TPU_V5E.flops_bf16,
                      flops_f32=TPU_V5E.flops_f32,
                      hbm_bw=TPU_V5E.hbm_bw, vmem_bytes=TPU_V5E.vmem_bytes,
                      ici_bw=TPU_V5E.ici_bw,
                      program_overhead=TPU_V5E.program_overhead)


#: Capability-vector axes, in order (see :func:`capability_vector`).
CAPABILITY_AXES = ("flops_bf16", "flops_f32", "hbm_bw", "vmem_bytes",
                   "program_overhead")


def capability_vector(spec: DeviceSpec) -> tuple[float, ...]:
    """The numeric capabilities that govern cross-device transfer, as a
    plain tuple in ``CAPABILITY_AXES`` order.

    These are the axes along which a tuned configuration's performance
    moves when the hardware changes: compute throughput (both precisions),
    memory bandwidth, on-chip memory capacity (feasibility!), and
    per-program launch overhead. ``repro.transfer.DeviceModel`` works on
    ratios of these vectors, so the absolute units never matter.
    """
    return (spec.flops_bf16, spec.flops_f32, spec.hbm_bw,
            float(spec.vmem_bytes), spec.program_overhead)


def current_device_kind() -> str:
    """Active device kind: env override, else the real JAX device."""
    env = os.environ.get(DEVICE_ENV)
    if env:
        return env
    kind = jax.devices()[0].device_kind.lower()
    if "tpu" in kind:
        # e.g. "TPU v5 lite" -> "tpu-v5e"
        if "v5" in kind and ("lite" in kind or "v5e" in kind):
            return "tpu-v5e"
        if "v4" in kind:
            return "tpu-v4"
        return kind.replace(" ", "-")
    return "cpu"


def current_device() -> DeviceSpec:
    return get_device(current_device_kind())
