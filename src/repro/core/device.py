"""Device descriptors.

The paper keys wisdom records by (GPU, architecture) — e.g. ("A100",
"Ampere"). Our analogue is (device *kind*, device *family*). On real TPUs the
kind comes from ``jax.devices()[0].device_kind``; on this CPU-only container
the simulated device pair stands in for the paper's A4000/A100 pair, and the
active kind can be forced with ``KERNEL_LAUNCHER_DEVICE``.

Two device *backends* are modeled (plus the CPU host): the TPU family the
repo grew up on, and a GPU family mirroring the paper's actual hardware
pair (an A100-class and an A4000-class part). ``DeviceSpec.backend``
drives kernel lowering (``repro.kernels._lowering``) — TPU-only Mosaic
compiler params must never reach a Triton lowering and vice versa — and
enters the transfer layer's similarity model (cross-backend predictions
are possible but confidence-penalized).

Unknown hardware is handled *honestly*: :func:`get_device` used to clone
TPU-v5e peak numbers for any unrecognized kind with no marker, which made
the cost model, roofline attribution, and transfer confidence silently
wrong on new hardware. Unknown kinds now come back flagged
``estimated=True``; the transfer model floors similarity for estimated
specs and roofline reports annotate fractions computed against guessed
peaks.

The numeric fields feed the analytical cost model (tuner/costmodel.py).
"""

from __future__ import annotations

import os
from dataclasses import dataclass, replace

import jax

DEVICE_ENV = "KERNEL_LAUNCHER_DEVICE"

#: Device backends a spec can declare; selects the kernel lowering path.
BACKENDS = ("tpu", "gpu", "cpu")


@dataclass(frozen=True)
class DeviceSpec:
    kind: str            # e.g. "tpu-v5e"
    family: str          # e.g. "tpu-v5"
    flops_bf16: float    # peak FLOP/s, bf16 on the MXU / tensor cores
    flops_f32: float     # peak FLOP/s, f32
    hbm_bw: float        # HBM bytes/s
    vmem_bytes: int      # per-core VMEM (TPU) / L2+shared (GPU) capacity
    ici_bw: float        # per-link interconnect bytes/s
    program_overhead: float  # seconds of fixed overhead per grid program
    num_cores: int = 1
    #: Which kernel lowering this device wants ("tpu" | "gpu" | "cpu").
    backend: str = "tpu"
    #: True when the peak numbers are guesses (unknown hardware cloned
    #: from a per-backend baseline), not a measured/spec'd part. Roofline
    #: fractions against an estimated spec are annotated, and the
    #: transfer model floors similarity so estimated pairs never clear
    #: the serving gate.
    estimated: bool = False
    #: Systolic-array / tensor-core tile granule the matmul unit pads
    #: each tile dimension to (128 on the TPU MXU, 16 on Ampere tensor
    #: cores) — feeds the cost model's alignment efficiency.
    matmul_granule: int = 128
    #: Matmul-unit peak over vector-unit peak (TPU VPU sits ~8x below
    #: the MXU; GPU CUDA-core f32 is a much smaller step down).
    vector_ratio: float = 8.0


# Simulated TPU pair (same-vendor, different balance point). v5e numbers
# match the roofline constants in EXPERIMENTS.md; v4 is the
# higher-bandwidth sibling.
TPU_V5E = DeviceSpec(
    kind="tpu-v5e", family="tpu-v5",
    flops_bf16=197e12, flops_f32=98.5e12,
    hbm_bw=819e9, vmem_bytes=16 * 2**20, ici_bw=50e9,
    program_overhead=1.2e-6,
)
TPU_V4 = DeviceSpec(
    kind="tpu-v4", family="tpu-v4",
    flops_bf16=275e12, flops_f32=137.5e12,
    hbm_bw=1228e9, vmem_bytes=32 * 2**20, ici_bw=100e9,
    program_overhead=1.0e-6,
)
# The training-class v5 part and the v6e (Trillium) generation: their
# raw kind strings ("TPU v5", "TPU v5p", "TPU v6 lite") used to fall
# through the generic slugifier into prefix-derived families that
# inherited the wrong peaks.
TPU_V5P = DeviceSpec(
    kind="tpu-v5p", family="tpu-v5p",
    flops_bf16=459e12, flops_f32=229.5e12,
    hbm_bw=2765e9, vmem_bytes=64 * 2**20, ici_bw=200e9,
    program_overhead=1.0e-6,
)
TPU_V6E = DeviceSpec(
    kind="tpu-v6e", family="tpu-v6",
    flops_bf16=918e12, flops_f32=459e12,
    hbm_bw=1640e9, vmem_bytes=64 * 2**20, ici_bw=100e9,
    program_overhead=1.1e-6,
)

# GPU pair mirroring the paper's actual hardware (A100 data-center part,
# A4000 workstation part — same architecture, ~4x apart in throughput).
# vmem_bytes models the L2 cache (the on-chip capacity a Triton tile's
# working set must respect); granule 16 is the Ampere tensor-core tile.
GPU_A100 = DeviceSpec(
    kind="gpu-a100", family="gpu-ampere",
    flops_bf16=312e12, flops_f32=156e12,
    hbm_bw=1555e9, vmem_bytes=40 * 2**20, ici_bw=600e9,
    program_overhead=2.2e-6,
    backend="gpu", matmul_granule=16, vector_ratio=8.0,
)
GPU_A4000 = DeviceSpec(
    kind="gpu-a4000", family="gpu-ampere",
    flops_bf16=76.7e12, flops_f32=38.3e12,
    hbm_bw=448e9, vmem_bytes=4 * 2**20, ici_bw=32e9,
    program_overhead=3.0e-6,
    backend="gpu", matmul_granule=16, vector_ratio=2.0,
)

CPU_HOST = DeviceSpec(
    kind="cpu", family="cpu",
    flops_bf16=5e11, flops_f32=5e11,
    hbm_bw=4e10, vmem_bytes=1 * 2**20, ici_bw=1e9,
    program_overhead=1e-7,
    backend="cpu",
)

DEVICES: dict[str, DeviceSpec] = {
    d.kind: d for d in (TPU_V5E, TPU_V4, TPU_V5P, TPU_V6E,
                        GPU_A100, GPU_A4000, CPU_HOST)
}

#: Per-backend baseline an unknown kind's peaks are cloned from — the
#: closest thing to a guess we can make, and the spec is flagged
#: ``estimated`` so every consumer knows it is one.
_BACKEND_BASELINE: dict[str, DeviceSpec] = {
    "tpu": TPU_V5E, "gpu": GPU_A100, "cpu": CPU_HOST,
}


def infer_backend(kind: str) -> str:
    """Best-effort backend for a device kind string (prefix only)."""
    if kind.startswith("gpu"):
        return "gpu"
    if kind.startswith("cpu"):
        return "cpu"
    return "tpu"


def get_device(kind: str) -> DeviceSpec:
    if kind in DEVICES:
        return DEVICES[kind]
    # Unknown real hardware: clone the backend's baseline peaks but mark
    # the spec estimated — consumers (cost model fractions, transfer
    # similarity) must not treat guessed numbers as ground truth.
    family = "-".join(kind.split("-")[:2]) if "-" in kind else kind
    backend = infer_backend(kind)
    return replace(_BACKEND_BASELINE[backend],
                   kind=kind, family=family, estimated=True)


#: Capability-vector axes, in order (see :func:`capability_vector`).
CAPABILITY_AXES = ("flops_bf16", "flops_f32", "hbm_bw", "vmem_bytes",
                   "program_overhead")


def capability_vector(spec: DeviceSpec) -> tuple[float, ...]:
    """The numeric capabilities that govern cross-device transfer, as a
    plain tuple in ``CAPABILITY_AXES`` order.

    These are the axes along which a tuned configuration's performance
    moves when the hardware changes: compute throughput (both precisions),
    memory bandwidth, on-chip memory capacity (feasibility!), and
    per-program launch overhead. ``repro.transfer.DeviceModel`` works on
    ratios of these vectors, so the absolute units never matter. The
    ``backend`` and ``estimated`` flags are *not* axes — they enter the
    model as a similarity penalty and floor instead (a ratio cannot
    express "different instruction set entirely").
    """
    return (spec.flops_bf16, spec.flops_f32, spec.hbm_bw,
            float(spec.vmem_bytes), spec.program_overhead)


#: Raw ``device_kind`` substring -> canonical kind, checked in order
#: (first match wins, so the "lite"/"e" variants are tested before the
#: bare generation markers — "tpu v5 lite" contains "v5" too).
_TPU_KIND_TABLE: tuple[tuple[str, str], ...] = (
    ("v5e", "tpu-v5e"),
    ("v5 lite", "tpu-v5e"),
    ("v5lite", "tpu-v5e"),
    ("v5p", "tpu-v5p"),
    ("v5", "tpu-v5p"),          # v5p hosts report a bare "TPU v5"
    ("v6e", "tpu-v6e"),
    ("v6 lite", "tpu-v6e"),
    ("v6lite", "tpu-v6e"),
    ("v4", "tpu-v4"),
)

_GPU_KIND_TABLE: tuple[tuple[str, str], ...] = (
    ("a100", "gpu-a100"),
    ("a4000", "gpu-a4000"),
)


def parse_device_kind(raw: str, platform: str = "") -> str:
    """Canonical device kind for a raw JAX ``device_kind`` string.

    ``raw`` is what ``jax.devices()[0].device_kind`` reports (e.g.
    "TPU v5 lite", "TPU v5p", "NVIDIA A100-SXM4-40GB"); ``platform`` is
    the JAX platform name ("tpu" / "gpu" / "cpu") and disambiguates GPU
    strings that never mention their vendor. Unrecognized hardware slugs
    to a prefixed kind ("tpu-…" / "gpu-…") so :func:`get_device` can at
    least pick the right backend baseline for its estimated spec.
    """
    kind = raw.lower()
    if "tpu" in kind or platform == "tpu":
        for marker, canonical in _TPU_KIND_TABLE:
            if marker in kind:
                return canonical
        slug = kind.replace(" ", "-")
        return slug if slug.startswith("tpu") else f"tpu-{slug}"
    if platform == "gpu" or any(v in kind for v in ("nvidia", "amd",
                                                    "rocm", "cuda")):
        for marker, canonical in _GPU_KIND_TABLE:
            if marker in kind:
                return canonical
        slug = kind.replace(" ", "-")
        return slug if slug.startswith("gpu") else f"gpu-{slug}"
    return "cpu"


def current_device_kind() -> str:
    """Active device kind: env override, else the real JAX device."""
    env = os.environ.get(DEVICE_ENV)
    if env:
        return env
    dev = jax.devices()[0]
    return parse_device_kind(dev.device_kind,
                             getattr(dev, "platform", ""))


def current_device() -> DeviceSpec:
    return get_device(current_device_kind())
