"""Strategy benchmarking harness: fraction-of-optimum curves + thresholds.

The methodology follows the auto-tuning benchmarking literature
(Schoonhoven et al., "Benchmarking optimization algorithms for
auto-tuning GPU kernels"; Tørring et al., "Towards a Benchmarking Suite
for Kernel Tuners"): run every strategy against the *same recorded
search space* (:class:`~repro.tunebench.simulate.SimulatedRunner`), and
report, per evaluation budget, the fraction of the space's known optimum
the strategy's best-so-far has reached:

    fraction(b) = optimum_score / best_score_after_b_evaluations

1.0 means the optimum was found; curves are monotone nondecreasing in
the budget. Everything is seeded and replayed, so a report is a pure
function of (datasets, strategies, budget, seeds) — byte-identical
across runs — and per-strategy *thresholds* on the final fraction turn
the comparison into a regression gate a CI job can fail on.

See ``docs/strategy-benchmarking.md`` for how to read the curves and how
to add a recorded space.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.tuner.strategies import STRATEGIES, TuningResult

from .dataset import SpaceDataset
from .simulate import SimulatedRunner

#: Report schema version (bump on structural changes).
#: v2: per-strategy ``wasted_evals`` + ``verdicts`` (sandbox-verdict
#: replay — budget burned re-proposing known-fatal configs).
REPORT_VERSION = 2

#: Default evaluation budget per simulated session.
DEFAULT_BUDGET = 64

#: Seeds averaged per strategy (each seed is one independent session).
DEFAULT_SEEDS = (0, 1, 2)

#: Regression gates on the mean final fraction-of-optimum. Set with
#: margin below the values the shipped recorded spaces produce today
#: (see benchmarks/strategy_bench.py); a strategy change that drops below
#: its gate made the tuner *worse* and should fail CI, not silently ship
#: worse wisdom. Exhaustive enumerates a lexicographic prefix, so at
#: partial budget it is a coverage baseline, not a competitor — its gate
#: only catches enumeration-order regressions.
DEFAULT_THRESHOLDS = {
    "random": 0.80,
    "bayes": 0.90,
    "anneal": 0.80,
    "exhaustive": 0.25,
}


@dataclass
class StrategyOutcome:
    """One strategy's aggregated performance on one dataset."""
    strategy: str
    threshold: float
    mean_curve: list[float]           # fraction-of-optimum per budget step
    final_fraction: float             # mean over seeds at full budget
    per_seed_final: list[float]
    per_seed_best_us: list[float]
    passed: bool = field(default=False)
    #: Evaluations spent re-proposing configs whose recorded sandbox
    #: verdict already said they fail fatally (summed over seeds). Live,
    #: each one costs a timeout or a child-process death — lower is
    #: better, and a strategy that won't learn from crashes shows up
    #: here even when its fraction-of-optimum looks fine.
    wasted_evals: int = 0
    #: Replayed sandbox verdicts by status, summed over seeds.
    verdicts: dict = field(default_factory=dict)

    def to_json(self) -> dict:
        return {"strategy": self.strategy, "threshold": self.threshold,
                "mean_curve": self.mean_curve,
                "final_fraction": self.final_fraction,
                "per_seed_final": self.per_seed_final,
                "per_seed_best_us": self.per_seed_best_us,
                "wasted_evals": self.wasted_evals,
                "verdicts": {k: self.verdicts[k]
                             for k in sorted(self.verdicts)},
                "pass": self.passed}


def run_on_dataset(dataset: SpaceDataset, strategy: str,
                   budget: int = DEFAULT_BUDGET,
                   seed: int = 0,
                   runner: SimulatedRunner | None = None) -> TuningResult:
    """One simulated tuning session: ``strategy`` over the recorded space.

    Wall-clock budgets are disabled (simulation must not depend on host
    speed); the evaluation budget is the only binding constraint.
    ``runner`` lets a caller supply the :class:`SimulatedRunner` so it
    can read the replay counters (hits, verdicts, wasted evals) after
    the session.
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; "
                         f"have {sorted(STRATEGIES)}")
    sim = runner if runner is not None else SimulatedRunner(dataset)
    space = dataset.space()
    if strategy == "exhaustive":
        return STRATEGIES["exhaustive"](space, sim, limit=budget)
    return STRATEGIES[strategy](space, sim, max_evals=budget,
                                rng=np.random.default_rng(seed),
                                time_budget_s=None)


def fraction_curve(dataset: SpaceDataset, result: TuningResult,
                   budget: int) -> list[float]:
    """Fraction-of-optimum after each evaluation, padded to ``budget``.

    Entry ``i`` is ``optimum / best_so_far`` after ``i + 1`` evaluations
    (0.0 while nothing feasible has been seen). Sessions that exhaust the
    space early are padded with their final value — stopping early with
    the optimum in hand is not a regression.
    """
    optimum = dataset.best()
    opt = optimum.score_us if optimum is not None else math.inf
    curve: list[float] = []
    best = math.inf
    for e in result.evaluations[:budget]:
        if e.feasible and e.score_us < best:
            best = e.score_us
        curve.append(0.0 if not math.isfinite(best) else opt / best)
    last = curve[-1] if curve else 0.0
    curve.extend([last] * (budget - len(curve)))
    return [round(f, 6) for f in curve]


def compare(datasets: Sequence[SpaceDataset],
            strategies: Sequence[str] | None = None,
            budget: int = DEFAULT_BUDGET,
            seeds: Sequence[int] = DEFAULT_SEEDS,
            thresholds: dict[str, float] | None = None) -> dict:
    """Benchmark every strategy against every recorded space.

    Returns the machine-readable report (JSON-serializable, stable key
    order, no timestamps): per dataset, per strategy, the mean
    fraction-of-optimum curve, the final fraction, and whether it cleared
    its threshold; a top-level ``"pass"`` ands them all. Deterministic:
    the same inputs produce a byte-identical document.
    """
    strategies = list(strategies if strategies is not None
                      else sorted(STRATEGIES))
    gates = dict(DEFAULT_THRESHOLDS)
    gates.update(thresholds or {})
    out_datasets = []
    all_pass = True
    for ds in datasets:
        optimum = ds.best()
        outcomes = []
        for name in strategies:
            curves, finals, bests = [], [], []
            wasted = 0
            verdicts: dict[str, int] = {}
            # Exhaustive enumeration ignores the seed: one session is the
            # whole sample (replicating it would both waste simulation
            # time and dress a constant up as per-seed statistics).
            strategy_seeds = (list(seeds)[:1] if name == "exhaustive"
                              else seeds)
            for seed in strategy_seeds:
                sim = SimulatedRunner(ds)
                result = run_on_dataset(ds, name, budget=budget, seed=seed,
                                        runner=sim)
                curve = fraction_curve(ds, result, budget)
                curves.append(curve)
                finals.append(curve[-1] if curve else 0.0)
                bests.append(round(result.best_score_us, 6)
                             if result.best_config is not None else None)
                wasted += sim.wasted_evals
                for v, n in sim.verdicts.items():
                    verdicts[v] = verdicts.get(v, 0) + n
            mean_curve = [round(float(np.mean(col)), 6)
                          for col in zip(*curves)] if curves else []
            final = round(float(np.mean(finals)), 6) if finals else 0.0
            threshold = float(gates.get(name, 0.0))
            outcome = StrategyOutcome(
                strategy=name, threshold=threshold, mean_curve=mean_curve,
                final_fraction=final, per_seed_final=finals,
                per_seed_best_us=bests, passed=final >= threshold,
                wasted_evals=wasted, verdicts=verdicts)
            all_pass = all_pass and outcome.passed
            outcomes.append(outcome)
        out_datasets.append({
            "dataset": ds.name(),
            "kernel": ds.kernel,
            "scenario": ds.scenario_key(),
            "objective": ds.objective,
            "entries": len(ds),
            "feasible": len(ds.feasible()),
            "optimum_us": (round(optimum.score_us, 6)
                           if optimum is not None else None),
            "strategies": [o.to_json() for o in outcomes],
        })
    return {
        "version": REPORT_VERSION,
        "budget": int(budget),
        "seeds": [int(s) for s in seeds],
        "strategies": strategies,
        "pass": all_pass,
        "datasets": out_datasets,
    }


def report_to_text(report: dict) -> str:
    """Human-readable rendering of a :func:`compare` report: one block
    per dataset with each strategy's final fraction, threshold verdict,
    and curve marks at 25/50/100% of the budget (what the ``compare``
    and ``report`` CLI subcommands print)."""
    lines = [f"strategy benchmark report (budget={report['budget']} evals, "
             f"seeds={report['seeds']})"]
    for ds in report["datasets"]:
        lines.append(f"\n{ds['dataset']}  "
                     f"[{ds['feasible']}/{ds['entries']} feasible, "
                     f"optimum {ds['optimum_us']}us]")
        for s in ds["strategies"]:
            curve = s["mean_curve"]
            marks = [curve[max(0, min(len(curve) - 1,
                                      int(q * len(curve)) - 1))]
                     if curve else 0.0 for q in (0.25, 0.5, 1.0)]
            status = "ok  " if s["pass"] else "FAIL"
            wasted = s.get("wasted_evals", 0)
            lines.append(
                f"  {status} {s['strategy']:<10} "
                f"final={s['final_fraction']:.4f} "
                f"(threshold {s['threshold']:.2f})  "
                f"curve@25/50/100%: "
                + "/".join(f"{m:.3f}" for m in marks)
                + (f"  wasted={wasted}" if wasted else ""))
    lines.append(f"\noverall: {'PASS' if report['pass'] else 'FAIL'}")
    return "\n".join(lines)


def dump_report(report: dict) -> str:
    """Canonical byte form of a report (what ``--out`` writes): sorted
    keys, two-space indent, trailing newline. Byte-identical for equal
    reports — the acceptance criterion the CI job and
    ``benchmarks/strategy_bench.py`` both check."""
    return json.dumps(report, indent=2, sort_keys=True) + "\n"
