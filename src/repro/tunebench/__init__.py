"""Recorded tuning-space datasets + simulated strategy benchmarking.

Beyond-paper subsystem. The paper's workflow (capture → tune → wisdom)
keeps only each tuning session's winner; this package keeps the whole
search: every ``(config, score, status)`` evaluation of a scenario lands
in a schema-versioned :class:`SpaceDataset`, a :class:`SimulatedRunner`
replays recorded spaces so all strategies run deterministically with
zero hardware, and the harness turns the replays into
fraction-of-optimum-vs-budget curves with per-strategy regression
thresholds — the dataset-driven methodology of the auto-tuning
benchmarking literature (Schoonhoven et al.; Tørring et al.).

* :mod:`.dataset`  — :class:`SpaceDataset` (versioned JSON, config-hash
  keys), :class:`DatasetStore`, recording and warm-start plumbing;
* :mod:`.simulate` — :class:`SimulatedRunner`: datasets as objectives;
* :mod:`.harness`  — :func:`compare`: strategies x datasets ->
  machine-readable report with thresholds;
* :mod:`.cli`      — ``python -m repro.tunebench``
  (record / run / compare / report).

Docs: ``docs/tuning-datasets.md`` (format),
``docs/strategy-benchmarking.md`` (methodology).
"""

from .dataset import (DATASET_SUFFIX, DATASET_VERSION, DatasetStore,
                      DatasetVersionError, SpaceDataset, SpaceEvaluation,
                      dataset_doc_version, history_from_dataset,
                      migrate_dataset_doc, record_space)
from .harness import (DEFAULT_BUDGET, DEFAULT_SEEDS, DEFAULT_THRESHOLDS,
                      REPORT_VERSION, compare, dump_report, fraction_curve,
                      report_to_text, run_on_dataset)
from .simulate import DatasetMiss, SimulatedRunner

__all__ = [
    "DATASET_SUFFIX", "DATASET_VERSION", "DatasetStore",
    "DatasetVersionError", "SpaceDataset", "SpaceEvaluation",
    "dataset_doc_version", "history_from_dataset", "migrate_dataset_doc",
    "record_space",
    "DEFAULT_BUDGET", "DEFAULT_SEEDS", "DEFAULT_THRESHOLDS",
    "REPORT_VERSION", "compare", "dump_report", "fraction_curve",
    "report_to_text", "run_on_dataset",
    "DatasetMiss", "SimulatedRunner",
]
