"""Simulated tuning: replay a recorded space instead of measuring.

A :class:`SimulatedRunner` is a drop-in ``Evaluate`` callable (the same
shape the tuner's evaluators have) whose answers come from a
:class:`~repro.tunebench.dataset.SpaceDataset` lookup instead of the cost
model or real hardware. Every strategy in
:mod:`repro.tuner.strategies` runs against it unchanged, deterministically
and in microseconds per evaluation — which is what makes strategy
comparison (:mod:`repro.tunebench.harness`) and tuner regression tests
possible on machines with no accelerator at all.
"""

from __future__ import annotations

from repro.core.param import Config
from repro.tuner.costmodel import INFEASIBLE
from repro.tuner.runner import EvalResult

from .dataset import SpaceDataset


class DatasetMiss(KeyError):
    """A strategy proposed a config the dataset has no record for and the
    runner was constructed with ``on_miss="error"``."""


class SimulatedRunner:
    """Replay recorded evaluations; never touches hardware.

    ``on_miss`` decides what an unrecorded config means:

    * ``"infeasible"`` (default) — treat it as infeasible. Exhaustively
      recorded datasets only miss on restricted configs, so this matches
      what live tuning would have seen.
    * ``"error"`` — raise :class:`DatasetMiss`. Use when the dataset is
      expected to be complete and a miss means the space drifted out from
      under the recording.

    Example::

        ds = SpaceDataset.load("matmul.space.json")
        sim = SimulatedRunner(ds)
        res = tune_bayes(ds.space(), sim, max_evals=64,
                         rng=np.random.default_rng(0), time_budget_s=None)
    """

    def __init__(self, dataset: SpaceDataset, on_miss: str = "infeasible"):
        if on_miss not in ("infeasible", "error"):
            raise ValueError(f"unknown on_miss policy {on_miss!r}")
        self.dataset = dataset
        self.on_miss = on_miss
        self.calls = 0
        self.hits = 0
        self.misses = 0
        #: Replayed sandbox verdicts, counted by status (entries recorded
        #: with a ``verdict`` — crashed/hung/oom/wrong configs).
        self.verdicts: dict[str, int] = {}
        #: Evaluations spent re-proposing a config whose recorded verdict
        #: already said it fails fatally. Live, each of these would cost a
        #: full sandbox timeout or a child-process death — a strategy
        #: that keeps walking into them wastes real tuning budget.
        self.wasted_evals = 0
        self._seen_fatal: set[str] = set()

    def __call__(self, config: Config) -> EvalResult:
        self.calls += 1
        entry = self.dataset.lookup(config)
        if entry is None:
            self.misses += 1
            if self.on_miss == "error":
                raise DatasetMiss(
                    f"config {config} not in dataset "
                    f"{self.dataset.name()} ({len(self.dataset)} entries)")
            return EvalResult(INFEASIBLE, False, error="not in dataset")
        self.hits += 1
        if not entry.feasible:
            if entry.verdict:
                # Replay the sandbox verdict the way a live
                # SandboxedEvaluator would report it, and charge repeat
                # proposals of a known-fatal config as wasted budget.
                self.verdicts[entry.verdict] = (
                    self.verdicts.get(entry.verdict, 0) + 1)
                key = self.dataset.key_for(config)
                if key in self._seen_fatal:
                    self.wasted_evals += 1
                self._seen_fatal.add(key)
                error = entry.error or f"sandbox:{entry.verdict}"
                if not error.startswith("sandbox:"):
                    error = f"sandbox:{entry.verdict}: {error}"
                return EvalResult(INFEASIBLE, False, error=error,
                                  info={"sandbox": entry.verdict})
            return EvalResult(INFEASIBLE, False, error=entry.error)
        return EvalResult(entry.score_us, True)
