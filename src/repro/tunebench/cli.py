"""``python -m repro.tunebench`` — record, replay, and compare.

Subcommands:

  record    exhaustively evaluate one scenario's config space into a
            ``*.space.json`` dataset (deterministic under the cost-model
            objective)
  run       one simulated tuning session of one strategy over a recorded
            space; prints the result (``--json`` for machines)
  compare   every strategy x every dataset -> fraction-of-optimum report
            with per-strategy regression thresholds (``--check`` exits
            non-zero when any strategy is below its gate)
  report    render a previously written report JSON as text

The loop end to end::

    python -m repro.tunebench record --kernel matmul \
        --problem 256,256,256 --dtype float32 --device tpu-v5e --out ds/
    python -m repro.tunebench compare --datasets 'ds/*.space.json' \
        --out report.json --check
    python -m repro.tunebench report report.json
"""

from __future__ import annotations

import argparse
import glob
import json
import sys
from pathlib import Path

from repro.core.registry import get_kernel
from repro.tuner.strategies import STRATEGIES

from .dataset import DATASET_SUFFIX, DatasetStore, SpaceDataset, record_space
from .harness import (DEFAULT_BUDGET, DEFAULT_SEEDS, compare, dump_report,
                      report_to_text, run_on_dataset)


def _parse_problem(text: str) -> tuple[int, ...]:
    return tuple(int(x) for x in text.replace("x", ",").split(",") if x)


def _cmd_record(args) -> int:
    builder = get_kernel(args.kernel)
    problem = _parse_problem(args.problem)
    ds = record_space(builder, problem, args.dtype, args.device,
                      objective=args.objective, limit=args.limit)
    out = Path(args.out)
    if out.suffix == ".json" or str(out).endswith(DATASET_SUFFIX):
        path = ds.save(out)
    else:
        path = DatasetStore(out).save(ds)
    best = ds.best()
    print(f"recorded {len(ds)} evaluation(s) "
          f"({len(ds.feasible())} feasible) -> {path}")
    if best is not None:
        print(f"optimum: {best.score_us:.2f}us {best.config}")
    return 0


def _load_datasets(patterns: list[str]) -> list[SpaceDataset]:
    paths: list[str] = []
    for pat in patterns:
        paths.extend(sorted(glob.glob(pat)))
    return [SpaceDataset.load(p) for p in dict.fromkeys(paths)]


def _cmd_run(args) -> int:
    ds = SpaceDataset.load(args.dataset)
    result = run_on_dataset(ds, args.strategy, budget=args.budget,
                            seed=args.seed)
    optimum = ds.best()
    payload = {
        "dataset": ds.name(), "strategy": args.strategy,
        "budget": args.budget, "seed": args.seed,
        "evals": len(result.evaluations),
        "best_score_us": (round(result.best_score_us, 6)
                          if result.best_config is not None else None),
        "best_config": result.best_config,
        "optimum_us": (round(optimum.score_us, 6)
                       if optimum is not None else None),
    }
    if args.json:
        print(json.dumps(payload, indent=2, sort_keys=True))
        return 0
    print(f"{payload['dataset']}: {args.strategy} x{payload['evals']} "
          f"evals -> best={payload['best_score_us']}us "
          f"(optimum {payload['optimum_us']}us)")
    print(f"config: {payload['best_config']}")
    return 0


def _cmd_compare(args) -> int:
    datasets = _load_datasets(args.datasets)
    if not datasets:
        print(f"no datasets match {args.datasets!r}", file=sys.stderr)
        return 1
    seeds = tuple(int(s) for s in args.seeds.split(","))
    report = compare(datasets, strategies=args.strategies,
                     budget=args.budget, seeds=seeds)
    text = dump_report(report)
    if args.out:
        Path(args.out).write_text(text)
        print(f"report -> {args.out}")
    print(report_to_text(report))
    if args.check and not report["pass"]:
        return 1
    return 0


def _cmd_report(args) -> int:
    with open(args.report) as f:
        report = json.load(f)
    print(report_to_text(report))
    if args.check and not report.get("pass", False):
        return 1
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tunebench",
        description="Recorded tuning-space datasets and simulated "
                    "strategy benchmarking.")
    sub = ap.add_subparsers(dest="command", required=True)

    p = sub.add_parser("record",
                       help="exhaustively record one scenario's space")
    p.add_argument("--kernel", required=True)
    p.add_argument("--problem", required=True,
                   help="problem size, e.g. 256,256,256 or 256x256x256")
    p.add_argument("--dtype", default="float32")
    p.add_argument("--device", default="tpu-v5e")
    p.add_argument("--objective", default="costmodel",
                   choices=("costmodel",),
                   help="wallclock recording goes through the tuner's "
                        "--record-dataset instead (needs captured args)")
    p.add_argument("--limit", type=int, default=None,
                   help="cap on configs evaluated (default: whole space)")
    p.add_argument("--out", default="datasets",
                   help="dataset directory, or an explicit *.space.json "
                        "path")
    p.set_defaults(fn=_cmd_record)

    p = sub.add_parser("run", help="one simulated session on one dataset")
    p.add_argument("--dataset", required=True)
    p.add_argument("--strategy", default="bayes",
                   choices=sorted(STRATEGIES))
    p.add_argument("--budget", type=int, default=DEFAULT_BUDGET)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_run)

    p = sub.add_parser("compare",
                       help="all strategies x all datasets -> report")
    p.add_argument("--datasets", nargs="+",
                   default=[f"datasets/*{DATASET_SUFFIX}"],
                   help="dataset globs")
    p.add_argument("--strategies", nargs="+", default=None,
                   choices=sorted(STRATEGIES))
    p.add_argument("--budget", type=int, default=DEFAULT_BUDGET)
    p.add_argument("--seeds", default=",".join(str(s)
                                               for s in DEFAULT_SEEDS))
    p.add_argument("--out", default=None, help="write report JSON here")
    p.add_argument("--check", action="store_true",
                   help="exit non-zero when any strategy misses its "
                        "threshold")
    p.set_defaults(fn=_cmd_compare)

    p = sub.add_parser("report", help="render a report JSON as text")
    p.add_argument("report")
    p.add_argument("--check", action="store_true")
    p.set_defaults(fn=_cmd_report)
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
