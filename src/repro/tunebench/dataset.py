"""Recorded tuning-space datasets — the search space as a reusable asset.

The capture → tune → wisdom workflow (paper §4.2–§4.4) keeps only each
session's *winner* and discards every other evaluation. A
:class:`SpaceDataset` keeps them all: one schema-versioned JSON document
per (kernel, device, problem size, dtype) scenario holding every
``(config, score, status)`` the objective ever produced for that
scenario, keyed by :meth:`~repro.core.param.ConfigSpace.config_hash`.
Recorded spaces are what make strategies comparable (replay the same
space through every strategy, deterministically, with zero hardware —
:mod:`repro.tunebench.simulate`), the tuner regression-testable
(:mod:`repro.tunebench.harness`), and cost models fittable from data
(:func:`repro.tuner.costmodel.fit_from_dataset`).

Like wisdom files, the format is versioned (``DATASET_VERSION``), loads
migrate old documents in memory, and documents from a *newer* schema are
refused loudly (:class:`DatasetVersionError`) rather than silently
misread. See ``docs/tuning-datasets.md`` for the field-by-field spec.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Sequence

from repro.core.param import Config, ConfigSpace
from repro.tuner.runner import EvalResult
from repro.tuner.strategies import Evaluation

#: Current on-disk schema version for ``*.space.json`` documents.
DATASET_VERSION = 1

#: Filename suffix for dataset files (mirrors ``.wisdom.json``).
DATASET_SUFFIX = ".space.json"

#: Score stored for evaluations that produced no finite time.
_INFEASIBLE = float("inf")


class DatasetVersionError(ValueError):
    """A dataset document declares a schema version this build cannot
    handle. Raised for documents from the *future* (version >
    ``DATASET_VERSION``): partially reading them could silently corrupt a
    benchmark baseline, so loading refuses loudly instead."""


def dataset_doc_version(doc: dict) -> int:
    """Schema version a dataset document declares (missing counts as 1)."""
    try:
        return int(doc.get("version", 1))
    except (TypeError, ValueError):
        raise DatasetVersionError(
            f"dataset document declares non-integer version "
            f"{doc.get('version')!r}") from None


def migrate_dataset_doc(doc: dict, source: str = "<memory>") -> dict:
    """Migrate a dataset document to the current ``DATASET_VERSION``.

    Returns a new document (the input is not mutated). Documents from a
    newer schema raise :class:`DatasetVersionError` — refusing loudly
    beats silently dropping fields a future writer considered essential.
    """
    version = dataset_doc_version(doc)
    if version > DATASET_VERSION:
        raise DatasetVersionError(
            f"dataset document {source} has version {version}, but this "
            f"build understands at most {DATASET_VERSION}; upgrade before "
            f"loading it (evaluations were NOT read)")
    out = json.loads(json.dumps(doc))      # deep copy, JSON-clean
    out["version"] = DATASET_VERSION
    return out


@dataclass
class SpaceEvaluation:
    """One recorded evaluation: a config, its score, and what happened.

    ``status`` is ``"ok"`` (feasible, ``score_us`` is the objective
    value) or ``"infeasible"`` (restricted, VMEM overflow, failed
    verification, build error — ``error`` says which, ``score_us`` is
    ``inf``). ``verdict`` optionally carries the sandbox verdict that
    produced an infeasible entry (``timeout``/``crash``/``oom``/
    ``numerics-mismatch`` — see :mod:`repro.sandbox.verdict`), so a
    replayed space remembers *how* a config failed, not just that it
    did; benchmarks charge strategies for re-proposing known-fatal
    configs. ``profile`` optionally carries the roofline counters the
    profiler attached to the evaluation (:func:`repro.prof.profile_fields`
    — FLOPs, HBM bytes, roofline time terms, bottleneck class), which is
    what lets :func:`repro.tuner.costmodel.fit_from_dataset` learn from
    hardware structure instead of raw config coordinates. Both are
    empty for ordinary entries and omitted from JSON, keeping previously
    recorded datasets byte-identical."""

    config: Config
    score_us: float
    status: str
    error: str = ""
    verdict: str = ""
    profile: dict = field(default_factory=dict)

    @property
    def feasible(self) -> bool:
        return self.status == "ok"

    def to_json(self) -> dict:
        out = {"config": dict(self.config),
               "score_us": (self.score_us if self.feasible else None),
               "status": self.status, "error": self.error}
        if self.verdict:
            out["verdict"] = self.verdict
        if self.profile:
            out["profile"] = dict(self.profile)
        return out

    @staticmethod
    def from_json(d: dict) -> "SpaceEvaluation":
        score = d.get("score_us")
        return SpaceEvaluation(
            config=dict(d["config"]),
            score_us=(_INFEASIBLE if score is None else float(score)),
            status=str(d.get("status", "ok")),
            error=str(d.get("error", "")),
            verdict=str(d.get("verdict", "")),
            profile=dict(d.get("profile", {})))


class SpaceDataset:
    """Every recorded evaluation of one tuning scenario's config space.

    A dataset is self-describing: it snapshots the parameter table
    (names, value sets, defaults — in declaration order, which fixes the
    ``config_hash`` key derivation) so a recorded space can be replayed
    on a host that does not even have the kernel registered.

    Example::

        ds = SpaceDataset("matmul", builder.space, (256, 256, 256),
                          "float32", "tpu-v5e")
        ds.add({"block_m": 128, ...}, 412.7, "ok")
        ds.save("matmul.space.json")
    """

    def __init__(self, kernel: str, space: ConfigSpace,
                 problem_size: Sequence[int], dtype: str, device_kind: str,
                 objective: str = "costmodel",
                 provenance: dict | None = None):
        self.kernel = kernel
        self.problem_size = tuple(int(x) for x in problem_size)
        self.dtype = dtype
        self.device_kind = device_kind
        self.objective = objective
        self.provenance = dict(provenance or {})
        # Snapshot the space: params only. Restrictions are kept as source
        # strings for provenance — membership in the recorded set is the
        # operative feasibility notion when replaying.
        self._space = ConfigSpace()
        for p in space.params.values():
            self._space.tune(p.name, p.values, p.default)
        self.restriction_srcs = list(getattr(space, "_restriction_srcs", []))
        self.evaluations: dict[str, SpaceEvaluation] = {}

    # -- identity ------------------------------------------------------------

    def space(self) -> ConfigSpace:
        """The snapshotted parameter space (no restrictions: the recorded
        entries themselves define what was reachable)."""
        return self._space

    def key_for(self, config: Config) -> str:
        """Entry key: the config's stable 64-bit hash, hex-encoded."""
        return f"{self._space.config_hash(config):016x}"

    def scenario_key(self) -> str:
        """Canonical scenario string (the online tracker's key format)."""
        problem = "x".join(str(d) for d in self.problem_size)
        return f"{self.device_kind}|{problem}|{self.dtype}"

    def name(self) -> str:
        """Filesystem-safe dataset name (used by :class:`DatasetStore`)."""
        problem = "x".join(str(d) for d in self.problem_size)
        return (f"{self.kernel}--{self.device_kind}--{problem}"
                f"--{self.dtype}")

    # -- mutation ------------------------------------------------------------

    def add(self, config: Config, score_us: float, status: str,
            error: str = "", verdict: str = "",
            profile: dict | None = None) -> None:
        """Record one evaluation. Re-recording the same config keeps the
        better outcome (an ``"ok"`` score always beats infeasible; two
        ok scores keep the lower), so repeated sessions only sharpen the
        dataset and recording stays deterministic in any order."""
        ev = SpaceEvaluation(dict(config), float(score_us), status, error,
                             verdict, dict(profile or {}))
        key = self.key_for(config)
        cur = self.evaluations.get(key)
        if cur is not None:
            if cur.feasible and (not ev.feasible
                                 or cur.score_us <= ev.score_us):
                return
        self.evaluations[key] = ev

    def record(self, config: Config, result: EvalResult) -> None:
        """Record a tuner :class:`~repro.tuner.runner.EvalResult` — the
        hook the evaluators' ``record_to`` parameter calls. Results that
        came through a :class:`~repro.sandbox.SandboxedEvaluator` carry
        their verdict (``info["sandbox"]``) into the entry; ``"ok"`` is
        not stored (it is the default)."""
        verdict = str(result.info.get("sandbox", ""))
        if verdict == "ok":
            verdict = ""
        self.add(config, result.score_us,
                 "ok" if result.feasible else "infeasible",
                 error=result.error, verdict=verdict,
                 profile=result.info.get("profile"))

    # -- queries -------------------------------------------------------------

    def lookup(self, config: Config) -> SpaceEvaluation | None:
        return self.evaluations.get(self.key_for(config))

    def feasible(self) -> list[SpaceEvaluation]:
        """Feasible entries, in key order (deterministic)."""
        return [self.evaluations[k] for k in sorted(self.evaluations)
                if self.evaluations[k].feasible]

    def best(self) -> SpaceEvaluation | None:
        """The dataset's optimum: lowest feasible score (ties broken by
        key so the answer is unique)."""
        feas = self.feasible()
        if not feas:
            return None
        return min(feas, key=lambda e: (e.score_us, self.key_for(e.config)))

    def __len__(self) -> int:
        return len(self.evaluations)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"SpaceDataset({self.name()!r}, {len(self)} entries, "
                f"{len(self.feasible())} feasible)")

    # -- persistence ---------------------------------------------------------

    def to_doc(self) -> dict:
        return {
            "format": "tuning-space",
            "version": DATASET_VERSION,
            "kernel": self.kernel,
            "device_kind": self.device_kind,
            "problem_size": list(self.problem_size),
            "dtype": self.dtype,
            "objective": self.objective,
            "provenance": self.provenance,
            "space": {
                "params": [{"name": p.name, "values": list(p.values),
                            "default": p.default}
                           for p in self._space.params.values()],
                "restrictions": list(self.restriction_srcs),
            },
            "evaluations": {k: e.to_json()
                            for k, e in sorted(self.evaluations.items())},
        }

    @staticmethod
    def from_doc(doc: dict, source: str = "<memory>") -> "SpaceDataset":
        if not isinstance(doc, dict):
            raise ValueError(f"dataset {source} is not a JSON object "
                             f"(got {type(doc).__name__})")
        if doc.get("format") not in (None, "tuning-space"):
            raise ValueError(f"dataset {source} has format "
                             f"{doc.get('format')!r}, not 'tuning-space'")
        doc = migrate_dataset_doc(doc, source)
        space = ConfigSpace()
        for p in doc.get("space", {}).get("params", []):
            space.tune(p["name"],
                       [_json_value(v) for v in p["values"]],
                       _json_value(p["default"]))
        ds = SpaceDataset(doc["kernel"], space,
                          doc["problem_size"], doc["dtype"],
                          doc["device_kind"],
                          objective=doc.get("objective", "costmodel"),
                          provenance=doc.get("provenance"))
        ds.restriction_srcs = [str(s) for s in
                               doc.get("space", {}).get("restrictions", [])]
        for key, entry in doc.get("evaluations", {}).items():
            ev = SpaceEvaluation.from_json(entry)
            want = ds.key_for(ev.config)
            if key != want:
                raise ValueError(
                    f"dataset {source}: entry key {key} does not match "
                    f"its config (expected {want}) — file corrupted or "
                    f"hand-edited")
            ds.evaluations[key] = ev
        return ds

    def save(self, path: Path | str) -> Path:
        """Write atomically (tmp + rename), indented, keys sorted — like
        wisdom files, datasets are meant to be diffed and checked in."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(".tmp")
        with open(tmp, "w") as f:
            json.dump(self.to_doc(), f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
        return path

    @staticmethod
    def load(path: Path | str) -> "SpaceDataset":
        path = Path(path)
        with open(path) as f:
            doc = json.load(f)
        return SpaceDataset.from_doc(doc, source=str(path))


def _json_value(v):
    """JSON round-trip normalization for parameter values (lists that were
    tuples come back as tuples so membership checks keep working)."""
    return tuple(v) if isinstance(v, list) else v


class DatasetStore:
    """A directory of recorded spaces, one file per scenario.

    The dataset analogue of :class:`~repro.distrib.store.WisdomStore`:
    deterministic filenames derived from the scenario, so any process
    that knows (kernel, device, problem, dtype) finds the same file.

    Example::

        store = DatasetStore("datasets")
        store.save(ds)
        again = store.load_for("matmul", "tpu-v5e", (256, 256, 256),
                               "float32")
    """

    def __init__(self, root: Path | str):
        self.root = Path(root)

    def path_for(self, kernel: str, device_kind: str,
                 problem_size: Sequence[int], dtype: str) -> Path:
        problem = "x".join(str(int(d)) for d in problem_size)
        return (self.root / f"{kernel}--{device_kind}--{problem}--{dtype}"
                            f"{DATASET_SUFFIX}")

    def save(self, dataset: SpaceDataset) -> Path:
        return dataset.save(self.root / (dataset.name() + DATASET_SUFFIX))

    def load_for(self, kernel: str, device_kind: str,
                 problem_size: Sequence[int],
                 dtype: str) -> SpaceDataset | None:
        """The scenario's dataset, or None when nothing was recorded."""
        path = self.path_for(kernel, device_kind, problem_size, dtype)
        if not path.exists():
            return None
        return SpaceDataset.load(path)

    def datasets(self) -> list[Path]:
        """Every dataset file in the store, sorted."""
        if not self.root.is_dir():
            return []
        return sorted(self.root.glob(f"*{DATASET_SUFFIX}"))

    def scenarios(self, kernel: str | None = None,
                  device_kind: str | None = None
                  ) -> list[tuple[str, str, tuple[int, ...], str, Path]]:
        """Recorded (kernel, device_kind, problem, dtype, path) tuples,
        parsed from the store's deterministic filenames and optionally
        filtered. This is how the transfer layer discovers which *source*
        devices have recorded spaces for a kernel without opening every
        file. Files whose names do not parse are skipped (they were not
        written by a :class:`DatasetStore`).

        Example::

            for kern, dev, problem, dtype, path in store.scenarios(
                    kernel="matmul"):
                ...
        """
        out = []
        for path in self.datasets():
            # rsplit: device/problem/dtype never contain "--", but a
            # kernel name could — it owns whatever is left on the left.
            parts = path.name[:-len(DATASET_SUFFIX)].rsplit("--", 3)
            if len(parts) != 4:
                continue
            kern, dev, problem_s, dtype = parts
            try:
                problem = tuple(int(d) for d in problem_s.split("x") if d)
            except ValueError:
                continue
            if kernel is not None and kern != kernel:
                continue
            if device_kind is not None and dev != device_kind:
                continue
            out.append((kern, dev, problem, dtype, path))
        return out


def history_from_dataset(dataset: SpaceDataset,
                         space: ConfigSpace | None = None
                         ) -> list[Evaluation]:
    """Convert recorded entries into strategy warm-start ``history``.

    The returned list plugs straight into any strategy's ``history``
    parameter (the same plumbing fleet workers checkpoint through): when
    the strategy proposes a config the dataset has a score for, the
    session replays the recorded evaluation instead of re-measuring.
    ``space`` filters entries to its valid set — a fleet worker passes
    its *shard* space so off-shard history can never leak a measurement
    into the wrong shard's result. Entries are ordered by key, so the
    history is identical on every host.
    """
    out: list[Evaluation] = []
    for key in sorted(dataset.evaluations):
        e = dataset.evaluations[key]
        if space is not None and not space.is_valid(e.config):
            continue
        out.append(Evaluation(config=dict(e.config), score_us=e.score_us,
                              feasible=e.feasible, wall_s=0.0,
                              error=e.error))
    return out


def record_space(builder, problem_size: Sequence[int], dtype: str,
                 device_kind: str, objective: str = "costmodel",
                 verify_args: Iterable | None = None,
                 limit: int | None = None) -> SpaceDataset:
    """Exhaustively evaluate a kernel's config space into a dataset.

    The ``record`` CLI's engine: every valid config (capped at ``limit``)
    goes through the scenario's evaluator with recording on, so the
    resulting dataset contains the space's true optimum and every
    infeasibility. With the deterministic cost-model objective the same
    call produces byte-identical datasets on any host.
    """
    from repro.tuner.runner import CostModelEvaluator, WallClockEvaluator
    from repro.tuner.strategies import tune_exhaustive

    dataset = SpaceDataset(builder.name, builder.space, problem_size, dtype,
                           device_kind, objective=objective)
    if objective == "costmodel":
        evaluate = CostModelEvaluator(
            builder, tuple(problem_size), dtype, device_kind,
            verify_args=(list(verify_args) if verify_args is not None
                         else None),
            record_to=dataset)
    elif objective == "wallclock":
        if verify_args is None:
            raise ValueError("wallclock objective needs concrete args")
        evaluate = WallClockEvaluator(builder, list(verify_args),
                                      record_to=dataset)
    else:
        raise ValueError(f"unknown objective {objective!r}")
    limit = limit if limit is not None else 1_000_000
    tune_exhaustive(builder.space, evaluate, limit=limit)
    dataset.provenance = {
        "recorder": "record_space",
        "objective": objective,
        "space_cardinality": builder.space.cardinality(),
        "limit": limit,
    }
    return dataset
