"""Fault-tolerant checkpointing.

Design (DESIGN.md §6):
  * atomic: write to ``step-N.tmp/`` then ``os.replace`` to ``step-N/`` —
    a crash mid-write never corrupts the latest checkpoint;
  * self-describing: a manifest (tree structure, shapes, dtypes, step, mesh
    shape, config hash) + one ``.npy`` per leaf;
  * keep-k retention;
  * **elastic restore**: leaves are stored unsharded (gathered), so a
    checkpoint taken on one mesh restores onto any other mesh — the restore
    path applies the *new* mesh's shardings (tested mesh(2,1) -> mesh(1,2));
  * resumable data pipeline: the step number addresses the deterministic
    dataset, so no data-state file is needed.

For multi-host deployments each host would write only its addressable
shards (same layout, per-shard files); this container is single-host, so
leaves serialize whole.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
from pathlib import Path
from typing import Any

import jax
import numpy as np

MANIFEST = "manifest.json"


def _flatten(tree) -> tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _tree_paths(tree) -> list[str]:
    paths = []
    for path, _ in jax.tree_util.tree_leaves_with_path(tree):
        parts = []
        for k in path:
            parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
        paths.append("/".join(parts))
    return paths


def config_hash(obj) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


def save_checkpoint(directory: Path | str, step: int, state,
                    extra_meta: dict | None = None) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step-{step:08d}"
    tmp = directory / f"step-{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, _ = _flatten(state)
    paths = _tree_paths(state)
    manifest = {"step": step, "leaves": [], "extra": extra_meta or {}}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf-{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append(
            {"path": p, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    with open(tmp / MANIFEST, "w") as f:
        json.dump(manifest, f, indent=2)
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    return final


def load_checkpoint(directory: Path | str, step: int | None = None,
                    like=None, shardings=None):
    """Restore. ``like``: a pytree (of arrays or ShapeDtypeStructs) giving
    the structure; ``shardings``: optional matching tree of NamedShardings
    for elastic placement on the *current* mesh."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    d = directory / f"step-{step:08d}"
    with open(d / MANIFEST) as f:
        manifest = json.load(f)
    arrays = [np.load(d / rec["file"]) for rec in manifest["leaves"]]
    if like is None:
        return manifest, arrays
    leaves, treedef = _flatten(like)
    if len(leaves) != len(arrays):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, state needs "
            f"{len(leaves)}")
    for rec, leaf in zip(manifest["leaves"], leaves):
        if tuple(rec["shape"]) != tuple(leaf.shape):
            raise ValueError(
                f"leaf {rec['path']}: checkpoint shape {rec['shape']} != "
                f"state shape {leaf.shape}")
    if shardings is not None:
        sleaves = treedef.flatten_up_to(shardings)
        arrays = [jax.device_put(a.astype(leaf.dtype), s)
                  for a, leaf, s in zip(arrays, leaves, sleaves)]
    else:
        arrays = [jax.numpy.asarray(a.astype(leaf.dtype))
                  for a, leaf in zip(arrays, leaves)]
    return jax.tree_util.tree_unflatten(treedef, arrays), manifest


def latest_step(directory: Path | str) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for p in directory.iterdir():
        if p.is_dir() and p.name.startswith("step-") \
                and not p.name.endswith(".tmp") \
                and (p / MANIFEST).exists():
            steps.append(int(p.name.split("-")[1]))
    return max(steps) if steps else None


class CheckpointManager:
    def __init__(self, directory: Path | str, keep: int = 3,
                 save_every: int = 100):
        self.directory = Path(directory)
        self.keep = keep
        self.save_every = save_every

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_every == 0

    def save(self, step: int, state, extra_meta: dict | None = None) -> Path:
        path = save_checkpoint(self.directory, step, state, extra_meta)
        self._gc()
        return path

    def restore_latest(self, like, shardings=None):
        return load_checkpoint(self.directory, None, like, shardings)

    def latest_step(self) -> int | None:
        return latest_step(self.directory)

    def _gc(self) -> None:
        steps = sorted(
            int(p.name.split("-")[1])
            for p in self.directory.iterdir()
            if p.is_dir() and p.name.startswith("step-")
            and not p.name.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.directory / f"step-{s:08d}",
                          ignore_errors=True)
        for p in self.directory.glob("*.tmp"):
            shutil.rmtree(p, ignore_errors=True)
