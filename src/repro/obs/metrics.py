"""Process-wide metrics: counters, gauges, and fixed-bucket histograms.

Zero-dependency by design (stdlib only) so every layer — the launch hot
path included — can report into one :class:`MetricsRegistry` without
pulling anything new into the import graph. Three properties matter more
here than feature count:

* **Determinism.** A snapshot is a plain JSON object with sorted series
  keys, and histogram bucket boundaries are *fixed at declaration* (never
  derived from observed data), so two processes fed the same observations
  serialize byte-identical snapshots — the property the fleet health
  aggregation and the CI report gate rely on.
* **Mergeability.** Snapshots from many workers combine with
  :func:`merge_snapshots` (counters and histogram buckets sum, gauges
  keep the max) into one fleet-wide snapshot of the same shape.
* **Cheapness.** Instrument sites hold a handle (``registry.counter(...)``)
  and call ``inc``/``observe`` on it; the disabled path never reaches this
  module at all (see ``repro.obs.runtime``).

Series identity is ``name{label=value,...}`` with labels sorted — the
Prometheus convention, chosen so snapshots grep well and reports can
parse series back into (name, labels) with :func:`parse_series`.
"""

from __future__ import annotations

import json
import threading
from pathlib import Path

#: Snapshot schema version (bump on incompatible format changes).
SNAPSHOT_VERSION = 1

#: Default histogram boundaries for microsecond latencies: a 1-2-5
#: geometric ladder from 1us to 1s. Fixed literals — never computed —
#: so bucket placement is identical in every process.
DEFAULT_BUCKETS_US = (
    1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0,
    1_000.0, 2_000.0, 5_000.0, 10_000.0, 20_000.0, 50_000.0,
    100_000.0, 200_000.0, 500_000.0, 1_000_000.0,
)

#: Boundaries for quantities in [0, 1] (ratios, confidences).
UNIT_BUCKETS = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0)

#: Boundaries for small cardinalities (cohort sizes, queue depths).
COUNT_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0)

_FORBIDDEN = set("{}=,\n")


def _check_part(kind: str, value: str) -> str:
    if not value or _FORBIDDEN & set(value):
        raise ValueError(f"{kind} {value!r} is empty or contains one of "
                         f"{''.join(sorted(_FORBIDDEN - {chr(10)}))!r}")
    return value


def series_key(name: str, labels: dict[str, str]) -> str:
    """Canonical series identity: ``name{k=v,...}`` with labels sorted.

    The one string form every snapshot keys series by; label values are
    arbitrary strings minus structural characters (``{}=,``).
    """
    _check_part("metric name", name)
    if not labels:
        return name
    parts = ",".join(f"{_check_part('label', k)}={_check_part('value', str(v))}"
                     for k, v in sorted(labels.items()))
    return f"{name}{{{parts}}}"


def parse_series(key: str) -> tuple[str, dict[str, str]]:
    """Inverse of :func:`series_key`: ``"a{k=v}"`` -> ``("a", {"k": "v"})``."""
    if "{" not in key:
        return key, {}
    name, _, rest = key.partition("{")
    if not rest.endswith("}"):
        raise ValueError(f"malformed series key {key!r}")
    body = rest[:-1]
    labels: dict[str, str] = {}
    for part in body.split(","):
        if part:
            k, _, v = part.partition("=")
            labels[k] = v
    return name, labels


class Counter:
    """A monotonically increasing value (floats allowed: budget spend in
    seconds is a counter too)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """A point-in-time value (queue depth, shard progress, age)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n


class Histogram:
    """Fixed-boundary histogram: ``bounds[i]`` is the inclusive upper edge
    of bucket ``i``; one implicit +Inf bucket catches the rest. Boundaries
    are part of the series identity — snapshots embed them, so any reader
    can re-bucket-check without access to the declaring code."""

    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: tuple[float, ...] = DEFAULT_BUCKETS_US):
        if not bounds or list(bounds) != sorted(bounds):
            raise ValueError(f"histogram bounds must be non-empty and "
                             f"ascending, got {bounds!r}")
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        i = 0
        for i, b in enumerate(self.bounds):      # noqa: B007 — tiny tuples
            if v <= b:
                break
        else:
            i = len(self.bounds)
        self.counts[i] += 1
        self.sum += v
        self.count += 1

    def to_json(self) -> dict:
        return {"bounds": list(self.bounds), "counts": list(self.counts),
                "sum": round(self.sum, 6), "count": self.count}


class MetricsRegistry:
    """All of one process's metric series, snapshottable as plain JSON.

    ``counter``/``gauge``/``histogram`` get-or-create a series by (name,
    labels); instrument sites may call them per event (one dict build +
    lookup) or hold the returned handle. Creation is locked; increments
    on the handles are plain attribute updates (single-writer per series
    in this codebase — launches, ticks, and fleet steps all happen on the
    calling thread).
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str, **labels: str) -> Counter:
        key = series_key(name, labels)
        c = self._counters.get(key)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(key, Counter())
        return c

    def gauge(self, name: str, **labels: str) -> Gauge:
        key = series_key(name, labels)
        g = self._gauges.get(key)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(key, Gauge())
        return g

    def histogram(self, name: str,
                  bounds: tuple[float, ...] = DEFAULT_BUCKETS_US,
                  **labels: str) -> Histogram:
        key = series_key(name, labels)
        h = self._histograms.get(key)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(key, Histogram(bounds))
        elif h.bounds != tuple(float(b) for b in bounds):
            raise ValueError(
                f"histogram {key} re-declared with different bounds")
        return h

    def snapshot(self) -> dict:
        """JSON-safe, deterministically ordered view of every series."""
        return {
            "version": SNAPSHOT_VERSION,
            "counters": {k: round(self._counters[k].value, 6)
                         for k in sorted(self._counters)},
            "gauges": {k: round(self._gauges[k].value, 6)
                       for k in sorted(self._gauges)},
            "histograms": {k: self._histograms[k].to_json()
                           for k in sorted(self._histograms)},
        }

    def clear(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()

    def __len__(self) -> int:
        return (len(self._counters) + len(self._gauges)
                + len(self._histograms))


def snapshot_bytes(snap: dict) -> bytes:
    """The canonical serialization — what :func:`save_snapshot` writes and
    the byte-determinism tests compare."""
    return (json.dumps(snap, indent=2, sort_keys=True) + "\n").encode()


def save_snapshot(snap: dict, path: Path | str) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_bytes(snapshot_bytes(snap))
    return path


def load_snapshot(path: Path | str) -> dict:
    with open(path) as f:
        snap = json.load(f)
    if not isinstance(snap, dict) or "counters" not in snap:
        raise ValueError(f"{path} is not a metrics snapshot")
    version = int(snap.get("version", 0))
    if version > SNAPSHOT_VERSION:
        raise ValueError(f"snapshot {path} has version {version}; this "
                         f"build understands at most {SNAPSHOT_VERSION}")
    return snap


def merge_snapshots(snaps: list[dict]) -> dict:
    """Combine worker snapshots into one fleet-wide snapshot.

    Counters and histogram buckets *sum* (they are rates of events that
    all really happened); gauges keep the *max* (point-in-time values from
    different hosts cannot meaningfully add — max surfaces the worst
    queue depth / oldest age, which is what a health view wants).
    Histograms with mismatched bounds for the same series refuse loudly.
    """
    out = {"version": SNAPSHOT_VERSION, "counters": {}, "gauges": {},
           "histograms": {}}
    for snap in snaps:
        for k, v in snap.get("counters", {}).items():
            out["counters"][k] = round(out["counters"].get(k, 0.0) + v, 6)
        for k, v in snap.get("gauges", {}).items():
            cur = out["gauges"].get(k)
            out["gauges"][k] = v if cur is None else max(cur, v)
        for k, h in snap.get("histograms", {}).items():
            cur = out["histograms"].get(k)
            if cur is None:
                out["histograms"][k] = {"bounds": list(h["bounds"]),
                                        "counts": list(h["counts"]),
                                        "sum": h["sum"],
                                        "count": h["count"]}
                continue
            if cur["bounds"] != list(h["bounds"]):
                raise ValueError(f"histogram {k}: bucket bounds differ "
                                 f"across snapshots")
            cur["counts"] = [a + b for a, b in zip(cur["counts"],
                                                   h["counts"])]
            cur["sum"] = round(cur["sum"] + h["sum"], 6)
            cur["count"] += h["count"]
    for section in ("counters", "gauges", "histograms"):
        out[section] = {k: out[section][k] for k in sorted(out[section])}
    return out
