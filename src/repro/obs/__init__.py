"""Observability: process metrics, span tracing, and wisdom health.

Zero-dependency telemetry substrate for every loop in the system —
serving, online tuning, fleet orchestration, sync, transfer — built from
two primitives and a report:

* :mod:`.metrics` — a process-wide :class:`MetricsRegistry` of counters,
  gauges, and fixed-bucket histograms whose snapshots are byte-
  deterministic JSON, mergeable across workers;
* :mod:`.trace`   — a span :class:`Tracer` exporting Chrome
  ``trace_event`` JSON (open in chrome://tracing or Perfetto);
* :mod:`.runtime` — the on/off switch: disabled (default) costs one
  global read + branch per instrument site, enabled via
  :func:`enable` or ``KERNEL_LAUNCHER_OBS=1``;
* :mod:`.report`  — the wisdom-health report (hit rates, tier breakdown,
  transfer confidence, top missing scenarios) rendered deterministically
  from a snapshot or a saved trace;
* ``python -m repro.obs`` — snapshot / report / trace CLI
  (:mod:`.cli`, demo run included).

Fleet-wide aggregation (periodic snapshots on the control bus) lives in
:mod:`repro.fleet.health`, which builds on :func:`merge_snapshots`.
"""

from .metrics import (COUNT_BUCKETS, DEFAULT_BUCKETS_US, SNAPSHOT_VERSION,
                      UNIT_BUCKETS, Counter, Gauge, Histogram,
                      MetricsRegistry, load_snapshot, merge_snapshots,
                      parse_series, save_snapshot, series_key,
                      snapshot_bytes)
from .report import (ScenarioHealth, fleet_report, render_report,
                     scenario_health, snapshot_from_trace)
from .runtime import (OBS_ENV, disable, enable, enabled, metrics,
                      obs_requested, tracer)
from .trace import (REQUIRED_EVENT_KEYS, Tracer, load_trace,
                    validate_trace)

__all__ = [
    "COUNT_BUCKETS", "DEFAULT_BUCKETS_US", "SNAPSHOT_VERSION",
    "UNIT_BUCKETS", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "load_snapshot", "merge_snapshots", "parse_series", "save_snapshot",
    "series_key", "snapshot_bytes",
    "ScenarioHealth", "fleet_report", "render_report", "scenario_health",
    "snapshot_from_trace",
    "OBS_ENV", "disable", "enable", "enabled", "metrics", "obs_requested",
    "tracer",
    "REQUIRED_EVENT_KEYS", "Tracer", "load_trace", "validate_trace",
]
