"""Wisdom-health report: what the telemetry says about serving quality.

The paper's promise is that every launch lands on a tuned configuration;
the health report measures how true that is right now. From a metrics
snapshot (or a saved Chrome trace — spans are converted to the same
counters first) it renders, deterministically:

* per-scenario **hit rates** — the share of launches served at tier
  "exact" (or forced/trial) vs the fuzzy/transfer/default miss tiers;
* the **tier breakdown** per kernel — where selection actually lands;
* the **transfer-confidence distribution** — how confident the served
  cross-device predictions were;
* the **top missing scenarios** — the launch-weighted list of scenarios
  the fleet should tune next (the same signal the demand ranker uses);
* **sandbox & oracle** outcomes — crash-isolated evaluation verdicts
  and correctness-check pass/fail mix (with max-error stats) when those
  series are present;
* **profiler bottlenecks** — per-kernel roofline classification of
  sampled launches (``prof.*`` series from :mod:`repro.prof`), with
  mean achieved roofline fraction and drift-event counts;
* one-line summaries of serve / online / fleet / sync activity when
  those series are present.

Rendering is a pure function of the snapshot dict: same snapshot, same
bytes — the property the CI report job asserts by rendering twice.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.scenario import HIT_TIERS, MISS_TIERS, SELECT_TIERS

from .metrics import merge_snapshots, parse_series

#: Metric the per-scenario sections read. One counter per
#: (kernel, scenario, tier), incremented at every launch/selection.
TIER_SERIES = "select.tier"


@dataclass
class ScenarioHealth:
    """Aggregated selection outcomes for one (kernel, scenario)."""

    kernel: str
    scenario: str
    tiers: dict[str, float] = field(default_factory=dict)

    @property
    def launches(self) -> float:
        return sum(self.tiers.values())

    @property
    def hits(self) -> float:
        return sum(v for t, v in self.tiers.items() if t in HIT_TIERS)

    @property
    def misses(self) -> float:
        return sum(v for t, v in self.tiers.items() if t in MISS_TIERS)

    @property
    def hit_rate(self) -> float:
        n = self.launches
        return self.hits / n if n else 0.0


def scenario_health(snapshot: dict) -> list[ScenarioHealth]:
    """Group the snapshot's ``select.tier`` counters by (kernel, scenario),
    deterministically ordered."""
    table: dict[tuple[str, str], ScenarioHealth] = {}
    for key, value in snapshot.get("counters", {}).items():
        name, labels = parse_series(key)
        if name != TIER_SERIES:
            continue
        kernel = labels.get("kernel", "?")
        scenario = labels.get("scenario", "?")
        tier = labels.get("tier", "?")
        sh = table.setdefault((kernel, scenario),
                              ScenarioHealth(kernel, scenario))
        sh.tiers[tier] = sh.tiers.get(tier, 0.0) + value
    return [table[k] for k in sorted(table)]


def snapshot_from_trace(trace: dict) -> dict:
    """Reduce a saved Chrome trace to the snapshot shape the report reads.

    ``launch`` spans carry kernel/scenario/tier in their args; each one
    becomes a ``select.tier`` increment, and span durations rebuild the
    per-kernel launch-latency histograms. A trace is therefore an
    alternative — replayable — source for the same health report.
    """
    from .metrics import MetricsRegistry
    reg = MetricsRegistry()
    for ev in trace.get("traceEvents", []):
        if ev.get("name") != "launch":
            continue
        args = ev.get("args", {})
        kernel = str(args.get("kernel", "?"))
        tier = str(args.get("tier", "?"))
        scenario = str(args.get("scenario", "?"))
        reg.counter(TIER_SERIES, kernel=kernel, scenario=scenario,
                    tier=tier).inc()
        if isinstance(ev.get("dur"), (int, float)):
            reg.histogram("launch.latency_us",
                          kernel=kernel).observe(ev["dur"])
    return reg.snapshot()


def _fmt_n(v: float) -> str:
    return str(int(v)) if float(v).is_integer() else f"{v:.2f}"


def _section(lines: list[str], title: str) -> None:
    if lines and lines[-1] != "":
        lines.append("")
    lines.append(title)
    lines.append("-" * len(title))


def _counter_total(snapshot: dict, name: str,
                   **match: str) -> float:
    total = 0.0
    for key, value in snapshot.get("counters", {}).items():
        n, labels = parse_series(key)
        if n != name:
            continue
        if all(labels.get(k) == v for k, v in match.items()):
            total += value
    return total


def _counter_rows(snapshot: dict, name: str) -> list[tuple[dict, float]]:
    rows = []
    for key, value in sorted(snapshot.get("counters", {}).items()):
        n, labels = parse_series(key)
        if n == name:
            rows.append((labels, value))
    return rows


def _histogram_rows(snapshot: dict, name: str) -> list[tuple[dict, dict]]:
    rows = []
    for key in sorted(snapshot.get("histograms", {})):
        n, labels = parse_series(key)
        if n == name:
            rows.append((labels, snapshot["histograms"][key]))
    return rows


def render_report(snapshot: dict, top: int = 10) -> str:
    """The wisdom-health report as text. Pure: same snapshot, same bytes.

    Example::

        print(render_report(load_snapshot("obs-snapshot.json")))
    """
    lines: list[str] = []
    health = scenario_health(snapshot)

    _section(lines, "Wisdom health (per scenario)")
    if not health:
        lines.append("no select.tier series in snapshot — nothing "
                     "launched with observability enabled")
    for sh in health:
        breakdown = " ".join(
            f"{t}={_fmt_n(sh.tiers[t])}"
            for t in (*SELECT_TIERS, "forced", "trial") if t in sh.tiers)
        lines.append(f"{sh.kernel} {sh.scenario}: "
                     f"hit-rate={sh.hit_rate:.2f} "
                     f"launches={_fmt_n(sh.launches)} [{breakdown}]")

    by_kernel: dict[str, dict[str, float]] = {}
    for sh in health:
        agg = by_kernel.setdefault(sh.kernel, {})
        for t, v in sh.tiers.items():
            agg[t] = agg.get(t, 0.0) + v
    _section(lines, "Tier breakdown (per kernel)")
    if not by_kernel:
        lines.append("(none)")
    for kernel in sorted(by_kernel):
        agg = by_kernel[kernel]
        total = sum(agg.values())
        parts = " ".join(
            f"{t}={_fmt_n(agg[t])} ({agg[t] / total:.0%})"
            for t in (*SELECT_TIERS, "forced", "trial") if t in agg)
        lines.append(f"{kernel}: {parts}")

    conf = {k: h for k, h in snapshot.get("histograms", {}).items()
            if parse_series(k)[0] == "select.transfer_confidence"}
    _section(lines, "Transfer-confidence distribution")
    if not conf:
        lines.append("no transferred records served")
    for key in sorted(conf):
        h = conf[key]
        _, labels = parse_series(key)
        buckets = []
        lo = 0.0
        for b, c in zip(h["bounds"], h["counts"]):
            if c:
                buckets.append(f"({lo:.1f},{b:.1f}]={c}")
            lo = b
        if h["counts"][len(h["bounds"])]:
            buckets.append(f"(>{h['bounds'][-1]:.1f})="
                           f"{h['counts'][len(h['bounds'])]}")
        mean = h["sum"] / h["count"] if h["count"] else 0.0
        lines.append(f"{labels.get('kernel', '?')}: n={h['count']} "
                     f"mean={mean:.3f} {' '.join(buckets)}")

    missing = sorted((sh for sh in health if sh.misses > 0),
                     key=lambda sh: (-sh.misses, sh.kernel, sh.scenario))
    _section(lines, f"Top missing scenarios (tune these next, top {top})")
    if not missing:
        lines.append("every observed scenario is served from exact wisdom")
    for sh in missing[:top]:
        worst = max((t for t in sh.tiers if t in MISS_TIERS),
                    key=lambda t: (sh.tiers[t], t))
        lines.append(f"{sh.kernel} {sh.scenario}: "
                     f"misses={_fmt_n(sh.misses)} "
                     f"dominant-tier={worst}")

    # Sandbox / oracle (PR 7): crash-isolated evaluation outcomes and
    # correctness-oracle verdicts, when those series are present.
    sandbox = _counter_rows(snapshot, "sandbox.verdict")
    oracle = _counter_rows(snapshot, "oracle.checks")
    if sandbox or oracle:
        _section(lines, "Sandbox & oracle")
        if sandbox:
            total = sum(v for _, v in sandbox)
            parts = " ".join(f"{labels.get('status', '?')}={_fmt_n(v)}"
                             for labels, v in sandbox)
            lines.append(f"sandbox verdicts: n={_fmt_n(total)} [{parts}]")
        by_k: dict[str, dict[str, float]] = {}
        for labels, v in oracle:
            agg = by_k.setdefault(labels.get("kernel", "?"), {})
            status = labels.get("status", "?")
            agg[status] = agg.get(status, 0.0) + v
        errs = {labels.get("kernel", "?"): h
                for labels, h in _histogram_rows(snapshot, "oracle.max_err")}
        for kernel in sorted(by_k):
            agg = by_k[kernel]
            parts = " ".join(f"{s}={_fmt_n(agg[s])}" for s in sorted(agg))
            h = errs.get(kernel)
            tail = ""
            if h and h["count"]:
                tail = (f" max-err mean={h['sum'] / h['count']:.2e} "
                        f"n={h['count']}")
            lines.append(f"oracle {kernel}: [{parts}]{tail}")

    # Profiler (repro.prof): sampled-launch roofline classification.
    prof = _counter_rows(snapshot, "prof.launches")
    if prof:
        _section(lines, "Profiler (roofline bottlenecks)")
        by_pk: dict[str, dict[str, float]] = {}
        for labels, v in prof:
            agg = by_pk.setdefault(labels.get("kernel", "?"), {})
            b = labels.get("bottleneck", "?")
            agg[b] = agg.get(b, 0.0) + v
        fracs = {labels.get("kernel", "?"): h for labels, h in
                 _histogram_rows(snapshot, "prof.roofline_fraction")}
        for kernel in sorted(by_pk):
            agg = by_pk[kernel]
            total = sum(agg.values())
            dominant = max(sorted(agg), key=lambda b: agg[b])
            parts = " ".join(f"{b}={_fmt_n(agg[b])}" for b in sorted(agg))
            h = fracs.get(kernel)
            frac = (f" mean-roofline-frac="
                    f"{h['sum'] / h['count']:.3f}"
                    if h and h["count"] else "")
            drift = _counter_total(snapshot, "prof.drift", kernel=kernel)
            lines.append(f"{kernel}: profiled={_fmt_n(total)} "
                         f"{dominant}-bound [{parts}]{frac} "
                         f"drift-events={_fmt_n(drift)}")

    activity: list[str] = []
    launches = _counter_total(snapshot, "launch.count")
    if launches:
        activity.append(f"launches={_fmt_n(launches)}")
    steps = _counter_total(snapshot, "serve.decode_steps")
    if steps:
        activity.append(f"decode-steps={_fmt_n(steps)}")
    done = _counter_total(snapshot, "serve.requests_completed")
    if done:
        activity.append(f"requests-completed={_fmt_n(done)}")
    sync_fail = (_counter_total(snapshot, "serve.sync_tick", outcome="failed")
                 + _counter_total(snapshot, "sync.failures"))
    activity.append(f"sync-failures={_fmt_n(sync_fail)}")
    trials = _counter_total(snapshot, "online.trials")
    promos = _counter_total(snapshot, "online.promotions",
                            outcome="promoted")
    if trials or promos:
        activity.append(f"online-trials={_fmt_n(trials)}")
        activity.append(f"online-promotions={_fmt_n(promos)}")
    leases = _counter_total(snapshot, "fleet.lease", event="acquire")
    if leases:
        activity.append(f"fleet-leases={_fmt_n(leases)}")
        activity.append(
            f"fleet-reclaims="
            f"{_fmt_n(_counter_total(snapshot, 'fleet.lease', event='reclaim'))}")
        activity.append(
            f"fleet-evals={_fmt_n(_counter_total(snapshot, 'fleet.shard_evals'))}")
    _section(lines, "Activity")
    lines.append(" ".join(activity))
    return "\n".join(lines) + "\n"


def fleet_report(snapshots: list[dict], top: int = 10) -> str:
    """Render one health report over many workers' snapshots (merged with
    :func:`~repro.obs.metrics.merge_snapshots` — counters sum, gauges
    keep the max). What the coordinator prints for fleet-wide health."""
    return render_report(merge_snapshots(snapshots), top=top)
