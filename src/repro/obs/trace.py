"""Span tracing exporting Chrome ``trace_event`` JSON (Perfetto-viewable).

A :class:`Tracer` records *complete* events (``"ph": "X"`` — begin time +
duration, the compact form), *instant* events (``"ph": "i"``), and
*counter* events (``"ph": "C"`` — named numeric series Perfetto renders
as stacked track charts; the profiler exports roofline counters this
way), tagged with the subsystem as the category. ``to_chrome()`` emits
the standard ``{"traceEvents": [...]}`` wrapper that chrome://tracing and
https://ui.perfetto.dev open directly, so a serving incident can be read
as a timeline: selection, compile, launch, sync ticks, fleet steps.

Time is injected (``clock``) the same way the fleet's lease layer injects
it: production uses ``time.perf_counter``, tests drive a manual clock so
exported traces are byte-deterministic. Thread ids are mapped to small
dense ints in first-seen order for the same reason.

The disabled path never reaches this module — ``repro.obs.runtime`` hands
instrument sites ``None`` instead of a tracer.
"""

from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from pathlib import Path
from typing import Callable

#: Keys every Chrome trace event must carry (the schema the validity
#: tests and ``validate_trace`` enforce).
REQUIRED_EVENT_KEYS = ("name", "cat", "ph", "ts", "pid", "tid")


class Tracer:
    """Collects span/instant events for one process.

    Example::

        tracer = Tracer()
        with tracer.span("launch", cat="kernel", kernel="matmul"):
            ...
        tracer.save("trace.json")     # open in Perfetto
    """

    def __init__(self, clock: Callable[[], float] | None = None,
                 pid: int = 1):
        self._clock = clock or time.perf_counter
        self._epoch = self._clock()
        self.pid = int(pid)
        self.events: list[dict] = []
        self._tids: dict[int, int] = {}

    def _now_us(self) -> float:
        return round((self._clock() - self._epoch) * 1e6, 3)

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            tid = self._tids[ident] = len(self._tids)
        return tid

    @contextmanager
    def span(self, name: str, cat: str = "repro", **args):
        """Record one complete event around the enclosed work. ``args``
        become the event's ``args`` dict (JSON-safe values only)."""
        t0 = self._now_us()
        try:
            yield self
        finally:
            t1 = self._now_us()
            self.events.append({
                "name": name, "cat": cat, "ph": "X",
                "ts": t0, "dur": round(t1 - t0, 3),
                "pid": self.pid, "tid": self._tid(),
                "args": {k: v for k, v in sorted(args.items())},
            })

    def instant(self, name: str, cat: str = "repro", **args) -> None:
        """Record a zero-duration marker (promotions, sync failures)."""
        self.events.append({
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": self._now_us(), "pid": self.pid, "tid": self._tid(),
            "args": {k: v for k, v in sorted(args.items())},
        })

    def counter(self, name: str, cat: str = "repro", **values) -> None:
        """Record a counter sample (``"ph": "C"``): one or more named
        numeric series at the current time. Perfetto plots each counter
        name as a track; the kernel profiler exports achieved-fraction /
        arithmetic-intensity samples this way. Non-numeric values raise
        — counter tracks are charts, not metadata."""
        args = {}
        for k, v in sorted(values.items()):
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                raise ValueError(f"counter series {k!r} has non-numeric "
                                 f"value {v!r}")
            args[k] = v
        if not args:
            raise ValueError("counter event needs at least one series")
        self.events.append({
            "name": name, "cat": cat, "ph": "C",
            "ts": self._now_us(), "pid": self.pid, "tid": self._tid(),
            "args": args,
        })

    def to_chrome(self) -> dict:
        """The standard Chrome ``trace_event`` JSON object."""
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms"}

    def save(self, path: Path | str) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_chrome(), indent=2,
                                   sort_keys=True) + "\n")
        return path

    def clear(self) -> None:
        self.events.clear()

    def __len__(self) -> int:
        return len(self.events)


def load_trace(path: Path | str) -> dict:
    """Read a saved Chrome trace, refusing files that are not one."""
    with open(path) as f:
        doc = json.load(f)
    errors = validate_trace(doc)
    if errors:
        raise ValueError(f"{path} is not a valid Chrome trace: "
                         f"{errors[0]} ({len(errors)} problem(s))")
    return doc


def validate_trace(doc) -> list[str]:
    """Schema check for Chrome ``trace_event`` JSON: the wrapper shape,
    required per-event keys, numeric timestamps, non-negative span
    durations, and numeric counter ("C") series. Returns a list of
    problems (empty = valid)."""
    errors: list[str] = []
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        return ["top level must be an object with a traceEvents list"]
    for i, ev in enumerate(doc["traceEvents"]):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        for k in REQUIRED_EVENT_KEYS:
            if k not in ev:
                errors.append(f"event {i}: missing key {k!r}")
        for k in ("ts", "dur"):
            if k in ev and not isinstance(ev[k], (int, float)):
                errors.append(f"event {i}: {k} is not numeric")
        if ev.get("ph") == "X":
            if "dur" not in ev:
                errors.append(f"event {i}: complete event without dur")
            elif isinstance(ev["dur"], (int, float)) and ev["dur"] < 0:
                errors.append(f"event {i}: negative duration")
        if ev.get("ph") == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                errors.append(f"event {i}: counter event without series "
                              f"(args must be a non-empty object)")
            else:
                for k, v in args.items():
                    if isinstance(v, bool) or not isinstance(
                            v, (int, float)):
                        errors.append(f"event {i}: counter series {k!r} "
                                      f"is not numeric")
    return errors
