"""Instrumented end-to-end demo: launches + a tiny local fleet.

``run_demo`` enables observability, drives a ``WisdomKernel`` through a
scripted mix of selection tiers (exact hits, a served cross-device
transfer, scenario-distance fallbacks, cold default launches), runs a
small in-process fleet over the same scenarios, publishes the process
snapshot onto the fleet control bus, and writes every artifact the
``python -m repro.obs`` CLI knows how to read:

* ``snapshot.json``        — this process's metric snapshot;
* ``fleet-snapshot.json``  — the bus-aggregated fleet-wide snapshot;
* ``trace.json``           — the Chrome trace (open in Perfetto);
* ``report.txt``           — the rendered wisdom-health report.

The launch mix is fixed, so the demo exercises every report section:
hit rates below 1.0, a transfer-confidence distribution, and a
non-empty top-missing-scenarios list.
"""

from __future__ import annotations

from pathlib import Path

import numpy as np

from . import runtime
from .metrics import save_snapshot
from .report import render_report


def _seed_wisdom(wisdom_dir: Path, device_kind: str) -> None:
    from repro.core.device import get_device
    from repro.core.wisdom import (Wisdom, WisdomRecord,
                                   make_provenance,
                                   make_transfer_provenance)
    family = get_device(device_kind).family
    w = Wisdom("matmul")
    w.add(WisdomRecord(
        device_kind=device_kind, device_family=family,
        problem_size=(64, 64, 64), dtype="float32",
        config={"block_m": 64, "block_n": 64, "block_k": 128,
                "grid_order": "mnk", "dim_semantics": "parallel"},
        score_us=104.2,
        provenance=make_provenance(strategy="exhaustive", evals=64,
                                   objective="costmodel")))
    w.add(WisdomRecord(
        device_kind=device_kind, device_family=family,
        problem_size=(128, 128, 128), dtype="float32",
        config={"block_m": 128, "block_n": 128, "block_k": 128,
                "grid_order": "mnk", "dim_semantics": "parallel"},
        score_us=96.0,
        provenance=make_transfer_provenance(
            source_device="tpu-v4", source_entries=32,
            confidence=0.72, predicted_us=96.0)))
    w.save(wisdom_dir)


def _mm(n: int, dtype=np.float32):
    rng = np.random.default_rng(n)
    a = rng.standard_normal((n, n)).astype(np.float32)
    b = rng.standard_normal((n, n)).astype(np.float32)
    if dtype is not np.float32:
        import jax.numpy as jnp
        return jnp.asarray(a).astype(dtype), jnp.asarray(b).astype(dtype)
    return a, b


def run_demo(out_dir: Path | str, fleet: bool = True) -> dict:
    """Run the instrumented demo; returns {artifact: path} plus the
    rendered report text under ``"report"``.

    Example::

        art = run_demo("obs-demo")
        print(art["report"])
    """
    from repro.core.registry import get_kernel
    from repro.core.wisdom_kernel import WisdomKernel

    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    runtime.disable()                       # fresh registry + tracer
    reg, tracer = runtime.enable()

    wisdom_dir = out / "wisdom"
    _seed_wisdom(wisdom_dir, "tpu-v5e")
    builder = get_kernel("matmul")

    k = WisdomKernel(builder, wisdom_dir=wisdom_dir,
                     device_kind="tpu-v5e", backend="reference")
    for _ in range(3):                      # tier: exact
        k(*_mm(64))
    for _ in range(2):                      # tier: transfer (confidence 0.72)
        k(*_mm(128))
    for _ in range(2):                      # tier: transfer again — the
        k(*_mm(32))                         # prediction outranks device+dtype
    import jax.numpy as jnp
    for _ in range(2):                      # tier: device (bf16 untuned)
        k(*_mm(64, dtype=jnp.bfloat16))

    cold = WisdomKernel(builder, wisdom_dir=out / "wisdom-empty",
                        device_kind="tpu-v4", backend="reference")
    for _ in range(3):                      # tier: default (empty wisdom)
        cold(*_mm(48))

    fleet_snap = reg.snapshot()
    if fleet:
        from repro.fleet import ControlBus, run_local_fleet
        from repro.fleet.health import (aggregate_fleet_metrics,
                                        publish_metrics)
        fr = run_local_fleet(
            n_workers=2,
            demand=[("matmul", ("tpu-v5e", (64, 64, 64), "float32"), 5)],
            strategy="random", n_shards=2, max_evals_per_shard=4)
        bus = ControlBus(fr.transport)
        publish_metrics(bus, "demo-host")
        fleet_snap = aggregate_fleet_metrics(bus)

    snap = reg.snapshot()
    artifacts = {
        "snapshot": str(save_snapshot(snap, out / "snapshot.json")),
        "fleet_snapshot": str(save_snapshot(fleet_snap,
                                            out / "fleet-snapshot.json")),
        "trace": str(tracer.save(out / "trace.json")),
    }
    report = render_report(snap)
    (out / "report.txt").write_text(report)
    artifacts["report_path"] = str(out / "report.txt")
    artifacts["report"] = report
    return artifacts
