"""``python -m repro.obs`` — snapshot / report / trace / demo.

Operator entry points over the observability artifacts:

* ``snapshot`` — merge metric snapshots (files and/or every snapshot
  published on a fleet bus directory) into one snapshot file;
* ``report``   — render the wisdom-health report from snapshot files, a
  saved Chrome trace, or a fleet bus directory;
* ``trace``    — validate a Chrome trace file and summarize it;
* ``demo``     — run the instrumented demo (launches + a tiny local
  fleet) and write snapshot/trace/report artifacts.

Every command is deterministic given its inputs: the same snapshot
bytes always render the same report bytes.
"""

from __future__ import annotations

import argparse
import sys

from .metrics import load_snapshot, merge_snapshots, save_snapshot
from .report import render_report, snapshot_from_trace
from .trace import load_trace, validate_trace


def _bus_snapshots(bus_dir: str) -> list[dict]:
    from repro.distrib.sync import DirectoryTransport
    from repro.fleet.bus import ControlBus
    from repro.fleet.health import fleet_snapshots
    return list(fleet_snapshots(ControlBus(DirectoryTransport(bus_dir)))
                .values())


def _gather(args: argparse.Namespace) -> dict:
    snaps = [load_snapshot(p) for p in args.snapshots]
    if args.trace:
        snaps.append(snapshot_from_trace(load_trace(args.trace)))
    if args.bus:
        snaps.extend(_bus_snapshots(args.bus))
    if not snaps:
        raise SystemExit("nothing to read: pass snapshot files, "
                         "--trace, or --bus")
    return snaps[0] if len(snaps) == 1 else merge_snapshots(snaps)


def _add_inputs(p: argparse.ArgumentParser) -> None:
    p.add_argument("snapshots", nargs="*",
                   help="metric snapshot JSON files")
    p.add_argument("--trace", help="saved Chrome trace to reduce to "
                                   "select.tier/latency series")
    p.add_argument("--bus", help="fleet bus directory: read every "
                                 "published fleet--metrics-- snapshot")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="metrics snapshots, Chrome traces, wisdom health")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("snapshot",
                       help="merge snapshots into one file")
    _add_inputs(p)
    p.add_argument("--out", required=True, help="output snapshot path")

    p = sub.add_parser("report", help="render the wisdom-health report")
    _add_inputs(p)
    p.add_argument("--top", type=int, default=10,
                   help="missing-scenario rows to show (default 10)")
    p.add_argument("--out", help="also write the report to this path")

    p = sub.add_parser("trace", help="validate + summarize a Chrome trace")
    p.add_argument("trace_file")

    p = sub.add_parser("demo", help="run the instrumented demo")
    p.add_argument("--out", default="obs-demo",
                   help="artifact directory (default obs-demo)")
    p.add_argument("--no-fleet", action="store_true",
                   help="skip the local-fleet portion")

    args = ap.parse_args(argv)

    if args.cmd == "snapshot":
        merged = _gather(args)
        path = save_snapshot(merged, args.out)
        print(f"wrote {path} ({len(merged.get('counters', {}))} counters, "
              f"{len(merged.get('gauges', {}))} gauges, "
              f"{len(merged.get('histograms', {}))} histograms)")
        return 0

    if args.cmd == "report":
        text = render_report(_gather(args), top=args.top)
        sys.stdout.write(text)
        if args.out:
            with open(args.out, "w") as f:
                f.write(text)
        return 0

    if args.cmd == "trace":
        try:
            doc = load_trace(args.trace_file)
        except ValueError as e:
            print(f"INVALID: {e}")
            return 1
        events = doc["traceEvents"]
        by_cat: dict[str, int] = {}
        for ev in events:
            by_cat[ev.get("cat", "?")] = by_cat.get(ev.get("cat", "?"), 0) + 1
        cats = " ".join(f"{c}={by_cat[c]}" for c in sorted(by_cat))
        print(f"valid Chrome trace: {len(events)} event(s) [{cats}]")
        print("open in https://ui.perfetto.dev or chrome://tracing")
        return 0

    if args.cmd == "demo":
        from .demo import run_demo
        art = run_demo(args.out, fleet=not args.no_fleet)
        for name in ("snapshot", "fleet_snapshot", "trace", "report_path"):
            print(f"{name}: {art[name]}")
        sys.stdout.write("\n" + art["report"])
        return 0

    raise AssertionError(f"unhandled command {args.cmd!r}")


if __name__ == "__main__":          # pragma: no cover
    raise SystemExit(main())
