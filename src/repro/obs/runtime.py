"""The observability on/off switch and process-wide default instances.

Instrument sites all follow one pattern::

    from repro import obs
    m = obs.metrics()
    if m is not None:
        m.counter("launch.count", kernel=name).inc()

When observability is disabled (the default) ``metrics()``/``tracer()``
return ``None`` — the per-event cost is one module-global read plus one
``is not None`` branch, measured and gated by
``benchmarks/overhead.py --check`` so instrumentation can sit directly on
the launch hot path.

Enable explicitly with :func:`enable` (returns the registry + tracer so
callers can snapshot/save them) or ambiently with
``KERNEL_LAUNCHER_OBS=1`` in the environment, which enables at import
time — the zero-code-change way to get telemetry out of an existing
deployment.
"""

from __future__ import annotations

import os

from .metrics import MetricsRegistry
from .trace import Tracer

OBS_ENV = "KERNEL_LAUNCHER_OBS"

_metrics: MetricsRegistry | None = None
_tracer: Tracer | None = None


def obs_requested() -> bool:
    """KERNEL_LAUNCHER_OBS=1 enables metrics + tracing at import time."""
    return os.environ.get(OBS_ENV, "").lower() in ("1", "true", "on", "yes")


def enable(registry: MetricsRegistry | None = None,
           tracer: Tracer | None = None,
           trace: bool = True) -> tuple[MetricsRegistry, Tracer | None]:
    """Turn observability on for this process.

    Installs (or accepts) a :class:`MetricsRegistry` and, unless
    ``trace=False``, a :class:`Tracer`, and returns both — idempotent:
    enabling twice keeps the already-installed instances so counters
    never reset mid-run.
    """
    global _metrics, _tracer
    if _metrics is None:
        _metrics = registry if registry is not None else MetricsRegistry()
    if trace and _tracer is None:
        _tracer = tracer if tracer is not None else Tracer()
    return _metrics, _tracer


def disable() -> None:
    """Turn observability off (instrument sites see ``None`` again)."""
    global _metrics, _tracer
    _metrics = None
    _tracer = None


def enabled() -> bool:
    return _metrics is not None


def metrics() -> MetricsRegistry | None:
    """The process registry, or None when observability is disabled —
    THE hot-path check: one global read, one branch."""
    return _metrics


def tracer() -> Tracer | None:
    """The process tracer, or None when disabled (or metrics-only)."""
    return _tracer


if obs_requested():            # pragma: no cover — env-dependent
    enable()
