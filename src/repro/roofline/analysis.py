"""Three-term roofline analysis from compiled dry-run artifacts.

  compute    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory     = HLO_bytes_per_chip / HBM_bw
  collective = collective_bytes_per_chip / link_bw

``compiled.cost_analysis()`` supplies FLOPs and bytes for the per-device
(SPMD-partitioned) module. Collective bytes are not in cost_analysis — we
parse the optimized HLO text and sum the output-operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants (TPU v5e target): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass

import numpy as np

from repro.models.config import ArchConfig


@dataclass(frozen=True)
class HW:
    peak_flops_bf16: float = 197e12
    peak_flops_f32: float = 98.5e12
    hbm_bw: float = 819e9
    ici_bw: float = 50e9
    hbm_bytes: int = 16 * 2**30


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "f32[16,128,8]{2,1,0}" or "bf16[]"
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, float]:
    """Sum output bytes per collective kind (deduping -start/-done pairs
    by counting only -start or the plain op)."""
    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    seen_start: set[str] = set()
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # counted at -start
        m = _OP_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(type_str)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def model_flops(cfg: ArchConfig, kind: str, global_batch: int,
                seq: int) -> float:
    """Reference useful FLOPs: 6·N_active·tokens (train) or
    2·N_active·tokens (inference)."""
    n = cfg.n_active_params()
    if kind == "train":
        tokens = global_batch * seq
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = global_batch * seq
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * global_batch * 1


def roofline_report(*, flops_per_chip: float, bytes_per_chip: float,
                    collective_per_chip: dict[str, float], chips: int,
                    cfg: ArchConfig, kind: str, global_batch: int, seq: int,
                    dtype: str = "bfloat16", hw: HW = HW()) -> dict:
    peak = hw.peak_flops_bf16 if dtype == "bfloat16" else hw.peak_flops_f32
    t_compute = flops_per_chip / peak
    t_memory = bytes_per_chip / hw.hbm_bw
    t_collective = collective_per_chip.get("total", 0.0) / hw.ici_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, kind, global_batch, seq)
    hlo_flops_global = flops_per_chip * chips
    useful = mf / hlo_flops_global if hlo_flops_global else 0.0
    bound = max(t_compute, t_memory, t_collective)
    # roofline fraction: useful model FLOPs per chip over what the dominant
    # term's time would allow at peak compute
    ideal_s = (mf / chips) / peak
    frac = ideal_s / bound if bound > 0 else 0.0
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops_per_chip": flops_per_chip,
        "hlo_bytes_per_chip": bytes_per_chip,
        "collective_bytes_per_chip": collective_per_chip,
        "useful_flops_ratio": useful,
        "roofline_fraction": frac,
        "step_time_bound_s": bound,
    }
