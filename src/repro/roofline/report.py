"""Render EXPERIMENTS.md tables from the dry-run JSON artifacts."""

from __future__ import annotations

import json
from pathlib import Path


def load_records(dryrun_dir: Path | str) -> list[dict]:
    out = []
    for p in sorted(Path(dryrun_dir).glob("*.json")):
        with open(p) as f:
            out.append(json.load(f))
    return out


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def dryrun_table(records: list[dict], mesh_tag: str) -> str:
    rows = ["| arch | shape | status | compile | flops/chip | bytes/chip "
            "| coll/chip | temp GiB |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"])):
        if r.get("mesh_tag") != mesh_tag:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP (documented) "
                        f"| — | — | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | **{r['status']}** "
                        f"| — | — | — | — | — |")
            continue
        w = r["hlo_walk"]
        coll = r["collective_bytes"]["total"]
        tmp = r["memory_analysis"].get("temp_bytes")
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']:.0f}s "
            f"| {w['flops']:.2e} | {w['bytes_fused']:.2e} | {coll:.2e} "
            f"| {tmp/2**30:.1f} |" if tmp else
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']:.0f}s "
            f"| {w['flops']:.2e} | {w['bytes_fused']:.2e} | {coll:.2e} "
            f"| n/a |")
    return "\n".join(rows)


def roofline_table(records: list[dict], mesh_tag: str = "singlepod") -> str:
    rows = ["| arch | shape | compute | memory | collective | dominant "
            "| model TF | useful | roofline |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"])):
        if r.get("mesh_tag") != mesh_tag or r["status"] != "ok":
            continue
        f = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(f['compute_s'])} "
            f"| {_fmt_s(f['memory_s'])} | {_fmt_s(f['collective_s'])} "
            f"| {f['dominant']} | {f['model_flops']/1e12:.1f} "
            f"| {f['useful_flops_ratio']:.2f} "
            f"| {f['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def main() -> None:  # pragma: no cover
    recs = load_records("experiments/dryrun")
    print("## Dry-run (single pod)\n")
    print(dryrun_table(recs, "singlepod"))
    print("\n## Dry-run (multi-pod)\n")
    print(dryrun_table(recs, "multipod"))
    print("\n## Roofline (single pod)\n")
    print(roofline_table(recs))


if __name__ == "__main__":  # pragma: no cover
    main()
