"""Trip-count-aware HLO cost walker.

``compiled.cost_analysis()`` counts each while-loop *body once* — for a
scan-over-layers model that under-reports FLOPs/bytes/collectives by ~L×.
This module parses the optimized (post-SPMD) HLO text, recovers each while
loop's trip count from its condition computation, and walks the call graph
(ENTRY -> fusion/call/while/conditional) accumulating:

  * flops        — dots exactly (2·M·N·K from contracting dims), elementwise
                   approximately (1 op/element);
  * hbm bytes    — operand+output bytes at fusion boundaries (inside a
                   fusion, traffic is internal VMEM/registers and skipped);
  * collectives  — output bytes per kind, trip-multiplied.

The walker is validated against unrolled-vs-scan equivalence in the tests.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "f8e3m4": 1, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_ELEMENTWISE_1 = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "power", "compare", "and", "or", "xor", "not", "negate", "abs",
    "exponential", "exponential-minus-one", "log", "log-plus-one",
    "sqrt", "rsqrt", "cbrt", "tanh", "sine", "cosine", "logistic",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "sign",
    "select", "clamp", "convert", "atan2", "erf",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "iota", "partition-id", "replica-id"}


def _shape_dims(type_str: str) -> list[tuple[str, int]]:
    """[(dtype, numel)] for each array in a (possibly tuple) type string."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dt, n))
    return out


def _numel(type_str: str) -> int:
    return sum(n for _, n in _shape_dims(type_str))


def _nbytes(type_str: str) -> int:
    return sum(n * _DTYPE_BYTES[dt] for dt, n in _shape_dims(type_str))


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str          # raw remainder of the line (operands + attributes)


@dataclass
class Computation:
    name: str
    ops: dict[str, Op] = field(default_factory=dict)
    param_types: dict[str, str] = field(default_factory=dict)


_COMP_HEADER = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s+->")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(.*)$")
_SIMPLE_TYPE = re.compile(
    r"^([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+([a-z][a-z0-9\-]*)\(")
_OPCODE_AFTER_TUPLE = re.compile(r"^\s+([a-z][a-z0-9\-]*)\(")
_TRIP_BACKEND = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _split_type_opcode(rhs: str) -> tuple[str, str, str] | None:
    """rhs of an op line -> (type_str, opcode, rest). Handles tuple types
    containing '/*index=N*/' comments via balanced-paren scanning."""
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    m = _OPCODE_AFTER_TUPLE.match(rhs[i + 1:])
                    if not m:
                        return None
                    return (rhs[:i + 1], m.group(1),
                            rhs[i + 1 + m.end():])
        return None
    m = _SIMPLE_TYPE.match(rhs)
    if not m:
        return None
    return m.group(1), m.group(2), rhs[m.end():]
_CALLED = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_RCONTRACT = re.compile(r"rhs_contracting_dims=\{([0-9,]*)\}")
_INT_CONST = re.compile(r"=\s+[su](?:8|16|32|64)\[\]\s+constant\((\d+)\)")


def parse_hlo(text: str) -> tuple[dict[str, Computation], str]:
    """Returns ({name: Computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = ""
    cur: Computation | None = None
    for line in text.splitlines():
        if line.endswith("{") and ("->" in line):
            m = _COMP_HEADER.match(line.strip())
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
                for pm in re.finditer(
                        r"%?([\w.\-]+):\s+((?:\([^)]*\))|(?:[a-z0-9]+"
                        r"\[[0-9,]*\](?:\{[^}]*\})?))", m.group(2)):
                    cur.param_types[pm.group(1)] = pm.group(2)
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        parts = _split_type_opcode(rhs)
        if parts is None:
            continue
        type_str, opcode, rest = parts
        cur.ops[name] = Op(name, type_str, opcode, rest)
    return comps, entry


def _dims_of(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class HloCost:
    def __init__(self, text: str):
        self.comps, self.entry = parse_hlo(text)
        self._memo: dict[tuple[str, bool], dict] = {}

    # -- helpers -------------------------------------------------------------

    def _operand_type(self, comp: Computation, opname: str) -> str | None:
        if opname in comp.ops:
            return comp.ops[opname].type_str
        if opname in comp.param_types:
            return comp.param_types[opname]
        return None

    def _trip_count(self, cond_name: str) -> int:
        """Recover a canonical counted loop's bound from its condition
        computation (jax scans lower to `i < N` with a scalar constant)."""
        cond = self.comps.get(cond_name)
        if cond is None:
            return 1
        consts = []
        for op in cond.ops.values():
            if op.opcode == "constant" and op.type_str.startswith(
                    ("s32", "s64", "u32", "u64")):
                # op.rest starts right after "constant(" -> e.g. "10), ..."
                m = re.match(r"(\d+)\)", op.rest)
                if m:
                    consts.append(int(m.group(1)))
        if not consts:
            return 1
        return max(consts)

    def _dot_flops(self, comp: Computation, op: Op) -> float:
        out_elems = _numel(op.type_str)
        names = _OPERANDS.findall(op.rest)
        c = _CONTRACT.search(op.rest)
        if c and names:
            lhs_t = self._operand_type(comp, names[0])
            if lhs_t:
                dims = _dims_of(lhs_t)
                k = 1
                for i in (int(x) for x in c.group(1).split(",") if x):
                    if i < len(dims):
                        k *= dims[i]
                return 2.0 * out_elems * k
        r = _RCONTRACT.search(op.rest)
        if r and len(names) > 1:
            rhs_t = self._operand_type(comp, names[1])
            if rhs_t:
                dims = _dims_of(rhs_t)
                k = 1
                for i in (int(x) for x in r.group(1).split(",") if x):
                    if i < len(dims):
                        k *= dims[i]
                return 2.0 * out_elems * k
        return 2.0 * out_elems  # fallback

    # -- the walk ------------------------------------------------------------

    def cost(self, comp_name: str | None = None,
             in_fusion: bool = False) -> dict:
        name = comp_name or self.entry
        key = (name, in_fusion)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        zero = {"flops": 0.0, "bytes": 0.0, "bytes_upper": 0.0,
                "collectives": {k: 0.0 for k in _COLLECTIVES}}
        if comp is None:
            return zero
        total = {"flops": 0.0, "bytes": 0.0, "bytes_upper": 0.0,
                 "collectives": {k: 0.0 for k in _COLLECTIVES}}

        def add(d: dict, scale: float = 1.0):
            total["flops"] += d["flops"] * scale
            total["bytes"] += d["bytes"] * scale
            total["bytes_upper"] += d["bytes_upper"] * scale
            for k in _COLLECTIVES:
                total["collectives"][k] += d["collectives"][k] * scale

        for op in comp.ops.values():
            oc = op.opcode
            if oc == "while":
                bt = _TRIP_BACKEND.search(op.rest)
                if bt:
                    trips = int(bt.group(1))
                else:
                    m = re.search(r"condition=%?([\w.\-]+)", op.rest)
                    trips = self._trip_count(m.group(1)) if m else 1
                b = re.search(r"body=%?([\w.\-]+)", op.rest)
                if b:
                    add(self.cost(b.group(1), in_fusion), trips)
                continue
            if oc == "fusion":
                c = re.search(r"calls=%?([\w.\-]+)", op.rest)
                if c:
                    add(self.cost(c.group(1), True))
                if not in_fusion:
                    # fusion boundary: counts only toward the pessimistic
                    # (CPU-schedule) bound — a TPU schedule fuses further.
                    total["bytes_upper"] += 2.0 * _nbytes(op.type_str)
                continue
            if oc in ("call", "async-start"):
                c = re.search(r"to_apply=%?([\w.\-]+)", op.rest)
                if c:
                    add(self.cost(c.group(1), in_fusion))
                continue
            if oc == "conditional":
                bm = _BRANCHES.search(op.rest)
                if bm:
                    branch_costs = [
                        self.cost(x.strip().lstrip("%"), in_fusion)
                        for x in bm.group(1).split(",")]
                    if branch_costs:
                        # charge the most expensive branch
                        best = max(branch_costs, key=lambda d: d["flops"])
                        add(best)
                continue
            base = oc.split("-start")[0] if oc.endswith("-start") else oc
            if base in _COLLECTIVES:
                nb = _nbytes(op.type_str)
                total["collectives"][base] += nb
                if not in_fusion:
                    total["bytes"] += float(nb)
                    total["bytes_upper"] += float(nb)
                continue
            counts_traffic = False
            if oc == "dot" or oc == "convolution":
                total["flops"] += self._dot_flops(comp, op)
                counts_traffic = True
            elif oc in _ELEMENTWISE_1:
                total["flops"] += _numel(op.type_str)
                # bare elementwise would be fused on TPU: no HBM charge
            elif oc == "reduce":
                # approximate: one op per input element
                names = _OPERANDS.findall(op.rest)
                t = self._operand_type(comp, names[0]) if names else None
                total["flops"] += _numel(t) if t else _numel(op.type_str)
                counts_traffic = True
            elif oc in ("copy", "transpose", "reverse", "pad", "concatenate",
                        "dynamic-slice", "dynamic-update-slice", "gather",
                        "scatter", "slice", "sort", "reduce-window",
                        "select-and-scatter"):
                counts_traffic = True
            if counts_traffic and not in_fusion:
                t = self._op_traffic(comp, op)
                total["bytes"] += t
                total["bytes_upper"] += t

        total["collectives"]["total"] = sum(
            total["collectives"][k] for k in _COLLECTIVES)
        self._memo[key] = total
        return total

    # ops that read only an output-sized window of (possibly huge) operands
    _SLICING = {"dynamic-slice", "gather", "slice"}
    # ops that write only an update-sized window
    _UPDATING = {"dynamic-update-slice", "scatter"}

    def _fused_is_slicing(self, comp_name: str) -> bool:
        c = self.comps.get(comp_name)
        if c is None:
            return False
        return any(o.opcode in self._SLICING | self._UPDATING
                   for o in c.ops.values())

    def _op_traffic(self, comp: Computation, op: Op) -> float:
        """HBM traffic proxy: output + operand bytes — with slicing ops
        (and fusions containing them) charging only the touched window,
        not the whole backing buffer."""
        out_b = _nbytes(op.type_str)
        if op.opcode in self._SLICING:
            return 2.0 * out_b
        if op.opcode in self._UPDATING:
            # traffic ~ the UPDATE window (read+write), not the full
            # aliased buffer the op's output type names
            names = _OPERANDS.findall(op.rest)
            if len(names) >= 2:
                t = self._operand_type(comp, names[1])
                if t is not None:
                    return 3.0 * _nbytes(t)
            return 3.0 * out_b
        slicing_fusion = False
        if op.opcode == "fusion":
            c = re.search(r"calls=%?([\w.\-]+)", op.rest)
            slicing_fusion = bool(c) and self._fused_is_slicing(c.group(1))
        nb = float(out_b)
        names = _OPERANDS.findall(op.rest)
        seen = 0
        for n in names:
            t = self._operand_type(comp, n)
            if t is None:
                continue
            ob = _nbytes(t)
            if slicing_fusion and ob > 4 * out_b:
                ob = out_b  # the fusion only touches a window of this
            nb += ob
            seen += 1
            if seen >= 6:
                break
        return nb


def hlo_cost_analysis(text: str) -> dict:
    """Trip-count-aware {flops, bytes, collectives{kind: bytes, total}}."""
    return HloCost(text).cost()
